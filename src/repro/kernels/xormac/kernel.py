"""Pallas TPU kernel: NH universal hash for optBlk MACs ("Integ Engine").

Computes the data-proportional part of the SeDA MAC: the NH hash
(multiply-accumulate over uint32 lanes, 64-bit accumulation emulated on
32-bit VPU lanes).  The per-block AES finalization runs on the (tiny)
hash list via the aes_ctr kernel.

The 64-bit row reduction uses a carry-free decomposition instead of a
sequential carry chain: the low words are split into 16-bit halves and
summed exactly in uint32 (exact while pairs-per-block <= 2^16, i.e.
optBlk <= 512 KiB), then recombined with an explicit carry into the
high word.  This keeps the whole reduction vectorized on the VPU —
no fori_loop dependency chain (the in-kernel equivalent of the paper's
parallelizable XOR-MAC argument).

    HBM -> VMEM: payload tile (TILE_N, L) u32, NH key (L,) u32
    VMEM -> HBM: hashes (TILE_N, 2) u32
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv, default_interpret

__all__ = ["nh_hash_kernel_call"]


def _nh_kernel(payload_ref, key_ref, out_ref):
    m = payload_ref[...]                      # (T, L) u32
    k = key_ref[...]                          # (L,) u32
    a = m[:, 0::2] + k[None, 0::2]            # (T, L/2) u32 (wraps)
    b = m[:, 1::2] + k[None, 1::2]

    # 32x32 -> 64-bit products as (hi, lo) u32 pairs.
    mask = jnp.uint32(0xFFFF)
    a_lo, a_hi = a & mask, a >> 16
    b_lo, b_hi = b & mask, b >> 16
    ll = a_lo * b_lo
    mid = a_lo * b_hi + a_hi * b_lo           # may wrap: recover carry
    mid_carry = (mid < a_lo * b_hi).astype(jnp.uint32)
    lo = ll + (mid << 16)
    lo_carry = (lo < ll).astype(jnp.uint32)
    hi = a_hi * b_hi + (mid >> 16) + (mid_carry << 16) + lo_carry

    # Exact vectorized 64-bit row sum: split lo into 16-bit halves.
    s0 = jnp.sum(lo & mask, axis=1, dtype=jnp.uint32)    # <= 2^16 terms * 2^16
    s1 = jnp.sum(lo >> 16, axis=1, dtype=jnp.uint32)
    t = (s0 >> 16) + s1
    lo_sum = (s0 & mask) | ((t & mask) << 16)
    carry = t >> 16
    hi_sum = jnp.sum(hi, axis=1, dtype=jnp.uint32) + carry
    out_ref[...] = jnp.stack([hi_sum, lo_sum], axis=-1)  # (T, 2)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def nh_hash_kernel_call(payload_u32: jax.Array, key_u32: jax.Array, *,
                        tile_n: int = 256,
                        interpret: bool | None = None) -> jax.Array:
    """(N, L) u32 payload + (L,) u32 key -> (N, 2) u32 NH hashes."""
    if interpret is None:
        interpret = default_interpret()
    n, lanes = payload_u32.shape
    assert lanes % 2 == 0
    assert lanes // 2 <= 65536, "optBlk too large for exact vectorized sum"
    tile_n = min(tile_n, max(8, n))
    n_pad = cdiv(n, tile_n) * tile_n
    payload_p = jnp.zeros((n_pad, lanes), jnp.uint32).at[:n].set(payload_u32)

    out = pl.pallas_call(
        _nh_kernel,
        grid=(n_pad // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, lanes), lambda i: (i, 0)),
            pl.BlockSpec((lanes,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_n, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 2), jnp.uint32),
        interpret=interpret,
    )(payload_p, key_u32)
    return out[:n]
