"""CI gate: the chaos bench must show faults contained, not survived
by luck.

Reads a ``bench_chaos.py`` JSON artifact and fails (exit 1) unless
every row shows:

* ``sessions_lost == 0`` — no session was declared dead; every victim
  of the injected fault recovered within the retry budget;
* ``sessions_recovered > 0`` — the fault actually fired and recovery
  actually ran (a silently dead injection hook would otherwise make
  the identity checks vacuous);
* ``unaffected_identical`` — sessions untouched by the fault produced
  bit-identical tokens to the fault-free run (quarantine blast radius
  stayed at one session / one shard);
* ``recovered_identical`` — the recovered sessions' recomputed tokens
  bit-match the fault-free run (secure recompute, not approximation);

and every ``shard_kill`` row additionally ``shard_failovers > 0``.

Usage::

    python benchmarks/check_chaos.py bench-chaos.json
"""

from __future__ import annotations

import json
import sys


def check_rows(results: list) -> int:
    if not results:
        print("[chaos] FAIL: no chaos rows to gate on")
        return 1
    ok = True

    def fail(label: str, msg: str) -> None:
        nonlocal ok
        print(f"[chaos] FAIL: {label}: {msg}")
        ok = False

    for r in results:
        label = r.get("name", r.get("scheme", "?"))
        if r.get("sessions_lost", 0) != 0:
            fail(label, f"{r['sessions_lost']} session(s) lost — recovery "
                        f"did not bring every victim back")
        if not r.get("sessions_recovered", 0):
            fail(label, "zero sessions_recovered — the injected fault "
                        "never fired or containment never ran")
        if not r.get("unaffected_identical", False):
            fail(label, "unaffected sessions diverged from the fault-free "
                        "run — containment leaked across sessions")
        if not r.get("recovered_identical", False):
            fail(label, "recovered sessions diverged from the fault-free "
                        "run — recompute recovery is not exact")
        if r.get("mode") == "shard_kill" and not r.get("shard_failovers", 0):
            fail(label, "shard-kill row recorded zero shard_failovers")
    n_kill = sum(1 for r in results if r.get("mode") == "shard_kill")
    print(f"[chaos] {len(results)} rows ({n_kill} shard-kill) checked")
    return 0 if ok else 1


def check(path: str) -> int:
    with open(path) as f:
        data = json.load(f)
    rc = check_rows(data.get("results", []))
    if rc == 0:
        print("[chaos] ok")
    return rc


if __name__ == "__main__":
    sys.exit(check(sys.argv[1]))
