"""Integrity MACs for SeDA (paper §III-C, Alg. 2).

Per-optBlk MAC, XOR-aggregated layer MAC, and model MAC.

Two MAC engines are provided:

* ``nh``  (default): UMAC-style — an NH universal hash compresses the
  optBlk payload to 64 bits (multiply-accumulate over uint32 lanes with
  emulated 64-bit accumulation — MXU/VPU-friendly on TPU), then a
  single AES-128 invocation over ``NH || binding`` acts as the PRF
  finalizer.  One AES call per optBlk regardless of its size.
* ``cbc``: AES-CBC-MAC over ``binding-block ‖ payload segments`` — pure
  AES, one call per 16B segment; the bit-exact conservative choice.

RePA defense: the *binding tuple* ``(PA, VN, layer_id, fmap_idx,
blk_idx)`` is mixed into every block MAC (Alg. 2 lines 7-8), so XOR
aggregation is order-sensitive in content: shuffling ciphertext blocks
changes every constituent MAC and the XOR no longer verifies.

The RePA-*vulnerable* strawman (hash of ciphertext only, as in
Securator's layer check) is exposed as ``engine="naive"`` for the
attack demonstration in tests/examples.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import aes

__all__ = [
    "Binding",
    "block_macs",
    "xor_aggregate",
    "layer_mac",
    "model_mac",
    "verify_layer",
    "MAC_BYTES",
]

MAC_BYTES = 8  # 64-bit MACs, as in the paper's 8B-MAC-per-64B-block example.


class Binding(NamedTuple):
    """Location details bound into each optBlk MAC (Alg. 2, line 8).

    All fields are uint32 arrays broadcastable to (n_blocks,).
    """

    pa: jax.Array         # physical address of the block
    vn: jax.Array         # version number
    layer_id: jax.Array
    fmap_idx: jax.Array
    blk_idx: jax.Array

    @staticmethod
    def make(pa, vn, layer_id, fmap_idx, blk_idx) -> "Binding":
        as_u32 = lambda v: jnp.asarray(v, dtype=jnp.uint32)
        return Binding(as_u32(pa), as_u32(vn), as_u32(layer_id),
                       as_u32(fmap_idx), as_u32(blk_idx))

    def words(self, n_blocks: int) -> jax.Array:
        """(n_blocks, 8) uint32: binding serialized as two 16B segments
        worth of words (padded), for mixing into hash inputs."""
        cols = [jnp.broadcast_to(f, (n_blocks,)) for f in self]
        cols += [jnp.zeros((n_blocks,), jnp.uint32)] * (8 - len(cols))
        return jnp.stack(cols, axis=-1)


# ---------------------------------------------------------------------------
# Emulated 64-bit accumulation on uint32 pairs.
# ---------------------------------------------------------------------------


def _mul32x32(a: jax.Array, b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Full 64-bit product of uint32 operands -> (hi, lo) uint32."""
    a_lo, a_hi = a & 0xFFFF, a >> 16
    b_lo, b_hi = b & 0xFFFF, b >> 16
    ll = a_lo * b_lo
    lh = a_lo * b_hi
    hl = a_hi * b_lo
    hh = a_hi * b_hi
    # lo = ll + ((lh + hl) << 16)   with carries into hi
    mid = lh + hl  # uint32 wraparound; carry recovered below
    mid_carry = (mid < lh).astype(jnp.uint32)  # carry out of 32-bit mid sum
    lo = ll + (mid << 16)  # uint32 wraparound
    lo_carry = (lo < ll).astype(jnp.uint32)
    hi = hh + (mid >> 16) + (mid_carry << 16) + lo_carry
    return hi, lo


def _add64(hi1, lo1, hi2, lo2) -> tuple[jax.Array, jax.Array]:
    lo = lo1 + lo2
    carry = (lo < lo1).astype(jnp.uint32)
    return hi1 + hi2 + carry, lo


def nh_hash(lanes_u32: jax.Array, key_u32: jax.Array) -> tuple[jax.Array, jax.Array]:
    """NH hash over the last axis: (..., 2L) u32 data, (2L,) u32 key.

    NH(m, k) = sum_i (m_{2i} + k_{2i}) * (m_{2i+1} + k_{2i+1})  mod 2^64.

    Returns (hi, lo) uint32 arrays of shape (...,).
    """
    m = lanes_u32.astype(jnp.uint32)
    k = key_u32.astype(jnp.uint32)
    a = (m[..., 0::2] + k[..., 0::2]).astype(jnp.uint32)
    b = (m[..., 1::2] + k[..., 1::2]).astype(jnp.uint32)
    hi, lo = _mul32x32(a, b)
    # Reduce along the last axis: sum the lo words tracking carries into hi.
    zeros = jnp.zeros(m.shape[:-1], jnp.uint32)

    def body(i, state):
        lo_sum, hi_sum = state
        new_lo = lo_sum + lo[..., i]
        carry = (new_lo < lo_sum).astype(jnp.uint32)
        return new_lo, hi_sum + hi[..., i] + carry

    lo_sum, hi_sum = jax.lax.fori_loop(0, lo.shape[-1], body, (zeros, zeros))
    return hi_sum, lo_sum


# ---------------------------------------------------------------------------
# Block MAC engines.
# ---------------------------------------------------------------------------


def nh_payload(blocks_u8: jax.Array, binding: Binding) -> jax.Array:
    """Build the NH input lanes: data lanes ‖ binding words, even length."""
    n_blocks, block_bytes = blocks_u8.shape
    lanes = jax.lax.bitcast_convert_type(
        blocks_u8.reshape(n_blocks, block_bytes // 4, 4), jnp.uint32)
    bind_words = binding.words(n_blocks)  # (n_blocks, 8)
    payload = jnp.concatenate([lanes, bind_words], axis=-1)  # (n, L+8)
    if payload.shape[-1] % 2:
        payload = jnp.pad(payload, ((0, 0), (0, 1)))
    return payload


def finalize_words(hi: jax.Array, lo: jax.Array, binding: Binding) -> jax.Array:
    """Counter words for the AES PRF finalization of an NH hash."""
    return jnp.stack(
        [hi, lo,
         jnp.broadcast_to(binding.pa, hi.shape) ^ jnp.broadcast_to(binding.layer_id, hi.shape),
         jnp.broadcast_to(binding.vn, hi.shape)
         ^ (jnp.broadcast_to(binding.fmap_idx, hi.shape) << 16)
         ^ jnp.broadcast_to(binding.blk_idx, hi.shape)],
        axis=-1)  # (n_blocks, 4) u32


def finalize_macs(hi: jax.Array, lo: jax.Array, binding: Binding,
                  round_keys: jax.Array) -> jax.Array:
    """AES(K, hash64 ‖ binding) -> truncated (n, MAC_BYTES) u8 MACs."""
    from repro.core import ctr as _ctr  # local import to avoid cycle
    fin = finalize_words(hi, lo, binding)
    blockpads = aes.aes128_encrypt_block(_ctr.counter_blocks(fin), round_keys)
    return blockpads[:, :MAC_BYTES]


def _nh_block_macs(blocks_u8: jax.Array, binding: Binding,
                   hash_key_u32: jax.Array, round_keys: jax.Array) -> jax.Array:
    """(n_blocks, block_bytes) u8 -> (n_blocks, 8) u8 MACs via NH + AES."""
    payload = nh_payload(blocks_u8, binding)
    if hash_key_u32.shape[-1] < payload.shape[-1]:
        raise ValueError(
            f"NH key too short: {hash_key_u32.shape[-1]} lanes for "
            f"{payload.shape[-1]}-lane payload (optBlk too large)")
    key = hash_key_u32[: payload.shape[-1]]
    hi, lo = nh_hash(payload, key)
    return finalize_macs(hi, lo, binding, round_keys)


def _cbc_block_macs(blocks_u8: jax.Array, binding: Binding,
                    round_keys: jax.Array) -> jax.Array:
    """AES-CBC-MAC over binding-block ‖ payload segments -> (n, 8) u8."""
    n_blocks, block_bytes = blocks_u8.shape
    n_segments = block_bytes // 16
    from repro.core import ctr as _ctr
    bind_words = binding.words(n_blocks)[:, :4]  # (n, 4) u32
    state = aes.aes128_encrypt_block(_ctr.counter_blocks(bind_words), round_keys)
    segs = blocks_u8.reshape(n_blocks, n_segments, 16)

    def body(i, state):
        return aes.aes128_encrypt_block(state ^ segs[:, i], round_keys)

    state = jax.lax.fori_loop(0, n_segments, body, state)
    return state[:, :MAC_BYTES]


def _naive_block_macs(blocks_u8: jax.Array, round_keys: jax.Array) -> jax.Array:
    """RePA-VULNERABLE strawman: MAC depends on ciphertext only (no
    binding).  Securator-style layer check target for Alg. 2's attack."""
    n_blocks, block_bytes = blocks_u8.shape
    n_segments = block_bytes // 16
    segs = blocks_u8.reshape(n_blocks, n_segments, 16)
    state = jnp.zeros((n_blocks, 16), jnp.uint8)

    def body(i, state):
        return aes.aes128_encrypt_block(state ^ segs[:, i], round_keys)

    state = jax.lax.fori_loop(0, n_segments, body, state)
    return state[:, :MAC_BYTES]


@functools.partial(jax.jit, static_argnames=("engine",))
def block_macs(blocks_u8: jax.Array, binding: Binding, *,
               hash_key_u32: jax.Array, round_keys: jax.Array,
               engine: str = "nh") -> jax.Array:
    """Per-optBlk MACs: (n_blocks, block_bytes) u8 -> (n_blocks, 8) u8."""
    if engine == "nh":
        return _nh_block_macs(blocks_u8, binding, hash_key_u32, round_keys)
    if engine == "cbc":
        return _cbc_block_macs(blocks_u8, binding, round_keys)
    if engine == "naive":
        return _naive_block_macs(blocks_u8, round_keys)
    raise ValueError(f"unknown MAC engine: {engine}")


# ---------------------------------------------------------------------------
# Multi-level aggregation.
# ---------------------------------------------------------------------------


def xor_aggregate(macs_u8: jax.Array, axis: int = 0) -> jax.Array:
    """XOR-MAC aggregation (Bellare et al.): XOR of all block MACs."""
    lanes = jax.lax.bitcast_convert_type(
        macs_u8.reshape(macs_u8.shape[:-1] + (MAC_BYTES // 4, 4)), jnp.uint32)
    agg = jax.lax.reduce(lanes, jnp.uint32(0), jax.lax.bitwise_xor, (axis,))
    return jax.lax.bitcast_convert_type(agg[..., None], jnp.uint8).reshape(
        agg.shape[:-1] + (MAC_BYTES,))


def layer_mac(blocks_u8: jax.Array, binding: Binding, *, hash_key_u32,
              round_keys, engine: str = "nh") -> jax.Array:
    """Layer MAC = XOR of all optBlk MACs within the layer -> (8,) u8."""
    return xor_aggregate(
        block_macs(blocks_u8, binding, hash_key_u32=hash_key_u32,
                   round_keys=round_keys, engine=engine))


def model_mac(layer_macs_u8: jax.Array) -> jax.Array:
    """Model MAC: single MAC representing all layer MACs -> (8,) u8."""
    return xor_aggregate(layer_macs_u8)


def verify_layer(blocks_u8: jax.Array, binding: Binding, expected_mac: jax.Array,
                 *, hash_key_u32, round_keys, engine: str = "nh") -> jax.Array:
    """Recompute a layer MAC and compare: returns a scalar bool array."""
    got = layer_mac(blocks_u8, binding, hash_key_u32=hash_key_u32,
                    round_keys=round_keys, engine=engine)
    return jnp.all(got == expected_mac)
