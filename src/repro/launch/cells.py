"""Cell builders: one lowerable program per (arch × shape × mesh).

A *cell* is the unit of the multi-pod dry-run: the jitted step function
plus ShapeDtypeStruct arguments and planner shardings.  Used by
launch/dryrun.py and launch/roofline.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import OPT_DTYPE_OVERRIDES, SHAPES, get_arch
from repro.configs.base import ArchDef, Shape
from repro.launch import sharding as shp
from repro.models import encdec as ed
from repro.models import lm as lm_mod
from repro.models.layers import shape_structs
from repro.models.partitioning import activation_context
from repro.serve.serve_step import make_decode_step, make_prefill_step
from repro.train.optimizer import AdamWConfig, opt_state_specs
from repro.train.train_step import make_train_step

__all__ = ["Cell", "build_cell", "ENCDEC_DECODE_SRC_LEN"]

ENCDEC_DECODE_SRC_LEN = 1024


@dataclasses.dataclass
class Cell:
    arch: ArchDef
    shape: Shape
    fn: Callable
    args: tuple                # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any         # pytree or None
    meta: dict

    def activation_rules(self, mesh) -> dict:
        """Logical activation axes -> mesh axes for this cell."""
        b_axis, s_axis = shp.batch_sharding(mesh, self.shape.global_batch)
        return {"batch": b_axis, "seq": s_axis, "residual": None,
                "vocab": "model", "experts": "model", "mlp": "model"}

    def lower(self, mesh):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings)
        with activation_context(mesh, self.activation_rules(mesh)):
            return jitted.lower(*self.args)


def _token_struct(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def _batch_structs(arch: ArchDef, cfg, shape: Shape) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if arch.kind == "encdec":
        src = s // 2
        tgt = s - src
        return {
            "src_embeds": jax.ShapeDtypeStruct((b, src, cfg.d_model),
                                               jnp.dtype(cfg.dtype)),
            "tgt_tokens": _token_struct(b, tgt),
            "labels": _token_struct(b, tgt),
        }
    batch = {}
    text = s
    if cfg.n_image_patches:
        text = s - cfg.n_image_patches
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_image_patches, cfg.d_vision), jnp.dtype(cfg.dtype))
    batch["tokens"] = _token_struct(b, text)
    batch["labels"] = _token_struct(b, text)
    return batch


def _batch_shardings(arch: ArchDef, cfg, shape: Shape, mesh) -> dict:
    tok = shp.token_sharding(mesh, shape.global_batch, shape.seq_len)
    b_axis = tok.spec[0] if len(tok.spec) else None
    if arch.kind == "encdec":
        return {
            "src_embeds": NamedSharding(mesh, P(b_axis, None, None)),
            "tgt_tokens": tok, "labels": tok,
        }
    out = {"tokens": tok, "labels": tok}
    if cfg.n_image_patches:
        out["image_embeds"] = NamedSharding(mesh, P(b_axis, None, None))
    return out


def _param_specs(arch: ArchDef, cfg):
    if arch.kind == "encdec":
        return ed.encdec_specs(cfg)
    return lm_mod.lm_specs(cfg)


def build_cell(arch_name: str, shape_name: str, mesh, *,
               smoke: bool = False) -> Cell:
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    if not arch.supports(shape):
        raise ValueError(
            f"{arch_name} skips {shape_name} (full-attention arch; "
            f"DESIGN.md §5)")
    cfg = arch.make_smoke_config() if smoke else arch.make_config()
    rules = arch.sharding_rules()

    specs = _param_specs(arch, cfg)
    params_structs = shape_structs(specs)
    params_shard = shp.param_shardings(specs, rules, mesh)
    meta = {"arch": arch_name, "shape": shape_name, "kind": shape.kind}

    if shape.kind == "train":
        opt_cfg = AdamWConfig(
            state_dtype=OPT_DTYPE_OVERRIDES.get(arch_name, "float32"))
        opt_specs = opt_state_specs(specs, opt_cfg)
        opt_structs = shape_structs(opt_specs)
        opt_shard = shp.param_shardings(opt_specs, rules, mesh)
        batch = _batch_structs(arch, cfg, shape)
        batch_shard = _batch_shardings(arch, cfg, shape, mesh)
        fn = make_train_step(arch, cfg, opt_cfg)
        return Cell(arch, shape, fn,
                    (params_structs, opt_structs, batch),
                    (params_shard, opt_shard, batch_shard),
                    (params_shard, opt_shard, None), meta)

    if shape.kind == "prefill":
        batch = _batch_structs(arch, cfg, shape)
        batch.pop("labels", None)
        batch_shard = _batch_shardings(arch, cfg, shape, mesh)
        batch_shard.pop("labels", None)
        fn = make_prefill_step(arch, cfg, max_len=shape.seq_len)
        return Cell(arch, shape, fn, (params_structs, batch),
                    (params_shard, batch_shard), None, meta)

    # decode: one new token against a cache of seq_len.
    b = shape.global_batch
    if arch.kind == "encdec":
        caches = ed.decoder_cache_specs(cfg, b, shape.seq_len,
                                        ENCDEC_DECODE_SRC_LEN)
        axes = ed.decoder_cache_axes(cfg)
    else:
        caches = lm_mod.cache_specs(cfg, b, shape.seq_len)
        axes = lm_mod.cache_axes(cfg)
    cache_shard = shp.cache_shardings(axes, caches, rules, mesh, b)
    tokens = _token_struct(b, 1)
    tok_shard = NamedSharding(
        mesh, P(shp.batch_sharding(mesh, b)[0], None))
    fn = make_decode_step(arch, cfg)
    return Cell(arch, shape, fn, (params_structs, tokens, caches),
                (params_shard, tok_shard, cache_shard),
                (None, cache_shard), meta)
