"""Reference implementations of the paper's two attacks.

* SECA — Single-Element Collision Attack (Algorithm 1, lines 1-4):
  against a wide block whose 16B segments share one OTP, the most
  frequent ciphertext segment reveals the pad (because the most
  frequent plaintext segment is guessable, e.g. all-zeros from ReLU
  sparsity / zero padding), and then the whole block decrypts.

* RePA — Re-Permutation Attack (Algorithm 2, lines 1-6): against a
  layer MAC formed by XORing per-block MACs that are NOT bound to
  block positions, any permutation of the ciphertext blocks passes
  verification while corrupting the model.

Both attacks run on the host (numpy) — the attacker sits on the memory
bus and manipulates raw bytes; they are used by tests/examples to show
they *succeed* against the strawman schemes and *fail* against SeDA's
defenses.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

__all__ = ["seca_recover_block", "SecaResult", "repa_shuffle"]


class SecaResult(NamedTuple):
    recovered_otp: np.ndarray      # (16,) uint8 candidate pad
    recovered_plain: np.ndarray    # (n_segments, 16) uint8
    collision_count: int           # multiplicity of the modal ciphertext


def seca_recover_block(cipher_block: np.ndarray,
                       most_value_p: np.ndarray | None = None) -> SecaResult:
    """Run SECA on one wide block: (block_bytes,) uint8 ciphertext.

    ``most_value_p`` is the attacker's guess for the most common
    plaintext segment (default: all zeros — the dominant value in
    padded / sparse DNN tensors).
    """
    segs = cipher_block.reshape(-1, 16)
    if most_value_p is None:
        most_value_p = np.zeros(16, np.uint8)
    # CALCFREQVALUE: modal ciphertext segment.
    uniq, counts = np.unique(segs, axis=0, return_counts=True)
    modal = uniq[np.argmax(counts)]
    otp = modal ^ most_value_p                       # line 2
    plain = segs ^ otp[None, :]                      # lines 3-4
    return SecaResult(otp.astype(np.uint8), plain.astype(np.uint8),
                      int(counts.max()))


def repa_shuffle(cipher_blocks: np.ndarray, *, seed: int = 0) -> np.ndarray:
    """RePA: permute the ciphertext blocks of a layer (SHUFFLEORDER).

    Returns the shuffled blocks; with a position-free XOR-MAC the layer
    MAC is unchanged (XOR commutes), so verification passes while the
    layer decrypts to garbage in the wrong positions.
    """
    rng = np.random.default_rng(seed)
    perm = rng.permutation(cipher_blocks.shape[0])
    # Ensure it is an actual derangement of at least two positions.
    if (perm == np.arange(len(perm))).all() and len(perm) > 1:
        perm[0], perm[1] = perm[1], perm[0]
    return cipher_blocks[perm]
