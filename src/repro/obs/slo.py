"""Live SLO watchdog over the engine's tick phases and verdicts.

:class:`SLOMonitor` is the health tier of ``repro.obs``: it consumes
signals the engine already produces — the wall-clock tick phases, the
ttft observations, and the host-synced MAC verdict stream — and turns
them into three kinds of alarms:

* **per-tenant ttft / p99-tick targets** — every request whose
  wall-clock time-to-first-token misses ``ttft_ms`` bumps
  ``slo_ttft_breaches`` (audited with the tenant), and an ok→breach
  transition of the rolling p99 tick latency vs ``p99_tick_ms`` bumps
  ``slo_tick_p99_breaches``;
* **integrity-failure-rate alarm** — a sliding window over the MAC
  verdict stream (``integrity_window``); when the failure rate crosses
  ``integrity_threshold`` (with at least ``integrity_min_failures``
  observed) the monitor latches ``slo_integrity_alarms`` and the
  engine is reported *failing* until the window drains;
* **stuck-tick watchdog** — :meth:`check_stalled` fires
  ``slo_stuck_ticks`` when the engine has pending work but no
  ``_tick_end`` landed within ``stall_factor`` × the rolling median
  tick duration (plus a ``min_stall_s`` floor so sub-millisecond
  median ticks don't turn scheduling jitter into pages); an idle
  engine is never stuck.

Every breach is emitted twice: as a registry counter (names declared
in :data:`repro.obs.metrics.ENGINE_COUNTERS`) *and* as a hash-chained
audit event (``slo_breach`` with a ``kind`` field) when the engine has
an audit log.  Attachment is explicit (``monitor.attach(engine)``)
and wraps the tick phases per instance exactly like the span tracer
does — an engine without a monitor executes zero additional host code.
"""

from __future__ import annotations

import math
import time
from collections import deque
from typing import Optional

__all__ = ["SLOMonitor", "merge_health"]

_STATUS_RANK = {"ok": 0, "degraded": 1, "failing": 2}


def _percentile(xs, q: float) -> float:
    """np.percentile(..., method='linear') over a small window."""
    if not xs:
        return math.nan
    xs = sorted(xs)
    if len(xs) == 1:
        return xs[0]
    pos = (len(xs) - 1) * (q / 100.0)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


class SLOMonitor:
    """Watchdog for one engine; see the module docstring."""

    def __init__(self, *, ttft_ms: Optional[float] = None,
                 p99_tick_ms: Optional[float] = None,
                 integrity_window: int = 256,
                 integrity_threshold: float = 0.5,
                 integrity_min_failures: int = 4,
                 stall_factor: float = 10.0,
                 min_stall_s: float = 0.0,
                 tick_window: int = 256, min_ticks: int = 8):
        self.ttft_ms = ttft_ms
        self.p99_tick_ms = p99_tick_ms
        self.integrity_window = integrity_window
        self.integrity_threshold = integrity_threshold
        self.integrity_min_failures = integrity_min_failures
        self.stall_factor = stall_factor
        self.min_stall_s = min_stall_s
        self.tick_window = tick_window
        self.min_ticks = min_ticks

        self.engine = None
        self._ticks: deque = deque(maxlen=tick_window)
        self._verdicts: deque = deque(maxlen=integrity_window)
        self._fail_count = 0
        self._tick_t0: Optional[float] = None
        self._last_end: Optional[float] = None
        self._tick_breached = False
        self._integrity_alarm = False
        self._stuck = False
        self.tenant_ttft: dict = {}          # tenant label -> deque of ms
        self.tenant_breaches: dict = {}      # tenant label -> count

    # -- attachment ---------------------------------------------------------

    def attach(self, engine) -> "SLOMonitor":
        """Wrap one engine's phases/hooks; returns self for chaining."""
        if self.engine is not None:
            raise ValueError("SLOMonitor is per-engine; attach a fresh one")
        if getattr(engine, "slo", None) is not None:
            raise ValueError("engine already has an SLOMonitor attached")
        self.engine = engine

        orig_begin = engine._tick_begin
        orig_end = engine._tick_end
        orig_ttft = engine._observe_ttft

        def tick_begin(*a, **kw):
            self._tick_t0 = time.perf_counter()
            return orig_begin(*a, **kw)

        def tick_end(*a, **kw):
            try:
                return orig_end(*a, **kw)
            finally:
                now = time.perf_counter()
                if self._tick_t0 is not None:
                    self._observe_tick(now - self._tick_t0)
                self._last_end = now
                self._stuck = False

        def observe_ttft(req):
            orig_ttft(req)
            if self.ttft_ms is not None and req.submit_time:
                ms = (time.perf_counter() - req.submit_time) * 1e3
                self._observe_ttft_ms(ms, self._tenant_label(req))

        engine._tick_begin = tick_begin
        engine._tick_end = tick_end
        engine._observe_ttft = observe_ttft
        engine.page_io.verdict_hooks.append(self._on_verdict)
        engine.slo = self
        return self

    def _tenant_label(self, req) -> str:
        idx = getattr(req, "tenant_idx", None)
        if idx is None:
            return "default"
        reg = self.engine.registry
        if reg is not None:
            try:
                return reg.by_index(idx).tenant_id
            except Exception:  # noqa: BLE001 - stale index after churn
                pass
        return str(idx)

    # -- signal ingestion ---------------------------------------------------

    def _breach(self, counter: str, kind: str, **fields) -> None:
        self.engine.stats[counter] += 1
        self.engine._audit("slo_breach", kind=kind, **fields)

    def _observe_ttft_ms(self, ms: float, tenant: str) -> None:
        dq = self.tenant_ttft.setdefault(
            tenant, deque(maxlen=self.tick_window))
        dq.append(ms)
        if ms > self.ttft_ms:
            self.tenant_breaches[tenant] = \
                self.tenant_breaches.get(tenant, 0) + 1
            self._breach("slo_ttft_breaches", "ttft", tenant=tenant,
                         ttft_ms=round(ms, 3), target_ms=self.ttft_ms)

    def _observe_tick(self, seconds: float) -> None:
        self._ticks.append(seconds)
        if self.p99_tick_ms is None or len(self._ticks) < self.min_ticks:
            return
        p99_ms = _percentile(self._ticks, 99) * 1e3
        if p99_ms > self.p99_tick_ms:
            if not self._tick_breached:
                self._tick_breached = True
                self._breach("slo_tick_p99_breaches", "tick_p99",
                             p99_ms=round(p99_ms, 3),
                             target_ms=self.p99_tick_ms)
        else:
            self._tick_breached = False

    def _on_verdict(self, ok: bool, op: str, ctx: dict) -> None:
        if len(self._verdicts) == self._verdicts.maxlen \
                and not self._verdicts[0]:
            self._fail_count -= 1
        self._verdicts.append(bool(ok))
        if not ok:
            self._fail_count += 1
        rate = self._fail_count / len(self._verdicts)
        if (self._fail_count >= self.integrity_min_failures
                and rate >= self.integrity_threshold):
            if not self._integrity_alarm:
                self._integrity_alarm = True
                self._breach("slo_integrity_alarms", "integrity_rate",
                             failure_rate=round(rate, 4),
                             window=len(self._verdicts),
                             threshold=self.integrity_threshold, op=op)
        elif rate < self.integrity_threshold:
            self._integrity_alarm = False

    # -- polling ------------------------------------------------------------

    def check_stalled(self, now: Optional[float] = None) -> bool:
        """Fire the watchdog if no tick ended within the deadline.

        ``now`` is injectable for tests; the deadline is
        ``max(stall_factor * median_tick, min_stall_s)`` past the last
        observed ``_tick_end``.  Latches *failing* until the next tick
        end; re-polling a latched stall does not re-fire the counter.
        An idle engine — no waiting requests, no occupied slots — is
        never stuck: a shard that drained early must not page while a
        sibling shard keeps the cluster loop busy.
        """
        if self._last_end is None or not self._ticks:
            return False
        eng = self.engine
        if eng is not None and not (
                eng._n_waiting()
                or any(s is not None for s in eng.slots)):
            return False
        if now is None:
            now = time.perf_counter()
        median = _percentile(self._ticks, 50)
        deadline = max(self.stall_factor * median, self.min_stall_s)
        if now - self._last_end > deadline:
            if not self._stuck:
                self._stuck = True
                self._breach("slo_stuck_ticks", "stuck_tick",
                             idle_s=round(now - self._last_end, 4),
                             deadline_s=round(deadline, 4))
            return True
        return False

    @property
    def hard_breach(self) -> bool:
        """True when the engine should be pulled out of rotation (and
        the launcher should exit non-zero): integrity alarm, stall, or
        a session lost for good to integrity recovery."""
        return (self._integrity_alarm or self._stuck
                or self._sessions_lost() > 0)

    def _sessions_lost(self) -> int:
        if self.engine is None:
            return 0
        return int(self.engine.stats.get("sessions_lost", 0))

    def _recovering(self) -> int:
        if self.engine is None or not hasattr(self.engine, "_n_recovering"):
            return 0
        return self.engine._n_recovering()

    def health(self) -> dict:
        """/healthz body: ok | degraded (soft SLO misses or sessions
        in integrity recovery) | failing."""
        recovering = self._recovering()
        soft = (sum(self.tenant_breaches.values()) > 0
                or self._tick_breached or recovering > 0)
        status = ("failing" if self.hard_breach
                  else "degraded" if soft else "ok")
        tenants = {t: {"p99_ms": round(_percentile(dq, 99), 3),
                       "breaches": self.tenant_breaches.get(t, 0)}
                   for t, dq in sorted(self.tenant_ttft.items())}
        out = {
            "status": status,
            "targets": {"ttft_ms": self.ttft_ms,
                        "p99_tick_ms": self.p99_tick_ms},
            "ticks": {"observed": len(self._ticks),
                      "p50_ms": round(_percentile(self._ticks, 50) * 1e3, 3)
                      if self._ticks else None,
                      "p99_ms": round(_percentile(self._ticks, 99) * 1e3, 3)
                      if self._ticks else None,
                      "p99_breached": self._tick_breached},
            "integrity": {"window": len(self._verdicts),
                          "failures": self._fail_count,
                          "alarm": self._integrity_alarm},
            "stuck": self._stuck,
            "tenants": tenants,
        }
        if self.engine is not None:
            out["shard"] = self.engine.shard_id
            out["recovery"] = {
                "recovering": recovering,
                "sessions_lost": self._sessions_lost(),
                "quarantined_pages":
                    len(getattr(self.engine, "quarantined", ())),
            }
        return out


def merge_health(healths: list) -> dict:
    """Cluster /healthz rollup: worst shard status wins; recovery
    state (sessions recovering/lost, quarantined pages) is summed."""
    if not healths:
        return {"status": "ok", "shards": []}
    worst = max(healths, key=lambda h: _STATUS_RANK.get(h["status"], 0))
    out = {"status": worst["status"], "shards": healths}
    recs = [h["recovery"] for h in healths if h.get("recovery")]
    if recs:
        out["recovery"] = {k: sum(r[k] for r in recs)
                          for k in ("recovering", "sessions_lost",
                                    "quarantined_pages")}
    return out
