"""DNN simulation configurations (paper Table II)."""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["NPUConfig", "SERVER_NPU", "EDGE_NPU", "NPUS"]


@dataclass(frozen=True)
class NPUConfig:
    name: str
    pe_rows: int
    pe_cols: int
    bandwidth_gbps: float     # off-chip, GB/s (4 channels total)
    freq_ghz: float
    sram_bytes: int
    precision_bytes: int = 1  # 1B per element (Table II)
    dram_channels: int = 4

    @property
    def bytes_per_cycle(self) -> float:
        """Off-chip bytes deliverable per accelerator cycle."""
        return self.bandwidth_gbps / self.freq_ghz

    @property
    def macs_per_cycle(self) -> int:
        return self.pe_rows * self.pe_cols


# Server NPU: Google TPU v1-like (Table II).
SERVER_NPU = NPUConfig(
    name="server",
    pe_rows=256, pe_cols=256,
    bandwidth_gbps=20.0,
    freq_ghz=1.0,
    sram_bytes=24 * 1024 * 1024,
)

# Edge NPU: Samsung Exynos 990-like (Table II).
EDGE_NPU = NPUConfig(
    name="edge",
    pe_rows=32, pe_cols=32,
    bandwidth_gbps=10.0,
    freq_ghz=2.75,
    sram_bytes=480 * 1024,
)

NPUS = {"server": SERVER_NPU, "edge": EDGE_NPU}
