"""Provenance stamp for benchmark JSON artifacts.

Every bench that writes a JSON file stamps it with a ``meta`` block —
git commit, jax/jaxlib/python versions, platform, UTC timestamp — so a
number in an uploaded CI artifact can always be traced back to the
exact tree and toolchain that produced it.  ``check_fast_paths.py``
and the other gates read only the ``benchmark``/``results`` keys and
ignore ``meta`` entirely.
"""

from __future__ import annotations

import os
import platform
import subprocess
import sys
from datetime import datetime, timezone

__all__ = ["run_meta", "stamp"]


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def _git_dirty() -> bool:
    """True when the worktree has uncommitted changes (or git is
    unavailable) — history rows from dirty runs are excluded from
    regression baselines (``benchmarks/check_regression.py``)."""
    try:
        out = subprocess.run(
            ["git", "status", "--porcelain"], capture_output=True,
            text=True, timeout=10)
        if out.returncode == 0:
            return bool(out.stdout.strip())
    except (OSError, subprocess.SubprocessError):
        pass
    return True


def run_meta() -> dict:
    """The provenance dict stamped onto every bench JSON artifact."""
    versions = {}
    try:
        import jax
        versions["jax"] = jax.__version__
    except Exception:  # noqa: BLE001 - provenance must never kill a bench
        versions["jax"] = "unknown"
    try:
        import jaxlib
        versions["jaxlib"] = jaxlib.__version__
    except Exception:  # noqa: BLE001
        versions["jaxlib"] = "unknown"
    return {
        "git_sha": _git_sha(),
        "git_dirty": _git_dirty(),
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        # Fingerprint includes the core count: wall-clock baselines in
        # the bench history only bind runs on comparable machines
        # (check_regression.py compares tok/s within-host only).
        "host": f"{platform.system()}-{platform.machine()}"
                f"-c{os.cpu_count()}",
        "timestamp_utc": datetime.now(timezone.utc).isoformat(),
        **versions,
    }


def stamp(payload: dict) -> dict:
    """Return ``payload`` with a ``meta`` provenance block added."""
    out = dict(payload)
    out["meta"] = run_meta()
    return out
