"""Protection-overhead profiler: HLO attribution and CostProfile.

The acceptance bar from the observability issue: for every scheme x
decode bucket on the kernel-capable smoke spec, the attributed
``protection + model`` cost must account for >= 95% of the compiled
decode fn's total HLO bytes-accessed and flops, the ``seda`` overhead
ratio must be nonzero, and ``off`` must be ~0.
"""

import json

import jax
import pytest

from repro.configs import get_arch
from repro.core.secure_exec import SCHEMES
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.obs.profiler import (CostProfile, attribute_hlo,
                                classify_source, profile_decode)
from repro.serve.cluster import ClusterEngine
from repro.serve.engine import SecureServingEngine


@pytest.fixture(scope="module")
def smoke():
    arch = get_arch("minitron-4b")
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    return arch, cfg, params


def _engine(smoke, **kw):
    arch, cfg, params = smoke
    kw.setdefault("max_slots", 1)
    kw.setdefault("page_tokens", 4)
    kw.setdefault("pages_per_slot", 2)
    return SecureServingEngine(arch, cfg, params, **kw)


class TestClassifySource:
    def test_kernel_and_core_files_are_protection(self):
        assert classify_source("/x/repro/kernels/aes_ctr/kernel.py", 10) \
            == "protection"
        assert classify_source("/x/repro/core/mac.py", 1) == "protection"
        assert classify_source("/x/repro/core/aes.py", 99) == "protection"

    def test_model_files_are_model(self):
        assert classify_source("/x/repro/models/layers.py", 5) == "model"
        assert classify_source("/x/repro/serve/engine.py", 5) == "model"

    def test_kv_pages_split_by_function_ranges(self):
        import inspect

        from repro.serve import kv_pages
        crypt_line = inspect.getsourcelines(kv_pages._crypt)[1] + 1
        assert classify_source(kv_pages.__file__, crypt_line) \
            == "protection"
        # Module line 1 (docstring) is paging glue, not protection.
        assert classify_source(kv_pages.__file__, 1) == "model"


class TestAttributeHlo:
    # A miniature module exercising the cascade: own metadata, a
    # metadata-less called computation (caller->callee inheritance),
    # and a fused body voted by its one attributed op.
    HLO = """\
HloModule test

%fused_computation (param_0.1: f32[8]) -> f32[8] {
  %param_0.1 = f32[8]{0} parameter(0)
  ROOT %m = f32[8]{0} multiply(f32[8]{0} %param_0.1, f32[8]{0} %param_0.1), metadata={op_name="mul" source_file="/x/repro/core/aes.py" source_line=5}
}

%helper (a.1: f32[8]) -> f32[8] {
  %a.1 = f32[8]{0} parameter(0)
  ROOT %n = f32[8]{0} negate(f32[8]{0} %a.1)
}

ENTRY %main (p0: f32[8], p1: f32[4,4]) -> f32[8] {
  %p0 = f32[8]{0} parameter(0)
  %p1 = f32[4,4]{1,0} parameter(1)
  %d = f32[4,4]{1,0} dot(f32[4,4]{1,0} %p1, f32[4,4]{1,0} %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}, metadata={op_name="mm" source_file="/x/repro/models/layers.py" source_line=9}
  %f = f32[8]{0} fusion(f32[8]{0} %p0), kind=kLoop, calls=%fused_computation
  ROOT %c = f32[8]{0} call(f32[8]{0} %f), to_apply=%helper, metadata={op_name="bc" source_file="/x/repro/core/aes.py" source_line=7}
}
"""

    def test_buckets_and_coverage(self):
        attr = attribute_hlo(self.HLO)
        total = attr["total"]
        assert total["bytes"] > 0 and total["flops"] > 0
        # Everything in the miniature module is attributable.
        assert attr["other"]["bytes"] == 0
        assert attr["other"]["flops"] == 0
        # dot: 2 * 16 out * 4 contract = 128 model flops.
        assert attr["model"]["flops"] == 128
        # multiply in the fused body (8) + negate in %helper (8).
        assert attr["protection"]["flops"] == 16
        # by_file strips the path up to the package root.
        assert set(attr["by_file"]) == {"core/aes.py", "models/layers.py"}

    def test_metadata_less_callee_inherits_from_call_site(self):
        attr = attribute_hlo(self.HLO)
        # %helper's negate carries no metadata anywhere; it must be
        # attributed through the call site's to_apply= (protection).
        assert attr["by_file"]["core/aes.py"]["flops"] >= 16


class TestProfileDecode:
    @pytest.mark.parametrize("scheme", sorted(SCHEMES))
    def test_coverage_all_schemes_and_buckets(self, smoke, scheme):
        eng = _engine(smoke, scheme=scheme, use_kernel=(scheme != "off"))
        for bucket in (1, 2):
            p = profile_decode(eng, bucket=bucket)
            assert isinstance(p, CostProfile)
            cov = p.coverage
            assert cov["bytes"] >= 0.95, (scheme, bucket, cov)
            assert cov["flops"] >= 0.95, (scheme, bucket, cov)
            if scheme == "off":
                assert p.overhead_bytes_ratio < 0.01
                assert p.overhead_flops_ratio < 0.01
            else:
                assert p.overhead_bytes_ratio > 0.01
                assert p.overhead_flops_ratio > 0.01

    def test_profile_export_and_gauges(self, smoke):
        eng = _engine(smoke, scheme="seda")
        out = eng.profile()
        assert out["scheme"] == "seda"
        assert len(out["profiles"]) == 1
        prof = out["profiles"][0]
        json.dumps(out)                     # JSON-serializable
        for key in ("protection", "model", "other", "total", "coverage",
                    "overhead_bytes_ratio", "roofline", "xla_cost"):
            assert key in prof
        # Gauges sample the cache (no compile at snapshot time).
        gauges = eng.metrics.snapshot()["gauges"]
        assert gauges["protection_overhead_ratio"] == {
            "2": pytest.approx(prof["overhead_bytes_ratio"])}
        assert "2" in gauges["protection_overhead_flops_ratio"]
        assert "2" in gauges["roofline_utilization"]

    def test_cluster_rollup(self, smoke):
        cluster = ClusterEngine(*smoke, shards=2, max_slots=1,
                                page_tokens=4, pages_per_slot=2,
                                scheme="seda")
        out = cluster.profile()
        assert out["scheme"] == "seda"
        assert [s["shard"] for s in out["shards"]] == [0, 1]
        roll = out["rollup"]
        assert roll["total"]["bytes"] == pytest.approx(sum(
            s["profiles"][0]["total"]["bytes"] for s in out["shards"]))
        assert roll["overhead_bytes_ratio"] > 0.01
        json.dumps(out)

    def test_roofline_fields(self, smoke):
        eng = _engine(smoke, scheme="off")
        p = profile_decode(eng, bucket=2)
        roof = p.roofline()
        assert roof["bound"] in ("compute", "memory")
        assert roof["roofline_s"] == pytest.approx(
            max(roof["compute_s"], roof["memory_s"]))
        # No measured ticks yet -> utilization omitted.
        assert "utilization" not in roof
