"""Secure shared-prefix KV cache + the unified submit / page-IO API.

Covers this PR's tentpole guarantees:
  * API parity — the keyword-only ``submit()`` (SubmitRequest) is
    token-identical to the legacy positional form (which warns), on the
    engine and the cluster alike;
  * PageIO — the free-function wrappers are bit-identical to the
    facade methods they delegate to;
  * prefix cache — content-addressed match/insert/refcount/reclaim
    host logic, and hit/miss/CoW serving that stays token-identical to
    the no-cache engine for every scheme;
  * isolation — a tenant never matches another tenant's chain, a
    byte-identical replay of a cached page under another tenant's
    session fails its MAC gate, and cross-tenant sharing works only
    through the explicit reseal-on-share;
  * cluster — routing prefers the shard holding the prefix, and stats
    aggregation forwards counters it never heard of.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.secure_exec import SCHEMES
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.serve import kv_pages as kvp
from repro.serve.cluster import ClusterEngine
from repro.serve.engine import (IntegrityError, SecureServingEngine,
                                SubmitRequest)
from repro.tenancy.keys import KeyHierarchy
from repro.tenancy.registry import TenantRegistry


@pytest.fixture(scope="module")
def smoke():
    arch = get_arch("minitron-4b")
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    return arch, cfg, params


def _tenant_engine(smoke, *, tenants=("alice",), prefix_cache=True,
                   scheme="seda", **kw):
    arch, cfg, params = smoke
    registry = TenantRegistry(KeyHierarchy(0), max_tenants=4)
    for t in tenants:
        registry.register(t)
    kw.setdefault("max_slots", 2)
    kw.setdefault("page_tokens", 4)
    kw.setdefault("pages_per_slot", 4)
    kw.setdefault("n_pages", 16)
    eng = SecureServingEngine(arch, cfg, params, scheme=scheme,
                              registry=registry, prefix_cache=prefix_cache,
                              **kw)
    return eng, registry


@pytest.fixture(scope="module")
def hitmiss_prompts():
    rng = np.random.default_rng(3)
    p7 = list(map(int, rng.integers(1, 256, 7)))
    p8 = p7 + [int(rng.integers(1, 256))]
    p9 = list(map(int, rng.integers(1, 256, 9)))
    # p7 seeds the chain (one full + one partial page with
    # page_tokens=4); the second p7 hits; p8 extends the partial leaf
    # (hit + copy-on-write); p9 is an unrelated miss.
    return [p7, p7, p8, p9]


@pytest.fixture(scope="module")
def hitmiss_baseline(smoke, hitmiss_prompts):
    """No-cache reference tokens for the hit/miss/CoW workload."""
    eng, registry = _tenant_engine(smoke, prefix_cache=False, scheme="off")
    sess = registry.open_session("alice")
    rids = [eng.submit(prompt=p, max_new_tokens=4, session=sess)
            for p in hitmiss_prompts]
    done = eng.run()
    return [done[r].generated for r in rids]


class TestSubmitRequest:
    def test_positional_form_warns_and_matches(self, smoke):
        arch, cfg, params = smoke
        legacy = SecureServingEngine(arch, cfg, params, scheme="off",
                                     max_slots=2, page_tokens=4,
                                     pages_per_slot=4)
        prompt = [5, 6, 7, 8, 9]
        with pytest.warns(DeprecationWarning):
            r0 = legacy.submit(prompt, 4)
        r1 = legacy.submit(prompt=prompt, max_new_tokens=4)
        r2 = legacy.submit(SubmitRequest(prompt=prompt, max_new_tokens=4))
        done = legacy.run()
        assert done[r0].generated == done[r1].generated
        assert done[r0].generated == done[r2].generated

    def test_keyword_form_does_not_warn(self, smoke):
        arch, cfg, params = smoke
        eng = SecureServingEngine(arch, cfg, params, scheme="off",
                                  max_slots=2, page_tokens=4,
                                  pages_per_slot=4)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            eng.submit(prompt=[1, 2, 3], max_new_tokens=2)
            eng.submit(SubmitRequest(prompt=[1, 2, 3], max_new_tokens=2))

    def test_argument_validation(self, smoke):
        arch, cfg, params = smoke
        eng = SecureServingEngine(arch, cfg, params, scheme="off",
                                  max_slots=2, page_tokens=4,
                                  pages_per_slot=4)
        sr = SubmitRequest(prompt=[1, 2], max_new_tokens=2)
        with pytest.raises(TypeError):
            eng.submit(sr, 4)
        with pytest.raises(TypeError):
            eng.submit(sr, max_new_tokens=4)
        with pytest.raises(TypeError), pytest.warns(DeprecationWarning):
            eng.submit([1, 2], prompt=[3, 4])
        with pytest.raises(TypeError), pytest.warns(DeprecationWarning):
            eng.submit([1, 2], 4, max_new_tokens=4)

    def test_cluster_shares_the_surface(self, smoke):
        arch, cfg, params = smoke
        cluster = ClusterEngine(arch, cfg, params, shards=2, scheme="off",
                                max_slots=2, page_tokens=4,
                                pages_per_slot=4)
        prompt = [9, 8, 7, 6, 5]
        with pytest.warns(DeprecationWarning):
            r0 = cluster.submit(prompt, 4)
        r1 = cluster.submit(SubmitRequest(prompt=prompt, max_new_tokens=4))
        done = cluster.run()
        assert done[r0].generated == done[r1].generated

    def test_share_prefix_opt_out(self, smoke):
        eng, registry = _tenant_engine(smoke)
        sess = registry.open_session("alice")
        prompt = list(range(1, 10))
        eng.submit(prompt=prompt, max_new_tokens=4, session=sess,
                   share_prefix=False)
        eng.run()
        assert eng.prefix_cache.pages_used == 0      # never seeded
        eng.submit(prompt=prompt, max_new_tokens=4, session=sess)
        eng.run()
        assert eng.prefix_cache.pages_used > 0
        hits_before = eng.stats["prefix_hit_pages"]
        eng.submit(prompt=prompt, max_new_tokens=4, session=sess,
                   share_prefix=False)
        eng.run()
        assert eng.stats["prefix_hit_pages"] == hits_before  # never read


class TestPageIO:
    """The free functions must stay bit-identical to the facade."""

    def _spec_and_data(self, keys, rng, scheme="seda"):
        from repro.models.attention import KVCache
        tree = [[KVCache(
            k=jax.ShapeDtypeStruct((2, 2, 16, 2, 8), jnp.float32),
            v=jax.ShapeDtypeStruct((2, 2, 16, 2, 8), jnp.float32),
            length=jax.ShapeDtypeStruct((2,), jnp.int32))]]
        spec = kvp.build_page_spec(tree, scheme=scheme, page_tokens=4,
                                   n_pages=6, max_slots=2, max_len=16)
        data = [jnp.asarray(rng.standard_normal((2, 1, 16, 2, 8)),
                            jnp.float32) for _ in spec.leaves]
        return spec, data

    def test_wrappers_bit_identical(self, keys, rng):
        spec, data = self._spec_and_data(keys, rng)
        io = kvp.PageIO(spec, keys)
        ids = jnp.asarray([0, 1, 2, 3], jnp.int32)
        vn = jnp.uint32(1)

        pool_fn = kvp.write_prefill(kvp.init_pool(spec), spec, keys, ids,
                                    data, 4, vn)
        pool_io = io.write_prefill(kvp.init_pool(spec), ids, data, 4, vn)
        for a, b in zip(pool_fn, pool_io):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        table = jnp.asarray([[0, 1, 2, 3], [-1, -1, -1, -1]], jnp.int32)
        lengths = jnp.asarray([16, 0], jnp.int32)
        dense_fn, ok_fn = kvp.read_pages(pool_fn, spec, keys, table, lengths)
        dense_io, ok_io = io.read(pool_io, table, lengths)
        assert bool(ok_fn) and bool(ok_io)
        for a, b in zip(dense_fn, dense_io):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        raw_fn, _ = kvp.read_pages_raw(pool_fn, spec, keys, ids)
        raw_io, _ = io.read_raw(pool_io, ids)
        for a, b in zip(raw_fn, raw_io):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        res_fn, ok1 = kvp.reseal_pages(pool_fn, spec, keys, ids,
                                       jnp.uint32(2))
        res_io, ok2 = io.reseal(pool_io, ids, jnp.uint32(2))
        assert bool(ok1) and bool(ok2)
        for a, b in zip(res_fn, res_io):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        dst = jnp.asarray([4, 5, spec.scratch_page, spec.scratch_page],
                          jnp.int32)
        mig_fn, ok3 = kvp.migrate_pages(pool_fn, spec, kvp.init_pool(spec),
                                        spec, keys, ids, dst, vn)
        mig_io, ok4 = io.migrate(pool_io, spec, kvp.init_pool(spec), ids,
                                 dst, vn)
        assert bool(ok3) and bool(ok4)
        for a, b in zip(mig_fn, mig_io):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_copy_rebinds_within_one_pool(self, keys, rng):
        spec, data = self._spec_and_data(keys, rng)
        io = kvp.PageIO(spec, keys)
        ids = jnp.asarray([0, 1], jnp.int32)
        pool = io.write_prefill(kvp.init_pool(spec), ids, data, 2,
                                jnp.uint32(1))
        dst = jnp.asarray([3, 4], jnp.int32)
        pool, ok = io.copy(pool, ids, dst, jnp.uint32(2))
        assert bool(ok)
        want, _ = io.read_raw(pool, ids)
        got, ok_dst = io.read_raw(pool, dst)
        assert bool(ok_dst)
        for a, b in zip(want, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestPrefixCacheUnit:
    """Host-side chain/refcount logic, no accelerator in the loop."""

    def _cache(self, capacity=8):
        return kvp.PrefixCache(page_tokens=4, capacity_pages=capacity)

    def _seed(self, pc, tenant, tokens, first_page=0):
        matched, missing = pc.missing(tenant, tokens)
        assert matched == []
        parent = None
        for i, (key, n) in enumerate(missing):
            parent = pc.insert(key, parent, first_page + i, n)
        return missing

    def test_match_walks_the_chain(self):
        pc = self._cache()
        toks = list(range(10, 19))                   # 4 + 4 + 1-partial
        self._seed(pc, 0, toks)
        assert [e.n_tokens for e in pc.match(0, toks)] == [4, 4, 1]
        assert [e.n_tokens for e in pc.match(0, toks[:8])] == [4, 4]
        assert [e.n_tokens for e in pc.match(0, toks[:6])] == [4]
        assert pc.match(0, [99] + toks[1:]) == []    # divergent first token
        assert pc.match_tokens(0, toks) == 9

    def test_partial_leaf_matches_inside_longer_prompt(self):
        pc = self._cache()
        self._seed(pc, 0, list(range(7)))            # 4 + 3-partial
        got = pc.match(0, list(range(9)))            # longer prompt
        assert [e.n_tokens for e in got] == [4, 3]

    def test_tenants_never_share_chains(self):
        pc = self._cache()
        toks = list(range(8))
        self._seed(pc, 0, toks)
        assert pc.match(1, toks) == []
        matched, missing = pc.missing(1, toks)
        assert matched == [] and len(missing) == 2

    def test_refcounts_pin_whole_chain(self):
        pc = self._cache()
        toks = list(range(8))
        self._seed(pc, 0, toks)
        chain = pc.match(0, toks)
        pc.acquire(chain)
        assert [e.refs for e in chain] == [1, 1]
        assert pc.reclaim(2) == []                   # pinned: nothing frees
        pc.release(chain)
        with pytest.raises(RuntimeError):
            pc.release(chain)                        # refcount underflow

    def test_reclaim_is_lru_leaf_first(self):
        pc = self._cache()
        self._seed(pc, 0, list(range(8)), first_page=0)      # pages 0, 1
        self._seed(pc, 1, list(range(50, 54)), first_page=5)  # page 5
        chain = pc.match(1, list(range(50, 54)))
        pc.acquire(chain)                             # refresh LRU
        pc.release(chain)
        freed = pc.reclaim(3)
        # Tenant 0's chain goes leaf-first (page 1 before its parent 0);
        # tenant 1's page is most recently used, so it frees last.
        assert freed == [1, 0, 5]

    def test_insert_rejects_dup_and_partial_parent(self):
        pc = self._cache(capacity=4)
        missing = self._seed(pc, 0, list(range(7)))   # full + partial leaf
        with pytest.raises(ValueError):
            pc.insert(missing[0][0], None, 9, 4)      # duplicate chunk
        partial = pc.match(0, list(range(7)))[-1]
        assert partial.n_tokens == 3
        with pytest.raises(ValueError):
            pc.insert((0, b"y"), partial, 9, 4)       # extend partial leaf
        _, plan = pc.missing(0, list(range(9)))
        assert plan == []                             # plan agrees: no extend

    def test_insert_rejects_over_capacity(self):
        pc = self._cache(capacity=1)
        self._seed(pc, 0, list(range(4)))
        with pytest.raises(ValueError):
            pc.insert((0, b"x"), None, 9, 4)

    def test_flush_scoped_by_tenant(self):
        pc = self._cache()
        self._seed(pc, 0, list(range(8)), first_page=0)
        self._seed(pc, 1, list(range(20, 28)), first_page=3)
        freed = pc.flush(tenant_index=0)
        assert sorted(freed) == [0, 1]
        assert pc.match(1, list(range(20, 28)))       # other tenant intact


class TestPrefixEngine:
    @pytest.mark.parametrize("scheme", sorted(SCHEMES))
    def test_hit_miss_cow_token_parity(self, smoke, hitmiss_prompts,
                                       hitmiss_baseline, scheme):
        eng, registry = _tenant_engine(smoke, scheme=scheme)
        sess = registry.open_session("alice")
        rids = [eng.submit(prompt=p, max_new_tokens=4, session=sess)
                for p in hitmiss_prompts]
        done = eng.run()
        got = [done[r].generated for r in rids]
        assert got == hitmiss_baseline, scheme
        assert eng.stats["prefix_hit_pages"] > 0
        assert eng.stats["prefill_pages_skipped"] > 0
        assert eng.stats["prefix_cow_pages"] > 0      # p8 extends a partial
        assert eng.stats["prefix_inserted_pages"] > 0

    def test_cache_survives_rotation(self, smoke, hitmiss_prompts,
                                     hitmiss_baseline):
        eng, registry = _tenant_engine(smoke)
        sess = registry.open_session("alice")
        p7 = hitmiss_prompts[0]
        r0 = eng.submit(prompt=p7, max_new_tokens=4, session=sess)
        eng.run()
        eng.rotate("alice")
        eng.rotate("alice")          # old session epochs leave the window
        hits0 = eng.stats["prefix_hit_pages"]
        r1 = eng.submit(prompt=p7, max_new_tokens=4, session=sess)
        done = eng.run()
        assert eng.stats["prefix_hit_pages"] > hits0
        assert done[r1].generated == hitmiss_baseline[0]
        assert eng.requests[r0].generated == hitmiss_baseline[0]

    def test_prefix_cache_requires_registry(self, smoke):
        arch, cfg, params = smoke
        with pytest.raises(ValueError, match="registry"):
            SecureServingEngine(arch, cfg, params, scheme="seda",
                                max_slots=2, page_tokens=4,
                                pages_per_slot=4, prefix_cache=True)


class TestPrefixIsolation:
    def test_no_cross_tenant_match(self, smoke, hitmiss_prompts):
        eng, registry = _tenant_engine(smoke, tenants=("alice", "bob"))
        sa = registry.open_session("alice")
        p7 = hitmiss_prompts[0]
        eng.submit(prompt=p7, max_new_tokens=4, session=sa)
        eng.run()
        bob = registry.tenants["bob"].index
        assert eng.prefix_cache.match(bob, p7) == []

    def test_cross_tenant_replay_rejected(self, smoke, hitmiss_prompts):
        """A byte-identical cached page forged into another tenant's
        slot directory must fail its MAC gate (cache keys are per
        tenant, and the fmap binding carries the owner)."""
        eng, registry = _tenant_engine(smoke, tenants=("alice", "bob"))
        sa = registry.open_session("alice")
        sb = registry.open_session("bob")
        p7 = hitmiss_prompts[0]
        eng.submit(prompt=p7, max_new_tokens=4, session=sa)
        eng.run()
        entry = next(iter(eng.prefix_cache._entries.values()))
        eng.submit(prompt=p7, max_new_tokens=6, session=sb)
        eng.step()                   # admit bob's slot
        slot = next(s for s in eng.slots if s is not None)
        assert slot.tenant.tenant_id == "bob"
        slot.pages[0] = entry.page_id         # replay alice's cache page
        slot.page_epochs[0] = kvp.PREFIX_ROLE
        with pytest.raises(IntegrityError):
            for _ in range(8):
                eng.step()

    def test_reseal_on_share_crosses_tenants(self, smoke, hitmiss_prompts,
                                             hitmiss_baseline):
        eng, registry = _tenant_engine(smoke, tenants=("alice", "bob"))
        sa = registry.open_session("alice")
        sb = registry.open_session("bob")
        p7 = hitmiss_prompts[0]
        eng.submit(prompt=p7, max_new_tokens=4, session=sa)
        eng.run()
        shared = eng.share_prefix(p7, from_session=sa, to_session=sb)
        assert shared > 0
        assert eng.stats["prefix_shared_pages"] == shared
        hits0 = eng.stats["prefix_hit_pages"]
        rb = eng.submit(prompt=p7, max_new_tokens=4, session=sb)
        done = eng.run()
        assert eng.stats["prefix_hit_pages"] > hits0
        assert done[rb].generated == hitmiss_baseline[0]

    def test_share_needs_valid_sessions(self, smoke, hitmiss_prompts):
        eng, registry = _tenant_engine(smoke, tenants=("alice", "bob"))
        sa = registry.open_session("alice")
        sb = registry.open_session("bob")
        registry.revoke(sb)
        with pytest.raises(PermissionError):
            eng.share_prefix(hitmiss_prompts[0], from_session=sa,
                             to_session=sb)


class TestClusterPrefix:
    def _cluster(self, smoke, prefix_cache=True):
        arch, cfg, params = smoke
        registry = TenantRegistry(KeyHierarchy(0), max_tenants=4)
        registry.register("alice")
        cluster = ClusterEngine(arch, cfg, params, shards=2, scheme="seda",
                                max_slots=2, page_tokens=4,
                                pages_per_slot=4, n_pages=16,
                                registry=registry,
                                prefix_cache=prefix_cache)
        return cluster, registry

    def test_routing_prefers_prefix_holder(self, smoke, hitmiss_prompts):
        base, reg0 = self._cluster(smoke, prefix_cache=False)
        s0 = reg0.open_session("alice")
        p9 = hitmiss_prompts[3]
        rids = [base.submit(prompt=p9, max_new_tokens=4, session=s0)
                for _ in range(4)]
        base.run()
        want = [base.requests[r].generated for r in rids]

        cluster, registry = self._cluster(smoke)
        sess = registry.open_session("alice")
        r0 = cluster.submit(prompt=p9, max_new_tokens=4, session=sess)
        cluster.run()
        rids2 = [cluster.submit(prompt=p9, max_new_tokens=4, session=sess)
                 for _ in range(3)]
        cluster.run()
        got = [cluster.requests[r].generated for r in [r0] + rids2]
        assert got == want
        seeded = [e.stats["prefix_inserted_pages"] for e in cluster.engines]
        hits = [e.stats["prefix_hit_pages"] for e in cluster.engines]
        assert sum(1 for s in seeded if s) == 1       # cache is shard-local
        # Every follow-up request routed to the seeded shard and hit.
        assert hits[seeded.index(max(seeded))] > 0
        assert cluster.engine_stats["prefix_hit_pages"] == sum(hits)

    def test_engine_stats_sums_unknown_counters(self, smoke):
        cluster, _ = self._cluster(smoke)
        for i, eng in enumerate(cluster.engines):
            eng.stats["brand_new_counter"] = i + 1
        agg = cluster.engine_stats
        assert agg["brand_new_counter"] == 3
        assert agg["prefix_hit_pages"] == 0
