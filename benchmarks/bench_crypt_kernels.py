"""Crypt/Integ engine micro-benchmarks (CPU wall time + work counters).

Wall times are CPU-interpret numbers (this container has no TPU); the
`derived` column carries the structural counts that transfer: AES
invocations per protected byte for B-AES vs T-AES — the paper's
hardware-scaling claim restated as compute work.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baes, ctr, mac
from repro.core.secure_memory import SecureKeys


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list:
    keys = SecureKeys.derive(0)
    rng = np.random.default_rng(0)
    rows = []
    n_bytes = 1 << 20  # 1 MiB payload

    for block_bytes in (64, 512):
        n_blocks = n_bytes // block_bytes
        data = jnp.asarray(rng.integers(0, 256, n_bytes, dtype=np.uint8))
        cw = jnp.asarray(np.stack(
            [np.zeros(n_blocks, np.uint32),
             np.arange(n_blocks, dtype=np.uint32) * (block_bytes // 16),
             np.zeros(n_blocks, np.uint32),
             np.ones(n_blocks, np.uint32)], -1))

        # B-AES: one AES invocation per wide block.
        f_baes = jax.jit(lambda d, c: baes.baes_encrypt(
            d, keys.round_keys, c, block_bytes=block_bytes, key=keys.key))
        us = _time(f_baes, data, cw)
        rows.append({
            "name": f"crypt_baes_{block_bytes}B_1MiB",
            "us_per_call": us,
            "derived": (f"aes_calls={n_blocks} "
                        f"aes_calls_per_KiB={n_blocks / 1024:.1f} "
                        f"throughput={n_bytes / us:.1f}MB/s"),
        })

        # T-AES: one AES invocation per 16B segment.
        f_taes = jax.jit(lambda d: ctr.ctr_encrypt(
            d, keys.round_keys, jnp.uint32(0), jnp.uint32(0), jnp.uint32(0),
            jnp.uint32(1)))
        us_t = _time(f_taes, data)
        rows.append({
            "name": f"crypt_taes_{block_bytes}B_1MiB",
            "us_per_call": us_t,
            "derived": (f"aes_calls={n_bytes // 16} "
                        f"baes_aes_saving={1 - n_blocks / (n_bytes // 16):.1%} "
                        f"speedup_vs_taes={us_t / us:.2f}x"),
        })

    # Integ engine: NH + AES finalize per 64B optBlk over 1 MiB.
    n_blocks = n_bytes // 64
    blocks = jnp.asarray(rng.integers(0, 256, (n_blocks, 64), dtype=np.uint8))
    bind = mac.Binding.make(np.arange(n_blocks, dtype=np.uint32) * 4, 1, 0, 0,
                            np.arange(n_blocks, dtype=np.uint32))
    f_mac = jax.jit(lambda b: mac.layer_mac(
        b, bind, hash_key_u32=keys.hash_key, round_keys=keys.round_keys))
    us = _time(f_mac, blocks)
    rows.append({
        "name": "integ_layer_mac_64B_1MiB",
        "us_per_call": us,
        "derived": (f"optblk_macs={n_blocks} layer_macs=1 "
                    f"offchip_metadata_bytes=8 (vs {n_blocks * 8} per-block)"),
    })
    return rows
