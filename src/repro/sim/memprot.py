"""Memory-protection scheme overlay (paper §IV, Table III).

Given a workload's ``LayerTrace``, compute each protection scheme's
off-chip traffic:

  * data moved at the scheme's protection granularity (over-fetch vs.
    the 64B-burst baseline when protection blocks exceed / misalign
    with the accelerator's tile chunks — the paper's intra/inter-layer
    tiling argument against coarse blocks),
  * metadata: MACs at protection granularity; VNs (SGX keeps its native
    64B-line counter granularity) read on loads and read-modify-written
    on stores; integrity-tree levels streamed when too large for the
    on-chip VN cache,
  * SeDA: optBlk granularity from the SecureLoop-style search (aligned
    with chunks ⇒ no over-fetch), optBlk MACs folded on-chip into layer
    MACs, layer MACs charged off-chip ("for fairness", §IV-A).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.sim.npu_configs import NPUConfig
from repro.sim.scalesim import BURST_BYTES, LayerTrace, WorkloadTrace
from repro.sim.secureloop import optimal_block_for_streams

__all__ = ["SchemeModel", "SCHEME_MODELS", "LayerSecurityTraffic",
           "overlay_layer", "overlay_scheme", "WorkloadSecurityResult"]

MAC_BYTES = 8
VN_BYTES = 8
LINE = 64          # metadata line / tree-node bytes
TREE_ARITY = 8
SGX_VN_GRANULARITY = 64  # SGX counters protect 64B lines regardless of MAC gran


@dataclass(frozen=True)
class SchemeModel:
    name: str
    granularity: int          # MAC protection block bytes (0 = per-layer optBlk)
    mac_offchip: bool
    vn_offchip: bool
    integrity_tree: bool
    layer_mac_offchip: bool   # SeDA: one 8B MAC per layer off-chip
    vn_cache_bytes: int = 16 * 1024
    mac_cache_bytes: int = 8 * 1024


SCHEME_MODELS = {
    "baseline": SchemeModel("baseline", 0, False, False, False, False),
    "sgx64": SchemeModel("sgx64", 64, True, True, True, False),
    "sgx512": SchemeModel("sgx512", 512, True, True, True, False),
    "mgx64": SchemeModel("mgx64", 64, True, False, False, False),
    "mgx512": SchemeModel("mgx512", 512, True, False, False, False),
    "seda": SchemeModel("seda", 0, False, False, False, True),
}


@dataclass(frozen=True)
class LayerSecurityTraffic:
    data_bytes: float         # payload at protection granularity
    meta_read: float
    meta_write: float
    granularity: int

    @property
    def total(self) -> float:
        return self.data_bytes + self.meta_read + self.meta_write


@dataclass(frozen=True)
class WorkloadSecurityResult:
    scheme: str
    baseline_bytes: float
    protected_bytes: float
    layers: tuple

    @property
    def traffic_overhead(self) -> float:
        return self.protected_bytes / self.baseline_bytes - 1.0


def _boundary_overfetch(s, gran: int) -> float:
    """Extra bytes when protection blocks straddle chunk boundaries.

    Each contiguous chunk (tile row / embedding row / tensor span)
    starts and ends at arbitrary offsets within a ``gran``-byte
    protection block; decrypt+verify forces fetching the whole block.
    Expected waste per chunk ~ (gran - BURST) for unaligned placement
    ((gran-BURST)/2 per edge); reads only fetch, writes additionally
    read back the partial blocks to recompute their MACs (RMW).
    """
    if s.total_bytes <= 0 or gran <= BURST_BYTES:
        return 0.0
    chunk = max(s.chunk_bytes, 1.0)
    n_chunks = max(1.0, s.total_bytes / chunk)
    # Expected boundary waste per chunk over random block alignment:
    # (gran-BURST)/2 at the start edge and the same at the end edge.
    per_chunk = float(gran - BURST_BYTES) if chunk % gran else 0.0
    overfetch = n_chunks * per_chunk
    if s.is_write:
        overfetch *= 2.0  # read-modify-write of partial protection blocks
    return overfetch


def _tree_levels(n_leaf_lines: float) -> list[float]:
    levels = []
    lines = n_leaf_lines
    while lines > 1:
        lines = -(-lines // TREE_ARITY)
        levels.append(lines)
    return levels


def overlay_layer(trace: LayerTrace, scheme: SchemeModel,
                  npu: NPUConfig) -> LayerSecurityTraffic:
    if scheme.name == "baseline":
        return LayerSecurityTraffic(trace.total_bytes, 0.0, 0.0, BURST_BYTES)

    if scheme.granularity == 0:  # SeDA: per-layer optBlk search
        gran = optimal_block_for_streams(trace.streams, npu)
    else:
        gran = scheme.granularity

    data_bytes = 0.0
    read_blocks = 0.0
    write_blocks = 0.0
    for s in trace.streams:
        base = s.burst_bytes()
        if scheme.name == "seda":
            # optBlk aligns with the chunk layout: no over-fetch beyond
            # the 64B DRAM bursts the baseline already pays.
            moved = base
        else:
            moved = base + _boundary_overfetch(s, gran)
        data_bytes += moved
        blocks = moved / gran
        if s.is_write:
            write_blocks += blocks
        else:
            read_blocks += blocks

    meta_read = meta_write = 0.0
    if scheme.mac_offchip:
        # MAC lines streamed: reads fetch MACs; writes write them back.
        meta_read += read_blocks * MAC_BYTES
        meta_write += write_blocks * MAC_BYTES
    if scheme.vn_offchip:
        # SGX: VNs at native 64B-line granularity, independent of MAC size.
        vn_read_blocks = sum(s.burst_bytes() for s in trace.streams
                             if not s.is_write) / SGX_VN_GRANULARITY
        vn_write_blocks = sum(s.burst_bytes() for s in trace.streams
                              if s.is_write) / SGX_VN_GRANULARITY
        meta_read += vn_read_blocks * VN_BYTES
        # VN increment on store: read old, write new.
        meta_read += vn_write_blocks * VN_BYTES
        meta_write += vn_write_blocks * VN_BYTES
    if scheme.integrity_tree:
        total_vn_lines = (read_blocks + write_blocks) * VN_BYTES / LINE
        for level_lines in _tree_levels(total_vn_lines):
            level_bytes = level_lines * LINE
            if level_bytes > scheme.vn_cache_bytes / 4:
                meta_read += level_bytes  # streamed; upper levels stay pinned
    if scheme.layer_mac_offchip:
        meta_read += MAC_BYTES
        meta_write += MAC_BYTES

    return LayerSecurityTraffic(data_bytes, meta_read, meta_write, gran)


def overlay_scheme(trace: WorkloadTrace, scheme_name: str,
                   npu: NPUConfig) -> WorkloadSecurityResult:
    scheme = SCHEME_MODELS[scheme_name]
    layers = tuple(overlay_layer(t, scheme, npu) for t in trace.layers)
    baseline = sum(t.total_bytes for t in trace.layers)
    protected = sum(l.total for l in layers)
    return WorkloadSecurityResult(scheme_name, baseline, protected, layers)
