"""Shared config machinery: shapes, arch definitions, sharding rules."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

__all__ = ["Shape", "SHAPES", "ArchDef", "DEFAULT_RULES"]


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


# The assigned input-shape set (same four for every LM-family arch).
SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}

# Logical-axis -> mesh-axis sharding rules (MaxText-style).  The
# planner (launch/sharding.py) checks divisibility per tensor dim and
# falls back to replication when a rule does not divide.
DEFAULT_RULES = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",     # EP over the model axis
    "experts_r": None,      # router output dim: replicated
    "embed": "data",        # FSDP: shard d_model over the data axis
    "lora": None,
    "head_dim": None,
    "layers": None,
    "conv_k": None,
    "vision": None,
}


@dataclasses.dataclass(frozen=True)
class ArchDef:
    """One assigned architecture: exact config + reduced smoke config."""

    name: str
    family: str                      # dense | ssm | vlm | audio | hybrid | moe
    kind: str                        # lm | encdec
    make_config: Callable[[], Any]
    make_smoke_config: Callable[[], Any]
    source: str                      # provenance note from the assignment
    rules: dict = dataclasses.field(default_factory=dict)  # rule overrides
    # Shape applicability:
    sub_quadratic: bool = False      # runs long_500k
    notes: str = ""

    def supports(self, shape: Shape) -> bool:
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False  # full-attention archs skip (DESIGN.md §5)
        return True

    def sharding_rules(self) -> dict:
        rules = dict(DEFAULT_RULES)
        rules.update(self.rules)
        return rules
