"""End-to-end driver (deliverable b): train a ~100M-param model with the
full production feature set — SeDA boundary, secure checkpoints,
preemption + resume, straggler logging.

Full run (a few hundred steps, ~100M params — sized for a real machine;
use --preset tiny for a CPU-friendly rehearsal of the identical path):

    PYTHONPATH=src python examples/secure_training.py --preset full
    PYTHONPATH=src python examples/secure_training.py --preset tiny

The script *kills itself* halfway through (simulated preemption) and
resumes from the last secure checkpoint, proving the fault-tolerance
path end to end.
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train

PRESETS = {
    # ~135M params (the full smollm-135m config), a few hundred steps.
    "full": ["--arch", "smollm-135m", "--steps", "300",
             "--global-batch", "16", "--seq-len", "512", "--lr", "3e-4"],
    # Identical code path, reduced config: finishes in ~3 min on CPU.
    "tiny": ["--arch", "smollm-135m", "--smoke", "--steps", "60",
             "--global-batch", "8", "--seq-len", "64", "--lr", "2e-3"],
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--scheme", default="seda")
    args = ap.parse_args()

    base = PRESETS[args.preset] + ["--scheme", args.scheme]
    with tempfile.TemporaryDirectory() as ckpt_dir:
        total_steps = int(base[base.index("--steps") + 1])
        half = total_steps // 2

        print(f"=== phase 1: train to step {half}, then 'preemption' ===")
        phase1 = list(base)
        phase1[phase1.index("--steps") + 1] = str(half)
        out1 = train.main(phase1 + ["--ckpt-dir", ckpt_dir,
                                    "--ckpt-every", str(max(10, half // 3)),
                                    "--log-every", "10"])
        print(f"phase 1 done at loss {out1['last_loss']:.3f} — simulating "
              f"preemption (process state discarded)\n")

        print("=== phase 2: cold restart, resume from secure checkpoint ===")
        out2 = train.main(base + ["--ckpt-dir", ckpt_dir,
                                  "--ckpt-every", "1000000",
                                  "--log-every", "10"])
        print(f"resumed and finished: loss {out1['first_loss']:.3f} -> "
              f"{out2['last_loss']:.3f} over {total_steps} steps "
              f"(phase-2 ran {out2['steps']} steps after restore)")
        assert out2["steps"] < total_steps, "resume did not skip done steps"
    print("=== secure_training OK ===")


if __name__ == "__main__":
    main()
