"""Sharding planner: logical axes -> NamedShardings over the mesh.

Every param carries logical axes in its ParamSpec; ArchDef supplies the
logical->mesh rules (DEFAULT_RULES + per-arch overrides).  The planner
enforces two invariants per tensor:

  * divisibility — a rule only applies when the dim size divides the
    mesh-axis size (else that dim replicates; recorded per arch);
  * axis uniqueness — one mesh axis shards at most one dim of a tensor
    (first dim in spec order wins; e.g. expert weights (E, d, ff) give
    'model' to E, so the 'mlp' rule falls back for ff).

Batch/activation sharding: batch shards over the DP axes (('pod',
'data') on the multi-pod mesh); when the global batch does not divide
(long_500k has batch 1), the planner switches to sequence sharding
(SP) for the long axis instead.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes, mesh_axis_size
from repro.models.layers import ParamSpec

__all__ = ["param_shardings", "batch_sharding", "logical_sharding",
           "cache_shardings", "replicated", "plan_report"]


def _resolve_axes(shape, axes, rules, mesh):
    out = []
    used: set = set()
    for dim, logical in zip(shape, axes):
        mesh_axis = rules.get(logical)
        if mesh_axis is None:
            out.append(None)
            continue
        key = tuple(mesh_axis) if isinstance(mesh_axis, (tuple, list)) else mesh_axis
        names = key if isinstance(key, tuple) else (key,)
        if any(n not in mesh.axis_names for n in names):
            out.append(None)
            continue
        size = mesh_axis_size(mesh, mesh_axis)
        if dim % size == 0 and size > 1 and key not in used \
                and not any(n in used for n in names):
            out.append(mesh_axis)
            used.add(key)
            used.update(names)
        else:
            out.append(None)
    return out


def logical_sharding(spec_shape, logical_axes, rules, mesh) -> NamedSharding:
    axes = _resolve_axes(spec_shape, logical_axes, rules, mesh)
    return NamedSharding(mesh, P(*axes))


def param_shardings(specs: Any, rules: dict, mesh) -> Any:
    """Spec pytree -> NamedSharding pytree."""
    return jax.tree_util.tree_map(
        lambda s: logical_sharding(s.shape, s.axes, rules, mesh),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def batch_sharding(mesh, global_batch: int, *, seq_dims: int = 1):
    """(batch_axes, seq_axis) choice for activations/inputs.

    Returns (batch_pspec_entry, seq_pspec_entry): batch over DP axes
    when divisible, else replicate batch and shard the sequence dim
    over 'data' (SP for the batch=1 long-context cells).
    """
    dp = dp_axes(mesh)
    dp_size = mesh_axis_size(mesh, dp)
    if global_batch % dp_size == 0 and dp_size > 1:
        return (dp if len(dp) > 1 else dp[0]), None
    return None, "data"


def token_sharding(mesh, global_batch: int, seq_len: int) -> NamedSharding:
    b_axis, s_axis = batch_sharding(mesh, global_batch)
    if s_axis is not None and seq_len % mesh_axis_size(mesh, s_axis) != 0:
        s_axis = None
    return NamedSharding(mesh, P(b_axis, s_axis))


def cache_shardings(cache_axes_tree: Any, cache_struct_tree: Any, rules: dict,
                    mesh, global_batch: int) -> Any:
    """Shardings for decode caches.

    ``cache_axes_tree`` mirrors the cache structs with tuples of logical
    axis names ('layers', 'batch', 'seq', 'kv_heads', 'head_dim', ...).
    """
    b_axis, s_axis = batch_sharding(mesh, global_batch)
    cache_rules = dict(rules)
    cache_rules.update({"batch": b_axis, "seq": s_axis})

    def one(axes, struct):
        return logical_sharding(struct.shape, axes, cache_rules, mesh)

    return jax.tree_util.tree_map(
        one, cache_axes_tree, cache_struct_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))


def plan_report(specs: Any, rules: dict, mesh) -> list:
    """Human-readable plan: [(path, shape, resolved PartitionSpec)]."""
    flat, _ = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    report = []
    for path, s in flat:
        axes = _resolve_axes(s.shape, s.axes, rules, mesh)
        report.append((jax.tree_util.keystr(path), s.shape, tuple(axes)))
    return report
