"""Gate the observability overhead recorded by the obs sweep.

    python benchmarks/check_obs_overhead.py bench-obs-overhead.json

Reads the ``obs_overhead`` JSON written by ``bench_secure_serving.py
--obs-json`` and fails (exit 1) when any scheme's fully-instrumented
run (tracing + metrics + audit) breaks the contract:

* ``tokens_match`` — instrumentation must be observation-only: the
  generated tokens are bit-identical with obs on and off;
* ``tok_per_s_on >= (1 - tolerance) * tok_per_s_off`` — the
  instrumented rate stays within ``--tolerance`` (default 5%) of the
  bare rate;
* the trace recorded events and the audit chain verifies.
"""

from __future__ import annotations

import argparse
import json
import sys


def check(data: dict, tolerance: float) -> list:
    """Returns a list of failure strings (empty = pass)."""
    if data.get("benchmark") != "obs_overhead":
        return [f"not an obs_overhead artifact: {data.get('benchmark')!r}"]
    failures = []
    for r in data["results"]:
        tag = f"scheme={r['scheme']} batch={r['batch']}"
        if not r["tokens_match"]:
            failures.append(f"{tag}: tokens differ with observability on")
        floor = (1.0 - tolerance) * r["tok_per_s_off"]
        if r["tok_per_s_on"] < floor:
            failures.append(
                f"{tag}: instrumented {r['tok_per_s_on']:.1f} tok/s is "
                f"below {floor:.1f} ({tolerance:.0%} under bare "
                f"{r['tok_per_s_off']:.1f})")
        if r["trace_events"] <= 0:
            failures.append(f"{tag}: tracer recorded no events")
        if not r["audit_chain_ok"]:
            failures.append(f"{tag}: audit chain failed verification")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("json_path")
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="allowed fractional tok/s regression (default 5%%)")
    args = ap.parse_args(argv)
    with open(args.json_path) as f:
        data = json.load(f)
    failures = check(data, args.tolerance)
    for msg in failures:
        print(f"[check-obs] FAIL {msg}")
    if failures:
        return 1
    n = len(data["results"])
    print(f"[check-obs] OK: {n} schemes within {args.tolerance:.0%}, "
          f"tokens identical, traces non-empty, audit chains verify")
    return 0


if __name__ == "__main__":
    sys.exit(main())
