"""mamba2-780m — SSD (state-space duality) [arXiv:2405.21060; unverified].

[ssm] 48L d_model=1536 (attn-free) vocab=50280, ssm_state=128.
d_inner = 2*d_model = 3072, head_dim 64 -> 48 SSD heads, conv width 4.
"""

from repro.configs.base import ArchDef
from repro.models.lm import LMConfig
from repro.models.mamba2 import Mamba2Config


def make_config() -> LMConfig:
    return LMConfig(
        name="mamba2-780m",
        n_layers=48, d_model=1536, n_heads=0, n_kv=0, head_dim=1,
        d_ff=0, vocab=50280,
        mixer="mamba", ffn="none", tie_embeddings=True,
        ssd_chunk=512,  # hillclimbed: -6%% memory term vs 256 (EXPERIMENTS.md)
        mamba=Mamba2Config(d_model=1536, d_inner=3072, head_dim=64,
                           d_state=128, n_groups=1, d_conv=4),
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="mamba2-780m-smoke",
        n_layers=2, d_model=32, n_heads=0, n_kv=0, head_dim=1,
        d_ff=0, vocab=256, dtype="float32",
        mixer="mamba", ffn="none", ssd_chunk=16, remat="none",
        mamba=Mamba2Config(d_model=32, d_inner=64, head_dim=16, d_state=8,
                           n_groups=1, d_conv=4),
    )


ARCH = ArchDef(
    name="mamba2-780m", family="ssm", kind="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    source="arXiv:2405.21060; unverified",
    sub_quadratic=True,  # O(1) decode state: runs long_500k
    notes="Attention-free: SeDA's layer MACs cover the SSD block "
          "projections; the SSM state never crosses the untrusted "
          "boundary (stays on-chip).",
)
