"""SeDA-secured checkpoints: the production deployment of the paper.

A checkpoint is exactly a pytree crossing the untrusted boundary
(persistent storage).  Every leaf is B-AES encrypted and carries a
layer MAC (XOR of its optBlk MACs, RePA-bound); the manifest records
the layer MACs, a model MAC, version numbers and the data-pipeline
state.  Restore verifies before trusting — a flipped byte anywhere
fails loudly.

Fault-tolerance properties:
  * crash-safe: leaves and manifest are fsynced into ``<dir>.tmp``, the
    manifest is written last, and the publish is a rename that never
    destroys the previous checkpoint first (the old directory is moved
    aside and removed only after the new one is in place) — a crash at
    any point leaves either the old or the new checkpoint discoverable,
    never a torn one (``latest_step``/``load_checkpoint`` ignore
    ``.tmp``/``.old`` debris and manifest-less directories);
  * self-describing manifest (step, specs, mesh shape at save time);
  * elastic: arrays are stored unsharded (gathered), so restore can
    re-shard onto any mesh (launch/elastic.py);
  * resumable data pipeline state rides in the manifest.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import secure_memory as sm
from repro.core import vn as vn_mod

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "CheckpointError"]

MANIFEST = "manifest.json"


class CheckpointError(RuntimeError):
    pass


def _leaf_files(flat_paths) -> list:
    return [f"leaf_{i:05d}.bin" for i in range(len(flat_paths))]


def _write_durable(path: str, data: bytes) -> None:
    """Write + flush + fsync: the bytes are on disk before rename."""
    with open(path, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())


def _fsync_dir(path: str) -> None:
    """Persist directory-entry metadata (renames) — best effort on
    filesystems that reject directory fds."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - platform-dependent
        return
    try:
        os.fsync(fd)
    except OSError:  # pragma: no cover - platform-dependent
        pass
    finally:
        os.close(fd)


def _is_complete(path: str) -> bool:
    """A checkpoint directory counts only once its manifest exists —
    the manifest is written last, so its presence implies every leaf."""
    return os.path.isfile(os.path.join(path, MANIFEST))


def save_checkpoint(directory: str, step: int, tree: Any,
                    keys: sm.SecureKeys, *, block_bytes: int = 512,
                    extra_state: Optional[dict] = None,
                    mesh_shape: Optional[tuple] = None,
                    audit_proofs: Optional[list] = None) -> str:
    """Protect ``tree`` with SeDA and write atomically.

    ``audit_proofs`` threads serving-side audit evidence into the
    manifest: a list of :class:`repro.serve.merkle_pool.AuditProof`
    objects (or their ``to_dict()`` forms) — typically one per live
    session, from ``Engine.audit_proof`` / ``ClusterEngine.audit_proof``
    — so a restored session carries a verifiable membership transcript
    instead of trust-me semantics.  :func:`load_checkpoint` re-verifies
    each stored proof host-independently before returning.

    Returns the final checkpoint path ``<directory>/step_<step>``.
    """
    spec = sm.make_region_spec(tree, block_bytes=block_bytes,
                               role=int(vn_mod.Role.WEIGHT))
    state = sm.protect(tree, keys, spec, step=step)

    final = os.path.join(directory, f"step_{step:08d}")
    tmp, old = final + ".tmp", final + ".old"
    for stale in (tmp, old):            # debris from a prior crash
        if os.path.exists(stale):
            shutil.rmtree(stale)
    os.makedirs(tmp, exist_ok=True)

    flat, _ = jax.tree_util.tree_flatten(tree)
    files = _leaf_files(flat)
    for ct, fname in zip(state.ciphertexts, files):
        _write_durable(os.path.join(tmp, fname),
                       np.asarray(ct).tobytes())

    manifest = {
        "step": step,
        "block_bytes": block_bytes,
        "vn_lo": int(state.vn_lo),
        "layer_macs": np.asarray(state.layer_macs).tolist(),
        "model_mac": np.asarray(state.model_mac).tolist(),
        "leaves": [
            {"file": fname, "path": layout.path,
             "shape": list(layout.spec.shape), "dtype": layout.spec.dtype,
             "nbytes": layout.spec.nbytes, "layer_id": layout.layer_id}
            for fname, layout in zip(files, spec.addr_map.leaves)
        ],
        "mesh_shape": list(mesh_shape) if mesh_shape else None,
        "extra_state": extra_state or {},
        "audit_proofs": [p if isinstance(p, dict) else p.to_dict()
                         for p in (audit_proofs or [])],
    }
    # The manifest is written LAST (and fsynced): its presence is the
    # commit record for the whole directory.
    _write_durable(os.path.join(tmp, MANIFEST),
                   json.dumps(manifest, indent=1).encode())
    _fsync_dir(tmp)
    # Publish without a destroy-then-rename window: move any previous
    # checkpoint aside, rename the new one in, only then drop the old.
    if os.path.exists(final):
        os.rename(final, old)
    os.rename(tmp, final)  # atomic publish
    _fsync_dir(directory)
    if os.path.exists(old):
        shutil.rmtree(old)
    return final


def load_checkpoint(path: str, template: Any, keys: sm.SecureKeys,
                    *, verify: str = "layer") -> tuple:
    """Load + decrypt + verify.  ``template`` fixes the pytree structure
    (arrays or ShapeDtypeStructs).  Returns (tree, manifest).

    Raises CheckpointError when integrity verification fails.
    """
    if not _is_complete(path):
        raise CheckpointError(f"no manifest in {path}: not a published "
                              f"checkpoint (torn or foreign directory)")
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)

    spec = sm.make_region_spec(template,
                               block_bytes=int(manifest["block_bytes"]),
                               role=int(vn_mod.Role.WEIGHT))
    if len(spec.addr_map.leaves) != len(manifest["leaves"]):
        raise CheckpointError(
            f"leaf count mismatch: template {len(spec.addr_map.leaves)} vs "
            f"checkpoint {len(manifest['leaves'])}")
    for layout, entry in zip(spec.addr_map.leaves, manifest["leaves"]):
        if (list(layout.spec.shape) != entry["shape"]
                or layout.spec.dtype != entry["dtype"]):
            raise CheckpointError(
                f"spec mismatch at {layout.path}: template "
                f"{layout.spec.shape}/{layout.spec.dtype} vs checkpoint "
                f"{entry['shape']}/{entry['dtype']}")

    cts = []
    for layout, entry in zip(spec.addr_map.leaves, manifest["leaves"]):
        raw = np.fromfile(os.path.join(path, entry["file"]), dtype=np.uint8)
        if raw.size != layout.padded_bytes:
            raise CheckpointError(f"truncated leaf file {entry['file']}")
        cts.append(jnp.asarray(raw))

    state = sm.SecureState(
        ciphertexts=tuple(cts),
        layer_macs=jnp.asarray(np.array(manifest["layer_macs"], np.uint8)),
        model_mac=jnp.asarray(np.array(manifest["model_mac"], np.uint8)),
        vn_lo=jnp.uint32(manifest["vn_lo"]),
    )
    tree, ok = sm.unprotect(state, keys, spec, verify=verify)
    if not bool(ok):
        raise CheckpointError(
            f"integrity verification FAILED for checkpoint {path} "
            f"(tampered or wrong key)")
    _verify_manifest_proofs(path, manifest)
    return tree, manifest


def _verify_manifest_proofs(path: str, manifest: dict) -> None:
    """Re-verify any serving audit proofs riding in the manifest.

    Each stored proof must still be internally consistent — leaf MAC
    hashes to the committed leaf, sibling path folds to the stated
    shard root, shard root binds into the stated cluster root.  A
    tampered transcript fails the restore loudly, exactly like a
    tampered weight leaf.  (Root *freshness* is the tenant's check at
    audit time, against the live root — a manifest can only attest the
    roots that were current at save time.)
    """
    stored = manifest.get("audit_proofs") or []
    if not stored:
        return
    # jax-free on purpose: proofs verify with hashlib alone.
    from repro.serve import merkle_pool as mkp
    for i, entry in enumerate(stored):
        try:
            mkp.verify_proof(mkp.proof_from_dict(entry))
        except mkp.ProofError as err:
            raise CheckpointError(
                f"audit proof {i} in checkpoint {path} failed verification "
                f"({type(err).__name__}: {err}) — session transcript "
                f"tampered") from err


def latest_step(directory: str) -> Optional[int]:
    """The newest *published* step: ``.tmp``/``.old`` debris and
    directories without a manifest (torn by a crash predating the
    write-manifest-last protocol) are never offered for restore."""
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")
             and not d.endswith(".tmp") and not d.endswith(".old")
             and _is_complete(os.path.join(directory, d))]
    return max(steps) if steps else None
