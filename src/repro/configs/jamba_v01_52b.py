"""jamba-v0.1-52b — Mamba+attention 1:7 interleave, MoE [arXiv:2403.19887; hf].

[hybrid] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16 experts top-2.  Period-8 blocks: attention at in-block index 3,
mamba elsewhere; MoE FFN on every 2nd layer.  (Jamba v0.1 uses Mamba-1
mixers; we instantiate the SSD/Mamba-2 block — the state-space mixer of
this framework — with Jamba's state size 16.  Recorded in DESIGN.md.)
"""

from repro.configs.base import ArchDef
from repro.models.lm import LMConfig
from repro.models.mamba2 import Mamba2Config
from repro.models.moe import MoEConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="jamba-v0.1-52b",
        n_layers=32, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
        d_ff=14336, vocab=65536,
        mixer="mamba", attn_every=8, attn_offset=3,
        ffn="moe", moe_every=2, moe_offset=1, tie_embeddings=True,
        mamba=Mamba2Config(d_model=4096, d_inner=8192, head_dim=128,
                           d_state=16, n_groups=1, d_conv=4),
        moe=MoEConfig(n_experts=16, top_k=2, d_model=4096, d_ff=14336,
                      capacity_factor=1.25),
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="jamba-v0.1-52b-smoke",
        n_layers=8, d_model=32, n_heads=4, n_kv=2, head_dim=8,
        d_ff=64, vocab=256, dtype="float32",
        mixer="mamba", attn_every=8, attn_offset=3,
        ffn="moe", moe_every=2, moe_offset=1,
        q_block=16, kv_block=16, ssd_chunk=8, remat="none",
        mamba=Mamba2Config(d_model=32, d_inner=64, head_dim=16, d_state=8,
                           n_groups=1, d_conv=4),
        moe=MoEConfig(n_experts=4, top_k=2, d_model=32, d_ff=64,
                      capacity_factor=2.0),
    )


ARCH = ArchDef(
    name="jamba-v0.1-52b", family="hybrid", kind="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    source="arXiv:2403.19887; hf",
    sub_quadratic=True,  # only 4/32 layers hold KV: runs long_500k
    notes="1:7 attn:mamba, MoE every 2nd layer.  long_500k KV cache "
          "exists only for the 4 attention layers.",
)
