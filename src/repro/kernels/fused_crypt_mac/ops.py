"""Wrapper: fused secure-read (decrypt + verify hash) for flat buffers."""

from __future__ import annotations

import jax

from repro.core import mac
from repro.core.bytesutil import bytes_to_u32, u32_to_bytes
from repro.kernels.aes_ctr.ops import keystream_bytes, keystream_lanes
from repro.kernels.fused_crypt_mac.kernel import fused_crypt_mac
from repro.kernels.otp_xor.ops import _div_lanes

__all__ = ["secure_read_kernel", "fused_crypt_mac"]


def secure_read_kernel(ct_u8: jax.Array, binding: mac.Binding,
                       round_keys: jax.Array, counter_words: jax.Array,
                       hash_key_u32: jax.Array, *, block_bytes: int,
                       subbytes: str = "take",
                       interpret: bool | None = None):
    """Kernel-backed secure read: returns (plaintext_u8, block_macs_u8).

    One pass over the ciphertext performs both the B-AES decrypt and
    the NH compression; the AES finalization of the MACs runs on the
    tiny hash list.  Bit-identical to the unfused core path.
    """
    n_segments = block_bytes // 16
    if n_segments - 1 > 10:
        raise ValueError("kernel path supports narrow mode (<= 11 segments)")
    base = keystream_lanes(counter_words, round_keys, subbytes=subbytes,
                           interpret=interpret)
    ct = bytes_to_u32(ct_u8).reshape(-1, n_segments * 4)
    n = ct.shape[0]
    div = _div_lanes(round_keys, n_segments)
    bind_words = binding.words(n)
    key = hash_key_u32[: ct.shape[1] + 8]
    pt_lanes, hashes = fused_crypt_mac(ct, base, div, bind_words, key,
                                       interpret=interpret)
    fin = mac.finalize_words(hashes[:, 0], hashes[:, 1], binding)
    pads = keystream_bytes(fin, round_keys, subbytes=subbytes,
                           interpret=interpret)
    pt = u32_to_bytes(pt_lanes.reshape(-1)).reshape(ct_u8.shape)
    return pt, pads[:, : mac.MAC_BYTES]
