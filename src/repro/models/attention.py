"""GQA/MQA attention: chunked (flash-style) training path + cached decode.

The training/prefill path never materializes the full (L, L) score
matrix: queries are processed in blocks with an online-softmax scan
over KV blocks (memory O(L * block) per head) — required for the 32k
prefill cells and the right roofline shape everywhere else.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import rope, spec

__all__ = ["attention_specs", "attention", "decode_attention", "KVCache",
           "init_kv_cache_specs", "decode_lengths", "scatter_new_token"]

NEG_INF = -1e30


def attention_specs(d_model: int, n_heads: int, n_kv: int, head_dim: int,
                    dtype: str):
    return {
        "wq": spec((d_model, n_heads, head_dim), ("embed", "heads", "head_dim"),
                   dtype),
        "wk": spec((d_model, n_kv, head_dim), ("embed", "kv_heads", "head_dim"),
                   dtype),
        "wv": spec((d_model, n_kv, head_dim), ("embed", "kv_heads", "head_dim"),
                   dtype),
        "wo": spec((n_heads, head_dim, d_model), ("heads", "head_dim", "embed"),
                   dtype),
    }


class KVCache(NamedTuple):
    k: jax.Array       # (B, L_max, n_kv, head_dim)
    v: jax.Array       # (B, L_max, n_kv, head_dim)
    length: jax.Array  # scalar int32: tokens currently cached


def init_kv_cache_specs(batch: int, max_len: int, n_kv: int, head_dim: int,
                        dtype: str):
    return KVCache(
        k=jax.ShapeDtypeStruct((batch, max_len, n_kv, head_dim), jnp.dtype(dtype)),
        v=jax.ShapeDtypeStruct((batch, max_len, n_kv, head_dim), jnp.dtype(dtype)),
        length=jax.ShapeDtypeStruct((), jnp.int32),
    )


def _qkv(params, x, positions):
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, params["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, params["wv"])
    q = rope(q, positions)
    k = rope(k, positions)
    return q, k, v


def _chunked_causal_attention(q, k, v, *, q_block: int, kv_block: int):
    """Online-softmax blockwise causal attention.

    q: (B, Lq, H, D); k/v: (B, Lk, Hkv, D) with H % Hkv == 0.
    Assumes Lq == Lk (training/prefill) for the causal structure.
    """
    b, lq, h, d = q.shape
    _, lk, hkv, _ = k.shape
    groups = h // hkv
    scale = 1.0 / math.sqrt(d)

    q_block = min(q_block, lq)
    kv_block = min(kv_block, lk)
    nq = -(-lq // q_block)
    nk = -(-lk // kv_block)
    lq_pad, lk_pad = nq * q_block, nk * kv_block
    if lq_pad != lq:
        q = jnp.pad(q, ((0, 0), (0, lq_pad - lq), (0, 0), (0, 0)))
    if lk_pad != lk:
        k = jnp.pad(k, ((0, 0), (0, lk_pad - lk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, lk_pad - lk), (0, 0), (0, 0)))

    # (B, nq, qb, H, D) -> scan over nq
    qb = q.reshape(b, nq, q_block, h, d).transpose(1, 0, 3, 2, 4)  # (nq,B,H,qb,D)
    kb = k.reshape(b, nk, kv_block, hkv, d).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, kv_block, hkv, d).transpose(1, 0, 3, 2, 4)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx
        qif = qi.astype(jnp.float32) * scale
        # Broadcast kv heads to q heads via reshape (B, Hkv, g, qb, D).
        qg = qif.reshape(b, hkv, groups, q_block, d)

        def kv_step(carry, kv_and_idx):
            m, s, o = carry
            ki, vi, ik = kv_and_idx
            logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg,
                                ki.astype(jnp.float32))
            qpos = iq * q_block + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, kv_block), 0)
            kpos = ik * kv_block + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, kv_block), 1)
            # Mask via small f32 (qb, kvb) tensors — a broadcast boolean
            # `where` materializes a full (B,H,qb,kvb) pred temp per kv
            # step once XLA hoists it out of the scan.
            keep = (kpos <= qpos).astype(jnp.float32)
            bias = (1.0 - keep) * NEG_INF
            logits = logits + bias[None, None, None]
            new_m = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - new_m)
            # Re-scale after the exp: a fully-masked block would otherwise
            # contribute exp(NEG_INF - NEG_INF) = 1 per position.
            p = jnp.exp(logits - new_m[..., None]) * keep[None, None, None]
            new_s = s * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bhkd->bhgqd", p, vi.astype(jnp.float32))
            new_o = o * alpha[..., None] + pv
            return (new_m, new_s, new_o), None

        m0 = jnp.full((b, hkv, groups, q_block), NEG_INF, jnp.float32)
        s0 = jnp.zeros((b, hkv, groups, q_block), jnp.float32)
        o0 = jnp.zeros((b, hkv, groups, q_block, d), jnp.float32)
        ik = jnp.arange(nk)
        (m, s, o), _ = jax.lax.scan(kv_step, (m0, s0, o0), (kb, vb, ik))
        out = o / jnp.maximum(s[..., None], 1e-30)
        return None, out.reshape(b, h, q_block, d)

    iq = jnp.arange(nq)
    _, outs = jax.lax.scan(q_step, None, (qb, iq))  # (nq, B, H, qb, D)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, lq_pad, h, d)
    return out[:, :lq].astype(q.dtype)


def attention(params, x, positions, *, q_block: int = 512,
              kv_block: int = 512, return_kv: bool = False):
    """Causal self-attention for training/prefill.  x: (B, L, d)."""
    q, k, v = _qkv(params, x, positions)
    ctx = _chunked_causal_attention(q, k, v, q_block=q_block,
                                    kv_block=kv_block)
    out = jnp.einsum("blhk,hkd->bld", ctx, params["wo"])
    if return_kv:
        return out, (k, v)
    return out


def decode_lengths(length: jax.Array, batch: int):
    """Normalize a decode cache length to per-sequence form.

    ``length`` may be a scalar (all sequences aligned, the classic
    serve path) or a (B,) vector (continuous batching: each slot
    decodes at its own position).  Returns ``(per_seq, lengths)`` with
    ``lengths`` always (B,) int32.
    """
    per_seq = length.ndim == 1
    lengths = length if per_seq else jnp.broadcast_to(length[None], (batch,))
    return per_seq, lengths.astype(jnp.int32)


def scatter_new_token(cache_arr, new, length, lengths, per_seq: bool):
    """Write a (B, 1, ...) new-token slice at each sequence's position.

    Per-sequence lengths use a one-hot masked write; the scalar path
    keeps the cheaper dynamic_update_slice.
    """
    if per_seq:
        l_max = cache_arr.shape[1]
        hit = (jnp.arange(l_max, dtype=jnp.int32)[None, :]
               == lengths[:, None])                    # (B, L)
        hit = hit.reshape(hit.shape + (1,) * (cache_arr.ndim - 2))
        return jnp.where(hit, new.astype(cache_arr.dtype), cache_arr)
    return jax.lax.dynamic_update_slice_in_dim(
        cache_arr, new.astype(cache_arr.dtype), length, axis=1)


def decode_attention(params, x, cache: KVCache, *, kv_shard_axis=None):
    """Single-token decode.  x: (B, 1, d); returns (out, new_cache).

    The new token's K/V are written at ``cache.length``; attention runs
    over the full cache with positions >= length masked out.  See
    :func:`decode_lengths` for the scalar vs (B,) length forms.
    """
    b, one, d = x.shape
    assert one == 1
    per_seq, lengths = decode_lengths(cache.length, b)
    positions = lengths[:, None]                       # (B, 1)
    q, k_new, v_new = _qkv(params, x, positions)

    l_max = cache.k.shape[1]
    k = scatter_new_token(cache.k, k_new, cache.length, lengths, per_seq)
    v = scatter_new_token(cache.v, v_new, cache.length, lengths, per_seq)

    h = q.shape[2]
    hkv = k.shape[2]
    groups = h // hkv
    scale = 1.0 / math.sqrt(q.shape[-1])
    qg = (q.astype(jnp.float32) * scale).reshape(b, 1, hkv, groups, -1)
    logits = jnp.einsum("bqhgd,blhd->bhgql", qg, k.astype(jnp.float32))
    mask = (jnp.arange(l_max)[None, None, None, None, :]
            <= lengths[:, None, None, None, None])
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhgql,blhd->bqhgd", p, v.astype(jnp.float32))
    ctx = ctx.reshape(b, 1, h, -1).astype(x.dtype)
    out = jnp.einsum("blhk,hkd->bld", ctx, params["wo"])
    new_cache = KVCache(k, v, cache.length + 1)
    return out, new_cache
