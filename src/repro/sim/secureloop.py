"""SecureLoop-style optimal authentication-block (optBlk) search (§III-C).

The paper uses SecureLoop's scheduling search to pick, per layer, the
authentication granularity that (a) aligns with the layer's tile fetch
chunks (no over-fetch, no redundant re-authentication of halo overlap)
and (b) minimizes metadata traffic, while also matching the *producer*
layer's write pattern with the *consumer* layer's read pattern
(inter-layer tiling, Fig. 3(b)).

Cost per candidate granularity g for a (total, chunk) stream:

    meta(g)      = blocks(g) * MAC_BYTES        (finer g = more MACs)
    overfetch(g) = moved(g) - moved(64B burst)  (coarser g = waste)
    halo(g)      = re-authenticated halo overlap when g spans rows the
                   next tile re-reads (conv windows with R > stride)

optBlk = argmin of the summed stream costs.  The cross-layer variant
minimizes max(producer write cost, consumer read cost) so one
granularity serves the ofmap_i -> ifmap_{i+1} tensor.
"""

from __future__ import annotations

from repro.sim.npu_configs import NPUConfig

__all__ = ["CANDIDATE_BLOCKS", "optimal_block_for_streams",
           "optimal_block_cross_layer", "stream_cost"]

CANDIDATE_BLOCKS = (32, 64, 128, 256, 512, 1024, 2048, 4096)
MAC_BYTES = 8
BURST = 64


def _rounded(total: float, chunk: float, g: int) -> float:
    if total <= 0:
        return 0.0
    chunk = max(chunk, 1.0)
    n_chunks = max(1.0, total / chunk)
    return n_chunks * (-(-chunk // g) * g)


def stream_cost(total: float, chunk: float, g: int, *,
                halo_fraction: float = 0.0) -> float:
    """Extra off-chip bytes for protecting one stream at granularity g."""
    if total <= 0:
        return 0.0
    moved = _rounded(total, chunk, g)
    baseline = _rounded(total, chunk, BURST)
    overfetch = max(0.0, moved - baseline)
    blocks = moved / g
    meta = blocks * MAC_BYTES
    # Halo rows are re-read by adjacent tiles: blocks spanning the halo
    # must be re-authenticated; cost grows with g beyond the chunk.
    halo = halo_fraction * total * (g / max(chunk, g))
    return meta + overfetch + halo


def optimal_block_for_streams(streams, npu: NPUConfig) -> int:
    """Intra-layer optBlk: argmin summed stream cost over candidates."""
    del npu  # granularity search is bandwidth-agnostic
    best_g, best_cost = CANDIDATE_BLOCKS[0], float("inf")
    for g in CANDIDATE_BLOCKS:
        cost = sum(stream_cost(s.total_bytes, s.chunk_bytes, g,
                               halo_fraction=s.halo_fraction)
                   for s in streams)
        if cost < best_cost:
            best_g, best_cost = g, cost
    return best_g


def optimal_block_cross_layer(producer, consumer, npu: NPUConfig) -> int:
    """Inter-layer optBlk for the ofmap_i -> ifmap_{i+1} tensor."""
    del npu
    prod = [s for s in producer.streams if s.is_write]
    cons = [s for s in consumer.streams if s.name == "ifmap"]
    best_g, best_cost = CANDIDATE_BLOCKS[0], float("inf")
    for g in CANDIDATE_BLOCKS:
        wcost = sum(stream_cost(s.total_bytes, s.chunk_bytes, g) for s in prod)
        rcost = sum(stream_cost(s.total_bytes, s.chunk_bytes, g,
                                halo_fraction=s.halo_fraction) for s in cons)
        cost = max(wcost, rcost)
        if cost < best_cost:
            best_g, best_cost = g, cost
    return best_g
