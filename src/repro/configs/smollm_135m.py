"""smollm-135m — llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf].

[dense] 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
"""

from repro.configs.base import ArchDef
from repro.models.lm import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="smollm-135m",
        n_layers=30, d_model=576, n_heads=9, n_kv=3, head_dim=64,
        d_ff=1536, vocab=49152,
        mixer="attn", ffn="dense", tie_embeddings=True,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="smollm-135m-smoke",
        n_layers=2, d_model=48, n_heads=3, n_kv=1, head_dim=16,
        d_ff=96, vocab=256, dtype="float32",
        mixer="attn", ffn="dense", q_block=16, kv_block=16, remat="none",
    )


ARCH = ArchDef(
    name="smollm-135m", family="dense", kind="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
    rules={"heads": None, "kv_heads": None},  # 9 and 3 don't divide 16
    notes="9 q-heads / 3 kv-heads not divisible by model=16: attention "
          "replicates over the model axis; d_ff=1536 (96/shard) and "
          "vocab TP-shard normally; d_model=576 not divisible by "
          "data=16, so FSDP falls back to replication (planner).",
)
