"""Continuous-batching secure serving engine over the paged KV pool.

The engine multiplexes many requests over ``max_slots`` decode lanes
and a shared pool of MAC-protected KV pages (:mod:`repro.serve.kv_pages`):

* **admission** — waiting requests are prefetched into a free slot when
  the pool has pages for their prompt; prefill runs per request and the
  resulting cache pages are encrypted + MACed into the pool;
* **decode** — one jitted computation per tick batches every running
  slot: gather pages -> decrypt -> verify touched pages -> attend/append
  -> re-encrypt + re-MAC only the dirty page per slot.  All schemes from
  :data:`repro.core.secure_exec.SCHEMES` run through the same step;
* **growth / eviction** — slots allocate pages on demand as decodes
  lengthen; under a full pool the youngest running request is preempted
  (pages freed, request requeued, KV recomputed on re-admission), so
  long-running decodes never deadlock the pool;
* **deferred verification** — the pool-level MAC (the model-MAC level
  of :mod:`repro.core.multilevel`) is checked off the critical path,
  every ``defer_interval`` ticks, amortizing it across the batch.

Host-side scheduling state (free list, queues, lengths) is plain
Python; everything that touches tensor data stays inside jit.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import multilevel
from repro.core import secure_memory as sm
from repro.core import vn as vn_mod
from repro.core.secure_exec import SCHEMES
from repro.models import lm as lm_mod
from repro.serve import kv_pages as kvp
from repro.serve.serve_step import greedy_sample

__all__ = ["IntegrityError", "Request", "SecureServingEngine"]


class IntegrityError(RuntimeError):
    """A MAC gate (page/block) or the deferred pool MAC failed."""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    state: str = "waiting"          # waiting | running | finished
    n_evictions: int = 0

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


@dataclasses.dataclass
class _Slot:
    req: Request
    length: int                     # KV tokens resident (host mirror)
    pages: list                     # owned pool page ids, in token order
    admit_seq: int


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


class SecureServingEngine:
    """Batched secure decoding with paged, MAC-protected KV residency.

    Typical use::

        eng = SecureServingEngine(arch, cfg, params, scheme="seda",
                                  max_slots=4, page_tokens=8,
                                  pages_per_slot=4, n_pages=12)
        rids = [eng.submit(prompt, max_new_tokens=8) for prompt in prompts]
        done = eng.run()            # {rid: Request}
    """

    def __init__(self, arch, cfg, params, *, scheme: str = "seda",
                 max_slots: int = 4, page_tokens: int = 8,
                 pages_per_slot: int = 8, n_pages: Optional[int] = None,
                 keys: Optional[sm.SecureKeys] = None,
                 use_kernel: bool = False, defer_interval: int = 16,
                 eos_id: Optional[int] = None,
                 verify_every_step: bool = True):
        if arch.kind != "lm":
            raise ValueError("the paged serving engine supports decoder-only "
                             "LMs (enc-dec serving stays on serve_step)")
        if scheme not in SCHEMES:
            raise KeyError(f"unknown scheme {scheme!r}")
        self.arch, self.cfg, self.params = arch, cfg, params
        self.scheme = scheme
        self.max_slots = max_slots
        self.page_tokens = page_tokens
        self.pages_per_slot = pages_per_slot
        self.max_len = page_tokens * pages_per_slot
        if n_pages is None:
            n_pages = max_slots * pages_per_slot
        self.n_pages = n_pages
        self.keys = keys if keys is not None else sm.SecureKeys.derive(0)
        self.defer_interval = defer_interval
        self.eos_id = eos_id
        self.verify_every_step = verify_every_step

        cache_tree = lm_mod.cache_specs(cfg, max_slots, self.max_len)
        flat, self.treedef = jax.tree_util.tree_flatten(cache_tree)
        paged = kvp.paged_flags(cache_tree)
        lengths = kvp.length_flags(cache_tree)
        self.paged_idx = [i for i, f in enumerate(paged) if f]
        self.len_leaves = [(i, flat[i].shape[0])
                           for i, f in enumerate(lengths) if f]
        self.onchip_idx = [i for i in range(len(flat))
                           if not paged[i] and not lengths[i]]
        self.n_leaves = len(flat)
        self.spec = kvp.build_page_spec(
            cache_tree, scheme=scheme, page_tokens=page_tokens,
            n_pages=n_pages, max_slots=max_slots, max_len=self.max_len,
            use_kernel=use_kernel)
        self.policy = (multilevel.SEDA_DEFAULT
                       if SCHEMES[scheme].verify == "layer"
                       else multilevel.SGX_LIKE if SCHEMES[scheme].emulate_tree
                       else multilevel.MGX_LIKE)

        # Device state.
        self.pool = kvp.init_pool(self.spec)
        self.onchip = [jnp.zeros(flat[i].shape, flat[i].dtype)
                       for i in self.onchip_idx]
        self._ok_accum = jnp.asarray(True)

        # Host scheduling state.
        self.waiting: deque = deque()
        self.slots: list = [None] * max_slots
        self.free_pages: list = list(range(n_pages))
        self.requests: dict = {}
        self._next_rid = 0
        self._admit_seq = 0
        self._epoch = 0
        self.tick = 0
        self.stats = {"admitted": 0, "preemptions": 0, "decode_steps": 0,
                      "deferred_checks": 0}

        self._decode_fn = jax.jit(self._build_decode_fn())
        self._prefill_fn = jax.jit(self._build_prefill_fn())
        self._writers: dict = {}

    # -- traced builders ----------------------------------------------------

    def _merge_cache_leaves(self, dense, onchip, lengths):
        leaves = [None] * self.n_leaves
        for j, idx in enumerate(self.paged_idx):
            leaves[idx] = dense[j]
        for idx, steps in self.len_leaves:
            leaves[idx] = jnp.broadcast_to(lengths[None, :],
                                           (steps, self.max_slots))
        for j, idx in enumerate(self.onchip_idx):
            leaves[idx] = onchip[j]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def _build_decode_fn(self):
        cfg, spec, keys = self.cfg, self.spec, self.keys

        def decode_fn(params, pool, onchip, page_table, lengths, active,
                      tokens, epoch):
            dense, ok = kvp.read_pages(pool, spec, keys, page_table, lengths)
            caches = self._merge_cache_leaves(dense, onchip, lengths)
            logits, new_caches = lm_mod.lm_decode(cfg, params, tokens, caches)
            tok = greedy_sample(logits)                    # (S, 1)
            new_leaves = jax.tree_util.tree_leaves(new_caches)
            vn = vn_mod.kv_page_vn(epoch)
            new_pool = kvp.write_dirty(
                pool, spec, keys, page_table,
                [new_leaves[i] for i in self.paged_idx], lengths, active, vn)
            new_onchip = []
            for j, idx in enumerate(self.onchip_idx):
                leaf = new_leaves[idx]
                keep = active.reshape((1, self.max_slots)
                                      + (1,) * (leaf.ndim - 2))
                new_onchip.append(jnp.where(keep, leaf, onchip[j]))
            return new_pool, new_onchip, tok, ok

        return decode_fn

    def _build_prefill_fn(self):
        cfg, max_len = self.cfg, self.max_len

        def prefill_fn(params, tokens):                    # tokens: (1, Lp)
            logits, caches = lm_mod.lm_prefill(cfg, params,
                                               {"tokens": tokens}, max_len)
            leaves = jax.tree_util.tree_leaves(caches)
            return (greedy_sample(logits),
                    [leaves[i] for i in self.paged_idx],
                    [leaves[i] for i in self.onchip_idx])

        return prefill_fn

    def _writer(self, n_write_pages: int):
        if n_write_pages not in self._writers:
            spec, keys = self.spec, self.keys

            def write(pool, page_ids, paged_leaves, epoch):
                vn = vn_mod.kv_page_vn(epoch)
                return kvp.write_prefill(pool, spec, keys, page_ids,
                                         paged_leaves, n_write_pages, vn)

            self._writers[n_write_pages] = jax.jit(write)
        return self._writers[n_write_pages]

    # -- public API ---------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int = 16) -> int:
        prompt = [int(t) for t in prompt]
        if not prompt or max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and max_new_tokens>=1")
        total = len(prompt) + max_new_tokens
        if total > self.max_len:
            raise ValueError(f"prompt+max_new_tokens={total} exceeds "
                             f"max_len={self.max_len}")
        worst_pages = _ceil_div(total, self.page_tokens)
        if worst_pages > min(self.pages_per_slot, self.n_pages):
            raise ValueError(f"request needs up to {worst_pages} pages; pool "
                             f"has {self.n_pages} (per-slot cap "
                             f"{self.pages_per_slot})")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, prompt, max_new_tokens)
        self.requests[rid] = req
        self.waiting.append(req)
        return rid

    def step(self) -> list:
        """One scheduler tick: admit, grow/evict, batched decode.

        Returns the requests that finished during this tick.
        """
        self.tick += 1
        finished: list = []
        self._admit(finished)
        self._ensure_growth()
        active_idx = [i for i, s in enumerate(self.slots) if s is not None]
        if active_idx:
            self._decode(active_idx, finished)
        if (self.policy.deferred_model_mac and self.defer_interval
                and self.tick % self.defer_interval == 0):
            self._deferred_check()
        return finished

    def run(self, max_ticks: int = 100_000) -> dict:
        """Drive ticks until every submitted request finished."""
        for _ in range(max_ticks):
            if not self.waiting and all(s is None for s in self.slots):
                break
            self.step()
        else:
            raise RuntimeError("run() exceeded max_ticks")
        if self.policy.deferred_model_mac:
            self._deferred_check()
        if not self.verify_every_step and not bool(self._ok_accum):
            raise IntegrityError("accumulated page-MAC verification failed")
        return {rid: r for rid, r in self.requests.items()
                if r.state == "finished"}

    def deferred_check(self) -> bool:
        """Model-level deferred MAC over the whole pool (paper Table I)."""
        return bool(kvp.deferred_pool_check(self.pool, self.spec))

    def decode_cost_analysis(self) -> dict:
        """XLA cost analysis of the jitted batched decode step.

        ``bytes accessed`` makes the protection traffic HLO-visible:
        the delta vs. the ``off`` scheme is the metadata + crypto
        traffic a scheme adds to one batched decode.
        """
        args = (
            self.params, self.pool, self.onchip,
            jnp.zeros((self.max_slots, self.pages_per_slot), jnp.int32),
            jnp.ones((self.max_slots,), jnp.int32),
            jnp.ones((self.max_slots,), bool),
            jnp.zeros((self.max_slots, 1), jnp.int32),
            jnp.uint32(1),
        )
        try:
            cost = self._decode_fn.lower(*args).compile().cost_analysis()
        except Exception:  # noqa: BLE001 - backend-dependent availability
            return {}
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        return dict(cost or {})

    @property
    def n_free_pages(self) -> int:
        return len(self.free_pages)

    # -- scheduler internals ------------------------------------------------

    def _next_epoch(self) -> jnp.ndarray:
        self._epoch += 1
        return jnp.uint32(self._epoch)

    def _admit(self, finished: list) -> None:
        while self.waiting and None in self.slots:
            req = self.waiting[0]
            seq = req.prompt + req.generated
            # +1 so the first decode's write position is always covered.
            n_alloc = min(len(seq) // self.page_tokens + 1,
                          self.pages_per_slot)
            if len(self.free_pages) < n_alloc:
                break
            self.waiting.popleft()
            slot_idx = self.slots.index(None)
            pages = [self.free_pages.pop() for _ in range(n_alloc)]
            tok, paged_leaves, onchip_leaves = self._prefill_fn(
                self.params, jnp.asarray([seq], jnp.int32))
            n_write = _ceil_div(len(seq), self.page_tokens)
            page_ids = np.full((self.pages_per_slot,),
                               self.spec.scratch_page, np.int32)
            page_ids[: len(pages)] = pages
            self.pool = self._writer(n_write)(
                self.pool, jnp.asarray(page_ids), paged_leaves,
                self._next_epoch())
            for j, idx in enumerate(self.onchip_idx):
                self.onchip[j] = self.onchip[j].at[:, slot_idx].set(
                    onchip_leaves[j][:, 0])
            self._admit_seq += 1
            self.stats["admitted"] += 1
            slot = _Slot(req, length=len(seq), pages=pages,
                         admit_seq=self._admit_seq)
            self.slots[slot_idx] = slot
            req.state = "running"
            req.generated.append(int(tok[0, 0]))
            self._maybe_finish(slot_idx, finished)

    def _ensure_growth(self) -> None:
        order = sorted((i for i, s in enumerate(self.slots) if s is not None),
                       key=lambda i: self.slots[i].admit_seq)
        for i in order:
            slot = self.slots[i]
            if slot is None:                      # evicted by an older slot
                continue
            need = slot.length // self.page_tokens
            while self.slots[i] is not None and len(slot.pages) <= need:
                if self.free_pages:
                    slot.pages.append(self.free_pages.pop())
                    continue
                self._preempt(self._pick_victim())

    def _pick_victim(self) -> int:
        """Globally youngest running slot (LIFO preemption, vLLM-style);
        may be the slot whose growth triggered the eviction."""
        candidates = [i for i, s in enumerate(self.slots) if s is not None]
        return max(candidates, key=lambda i: self.slots[i].admit_seq)

    def _preempt(self, idx: int) -> None:
        slot = self.slots[idx]
        self.free_pages.extend(slot.pages)
        self.slots[idx] = None
        slot.req.state = "waiting"
        slot.req.n_evictions += 1
        self.stats["preemptions"] += 1
        self.waiting.appendleft(slot.req)         # preempted go to the front

    def _release(self, idx: int) -> None:
        slot = self.slots[idx]
        self.free_pages.extend(slot.pages)
        self.slots[idx] = None
        slot.req.state = "finished"

    def _maybe_finish(self, idx: int, finished: list) -> None:
        slot = self.slots[idx]
        req = slot.req
        hit_eos = (self.eos_id is not None and req.generated
                   and req.generated[-1] == self.eos_id)
        if req.done or hit_eos:
            self._release(idx)
            finished.append(req)

    def _decode(self, active_idx: list, finished: list) -> None:
        page_table = np.full((self.max_slots, self.pages_per_slot), -1,
                             np.int32)
        lengths = np.zeros((self.max_slots,), np.int32)
        active = np.zeros((self.max_slots,), bool)
        tokens = np.zeros((self.max_slots, 1), np.int32)
        for i in active_idx:
            slot = self.slots[i]
            page_table[i, : len(slot.pages)] = slot.pages
            lengths[i] = slot.length
            active[i] = True
            tokens[i, 0] = slot.req.generated[-1]
        self.pool, self.onchip, toks, ok = self._decode_fn(
            self.params, self.pool, self.onchip, jnp.asarray(page_table),
            jnp.asarray(lengths), jnp.asarray(active), jnp.asarray(tokens),
            self._next_epoch())
        self.stats["decode_steps"] += 1
        if self.verify_every_step:
            if not bool(ok):
                raise IntegrityError(
                    f"page MAC verification failed at tick {self.tick} "
                    f"(scheme={self.scheme})")
        else:
            self._ok_accum = self._ok_accum & ok
        toks = np.asarray(toks)
        for i in active_idx:
            slot = self.slots[i]
            slot.length += 1
            slot.req.generated.append(int(toks[i, 0]))
            self._maybe_finish(i, finished)

    def _deferred_check(self) -> None:
        self.stats["deferred_checks"] += 1
        if not self.deferred_check():
            raise IntegrityError("deferred pool-level MAC check failed "
                                 f"(tick {self.tick}, scheme={self.scheme})")
