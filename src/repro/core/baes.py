"""Bandwidth-aware encryption (B-AES) — the paper's §III-B mechanism.

A *wide block* of ``block_bytes`` (e.g. 64B like Securator, or larger)
is encrypted with a SINGLE AES invocation:

  1. base OTP  = AES-CTR_{Ke}(PA || VN)                      (Alg. 1, l.5)
  2. OTP_i     = base OTP ^ key_i   for segment i             (Alg. 1, l.6-7)

where ``key_i`` are the round keys from KeyExpansion.  Each 128-bit
segment of the wide block therefore sees a *distinct* pad, defeating
the Single-Element Collision Attack (SECA) while spending 1/N of the
AES work of the traditional multi-engine path (T-AES).

When a wide block has more segments than available round keys, the
paper re-seeds KeyExpansion with ``key ^ (PA || VN)`` to mint more
diversifiers ("wide mode").  We implement that by deriving additional
key schedules from perturbed keys; schedules are generated inside the
traced computation so PA/VN may be traced values.

Security remark (faithful-reproduction note): XORing round keys into
pads means a hypothetical attacker who recovered two segment pads of
the same block would learn ``key_i ^ key_j``.  The paper asserts the
expanded keys are "inherently secure" and we reproduce that design
decision; the tests demonstrate the SECA defense the paper claims.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import aes, ctr

__all__ = [
    "n_diversifiers",
    "diversifiers",
    "baes_otps",
    "baes_encrypt",
    "baes_decrypt",
    "shared_otp_encrypt",
]

# Round keys 1..10 are used as diversifiers for segments 1..; segment 0
# keeps the base OTP.  key_0 (the raw cipher key) is never XORed into a
# pad so that a recovered pad can not be combined with the base OTP to
# reveal the key itself.
_DIVERSIFIERS_PER_SCHEDULE = 10


def n_diversifiers(n_segments: int) -> int:
    """Number of extra key schedules needed for ``n_segments`` segments."""
    extra = max(0, n_segments - 1 - _DIVERSIFIERS_PER_SCHEDULE)
    return (extra + _DIVERSIFIERS_PER_SCHEDULE - 1) // _DIVERSIFIERS_PER_SCHEDULE


def diversifiers(round_keys: jax.Array, n_segments: int,
                 counter_words: jax.Array | None = None,
                 key: jax.Array | None = None) -> jax.Array:
    """Per-segment XOR diversifiers, shape (n_segments, 16) uint8.

    Segment 0 gets the zero diversifier (base OTP used as-is); segments
    1..10 get round keys 1..10; beyond that, wide mode derives extra
    schedules from ``key ^ (PA || VN ^ j)``.
    """
    divs = [jnp.zeros((16,), jnp.uint8)]
    divs.extend(round_keys[1 + (i % _DIVERSIFIERS_PER_SCHEDULE)]
                for i in range(min(n_segments - 1, _DIVERSIFIERS_PER_SCHEDULE)))
    if n_segments - 1 > _DIVERSIFIERS_PER_SCHEDULE:
        if key is None or counter_words is None:
            raise ValueError("wide-mode B-AES needs the raw key and counter words")
        ctr_bytes = ctr.counter_blocks(counter_words.reshape(4))
        remaining = n_segments - 1 - _DIVERSIFIERS_PER_SCHEDULE
        for j in range(n_diversifiers(n_segments)):
            seed = key ^ ctr_bytes ^ jnp.uint8(j + 1)
            extra = aes.key_expansion(seed)
            take = min(remaining, _DIVERSIFIERS_PER_SCHEDULE)
            divs.extend(extra[1 + r] for r in range(take))
            remaining -= take
    return jnp.stack(divs[:n_segments])


@functools.partial(jax.jit, static_argnames=("n_segments",))
def baes_otps(round_keys: jax.Array, counter_words: jax.Array, *,
              n_segments: int, key: jax.Array | None = None) -> jax.Array:
    """OTPs for every segment of every wide block.

    Args:
      round_keys: (11, 16) uint8 key schedule.
      counter_words: (n_blocks, 4) uint32 — PA||VN per wide block.
      n_segments: 16B segments per wide block (block_bytes // 16).
      key: raw 16B key, only needed for wide mode (n_segments > 11).

    Returns: (n_blocks, n_segments, 16) uint8 pads.
    """
    base = ctr.ctr_keystream(round_keys, counter_words)  # (n_blocks, 16)
    if n_segments - 1 > _DIVERSIFIERS_PER_SCHEDULE:
        # Wide mode: diversifiers depend on each block's counter.
        def per_block(counter, base_otp):
            div = diversifiers(round_keys, n_segments, counter, key)
            return base_otp[None, :] ^ div

        return jax.vmap(per_block)(counter_words, base)
    div = diversifiers(round_keys, n_segments)  # (n_segments, 16)
    return base[:, None, :] ^ div[None, :, :]


def baes_encrypt(plaintext: jax.Array, round_keys: jax.Array,
                 counter_words: jax.Array, *, block_bytes: int,
                 key: jax.Array | None = None) -> jax.Array:
    """Encrypt a flat uint8 buffer (len % block_bytes == 0) with B-AES.

    ``counter_words`` holds one (PA||VN) per wide block: (n_blocks, 4).
    """
    n_segments = block_bytes // 16
    blocks = plaintext.reshape(-1, n_segments, 16)
    otps = baes_otps(round_keys, counter_words, n_segments=n_segments, key=key)
    return (blocks ^ otps).reshape(plaintext.shape)


# XOR stream cipher: decryption == encryption.
baes_decrypt = baes_encrypt


def shared_otp_encrypt(plaintext: jax.Array, round_keys: jax.Array,
                       counter_words: jax.Array, *, block_bytes: int) -> jax.Array:
    """The INSECURE strawman (paper §III-B challenge 2): every 16B segment
    of a wide block reuses the same OTP.  Exists so tests/examples can
    demonstrate the SECA attack succeeding against it.
    """
    n_segments = block_bytes // 16
    blocks = plaintext.reshape(-1, n_segments, 16)
    base = ctr.ctr_keystream(round_keys, counter_words)  # (n_blocks, 16)
    return (blocks ^ base[:, None, :]).reshape(plaintext.shape)
