"""Pure-JAX AES-128 (FIPS-197).

This is the reference "AES engine" of SeDA (paper Fig. 2(b)): SubBytes,
ShiftRows, MixColumns, AddRoundKey, plus the KeyExpansion module whose
round keys the bandwidth-aware encryption mechanism (B-AES, paper
Alg. 1 defense) reuses as XOR diversifiers.

State layout: a block is a ``(16,)`` uint8 vector in FIPS column-major
order (byte ``i`` is row ``i % 4``, column ``i // 4``).  All functions
are batched over a leading axis and jit-compatible; the S-box is a
constant 256-entry table applied with ``jnp.take``.

Validated against FIPS-197 Appendix B/C and NIST SP 800-38A vectors in
``tests/test_aes.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SBOX",
    "INV_SBOX",
    "RCON",
    "key_expansion",
    "key_expansion_np",
    "aes128_encrypt_block",
    "aes128_encrypt",
    "sub_bytes",
    "shift_rows",
    "mix_columns",
    "add_round_key",
]

# ---------------------------------------------------------------------------
# Constant tables (computed once with numpy at import time).
# ---------------------------------------------------------------------------


def _build_sbox() -> np.ndarray:
    """Build the AES S-box from GF(2^8) inversion + affine transform."""
    # Multiplicative inverse table via exp/log tables over generator 3.
    exp = np.zeros(512, dtype=np.uint8)
    log = np.zeros(256, dtype=np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        # multiply x by generator 0x03 = x ^ (x<<1) with reduction
        x ^= (x << 1) ^ (0x1B if x & 0x80 else 0)
        x &= 0xFF
    for i in range(255, 512):
        exp[i] = exp[i - 255]

    sbox = np.zeros(256, dtype=np.uint8)
    for v in range(256):
        inv = 0 if v == 0 else int(exp[255 - log[v]])
        # Affine transform: b ^ rot(b,1..4) ^ 0x63.
        b = inv
        res = 0x63
        for shift in range(5):
            res ^= ((b << shift) | (b >> (8 - shift))) & 0xFF
        sbox[v] = res
    return sbox


_SBOX_NP = _build_sbox()
_INV_SBOX_NP = np.zeros(256, dtype=np.uint8)
_INV_SBOX_NP[_SBOX_NP] = np.arange(256, dtype=np.uint8)

# Round constants for key expansion (first byte of rcon word).
_RCON_NP = np.array([0x01, 0x02, 0x04, 0x08, 0x10, 0x20, 0x40, 0x80, 0x1B, 0x36],
                    dtype=np.uint8)

SBOX = jnp.asarray(_SBOX_NP)
INV_SBOX = jnp.asarray(_INV_SBOX_NP)
RCON = jnp.asarray(_RCON_NP)

# ShiftRows permutation on the 16-byte column-major state:
# new[r + 4c] = old[r + 4((c + r) % 4)].
_SHIFT_ROWS_PERM_NP = np.array(
    [(r + 4 * ((c + r) % 4)) for c in range(4) for r in range(4)], dtype=np.int32
)
_SHIFT_ROWS_PERM = jnp.asarray(_SHIFT_ROWS_PERM_NP)


# ---------------------------------------------------------------------------
# GF(2^8) helpers (uint8 arrays, promoted internally to avoid overflow UB).
# ---------------------------------------------------------------------------


def _xtime(x: jax.Array) -> jax.Array:
    """Multiply by 2 in GF(2^8) with the AES reduction polynomial."""
    x16 = x.astype(jnp.uint16)
    doubled = (x16 << 1) ^ jnp.where(x16 & 0x80, jnp.uint16(0x1B), jnp.uint16(0))
    return (doubled & 0xFF).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Round transforms.  All operate on (..., 16) uint8 states.
# ---------------------------------------------------------------------------


def sub_bytes(state: jax.Array) -> jax.Array:
    return jnp.take(SBOX, state.astype(jnp.int32), axis=0)


def shift_rows(state: jax.Array) -> jax.Array:
    return jnp.take(state, _SHIFT_ROWS_PERM, axis=-1)


def mix_columns(state: jax.Array) -> jax.Array:
    s = state.reshape(state.shape[:-1] + (4, 4))  # (..., col, row)
    a0, a1, a2, a3 = s[..., 0], s[..., 1], s[..., 2], s[..., 3]
    x0, x1, x2, x3 = _xtime(a0), _xtime(a1), _xtime(a2), _xtime(a3)
    b0 = x0 ^ (x1 ^ a1) ^ a2 ^ a3
    b1 = a0 ^ x1 ^ (x2 ^ a2) ^ a3
    b2 = a0 ^ a1 ^ x2 ^ (x3 ^ a3)
    b3 = (x0 ^ a0) ^ a1 ^ a2 ^ x3
    out = jnp.stack([b0, b1, b2, b3], axis=-1)
    return out.reshape(state.shape)


def add_round_key(state: jax.Array, round_key: jax.Array) -> jax.Array:
    return state ^ round_key


# ---------------------------------------------------------------------------
# Key expansion.
# ---------------------------------------------------------------------------


def key_expansion_np(key: np.ndarray) -> np.ndarray:
    """FIPS-197 key expansion in numpy: (16,) uint8 -> (11, 16) uint8.

    Returned round keys are in the same flat byte order as the input key
    (word-major: bytes 4i..4i+3 are word i).
    """
    key = np.asarray(key, dtype=np.uint8).reshape(16)
    words = [key[4 * i: 4 * i + 4].copy() for i in range(4)]
    for i in range(4, 44):
        temp = words[i - 1].copy()
        if i % 4 == 0:
            temp = np.roll(temp, -1)  # RotWord
            temp = _SBOX_NP[temp]     # SubWord
            temp[0] ^= _RCON_NP[i // 4 - 1]
        words.append(words[i - 4] ^ temp)
    return np.stack([np.concatenate(words[4 * r: 4 * r + 4]) for r in range(11)])


def key_expansion(key: jax.Array) -> jax.Array:
    """Traceable key expansion: (16,) uint8 -> (11, 16) uint8.

    Used when the key is a traced value (e.g. re-seeded per block with
    ``key ^ (PA || VN)`` for B-AES wide-diversification mode).
    """
    key = key.reshape(16).astype(jnp.uint8)
    words = [key[4 * i: 4 * i + 4] for i in range(4)]
    for i in range(4, 44):
        temp = words[i - 1]
        if i % 4 == 0:
            temp = jnp.roll(temp, -1)
            temp = jnp.take(SBOX, temp.astype(jnp.int32), axis=0)
            temp = temp.at[0].set(temp[0] ^ RCON[i // 4 - 1])
        words.append(words[i - 4] ^ temp)
    return jnp.stack([jnp.concatenate(words[4 * r: 4 * r + 4]) for r in range(11)])


# ---------------------------------------------------------------------------
# Block encryption.
# ---------------------------------------------------------------------------


def aes128_encrypt_block(block: jax.Array, round_keys: jax.Array) -> jax.Array:
    """Encrypt ``(..., 16)`` uint8 blocks with ``(11, 16)`` round keys."""
    state = add_round_key(block, round_keys[0])

    def round_fn(i, state):
        state = sub_bytes(state)
        state = shift_rows(state)
        state = mix_columns(state)
        return add_round_key(state, round_keys[i])

    state = jax.lax.fori_loop(1, 10, round_fn, state)
    state = sub_bytes(state)
    state = shift_rows(state)
    return add_round_key(state, round_keys[10])


@functools.partial(jax.jit, static_argnames=())
def aes128_encrypt(blocks: jax.Array, round_keys: jax.Array) -> jax.Array:
    """Jitted batched AES-128 encryption of ``(n, 16)`` uint8 blocks."""
    return aes128_encrypt_block(blocks, round_keys)
