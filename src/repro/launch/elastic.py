"""Elastic re-scaling: restore a secure checkpoint onto a different mesh.

Checkpoints store arrays unsharded (gathered at save), so scaling a job
from N to M hosts is: verify + decrypt the checkpoint, build the new
mesh's planner shardings, and ``jax.device_put`` each leaf.  The data
pipeline replays deterministically from the step recorded in the
manifest, so the token stream is unchanged across the re-shard.

    reshard_params(params_or_path, arch_name, new_mesh) -> sharded pytree
"""

from __future__ import annotations

from typing import Any

import jax

from repro.configs import get_arch
from repro.launch import sharding as shp
from repro.models import encdec as ed
from repro.models import lm as lm_mod

__all__ = ["plan_for_mesh", "reshard_params"]


def plan_for_mesh(arch_name: str, mesh, *, smoke: bool = False):
    """(specs, shardings) for an arch on a target mesh."""
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_config() if smoke else arch.make_config()
    specs = (ed.encdec_specs(cfg) if arch.kind == "encdec"
             else lm_mod.lm_specs(cfg))
    return specs, shp.param_shardings(specs, arch.sharding_rules(), mesh)


def reshard_params(params: Any, arch_name: str, new_mesh, *,
                   smoke: bool = False) -> Any:
    """Place (restored, unsharded) params onto a new mesh's layout."""
    _, shardings = plan_for_mesh(arch_name, new_mesh, smoke=smoke)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), params, shardings)
