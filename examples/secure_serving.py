"""Secure serving: batched greedy decoding with SeDA-protected weights.

    PYTHONPATH=src python examples/secure_serving.py

The model's weights are verified (layer MACs) before serving starts —
the MGX/SeDA "weights are read-only at inference" fast path: VNs are
constant, so the protected image is generated once and every restart
re-verifies it.  Decodes a batch of requests with the KV cache, then
demonstrates the model-MAC deferred check (paper Table I: verification
available at end of inference).
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import secure_memory as sm
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.serve.serve_step import greedy_sample, make_decode_step, make_prefill_step


def main() -> None:
    arch = get_arch("minitron-4b")
    cfg = arch.make_smoke_config()
    print(f"=== secure serving: {cfg.name} ===")
    keys = sm.SecureKeys.derive(42)
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))

    # Provision: protect the weights once (model "shipped" encrypted).
    region = sm.make_region_spec(params, block_bytes=512)
    protected = sm.protect(params, keys, region, step=0)
    print("weights protected:",
          f"{sum(ct.shape[0] for ct in protected.ciphertexts)} ciphertext "
          f"bytes, {region.n_layers} layer MACs on-chip, 1 model MAC")

    # Serve start: decrypt + LAYER-gate verification.
    t0 = time.perf_counter()
    served_params, ok = sm.unprotect(protected, keys, region, verify="layer")
    print(f"weights decrypted+verified in {time.perf_counter() - t0:.2f}s "
          f"(integrity={'OK' if bool(ok) else 'FAIL'})")
    assert bool(ok)

    # Batched requests.
    batch, prompt_len, gen_len, max_len = 4, 12, 8, 32
    rng = np.random.default_rng(1)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab, (batch, prompt_len),
                                       dtype=np.int64).astype(np.int32))
    prefill = jax.jit(make_prefill_step(arch, cfg, max_len))
    decode = jax.jit(make_decode_step(arch, cfg))

    logits, caches = prefill(served_params, {"tokens": prompts})
    tok = greedy_sample(logits)
    generated = [tok]
    t0 = time.perf_counter()
    for _ in range(gen_len - 1):
        logits, caches = decode(served_params, tok, caches)
        tok = greedy_sample(logits)
        generated.append(tok)
    dt = time.perf_counter() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decoded {gen_len} tokens x {batch} requests in {dt:.2f}s "
          f"({batch * gen_len / dt:.1f} tok/s on CPU)")
    for i in range(batch):
        print(f"  request {i}: prompt={np.asarray(prompts[i])[:6]}... "
              f"-> generated={np.asarray(out[i])}")

    # Deferred model-MAC check at end of inference (Table I).
    _, model_ok = sm.unprotect(protected, keys, region, verify="model")
    print(f"deferred model-MAC check at end of inference: "
          f"{'OK' if bool(model_ok) else 'FAIL'}")
    assert bool(model_ok)

    # --- Continuous batching: the paged, MAC-protected KV pool -----------
    # Multi-user serving where the KV cache itself crosses the boundary:
    # pages carry their own MAC+VN, decode steps verify only touched
    # pages, and an undersized pool forces eviction (preempted requests
    # are recomputed on re-admission — greedy tokens are unchanged).
    from repro.serve.engine import SecureServingEngine

    print("\n--- paged secure serving engine (continuous batching) ---")
    eng = SecureServingEngine(arch, cfg, served_params, scheme="seda",
                              max_slots=3, page_tokens=4, pages_per_slot=6,
                              n_pages=10, keys=keys)
    rng = np.random.default_rng(7)
    rids = [eng.submit(prompt=list(map(int, rng.integers(1, cfg.vocab, n))),
                       max_new_tokens=8) for n in (6, 9, 12)]
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    for rid in rids:
        print(f"  request {rid}: generated={done[rid].generated} "
              f"(evicted {done[rid].n_evictions}x)")
    n_toks = sum(len(done[r].generated) for r in rids)
    print(f"engine: {n_toks} tokens in {dt:.2f}s, "
          f"{eng.stats['preemptions']} preemptions, "
          f"{eng.stats['deferred_checks']} deferred pool-MAC checks, "
          f"deferred check {'OK' if eng.deferred_check() else 'FAIL'}")
    assert eng.deferred_check()
    print("=== secure_serving OK ===")


if __name__ == "__main__":
    main()
