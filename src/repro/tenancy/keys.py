"""Hierarchical tenant key derivation (per-tenant cryptographic domains).

GuardNN argues for fresh, narrowly-scoped keys per inference to shrink
the blast radius of key compromise; SEAL binds ciphertext to its
owner's identity.  This module gives the serving stack both: every
tenant gets its own subtree of the key hierarchy, and every *epoch*
within a tenant gets fresh data-plane keys, so leaking one tenant's
epoch key exposes exactly one tenant-epoch of KV state and nothing
else.

::

    root (16B, fused/HSM stand-in)
     └─ tenant master   M_t = PRF(root, "tenant" ‖ tenant_id)
         ├─ encrypt     E_t = PRF(M_t, "purpose:enc")
         ├─ MAC         H_t = PRF(M_t, "purpose:mac")
         └─ VN          V_t = PRF(M_t, "purpose:vn")
             per epoch e (bumped by ``rotate()``):
               cipher key    E_{t,e}  = PRF(E_t, "epoch" ‖ u64(e))
               NH hash key   lanes    = AES-CTR_{PRF(H_t, "epoch" ‖ u64(e))}
               counter salt  s_{t,e}  = PRF(V_t, "epoch" ‖ u64(e))[:4]
             plus one epoch-independent prefix-cache branch (label
             "cache:prefix") sealing shared-prefix KV pages that must
             stay verifiable across rotations (see
             :class:`repro.serve.kv_pages.PrefixCache`).

PRF is AES-128-CBC-MAC over 0x80-padded message blocks, built on the
same :mod:`repro.core.aes` engine the data plane uses (the hierarchy
costs nothing the accelerator doesn't already have).  The derived
``SecureKeys`` plug straight into the existing kv-page crypto: the
cipher key's schedule doubles as the MAC finalizer PRF key (as in the
paper's fused AES engines), the NH lanes are the MAC key material, and
the VN-derived salt diversifies the CTR counter stream per
tenant-epoch.

Derivation runs eagerly at registration/rotation time (a handful of
16B AES calls + one batched call for the NH lanes) — never on the
decode critical path.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import aes
from repro.core import secure_memory as sm

__all__ = ["KeyHierarchy", "TenantKeySet", "prf"]


def _aes_blocks_np(blocks: np.ndarray, round_keys: np.ndarray) -> np.ndarray:
    """Eager AES-128 of (n, 16) u8 blocks via the core engine."""
    out = aes.aes128_encrypt(jnp.asarray(blocks, jnp.uint8),
                             jnp.asarray(round_keys, jnp.uint8))
    return np.asarray(out, np.uint8)


def _pad_message(msg: bytes) -> np.ndarray:
    """ISO/IEC 9797-1 method-2 padding: 0x80 then zeros to 16B blocks."""
    buf = msg + b"\x80"
    buf += b"\x00" * (-len(buf) % 16)
    return np.frombuffer(buf, np.uint8).reshape(-1, 16)


def prf(key: np.ndarray, msg: bytes) -> np.ndarray:
    """AES-128-CBC-MAC PRF: (16,) u8 key x message bytes -> (16,) u8."""
    round_keys = aes.key_expansion_np(np.asarray(key, np.uint8).reshape(16))
    state = np.zeros(16, np.uint8)
    for block in _pad_message(msg):
        state = _aes_blocks_np((state ^ block)[None], round_keys)[0]
    return state


def _expand_lanes(seed_key: np.ndarray, n_lanes: int) -> np.ndarray:
    """AES-CTR keystream under ``seed_key`` -> (n_lanes,) u32 NH lanes."""
    round_keys = aes.key_expansion_np(seed_key)
    n_blocks = -(-n_lanes * 4 // 16)
    counters = np.zeros((n_blocks, 16), np.uint8)
    idx = np.arange(n_blocks, dtype=np.uint32)
    for shift, col in zip((24, 16, 8, 0), range(12, 16)):
        counters[:, col] = (idx >> shift) & 0xFF
    stream = _aes_blocks_np(counters, round_keys).reshape(-1)
    return stream[: n_lanes * 4].view(np.uint32).copy()


@dataclasses.dataclass
class TenantKeySet:
    """One tenant's subtree of the hierarchy, with live epoch state.

    Epoch key material is held per epoch in ``_epochs``; retention is
    enforced by :meth:`drop_before` (called by the registry when an
    epoch leaves the retained window) so compromised hosts cannot be
    made to decrypt arbitrarily old ciphertext.
    """

    tenant_id: str
    master: np.ndarray
    enc_key: np.ndarray
    mac_key: np.ndarray
    vn_key: np.ndarray
    nh_lanes: int
    current_epoch: int = 0
    _epochs: dict = dataclasses.field(default_factory=dict)
    _cache: tuple = None

    def epoch_keys(self, epoch: int) -> sm.SecureKeys:
        """Data-plane ``SecureKeys`` for one (tenant, epoch)."""
        return self._materialize(epoch)[0]

    def epoch_salt(self, epoch: int) -> int:
        """u32 CTR-counter salt derived from the VN purpose key."""
        return self._materialize(epoch)[1]

    def _materialize(self, epoch: int):
        if epoch < 0:
            raise KeyError(f"tenant {self.tenant_id!r}: negative epoch")
        if epoch not in self._epochs:
            if epoch < self.current_epoch:
                raise KeyError(
                    f"tenant {self.tenant_id!r}: epoch {epoch} key material "
                    f"was dropped (current epoch {self.current_epoch})")
            label = b"epoch" + int(epoch).to_bytes(8, "little")
            cipher = prf(self.enc_key, label)
            lanes = _expand_lanes(prf(self.mac_key, label), self.nh_lanes)
            salt = int(prf(self.vn_key, label)[:4].view(np.uint32)[0])
            keys = sm.SecureKeys(
                key=jnp.asarray(cipher),
                round_keys=jnp.asarray(aes.key_expansion_np(cipher)),
                hash_key=jnp.asarray(lanes))
            self._epochs[epoch] = (keys, salt)
        return self._epochs[epoch]

    def cache_keys(self) -> sm.SecureKeys:
        """Data-plane keys for this tenant's prefix-cache binding.

        Derived from the purpose keys under a dedicated ``cache``
        label instead of an epoch label, so the binding is *epoch
        independent*: pages sealed into the shared-prefix cache stay
        verifiable across ``rotate()`` (VN-stable shared reads never
        re-MAC).  Revocation of cached state is therefore an explicit
        cache flush, not a key rotation.
        """
        return self._materialize_cache()[0]

    def cache_salt(self) -> int:
        """u32 CTR-counter salt for the prefix-cache binding."""
        return self._materialize_cache()[1]

    def _materialize_cache(self):
        if self._cache is None:
            label = b"cache:prefix"
            cipher = prf(self.enc_key, label)
            lanes = _expand_lanes(prf(self.mac_key, label), self.nh_lanes)
            salt = int(prf(self.vn_key, label)[:4].view(np.uint32)[0])
            keys = sm.SecureKeys(
                key=jnp.asarray(cipher),
                round_keys=jnp.asarray(aes.key_expansion_np(cipher)),
                hash_key=jnp.asarray(lanes))
            self._cache = (keys, salt)
        return self._cache

    def rotate(self) -> int:
        """Bump the epoch; the new keys derive lazily on first use."""
        self.current_epoch += 1
        self._materialize(self.current_epoch)
        return self.current_epoch

    def drop_before(self, epoch: int) -> None:
        """Destroy key material for epochs < ``epoch`` (retention edge)."""
        for e in [e for e in self._epochs if e < epoch]:
            del self._epochs[e]


class KeyHierarchy:
    """Root of the KDF tree: derives per-tenant key subtrees.

    ``root`` may be an int seed (tests/demos) or 16 raw bytes (a real
    deployment would source these from the fused key / HSM the paper's
    threat model assumes on-chip).
    """

    def __init__(self, root, *, nh_lanes: int = 2048):
        if isinstance(root, (int, np.integer)):
            rng = np.random.default_rng(np.uint32(root))
            root = rng.integers(0, 256, size=16, dtype=np.uint8)
        root = np.asarray(
            np.frombuffer(root, np.uint8) if isinstance(root, bytes) else root,
            np.uint8).reshape(16)
        self._root = root
        self.nh_lanes = nh_lanes

    def derive_tenant(self, tenant_id: str) -> TenantKeySet:
        master = prf(self._root, b"tenant" + tenant_id.encode())
        return TenantKeySet(
            tenant_id=tenant_id,
            master=master,
            enc_key=prf(master, b"purpose:enc"),
            mac_key=prf(master, b"purpose:mac"),
            vn_key=prf(master, b"purpose:vn"),
            nh_lanes=self.nh_lanes)
