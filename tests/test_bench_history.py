"""Bench history rows and the regression gate.

Pure-host tests over ``benchmarks/history.py`` and
``benchmarks/check_regression.py``: normalization is stable and
whitelisted, dirty/foreign-host rows never become baselines, a
synthetic regressed row exits non-zero, a clean run exits zero.
"""

import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                       / "benchmarks"))

import check_regression  # noqa: E402
import history  # noqa: E402
from _meta import run_meta, stamp  # noqa: E402

META = {"git_sha": "abc123", "git_dirty": False, "host": "Linux-x86_64",
        "timestamp_utc": "2026-01-01T00:00:00+00:00"}


def _payload(tok_per_s=100.0, overhead=0.2, dirty=False,
             host="Linux-x86_64"):
    meta = dict(META, git_dirty=dirty, host=host)
    return {"benchmark": "secure_serving",
            "results": [{"scheme": "seda", "batch": 8,
                         "tok_per_s": tok_per_s,
                         "traffic_overhead": overhead,
                         "latency": {"p50": 1.0}}],     # not whitelisted
            "meta": meta}


class TestNormalize:
    def test_row_shape_and_whitelists(self):
        rows = history.normalize(_payload())
        assert len(rows) == 1
        row = rows[0]
        assert row["benchmark"] == "secure_serving"
        assert row["scheme"] == "seda"
        assert row["config"] == "batch=8"
        assert row["metrics"] == {"tok_per_s": 100.0,
                                  "traffic_overhead": 0.2}
        assert row["git_dirty"] is False
        assert row["host"] == "Linux-x86_64"

    def test_scheme_extracted_from_name(self):
        payload = {"benchmark": "secure_step",
                   "results": [{"name": "decode_seda512_kernel",
                                "us_per_call": 42.0}],
                   "meta": META}
        row = history.normalize(payload)[0]
        assert row["scheme"] == "seda512"
        assert row["config"] == "name=decode_seda512_kernel"

    def test_missing_meta_defaults_dirty(self):
        payload = {"benchmark": "b", "results": [{"scheme": "off",
                                                  "tok_per_s": 1.0}]}
        row = history.normalize(payload)[0]
        assert row["git_dirty"] is True        # unprovenanced = untrusted

    def test_resultless_metrics_skipped(self):
        payload = {"benchmark": "b", "results": [{"scheme": "off",
                                                  "note": "no metrics"}],
                   "meta": META}
        assert history.normalize(payload) == []


class TestHistoryFile:
    def test_append_load_roundtrip_and_bad_lines(self, tmp_path):
        path = tmp_path / "hist.jsonl"
        n = history.append_history(str(path), [_payload(),
                                               _payload(110.0)])
        assert n == 2
        path.write_text(path.read_text() + "{corrupt\n\n")
        rows = history.load_history(str(path))
        assert len(rows) == 2
        assert rows[1]["metrics"]["tok_per_s"] == 110.0

    def test_missing_file_is_empty(self, tmp_path):
        assert history.load_history(str(tmp_path / "nope.jsonl")) == []


class TestStamp:
    def test_meta_has_dirty_bool_and_host(self):
        meta = stamp({"benchmark": "x", "results": []})["meta"]
        assert isinstance(meta["git_dirty"], bool)
        assert meta["host"] and "-" in meta["host"]
        assert meta is not run_meta()          # fresh dict per call


class TestGate:
    def _history(self, *payloads):
        rows = []
        for p in payloads:
            rows.extend(history.normalize(p))
        return rows

    def test_first_run_warns_only(self):
        current = history.normalize(_payload())
        failures, warnings, table = check_regression.check(current, [])
        assert not failures
        assert len(warnings) == 2              # one per metric
        assert any("WARN" in line for line in table)

    def test_throughput_regression_fails(self):
        base = self._history(_payload(tok_per_s=100.0))
        current = history.normalize(_payload(tok_per_s=40.0))  # -60%
        failures, _, _ = check_regression.check(current, base)
        assert any("tok_per_s" in f for f in failures)

    def test_within_band_passes(self):
        base = self._history(_payload(tok_per_s=100.0))
        current = history.normalize(_payload(tok_per_s=60.0))  # -40%
        failures, _, _ = check_regression.check(current, base)
        assert not any("tok_per_s" in f for f in failures)

    def test_ratio_regression_tight_band(self):
        base = self._history(_payload(overhead=0.10))
        worse = history.normalize(_payload(overhead=0.30))
        failures, _, _ = check_regression.check(worse, base)
        assert any("traffic_overhead" in f for f in failures)
        # Inside rel+abs slack: 0.10 -> 0.14 is fine.
        ok = history.normalize(_payload(overhead=0.14))
        failures, _, _ = check_regression.check(ok, base)
        assert not failures

    def test_dirty_baseline_excluded(self):
        base = self._history(_payload(tok_per_s=1000.0, dirty=True))
        current = history.normalize(_payload(tok_per_s=40.0))
        failures, warnings, _ = check_regression.check(current, base)
        assert not failures                    # dirty row never a baseline
        assert warnings

    def test_foreign_host_throughput_excluded(self):
        base = self._history(_payload(tok_per_s=1000.0,
                                      host="Darwin-arm64"))
        current = history.normalize(_payload(tok_per_s=40.0))
        failures, _, _ = check_regression.check(current, base)
        assert not any("tok_per_s" in f for f in failures)
        # Ratio metrics stay host-independent.
        base = self._history(_payload(overhead=0.10, host="Darwin-arm64"))
        worse = history.normalize(_payload(overhead=0.40))
        failures, _, _ = check_regression.check(worse, base)
        assert any("traffic_overhead" in f for f in failures)

    def test_cli_exit_codes(self, tmp_path, capsys):
        hist = tmp_path / "hist.jsonl"
        history.append_history(str(hist), [_payload(tok_per_s=100.0)])
        good = tmp_path / "good.json"
        good.write_text(json.dumps(_payload(tok_per_s=95.0)))
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps(_payload(tok_per_s=10.0)))

        assert check_regression.main(
            ["--history", str(hist), str(good)]) == 0
        assert check_regression.main(
            ["--history", str(hist), str(bad)]) == 1
        out = capsys.readouterr().out
        assert "FAIL" in out and "baseline" in out

    def test_improvement_never_fails(self):
        base = self._history(_payload(tok_per_s=100.0, overhead=0.2))
        current = history.normalize(_payload(tok_per_s=500.0,
                                             overhead=0.01))
        failures, _, _ = check_regression.check(current, base)
        assert not failures
