"""Cluster scheduler: sharded secure serving across many devices.

A :class:`ClusterEngine` serves one logical request stream over N
shards, each shard a full
:class:`~repro.serve.engine.SecureServingEngine` (continuous batching,
paged MAC-protected pool, optional per-tenant key domains) pinned to
its own accelerator:

* **routing** — :meth:`submit` places each request on the least-loaded
  shard, with tenant affinity: among near-tied shards, one already
  holding the tenant's pages wins (its key rows are hot and its quota
  accounting is local);
* **one multi-device dispatch per tick** — every shard's jitted decode
  is *dispatched* before any shard is *collected* (the engine tick is
  split into begin/dispatch/collect/end phases), so the per-tick
  device work of all shards overlaps instead of serializing;
* **shard-bound integrity** — every shard's pool carries the shard id
  in its RePA bindings and CTR counters (:mod:`repro.serve.kv_pages`),
  and the per-shard deferred pool MACs roll up into a cluster root MAC
  (:mod:`repro.serve.sharded_pool`) checked off the critical path;
* **secure page migration** — when a shard starves (queued work it
  cannot admit, or imminent page-growth pressure) while another has
  room, the starved shard's youngest running slot MOVES: its pages are
  decrypted + verified under the source shard's binding, hop devices
  as plaintext inside the trusted computation, and are re-encrypted +
  re-MACed under the destination's binding — no eviction, no prefill
  recompute, and the source-shard ciphertext is useless at the
  destination;
* **cluster-wide rotation** — :meth:`rotate` runs through the shared
  registry, whose pre/post hooks fan out to every shard engine: pages
  about to leave the retained key window are eagerly resealed on
  whichever shard holds them;
* **shard failover** — with ``fault_tolerance`` on, a shard whose tick
  or root-MAC contribution raises is folded out of the cluster: its
  sessions drain onto surviving shards by secure recompute (a
  compromised shard's pages are never migrated or trusted), its pool
  MAC leaves the root compression, and ``shard_failovers`` counts the
  event.  Page-level faults stay contained inside the shard engine
  (slot quarantine + recovery) and never escalate to failover.

Works on one host: ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
gives N CPU devices; with a single device the shards stay logical
(separate pools, same device) and everything — including cross-shard
replay rejection — behaves identically.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import secure_memory as sm
from repro.obs import audit as audit_mod
from repro.obs import metrics as metrics_mod
from repro.obs import trace as trace_mod
from repro.serve import kv_pages as kvp
from repro.serve.engine import (IntegrityError, RunResult,
                                SecureServingEngine, SubmitAPI,
                                SubmitRequest, latency_percentiles)
from repro.serve.sharded_pool import ShardedKVPool

__all__ = ["ClusterEngine"]


class ClusterEngine(SubmitAPI):
    """N shard engines behind one ``submit()``/``run()`` plane.

    Single-tenant use::

        cluster = ClusterEngine(arch, cfg, params, shards=2,
                                scheme="seda", max_slots=2,
                                page_tokens=8, pages_per_slot=4)
        rids = [cluster.submit(prompt=p, max_new_tokens=8) for p in prompts]
        done = cluster.run()        # RunResult, same shape as Engine's

    Multi-tenant: pass ``registry=`` exactly as for the single engine;
    sessions are cluster-wide (the registry is shared by every shard).
    ``max_slots`` / ``n_pages`` are PER SHARD — a cluster of 4 shards
    with ``max_slots=4`` decodes up to 16 slots per tick.
    """

    def __init__(self, arch, cfg, params, *, shards: int = 2,
                 scheme: str = "seda", max_slots: int = 4,
                 page_tokens: int = 8, pages_per_slot: int = 8,
                 n_pages: Optional[int] = None,
                 keys: Optional[sm.SecureKeys] = None,
                 registry=None, rotate_every: int = 0,
                 defer_interval: int = 16, devices=None,
                 migrate: bool = True, fault_tolerance=None,
                 trace=None, audit=None,
                 **engine_kw):
        if shards < 1:
            raise ValueError("need at least one shard")
        if rotate_every and registry is None:
            raise ValueError("rotate_every needs a tenant registry")
        if devices is None:
            local = jax.local_devices()
            # One physical device: keep the shards logical (no committed
            # placement) — bit-identical to the single-device engine.
            devices = ([None] * shards if len(local) == 1
                       else [local[s % len(local)] for s in range(shards)])
        elif len(devices) != shards:
            raise ValueError(f"{len(devices)} devices for {shards} shards")
        self.registry = registry
        self.rotate_every = rotate_every
        self.defer_interval = defer_interval
        self.migrate = migrate
        # Shard failover mirrors the engine knob: None = strict (an
        # IntegrityError escapes and aborts the cluster); True or a
        # RecoveryPolicy also turns on page-level containment inside
        # every shard engine.
        self.ft = None
        if fault_tolerance:
            from repro.serve.faults import RecoveryPolicy
            self.ft = (RecoveryPolicy() if fault_tolerance is True
                       else fault_tolerance)
        self.failed_shards: set = set()
        # Per-migration audit hand-offs: {"rid", "from_shard",
        # "to_shard", "src_root", "proof"} — the destination-side proof
        # taken the moment the slot landed (see ``_migrate_slot``).
        self.migration_proofs: list = []
        if keys is None:
            keys = sm.SecureKeys.derive(0)
        # One chained audit log for the whole cluster: every shard's
        # records land on a single chain (the shard id is a field), so
        # cross-shard event ordering is itself tamper-evident.
        if isinstance(audit, audit_mod.AuditLog):
            self.audit = audit                # adopt even when empty/falsy
        elif audit:
            self.audit = audit_mod.AuditLog()
        else:
            self.audit = None
        self.engines = []
        for s in range(shards):
            dev = devices[s]
            self.engines.append(SecureServingEngine(
                arch, cfg,
                params if dev is None else jax.device_put(params, dev),
                scheme=scheme, max_slots=max_slots, page_tokens=page_tokens,
                pages_per_slot=pages_per_slot, n_pages=n_pages,
                keys=keys if dev is None else jax.device_put(keys, dev),
                registry=registry, rotate_every=0,
                shard_id=s, n_shards=shards, device=dev,
                preempt_hook=self._take_preempted,
                defer_interval=defer_interval,
                fault_tolerance=self.ft,
                trace=bool(trace), audit=self.audit, **engine_kw))
        self.sharded = ShardedKVPool(self.engines)
        self.devices = devices
        self.tick = 0
        self.requests: dict = {}            # cluster rid -> Request
        self._next_rid = 0
        self._rotate_rr = 0
        self._orphans: deque = deque()      # preempted, awaiting re-route
        self.metrics = metrics_mod.MetricsRegistry()
        for name, help_ in metrics_mod.CLUSTER_COUNTERS.items():
            self.metrics.counter(name, help_)
        self._stats = metrics_mod.StatsView(self.metrics)
        # The cluster's own tracer sits on its own pid track (one past
        # the last shard) so the cluster_tick span does not interleave
        # with shard 0's phase spans.  Each shard engine traces under
        # pid=shard_id (they build their own tracers above).
        self.tracer = None
        if trace:
            self.tracer = (trace if isinstance(trace, trace_mod.SpanTracer)
                           else trace_mod.SpanTracer(pid=shards))
            self._instrument_step()

    # -- observability -------------------------------------------------------

    def _instrument_step(self) -> None:
        """Wrap :meth:`step` with a span + wall-clock histogram."""
        hist = self.metrics.histogram(
            "cluster_tick_seconds",
            metrics_mod.CLUSTER_HISTOGRAMS["cluster_tick_seconds"])
        tracer, inner = self.tracer, self.step

        def wrapper(*a, **kw):
            t0 = time.perf_counter_ns()
            try:
                return inner(*a, **kw)
            finally:
                t1 = time.perf_counter_ns()
                tracer.add("cluster_tick", t0, t1, {"tick": self.tick})
                hist.observe((t1 - t0) / 1e9)

        self.step = wrapper

    @property
    def stats(self):
        """The cluster-level counters under the old dict API."""
        return self._stats

    def _audit(self, event: str, **fields) -> None:
        """Append one cluster-level security event (no-op when off)."""
        if self.audit is not None:
            self.audit.append(event, shard=-1, tick=self.tick, **fields)

    def snapshot(self) -> dict:
        """Cluster metrics + every shard's snapshot + the rollup.

        ``shards`` carries each engine's own snapshot (labeled
        ``shard=<id>``); ``rollup`` is the summed counter view
        (:attr:`engine_stats` — ``rotations`` takes the max, not the
        sum).
        """
        out = self.metrics.snapshot()
        out["shards"] = [e.snapshot() for e in self.engines]
        out["rollup"] = dict(self.engine_stats)
        return out

    def prometheus(self) -> str:
        """Prometheus text: cluster metrics + per-shard blocks
        (each shard's samples carry its ``shard=`` label)."""
        return "".join([self.metrics.prometheus()]
                       + [e.prometheus() for e in self.engines])

    def profile(self, buckets=None, uniform: bool = False,
                refresh: bool = False) -> dict:
        """Per-shard device-cost profiles + the cluster rollup.

        ``shards`` carries each engine's :meth:`profile` export (every
        entry labeled with its ``shard`` id, matching the ``shard=``
        labels on the per-shard profiler gauges); ``rollup`` sums the
        attributed protection/model bytes+flops across shards and
        recomputes the combined overhead ratios.
        """
        shards = [e.profile(buckets, uniform, refresh)
                  for e in self.engines]
        rollup = {k: {"bytes": 0.0, "flops": 0.0}
                  for k in ("protection", "model", "other", "total")}
        for shard in shards:
            for prof in shard["profiles"]:
                for k in rollup:
                    rollup[k]["bytes"] += prof[k]["bytes"]
                    rollup[k]["flops"] += prof[k]["flops"]
        model = rollup["model"]
        rollup["overhead_bytes_ratio"] = (
            rollup["protection"]["bytes"] / model["bytes"]
            if model["bytes"] else 0.0)
        rollup["overhead_flops_ratio"] = (
            rollup["protection"]["flops"] / model["flops"]
            if model["flops"] else 0.0)
        return {"scheme": self.engines[0].scheme if self.engines else None,
                "shards": shards, "rollup": rollup}

    def export_trace(self, path: Optional[str] = None) -> dict:
        """One Chrome trace merging cluster + every shard's spans
        (per-shard ``pid`` tracks show the dispatch/collect overlap)."""
        if self.tracer is None:
            raise ValueError("cluster was built without trace=...")
        extra = []
        for engine in self.engines:
            if engine.tracer is not None:
                extra += engine.tracer.events()
        return self.tracer.export(path, extra_events=extra)

    # -- submission / routing ------------------------------------------------

    def _submit(self, request: SubmitRequest) -> int:
        """Route one request to a shard; returns a cluster-wide rid."""
        tokens = [int(t) for t in request.prompt]
        tenant_index = (request.session.index
                        if request.session is not None else None)
        shard = self._route(tenant_index,
                            tokens if request.share_prefix else None)
        engine = self.engines[shard]
        local_rid = engine._submit(dataclasses.replace(request,
                                                       prompt=tokens))
        rid = self._next_rid
        self._next_rid += 1
        self.requests[rid] = engine.requests[local_rid]
        return rid

    def _load(self, engine) -> int:
        return (engine._n_waiting()
                + sum(1 for s in engine.slots if s is not None))

    def _has_tenant(self, engine, tenant_index: int) -> bool:
        if engine._tenant_waiting.get(tenant_index):
            return True
        return any(s is not None and s.tenant is not None
                   and s.tenant.index == tenant_index
                   for s in engine.slots)

    def _route(self, tenant_index: Optional[int],
               tokens: Optional[list] = None) -> int:
        """Prefix-holding shards first, then least-loaded.

        Prefix caches are shard-local (cache pages are sealed into one
        shard's pool and shard-bound by the RePA binding), so a request
        whose prompt prefix is cached anywhere goes to the shard
        covering the most tokens — skipping prefill beats starting on
        an idler shard.  Within the candidate set: least-loaded, with
        tenant affinity breaking near-ties."""
        cover = [0] * len(self.engines)
        if tenant_index is not None and tokens is not None and \
                len(tokens) > 1:
            cover = [e.prefix_cache.match_tokens(tenant_index, tokens[:-1])
                     if e.prefix_cache is not None else 0
                     for e in self.engines]
        for s in self.failed_shards:      # folded-out shards take nothing
            cover[s] = -1
        top = max(cover)
        best = None
        for s, engine in enumerate(self.engines):
            if s in self.failed_shards or cover[s] < top:
                continue
            score = float(self._load(engine))
            if tenant_index is not None and \
                    self._has_tenant(engine, tenant_index):
                score -= 0.5
            if best is None or score < best[0]:
                best = (score, s)
        return best[1]

    def _take_preempted(self, req) -> bool:
        """Engine preempt hook: the cluster re-routes evicted work."""
        self._orphans.append(req)
        return True

    def _requeue_orphans(self) -> None:
        while self._orphans:
            req = self._orphans.popleft()
            shard = self._route(
                req.tenant_idx,
                req.prompt + req.generated if req.share_prefix else None)
            engine = self.engines[shard]
            if req.tenant_idx is not None:
                if not engine._tenant_active(req.tenant_idx):
                    engine._activate_vtime(req.tenant_idx)
                engine._tenant_waiting.setdefault(
                    req.tenant_idx, deque()).appendleft(req)
            else:
                engine.waiting.appendleft(req)
            self.stats["rerouted_preemptions"] += 1

    # -- the cluster tick ----------------------------------------------------

    def step(self) -> list:
        """One cluster tick: every shard admits, then every shard's
        decode is dispatched, then every shard is collected — one
        multi-device dispatch wave per tick.  Returns finished
        requests across all shards.

        With ``fault_tolerance`` on, a shard whose tick phase raises
        without page context is failed over (:meth:`_failover`) while
        the other shards' tick proceeds untouched; raises that carry
        page context are contained inside that shard (quarantine +
        recompute) and never escalate to failover."""
        self.tick += 1
        if (self.registry is not None and self.rotate_every
                and self.tick % self.rotate_every == 0
                and self.registry.n_tenants):
            idx = self._rotate_rr % self.registry.n_tenants
            self._rotate_rr += 1
            self.rotate(self.registry.by_index(idx).tenant_id)
        finished: list = []
        if self.ft is None:
            actives = [e._tick_begin(finished) for e in self.engines]
            pendings = [e._decode_dispatch(a) if a else None
                        for e, a in zip(self.engines, actives)]
            for engine, active, pending in zip(self.engines, actives,
                                               pendings):
                if pending is not None:
                    engine._decode_collect(active, pending, finished)
            for engine in self.engines:
                engine._tick_end()
        else:
            self._step_ft(finished)
        if self.migrate and self._n_live() > 1:
            self._maybe_migrate()
        self._requeue_orphans()
        if self.defer_interval and self.tick % self.defer_interval == 0:
            self._root_check()
        return finished

    def _step_ft(self, finished: list) -> None:
        """The guarded tick phases: dispatch-all-before-collect-any is
        preserved across the surviving shards; a shard that raises is
        skipped for the rest of the tick and failed over at the end."""
        live = self._live_engines()
        failed_now: dict = {}

        def guard(engine, fn, *a):
            if engine.shard_id in failed_now:
                return None
            try:
                return fn(*a)
            except IntegrityError as err:
                if getattr(err, "ctx", None) is not None:
                    # Engine-raised with fault context: page-level,
                    # contained in place on that shard.
                    engine._contain_error(err)
                else:
                    failed_now[engine.shard_id] = err
                return None

        actives = [guard(e, e._tick_begin, finished) for e in live]
        pendings = [guard(e, e._decode_dispatch, a) if a else None
                    for e, a in zip(live, actives)]
        for engine, active, pending in zip(live, actives, pendings):
            if pending is not None:
                guard(engine, engine._decode_collect, active, pending,
                      finished)
        for engine in live:
            guard(engine, engine._tick_end)
        for shard, err in failed_now.items():
            self._failover(shard, err)

    def run(self, max_ticks: int = 100_000) -> RunResult:
        """Drive cluster ticks until every submitted request finished
        (or, with fault tolerance on, failed for good)."""
        for _ in range(max_ticks):
            if self._busy():
                self.step()
                continue
            if self._end_checks():
                break
        else:
            raise RuntimeError("run() exceeded max_ticks")
        result = RunResult({rid: req for rid, req in self.requests.items()
                            if req.state == "finished"})
        result.latency = latency_percentiles(self.requests.values())
        return result

    def _end_checks(self) -> bool:
        """End-of-run deferred checks across the surviving shards.

        Strict mode raises on any failure; with fault tolerance on, a
        shard-localizable failure is contained (page quarantine or
        shard failover — either may requeue work, in which case the
        run loop keeps ticking).  Returns True once fully drained."""
        for engine in self._live_engines():
            if engine.policy.deferred_model_mac:
                try:
                    engine._deferred_check()
                except IntegrityError as err:
                    if self.ft is None:
                        raise
                    engine._contain_error(err)
            if not engine.verify_every_step and not bool(engine._ok_accum):
                err = IntegrityError(
                    "accumulated page-MAC verification failed "
                    f"(shard {engine.shard_id})")
                if self.ft is None:
                    raise err
                # The accumulator cannot say which tick failed; the
                # whole shard is suspect and folds out.
                engine._ok_accum = jnp.asarray(True)
                self._failover(engine.shard_id, err)
        self._root_check()
        self._requeue_orphans()
        return not self._busy()

    def _busy(self) -> bool:
        if self._orphans:
            return True
        return any(e._n_waiting() or any(s is not None for s in e.slots)
                   for e in self.engines)

    def _live_engines(self) -> list:
        return [e for e in self.engines
                if e.shard_id not in self.failed_shards]

    def _n_live(self) -> int:
        return len(self.engines) - len(self.failed_shards)

    def rotate(self, tenant_id: str) -> int:
        """Cluster-wide live rotation (fans out to every shard)."""
        if self.registry is None:
            raise ValueError("rotate() needs a tenant registry")
        return self.registry.rotate(tenant_id)

    def _root_check(self) -> None:
        self.stats["root_checks"] += 1
        if self.sharded.deferred_root_check():
            return
        msg = f"cluster root MAC check failed (tick {self.tick})"
        self._audit("integrity_error", op="root_check", detail=msg)
        if self.ft is None:
            raise IntegrityError(msg)
        # Localize: a root mismatch means at least one shard's pool MAC
        # diverged from its incrementally-folded mirror (or its own
        # deferred identity).  Those shards fold out; their sessions
        # recompute on survivors.
        bad = self.sharded.failing_shards()
        if not bad:
            raise IntegrityError(msg)   # unlocalizable — do not serve on
        for shard in bad:
            self._failover(shard, IntegrityError(msg))

    def _failover(self, shard: int, err=None) -> None:
        """Fold one failed shard out of the cluster.

        Every session on the shard — running or queued — drains onto
        the survivors by secure recompute (re-routed by
        :meth:`_requeue_orphans`, re-prefilled from prompt + emitted
        tokens at re-admission).  The failed shard's pages are NEVER
        migrated or trusted, its free list is emptied so nothing can
        land there, and its pool MAC leaves the cluster root
        compression.  Raises when no survivor would remain."""
        if shard in self.failed_shards:
            return
        if len(self.failed_shards) + 1 >= len(self.engines):
            raise IntegrityError(
                f"shard {shard} failed with no survivor left"
                + (f": {err}" if err is not None else ""))
        self.failed_shards.add(shard)
        engine = self.engines[shard]
        drained = 0
        for i, slot in enumerate(engine.slots):
            if slot is None:
                continue
            req = slot.req
            engine._preempt(i)          # hook hands the req to _orphans
            req.recovering = True
            drained += 1
        if engine.registry is None:
            while engine.waiting:
                self._orphans.append(engine.waiting.popleft())
                drained += 1
        else:
            for queue in engine._tenant_waiting.values():
                while queue:
                    self._orphans.append(queue.popleft())
                    drained += 1
        engine.free_pages = []
        self.sharded.fold_out(shard)
        self.stats["shard_failovers"] += 1
        self._audit("shard_failover", shard=shard, sessions=drained,
                    detail=str(err) if err is not None else None)

    def deferred_check(self) -> bool:
        """Cluster root MAC + every shard's deferred pool MAC."""
        return self.sharded.deferred_root_check()

    def audit_proof(self, session=None, *, rid: Optional[int] = None) -> list:
        """Cluster-wide audit proofs for one session (or one request).

        One :class:`repro.serve.merkle_pool.AuditProof` per active
        shard holding the session's frames, each carrying the ordered
        active shard-root set and the cluster root they compress to —
        so the tenant verifies leaf -> shard root -> cluster root
        entirely host-independently (``verify_proof``), with no keys
        and no pool access.  Failed-over shards are folded out of the
        root set exactly as they are from the pool-MAC compression.
        """
        import dataclasses as _dc

        from repro.serve import merkle_pool as mkp
        pairs = self.sharded.merkle_roots()
        cluster = {"shard_roots": [(s, r.hex()) for s, r in pairs],
                   "root": mkp.compress_roots(pairs).hex()}
        proofs = []
        for shard in self.sharded._active:
            engine = self.engines[shard]
            try:
                p = engine.audit_proof(session, rid=rid)
            except KeyError:
                continue            # rid not resident on this shard
            if p.pages:
                proofs.append(_dc.replace(p, cluster=cluster))
        return proofs

    @property
    def engine_stats(self) -> dict:
        """Per-shard engine stats, summed — except ``rotations``:
        every engine's post-rotation hook observes every registry
        rotation, so summing would multiply the count by the shard
        fan-out; the max IS the cluster-wide rotation count."""
        agg: dict = {}
        for engine in self.engines:
            for k, v in engine.stats.items():
                agg[k] = agg.get(k, 0) + v
        if "rotations" in agg:
            agg["rotations"] = max(e.stats["rotations"]
                                   for e in self.engines)
        return agg

    # -- secure page migration ----------------------------------------------

    def _growth_pressure(self, engine) -> bool:
        """Queued work the shard cannot admit, or imminent page growth
        its free list cannot cover."""
        free = len(engine.free_pages)
        heads = []
        if engine.registry is None:
            if engine.waiting:
                heads.append(engine.waiting[0])
        else:
            heads += [q[0] for q in engine._tenant_waiting.values() if q]
        if heads and any(engine._admission_pages(r) > free for r in heads):
            return True
        need_soon = sum(
            1 for s in engine.slots if s is not None
            and (s.length + 1) // engine.page_tokens >= len(s.pages))
        return need_soon > free

    def _pick_migration(self, src: int):
        """(victim slot, destination shard) for one starved shard."""
        engine = self.engines[src]
        candidates = [i for i, s in enumerate(engine.slots) if s is not None]
        if not candidates:
            return None
        victim = max(candidates, key=lambda i: engine.slots[i].admit_seq)
        slot = engine.slots[victim]
        n = len(slot.pages)
        best = None
        for d, dst in enumerate(self.engines):
            if d == src or d in self.failed_shards or \
                    None not in dst.slots:
                continue
            # Headroom: the slot must land AND keep growing a while.
            if len(dst.free_pages) < n + 1:
                continue
            if slot.tenant is not None and \
                    dst.tenant_resident_pages(slot.tenant.index) + n > \
                    slot.tenant.page_quota:
                continue
            if best is None or len(dst.free_pages) > best[0]:
                best = (len(dst.free_pages), d)
        if best is None:
            return None
        return victim, best[1]

    def _maybe_migrate(self) -> None:
        for src in range(len(self.engines)):
            if src in self.failed_shards:
                continue
            if not self._growth_pressure(self.engines[src]):
                continue
            pick = self._pick_migration(src)
            if pick is None:
                continue
            if self.ft is None:
                self._migrate_slot(src, *pick)
                continue
            try:
                self._migrate_slot(src, *pick)
            except IntegrityError as err:
                # Migration re-verifies the source pages before the
                # move; a failure is a source-shard page fault and is
                # contained there (the slot stays put, recovery takes
                # over).
                self.engines[src]._contain_error(err)

    def _migrate_slot(self, src: int, slot_idx: int, dst: int) -> None:
        """Move one running slot's pages src -> dst, resealing them
        under the destination shard's binding (no recompute)."""
        import jax.numpy as jnp
        import numpy as np

        es, ed = self.engines[src], self.engines[dst]
        slot = es.slots[slot_idx]
        n = len(slot.pages)
        p = es.pages_per_slot                         # bucketed dispatch size
        src_ids = np.full((p,), es.spec.scratch_page, np.int32)
        src_ids[:n] = slot.pages
        tenant = slot.tenant
        if tenant is None:
            leaf_pages, ok = es._page_reader(p)(es.pool,
                                                jnp.asarray(src_ids))
        else:
            rows = np.zeros((p,), np.int32)
            epochs = np.zeros((p,), np.uint32)
            for j, e in enumerate(slot.page_epochs):
                epochs[j] = e
                if e & kvp.PREFIX_ROLE:
                    # Shared prefix page: read under the tenant's
                    # epoch-independent cache binding.  The copy lands
                    # at the destination as a PRIVATE page (the cache
                    # and its refcounts are shard-local).
                    rows[j] = self.registry.cache_row(tenant.index)
                    continue
                try:
                    rows[j] = self.registry.key_row(tenant.index, e)
                except KeyError as exc:
                    raise es._integrity_fail(
                        f"migration source shard {src} slot {slot_idx} "
                        f"page {j}: {exc.args[0]}",
                        op="migration", tenant=tenant.tenant_id,
                        to_shard=dst) from exc
            owners = np.full((p,), tenant.index, np.uint32)
            leaf_pages, ok = es._page_reader(p)(
                es.pool, jnp.asarray(src_ids), es._bank(),
                jnp.asarray(rows), jnp.asarray(owners), jnp.asarray(epochs))
        if not es.page_io.report_verdict(ok, "migration"):
            raise es._integrity_fail(
                f"secure migration: source shard {src} page verification "
                f"failed (slot {slot_idx}, scheme={es.scheme})",
                op="migration", slot=slot_idx, to_shard=dst,
                pages=[int(p) for p in slot.pages])
        dst_pages = [ed.free_pages.pop() for _ in range(n)]
        dst_ids = np.full((p,), ed.spec.scratch_page, np.int32)
        dst_ids[:n] = dst_pages
        if ed._device is not None and ed._device != es._device:
            leaf_pages = jax.device_put(leaf_pages, ed._device)
        if tenant is None:
            ed.pool = ed._page_writer(p)(ed.pool, jnp.asarray(dst_ids),
                                         leaf_pages, ed._next_epoch())
            page_epochs = []
        else:
            cur = tenant.current_epoch
            row = self.registry.key_row(tenant.index, cur)
            ed.pool = ed._page_writer(p)(
                ed.pool, jnp.asarray(dst_ids), leaf_pages, ed._next_epoch(),
                ed._bank(), jnp.full((p,), row, jnp.int32),
                jnp.full((p,), tenant.index, jnp.uint32),
                jnp.full((p,), np.uint32(cur), jnp.uint32))
            page_epochs = [cur] * n
        # Host state: the slot moves wholesale; its request never
        # leaves the "running" state and nothing is recomputed.
        dst_slot = ed.slots.index(None)
        for j in range(len(ed.onchip)):
            col = es.onchip[j][:, slot_idx]
            if ed._device is not None and ed._device != es._device:
                col = jax.device_put(col, ed._device)
            ed.onchip[j] = ed.onchip[j].at[:, dst_slot].set(col)
        es.slots[slot_idx] = None
        es.page_table.clear(slot_idx)
        # Shared prefix pages stay behind with the source shard's cache
        # (only their pin is dropped); the private tail is freed.
        if slot.shared_n:
            es.prefix_cache.release(slot.shared_entries)
        es._free(slot.pages[slot.shared_n:])
        ed._admit_seq += 1
        slot.pages = dst_pages
        slot.page_epochs = page_epochs
        slot.shared_n = 0
        slot.shared_entries = []
        slot.admit_seq = ed._admit_seq
        ed.slots[dst_slot] = slot
        ed.page_table.install(dst_slot, slot)
        # Thread the audit trail through the move: the migrated session
        # immediately re-proves membership against the destination
        # shard's root, and the hand-off (old root -> new proof) is
        # recorded so a tenant can audit that its transcript survived
        # the migration rather than trusting it did.
        src_root = dst_root = None
        if es.merkle is not None and ed.merkle is not None:
            es._merkle_sync()
            src_root = es.merkle.root_hex()
            proof = ed.audit_proof(rid=slot.req.rid)
            dst_root = proof.root
            self.migration_proofs.append(
                {"rid": slot.req.rid, "from_shard": src, "to_shard": dst,
                 "src_root": src_root, "proof": proof.to_dict()})
        self.stats["migrations"] += 1
        self._audit("migration", from_shard=src, to_shard=dst, pages=n,
                    tenant=tenant.tenant_id if tenant is not None else None,
                    src_root=src_root, dst_root=dst_root)
