import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: re-lower one cell with a code variant and
print the three roofline terms, for hypothesis -> change -> measure
cycles.  Variants are applied by monkeypatching config knobs.

Usage:
    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch olmoe-1b-7b --shape train_4k --set moe.dispatch=dp
"""

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import time         # noqa: E402

from repro.configs import get_arch                     # noqa: E402
from repro.launch.analysis import analyze_hlo              # noqa: E402
from repro.launch.cells import build_cell                  # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS  # noqa: E402


def _apply_overrides(cfg, sets):
    for kv in sets:
        path, val = kv.split("=", 1)
        try:
            val = json.loads(val)
        except json.JSONDecodeError:
            pass
        parts = path.split(".")
        if len(parts) == 1:
            cfg = dataclasses.replace(cfg, **{parts[0]: val})
        else:
            sub = getattr(cfg, parts[0])
            sub = sub._replace(**{parts[1]: val})
            cfg = dataclasses.replace(cfg, **{parts[0]: sub})
    return cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="config override, e.g. moe.dispatch=dp")
    ap.add_argument("--rule", action="append", default=[],
                    help="sharding-rule override, e.g. embed=null")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    arch = get_arch(args.arch)

    # Build the cell with overridden config/rules: patch the registry.
    base_make = arch.make_config
    rule_overrides = dict(arch.rules)
    for kv in args.rule:
        k, v = kv.split("=", 1)
        rule_overrides[k] = json.loads(v) if v in ("null",) else (
            tuple(v.split("+")) if "+" in v else v)
    arch_patched = dataclasses.replace(
        arch, make_config=lambda: _apply_overrides(base_make(), args.set),
        rules=rule_overrides)
    import repro.configs as cfgs
    cfgs.ARCHS[args.arch] = arch_patched

    t0 = time.time()
    cell = build_cell(args.arch, args.shape, mesh)
    compiled = cell.lower(mesh).compile()
    t1 = time.time()
    stats = analyze_hlo(compiled.as_text())

    terms = {
        "compute_s": stats.dot_flops / PEAK_FLOPS,
        "memory_s": stats.mem_bytes / HBM_BW,
        "collective_s": stats.collective_total / LINK_BW,
    }
    print(f"[hillclimb] {args.arch} x {args.shape} x {args.mesh} "
          f"overrides={args.set} (compile {t1 - t0:.0f}s)")
    print(f"  dot_flops/chip = {stats.dot_flops:.4g}")
    print(f"  mem_bytes/chip = {stats.mem_bytes:.4g}")
    print(f"  collectives/chip = "
          f"{ {k: float(f'{v:.4g}') for k, v in stats.collectives.items()} }")
    for k, v in terms.items():
        print(f"  {k:14s} = {v:.4g}")
    print(f"  dominant = {max(terms, key=terms.get)}")


if __name__ == "__main__":
    main()
