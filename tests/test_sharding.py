"""Sharding planner invariants across all 10 archs (no devices needed:
NamedSharding construction is validated against a 16x16 abstract mesh)."""

import math

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_arch
from repro.launch import sharding as shp
from repro.launch.mesh import make_test_mesh
from repro.models import encdec as ed
from repro.models import lm as lm_mod
from repro.models.layers import ParamSpec


@pytest.fixture(scope="module")
def mesh():
    # 1 real device is enough to build an abstract mesh object for
    # planner logic (we never place data in these tests).
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    from jax.sharding import Mesh
    return Mesh(dev, ("data", "model"))


def _mesh_sizes(overrides):
    """Fake mesh-shape lookup for divisibility math (production 16x16)."""
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    return FakeMesh()


@pytest.mark.parametrize("arch_name", sorted(ARCHS))
class TestPlanner:
    def _specs(self, arch):
        cfg = arch.make_config()
        return (ed.encdec_specs(cfg) if arch.kind == "encdec"
                else lm_mod.lm_specs(cfg))

    def test_every_rule_application_divides(self, arch_name):
        """Resolved specs never assign a mesh axis that does not divide
        the dim — the invariant that makes lower() never fail on
        sharding mismatches."""
        arch = get_arch(arch_name)
        specs = self._specs(arch)
        rules = arch.sharding_rules()
        fake = _mesh_sizes(rules)
        flat, _ = jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        checked = 0
        for path, s in flat:
            axes = shp._resolve_axes(s.shape, s.axes, rules, fake)
            for dim, axis in zip(s.shape, axes):
                if axis is None:
                    continue
                names = axis if isinstance(axis, tuple) else (axis,)
                size = math.prod(fake.shape[n] for n in names)
                assert dim % size == 0, (arch_name,
                                         jax.tree_util.keystr(path), s.shape)
                checked += 1
        assert checked > 0, "planner sharded nothing — rules broken"

    def test_no_axis_used_twice_per_tensor(self, arch_name):
        arch = get_arch(arch_name)
        specs = self._specs(arch)
        rules = arch.sharding_rules()
        fake = _mesh_sizes(rules)
        flat, _ = jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        for path, s in flat:
            axes = shp._resolve_axes(s.shape, s.axes, rules, fake)
            used = [a for a in axes if a is not None]
            flat_used = []
            for a in used:
                flat_used.extend(a if isinstance(a, tuple) else (a,))
            assert len(flat_used) == len(set(flat_used)), (
                arch_name, jax.tree_util.keystr(path), axes)

    def test_big_weights_sharded(self, arch_name):
        """Any tensor >= 64MB (bf16) must shard on at least one axis on
        the production mesh — else a single chip would hold it whole."""
        arch = get_arch(arch_name)
        specs = self._specs(arch)
        rules = arch.sharding_rules()
        fake = _mesh_sizes(rules)
        flat, _ = jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        for path, s in flat:
            nbytes = math.prod(s.shape) * 2
            if nbytes < 64 * 1024 * 1024:
                continue
            axes = shp._resolve_axes(s.shape, s.axes, rules, fake)
            assert any(a is not None for a in axes), (
                arch_name, jax.tree_util.keystr(path), s.shape,
                "unsharded large tensor")


class TestBatchSharding:
    def test_divisible_batch_shards_over_dp(self):
        fake = _mesh_sizes({})
        b_axis, s_axis = shp.batch_sharding(fake, 256)
        assert b_axis == "data" and s_axis is None

    def test_batch_one_falls_back_to_sequence(self):
        fake = _mesh_sizes({})
        b_axis, s_axis = shp.batch_sharding(fake, 1)
        assert b_axis is None and s_axis == "data"
