"""Host-side span tracer: tick phases -> Chrome trace-event JSON.

A :class:`SpanTracer` records complete ("ph": "X") spans into a
bounded ring buffer; :meth:`SpanTracer.export` renders the Chrome
trace-event format that ``chrome://tracing`` and Perfetto load
directly.  The engine wraps its tick phases (``_tick_begin`` /
``_decode_dispatch`` / ``_decode_collect`` / ``_tick_end``) in spans
when constructed with ``trace=...``; each shard engine traces under
its own ``pid`` so a cluster export shows the per-shard overlap the
dispatch-all-before-collect-any tick is supposed to buy.

Timing uses ``time.perf_counter_ns`` against a per-process origin, so
spans from tracers created at different times still land on one
comparable timeline.
"""

from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager
from typing import Optional

__all__ = ["SpanTracer"]

# One origin per process: every tracer's timestamps are offsets from
# here, so multi-tracer (cluster) exports share a timeline.
_ORIGIN_NS = time.perf_counter_ns()


class SpanTracer:
    """Ring buffer of completed spans, Chrome-trace exportable."""

    def __init__(self, *, pid: int = 0, tid: int = 0,
                 capacity: int = 65536):
        self.pid, self.tid = pid, tid
        self._events: deque = deque(maxlen=capacity)

    def __len__(self) -> int:
        return len(self._events)

    def add(self, name: str, t0_ns: int, t1_ns: int,
            args: Optional[dict] = None) -> None:
        """Record one completed span (absolute perf_counter_ns pair)."""
        event = {
            "name": name,
            "ph": "X",
            "ts": (t0_ns - _ORIGIN_NS) / 1000.0,     # microseconds
            "dur": (t1_ns - t0_ns) / 1000.0,
            "pid": self.pid,
            "tid": self.tid,
        }
        if args:
            event["args"] = args
        self._events.append(event)

    @contextmanager
    def span(self, name: str, **args):
        """Context manager recording one span around its body."""
        t0 = time.perf_counter_ns()
        try:
            yield
        finally:
            self.add(name, t0, time.perf_counter_ns(), args or None)

    def events(self) -> list:
        """The buffered spans as Chrome trace-event dicts (oldest first)."""
        return list(self._events)

    def clear(self) -> None:
        self._events.clear()

    def export(self, path: Optional[str] = None, *,
               extra_events: Optional[list] = None) -> dict:
        """The Chrome trace-event JSON document; written when ``path``.

        ``extra_events`` lets a cluster merge its shard tracers into
        one file (every tracer stamps its own ``pid``).
        """
        events = self.events() + list(extra_events or [])
        events.sort(key=lambda e: e["ts"])
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc
