"""Batched secure serving: tokens/s + protection traffic per scheme.

The serving analogue of :mod:`benchmarks.bench_secure_step`: the
continuous-batching engine with the paged, MAC-protected KV pool
(:mod:`repro.serve.engine`) decodes under every protection scheme at
batch sizes {1, 8, 32}, reporting

* steady-state decode throughput (tokens/s, compile excluded), and
* HLO-visible protection traffic: ``bytes accessed`` of the jitted
  batched decode step, minus the same measurement for the ``off``
  scheme (the paper's DRAM-traffic-overhead axis).

A second sweep — **decode scaling** — pins the pool size and sweeps
the live context length: with the two-level page table's pow2
page-count bucketing, the decode's gather/crypt/MAC work follows the
touched-page bucket, so tok/s and ``bytes accessed`` should track the
context, not the pool.  The all-resident window (the pre-bucketing
behaviour) is measured alongside as the baseline the bucketing beats.

A third sweep — **shared prefix** — measures the secure prefix cache:
``hit_rate`` of the batch shares one prompt, and the cached engine's
tok/s, prefill pages skipped, and CoW count are reported next to a
per-point token-identity check against the no-cache engine.

Standalone JSON mode for the CI perf-smoke job::

    PYTHONPATH=src python benchmarks/bench_secure_serving.py \
        --batch-sizes 1,8 --gen-len 6 --json results.json \
        --decode-scaling-json decode-scaling.json \
        --shared-prefix-json shared-prefix.json
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.serve import kv_pages as kvp
from repro.serve.engine import SecureServingEngine

try:                                    # package or script invocation
    from benchmarks._meta import stamp
except ImportError:
    from _meta import stamp

DEFAULT_SCHEMES = ("off", "seda", "seda512", "mgx64", "sgx64")
DEFAULT_BATCHES = (1, 8, 32)
DEFAULT_SCALING_CONTEXTS = (8, 24, 56)
DEFAULT_HIT_RATES = (0.0, 0.5, 1.0)


def _measure(arch, cfg, params, scheme: str, batch: int, *,
             page_tokens: int, pages_per_slot: int, gen_len: int,
             prompt_len: int, seed: int = 0,
             use_kernel: bool = False) -> dict:
    rng = np.random.default_rng(seed)
    eng = SecureServingEngine(
        arch, cfg, params, scheme=scheme, max_slots=batch,
        page_tokens=page_tokens, pages_per_slot=pages_per_slot,
        n_pages=batch * pages_per_slot, use_kernel=use_kernel)
    for _ in range(batch):
        prompt = list(map(int, rng.integers(1, cfg.vocab, prompt_len)))
        eng.submit(prompt=prompt, max_new_tokens=gen_len)
    eng.step()                       # admission + first decode (compiles)
    t0 = time.perf_counter()
    steps = 0
    while any(s is not None for s in eng.slots) or eng.waiting:
        eng.step()
        steps += 1
    dt = time.perf_counter() - t0
    cost = eng.decode_cost_analysis()
    return {
        "scheme": scheme,
        "batch": batch,
        "decode_steps_timed": steps,
        "tok_per_s": batch * steps / max(dt, 1e-9),
        "us_per_step": dt / max(steps, 1) * 1e6,
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "preemptions": eng.stats["preemptions"],
        "prefill_compiles": eng.stats["prefill_compiles"],
        "uniform_fast_ticks": eng.stats["uniform_fast_ticks"],
        "fused_write_ticks": eng.stats["fused_write_ticks"],
        "latency": eng.latency_stats(),
    }


def collect(schemes=DEFAULT_SCHEMES, batch_sizes=DEFAULT_BATCHES, *,
            arch_name: str = "minitron-4b", page_tokens: int = 8,
            pages_per_slot: int = 4, gen_len: int = 8,
            prompt_len: int = 9, use_kernel: bool = False) -> list:
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    results = []
    for batch in batch_sizes:
        base_bytes = None
        for scheme in schemes:
            r = _measure(arch, cfg, params, scheme, batch,
                         page_tokens=page_tokens,
                         pages_per_slot=pages_per_slot, gen_len=gen_len,
                         prompt_len=prompt_len, use_kernel=use_kernel)
            if scheme == "off":
                base_bytes = r["bytes_accessed"]
            if base_bytes:
                r["protection_traffic_bytes"] = (r["bytes_accessed"]
                                                 - base_bytes)
                r["traffic_overhead"] = r["bytes_accessed"] / base_bytes - 1
            results.append(r)
    return results


def _measure_obs(arch, cfg, params, scheme: str, *, batch: int,
                 page_tokens: int, pages_per_slot: int, gen_len: int,
                 prompt_len: int, seed: int = 0, repeats: int = 3):
    """One scheme's obs-overhead point: tok/s and tokens, obs off vs on.

    The instrumented engine runs with tracing AND the audit log enabled
    (the worst observability case); ``tokens_match`` asserts the
    instrumentation is observation-only.  A warmup pass takes every
    compile off the clock, then each variant is timed ``repeats``
    times and the best rate kept, damping scheduler noise on loaded CI
    runners.
    """
    prompts = [list(map(int,
                        np.random.default_rng(seed + i)
                        .integers(1, cfg.vocab, prompt_len)))
               for i in range(batch)]

    def run(obs: bool):
        eng = SecureServingEngine(
            arch, cfg, params, scheme=scheme, max_slots=batch,
            page_tokens=page_tokens, pages_per_slot=pages_per_slot,
            n_pages=batch * pages_per_slot, trace=obs, audit=obs)

        def drain() -> tuple:
            steps = 0
            t0 = time.perf_counter()
            while any(s is not None for s in eng.slots) or eng.waiting:
                eng.step()
                steps += 1
            return steps, time.perf_counter() - t0

        # Warmup pass: compiles every prefill shape and decode bucket
        # this workload will ever touch, so the timed passes below
        # measure steady-state ticks only (greedy decode: every pass
        # over the same prompts generates the same tokens).
        rids = [eng.submit(prompt=p, max_new_tokens=gen_len)
                for p in prompts]
        drain()
        tokens = sorted((i, tuple(eng.requests[r].generated))
                        for i, r in enumerate(rids))
        best = 0.0
        for _ in range(repeats):
            for p in prompts:
                eng.submit(prompt=p, max_new_tokens=gen_len)
            steps, dt = drain()
            best = max(best, batch * steps / max(dt, 1e-9))
        return eng, best, tokens

    _, best_off, tokens_off = run(False)
    eng_on, best_on, tokens_on = run(True)
    doc = eng_on.export_trace()
    row = {
        "scheme": scheme,
        "batch": batch,
        "tok_per_s_off": best_off,
        "tok_per_s_on": best_on,
        "obs_overhead": 1.0 - best_on / max(best_off, 1e-9),
        "tokens_match": tokens_off == tokens_on,
        "trace_events": len(doc["traceEvents"]),
        "audit_records": len(eng_on.audit),
        "audit_chain_ok": eng_on.audit.verify_chain(),
    }
    return row, eng_on


def collect_obs_overhead(schemes=DEFAULT_SCHEMES, *,
                         arch_name: str = "minitron-4b", batch: int = 4,
                         page_tokens: int = 8, pages_per_slot: int = 4,
                         gen_len: int = 8, prompt_len: int = 9,
                         trace_out=None, metrics_json=None) -> list:
    """Instrumented-vs-bare sweep: full observability must be ~free.

    Optionally writes the LAST scheme's instrumented artifacts (Chrome
    trace, metrics snapshot) — the CI perf-smoke uploads those.
    """
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    results, eng = [], None
    for scheme in schemes:
        row, eng = _measure_obs(
            arch, cfg, params, scheme, batch=batch, page_tokens=page_tokens,
            pages_per_slot=pages_per_slot, gen_len=gen_len,
            prompt_len=prompt_len)
        results.append(row)
    if trace_out and eng is not None:
        eng.export_trace(trace_out)
    if metrics_json and eng is not None:
        with open(metrics_json, "w") as f:
            json.dump(eng.snapshot(), f, indent=2, sort_keys=True)
    return results


def collect_protection_profiles(schemes=DEFAULT_SCHEMES, *,
                                arch_name: str = "minitron-4b",
                                page_tokens: int = 4,
                                pages_per_slot: int = 2,
                                use_kernel: bool = False) -> list:
    """One ``Engine.profile()`` per scheme: the HLO-attributed
    protection-vs-model split for the largest decode bucket.

    The flattened ``overhead_*_ratio`` numbers feed the bench history
    (they are deterministic per compile, so the regression gate holds
    them to a tight band); the full per-file attribution rides along
    under ``profile`` for the artifact reader.
    """
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    rows = []
    for scheme in schemes:
        eng = SecureServingEngine(
            arch, cfg, params, scheme=scheme, max_slots=1,
            page_tokens=page_tokens, pages_per_slot=pages_per_slot,
            use_kernel=use_kernel and scheme != "off")
        for prof in eng.profile()["profiles"]:
            rows.append({
                "scheme": scheme,
                "bucket": prof["bucket"],
                "overhead_bytes_ratio": prof["overhead_bytes_ratio"],
                "overhead_flops_ratio": prof["overhead_flops_ratio"],
                "coverage_bytes": prof["coverage"]["bytes"],
                "coverage_flops": prof["coverage"]["flops"],
                "profile": prof,
            })
    return rows


def _measure_decode_scaling(arch, cfg, params, scheme: str, *, batch: int,
                            page_tokens: int, pages_per_slot: int,
                            prompt_len: int, gen_len: int,
                            seed: int = 0) -> dict:
    """One decode-scaling point: fixed pool, one live context length."""
    rng = np.random.default_rng(seed)
    eng = SecureServingEngine(
        arch, cfg, params, scheme=scheme, max_slots=batch,
        page_tokens=page_tokens, pages_per_slot=pages_per_slot,
        n_pages=batch * pages_per_slot)
    for _ in range(batch):
        prompt = list(map(int, rng.integers(1, cfg.vocab, prompt_len)))
        eng.submit(prompt=prompt, max_new_tokens=gen_len)
    eng.step()                       # admission + first decode (compiles)
    t0 = time.perf_counter()
    steps = 0
    while any(s is not None for s in eng.slots) or eng.waiting:
        eng.step()
        steps += 1
    dt = time.perf_counter() - t0
    # The last decode runs at the pre-increment length prompt+gen-1;
    # that is the widest window the engine actually dispatched.
    bucket = kvp.page_count_bucket(
        (prompt_len + gen_len - 1) // page_tokens + 1, pages_per_slot)
    cost_bucket = eng.decode_cost_analysis(bucket)
    cost_full = eng.decode_cost_analysis()       # all-resident baseline
    decode_steps = max(eng.stats["decode_steps"], 1)
    return {
        "scheme": scheme,
        "batch": batch,
        "context_len": prompt_len + gen_len,
        "pool_pages_per_slot": pages_per_slot,
        "peak_bucket": bucket,
        "tok_per_s": batch * steps / max(dt, 1e-9),
        "us_per_step": dt / max(steps, 1) * 1e6,
        "page_reads_per_step": eng.stats["decode_page_reads"] / decode_steps,
        "all_resident_page_reads_per_step": batch * pages_per_slot,
        "bytes_accessed_bucket": float(
            cost_bucket.get("bytes accessed", 0.0)),
        "bytes_accessed_all_resident": float(
            cost_full.get("bytes accessed", 0.0)),
    }


def collect_decode_scaling(context_lens=DEFAULT_SCALING_CONTEXTS, *,
                           arch_name: str = "minitron-4b",
                           scheme: str = "seda", batch: int = 2,
                           page_tokens: int = 8, pages_per_slot: int = 8,
                           gen_len: int = 6) -> list:
    """tok/s + decode work vs. live context length at a FIXED pool size.

    Every point serves from the same (batch * pages_per_slot)-page
    pool; only the prompt length moves.  With touched-page bucketing
    the per-step page reads and HLO bytes follow the context's pow2
    bucket; the ``all_resident_*`` fields are the pre-bucketing
    baseline (full ``pages_per_slot`` window every step).
    """
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    results = []
    for prompt_len in context_lens:
        results.append(_measure_decode_scaling(
            arch, cfg, params, scheme, batch=batch, page_tokens=page_tokens,
            pages_per_slot=pages_per_slot, prompt_len=prompt_len,
            gen_len=gen_len))
    return results


def _measure_shared_prefix(arch, cfg, params, scheme: str, hit_rate: float,
                           *, batch: int, page_tokens: int,
                           pages_per_slot: int, gen_len: int,
                           prompt_len: int, seed: int = 0) -> dict:
    """One shared-prefix point: ``hit_rate`` of the batch shares one
    prompt; the cached engine's tokens are checked against a no-cache
    engine (token identity is part of the measurement)."""
    from repro.tenancy.keys import KeyHierarchy
    from repro.tenancy.registry import TenantRegistry

    rng = np.random.default_rng(seed)
    shared = list(map(int, rng.integers(1, cfg.vocab, prompt_len)))
    n_shared = round(hit_rate * batch)
    prompts = [list(shared) if i < n_shared else
               list(map(int, rng.integers(1, cfg.vocab, prompt_len)))
               for i in range(batch)]

    def run_once(prefix_cache: bool):
        registry = TenantRegistry(KeyHierarchy(0), max_tenants=2)
        registry.register("bench")
        eng = SecureServingEngine(
            arch, cfg, params, scheme=scheme, max_slots=batch,
            page_tokens=page_tokens, pages_per_slot=pages_per_slot,
            n_pages=(batch + 1) * pages_per_slot, registry=registry,
            prefix_cache=prefix_cache, prefix_cache_pages=pages_per_slot)
        sess = registry.open_session("bench")
        rids = [eng.submit(prompt=p, max_new_tokens=gen_len, session=sess)
                for p in prompts]
        eng.step()                   # admission + first decode (compiles)
        t0 = time.perf_counter()
        steps = 0
        while any(s is not None for s in eng.slots) or eng._n_waiting():
            eng.step()
            steps += 1
        dt = time.perf_counter() - t0
        tokens = [eng.requests[r].generated for r in rids]
        return eng, tokens, steps, dt

    base_eng, base_tokens, _, _ = run_once(False)
    eng, tokens, steps, dt = run_once(True)
    return {
        "scheme": scheme,
        "hit_rate": hit_rate,
        "batch": batch,
        "tok_per_s": batch * steps / max(dt, 1e-9),
        "us_per_step": dt / max(steps, 1) * 1e6,
        "prefix_hit_pages": eng.stats["prefix_hit_pages"],
        "prefix_cow_pages": eng.stats["prefix_cow_pages"],
        "prefix_inserted_pages": eng.stats["prefix_inserted_pages"],
        "prefill_pages_skipped": eng.stats["prefill_pages_skipped"],
        "prefill_compiles": eng.stats["prefill_compiles"],
        "baseline_prefill_compiles": base_eng.stats["prefill_compiles"],
        "tokens_match": tokens == base_tokens,
    }


def collect_shared_prefix(hit_rates=DEFAULT_HIT_RATES,
                          schemes=("off", "seda"), *,
                          arch_name: str = "minitron-4b", batch: int = 4,
                          page_tokens: int = 8, pages_per_slot: int = 4,
                          gen_len: int = 6, prompt_len: int = 17) -> list:
    """Shared-prefix sweep: hit-rate x scheme, tok/s + prefill pages
    skipped, with per-point token-identity vs. the no-cache engine."""
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    results = []
    for scheme in schemes:
        for hr in hit_rates:
            results.append(_measure_shared_prefix(
                arch, cfg, params, scheme, hr, batch=batch,
                page_tokens=page_tokens, pages_per_slot=pages_per_slot,
                gen_len=gen_len, prompt_len=prompt_len))
    return results


def run_shared_prefix() -> list:
    """benchmarks.run suite hook for the shared-prefix sweep."""
    rows = []
    for r in collect_shared_prefix(hit_rates=(0.0, 1.0)):
        rows.append({
            "name": (f"shared_prefix_{r['scheme']}"
                     f"_hit{int(r['hit_rate'] * 100)}"),
            "us_per_call": r["us_per_step"],
            "derived": (f"tok/s={r['tok_per_s']:.1f} "
                        f"pages_skipped={r['prefill_pages_skipped']} "
                        f"cow={r['prefix_cow_pages']} "
                        f"tokens_match={r['tokens_match']}"),
        })
    return rows


def run_decode_scaling() -> list:
    """benchmarks.run suite hook for the decode-scaling sweep."""
    rows = []
    for r in collect_decode_scaling():
        saved = 1.0 - (r["page_reads_per_step"]
                       / max(r["all_resident_page_reads_per_step"], 1))
        rows.append({
            "name": f"decode_scaling_ctx{r['context_len']}",
            "us_per_call": r["us_per_step"],
            "derived": (f"tok/s={r['tok_per_s']:.1f} "
                        f"bucket={r['peak_bucket']}/"
                        f"{r['pool_pages_per_slot']} "
                        f"page_reads_saved={saved:.1%}"),
        })
    return rows


def run() -> list:
    """benchmarks.run suite hook: CSV rows for a reduced sweep."""
    rows = []
    for r in collect(batch_sizes=(1, 8), gen_len=6):
        overhead = r.get("traffic_overhead")
        derived = (f"tok/s={r['tok_per_s']:.1f} "
                   f"steps={r['decode_steps_timed']}")
        lat = r.get("latency") or {}
        if lat:
            derived += (f" ttft_p95={lat['p95_ttft_ticks']:.1f}")
        if overhead is not None:
            derived += f" traffic_overhead={overhead:+.1%}"
        rows.append({
            "name": f"serve_{r['scheme']}_b{r['batch']}",
            "us_per_call": r["us_per_step"],
            "derived": derived,
        })
    return rows


def main(argv=None) -> list:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--schemes", default=",".join(DEFAULT_SCHEMES))
    ap.add_argument("--batch-sizes", default=",".join(map(str,
                                                          DEFAULT_BATCHES)))
    ap.add_argument("--page-tokens", type=int, default=8)
    ap.add_argument("--pages-per-slot", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=9)
    ap.add_argument("--use-kernel", action="store_true",
                    help="route the protection crypto through the fused "
                         "Pallas kernels (read AND write direction)")
    ap.add_argument("--json", default=None, help="write results to this file")
    ap.add_argument("--decode-scaling-json", default=None,
                    help="also run the decode-scaling sweep (tok/s + decode "
                         "work vs. context length at fixed pool size) and "
                         "write its results to this file")
    ap.add_argument("--scaling-contexts",
                    default=",".join(map(str, DEFAULT_SCALING_CONTEXTS)))
    ap.add_argument("--shared-prefix-json", default=None,
                    help="also run the shared-prefix sweep (hit-rate x "
                         "scheme, tok/s + prefill pages skipped + token "
                         "identity vs. the no-cache engine) and write its "
                         "results to this file")
    ap.add_argument("--hit-rates",
                    default=",".join(map(str, DEFAULT_HIT_RATES)))
    ap.add_argument("--obs-json", default=None,
                    help="also run the observability-overhead sweep "
                         "(tok/s + token identity, tracing+audit on vs "
                         "off) and write its results to this file")
    ap.add_argument("--trace-out", default=None,
                    help="write the obs sweep's Chrome trace here "
                         "(needs --obs-json)")
    ap.add_argument("--metrics-json", default=None,
                    help="write the obs sweep's metrics snapshot here "
                         "(needs --obs-json)")
    ap.add_argument("--profile-json", default=None,
                    help="also run the protection-overhead profiler "
                         "(Engine.profile() per scheme) and write its "
                         "results to this file")
    args = ap.parse_args(argv)
    if (args.trace_out or args.metrics_json) and not args.obs_json:
        raise SystemExit("--trace-out/--metrics-json need --obs-json "
                         "(they dump the instrumented sweep's engine)")

    results = collect(
        schemes=tuple(args.schemes.split(",")),
        batch_sizes=tuple(int(b) for b in args.batch_sizes.split(",")),
        arch_name=args.arch, page_tokens=args.page_tokens,
        pages_per_slot=args.pages_per_slot, gen_len=args.gen_len,
        prompt_len=args.prompt_len, use_kernel=args.use_kernel)
    for r in results:
        print(f"[serve-bench] scheme={r['scheme']:<8} batch={r['batch']:<3} "
              f"tok/s={r['tok_per_s']:9.1f} "
              f"traffic={r.get('protection_traffic_bytes', 0):12.0f}B")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(stamp({"benchmark": "secure_serving",
                             "results": results}), f, indent=2)
        print(f"[serve-bench] wrote {args.json}")
    if args.decode_scaling_json:
        scaling = collect_decode_scaling(
            tuple(int(c) for c in args.scaling_contexts.split(",")),
            arch_name=args.arch)
        for r in scaling:
            print(f"[serve-bench] decode-scaling ctx={r['context_len']:<4} "
                  f"bucket={r['peak_bucket']}/{r['pool_pages_per_slot']} "
                  f"tok/s={r['tok_per_s']:9.1f} "
                  f"page_reads/step={r['page_reads_per_step']:.1f} "
                  f"(all-resident {r['all_resident_page_reads_per_step']})")
        with open(args.decode_scaling_json, "w") as f:
            json.dump(stamp({"benchmark": "decode_scaling",
                             "results": scaling}), f, indent=2)
        print(f"[serve-bench] wrote {args.decode_scaling_json}")
    if args.shared_prefix_json:
        prefix = collect_shared_prefix(
            tuple(float(h) for h in args.hit_rates.split(",")),
            arch_name=args.arch)
        for r in prefix:
            print(f"[serve-bench] shared-prefix scheme={r['scheme']:<6} "
                  f"hit={r['hit_rate']:<4} tok/s={r['tok_per_s']:9.1f} "
                  f"pages_skipped={r['prefill_pages_skipped']:<3} "
                  f"cow={r['prefix_cow_pages']:<2} "
                  f"tokens_match={r['tokens_match']}")
        with open(args.shared_prefix_json, "w") as f:
            json.dump(stamp({"benchmark": "shared_prefix",
                             "results": prefix}), f, indent=2)
        print(f"[serve-bench] wrote {args.shared_prefix_json}")
    if args.obs_json:
        obs = collect_obs_overhead(
            tuple(args.schemes.split(",")), arch_name=args.arch,
            page_tokens=args.page_tokens,
            pages_per_slot=args.pages_per_slot, gen_len=args.gen_len,
            prompt_len=args.prompt_len, trace_out=args.trace_out,
            metrics_json=args.metrics_json)
        for r in obs:
            print(f"[serve-bench] obs scheme={r['scheme']:<8} "
                  f"off={r['tok_per_s_off']:9.1f} "
                  f"on={r['tok_per_s_on']:9.1f} tok/s "
                  f"({r['obs_overhead']:+.1%}) "
                  f"tokens_match={r['tokens_match']} "
                  f"trace_events={r['trace_events']}")
        with open(args.obs_json, "w") as f:
            json.dump(stamp({"benchmark": "obs_overhead", "results": obs}),
                      f, indent=2)
        print(f"[serve-bench] wrote {args.obs_json}")
    if args.profile_json:
        profiles = collect_protection_profiles(
            tuple(args.schemes.split(",")), arch_name=args.arch,
            use_kernel=args.use_kernel)
        for r in profiles:
            print(f"[serve-bench] profile scheme={r['scheme']:<8} "
                  f"bucket={r['bucket']} "
                  f"overhead_bytes={r['overhead_bytes_ratio']:.3f} "
                  f"overhead_flops={r['overhead_flops_ratio']:.3f} "
                  f"coverage={r['coverage_bytes']:.2%}/"
                  f"{r['coverage_flops']:.2%}")
        with open(args.profile_json, "w") as f:
            json.dump(stamp({"benchmark": "protection_profile",
                             "results": profiles}), f, indent=2)
        print(f"[serve-bench] wrote {args.profile_json}")
    return results


if __name__ == "__main__":
    main()
