"""Docs gate: links resolve, attack rows name real tests, examples run.

Three checks over the repo's user-facing markdown (README.md +
docs/*.md), kept dependency-free so the CI docs job stays cheap:

* **links** — every relative markdown link target exists on disk
  (external http(s)/mailto links and GitHub-side paths that resolve
  outside the repo, like the CI badge, are skipped);
* **test references** — every ``tests/test_*.py::TestClass::test_name``
  mentioned in the docs (the threat model's attack table, the
  architecture spec's invariant pointers) names a class/function that
  actually exists, checked by parsing the test file's AST — a renamed
  test cannot silently orphan a protection claim;
* **doctests** — fenced ``python`` blocks containing ``>>>`` examples
  run under :mod:`doctest` (importing ``repro`` needs ``PYTHONPATH=src``
  or an installed package, exactly like the test suite).

Usage::

    PYTHONPATH=src python docs/check_docs.py

Exit code 0 when everything holds; 1 with a per-finding report
otherwise.  ``tests/test_docs.py`` runs the same checks in tier-1.
"""

from __future__ import annotations

import ast
import doctest
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_TEST_REF = re.compile(r"(tests/test_\w+\.py)::(\w+)(?:::(\w+))?")
_PY_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def doc_files() -> list:
    """The markdown this gate owns: README + the docs/ subsystem."""
    return [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))


def check_links(path: pathlib.Path) -> list:
    """Relative link targets that do not exist on disk."""
    errors = []
    for target in _LINK.findall(path.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        resolved = (path.parent / target.split("#")[0]).resolve()
        if not resolved.is_relative_to(ROOT):
            continue        # GitHub-side path (e.g. the CI badge)
        if not resolved.exists():
            errors.append(f"{path.name}: broken link -> {target}")
    return errors


def _test_index(test_path: pathlib.Path) -> tuple:
    """(class -> its method names, module-level function names)."""
    tree = ast.parse(test_path.read_text())
    classes = {n.name: {m.name for m in n.body
                        if isinstance(m, ast.FunctionDef)}
               for n in tree.body if isinstance(n, ast.ClassDef)}
    functions = {n.name for n in tree.body
                 if isinstance(n, ast.FunctionDef)}
    return classes, functions


def check_test_refs(path: pathlib.Path) -> list:
    """``tests/…::Class::test`` references that name nothing real."""
    errors = []
    indexes: dict = {}
    for file_part, cls, fn in _TEST_REF.findall(path.read_text()):
        test_path = ROOT / file_part
        if not test_path.exists():
            errors.append(f"{path.name}: missing test file {file_part}")
            continue
        if file_part not in indexes:
            indexes[file_part] = _test_index(test_path)
        classes, functions = indexes[file_part]
        if cls.startswith("Test"):
            methods = classes.get(cls)
            if methods is None:
                errors.append(f"{path.name}: no class {cls} in {file_part}")
            elif fn and fn not in methods:
                errors.append(
                    f"{path.name}: no test {cls}::{fn} in {file_part}")
        elif cls not in functions:       # module-level test function
            errors.append(f"{path.name}: no test {cls} in {file_part}")
    return errors


def check_doctests(path: pathlib.Path) -> list:
    """Run every fenced ``python`` block that carries >>> examples."""
    errors = []
    parser = doctest.DocTestParser()
    for i, block in enumerate(_PY_FENCE.findall(path.read_text())):
        if ">>>" not in block:
            continue
        name = f"{path.name}[python-block-{i}]"
        test = parser.get_doctest(block, {}, name, str(path), 0)
        runner = doctest.DocTestRunner(verbose=False)
        report: list = []
        runner.run(test, out=report.append)
        if runner.failures:
            errors.append(f"{name}: {runner.failures} doctest failure(s)\n"
                          + "".join(report).rstrip())
    return errors


def _check_stats_module():
    """Load the stats gate (sibling file; importlib so both the script
    and the tests' file-path loading of THIS module find it)."""
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "check_stats", pathlib.Path(__file__).parent / "check_stats.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def run_checks() -> list:
    errors = []
    for path in doc_files():
        if not path.exists():
            errors.append(f"missing doc file: {path.relative_to(ROOT)}")
            continue
        errors += check_links(path)
        errors += check_test_refs(path)
        errors += check_doctests(path)
    errors += _check_stats_module().run_checks()
    return errors


def main() -> int:
    errors = run_checks()
    files = ", ".join(p.name for p in doc_files())
    for e in errors:
        print(f"[docs] FAIL: {e}")
    if errors:
        return 1
    print(f"[docs] ok ({files})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
