"""Pure-jnp oracle for the NH-hash / XOR-MAC kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mac

__all__ = ["nh_hash_ref", "block_macs_ref", "layer_mac_ref"]


def nh_hash_ref(payload_u32: jax.Array, key_u32: jax.Array) -> jax.Array:
    """(N, L) u32 payload + (L,) u32 key -> (N, 2) u32 (hi, lo)."""
    hi, lo = mac.nh_hash(payload_u32, key_u32)
    return jnp.stack([hi, lo], axis=-1)


def block_macs_ref(blocks_u8, binding, *, hash_key_u32, round_keys):
    return mac.block_macs(blocks_u8, binding, hash_key_u32=hash_key_u32,
                          round_keys=round_keys, engine="nh")


def layer_mac_ref(blocks_u8, binding, *, hash_key_u32, round_keys):
    return mac.layer_mac(blocks_u8, binding, hash_key_u32=hash_key_u32,
                         round_keys=round_keys, engine="nh")
