"""Wrappers: fused secure-read AND secure-write for flat buffers.

``secure_read_kernel*`` decrypts + hashes incoming ciphertext;
``secure_write_kernel*`` encrypts + hashes the fresh ciphertext (the
one-pass dirty-page reseal).  The ``_mixed`` variants gather each
optBlk's AES schedule, B-AES diversifiers and NH key row from a device
key bank, so one dispatch serves pages owned by different
(tenant, epoch) rows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mac
from repro.core.bytesutil import bytes_to_u32, u32_to_bytes
from repro.kernels.aes_ctr.ops import (keystream_bytes, keystream_bytes_multi,
                                       keystream_lanes, keystream_lanes_multi)
from repro.kernels.fused_crypt_mac.kernel import (fused_crypt_mac,
                                                  fused_crypt_mac_mixed,
                                                  fused_crypt_mac_write,
                                                  fused_crypt_mac_write_mixed)
from repro.kernels.otp_xor.ops import _div_lanes

__all__ = ["secure_read_kernel", "secure_read_kernel_mixed",
           "secure_write_kernel", "secure_write_kernel_mixed",
           "fused_crypt_mac", "fused_crypt_mac_mixed",
           "fused_crypt_mac_write", "fused_crypt_mac_write_mixed"]


def _secure_crossing(data_u8: jax.Array, binding: mac.Binding,
                     round_keys: jax.Array, counter_words: jax.Array,
                     hash_key_u32: jax.Array, kernel, *, block_bytes: int,
                     subbytes: str, interpret: bool | None):
    """Single-key crossing: one fused pass + AES MAC finalization.

    Read and write share every step — base keystream, diversifiers,
    binding words, NH-hash finalization pads — except the fused
    ``kernel`` body (hash the incoming vs. the outgoing bytes), so the
    orchestration lives once and the two directions cannot drift.
    """
    n_segments = block_bytes // 16
    if n_segments - 1 > 10:
        raise ValueError("kernel path supports narrow mode (<= 11 segments)")
    base = keystream_lanes(counter_words, round_keys, subbytes=subbytes,
                           interpret=interpret)
    data = bytes_to_u32(data_u8).reshape(-1, n_segments * 4)
    div = _div_lanes(round_keys, n_segments)
    bind_words = binding.words(data.shape[0])
    key = hash_key_u32[: data.shape[1] + 8]
    out_lanes, hashes = kernel(data, base, div, bind_words, key,
                               interpret=interpret)
    fin = mac.finalize_words(hashes[:, 0], hashes[:, 1], binding)
    pads = keystream_bytes(fin, round_keys, subbytes=subbytes,
                           interpret=interpret)
    out = u32_to_bytes(out_lanes.reshape(-1)).reshape(data_u8.shape)
    return out, pads[:, : mac.MAC_BYTES]


def _secure_crossing_mixed(data_u8: jax.Array, binding: mac.Binding,
                           bank_round_keys: jax.Array,
                           counter_words: jax.Array,
                           bank_hash_key: jax.Array, row_idx: jax.Array,
                           kernel, *, block_bytes: int, subbytes: str,
                           interpret: bool | None):
    """Mixed-key crossing: per-block bank-row gather + one fused pass."""
    n_segments = block_bytes // 16
    if n_segments - 1 > 10:
        raise ValueError("kernel path supports narrow mode (<= 11 segments)")
    rk_blocks = bank_round_keys[row_idx]                 # (N, 11, 16)
    base = keystream_lanes_multi(counter_words, rk_blocks,
                                 subbytes=subbytes, interpret=interpret)
    data = bytes_to_u32(data_u8).reshape(-1, n_segments * 4)
    # Diversifiers are a pure function of a row's schedule: build the
    # (K, S, 4) bank once, then gather rows per block.
    div_bank = jax.vmap(lambda rk: _div_lanes(rk, n_segments))(
        bank_round_keys)
    div = div_bank[row_idx]                              # (N, S, 4)
    bind_words = binding.words(data.shape[0])
    key = bank_hash_key[:, : data.shape[1] + 8].astype(jnp.uint32)[row_idx]
    out_lanes, hashes = kernel(data, base, div, bind_words, key,
                               interpret=interpret)
    fin = mac.finalize_words(hashes[:, 0], hashes[:, 1], binding)
    pads = keystream_bytes_multi(fin, rk_blocks, subbytes=subbytes,
                                 interpret=interpret)
    out = u32_to_bytes(out_lanes.reshape(-1)).reshape(data_u8.shape)
    return out, pads[:, : mac.MAC_BYTES]


def secure_read_kernel(ct_u8: jax.Array, binding: mac.Binding,
                       round_keys: jax.Array, counter_words: jax.Array,
                       hash_key_u32: jax.Array, *, block_bytes: int,
                       subbytes: str = "take",
                       interpret: bool | None = None):
    """Kernel-backed secure read: returns (plaintext_u8, block_macs_u8).

    One pass over the ciphertext performs both the B-AES decrypt and
    the NH compression; the AES finalization of the MACs runs on the
    tiny hash list.  Bit-identical to the unfused core path.
    """
    return _secure_crossing(ct_u8, binding, round_keys, counter_words,
                            hash_key_u32, fused_crypt_mac,
                            block_bytes=block_bytes, subbytes=subbytes,
                            interpret=interpret)


def secure_write_kernel(pt_u8: jax.Array, binding: mac.Binding,
                        round_keys: jax.Array, counter_words: jax.Array,
                        hash_key_u32: jax.Array, *, block_bytes: int,
                        subbytes: str = "take",
                        interpret: bool | None = None):
    """Kernel-backed secure write: returns (ciphertext_u8, block_macs_u8).

    One pass over the plaintext performs both the B-AES encrypt and the
    NH compression of the fresh ciphertext; the AES finalization runs
    on the tiny hash list.  Bit-identical to encrypting via the unfused
    core path and then MACing the result.
    """
    return _secure_crossing(pt_u8, binding, round_keys, counter_words,
                            hash_key_u32, fused_crypt_mac_write,
                            block_bytes=block_bytes, subbytes=subbytes,
                            interpret=interpret)


def secure_read_kernel_mixed(ct_u8: jax.Array, binding: mac.Binding,
                             bank_round_keys: jax.Array,
                             counter_words: jax.Array,
                             bank_hash_key: jax.Array, row_idx: jax.Array, *,
                             block_bytes: int, subbytes: str = "take",
                             interpret: bool | None = None):
    """Mixed-key fused secure read: per-BLOCK keys gathered from a bank.

    Args:
      bank_round_keys: (K, 11, 16) u8 — the device key bank's schedules
        (one row per retained (tenant, epoch)).
      bank_hash_key: (K, n_lanes) u32 NH key rows.
      row_idx: (N,) int32 bank row per optBlk (a page's row repeated
        over its blocks).

    Every block is decrypted and NH-hashed under its OWN bank row in
    one fused pass — the route that keeps MIXED-row decode ticks on the
    fused kernels instead of falling back to the vmapped per-page
    reference.  Bit-identical to that vmapped path.
    """
    return _secure_crossing_mixed(ct_u8, binding, bank_round_keys,
                                  counter_words, bank_hash_key, row_idx,
                                  fused_crypt_mac_mixed,
                                  block_bytes=block_bytes, subbytes=subbytes,
                                  interpret=interpret)


def secure_write_kernel_mixed(pt_u8: jax.Array, binding: mac.Binding,
                              bank_round_keys: jax.Array,
                              counter_words: jax.Array,
                              bank_hash_key: jax.Array, row_idx: jax.Array, *,
                              block_bytes: int, subbytes: str = "take",
                              interpret: bool | None = None):
    """Mixed-key fused secure write: per-BLOCK keys gathered from a bank.

    The write half of the mixed-key fused path: every block is
    encrypted and its fresh ciphertext NH-hashed under its OWN bank row
    in one fused pass — the route that keeps MIXED-row dirty-page
    reseals on the fused kernels instead of the vmapped per-page
    reference.  Bit-identical to that vmapped path.
    """
    return _secure_crossing_mixed(pt_u8, binding, bank_round_keys,
                                  counter_words, bank_hash_key, row_idx,
                                  fused_crypt_mac_write_mixed,
                                  block_bytes=block_bytes, subbytes=subbytes,
                                  interpret=interpret)
