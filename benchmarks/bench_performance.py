"""Paper Fig. 6: normalized performance per protection scheme."""

from __future__ import annotations

import statistics
import time

from repro.sim.dram import performance
from repro.sim.memprot import overlay_scheme
from repro.sim.npu_configs import NPUS
from repro.sim.scalesim import simulate_workload
from repro.sim.workloads import WORKLOADS

PAPER_SLOWDOWN = {
    ("server", "sgx64"): 0.2204, ("server", "mgx64"): 0.1093,
    ("server", "sgx512"): 0.0849, ("server", "mgx512"): 0.0428,
    ("server", "seda"): 0.01,
    ("edge", "sgx64"): 0.2110, ("edge", "mgx64"): 0.1095,
    ("edge", "sgx512"): 0.0584, ("edge", "mgx512"): 0.0290,
    ("edge", "seda"): 0.01,
}


def run() -> list:
    rows = []
    for npu_name, npu in NPUS.items():
        seda_slow = None
        mgx_slow = None
        for scheme in ("sgx64", "sgx512", "mgx64", "mgx512", "seda"):
            t0 = time.perf_counter()
            slows = []
            for w in WORKLOADS.values():
                tr = simulate_workload(w, npu)
                sec = overlay_scheme(tr, scheme, npu)
                slows.append(performance(tr, sec, npu).slowdown)
            dt = (time.perf_counter() - t0) * 1e6
            mean = statistics.mean(slows)
            if scheme == "seda":
                seda_slow = mean
            if scheme == "mgx64":
                mgx_slow = mean
            paper = PAPER_SLOWDOWN[(npu_name, scheme)]
            rows.append({
                "name": f"fig6_{npu_name}_{scheme}",
                "us_per_call": dt,
                "derived": (f"slowdown={mean:+.2%} paper<={paper:+.2%} "
                            f"norm_perf={1 / (1 + mean):.4f}"),
            })
        # The abstract's headline: SeDA reduces overhead by >12%.
        rows.append({
            "name": f"fig6_{npu_name}_seda_improvement_vs_mgx64",
            "us_per_call": 0.0,
            "derived": (f"improvement={mgx_slow - seda_slow:+.2%} "
                        f"paper={'12.26%' if npu_name == 'server' else '12.29%'}"),
        })
    return rows
