"""Public jit'd wrappers for the AES-CTR keystream kernel.

The ``*_multi`` variants take per-block (N, 11, 16) key schedules —
the primitive both mixed-key fused paths build on: the READ side
(:func:`repro.kernels.fused_crypt_mac.ops.secure_read_kernel_mixed`)
uses them for base OTPs and MAC finalization pads, and the WRITE side
(:func:`repro.kernels.fused_crypt_mac.ops.secure_write_kernel_mixed`)
for the dirty-page reseal's keystream + fresh-ciphertext MAC pads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.aes_ctr.kernel import (aes_ctr_keystream,
                                          aes_ctr_keystream_multi)

__all__ = ["keystream_lanes", "keystream_bytes", "keystream_lanes_multi",
           "keystream_bytes_multi"]


def keystream_lanes(counter_words: jax.Array, round_keys: jax.Array, *,
                    subbytes: str = "take",
                    interpret: bool | None = None) -> jax.Array:
    """OTPs as (N, 4) uint32 little-endian lanes."""
    return aes_ctr_keystream(counter_words, round_keys, subbytes=subbytes,
                             interpret=interpret)


def keystream_bytes(counter_words: jax.Array, round_keys: jax.Array, *,
                    subbytes: str = "take",
                    interpret: bool | None = None) -> jax.Array:
    """OTPs as (N, 16) uint8, matching :mod:`repro.core.ctr` layout."""
    lanes = keystream_lanes(counter_words, round_keys, subbytes=subbytes,
                            interpret=interpret)
    return jax.lax.bitcast_convert_type(lanes[..., None], jnp.uint8).reshape(
        lanes.shape[0], 16)


def keystream_lanes_multi(counter_words: jax.Array,
                          round_keys_per: jax.Array, *,
                          subbytes: str = "take",
                          interpret: bool | None = None) -> jax.Array:
    """Mixed-key OTPs: per-block (N, 11, 16) schedules -> (N, 4) u32."""
    return aes_ctr_keystream_multi(counter_words, round_keys_per,
                                   subbytes=subbytes, interpret=interpret)


def keystream_bytes_multi(counter_words: jax.Array,
                          round_keys_per: jax.Array, *,
                          subbytes: str = "take",
                          interpret: bool | None = None) -> jax.Array:
    """Mixed-key OTPs as (N, 16) uint8."""
    lanes = keystream_lanes_multi(counter_words, round_keys_per,
                                  subbytes=subbytes, interpret=interpret)
    return jax.lax.bitcast_convert_type(lanes[..., None], jnp.uint8).reshape(
        lanes.shape[0], 16)
