"""The docs/ subsystem is part of the contract, not decoration.

Runs the same checks as the CI docs job (``docs/check_docs.py``):
required files exist, markdown links resolve, every attack row in the
threat model names a real test, and the fenced doctest examples
execute.  A refactor that renames a test or module referenced by the
docs fails here, not in a reader's hands.
"""

import importlib.util
import pathlib

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", ROOT / "docs" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestDocs:
    def test_required_docs_exist(self):
        for name in ("architecture.md", "threat_model.md"):
            assert (ROOT / "docs" / name).exists(), name

    def test_links_test_refs_and_doctests(self):
        mod = _check_docs()
        assert mod.run_checks() == []

    def test_threat_model_covers_the_claimed_attacks(self):
        """Every attack class the repo claims to reject has at least
        one table ROW that both names the attack and cites a test —
        a per-class check, so dropping one row's reference cannot hide
        behind another row's."""
        mod = _check_docs()
        rows = [line for line in
                (ROOT / "docs" / "threat_model.md").read_text().splitlines()
                if line.lstrip().startswith("|")]
        for attack in ("tamper", "replay", "cross-tenant", "stale-epoch",
                       "cross-shard", "listener-bypass"):
            cited = [r for r in rows if attack in r.lower()
                     and mod._TEST_REF.search(r)]
            assert cited, f"no table row names a test for {attack!r}"

    def test_checker_catches_a_broken_test_ref(self, tmp_path):
        """The gate itself must not be vacuous: a doc naming a
        nonexistent test is reported."""
        mod = _check_docs()
        bad = tmp_path / "bad.md"
        bad.write_text("see `tests/test_serving_engine.py::TestTamper::"
                       "test_this_never_existed`")
        errors = mod.check_test_refs(bad)
        assert errors and "test_this_never_existed" in errors[0]
