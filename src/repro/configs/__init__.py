"""Architecture registry: the 10 assigned archs as selectable configs.

``get_arch(name)`` / ``ARCHS`` are the public entry points used by the
launcher (``--arch <id>``), the dry-run, and the smoke tests.
"""

from __future__ import annotations

from repro.configs.base import SHAPES, ArchDef, Shape  # noqa: F401
from repro.configs.deepseek_v3_671b import ARCH as _deepseek_v3
from repro.configs.granite_34b import ARCH as _granite
from repro.configs.jamba_v01_52b import ARCH as _jamba
from repro.configs.mamba2_780m import ARCH as _mamba2
from repro.configs.minitron_4b import ARCH as _minitron4
from repro.configs.minitron_8b import ARCH as _minitron8
from repro.configs.olmoe_1b_7b import ARCH as _olmoe
from repro.configs.pixtral_12b import ARCH as _pixtral
from repro.configs.seamless_m4t_large_v2 import ARCH as _seamless
from repro.configs.smollm_135m import ARCH as _smollm

ARCHS: dict[str, ArchDef] = {a.name: a for a in (
    _minitron4, _minitron8, _granite, _smollm, _mamba2,
    _pixtral, _seamless, _jamba, _olmoe, _deepseek_v3,
)}

# Optimizer-state dtype overrides: the largest configs keep Adam moments
# in bf16 so the 512-chip multi-pod training cell fits v5e HBM.
OPT_DTYPE_OVERRIDES = {
    "deepseek-v3-671b": "bfloat16",
    "jamba-v0.1-52b": "bfloat16",
    "granite-34b": "bfloat16",
}


def get_arch(name: str) -> ArchDef:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def cells(include_skips: bool = False):
    """All (arch, shape) dry-run cells, honoring the documented skips."""
    out = []
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            if arch.supports(shape):
                out.append((arch, shape))
            elif include_skips:
                out.append((arch, shape))
    return out
