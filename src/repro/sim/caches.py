"""Metadata caches (VN / MAC) — LRU, write-back, write-allocate (§IV-A)."""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

__all__ = ["LRUCache", "CacheStats"]


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    writebacks: int = 0

    @property
    def accesses(self) -> int:
        return self.hits + self.misses

    @property
    def miss_rate(self) -> float:
        return self.misses / self.accesses if self.accesses else 0.0


class LRUCache:
    """Line-granular LRU cache used for trace-mode metadata simulation."""

    def __init__(self, capacity_bytes: int, line_bytes: int = 64):
        self.capacity_lines = max(1, capacity_bytes // line_bytes)
        self.line_bytes = line_bytes
        self._lines: OrderedDict[int, bool] = OrderedDict()  # addr -> dirty
        self.stats = CacheStats()

    def access(self, byte_addr: int, *, write: bool = False) -> bool:
        """Touch the line containing ``byte_addr``; returns True on hit."""
        line = byte_addr // self.line_bytes
        if line in self._lines:
            self._lines.move_to_end(line)
            self._lines[line] = self._lines[line] or write
            self.stats.hits += 1
            return True
        self.stats.misses += 1
        if len(self._lines) >= self.capacity_lines:
            _, dirty = self._lines.popitem(last=False)
            if dirty:
                self.stats.writebacks += 1
        self._lines[line] = write
        return False

    def flush(self) -> int:
        """Write back all dirty lines; returns count."""
        dirty = sum(1 for d in self._lines.values() if d)
        self.stats.writebacks += dirty
        self._lines.clear()
        return dirty
