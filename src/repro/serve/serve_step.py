"""Serve-step builders: prefill and single-token decode per arch kind.

``serve_step`` is what the ``decode_*`` / ``long_*`` dry-run cells
lower: one new token against a KV cache of seq_len.  The secure variant
verifies the cache's layer MACs on read and re-MACs the updated cache
slice on write (SeDA's serving-side boundary: the KV/latent cache is
the tensor that crosses to untrusted memory during long decodes).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import encdec as ed
from repro.models import lm as lm_mod

__all__ = ["make_prefill_step", "make_decode_step", "greedy_sample"]


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]


def make_prefill_step(arch, cfg, max_len: int) -> Callable:
    if arch.kind == "encdec":
        def prefill(params, batch):
            return ed.decoder_prefill(cfg, params, batch, max_len)
        return prefill

    def prefill(params, batch):
        return lm_mod.lm_prefill(cfg, params, batch, max_len)
    return prefill


def make_decode_step(arch, cfg) -> Callable:
    """decode(params, tokens (B,1), caches) -> (logits, new caches)."""
    if arch.kind == "encdec":
        def decode(params, tokens, caches):
            return ed.decoder_decode(cfg, params, tokens, caches)
        return decode

    def decode(params, tokens, caches):
        return lm_mod.lm_decode(cfg, params, tokens, caches)
    return decode
