"""Pallas TPU kernel: fused B-AES diversify + XOR ("Crypt Engine", Fig. 3(a)).

The bandwidth-critical half of SeDA's bandwidth-aware encryption: given
one base OTP per wide block (from the AES kernel) and the per-segment
diversifiers (round keys), XOR the diversified pads into the data
stream.  Pure elementwise traffic — the kernel exists to keep this at
HBM roofline with explicit VMEM tiling instead of materializing the
(N, S, 16) pad tensor in HBM (which would add 2x write + read traffic).

Layout: data is viewed as (N, S*4) uint32 lanes (S = segments per wide
block).  For the paper's 512B wide blocks S*4 = 128 — one full TPU lane
register row, the natural tile width.

    HBM -> VMEM: data tile (TILE_N, S*4), base OTPs (TILE_N, 4),
                 diversifiers (S, 4)
    compute:     out[n, 4s+l] = data ^ base[n, l] ^ div[s, l]
    VMEM -> HBM: ciphertext tile (TILE_N, S*4)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv, default_interpret

__all__ = ["otp_xor"]


def _otp_xor_kernel(data_ref, base_ref, div_ref, out_ref):
    data = data_ref[...]                       # (T, S*4) u32
    base = base_ref[...]                       # (T, 4) u32
    div = div_ref[...]                         # (S, 4) u32
    t = data.shape[0]
    s = div.shape[0]
    d = data.reshape(t, s, 4)
    pads = base[:, None, :] ^ div[None, :, :]  # (T, S, 4)
    out_ref[...] = (d ^ pads).reshape(t, s * 4)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def otp_xor(data_lanes: jax.Array, base_otp_lanes: jax.Array,
            div_lanes: jax.Array, *, tile_n: int = 512,
            interpret: bool | None = None) -> jax.Array:
    """(N, S*4) u32 data, (N, 4) u32 base OTPs, (S, 4) u32 diversifiers."""
    if interpret is None:
        interpret = default_interpret()
    n, lanes = data_lanes.shape
    s = div_lanes.shape[0]
    assert lanes == 4 * s, (lanes, s)
    tile_n = min(tile_n, max(8, n))
    n_pad = cdiv(n, tile_n) * tile_n
    data_p = jnp.zeros((n_pad, lanes), jnp.uint32).at[:n].set(data_lanes)
    base_p = jnp.zeros((n_pad, 4), jnp.uint32).at[:n].set(base_otp_lanes)

    out = pl.pallas_call(
        _otp_xor_kernel,
        grid=(n_pad // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, lanes), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 4), lambda i: (i, 0)),
            pl.BlockSpec((s, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, lanes), jnp.uint32),
        interpret=interpret,
    )(data_p, base_p, div_lanes)
    return out[:n]
