"""AES-CTR mode with SeDA counter construction (paper Eq. 1/2, Fig. 2(a)).

The counter of a 128-bit segment concatenates the physical address (PA)
of the segment and the version number (VN) of the enclosing data block:

    counter = PA (64b) || VN (64b)

PA/VN are carried as pairs of uint32 words (JAX default x64-off).  The
counter block byte layout is big-endian per word:

    [pa_hi, pa_lo, vn_hi, vn_lo]  ->  16 bytes

``ctr_encrypt``/``ctr_decrypt`` implement the *traditional* (T-AES)
path: one AES invocation per 128-bit segment, counters advancing with
the segment PA.  The bandwidth-aware path (one AES invocation per wide
block) lives in :mod:`repro.core.baes`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import aes

__all__ = [
    "pack_counter_words",
    "counter_blocks",
    "ctr_keystream",
    "ctr_encrypt",
    "ctr_decrypt",
    "split_addr",
]


def split_addr(addr) -> tuple[jax.Array, jax.Array]:
    """Split a python int (or uint32 array) address into (hi, lo) words."""
    if isinstance(addr, (int,)):
        return (jnp.uint32((addr >> 32) & 0xFFFFFFFF), jnp.uint32(addr & 0xFFFFFFFF))
    addr = jnp.asarray(addr, dtype=jnp.uint32)
    return jnp.zeros_like(addr), addr


def pack_counter_words(pa_hi, pa_lo, vn_hi, vn_lo) -> jax.Array:
    """Pack four uint32 words into (..., 4) uint32 counter words."""
    return jnp.stack(
        jnp.broadcast_arrays(
            jnp.asarray(pa_hi, jnp.uint32),
            jnp.asarray(pa_lo, jnp.uint32),
            jnp.asarray(vn_hi, jnp.uint32),
            jnp.asarray(vn_lo, jnp.uint32),
        ),
        axis=-1,
    )


def counter_blocks(words: jax.Array) -> jax.Array:
    """(..., 4) uint32 counter words -> (..., 16) uint8 counter blocks.

    Each word is serialized big-endian so that incrementing ``pa_lo``
    increments the counter block like a big integer.
    """
    w = words.astype(jnp.uint32)
    shifts = jnp.asarray([24, 16, 8, 0], dtype=jnp.uint32)
    bytes_per_word = (w[..., :, None] >> shifts) & jnp.uint32(0xFF)
    return bytes_per_word.astype(jnp.uint8).reshape(words.shape[:-1] + (16,))


def ctr_keystream(round_keys: jax.Array, counter_words: jax.Array) -> jax.Array:
    """OTP = AES-CTR_{Ke}(PA || VN): (..., 4) u32 counters -> (..., 16) u8."""
    return aes.aes128_encrypt_block(counter_blocks(counter_words), round_keys)


def _segment_counters(n_segments: int, pa_hi, pa_lo, vn_hi, vn_lo) -> jax.Array:
    """Counters for consecutive 16B segments starting at (pa_hi, pa_lo)."""
    idx = jnp.arange(n_segments, dtype=jnp.uint32)
    lo = jnp.asarray(pa_lo, jnp.uint32) + idx
    carry = (lo < jnp.asarray(pa_lo, jnp.uint32)).astype(jnp.uint32)
    hi = jnp.asarray(pa_hi, jnp.uint32) + carry
    return pack_counter_words(hi, lo, jnp.broadcast_to(jnp.asarray(vn_hi, jnp.uint32), idx.shape),
                              jnp.broadcast_to(jnp.asarray(vn_lo, jnp.uint32), idx.shape))


def ctr_encrypt(plaintext: jax.Array, round_keys: jax.Array, pa_hi, pa_lo,
                vn_hi, vn_lo) -> jax.Array:
    """T-AES encryption: one AES call per 16B segment.

    ``plaintext`` is a flat uint8 buffer with ``len % 16 == 0``; the
    segment at byte offset ``16*i`` uses counter ``(PA + i) || VN``.
    """
    segs = plaintext.reshape(-1, 16)
    counters = _segment_counters(segs.shape[0], pa_hi, pa_lo, vn_hi, vn_lo)
    otp = ctr_keystream(round_keys, counters)
    return (segs ^ otp).reshape(plaintext.shape)


# CTR decryption is the same operation (Eq. 2).
ctr_decrypt = ctr_encrypt
