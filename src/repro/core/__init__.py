"""SeDA core: the paper's contribution as composable JAX modules.

- :mod:`repro.core.aes`       — FIPS-197 AES-128 + KeyExpansion
- :mod:`repro.core.ctr`       — AES-CTR with PA||VN counters (T-AES path)
- :mod:`repro.core.baes`      — bandwidth-aware encryption (B-AES, §III-B)
- :mod:`repro.core.mac`       — optBlk/layer/model MACs + XOR-MAC (§III-C)
- :mod:`repro.core.vn`        — MGX-style on-chip version numbers
- :mod:`repro.core.attacks`   — SECA / RePA reference attacks
- :mod:`repro.core.secure_memory` — protect/unprotect pytrees
- :mod:`repro.core.secure_exec`   — SecureExecutor step wrapper
"""

from repro.core import aes, attacks, baes, ctr, mac, multilevel, vn  # noqa: F401
from repro.core.secure_exec import SCHEMES, SecureExecutor  # noqa: F401
from repro.core.secure_memory import (  # noqa: F401
    RegionSpec,
    SecureKeys,
    SecureState,
    make_region_spec,
    protect,
    unprotect,
)
