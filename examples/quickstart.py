"""Quickstart: train a small LM with the SeDA secure boundary ON.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates in ~2 minutes on CPU:
  1. pick an assigned architecture (reduced config),
  2. train with params living ENCRYPTED+MAC'd between steps (scheme
     'seda'), integrity-verified on every step,
  3. save a SeDA-secured checkpoint, tamper with it, and watch the
     restore refuse the tampered bytes.
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.checkpoint.secure_ckpt import CheckpointError, load_checkpoint
from repro.core.secure_memory import SecureKeys
from repro.launch import train


def main() -> None:
    print("=== SeDA quickstart: secure training of smollm-135m (reduced) ===")
    with tempfile.TemporaryDirectory() as ckpt_dir:
        out = train.main([
            "--arch", "smollm-135m", "--smoke",
            "--steps", "40", "--global-batch", "8", "--seq-len", "64",
            "--lr", "2e-3", "--scheme", "seda",
            "--ckpt-dir", ckpt_dir, "--ckpt-every", "40", "--log-every", "10",
        ])
        print(f"trained {out['steps']} steps: loss "
              f"{out['first_loss']:.3f} -> {out['last_loss']:.3f}")

        # --- tamper with the checkpoint; restore must fail loudly --------
        step_dir = os.path.join(ckpt_dir, "step_00000040")
        leaf = os.path.join(step_dir, "leaf_00000.bin")
        raw = bytearray(open(leaf, "rb").read())
        raw[7] ^= 0xFF
        open(leaf, "wb").write(bytes(raw))

        keys = SecureKeys.derive(0)
        from repro.configs import get_arch
        from repro.models.layers import shape_structs
        from repro.models.lm import lm_specs
        cfg = get_arch("smollm-135m").make_smoke_config()
        template = shape_structs(lm_specs(cfg))
        try:
            load_checkpoint(step_dir, template, keys)
            raise SystemExit("BUG: tampered checkpoint was accepted!")
        except CheckpointError as e:
            print(f"tampered checkpoint rejected as expected: {e}")
    print("=== quickstart OK ===")


if __name__ == "__main__":
    main()
