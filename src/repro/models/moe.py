"""Mixture-of-Experts FFN (top-k routing, sort-based dispatch).

Dispatch uses the sort + capacity + batched-matmul formulation (the
standard "sparse matmul" MoE path in JAX): token-slots are sorted by
expert id, ranked within their expert segment, and scattered into an
(E, C, d) buffer that feeds one batched GEMM per projection.  This
avoids the (T, E, C) one-hot dispatch tensor, which is infeasible for
256-expert configs, and shards cleanly: the buffer is EP-sharded over
the 'experts' logical axis while token tensors stay batch-sharded (the
scatter/gather lower to all-to-alls under SPMD).

Supports a DeepSeek-style shared expert alongside the routed ones.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense, spec
from repro.models.partitioning import constrain

__all__ = ["MoEConfig", "moe_specs", "moe_ffn", "dense_ffn", "ffn_specs"]


class MoEConfig(NamedTuple):
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int               # per-expert hidden dim
    n_shared: int = 0       # shared-expert count (DeepSeek-V3: 1)
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    # Sharding of the (E, C, d) dispatch buffer (hillclimbed, see
    # EXPERIMENTS.md §Perf): 'free' lets SPMD propagation choose (4.7x
    # lower collective traffic than forcing EP); 'ep' = expert dim over
    # the model axis (the pre-hillclimb baseline); 'dp' = capacity dim
    # over the data axis (refuted: worse).
    dispatch: str = "free"


def ffn_specs(d_model: int, d_ff: int, dtype: str, gated: bool = True):
    """Dense (Swi)GLU FFN specs."""
    s = {
        "w_up": spec((d_model, d_ff), ("embed", "mlp"), dtype),
        "w_down": spec((d_ff, d_model), ("mlp", "embed"), dtype),
    }
    if gated:
        s["w_gate"] = spec((d_model, d_ff), ("embed", "mlp"), dtype)
    return s


def dense_ffn(params, x):
    """SwiGLU FFN: x (..., d) -> (..., d)."""
    up = dense(x, params["w_up"])
    if "w_gate" in params:
        up = jax.nn.silu(dense(x, params["w_gate"])) * up
    else:
        up = jax.nn.gelu(up)
    return dense(up, params["w_down"])


def moe_specs(cfg: MoEConfig, dtype: str):
    s = {
        "router": spec((cfg.d_model, cfg.n_experts), ("embed", "experts_r"),
                       "float32"),
        "w_gate": spec((cfg.n_experts, cfg.d_model, cfg.d_ff),
                       ("experts", "embed", "mlp"), dtype),
        "w_up": spec((cfg.n_experts, cfg.d_model, cfg.d_ff),
                     ("experts", "embed", "mlp"), dtype),
        "w_down": spec((cfg.n_experts, cfg.d_ff, cfg.d_model),
                       ("experts", "mlp", "embed"), dtype),
    }
    if cfg.n_shared:
        shared_ff = cfg.shared_d_ff or cfg.d_ff
        s["shared"] = ffn_specs(cfg.d_model, shared_ff * cfg.n_shared, dtype)
    return s


def moe_ffn(cfg: MoEConfig, params, x, *, capacity: int | None = None):
    """x: (T, d) -> (T, d) with auxiliary load-balance loss.

    Returns (y, aux_loss).
    """
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    if capacity is None:
        capacity = max(1, int(t * k / e * cfg.capacity_factor))

    router_logits = dense(x.astype(jnp.float32), params["router"])  # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch-style).
    me = probs.mean(axis=0)                               # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # --- sort-based dispatch ------------------------------------------------
    slot_expert = idx.reshape(-1)                         # (T*k,)
    slot_token = (jnp.arange(t * k, dtype=jnp.int32) // k)
    order = jnp.argsort(slot_expert)                      # stable
    sorted_e = slot_expert[order]
    sorted_tok = slot_token[order]
    # Rank within the expert segment.
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(t * k, dtype=jnp.int32) - seg_start.astype(jnp.int32)
    keep = rank < capacity                                # overflow drops
    dest = sorted_e.astype(jnp.int32) * capacity + jnp.minimum(rank, capacity - 1)

    gathered = x[sorted_tok] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((e * capacity, d), x.dtype)
    buf = buf.at[dest].add(jnp.where(keep[:, None], gathered, 0))
    buf = buf.reshape(e, capacity, d)
    if cfg.dispatch == "ep":
        buf = constrain(buf, "experts", None, None)
    elif cfg.dispatch == "dp":
        buf = constrain(buf, None, "batch", None)
    # 'free': leave the buffer sharding to SPMD propagation.

    # --- expert computation (batched GEMMs over the expert dim) ------------
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"],
                      preferred_element_type=jnp.float32).astype(x.dtype)
    h = jax.nn.silu(gate) * up
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"],
                     preferred_element_type=jnp.float32).astype(x.dtype)

    # --- combine -------------------------------------------------------------
    slot_out = out.reshape(e * capacity, d)[dest]
    slot_out = jnp.where(keep[:, None], slot_out, 0)
    # Un-sort and weight by gates.
    unsorted = jnp.zeros((t * k, d), x.dtype).at[order].set(slot_out)
    y = (unsorted.reshape(t, k, d)
         * gates[..., None].astype(x.dtype)).sum(axis=1)

    if cfg.n_shared:
        y = y + dense_ffn(params["shared"], x)
    return y, aux
