"""The paper's 13 DNN benchmark workloads (§IV-A) as layer tables.

Layer dimensions are reconstructed from the public SCALE-Sim topology
set (the simulator the paper uses) and the original architectures.
Every layer is normalized to the systolic GEMM view:

    conv:  M = P*Q (output pixels), K = R*S*C, N = num_filters
    gemm:  (M, K, N) directly

which is exactly how SCALE-Sim maps conv onto the array.  DNN tiling
metadata (ifmap row bytes, halo overlap) is derived from the conv
geometry for the optBlk search.
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["Layer", "Workload", "WORKLOADS", "conv", "gemm"]


@dataclass(frozen=True)
class Layer:
    name: str
    m: int                  # output rows of the GEMM view
    k: int                  # contraction dim
    n: int                  # output cols (filters)
    kind: str = "conv"      # conv | dwconv | gemm | embed
    # conv geometry for tiling/halo analysis (0 when kind == gemm):
    h: int = 0              # input height
    w: int = 0              # input width
    c: int = 0              # input channels
    r: int = 0              # filter height
    s: int = 0              # filter width
    stride: int = 1

    @property
    def ifmap_bytes(self) -> int:
        return self.m * self.k

    @property
    def filter_bytes(self) -> int:
        return self.k * self.n

    @property
    def ofmap_bytes(self) -> int:
        return self.m * self.n

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n

    @property
    def has_halo(self) -> bool:
        """Tile halo exists when the conv window overlaps (R or S > stride)."""
        return self.kind in ("conv", "dwconv") and max(self.r, self.s) > self.stride


@dataclass(frozen=True)
class Workload:
    name: str
    layers: tuple

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @property
    def total_bytes(self) -> int:
        return sum(l.ifmap_bytes + l.filter_bytes + l.ofmap_bytes
                   for l in self.layers)


def conv(name, h, w, c, k_filters, r, s, stride=1, pad=None) -> Layer:
    if pad is None:
        pad = r // 2
    p = (h + 2 * pad - r) // stride + 1
    q = (w + 2 * pad - s) // stride + 1
    return Layer(name, m=p * q, k=r * s * c, n=k_filters, kind="conv",
                 h=h, w=w, c=c, r=r, s=s, stride=stride)


def dwconv(name, h, w, c, r, s, stride=1) -> Layer:
    pad = r // 2
    p = (h + 2 * pad - r) // stride + 1
    q = (w + 2 * pad - s) // stride + 1
    # Depthwise: each channel convolved independently; GEMM view per
    # channel batched — model as M=P*Q, K=R*S, N=C (utilization-poor).
    return Layer(name, m=p * q, k=r * s, n=c, kind="dwconv",
                 h=h, w=w, c=c, r=r, s=s, stride=stride)


def gemm(name, m, k, n) -> Layer:
    return Layer(name, m=m, k=k, n=n, kind="gemm")


def _lenet() -> Workload:
    return Workload("lenet", (
        conv("c1", 28, 28, 1, 6, 5, 5, pad=2),
        conv("c3", 14, 14, 6, 16, 5, 5, pad=0),
        gemm("f5", 1, 400, 120),
        gemm("f6", 1, 120, 84),
        gemm("f7", 1, 84, 10),
    ))


def _alexnet() -> Workload:
    return Workload("alexnet", (
        conv("c1", 227, 227, 3, 96, 11, 11, stride=4, pad=0),
        conv("c2", 27, 27, 96, 256, 5, 5),
        conv("c3", 13, 13, 256, 384, 3, 3),
        conv("c4", 13, 13, 384, 384, 3, 3),
        conv("c5", 13, 13, 384, 256, 3, 3),
        gemm("f6", 1, 9216, 4096),
        gemm("f7", 1, 4096, 4096),
        gemm("f8", 1, 4096, 1000),
    ))


def _mobilenet() -> Workload:
    layers = [conv("c0", 224, 224, 3, 32, 3, 3, stride=2)]
    cfg = [(112, 32, 64, 1), (112, 64, 128, 2), (56, 128, 128, 1),
           (56, 128, 256, 2), (28, 256, 256, 1), (28, 256, 512, 2),
           (14, 512, 512, 1), (14, 512, 512, 1), (14, 512, 512, 1),
           (14, 512, 512, 1), (14, 512, 512, 1), (14, 512, 1024, 2),
           (7, 1024, 1024, 1)]
    for i, (hw, cin, cout, stride) in enumerate(cfg):
        layers.append(dwconv(f"dw{i}", hw, hw, cin, 3, 3, stride))
        out_hw = hw // stride
        layers.append(conv(f"pw{i}", out_hw, out_hw, cin, cout, 1, 1, pad=0))
    layers.append(gemm("fc", 1, 1024, 1000))
    return Workload("mobilenet", tuple(layers))


def _resnet18() -> Workload:
    layers = [conv("c1", 224, 224, 3, 64, 7, 7, stride=2)]
    stages = [(56, 64, 64, 1), (56, 64, 64, 1),
              (56, 64, 128, 2), (28, 128, 128, 1),
              (28, 128, 256, 2), (14, 256, 256, 1),
              (14, 256, 512, 2), (7, 512, 512, 1)]
    for i, (hw, cin, cout, stride) in enumerate(stages):
        layers.append(conv(f"b{i}a", hw, hw, cin, cout, 3, 3, stride=stride))
        out_hw = hw // stride
        layers.append(conv(f"b{i}b", out_hw, out_hw, cout, cout, 3, 3))
    layers.append(gemm("fc", 1, 512, 1000))
    return Workload("resnet18", tuple(layers))


def _googlenet() -> Workload:
    # Inception-v1 main trunk + representative inception branches.
    layers = [
        conv("c1", 224, 224, 3, 64, 7, 7, stride=2),
        conv("c2r", 56, 56, 64, 64, 1, 1, pad=0),
        conv("c2", 56, 56, 64, 192, 3, 3),
    ]
    # (hw, cin, 1x1, 3x3red, 3x3, 5x5red, 5x5, pool_proj)
    inception = [
        (28, 192, 64, 96, 128, 16, 32, 32),
        (28, 256, 128, 128, 192, 32, 96, 64),
        (14, 480, 192, 96, 208, 16, 48, 64),
        (14, 512, 160, 112, 224, 24, 64, 64),
        (14, 512, 128, 128, 256, 24, 64, 64),
        (14, 512, 112, 144, 288, 32, 64, 64),
        (14, 528, 256, 160, 320, 32, 128, 128),
        (7, 832, 256, 160, 320, 32, 128, 128),
        (7, 832, 384, 192, 384, 48, 128, 128),
    ]
    for i, (hw, cin, c1, c3r, c3, c5r, c5, pp) in enumerate(inception):
        layers += [
            conv(f"i{i}_1x1", hw, hw, cin, c1, 1, 1, pad=0),
            conv(f"i{i}_3r", hw, hw, cin, c3r, 1, 1, pad=0),
            conv(f"i{i}_3x3", hw, hw, c3r, c3, 3, 3),
            conv(f"i{i}_5r", hw, hw, cin, c5r, 1, 1, pad=0),
            conv(f"i{i}_5x5", hw, hw, c5r, c5, 5, 5),
            conv(f"i{i}_pp", hw, hw, cin, pp, 1, 1, pad=0),
        ]
    layers.append(gemm("fc", 1, 1024, 1000))
    return Workload("googlenet", tuple(layers))


def _dlrm() -> Workload:
    # MLPerf DLRM: bottom MLP 13-512-256-64, top MLP 512-256-1 (batch 128)
    # + embedding gathers (memory-bound reads modeled as embed "layers").
    b = 128
    return Workload("dlrm", (
        gemm("bot0", b, 13, 512),
        gemm("bot1", b, 512, 256),
        gemm("bot2", b, 256, 64),
        Layer("embed", m=b * 26, k=1, n=64, kind="embed"),
        gemm("top0", b, 479, 512),
        gemm("top1", b, 512, 256),
        gemm("top2", b, 256, 1),
    ))


def _alphagozero() -> Workload:
    layers = [conv("c_in", 19, 19, 17, 256, 3, 3)]
    for i in range(19):  # 19 residual blocks x 2 convs
        layers.append(conv(f"r{i}a", 19, 19, 256, 256, 3, 3))
        layers.append(conv(f"r{i}b", 19, 19, 256, 256, 3, 3))
    layers += [conv("policy", 19, 19, 256, 2, 1, 1, pad=0),
               gemm("policy_fc", 1, 722, 362),
               conv("value", 19, 19, 256, 1, 1, 1, pad=0),
               gemm("value_fc", 1, 361, 256)]
    return Workload("alphagozero", tuple(layers))


def _ds2() -> Workload:
    # DeepSpeech2: 2 conv frontend + 5 bidirectional GRU (as GEMMs) + fc.
    t = 300  # time steps
    layers = [
        conv("c1", 161, t, 1, 32, 41, 11, stride=2),
        conv("c2", 81, t // 2, 32, 32, 21, 11, stride=2),
    ]
    h = 1760
    for i in range(5):
        in_dim = 41 * 32 * 2 if i == 0 else h
        layers.append(gemm(f"gru{i}_x", t // 4, in_dim, 3 * h))
        layers.append(gemm(f"gru{i}_h", t // 4, h, 3 * h))
    layers.append(gemm("fc", t // 4, h, 29))
    return Workload("ds2", tuple(layers))


def _fasterrcnn() -> Workload:
    # VGG16 backbone @600x600 + RPN + detection head.
    layers = []
    vgg = [(600, 3, 64), (600, 64, 64), (300, 64, 128), (300, 128, 128),
           (150, 128, 256), (150, 256, 256), (150, 256, 256),
           (75, 256, 512), (75, 512, 512), (75, 512, 512),
           (37, 512, 512), (37, 512, 512), (37, 512, 512)]
    for i, (hw, cin, cout) in enumerate(vgg):
        layers.append(conv(f"v{i}", hw, hw, cin, cout, 3, 3))
    layers += [
        conv("rpn", 37, 37, 512, 512, 3, 3),
        conv("rpn_cls", 37, 37, 512, 18, 1, 1, pad=0),
        conv("rpn_box", 37, 37, 512, 36, 1, 1, pad=0),
        gemm("head_fc6", 300, 25088, 4096),
        gemm("head_fc7", 300, 4096, 4096),
    ]
    return Workload("fasterrcnn", tuple(layers))


def _ncf() -> Workload:
    b = 256
    return Workload("ncf", (
        Layer("embed", m=b * 2, k=1, n=64, kind="embed"),
        gemm("mlp0", b, 128, 256),
        gemm("mlp1", b, 256, 128),
        gemm("mlp2", b, 128, 64),
        gemm("out", b, 128, 1),
    ))


def _sentimental() -> Workload:
    # seqCNN for sentiment: embedding + 1D convs + fc.
    seq, emb = 400, 128
    return Workload("sentimental", (
        Layer("embed", m=seq, k=1, n=emb, kind="embed"),
        conv("conv3", seq, 1, emb, 128, 3, 1, pad=1),
        conv("conv4", seq, 1, emb, 128, 4, 1, pad=1),
        conv("conv5", seq, 1, emb, 128, 5, 1, pad=2),
        gemm("fc", 1, 384, 2),
    ))


def _transformer_fwd() -> Workload:
    # Transformer-base forward: 6 layers, d=512, ffn=2048, seq=128.
    seq, d, ffn, heads = 128, 512, 2048, 8
    layers = []
    for i in range(6):
        layers += [
            gemm(f"l{i}_qkv", seq, d, 3 * d),
            gemm(f"l{i}_scores", heads * seq, d // heads, seq),
            gemm(f"l{i}_ctx", heads * seq, seq, d // heads),
            gemm(f"l{i}_proj", seq, d, d),
            gemm(f"l{i}_ff1", seq, d, ffn),
            gemm(f"l{i}_ff2", seq, ffn, d),
        ]
    return Workload("transformer_fwd", tuple(layers))


def _yolo_tiny() -> Workload:
    layers = []
    cfg = [(416, 3, 16), (208, 16, 32), (104, 32, 64), (52, 64, 128),
           (26, 128, 256), (13, 256, 512), (13, 512, 1024), (13, 1024, 256)]
    for i, (hw, cin, cout) in enumerate(cfg):
        layers.append(conv(f"c{i}", hw, hw, cin, cout, 3, 3))
    layers.append(conv("head", 13, 13, 256, 255, 1, 1, pad=0))
    return Workload("yolo_tiny", tuple(layers))


WORKLOADS = {w.name: w for w in (
    _lenet(), _alexnet(), _mobilenet(), _resnet18(), _googlenet(), _dlrm(),
    _alphagozero(), _ds2(), _fasterrcnn(), _ncf(), _sentimental(),
    _transformer_fwd(), _yolo_tiny(),
)}
