"""Tenant registry: identities, quotas, sessions, and the key bank.

The registry is the control plane of the tenancy subsystem:

* **registration** assigns each tenant a dense index, a scheduling
  weight, and a page quota (the hard cap on its resident KV pages);
* **session handles** are the capability requests must carry into
  :meth:`repro.serve.engine.SecureServingEngine.submit` — an opaque
  token bound to a tenant, revocable without touching key material;
* the **key bank** is the device-resident view of every retained
  (tenant, epoch) data-plane key set.  The jitted decode step gathers
  per-page keys from the bank by row index, so one traced computation
  serves pages of many tenants and epochs at once;
* **rotation** bumps a tenant's epoch: the new epoch's keys land in
  the bank row of the epoch leaving the retained window, the dropped
  epoch's host-side key material is destroyed, and pages still
  encrypted under retained older epochs keep verifying until their
  next dirty write re-encrypts them (lazy rotation).

Bank row layout: ``row(tenant, epoch) = tenant.index * retain +
epoch % retain`` — with the default ``retain=2`` each tenant owns two
rows that current/previous epochs ping-pong between.  One extra row
per tenant sits after the epoch block: ``cache_row(tenant) =
max_tenants * retain + tenant.index`` holds the tenant's
epoch-independent prefix-cache keys (installed once at registration,
untouched by rotation), so shared-prefix pages keep verifying across
``rotate()``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.tenancy.keys import KeyHierarchy

__all__ = ["KeyBank", "SessionHandle", "Tenant", "TenantRegistry"]


class KeyBank(NamedTuple):
    """Stacked per-row data-plane key material (device arrays).

    Rows indexed by :meth:`TenantRegistry.key_row`; unregistered rows
    are zero (any page claiming them fails its MAC gate).
    """

    key: jnp.ndarray         # (K, 16) u8 cipher keys
    round_keys: jnp.ndarray  # (K, 11, 16) u8 schedules
    hash_key: jnp.ndarray    # (K, n_lanes) u32 NH lanes
    salt: jnp.ndarray        # (K,) u32 CTR-counter salts


class SessionHandle(NamedTuple):
    """Capability a request carries: who it is + a revocable token."""

    tenant_id: str
    index: int
    token: int


@dataclasses.dataclass
class Tenant:
    tenant_id: str
    index: int
    weight: float
    page_quota: int
    keyset: "object"         # tenancy.keys.TenantKeySet

    @property
    def current_epoch(self) -> int:
        return self.keyset.current_epoch


class TenantRegistry:
    """Control plane over a :class:`~repro.tenancy.keys.KeyHierarchy`."""

    def __init__(self, hierarchy: Optional[KeyHierarchy] = None, *,
                 max_tenants: int = 8, retain: int = 2,
                 default_quota: Optional[int] = None):
        if retain < 2:
            raise ValueError("retain < 2 would drop the previous epoch key "
                             "lazy rotation still needs for reads")
        self.hierarchy = hierarchy or KeyHierarchy(0)
        self.max_tenants = max_tenants
        self.retain = retain
        self.default_quota = default_quota
        self.tenants: dict[str, Tenant] = {}
        self._by_index: list[Tenant] = []
        self._sessions: dict[int, str] = {}
        self._next_token = 0
        self._rotation_hooks: list = []
        self._pre_rotation_hooks: list = []
        self._bank_replicas: dict = {}      # device -> KeyBank copy
        k = max_tenants * (retain + 1)   # epoch rows + one cache row each
        lanes = self.hierarchy.nh_lanes
        self._bank = KeyBank(
            key=jnp.zeros((k, 16), jnp.uint8),
            round_keys=jnp.zeros((k, 11, 16), jnp.uint8),
            hash_key=jnp.zeros((k, lanes), jnp.uint32),
            salt=jnp.zeros((k,), jnp.uint32))

    # -- registration / sessions --------------------------------------------

    def register(self, tenant_id: str, *, weight: float = 1.0,
                 page_quota: Optional[int] = None) -> Tenant:
        if tenant_id in self.tenants:
            raise ValueError(f"tenant {tenant_id!r} already registered")
        if len(self._by_index) >= self.max_tenants:
            raise ValueError(f"registry full ({self.max_tenants} tenants)")
        if weight <= 0:
            raise ValueError("tenant weight must be positive")
        quota = page_quota if page_quota is not None else self.default_quota
        tenant = Tenant(tenant_id=tenant_id, index=len(self._by_index),
                        weight=weight,
                        page_quota=quota if quota is not None else 1 << 30,
                        keyset=self.hierarchy.derive_tenant(tenant_id))
        self.tenants[tenant_id] = tenant
        self._by_index.append(tenant)
        self._install_epoch(tenant, tenant.current_epoch)
        self._install_cache_row(tenant)
        return tenant

    def open_session(self, tenant_id: str) -> SessionHandle:
        tenant = self.tenants[tenant_id]
        token = self._next_token
        self._next_token += 1
        self._sessions[token] = tenant_id
        return SessionHandle(tenant_id, tenant.index, token)

    def revoke(self, handle: SessionHandle) -> None:
        self._sessions.pop(handle.token, None)

    def validate(self, handle: SessionHandle) -> Tenant:
        if self._sessions.get(handle.token) != handle.tenant_id:
            raise PermissionError(
                f"invalid or revoked session for tenant {handle.tenant_id!r}")
        tenant = self.tenants[handle.tenant_id]
        if tenant.index != handle.index:
            raise PermissionError("session handle/tenant index mismatch")
        return tenant

    def by_index(self, index: int) -> Tenant:
        return self._by_index[index]

    @property
    def n_tenants(self) -> int:
        return len(self._by_index)

    # -- key bank / rotation -------------------------------------------------

    @property
    def bank(self) -> KeyBank:
        return self._bank

    def bank_for(self, device=None) -> KeyBank:
        """Device-resident replica of the key bank.

        Sharded serving runs one engine per accelerator; each shard's
        jitted step needs the bank *on its own device* (committed
        arrays from different devices cannot meet in one computation).
        Replicas are cached per device and invalidated whenever the
        bank changes (registration / rotation), so a rotation fans the
        new row out to every shard on its next tick.
        """
        if device is None:
            return self._bank
        replica = self._bank_replicas.get(device)
        if replica is None:
            import jax
            replica = KeyBank(*(jax.device_put(a, device)
                                for a in self._bank))
            self._bank_replicas[device] = replica
        return replica

    def key_row(self, index: int, epoch: int) -> int:
        """Bank row for (tenant index, epoch); KeyError outside retention."""
        tenant = self._by_index[index]
        if not (tenant.current_epoch - self.retain < epoch
                <= tenant.current_epoch):
            raise KeyError(
                f"tenant {tenant.tenant_id!r}: epoch {epoch} outside the "
                f"retained window (current {tenant.current_epoch}, "
                f"retain {self.retain})")
        return index * self.retain + epoch % self.retain

    def cache_row(self, index: int) -> int:
        """Bank row holding ``index``'s epoch-independent cache keys."""
        if not (0 <= index < len(self._by_index)):
            raise KeyError(f"tenant index {index} not registered")
        return self.max_tenants * self.retain + index

    def cache_keys_for(self, index: int):
        """Host-side ``SecureKeys`` for a tenant's prefix-cache binding."""
        return self._by_index[index].keyset.cache_keys()

    def attach_rotation_hook(self, hook, *, pre: bool = False) -> None:
        """Register ``hook(tenant, new_epoch)`` to run around rotations.

        Every serving engine built on this registry attaches hooks so
        that a rotation — no matter which engine (or operator) triggers
        it — lets *all* engines react.  ``pre=True`` hooks run BEFORE
        any key material moves: the epoch about to leave the retained
        window is still in the bank, so engines can eagerly reseal its
        resident pages to a surviving epoch (no preemption, no KV
        recompute).  Post hooks run after the new keys are installed.
        The registry holds a strong reference to each hook, so its
        lifetime bounds the engines'.
        """
        (self._pre_rotation_hooks if pre else self._rotation_hooks).append(
            hook)

    def rotate(self, tenant_id: str) -> int:
        """Bump ``tenant_id``'s epoch (live rotation).

        Pre-rotation hooks run first, while the epoch about to fall out
        of the retained window still has its keys in the bank (eager
        reseal happens there).  Then the new epoch's keys overwrite the
        bank row of the dropped epoch, whose host-side material is
        destroyed.  Pages written under the *previous* epoch keep
        verifying (its keys are retained) until their next dirty write
        re-encrypts them under the new epoch.  Post-rotation hooks run
        last, so every engine sharing this registry reacts.
        """
        tenant = self.tenants[tenant_id]
        new_epoch = tenant.current_epoch + 1
        for hook in self._pre_rotation_hooks:
            hook(tenant, new_epoch)
        if tenant.keyset.rotate() != new_epoch:
            raise RuntimeError("keyset rotation desynced from the epoch "
                               "announced to pre-rotation hooks")
        tenant.keyset.drop_before(new_epoch - self.retain + 1)
        self._install_epoch(tenant, new_epoch)
        for hook in self._rotation_hooks:
            hook(tenant, new_epoch)
        return new_epoch

    def keys_for(self, index: int, epoch: int):
        """Host-side ``SecureKeys`` for (tenant index, epoch)."""
        return self._by_index[index].keyset.epoch_keys(epoch)

    def _install_epoch(self, tenant: Tenant, epoch: int) -> None:
        row = self.key_row(tenant.index, epoch)
        keys = tenant.keyset.epoch_keys(epoch)
        salt = tenant.keyset.epoch_salt(epoch)
        self._bank = KeyBank(
            key=self._bank.key.at[row].set(keys.key),
            round_keys=self._bank.round_keys.at[row].set(keys.round_keys),
            hash_key=self._bank.hash_key.at[row].set(
                keys.hash_key[: self._bank.hash_key.shape[1]]),
            salt=self._bank.salt.at[row].set(np.uint32(salt)))
        self._bank_replicas.clear()         # shard replicas re-fan-out lazily

    def _install_cache_row(self, tenant: Tenant) -> None:
        row = self.cache_row(tenant.index)
        keys = tenant.keyset.cache_keys()
        salt = tenant.keyset.cache_salt()
        self._bank = KeyBank(
            key=self._bank.key.at[row].set(keys.key),
            round_keys=self._bank.round_keys.at[row].set(keys.round_keys),
            hash_key=self._bank.hash_key.at[row].set(
                keys.hash_key[: self._bank.hash_key.shape[1]]),
            salt=self._bank.salt.at[row].set(np.uint32(salt)))
        self._bank_replicas.clear()
