"""Shared fixtures.  NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see the real 1-device CPU; only launch/dryrun.py forces
512 placeholder devices (and only in its own process)."""

import jax
import numpy as np
import pytest

from repro.core.secure_memory import SecureKeys


@pytest.fixture(scope="session")
def keys() -> SecureKeys:
    return SecureKeys.derive(1234)


@pytest.fixture()
def rng() -> np.random.Generator:
    return np.random.default_rng(0)
