"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax
initialization; tests see the default 1-device CPU).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "dp_axes", "mesh_axis_size"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires >= prod(shape) host devices)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def dp_axes(mesh) -> tuple:
    """The data-parallel mesh axes: ('pod', 'data') when a pod axis exists."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def mesh_axis_size(mesh, axis) -> int:
    """Size of a mesh axis or tuple of axes (product)."""
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]
