"""Multi-tenant secure serving: per-tenant key domains on one engine.

    PYTHONPATH=src python examples/multi_tenant_serving.py

Three tenants share one continuous-batching engine and one paged KV
pool, but never one cryptographic domain:

* each tenant's KV pages are encrypted + MACed under keys from its own
  subtree of the hierarchical KDF (root -> tenant master -> purpose
  -split enc/MAC/VN keys -> epoch keys);
* the RePA binding carries (tenant, epoch), so relocating a page
  across tenants fails its MAC gate — demonstrated below by pointing
  one tenant's slot at another tenant's pages;
* admission is weighted-fair (tenant weights 2:1:1) and quota-gated;
* mid-flight ``rotate()`` bumps one tenant's key epoch live: old pages
  keep verifying under the retained previous-epoch key and re-encrypt
  lazily on their next dirty write — decode output is unchanged.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.serve.engine import IntegrityError, SecureServingEngine
from repro.tenancy import KeyHierarchy, TenantRegistry


def make_engine(arch, cfg, params, registry, **kw):
    return SecureServingEngine(arch, cfg, params, scheme="seda",
                               max_slots=3, page_tokens=4, pages_per_slot=6,
                               n_pages=14, registry=registry, **kw)


def main() -> None:
    arch = get_arch("minitron-4b")
    cfg = arch.make_smoke_config()
    print(f"=== multi-tenant secure serving: {cfg.name} ===")
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))

    registry = TenantRegistry(KeyHierarchy(42), max_tenants=4)
    registry.register("alice", weight=2.0, page_quota=8)
    registry.register("bob", weight=1.0, page_quota=6)
    registry.register("carol", weight=1.0, page_quota=6)
    sessions = {t: registry.open_session(t) for t in registry.tenants}
    print(f"registered {registry.n_tenants} tenants "
          f"(weights 2:1:1, quotas 8/6/6 pages), "
          f"key bank: {registry.bank.key.shape[0]} rows "
          f"({registry.retain} retained epochs each)")

    eng = make_engine(arch, cfg, params, registry)
    rng = np.random.default_rng(7)
    rids = {}
    for tenant_id, n in zip(("alice", "bob", "carol"), (6, 9, 12)):
        prompt = list(map(int, rng.integers(1, cfg.vocab, n)))
        rids[tenant_id] = eng.submit(prompt=prompt, max_new_tokens=8,
                                     session=sessions[tenant_id])

    # Rotate alice's keys after a few ticks — live, mid-decode.
    for _ in range(3):
        eng.step()
    new_epoch = eng.rotate("alice")
    print(f"rotated alice's keys mid-decode -> epoch {new_epoch} "
          f"(old pages verify under the retained epoch, re-encrypt on "
          f"next dirty write)")
    done = eng.run()
    for tenant_id, rid in rids.items():
        print(f"  {tenant_id:>6}: generated={done[rid].generated}")
    print(f"engine: {eng.stats['decode_steps']} decode steps, "
          f"{eng.stats['preemptions']} preemptions, "
          f"{eng.stats['rotations']} rotations, "
          f"prefill compiled {eng.stats['prefill_compiles']}x "
          f"(length-bucketed), "
          f"deferred pool MAC {'OK' if eng.deferred_check() else 'FAIL'}")
    if done.latency:
        print(f"latency: ttft p50={done.latency['p50_ttft_ticks']:.1f} "
              f"p95={done.latency['p95_ttft_ticks']:.1f} ticks")
    assert eng.deferred_check()

    # --- cross-tenant isolation: point bob's slot at carol's pages ------
    # (same key epoch on both sides, so rejection comes from the MAC
    # gate: carol's pages carry carol's keys + (tenant, epoch) binding)
    eng2 = make_engine(arch, cfg, params, registry)
    rc = eng2.submit(prompt=list(map(int, rng.integers(1, cfg.vocab, 6))),
                     max_new_tokens=8, session=sessions["carol"])
    rb = eng2.submit(prompt=list(map(int, rng.integers(1, cfg.vocab, 6))),
                     max_new_tokens=8, session=sessions["bob"])
    eng2.step()
    slot_c = next(s for s in eng2.slots if s and s.req.rid == rc)
    slot_b = next(s for s in eng2.slots if s and s.req.rid == rb)
    slot_b.pages, slot_b.page_epochs = (list(slot_c.pages),
                                        list(slot_c.page_epochs))
    try:
        eng2.step()
        raise AssertionError("cross-tenant page read was NOT rejected")
    except IntegrityError as e:
        print(f"cross-tenant page read rejected as designed: {e}")
    print("=== multi_tenant_serving OK ===")


if __name__ == "__main__":
    main()
