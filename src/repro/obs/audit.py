"""Append-only, SHA-256 hash-chained security audit log.

The paper-side schemes make *data* tampering detectable; this module
is the software analogue for the *event record*: every
security-relevant action in the serving stack (integrity verdicts and
failures, key rotations, eager reseals, secure migrations, prefix
cache inserts / cross-tenant shares, copy-on-write privatizations) is
appended as a record whose hash covers both its own canonical JSON
payload and the previous record's hash.  Truncating, reordering,
editing, or injecting records therefore breaks
:meth:`AuditLog.verify_chain` — tampering with the log is itself
detectable, in the GuardNN/SEALing minimal-trust-verification sense.

Records are plain dicts (JSON-able by construction); the chain hash is
computed over the canonical serialization (sorted keys, no
whitespace), so a log round-tripped through JSON still verifies.
"""

from __future__ import annotations

import hashlib
import json
import time
from typing import Optional

__all__ = ["AuditLog"]

GENESIS = "0" * 64


def _canonical(payload: dict) -> bytes:
    return json.dumps(payload, sort_keys=True,
                      separators=(",", ":")).encode()


class AuditLog:
    """Hash-chained, append-only event log.

    ``append`` stamps each record with a sequence number, a UTC
    timestamp, the previous record's hash, and its own chain hash;
    ``verify_chain`` recomputes the whole chain and fails on any
    mutation.  ``records`` returns deep-ish copies so callers cannot
    accidentally corrupt the chain (tests tamper via the ``_records``
    internals on purpose).
    """

    def __init__(self):
        self._records: list = []

    def __len__(self) -> int:
        return len(self._records)

    @property
    def head(self) -> str:
        """The chain head hash (GENESIS when empty)."""
        return self._records[-1]["hash"] if self._records else GENESIS

    def append(self, event: str, **fields) -> dict:
        """Append one event; returns the sealed record."""
        payload = {"seq": len(self._records), "event": str(event),
                   "ts": time.time(), "prev": self.head}
        for k, v in fields.items():
            if k in payload or k == "hash":
                raise ValueError(f"audit field {k!r} is reserved")
            payload[k] = v
        record = dict(payload)
        record["hash"] = hashlib.sha256(_canonical(payload)).hexdigest()
        self._records.append(record)
        return dict(record)

    def records(self) -> list:
        return [dict(r) for r in self._records]

    def verify_chain(self) -> bool:
        """True iff every record's hash and back-link still hold."""
        prev = GENESIS
        for i, record in enumerate(self._records):
            payload = {k: v for k, v in record.items() if k != "hash"}
            if payload.get("seq") != i or payload.get("prev") != prev:
                return False
            if record.get("hash") != \
                    hashlib.sha256(_canonical(payload)).hexdigest():
                return False
            prev = record["hash"]
        return True

    def events(self, event: Optional[str] = None) -> list:
        """Records filtered by event type (all when ``None``)."""
        return [dict(r) for r in self._records
                if event is None or r["event"] == event]

    def dump(self, path: str) -> None:
        """Write the log as JSON lines (one record per line)."""
        with open(path, "w") as f:
            for record in self._records:
                f.write(json.dumps(record, sort_keys=True) + "\n")

    @classmethod
    def load(cls, path: str) -> "AuditLog":
        """Load a dumped log (callers should ``verify_chain`` it)."""
        log = cls()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    log._records.append(json.loads(line))
        return log
