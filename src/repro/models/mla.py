"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and keys/values are low-rank compressed; only the compressed
KV latent ``c_kv`` (kv_lora_rank) plus the shared RoPE key (rope dim)
are cached for decode — the architecture's memory saving, and exactly
the tensor SeDA protects when the cache crosses the untrusted boundary.

Dims follow the DeepSeek-V3 report: q_lora_rank 1536, kv_lora_rank 512,
qk_nope_head_dim 128, qk_rope_head_dim 64, v_head_dim 128.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense, rms_norm, rope, spec

__all__ = ["MLAConfig", "mla_specs", "mla_attention", "mla_decode",
           "MLACache", "init_mla_cache_specs"]

NEG_INF = -1e30


class MLAConfig(NamedTuple):
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


class MLACache(NamedTuple):
    c_kv: jax.Array    # (B, L_max, kv_lora_rank)
    k_pe: jax.Array    # (B, L_max, qk_rope_dim)
    length: jax.Array


def init_mla_cache_specs(cfg: MLAConfig, batch: int, max_len: int, dtype: str):
    return MLACache(
        c_kv=jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank),
                                  jnp.dtype(dtype)),
        k_pe=jax.ShapeDtypeStruct((batch, max_len, cfg.qk_rope_dim),
                                  jnp.dtype(dtype)),
        length=jax.ShapeDtypeStruct((), jnp.int32),
    )


def mla_specs(cfg: MLAConfig, dtype: str):
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": spec((cfg.d_model, cfg.q_lora_rank), ("embed", "lora"), dtype),
        "q_norm": spec((cfg.q_lora_rank,), ("lora",), "float32", init="ones"),
        "wq_b": spec((cfg.q_lora_rank, h, dn + dr), ("lora", "heads", "head_dim"),
                     dtype),
        "wkv_a": spec((cfg.d_model, cfg.kv_lora_rank + dr), ("embed", "lora"),
                      dtype),
        "kv_norm": spec((cfg.kv_lora_rank,), ("lora",), "float32", init="ones"),
        "wkv_b": spec((cfg.kv_lora_rank, h, dn + dv), ("lora", "heads", "head_dim"),
                      dtype),
        "wo": spec((h, dv, cfg.d_model), ("heads", "head_dim", "embed"), dtype),
    }


def _project_q(cfg: MLAConfig, params, x, positions):
    cq = rms_norm(dense(x, params["wq_a"]), params["q_norm"])
    q = jnp.einsum("blr,rhk->blhk", cq, params["wq_b"])
    q_nope, q_pe = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_pe = rope(q_pe, positions)
    return jnp.concatenate([q_nope, q_pe], axis=-1)


def _project_kv_latent(cfg: MLAConfig, params, x, positions):
    kv = dense(x, params["wkv_a"])  # (B, L, rank + dr)
    c_kv = rms_norm(kv[..., : cfg.kv_lora_rank], params["kv_norm"])
    k_pe = rope(kv[..., None, cfg.kv_lora_rank:], positions)[..., 0, :]
    return c_kv, k_pe


def _expand_kv(cfg: MLAConfig, params, c_kv, k_pe):
    kv = jnp.einsum("blr,rhk->blhk", c_kv, params["wkv_b"])
    k_nope = kv[..., : cfg.qk_nope_dim]
    v = kv[..., cfg.qk_nope_dim:]
    k_pe_b = jnp.broadcast_to(k_pe[:, :, None, :],
                              k_pe.shape[:2] + (cfg.n_heads, cfg.qk_rope_dim))
    k = jnp.concatenate([k_nope, k_pe_b], axis=-1)
    return k, v


def mla_attention(cfg: MLAConfig, params, x, positions, *,
                  q_block: int = 512, kv_block: int = 512):
    """Causal MLA for training/prefill.  x: (B, L, d)."""
    from repro.models.attention import _chunked_causal_attention
    q = _project_q(cfg, params, x, positions)
    c_kv, k_pe = _project_kv_latent(cfg, params, x, positions)
    k, v = _expand_kv(cfg, params, c_kv, k_pe)
    # Pad V to the QK head dim so the flash kernel sees equal dims.
    dq = q.shape[-1]
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dq - v.shape[-1])))
    ctx = _chunked_causal_attention(q, k, v_pad, q_block=q_block,
                                    kv_block=kv_block)
    ctx = ctx[..., : cfg.v_head_dim]
    return jnp.einsum("blhk,hkd->bld", ctx, params["wo"])


def mla_decode(cfg: MLAConfig, params, x, cache: MLACache):
    """Single-token decode with the compressed cache.  x: (B, 1, d).

    ``cache.length`` may be a scalar or (B,) for continuous batching
    (see :func:`repro.models.attention.decode_lengths`).
    """
    from repro.models.attention import decode_lengths, scatter_new_token
    b = x.shape[0]
    per_seq, lengths = decode_lengths(cache.length, b)
    positions = lengths[:, None]                              # (B, 1)
    q = _project_q(cfg, params, x, positions)                 # (B,1,H,dn+dr)
    c_new, kpe_new = _project_kv_latent(cfg, params, x, positions)

    l_max = cache.c_kv.shape[1]
    c_kv = scatter_new_token(cache.c_kv, c_new, cache.length, lengths,
                             per_seq)
    k_pe = scatter_new_token(cache.k_pe, kpe_new, cache.length, lengths,
                             per_seq)

    k, v = _expand_kv(cfg, params, c_kv, k_pe)                # (B,L,H,*)
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhk,blhk->bhql", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    mask = (jnp.arange(l_max)[None, None, None, :]
            <= lengths[:, None, None, None])
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhql,blhd->bqhd", p, v.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("blhk,hkd->bld", ctx, params["wo"])
    return out, MLACache(c_kv, k_pe, cache.length + 1)
