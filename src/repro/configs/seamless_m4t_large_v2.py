"""seamless-m4t-large-v2 — enc-dec, multimodal [arXiv:2308.11596; hf].

[audio] 24L d_model=1024 16H (GQA kv=16 = MHA) d_ff=8192 vocab=256206.
24 encoder + 24 decoder layers; the speech frontend (w2v-BERT) is a
STUB: input_specs() provides precomputed frame embeddings.
"""

from repro.configs.base import ArchDef
from repro.models.encdec import EncDecConfig

DECODE_SRC_LEN = 1024  # encoder frames cached for decode cells


def make_config() -> EncDecConfig:
    return EncDecConfig(
        name="seamless-m4t-large-v2",
        enc_layers=24, dec_layers=24, d_model=1024, n_heads=16, n_kv=16,
        head_dim=64, d_ff=8192, vocab=256206,
    )


def make_smoke_config() -> EncDecConfig:
    return EncDecConfig(
        name="seamless-m4t-large-v2-smoke",
        enc_layers=2, dec_layers=2, d_model=64, n_heads=4, n_kv=4,
        head_dim=16, d_ff=128, vocab=256, dtype="float32",
        q_block=16, kv_block=16, remat="none",
    )


ARCH = ArchDef(
    name="seamless-m4t-large-v2", family="audio", kind="encdec",
    make_config=make_config, make_smoke_config=make_smoke_config,
    source="arXiv:2308.11596; hf",
    notes="Enc-dec: decode cells run the text decoder (self-KV cache of "
          "seq_len + cached cross K/V over 1024 encoder frames).  Audio "
          "frontend stubbed to frame embeddings per the assignment.",
)
