"""End-to-end integration: training loop, resume, serving, dry-run infra."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


class TestTrainLoop:
    def test_secure_training_with_resume(self, tmp_path):
        from repro.launch import train
        args = ["--arch", "smollm-135m", "--smoke", "--global-batch", "4",
                "--seq-len", "32", "--scheme", "seda", "--log-every", "100",
                "--ckpt-dir", str(tmp_path), "--ckpt-every", "4"]
        out1 = train.main(args + ["--steps", "6"])
        assert out1["steps"] == 6
        assert np.isfinite(out1["last_loss"])
        # Resume: the final checkpoint is at step 6, so only 2 steps run.
        out2 = train.main(args + ["--steps", "8"])
        assert out2["steps"] == 2  # resumed from step 6 -> steps 6..7
        assert np.isfinite(out2["last_loss"])

    def test_insecure_loop_loss_decreases(self):
        from repro.launch import train
        out = train.main(["--arch", "smollm-135m", "--smoke", "--steps",
                          "150", "--global-batch", "8", "--seq-len", "64",
                          "--lr", "5e-3", "--log-every", "1000"])
        assert out["last_loss"] < out["first_loss"] - 0.1, (
            f"loss did not decrease: {out['first_loss']} -> "
            f"{out['last_loss']}")


class TestServing:
    def test_prefill_decode_roundtrip(self):
        from repro.configs import get_arch
        from repro.models import lm as lm_mod
        from repro.models.layers import init_params
        from repro.serve.serve_step import (greedy_sample, make_decode_step,
                                            make_prefill_step)
        arch = get_arch("olmoe-1b-7b")  # exercises the MoE decode path
        cfg = arch.make_smoke_config()
        params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
        prompts = jnp.ones((2, 8), jnp.int32)
        prefill = make_prefill_step(arch, cfg, max_len=16)
        decode = make_decode_step(arch, cfg)
        logits, caches = prefill(params, {"tokens": prompts})
        tok = greedy_sample(logits)
        for _ in range(3):
            logits, caches = decode(params, tok, caches)
            tok = greedy_sample(logits)
            assert tok.shape == (2, 1)
            assert bool(jnp.isfinite(logits).all())


class TestDryRunInfra:
    """The dry-run machinery itself, on an 8-device subprocess (the full
    512-device sweep runs via `python -m repro.launch.dryrun --all`;
    its 64-cell results are recorded in EXPERIMENTS.md)."""

    @pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
    def test_smoke_cell_lowers_and_compiles(self, shape):
        code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.launch.cells import build_cell
try:  # axis_types only exists on newer jax; Auto is the default anyway
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
except AttributeError:
    mesh = jax.make_mesh((2, 4), ("data", "model"))
cell = build_cell("smollm-135m", "{shape}", mesh, smoke=True)
compiled = cell.lower(mesh).compile()
assert compiled.cost_analysis() is not None
print("CELL_OK")
"""
        env = dict(os.environ, PYTHONPATH=SRC)
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=500)
        assert "CELL_OK" in out.stdout, out.stderr[-2000:]

    def test_hlo_analysis_loop_awareness(self):
        """The analyzer multiplies scan-body flops by trip counts."""
        import jax
        from repro.launch.analysis import analyze_hlo

        def f(x):
            def body(c, _):
                return c @ c, None
            out, _ = jax.lax.scan(body, x, None, length=7)
            return out

        hlo = jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile().as_text()
        stats = analyze_hlo(hlo)
        # 7 iterations x 2*64^3 flops each.
        assert stats.dot_flops == pytest.approx(7 * 2 * 64 ** 3, rel=0.01)
        assert 7 in stats.trip_counts
