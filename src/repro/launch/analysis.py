"""Loop-aware HLO analysis for the roofline.

``compiled.cost_analysis()`` (and a naive text scan) count a while-loop
body ONCE — but our models scan over layers, so flops/collective bytes
must be multiplied by trip counts.  This module parses the post-SPMD
HLO text into computations, builds the call graph (while bodies x trip
count, fusions/calls x1), and propagates multiplicities from ENTRY.

Per computation we count:
  * dot flops: 2 * prod(output shape) * prod(contracting dims) — exact
    for the matmul-dominated transformer/SSD graphs (elementwise flops
    are excluded by design; they are roofline-irrelevant);
  * collective operand bytes by kind (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute).

Shapes in the post-SPMD module are per-device, so totals are per-chip.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

from repro.launch.hlo_utils import DTYPE_BYTES, collective_bytes

__all__ = ["ModuleStats", "analyze_hlo"]

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_SHAPE = re.compile(r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64"
                    r"|c64|c128)\[([0-9,]*)\]")
_DOT = re.compile(r"=\s*[a-z0-9]+\[([0-9,]*)\][^=]*\bdot\(")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_WHILE = re.compile(r"\bwhile\(.*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_WHILE_REV = re.compile(r"\bwhile\(.*body=%?([\w.\-]+),\s*condition=%?([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")


@dataclass
class ModuleStats:
    dot_flops: float = 0.0
    collectives: dict = field(default_factory=dict)
    mem_bytes: float = 0.0   # HLO-level operand+output traffic (loop-aware)
    n_while: int = 0
    trip_counts: list = field(default_factory=list)

    @property
    def collective_total(self) -> float:
        return sum(v for k, v in self.collectives.items()
                   if k not in ("total", "count"))


def _split_computations(text: str) -> dict:
    comps: dict[str, list] = {}
    entry = None
    current = None
    for line in text.splitlines():
        stripped = line.strip()
        is_header = (not line.startswith(" ") and "(" in line
                     and stripped.endswith("{") and "->" in line)
        if is_header:
            m = _COMP_HEADER.match(line)
            if m:
                current = m.group(1)
                comps[current] = []
                if line.startswith("ENTRY"):
                    entry = current
                continue
        if stripped == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(stripped)
    return comps, entry


_DEF = re.compile(r"^%?([\w.\-]+)\s*=")
# Newer XLA prints typed operands — `dot(f32[16,16]{1,0} %arg, ...)` —
# so the lhs shape may be inline (group 1); otherwise fall back to the
# operand name (group 2) via the symbol table.
_DOT_OPERANDS = re.compile(
    r"\bdot\(\s*(?:[a-z0-9]+\[([0-9,]*)\](?:\{[0-9,]*\})?\s+)?%?([\w.\-]+)")


def _symbol_table(lines: list) -> dict:
    """instruction name -> output dims (first shape literal after '=')."""
    table = {}
    for line in lines:
        d = _DEF.match(line)
        if not d:
            continue
        eq = line.index("=")
        s = _SHAPE.search(line, eq)
        if s:
            table[d.group(1)] = [int(x) for x in s.group(2).split(",") if x]
    return table


def _symbol_bytes(lines: list) -> dict:
    """instruction name -> output byte size (dtype-aware, tuples summed)."""
    table = {}
    for line in lines:
        d = _DEF.match(line)
        if not d or "=" not in line:
            continue
        table[d.group(1)] = _dtype_bytes_of_line_output(line)
    return table


def _dot_flops_of_line(line: str, symtab: dict) -> float:
    m = _DOT.search(line)
    if not m:
        return 0.0
    out_elems = 1
    for d in m.group(1).split(","):
        if d:
            out_elems *= int(d)
    contract_elems = 1
    cm = _CONTRACT.search(line)
    om = _DOT_OPERANDS.search(line)
    if cm and om:
        if om.group(1) is not None:
            lhs = [int(x) for x in om.group(1).split(",") if x]
        else:
            lhs = symtab.get(om.group(2))
        if lhs:
            for i in (int(x) for x in cm.group(1).split(",") if x):
                if i < len(lhs):
                    contract_elems *= lhs[i]
    return 2.0 * out_elems * contract_elems


_REF = re.compile(r"%([\w.\-]+)")
_ATTR_REFS = re.compile(r"(?:calls|to_apply|condition|body)=%[\w.\-]+")
_OPCODE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")
# Zero-traffic opcodes: layout/tuple/control plumbing (while itself is
# aliased carry passing; its body's slices are charged separately).
_NO_TRAFFIC_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "iota", "after-all", "partition-id", "replica-id", "while",
    "conditional", "call", "custom-call",
}


def _opcode_of_line(line: str, region_end: int) -> str:
    m = _OPCODE.search(line, region_end)
    return m.group(1) if m else ""


def _dtype_bytes_of_line_output(line: str) -> float:
    """Sum of all output shape bytes printed immediately after '='."""
    eq = line.index("=")
    rhs = line[eq + 1:].lstrip()
    base = len(line) - len(rhs)
    if rhs.startswith("("):
        # Tuple output: region is the balanced paren group.
        depth = 0
        end = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        region = line[base: base + end + 1]
    else:
        op_paren = line.find("(", eq)
        region = line[eq: op_paren if op_paren != -1 else len(line)]
    total = 0
    for dtype, dims in _SHAPE.findall(region):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return float(total)


def _output_region_end(line: str) -> int:
    """Index just past the output type block (start of the op name)."""
    eq = line.index("=")
    rhs = line[eq + 1:].lstrip()
    base = len(line) - len(rhs)
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return base + i + 1
    m = _SHAPE.search(line, eq)
    return m.end() if m else eq + 1


def _line_traffic(line: str, symtab_bytes: dict) -> float:
    """operand + output bytes of one instruction."""
    if "=" not in line:
        return 0.0
    region_end = _output_region_end(line)
    opcode = _opcode_of_line(line, region_end)
    if opcode in _NO_TRAFFIC_OPS:
        return 0.0
    out = _dtype_bytes_of_line_output(line)
    body = _ATTR_REFS.sub("", line[region_end:])
    # Strip metadata tail (op names there contain no %refs anyway).
    meta = body.find("metadata=")
    if meta != -1:
        body = body[:meta]
    operands = 0.0
    for name in _REF.findall(body):
        operands += symtab_bytes.get(name, 0.0)
    return out + operands


def _trip_count(cond_lines: list) -> int:
    """Max plausible loop-bound constant in the condition computation."""
    best = 1
    for line in cond_lines:
        for c in _CONST_INT.findall(line):
            v = int(c)
            if 1 < v <= 1_000_000:
                best = max(best, v)
    return best


def analyze_hlo(text: str) -> ModuleStats:
    comps, entry = _split_computations(text)
    if entry is None:
        # Fallback: treat the whole text as one computation.
        stats = ModuleStats()
        lines = [l.strip() for l in text.splitlines()]
        symtab = _symbol_table(lines)
        stats.dot_flops = sum(_dot_flops_of_line(l, symtab) for l in lines)
        stats.mem_bytes = sum(_line_traffic(l, _symbol_bytes(lines))
                              for l in lines)
        stats.collectives = collective_bytes(text)
        return stats

    # Fusion bodies: their internal ops read VMEM/registers, not HBM —
    # traffic is charged at the fusion call site instead.
    fusion_bodies: set = set()
    for lines in comps.values():
        for line in lines:
            if " fusion(" in line or "\tfusion(" in line or "= fusion(" in line:
                cm = _CALLS.search(line)
                if cm:
                    fusion_bodies.add(cm.group(1))

    # Per-computation raw stats.
    raw_flops = {}
    raw_coll = {}
    raw_mem = {}
    edges = defaultdict(list)  # comp -> [(child, multiplier)]
    n_while = 0
    trips = []
    for name, lines in comps.items():
        symtab = _symbol_table(lines)
        raw_flops[name] = sum(_dot_flops_of_line(l, symtab) for l in lines)
        raw_coll[name] = collective_bytes("\n".join(lines))
        if name in fusion_bodies:
            raw_mem[name] = 0.0
        else:
            sym_bytes = _symbol_bytes(lines)
            raw_mem[name] = sum(_line_traffic(l, sym_bytes) for l in lines)
        for line in lines:
            wm = _WHILE.search(line) or _WHILE_REV.search(line)
            if wm and "while(" in line:
                g1, g2 = wm.group(1), wm.group(2)
                cond, body = (g1, g2) if _WHILE.search(line) else (g2, g1)
                trip = _trip_count(comps.get(cond, []))
                n_while += 1
                trips.append(trip)
                edges[name].append((body, trip))
                edges[name].append((cond, trip + 1))
                continue
            bm = _BRANCHES.search(line)
            if bm:
                for branch in bm.group(1).split(","):
                    edges[name].append((branch.strip().lstrip("%"), 1))
                continue
            cm = _CALLS.search(line)
            if cm:
                edges[name].append((cm.group(1), 1))

    # Propagate multiplicities from ENTRY in topological order (the HLO
    # call graph is a DAG; fusions may be shared by several parents).
    reachable = {entry}
    stack = [entry]
    while stack:
        c = stack.pop()
        for child, _ in edges.get(c, []):
            if child in comps and child not in reachable:
                reachable.add(child)
                stack.append(child)
    indeg: dict[str, int] = defaultdict(int)
    for c in reachable:
        for child, _ in edges.get(c, []):
            if child in reachable:
                indeg[child] += 1
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    queue = [c for c in reachable if indeg[c] == 0]
    while queue:
        c = queue.pop()
        for child, k in edges.get(c, []):
            if child not in reachable:
                continue
            mult[child] += mult[c] * k
            indeg[child] -= 1
            if indeg[child] == 0:
                queue.append(child)

    stats = ModuleStats(n_while=n_while, trip_counts=trips)
    coll_total: dict = defaultdict(float)
    for name in comps:
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        stats.dot_flops += raw_flops[name] * m
        stats.mem_bytes += raw_mem[name] * m
        for k, v in raw_coll[name].items():
            if k in ("total", "count"):
                continue
            coll_total[k] += v * m
    stats.collectives = dict(coll_total)
    return stats
