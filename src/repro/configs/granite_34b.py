"""granite-34b — llama-arch code model [arXiv:2405.04324; hf].

[dense] 88L d_model=6144 48H (GQA kv=1, i.e. MQA) d_ff=24576 vocab=49152.
"""

from repro.configs.base import ArchDef
from repro.models.lm import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="granite-34b",
        n_layers=88, d_model=6144, n_heads=48, n_kv=1, head_dim=128,
        d_ff=24576, vocab=49152,
        mixer="attn", ffn="dense", gated_ffn=False,  # GPT-BigCode plain MLP
        tie_embeddings=True,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="granite-34b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv=1, head_dim=16,
        d_ff=128, vocab=256, dtype="float32",
        mixer="attn", ffn="dense", gated_ffn=False,
        q_block=16, kv_block=16, remat="none",
    )


ARCH = ArchDef(
    name="granite-34b", family="dense", kind="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    source="arXiv:2405.04324; hf",
    rules={"kv_heads": None},  # MQA: the single KV head replicates
    notes="MQA (kv=1): KV projections/cache replicate over the model "
          "axis; q heads TP-shard 48/16.",
)
