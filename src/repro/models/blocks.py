"""Layer-block combinators: (mixer, ffn) pairs covering every assigned arch.

mixer: 'attn' (GQA/MQA + RoPE) | 'mla' (DeepSeek latent) | 'mamba' (SSD)
ffn:   'dense' (SwiGLU) | 'moe' (top-k routed) | 'none' (pure-mamba blocks)

Each block is pre-norm residual.  The same block definitions serve
training forward, prefill (returning caches) and single-token decode
(consuming caches), so the three lowered programs share structure.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mamba2 as mamba_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.layers import rms_norm, spec

__all__ = ["LayerKind", "block_specs", "block_forward", "block_prefill",
           "block_decode", "block_cache_specs"]


class LayerKind(NamedTuple):
    mixer: str
    ffn: str


def block_specs(cfg, kind: LayerKind) -> dict:
    s: dict[str, Any] = {
        "norm_mixer": spec((cfg.d_model,), ("embed",), "float32", init="ones"),
    }
    if kind.mixer == "attn":
        s["attn"] = attn_mod.attention_specs(cfg.d_model, cfg.n_heads,
                                             cfg.n_kv, cfg.head_dim, cfg.dtype)
    elif kind.mixer == "mla":
        s["mla"] = mla_mod.mla_specs(cfg.mla, cfg.dtype)
    elif kind.mixer == "mamba":
        s["mamba"] = mamba_mod.mamba2_specs(cfg.mamba, cfg.dtype)
    else:
        raise ValueError(kind.mixer)

    if kind.ffn != "none":
        s["norm_ffn"] = spec((cfg.d_model,), ("embed",), "float32", init="ones")
    if kind.ffn == "dense":
        s["ffn"] = moe_mod.ffn_specs(cfg.d_model, cfg.d_ff, cfg.dtype,
                                     gated=getattr(cfg, "gated_ffn", True))
    elif kind.ffn == "moe":
        s["moe"] = moe_mod.moe_specs(cfg.moe, cfg.dtype)
    return s


def _apply_ffn(cfg, kind: LayerKind, params, x, aux):
    if kind.ffn == "none":
        return x, aux
    h = rms_norm(x, params["norm_ffn"])
    if kind.ffn == "dense":
        return x + moe_mod.dense_ffn(params["ffn"], h), aux
    b, l, d = h.shape
    y, moe_aux = moe_mod.moe_ffn(cfg.moe, params["moe"], h.reshape(b * l, d))
    return x + y.reshape(b, l, d), aux + moe_aux


def block_forward(cfg, kind: LayerKind, params, x, positions, aux):
    h = rms_norm(x, params["norm_mixer"])
    if kind.mixer == "attn":
        x = x + attn_mod.attention(params["attn"], h, positions,
                                   q_block=cfg.q_block, kv_block=cfg.kv_block)
    elif kind.mixer == "mla":
        x = x + mla_mod.mla_attention(cfg.mla, params["mla"], h, positions,
                                      q_block=cfg.q_block,
                                      kv_block=cfg.kv_block)
    else:
        x = x + mamba_mod.mamba2_forward(cfg.mamba, params["mamba"], h,
                                         chunk=cfg.ssd_chunk)
    return _apply_ffn(cfg, kind, params, x, aux)


# ---------------------------------------------------------------------------
# Caches.
# ---------------------------------------------------------------------------


def block_cache_specs(cfg, kind: LayerKind, batch: int, max_len: int):
    if kind.mixer == "attn":
        return attn_mod.init_kv_cache_specs(batch, max_len, cfg.n_kv,
                                            cfg.head_dim, cfg.dtype)
    if kind.mixer == "mla":
        return mla_mod.init_mla_cache_specs(cfg.mla, batch, max_len, cfg.dtype)
    return mamba_mod.init_mamba2_state_specs(cfg.mamba, batch, cfg.dtype)


def block_cache_axes(cfg, kind: LayerKind):
    """Logical axes mirroring block_cache_specs (for the sharding planner)."""
    if kind.mixer == "attn":
        kv = ("batch", "seq", "kv_heads", "head_dim")
        return attn_mod.KVCache(k=kv, v=kv, length=())
    if kind.mixer == "mla":
        return mla_mod.MLACache(c_kv=("batch", "seq", "lora"),
                                k_pe=("batch", "seq", "head_dim"), length=())
    return mamba_mod.Mamba2State(ssm=("batch", "heads", "head_dim", "state"),
                                 conv=("batch", "conv_k", "mlp"), length=())


def _pad_to(x, max_len):
    """Pad (B, L, ...) along axis 1 up to max_len."""
    pad = [(0, 0)] * x.ndim
    pad[1] = (0, max_len - x.shape[1])
    return jnp.pad(x, pad)


def block_prefill(cfg, kind: LayerKind, params, x, positions, aux, max_len):
    """Forward + produce this block's decode cache (padded to max_len)."""
    h = rms_norm(x, params["norm_mixer"])
    length = jnp.asarray(x.shape[1], jnp.int32)
    if kind.mixer == "attn":
        out, (k, v) = attn_mod.attention(params["attn"], h, positions,
                                         q_block=cfg.q_block,
                                         kv_block=cfg.kv_block, return_kv=True)
        x = x + out
        cache = attn_mod.KVCache(_pad_to(k.astype(jnp.dtype(cfg.dtype)), max_len),
                                 _pad_to(v.astype(jnp.dtype(cfg.dtype)), max_len),
                                 length)
    elif kind.mixer == "mla":
        out = mla_mod.mla_attention(cfg.mla, params["mla"], h, positions,
                                    q_block=cfg.q_block, kv_block=cfg.kv_block)
        c_kv, k_pe = mla_mod._project_kv_latent(cfg.mla, params["mla"], h,
                                                positions)
        x = x + out
        cache = mla_mod.MLACache(
            _pad_to(c_kv.astype(jnp.dtype(cfg.dtype)), max_len),
            _pad_to(k_pe.astype(jnp.dtype(cfg.dtype)), max_len), length)
    else:
        out, state = mamba_mod.mamba2_forward(cfg.mamba, params["mamba"], h,
                                              chunk=cfg.ssd_chunk,
                                              return_state=True)
        x = x + out
        # Conv rolling window = last (d_conv - 1) conv inputs.
        zxbcdt = jnp.einsum("bld,dp->blp", h, params["mamba"]["in_proj"])
        _, xbc, _ = mamba_mod._split_proj(cfg.mamba, zxbcdt)
        d_conv = cfg.mamba.d_conv
        conv_win = xbc[:, -(d_conv - 1):, :].astype(jnp.dtype(cfg.dtype))
        cache = mamba_mod.Mamba2State(state, conv_win, length)
    x, aux = _apply_ffn(cfg, kind, params, x, aux)
    return x, cache, aux


def block_decode(cfg, kind: LayerKind, params, x, cache, aux):
    h = rms_norm(x, params["norm_mixer"])
    if kind.mixer == "attn":
        out, cache = attn_mod.decode_attention(params["attn"], h, cache)
    elif kind.mixer == "mla":
        out, cache = mla_mod.mla_decode(cfg.mla, params["mla"], h, cache)
    else:
        out, cache = mamba_mod.mamba2_decode(cfg.mamba, params["mamba"], h,
                                             cache)
    x = x + out
    x, aux = _apply_ffn(cfg, kind, params, x, aux)
    return x, cache, aux
