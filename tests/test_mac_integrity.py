"""Multi-level MAC (§III-C): XOR-MAC, RePA attack/defense, properties."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import attacks, mac
from repro.core.secure_memory import SecureKeys


def _bind(n, layer=3, fmap=1, vn=7):
    return mac.Binding.make(np.arange(n, dtype=np.uint32) * 4, vn, layer,
                            fmap, np.arange(n, dtype=np.uint32))


@pytest.fixture()
def blocks(rng):
    return jnp.asarray(rng.integers(0, 256, (16, 64), dtype=np.uint8))


class TestBlockMACs:
    @pytest.mark.parametrize("engine", ["nh", "cbc"])
    def test_deterministic(self, keys, blocks, engine):
        kw = dict(hash_key_u32=keys.hash_key, round_keys=keys.round_keys,
                  engine=engine)
        m1 = mac.block_macs(blocks, _bind(16), **kw)
        m2 = mac.block_macs(blocks, _bind(16), **kw)
        assert (np.asarray(m1) == np.asarray(m2)).all()

    @pytest.mark.parametrize("engine", ["nh", "cbc"])
    def test_distinct_blocks_distinct_macs(self, keys, blocks, engine):
        m = np.asarray(mac.block_macs(blocks, _bind(16),
                                      hash_key_u32=keys.hash_key,
                                      round_keys=keys.round_keys,
                                      engine=engine))
        assert len({bytes(x) for x in m}) == 16

    @pytest.mark.parametrize("engine", ["nh", "cbc"])
    def test_binding_sensitivity(self, keys, blocks, engine):
        """Same data, different (layer, fmap, blk) binding -> different MAC
        (the RePA defense, Alg. 2 lines 7-8)."""
        kw = dict(hash_key_u32=keys.hash_key, round_keys=keys.round_keys,
                  engine=engine)
        m1 = np.asarray(mac.block_macs(blocks, _bind(16, layer=3), **kw))
        m2 = np.asarray(mac.block_macs(blocks, _bind(16, layer=4), **kw))
        assert not (m1 == m2).all()
        m3 = np.asarray(mac.block_macs(blocks, _bind(16, vn=8), **kw))
        assert not (m1 == m3).all()

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 15), st.integers(0, 63), st.integers(0, 7))
    def test_tamper_detection_property(self, blk_idx, byte_idx, bit):
        """Flipping ANY bit of ANY block changes that block's MAC."""
        keys = SecureKeys.derive(321)
        rng = np.random.default_rng(5)
        blocks = jnp.asarray(rng.integers(0, 256, (16, 64), dtype=np.uint8))
        kw = dict(hash_key_u32=keys.hash_key, round_keys=keys.round_keys)
        m1 = np.asarray(mac.block_macs(blocks, _bind(16), **kw))
        tampered = blocks.at[blk_idx, byte_idx].set(
            blocks[blk_idx, byte_idx] ^ (1 << bit))
        m2 = np.asarray(mac.block_macs(tampered, _bind(16), **kw))
        assert not (m1[blk_idx] == m2[blk_idx]).all()


class TestRePA:
    """Algorithm 2: shuffle attack on XOR-aggregated layer MACs."""

    def test_repa_succeeds_against_naive_xormac(self, keys, blocks):
        kw = dict(hash_key_u32=keys.hash_key, round_keys=keys.round_keys,
                  engine="naive")
        layer1 = mac.layer_mac(blocks, _bind(16), **kw)
        shuffled = jnp.asarray(attacks.repa_shuffle(np.asarray(blocks)))
        layer2 = mac.layer_mac(shuffled, _bind(16), **kw)
        # XOR commutes and naive MACs ignore position: verification PASSES
        # although the layer content is permuted -> attack succeeds.
        assert (np.asarray(layer1) == np.asarray(layer2)).all()

    def test_repa_fails_against_seda_binding(self, keys, blocks):
        kw = dict(hash_key_u32=keys.hash_key, round_keys=keys.round_keys,
                  engine="nh")
        layer1 = mac.layer_mac(blocks, _bind(16), **kw)
        shuffled = jnp.asarray(attacks.repa_shuffle(np.asarray(blocks)))
        layer2 = mac.layer_mac(shuffled, _bind(16), **kw)
        assert not (np.asarray(layer1) == np.asarray(layer2)).all()

    def test_model_mac_hierarchy(self, keys, blocks):
        kw = dict(hash_key_u32=keys.hash_key, round_keys=keys.round_keys)
        l1 = mac.layer_mac(blocks, _bind(16, layer=0), **kw)
        l2 = mac.layer_mac(blocks ^ jnp.uint8(1), _bind(16, layer=1), **kw)
        model = mac.model_mac(jnp.stack([l1, l2]))
        assert model.shape == (mac.MAC_BYTES,)
        model2 = mac.model_mac(jnp.stack([l1, l1]))
        assert not (np.asarray(model) == np.asarray(model2)).all()

    def test_verify_layer(self, keys, blocks):
        kw = dict(hash_key_u32=keys.hash_key, round_keys=keys.round_keys)
        lm = mac.layer_mac(blocks, _bind(16), **kw)
        assert bool(mac.verify_layer(blocks, _bind(16), lm, **kw))
        assert not bool(mac.verify_layer(blocks ^ jnp.uint8(2), _bind(16),
                                         lm, **kw))


class TestNH:
    def test_nh_matches_bigint_reference(self, rng):
        m = rng.integers(0, 2**32, size=(4, 16), dtype=np.uint32)
        k = rng.integers(0, 2**32, size=16, dtype=np.uint32)
        hi, lo = mac.nh_hash(jnp.asarray(m), jnp.asarray(k))
        for r in range(4):
            acc = 0
            for i in range(0, 16, 2):
                acc = (acc + ((int(m[r, i]) + int(k[i])) % 2**32)
                       * ((int(m[r, i + 1]) + int(k[i + 1])) % 2**32)) % 2**64
            assert (int(hi[r]) << 32) + int(lo[r]) == acc

    def test_mul32x32_exhaustive_edges(self):
        edge = np.array([0, 1, 2, 0xFFFF, 0x10000, 0x7FFFFFFF, 0x80000000,
                         0xFFFFFFFE, 0xFFFFFFFF], dtype=np.uint32)
        a, b = np.meshgrid(edge, edge)
        hi, lo = mac._mul32x32(jnp.asarray(a.ravel()), jnp.asarray(b.ravel()))
        want = a.ravel().astype(object) * b.ravel().astype(object)
        got = (np.asarray(hi).astype(object) << 32) + np.asarray(lo)
        assert (got == want).all()
