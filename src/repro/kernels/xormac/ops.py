"""Kernel-backed optBlk MAC + layer MAC (bit-identical to core.mac "nh")."""

from __future__ import annotations

import jax

from repro.core import mac
from repro.kernels.aes_ctr.ops import keystream_bytes
from repro.kernels.xormac.kernel import nh_hash_kernel_call

__all__ = ["block_macs_kernel", "layer_mac_kernel", "nh_hash_kernel_call"]


def block_macs_kernel(blocks_u8: jax.Array, binding: mac.Binding, *,
                      hash_key_u32: jax.Array, round_keys: jax.Array,
                      subbytes: str = "take",
                      interpret: bool | None = None) -> jax.Array:
    """(n_blocks, block_bytes) u8 -> (n_blocks, 8) u8 MACs.

    NH compression runs in the xormac kernel; the AES PRF finalization
    reuses the aes_ctr kernel on the (n_blocks, 4) hash words.
    """
    payload = mac.nh_payload(blocks_u8, binding)
    if hash_key_u32.shape[-1] < payload.shape[-1]:
        raise ValueError("NH key too short for this optBlk size")
    hashes = nh_hash_kernel_call(payload, hash_key_u32[: payload.shape[-1]],
                                 interpret=interpret)
    fin = mac.finalize_words(hashes[:, 0], hashes[:, 1], binding)
    pads = keystream_bytes(fin, round_keys, subbytes=subbytes,
                           interpret=interpret)
    return pads[:, : mac.MAC_BYTES]


def layer_mac_kernel(blocks_u8: jax.Array, binding: mac.Binding, *,
                     hash_key_u32: jax.Array, round_keys: jax.Array,
                     subbytes: str = "take",
                     interpret: bool | None = None) -> jax.Array:
    """Layer MAC = XOR of kernel-computed optBlk MACs -> (8,) u8."""
    macs = block_macs_kernel(blocks_u8, binding, hash_key_u32=hash_key_u32,
                             round_keys=round_keys, subbytes=subbytes,
                             interpret=interpret)
    return mac.xor_aggregate(macs)
