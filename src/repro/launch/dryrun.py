import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape) cell, ``.lower().compile()`` the
step program on the production mesh — 16x16 single-pod AND 2x16x16
multi-pod — and record memory_analysis / cost_analysis / collective
bytes into artifacts/dryrun/*.json.  A failure here (sharding mismatch,
OOM at compile, unsupported collective) is a bug in the system.

The XLA_FLAGS line above MUST run before any other import that touches
jax: jax locks the device count at first init.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-4b \
        --shape train_4k --mesh single
"""

import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402

from repro.configs import SHAPES, get_arch             # noqa: E402
from repro.launch.analysis import analyze_hlo               # noqa: E402
from repro.launch.cells import build_cell                   # noqa: E402
from repro.launch.hlo_utils import collective_bytes         # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")


def _mem_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
        val = getattr(ma, field, None)
        if val is not None:
            out[field] = int(val)
    return out


def _cost_analysis_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if not ca:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    keep = {}
    for k, v in ca.items():
        if k in ("flops", "bytes accessed", "transcendentals",
                 "optimal_seconds") or k.startswith("bytes accessed"):
            keep[k] = float(v)
    return keep


def run_cell(arch_name: str, shape_name: str, mesh_kind: str,
             out_dir: str, *, keep_hlo: bool = False) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    record = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(zip(mesh.axis_names,
                               [mesh.shape[a] for a in mesh.axis_names])),
        "status": "unknown",
    }
    t0 = time.time()
    try:
        cell = build_cell(arch_name, shape_name, mesh)
        lowered = cell.lower(mesh)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()
        hlo = compiled.as_text()
        stats = analyze_hlo(hlo)  # loop-aware per-chip flops + collectives
        record.update({
            "status": "ok",
            "lower_s": round(t_lower - t0, 2),
            "compile_s": round(t_compile - t_lower, 2),
            "memory_analysis": _mem_analysis_dict(compiled),
            "cost_analysis": _cost_analysis_dict(compiled),
            "collectives_raw": collective_bytes(hlo),
            "dot_flops_per_chip": stats.dot_flops,
            "mem_bytes_per_chip": stats.mem_bytes,
            "collectives_per_chip": stats.collectives,
            "collective_total_per_chip": stats.collective_total,
            "while_trip_counts": stats.trip_counts,
            "hlo_lines": hlo.count("\n"),
        })
        print(f"[dryrun] {arch_name} x {shape_name} x {mesh_kind}: OK "
              f"(lower {record['lower_s']}s, compile {record['compile_s']}s)")
        print(f"  memory_analysis: {record['memory_analysis']}")
        print(f"  cost_analysis:   {record['cost_analysis']}")
        print(f"  dot_flops/chip:  {record['dot_flops_per_chip']:.4g} "
              f"(trips {record['while_trip_counts']})")
        print(f"  collectives/chip: {record['collectives_per_chip']}")
        if keep_hlo:
            with open(os.path.join(
                    out_dir, f"{arch_name}_{shape_name}_{mesh_kind}.hlo.txt"),
                    "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001 - record and continue
        record.update({"status": "fail", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]})
        print(f"[dryrun] {arch_name} x {shape_name} x {mesh_kind}: FAIL {e}")

    fname = f"{arch_name}_{shape_name}_{mesh_kind}.json"
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=1, default=str)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACTS))
    args = ap.parse_args()

    n_dev = len(jax.devices())
    assert n_dev >= 512, f"dry-run needs 512 placeholder devices, got {n_dev}"

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        # Smallest archs first so results stream early.
        order = ["smollm-135m", "mamba2-780m", "seamless-m4t-large-v2",
                 "olmoe-1b-7b", "minitron-4b", "minitron-8b", "pixtral-12b",
                 "jamba-v0.1-52b", "granite-34b", "deepseek-v3-671b"]
        for a in order:
            for s in SHAPES:
                if get_arch(a).supports(SHAPES[s]):
                    cells.append((a, s))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape))

    results = []
    for a, s in cells:
        for m in meshes:
            fname = os.path.join(args.out, f"{a}_{s}_{m}.json")
            if args.skip_existing and os.path.exists(fname):
                with open(fname) as f:
                    rec = json.load(f)
                if rec.get("status") == "ok":
                    print(f"[dryrun] {a} x {s} x {m}: cached OK")
                    results.append(rec)
                    continue
            results.append(run_cell(a, s, m, args.out,
                                    keep_hlo=args.keep_hlo))

    ok = sum(1 for r in results if r["status"] == "ok")
    print(f"\n[dryrun] {ok}/{len(results)} cells OK")
    if ok != len(results):
        for r in results:
            if r["status"] != "ok":
                print(f"  FAIL: {r['arch']} x {r['shape']} x {r['mesh']}: "
                      f"{r.get('error')}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
