"""Train-step builders for every arch kind, with optional SeDA boundary.

``make_train_step(arch, cfg, opt_cfg)`` returns a pure function

    step(params, opt_state, batch) -> (params, opt_state, metrics)

suitable for ``jax.jit`` with in/out shardings from the planner.  When
``secure`` is given, the step runs inside the SecureExecutor boundary:
params are decrypted+verified on entry and re-protected on exit (the
paper-faithful HBM-as-untrusted emulation mode, measurable in
cost_analysis).
"""

from __future__ import annotations

from typing import Callable

import jax

from repro.models import encdec as ed
from repro.models import lm as lm_mod
from repro.train.optimizer import AdamWConfig, adamw_update

__all__ = ["make_loss_fn", "make_train_step", "make_secure_train_step"]


def make_loss_fn(arch, cfg) -> Callable:
    if arch.kind == "encdec":
        return lambda params, batch: ed.encdec_loss(cfg, params, batch)
    return lambda params, batch: lm_mod.lm_loss(cfg, params, batch)


def make_train_step(arch, cfg, opt_cfg: AdamWConfig) -> Callable:
    loss_fn = make_loss_fn(arch, cfg)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = adamw_update(
            grads, params, opt_state, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_secure_train_step(arch, cfg, opt_cfg: AdamWConfig, executor,
                           region_spec) -> Callable:
    """Paper-faithful mode: params live protected in untrusted memory.

    step(secure_state, opt_state, batch, step_idx)
        -> (secure_state', opt_state', metrics)

    The decrypt -> train -> re-encrypt pipeline is one jitted program;
    `ok` (integrity verification) is returned in metrics and must be
    checked by the host loop (a False aborts training — tamper evident).
    """
    inner = make_train_step(arch, cfg, opt_cfg)

    def secure_step(secure_state, opt_state, batch, step_idx):
        params, ok = executor.unprotect(secure_state, region_spec)
        params, opt_state, metrics = inner(params, opt_state, batch)
        new_state = executor.protect(params, region_spec, step=step_idx + 1)
        metrics["integrity_ok"] = ok
        return new_state, opt_state, metrics

    return secure_step
