"""minitron-8b — pruned Nemotron [arXiv:2407.14679; hf].

[dense] 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""

from repro.configs.base import ArchDef
from repro.models.lm import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="minitron-8b",
        n_layers=32, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
        d_ff=16384, vocab=256000,
        mixer="attn", ffn="dense", tie_embeddings=True,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="minitron-8b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256, dtype="float32",
        mixer="attn", ffn="dense", q_block=16, kv_block=16, remat="none",
    )


ARCH = ArchDef(
    name="minitron-8b", family="dense", kind="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    source="arXiv:2407.14679; hf",
)
