"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: quadratic
attention-like computation inside fixed-size chunks plus a linear
inter-chunk state recurrence (lax.scan) — sub-quadratic in sequence
length, which is what qualifies the mamba2/jamba configs for the
long_500k cells.  Decode is the O(1) single-step recurrence on the
(B, H, P, N) state.

``ssd_reference`` is the naive sequential recurrence used as the test
oracle.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense, rms_norm, spec
from repro.models.partitioning import constrain

__all__ = ["Mamba2Config", "mamba2_specs", "mamba2_forward", "mamba2_decode",
           "Mamba2State", "init_mamba2_state_specs", "ssd_chunked",
           "ssd_reference"]


class Mamba2Config(NamedTuple):
    d_model: int
    d_inner: int          # expand * d_model
    head_dim: int = 64    # P
    d_state: int = 128    # N
    n_groups: int = 1     # G
    d_conv: int = 4

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def proj_dim(self) -> int:
        # [z (d_inner), x (d_inner), B (G*N), C (G*N), dt (H)]
        return (2 * self.d_inner + 2 * self.n_groups * self.d_state
                + self.n_heads)


class Mamba2State(NamedTuple):
    ssm: jax.Array     # (B, H, P, N)
    conv: jax.Array    # (B, d_conv - 1, conv_dim) rolling window
    length: jax.Array  # scalar int32


def init_mamba2_state_specs(cfg: Mamba2Config, batch: int, dtype: str):
    return Mamba2State(
        ssm=jax.ShapeDtypeStruct((batch, cfg.n_heads, cfg.head_dim,
                                  cfg.d_state), jnp.float32),
        conv=jax.ShapeDtypeStruct((batch, cfg.d_conv - 1, cfg.conv_dim),
                                  jnp.dtype(dtype)),
        length=jax.ShapeDtypeStruct((), jnp.int32),
    )


def mamba2_specs(cfg: Mamba2Config, dtype: str):
    return {
        "in_proj": spec((cfg.d_model, cfg.proj_dim), ("embed", "mlp"), dtype),
        "conv_w": spec((cfg.d_conv, cfg.conv_dim), ("conv_k", "mlp"), dtype),
        "conv_b": spec((cfg.conv_dim,), ("mlp",), dtype, init="zeros"),
        "a_log": spec((cfg.n_heads,), ("heads",), "float32", init="zeros"),
        "d_skip": spec((cfg.n_heads,), ("heads",), "float32", init="ones"),
        "dt_bias": spec((cfg.n_heads,), ("heads",), "float32", init="zeros"),
        "norm": spec((cfg.d_inner,), ("mlp",), "float32", init="ones"),
        "out_proj": spec((cfg.d_inner, cfg.d_model), ("mlp", "embed"), dtype),
    }


def _split_proj(cfg: Mamba2Config, zxbcdt):
    di, gn, h = cfg.d_inner, cfg.n_groups * cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn:]
    assert dt.shape[-1] == h
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b):
    """Depthwise causal conv over (B, L, C) with kernel (K, C)."""
    k = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1], :] * conv_w[i][None, None, :]
              for i in range(k))
    return jax.nn.silu(out + conv_b[None, None, :])


def _ssm_inputs(cfg: Mamba2Config, params, xbc_conv, dt_raw):
    """Split conv output and compute per-step decay/inputs."""
    b, l, _ = xbc_conv.shape
    di, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    x = xbc_conv[..., :di].reshape(b, l, cfg.n_heads, cfg.head_dim)
    bb = xbc_conv[..., di: di + gn].reshape(b, l, cfg.n_groups, cfg.d_state)
    cc = xbc_conv[..., di + gn:].reshape(b, l, cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])      # (B,L,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))             # (H,) < 0
    log_decay = dt * a[None, None, :]                             # (B,L,H)
    return x, bb, cc, dt, log_decay


def ssd_chunked(x, bb, cc, dt, log_decay, *, chunk: int = 256):
    """Chunked SSD scan.

    x: (B,L,H,P) f32; bb/cc: (B,L,G,N) f32; dt/log_decay: (B,L,H) f32.
    Returns y: (B,L,H,P) f32 and the final state (B,H,P,N).
    """
    b, l, h, p = x.shape
    g, n = bb.shape[2], bb.shape[3]
    heads_per_group = h // g
    chunk = min(chunk, l)
    nc = -(-l // chunk)
    lp = nc * chunk
    if lp != l:
        padw = ((0, 0), (0, lp - l), (0, 0), (0, 0))
        x = jnp.pad(x, padw)
        bb = jnp.pad(bb, padw)
        cc = jnp.pad(cc, padw)
        dt = jnp.pad(dt, ((0, 0), (0, lp - l), (0, 0)))
        log_decay = jnp.pad(log_decay, ((0, 0), (0, lp - l), (0, 0)))

    # Broadcast groups to heads.
    def g2h(t):  # (B,L,G,N) -> (B,L,H,N)
        return jnp.repeat(t, heads_per_group, axis=2)

    bbh, cch = g2h(bb), g2h(cc)
    xd = x * dt[..., None]  # dt-weighted inputs

    # Reshape to chunks: (nc, B, chunk, ...)
    def to_chunks(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xc, bc, ccc = to_chunks(xd), to_chunks(bbh), to_chunks(cch)
    ldc = to_chunks(log_decay)  # (nc, B, chunk, H)

    def chunk_step(h_prev, inputs):
        xi, bi, ci, ld = inputs           # (B,Q,H,P), (B,Q,H,N), ..., (B,Q,H)
        cum = jnp.cumsum(ld, axis=1)      # (B,Q,H) log prod a_1..a_i
        total = cum[:, -1]                # (B,H)
        # Intra-chunk (attention-like with decay kernel):
        # L[i,j] = exp(cum_i - cum_j) for i >= j.
        li = cum[:, :, None, :] - cum[:, None, :, :]          # (B,Q,Q,H)
        iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        ik = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        causal = (ik <= iq)[None, :, :, None]
        lmat = jnp.where(causal, jnp.exp(li), 0.0)
        scores = jnp.einsum("bqhn,bkhn->bqkh", ci, bi) * lmat
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", scores, xi)
        # Inter-chunk: contribution of the carried state.
        decay_in = jnp.exp(cum)                               # (B,Q,H)
        y_inter = jnp.einsum("bqhn,bhpn->bqhp", ci * decay_in[..., None],
                             h_prev)
        # State update: S = sum_j exp(total - cum_j) B_j x_j^T.
        decay_out = jnp.exp(total[:, None, :] - cum)          # (B,Q,H)
        s_new = jnp.einsum("bqhn,bqhp->bhpn", bi * decay_out[..., None], xi)
        h_next = jnp.exp(total)[..., None, None] * h_prev + s_new
        return h_next, y_intra + y_inter

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    h_final, ys = jax.lax.scan(chunk_step, h0, (xc, bc, ccc, ldc))
    y = ys.swapaxes(0, 1).reshape(b, lp, h, p)[:, :l]
    return y, h_final


def ssd_reference(x, bb, cc, dt, log_decay):
    """Naive sequential recurrence (test oracle): O(L) python loop."""
    b, l, h, p = x.shape
    g, n = bb.shape[2], bb.shape[3]
    hpg = h // g
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(l):
        a_t = jnp.exp(log_decay[:, t])                        # (B,H)
        bt = jnp.repeat(bb[:, t], hpg, axis=1)                # (B,H,N)
        ct = jnp.repeat(cc[:, t], hpg, axis=1)
        xt = x[:, t] * dt[:, t][..., None]                    # (B,H,P)
        state = (a_t[..., None, None] * state
                 + jnp.einsum("bhn,bhp->bhpn", bt, xt))
        ys.append(jnp.einsum("bhpn,bhn->bhp", state, ct))
    return jnp.stack(ys, axis=1), state


def mamba2_forward(cfg: Mamba2Config, params, x, *, chunk: int = 256,
                   return_state: bool = False):
    """Full block: x (B, L, d_model) -> (B, L, d_model).

    The fused [z|x|B|C|dt] projection is applied as per-stream weight
    slices (static) instead of slicing the activation: activation
    splits at non-shard-aligned channel offsets forced SPMD to reshard
    each piece — 84 GB/chip/step of collective-permute on the 48L
    config (§Perf hillclimb, EXPERIMENTS.md).  Depthwise conv commutes
    with the channel split, so the math is unchanged.
    """
    di, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    w = params["in_proj"]
    cw, cb = params["conv_w"], params["conv_b"]
    z = dense(x, w[:, :di])
    xp = dense(x, w[:, di: 2 * di])
    bp = dense(x, w[:, 2 * di: 2 * di + gn])
    cp = dense(x, w[:, 2 * di + gn: 2 * di + 2 * gn])
    dt_raw = dense(x, w[:, 2 * di + 2 * gn:])
    xp = constrain(xp, "batch", None, "mlp")
    xp = _causal_conv(xp, cw[:, :di], cb[:di])
    bp = _causal_conv(bp, cw[:, di: di + gn], cb[di: di + gn])
    cp = _causal_conv(cp, cw[:, di + gn:], cb[di + gn:])
    b_, l_ = x.shape[:2]
    xi = xp.reshape(b_, l_, cfg.n_heads, cfg.head_dim)
    bb = bp.reshape(b_, l_, cfg.n_groups, cfg.d_state)
    cc = cp.reshape(b_, l_, cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    ld = dt * a[None, None, :]
    y, state = ssd_chunked(xi.astype(jnp.float32), bb.astype(jnp.float32),
                           cc.astype(jnp.float32), dt, ld, chunk=chunk)
    y = y + params["d_skip"][None, None, :, None] * xi.astype(jnp.float32)
    y = y.reshape(x.shape[0], x.shape[1], cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = dense(y, params["out_proj"])
    if return_state:
        return out, state
    return out


def mamba2_decode(cfg: Mamba2Config, params, x, state: Mamba2State):
    """Single-token decode: x (B, 1, d_model) -> (out, new_state)."""
    b = x.shape[0]
    zxbcdt = dense(x, params["in_proj"])
    z, xbc_new, dt_raw = _split_proj(cfg, zxbcdt)

    # Rolling causal conv window.
    window = jnp.concatenate([state.conv, xbc_new.astype(state.conv.dtype)],
                             axis=1)                     # (B, d_conv, C)
    conv_out = jnp.einsum("bkc,kc->bc", window, params["conv_w"])
    xbc = jax.nn.silu(conv_out + params["conv_b"][None, :])[:, None, :]
    new_conv = window[:, 1:, :]

    xi, bb, cc, dt, ld = _ssm_inputs(cfg, params, xbc, dt_raw)
    a_t = jnp.exp(ld[:, 0])                              # (B,H)
    hpg = cfg.n_heads // cfg.n_groups
    bt = jnp.repeat(bb[:, 0], hpg, axis=1).astype(jnp.float32)
    ct = jnp.repeat(cc[:, 0], hpg, axis=1).astype(jnp.float32)
    xt = (xi[:, 0] * dt[:, 0][..., None]).astype(jnp.float32)
    new_ssm = (a_t[..., None, None] * state.ssm
               + jnp.einsum("bhn,bhp->bhpn", bt, xt))
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, ct)
    y = y + params["d_skip"][None, :, None] * xi[:, 0].astype(jnp.float32)
    y = y.reshape(b, 1, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = dense(y, params["out_proj"])
    return out, Mamba2State(new_ssm, new_conv, state.length + 1)
