"""Multi-tenant key management for the secure serving engine.

`keys`     — hierarchical AES-based KDF: root key -> per-tenant master
             -> purpose-split {encrypt, MAC, VN} keys -> numbered epoch
             keys, with explicit epoch rotation.
`registry` — tenant registration, per-tenant page quotas / weights,
             session handles, and the device-resident key bank the
             serving data plane gathers per-page keys from.
"""

from repro.tenancy.keys import KeyHierarchy, TenantKeySet
from repro.tenancy.registry import (KeyBank, SessionHandle, Tenant,
                                    TenantRegistry)

__all__ = ["KeyHierarchy", "TenantKeySet", "KeyBank", "SessionHandle",
           "Tenant", "TenantRegistry"]
