"""Optimizer + gradient compression + elastic resharding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.grad_comp import (CompressionState, compress_grads,
                                   init_compression,
                                   make_compressed_train_step)
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


class TestAdamW:
    def test_step_moves_toward_minimum(self):
        cfg = AdamWConfig(lr=0.1, warmup_steps=1, weight_decay=0.0)
        params = {"w": jnp.asarray([4.0, -3.0])}
        opt = init_opt_state(params, cfg)
        for _ in range(50):
            grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
            params, opt, m = adamw_update(grads, params, opt, cfg)
        assert float(jnp.abs(params["w"]).max()) < 1.0
        assert int(opt.count) == 50

    def test_grad_clip(self):
        cfg = AdamWConfig(lr=1e-3, grad_clip=1.0)
        params = {"w": jnp.ones(4)}
        opt = init_opt_state(params, cfg)
        _, _, m = adamw_update({"w": jnp.full(4, 1e6)}, params, opt, cfg)
        assert float(m["grad_norm"]) > 1e5  # reported pre-clip

    def test_bf16_state_dtype(self):
        cfg = AdamWConfig(state_dtype="bfloat16")
        params = {"w": jnp.ones(4, jnp.bfloat16)}
        opt = init_opt_state(params, cfg)
        assert opt.mu["w"].dtype == jnp.bfloat16
        p2, opt2, _ = adamw_update({"w": jnp.ones(4, jnp.bfloat16)}, params,
                                   opt, cfg)
        assert opt2.nu["w"].dtype == jnp.bfloat16
        assert p2["w"].dtype == jnp.bfloat16


class TestGradCompression:
    def test_error_feedback_bounds_bias(self):
        """With error feedback, the *accumulated* applied gradient tracks
        the true gradient sum despite int8 quantization."""
        rng = np.random.default_rng(0)
        g_true = jnp.asarray(rng.standard_normal((64,)), jnp.float32) * 1e-3
        state = init_compression({"w": g_true})
        applied = jnp.zeros_like(g_true)
        for _ in range(20):
            deq, state = compress_grads({"w": g_true}, state)
            applied = applied + deq["w"]
        total_err = float(jnp.abs(applied - 20 * g_true).max())
        # residual is at most one quantization step, not 20.
        one_step = float(jnp.max(jnp.abs(g_true))) / 127
        assert total_err <= 2 * one_step

    def test_compressed_step_trains(self):
        cfg = AdamWConfig(lr=0.05, warmup_steps=1, weight_decay=0.0)

        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"]
            loss = jnp.mean((pred - batch["y"]) ** 2)
            return loss, {}

        def opt_update(grads, params, opt_state):
            return adamw_update(grads, params, opt_state, cfg)

        step = jax.jit(make_compressed_train_step(loss_fn, opt_update))
        rng = np.random.default_rng(1)
        w_true = jnp.asarray(rng.standard_normal((8,)), jnp.float32)
        x = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
        batch = {"x": x, "y": x @ w_true}
        params = {"w": jnp.zeros(8)}
        opt = init_opt_state(params, cfg)
        comp = init_compression(params)
        first = None
        for _ in range(60):
            params, opt, comp, m = step(params, opt, comp, batch)
            if first is None:
                first = float(m["loss"])
        assert float(m["loss"]) < first * 0.2


class TestElastic:
    def test_reshard_roundtrip_single_device(self):
        from repro.launch.elastic import plan_for_mesh, reshard_params
        from repro.models.layers import init_params
        from repro.models import lm as lm_mod
        from repro.configs import get_arch
        from jax.sharding import Mesh
        arch = get_arch("smollm-135m")
        cfg = arch.make_smoke_config()
        params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
        dev = np.array(jax.devices()[:1]).reshape(1, 1)
        mesh = Mesh(dev, ("data", "model"))
        out = reshard_params(params, "smollm-135m", mesh, smoke=True)
        for a, b in zip(jax.tree_util.tree_leaves(out),
                        jax.tree_util.tree_leaves(params)):
            assert (np.asarray(a) == np.asarray(b)).all()
