"""SecureExecutor — SeDA as a first-class feature of the training/serving loop.

Wraps a jitted step function so that designated pytrees (params,
optimizer state, activations being offloaded) live *protected* in
untrusted memory: the step decrypts+verifies on entry and
re-encrypts+MACs on exit.  The whole protect/step/unprotect pipeline is
one jitted computation, so `cost_analysis()` of the compiled artifact
exposes the security overhead exactly the way the paper's simulator
measures DRAM traffic.

Schemes (paper Table III):

  off      — no protection (unprotected baseline)
  sgx64    — 64B granularity, per-block gate, off-chip VN + integrity
             tree emulated (extra metadata tensors are read/written so
             the traffic is HLO-visible)
  sgx512   — 512B granularity variant
  mgx64    — 64B granularity, per-block MACs, on-chip VNs (no tree)
  mgx512   — 512B granularity variant
  seda     — B-AES + multi-level MACs: layer MAC gate, model MAC deferred

The integrity-tree emulation for ``sgx*`` charges the canonical
8-ary-tree metadata bytes: per protected block, one VN read plus
ceil(log8(n_blocks)) tree-node touches (see sim/memprot.py for the
trace-level model used in the paper-reproduction benchmarks).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import vn
from repro.core import secure_memory as sm

__all__ = ["SchemeConfig", "SCHEMES", "SecureExecutor", "emulated_tree_probe"]


def emulated_tree_probe(n_blocks: int) -> jax.Array:
    """Touch VN-table + 8-ary-tree-node bytes so HLO traffic matches SGX.

    The check itself is a tautology (we model traffic, not a second MAC
    hierarchy); `sim/` carries the faithful per-access model.  Shared by
    the training-loop executor and the paged serving pool so the two
    paths charge identical emulated metadata traffic.
    """
    # 8B VN per block + 8-ary tree nodes (64B each) above them.
    n_nodes = 0
    level = max(1, n_blocks)
    while level > 1:
        level = (level + 7) // 8
        n_nodes += level
    vn_table = jnp.zeros((max(1, n_blocks), 2), jnp.uint32)
    tree_nodes = jnp.zeros((max(1, n_nodes), 16), jnp.uint32)
    probe = (jnp.sum(vn_table) + jnp.sum(tree_nodes)).astype(jnp.uint32)
    return probe == jnp.uint32(0)


@dataclasses.dataclass(frozen=True)
class SchemeConfig:
    name: str
    block_bytes: int          # protection granularity
    verify: str               # "layer" | "block" | "none"
    mac_engine: str           # "nh" | "cbc" | "naive"
    emulate_vn_offchip: bool  # SGX: VN table in untrusted memory
    emulate_tree: bool        # SGX: integrity-tree traffic
    baes: bool                # bandwidth-aware encryption (False = T-AES)


SCHEMES = {
    "off": SchemeConfig("off", 64, "none", "nh", False, False, True),
    "sgx64": SchemeConfig("sgx64", 64, "block", "nh", True, True, False),
    "sgx512": SchemeConfig("sgx512", 512, "block", "nh", True, True, False),
    "mgx64": SchemeConfig("mgx64", 64, "block", "nh", False, False, False),
    "mgx512": SchemeConfig("mgx512", 512, "block", "nh", False, False, False),
    "seda": SchemeConfig("seda", 64, "layer", "nh", False, False, True),
    # Beyond-paper: wide-block B-AES (512B optBlk) — 8x fewer AES
    # invocations per protected byte via wide-mode diversification.
    "seda512": SchemeConfig("seda512", 512, "layer", "nh", False, False, True),
}


class SecureExecutor:
    """Wraps ``step_fn(params, *args) -> (params, aux)`` with the boundary.

    Typical use::

        ex = SecureExecutor(scheme="seda", keys=SecureKeys.derive(0))
        spec = ex.region_spec(params)
        protected = ex.protect(params, spec, step=0)
        protected, aux, ok = ex.step(step_fn, protected, spec, step, *args)
    """

    def __init__(self, scheme: str = "seda", keys: sm.SecureKeys | None = None,
                 role: int = int(vn.Role.WEIGHT)):
        self.cfg = SCHEMES[scheme]
        self.keys = keys if keys is not None else sm.SecureKeys.derive(0)
        self.role = role

    # -- region handling ----------------------------------------------------

    def region_spec(self, tree: Any, layer_of=None) -> sm.RegionSpec:
        return sm.make_region_spec(
            tree, block_bytes=self.cfg.block_bytes,
            mac_engine=self.cfg.mac_engine, role=self.role, layer_of=layer_of,
            use_baes=self.cfg.baes)

    def protect(self, tree: Any, spec: sm.RegionSpec, *, step=0) -> sm.SecureState:
        if self.cfg.name == "off":
            return tree  # passthrough: unprotected baseline
        return sm.protect(tree, self.keys, spec, step=step)

    def unprotect(self, state, spec: sm.RegionSpec):
        if self.cfg.name == "off":
            return state, jnp.asarray(True)
        verify = {"layer": "layer", "block": "layer", "none": "none"}[self.cfg.verify]
        tree, ok = sm.unprotect(state, self.keys, spec, verify=verify)
        if self.cfg.emulate_tree:
            ok = ok & self._emulated_tree_check(state)
        return tree, ok

    # -- the wrapped step ----------------------------------------------------

    def make_secure_step(self, step_fn: Callable, spec: sm.RegionSpec) -> Callable:
        """Return a jittable ``(state, step_idx, *args) -> (state', aux, ok)``."""
        cfg = self.cfg
        keys = self.keys

        if cfg.name == "off":
            def insecure_step(state, step_idx, *args):
                new_tree, aux = step_fn(state, *args)
                return new_tree, aux, jnp.asarray(True)
            return insecure_step

        def secure_step(state: sm.SecureState, step_idx, *args):
            tree, ok = self.unprotect(state, spec)
            new_tree, aux = step_fn(tree, *args)
            new_state = sm.protect(new_tree, keys, spec, step=step_idx + 1)
            return new_state, aux, ok

        return secure_step

    # -- SGX integrity-tree emulation ----------------------------------------

    def _emulated_tree_check(self, state: sm.SecureState) -> jax.Array:
        total_blocks = sum(ct.shape[0] // self.cfg.block_bytes
                           for ct in state.ciphertexts)
        return emulated_tree_probe(total_blocks)
