"""Observability layer for the secure serving stack.

Three cooperating pieces, all host-side and dependency-free:

* :mod:`repro.obs.metrics` — a declared-metrics registry (counters,
  gauges, histograms) that replaces the engines' raw ``stats`` dicts
  while keeping the old dict API bit-compatible via
  :class:`~repro.obs.metrics.StatsView`;
* :mod:`repro.obs.trace` — a ring-buffer span tracer for the tick
  phases, exporting Chrome trace-event JSON (Perfetto-loadable);
* :mod:`repro.obs.audit` — an append-only SHA-256 hash-chained audit
  log of security-relevant events (integrity verdicts, rotations,
  reseals, migrations, prefix cache traffic) whose
  ``verify_chain()`` makes tampering with the log itself detectable;
* :mod:`repro.obs.profiler` — compiled-HLO cost attribution splitting
  the decode step's bytes/flops into protection vs. model work, with
  roofline utilization per decode variant;
* :mod:`repro.obs.slo` — per-tenant SLO watchdog (TTFT, p99 tick
  latency, integrity-failure rate, stuck ticks) that feeds breach
  counters and audit events off the existing tick-phase hooks.

Everything here is disabled-by-default on the hot path: counters cost
one attribute bump (same order as the dict they replaced), gauges are
sampled lazily at snapshot time, and span/phase timing only runs when
a tracer was explicitly attached (``Engine(trace=...)``).
"""

from repro.obs.audit import AuditLog
from repro.obs.metrics import (CLUSTER_COUNTERS, ENGINE_COUNTERS,
                               ENGINE_GAUGES, ENGINE_HISTOGRAMS,
                               MetricsRegistry, StatsView)
from repro.obs.profiler import (CostProfile, attribute_hlo,
                                classify_source, profile_decode)
from repro.obs.slo import SLOMonitor, merge_health
from repro.obs.trace import SpanTracer

__all__ = ["AuditLog", "CLUSTER_COUNTERS", "CostProfile",
           "ENGINE_COUNTERS", "ENGINE_GAUGES", "ENGINE_HISTOGRAMS",
           "MetricsRegistry", "SLOMonitor", "SpanTracer", "StatsView",
           "attribute_hlo", "classify_source", "merge_health",
           "profile_decode"]
