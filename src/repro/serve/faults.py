"""Deterministic fault injection for the secure serving stack.

The chaos harness for ISSUE 9: a :class:`FaultPlan` is a seeded,
replayable schedule of memory-tamper and availability faults that a
test or benchmark attaches to a live engine (or cluster).  Faults fire
from a wrapper around ``_tick_begin`` — *after* admission has written
the tick's pages and *before* decode reads them back — so every state
fault models exactly what SeDA's threat model assumes: untrusted
memory mutated between a verified write and the next read.

State faults therefore mutate ``engine._pool`` directly, bypassing the
pool-property setter and its listeners: the incrementally-maintained
cluster mirrors must *not* observe the tamper, precisely as a physical
attacker bypasses the accelerator's MAC pipeline.

Fault kinds
-----------
``bitflip``
    XOR one ciphertext byte of a resident page (leaf 0).
``vn_bump``
    Increment a page's version number — a freshness/replay violation.
``page_swap``
    Swap two resident pages wholesale (ciphertext, MACs, VN).  The
    XOR pool MAC is invariant under swaps; only per-page binding to
    the physical page id catches this.
``mac_corrupt``
    Flip a byte of a stored page MAC.
``pool_mac_zap``
    Flip a byte of the deferred pool MAC itself — only the deferred
    model-level check can see this.
``transient``
    Force one decode verdict to ``False`` without touching state,
    via :attr:`PageIO.fault_hooks` — models a transient read glitch
    that a bounded re-read distinguishes from persistent tamper.
``shard_kill``
    Raise ``IntegrityError`` out of the target shard's tick — the
    cluster-level availability fault driving shard failover.

:class:`RecoveryPolicy` (the engine's ``fault_tolerance`` knob) also
lives here so the containment layer and the harness share one module.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

__all__ = ["FAULT_KINDS", "Fault", "FaultPlan", "RecoveryPolicy"]

FAULT_KINDS = ("bitflip", "vn_bump", "page_swap", "mac_corrupt",
               "pool_mac_zap", "transient", "shard_kill")

#: Fault kinds that mutate pool state (vs. verdict/availability faults).
STATE_FAULTS = ("bitflip", "vn_bump", "page_swap", "mac_corrupt",
                "pool_mac_zap")


@dataclasses.dataclass
class RecoveryPolicy:
    """Knobs for quarantine-and-recompute recovery.

    ``max_retries`` bounds how often one session may be preempted for
    integrity recovery before it is declared dead (``sessions_lost``);
    re-admission of attempt *k* is held back ``backoff_ticks * 2**(k-1)``
    ticks.  ``reread_retries`` bounds the extra re-reads a failing page
    gets during localization before it is condemned as persistent
    tamper rather than a transient fault.
    """

    max_retries: int = 3
    backoff_ticks: int = 1
    reread_retries: int = 1


@dataclasses.dataclass
class Fault:
    """One scheduled fault.

    ``tick`` is the earliest engine tick (post-increment, i.e. the
    value ``engine.tick`` holds during that tick's decode) at which the
    fault fires; a state fault whose target slot is not yet occupied
    stays armed and retries each tick.  ``page`` overrides slot-based
    targeting with an absolute physical page id; otherwise the target
    is ``engine.slots[slot].pages[page_pos]`` resolved at fire time.
    ``page2`` names the swap partner for ``page_swap`` (default: the
    slot's next resident page).  ``bit`` selects the byte/bit position
    for ``bitflip``.
    """

    tick: int
    kind: str
    shard: int = 0
    slot: int = 0
    page_pos: int = 0
    page: Optional[int] = None
    page2: Optional[int] = None
    bit: int = 0
    fired: bool = False


class FaultPlan:
    """A deterministic, seeded schedule of faults.

    Attach with :meth:`attach` (one engine) or :meth:`attach_cluster`
    (every shard engine); both are idempotent per engine.  The plan
    records what actually fired in :attr:`fired` for assertions.
    """

    def __init__(self, faults):
        faults = list(faults)
        for f in faults:
            if f.kind not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {f.kind!r}; "
                                 f"expected one of {FAULT_KINDS}")
        self.faults = faults
        self.fired: list = []

    @classmethod
    def random(cls, seed: int, *, n_faults: int = 1,
               tick_range=(2, 8), kinds=("bitflip",),
               n_shards: int = 1, n_slots: int = 1) -> "FaultPlan":
        """Seeded random plan — same seed, same schedule, always."""
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            faults.append(Fault(
                tick=int(rng.integers(tick_range[0], tick_range[1])),
                kind=kind,
                shard=int(rng.integers(n_shards)),
                slot=int(rng.integers(n_slots)),
                bit=int(rng.integers(64))))
        return cls(faults)

    # -- attachment ---------------------------------------------------------

    def attach(self, engine) -> "FaultPlan":
        """Hook this plan into one engine's tick and verdict paths."""
        plan = self
        orig_begin = engine._tick_begin

        def tick_begin(*a, **kw):
            out = orig_begin(*a, **kw)
            plan._fire(engine)
            return out

        engine._tick_begin = tick_begin
        engine.page_io.fault_hooks.append(self._verdict_hook(engine))
        return self

    def attach_cluster(self, cluster) -> "FaultPlan":
        """Hook this plan into every shard engine of a cluster."""
        for engine in cluster.engines:
            self.attach(engine)
        return self

    # -- firing -------------------------------------------------------------

    def _due(self, engine):
        shard = getattr(engine, "shard_id", 0)
        return [f for f in self.faults
                if not f.fired and f.shard == shard
                and engine.tick >= f.tick]

    def _fire(self, engine) -> None:
        for f in self._due(engine):
            if f.kind == "shard_kill":
                self._mark(f)
                from repro.serve.engine import IntegrityError
                raise IntegrityError(
                    f"injected shard-kill fault on shard {f.shard} "
                    f"at tick {engine.tick}")
            if f.kind == "transient":
                continue        # fires from the verdict hook instead
            if self._apply_state(engine, f):
                self._mark(f)

    def _verdict_hook(self, engine):
        plan = self

        def hook(ok: bool, op: str, ctx: dict) -> bool:
            if op != "decode":
                return ok
            for f in plan._due(engine):
                if f.kind == "transient":
                    plan._mark(f)
                    return False
            return ok

        return hook

    def _mark(self, fault: Fault) -> None:
        fault.fired = True
        self.fired.append(fault)

    # -- state mutation (bypasses the pool setter on purpose) ---------------

    def _resolve(self, engine, fault: Fault):
        """(page, page2) physical targets, or None if not yet hittable."""
        if fault.page is not None:
            return int(fault.page), fault.page2
        if fault.slot >= len(engine.slots):
            return None
        slot = engine.slots[fault.slot]
        if slot is None or fault.page_pos >= len(slot.pages):
            return None
        pid = int(slot.pages[fault.page_pos])
        pid2 = fault.page2
        if fault.kind == "page_swap" and pid2 is None:
            nxt = fault.page_pos + 1
            if nxt >= len(slot.pages):
                return None
            pid2 = int(slot.pages[nxt])
        return pid, pid2

    def _apply_state(self, engine, fault: Fault) -> bool:
        pool = engine._pool
        if fault.kind == "pool_mac_zap":
            pm = pool.pool_mac
            engine._pool = pool._replace(
                pool_mac=pm.at[0].set(pm[0] ^ np.uint8(0xFF)))
            return True
        target = self._resolve(engine, fault)
        if target is None:
            return False        # slot not occupied yet; stay armed
        pid, pid2 = target
        if fault.kind == "bitflip":
            ct = pool.cts[0]
            b = fault.bit % int(ct.shape[1])
            new_ct = ct.at[pid, b].set(
                ct[pid, b] ^ np.uint8(1 << (fault.bit % 8)))
            engine._pool = pool._replace(cts=(new_ct,) + pool.cts[1:])
        elif fault.kind == "vn_bump":
            engine._pool = pool._replace(
                page_vns=pool.page_vns.at[pid].add(1))
        elif fault.kind == "mac_corrupt":
            pm = pool.page_macs
            engine._pool = pool._replace(
                page_macs=pm.at[pid, 0].set(pm[pid, 0] ^ np.uint8(0xFF)))
        elif fault.kind == "page_swap":
            idx = jnp.asarray([pid, pid2])
            rev = jnp.asarray([pid2, pid])
            engine._pool = pool._replace(
                cts=tuple(ct.at[idx].set(ct[rev]) for ct in pool.cts),
                page_macs=pool.page_macs.at[idx].set(pool.page_macs[rev]),
                block_macs=tuple(bm.at[idx].set(bm[rev])
                                 for bm in pool.block_macs),
                page_vns=pool.page_vns.at[idx].set(pool.page_vns[rev]))
        else:  # pragma: no cover - guarded by FAULT_KINDS validation
            raise ValueError(fault.kind)
        return True
