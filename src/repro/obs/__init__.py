"""Observability layer for the secure serving stack.

Three cooperating pieces, all host-side and dependency-free:

* :mod:`repro.obs.metrics` — a declared-metrics registry (counters,
  gauges, histograms) that replaces the engines' raw ``stats`` dicts
  while keeping the old dict API bit-compatible via
  :class:`~repro.obs.metrics.StatsView`;
* :mod:`repro.obs.trace` — a ring-buffer span tracer for the tick
  phases, exporting Chrome trace-event JSON (Perfetto-loadable);
* :mod:`repro.obs.audit` — an append-only SHA-256 hash-chained audit
  log of security-relevant events (integrity verdicts, rotations,
  reseals, migrations, prefix cache traffic) whose
  ``verify_chain()`` makes tampering with the log itself detectable.

Everything here is disabled-by-default on the hot path: counters cost
one attribute bump (same order as the dict they replaced), gauges are
sampled lazily at snapshot time, and span/phase timing only runs when
a tracer was explicitly attached (``Engine(trace=...)``).
"""

from repro.obs.audit import AuditLog
from repro.obs.metrics import (CLUSTER_COUNTERS, ENGINE_COUNTERS,
                               ENGINE_GAUGES, ENGINE_HISTOGRAMS,
                               MetricsRegistry, StatsView)
from repro.obs.trace import SpanTracer

__all__ = ["AuditLog", "CLUSTER_COUNTERS", "ENGINE_COUNTERS",
           "ENGINE_GAUGES", "ENGINE_HISTOGRAMS", "MetricsRegistry",
           "SpanTracer", "StatsView"]
