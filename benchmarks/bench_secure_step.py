"""Secure-execution overhead in the JAX training loop (per scheme).

The JAX analogue of Fig. 6: a small LM train step wrapped by the
SecureExecutor under each protection scheme, measured in wall time on
CPU and in crypto work (AES calls per step).  Shows the same ordering
the paper's simulator produces: sgx64 > mgx64 > seda ~ off.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import SecureExecutor
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step

try:                                    # package or script invocation
    from benchmarks._meta import stamp
except ImportError:
    from _meta import stamp


def run() -> list:
    arch = get_arch("minitron-4b")
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = init_opt_state(params, opt_cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 33), dtype=np.int64)
                       .astype(np.int32))
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    inner = make_train_step(arch, cfg, opt_cfg)

    total_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree_util.tree_leaves(params))

    rows = []
    base_us = None
    for scheme in ("off", "seda", "seda512", "mgx64", "sgx64"):
        ex = SecureExecutor(scheme=scheme)
        spec = ex.region_spec(params)

        def step3(state, opt):
            def one(carry, idx):
                state, opt = carry
                tree, ok = ex.unprotect(state, spec)
                tree, opt, m = inner(tree, opt, batch)
                state = ex.protect(tree, spec, step=idx)
                return (state, opt), m["loss"]
            (state, opt), losses = jax.lax.scan(one, (state, opt),
                                                jnp.arange(3))
            return state, opt, losses

        state = ex.protect(params, spec, step=0)
        f = jax.jit(step3)
        f(state, opt)  # compile
        t0 = time.perf_counter()
        jax.block_until_ready(f(state, opt))
        us = (time.perf_counter() - t0) / 3 * 1e6
        if scheme == "off":
            base_us = us
        if scheme == "off":
            crypto = "none"
        else:
            bb = ex.cfg.block_bytes
            aes_per_protect = (total_bytes // bb if ex.cfg.baes
                               else total_bytes // 16)
            crypto = (f"aes_calls/step~{2 * aes_per_protect} "
                      f"granularity={bb}B baes={ex.cfg.baes}")
        rows.append({
            "name": f"secure_step_{scheme}",
            "us_per_call": us,
            "derived": f"overhead={(us / base_us - 1):+.1%} {crypto}",
        })
    return rows


def main(argv=None) -> list:
    """Standalone JSON mode for the CI perf-smoke job."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, help="write rows to this file")
    args = ap.parse_args(argv)
    rows = run()
    for row in rows:
        print(f"[secure-step] {row['name']:<24} "
              f"{row['us_per_call']:12.1f}us  {row['derived']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(stamp({"benchmark": "secure_step", "results": rows}),
                      f, indent=2)
        print(f"[secure-step] wrote {args.json}")
    return rows


if __name__ == "__main__":
    main()
