"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import aes, baes, mac
from repro.core.secure_memory import SecureKeys
from repro.kernels.aes_ctr import ops as aes_ops
from repro.kernels.aes_ctr.ref import (aes_ctr_keystream_lanes_ref,
                                       aes_ctr_keystream_ref)
from repro.kernels.fused_crypt_mac.kernel import (fused_crypt_mac_mixed,
                                                  fused_crypt_mac_write,
                                                  fused_crypt_mac_write_mixed)
from repro.kernels.fused_crypt_mac.ops import (secure_read_kernel,
                                               secure_read_kernel_mixed,
                                               secure_write_kernel,
                                               secure_write_kernel_mixed)
from repro.kernels.fused_crypt_mac.ref import (fused_crypt_mac_mixed_ref,
                                               fused_crypt_mac_write_mixed_ref,
                                               fused_crypt_mac_write_ref)
from repro.kernels.otp_xor import ops as ox_ops
from repro.kernels.otp_xor.ref import otp_xor_ref
from repro.kernels.xormac import ops as xm_ops
from repro.kernels.xormac.ref import nh_hash_ref


@pytest.fixture(scope="module")
def kkeys():
    return SecureKeys.derive(77)


class TestAESCTRKernel:
    @pytest.mark.parametrize("n", [1, 7, 256, 1000])
    @pytest.mark.parametrize("subbytes", ["take", "onehot"])
    def test_vs_oracle(self, kkeys, n, subbytes):
        rng = np.random.default_rng(n)
        cw = jnp.asarray(rng.integers(0, 2**32, (n, 4), dtype=np.uint32))
        got = aes_ops.keystream_lanes(cw, kkeys.round_keys, subbytes=subbytes)
        want = aes_ctr_keystream_lanes_ref(cw, kkeys.round_keys)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_bytes_layout(self, kkeys):
        cw = jnp.asarray([[0, 5, 0, 9]], dtype=jnp.uint32)
        got = aes_ops.keystream_bytes(cw, kkeys.round_keys)
        want = aes_ctr_keystream_ref(cw, kkeys.round_keys)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("tile_n", [8, 64, 512])
    def test_tile_sweep(self, kkeys, tile_n):
        rng = np.random.default_rng(1)
        cw = jnp.asarray(rng.integers(0, 2**32, (100, 4), dtype=np.uint32))
        got = aes_ops.keystream_lanes(cw, kkeys.round_keys)
        from repro.kernels.aes_ctr.kernel import aes_ctr_keystream
        got_t = aes_ctr_keystream(cw, kkeys.round_keys, tile_n=tile_n)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(got_t))


class TestOtpXorKernel:
    @pytest.mark.parametrize("n,s", [(1, 2), (13, 4), (300, 8), (64, 32)])
    def test_vs_oracle(self, n, s):
        rng = np.random.default_rng(n * s)
        data = jnp.asarray(rng.integers(0, 2**32, (n, s * 4), dtype=np.uint32))
        base = jnp.asarray(rng.integers(0, 2**32, (n, 4), dtype=np.uint32))
        div = jnp.asarray(rng.integers(0, 2**32, (s, 4), dtype=np.uint32))
        got = ox_ops.otp_xor(data, base, div)
        want = otp_xor_ref(data, base, div)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("block_bytes", [32, 64, 128])
    def test_full_baes_path_vs_core(self, kkeys, block_bytes):
        rng = np.random.default_rng(0)
        n = 40
        pt = jnp.asarray(rng.integers(0, 256, block_bytes * n, dtype=np.uint8))
        cw = jnp.asarray(np.stack(
            [np.zeros(n, np.uint32),
             np.arange(n, dtype=np.uint32) * (block_bytes // 16),
             np.zeros(n, np.uint32), np.full(n, 3, np.uint32)], -1))
        got = ox_ops.baes_encrypt_kernel(pt, kkeys.round_keys, cw,
                                         block_bytes=block_bytes)
        want = baes.baes_encrypt(pt, kkeys.round_keys, cw,
                                 block_bytes=block_bytes, key=kkeys.key)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestXorMacKernel:
    @pytest.mark.parametrize("n,lanes", [(1, 8), (50, 24), (200, 136)])
    def test_nh_vs_oracle(self, kkeys, n, lanes):
        rng = np.random.default_rng(n)
        payload = jnp.asarray(rng.integers(0, 2**32, (n, lanes),
                                           dtype=np.uint32))
        key = kkeys.hash_key[:lanes]
        got = xm_ops.nh_hash_kernel_call(payload, key)
        want = nh_hash_ref(payload, key)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_block_macs_bitexact_vs_core(self, kkeys):
        rng = np.random.default_rng(2)
        blocks = jnp.asarray(rng.integers(0, 256, (33, 64), dtype=np.uint8))
        bind = mac.Binding.make(np.arange(33) * 4, 7, 2, 1, np.arange(33))
        got = xm_ops.block_macs_kernel(blocks, bind,
                                       hash_key_u32=kkeys.hash_key,
                                       round_keys=kkeys.round_keys)
        want = mac.block_macs(blocks, bind, hash_key_u32=kkeys.hash_key,
                              round_keys=kkeys.round_keys, engine="nh")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_layer_mac_bitexact(self, kkeys):
        rng = np.random.default_rng(3)
        blocks = jnp.asarray(rng.integers(0, 256, (16, 64), dtype=np.uint8))
        bind = mac.Binding.make(np.arange(16) * 4, 9, 0, 0, np.arange(16))
        got = xm_ops.layer_mac_kernel(blocks, bind,
                                      hash_key_u32=kkeys.hash_key,
                                      round_keys=kkeys.round_keys)
        want = mac.layer_mac(blocks, bind, hash_key_u32=kkeys.hash_key,
                             round_keys=kkeys.round_keys)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestFusedCryptMac:
    @pytest.mark.parametrize("n_blocks", [4, 40])
    def test_fused_read_path(self, kkeys, n_blocks):
        rng = np.random.default_rng(4)
        bb = 64
        pt = jnp.asarray(rng.integers(0, 256, bb * n_blocks, dtype=np.uint8))
        cw = jnp.asarray(np.stack(
            [np.zeros(n_blocks, np.uint32),
             np.arange(n_blocks, dtype=np.uint32) * 4,
             np.zeros(n_blocks, np.uint32),
             np.full(n_blocks, 9, np.uint32)], -1))
        ct = baes.baes_encrypt(pt, kkeys.round_keys, cw, block_bytes=bb,
                               key=kkeys.key)
        bind = mac.Binding.make(np.arange(n_blocks) * 4, 9, 1, 0,
                                np.arange(n_blocks))
        pt2, macs = secure_read_kernel(ct, bind, kkeys.round_keys, cw,
                                       kkeys.hash_key, block_bytes=bb)
        np.testing.assert_array_equal(np.asarray(pt2), np.asarray(pt))
        want = mac.block_macs(ct.reshape(n_blocks, bb), bind,
                              hash_key_u32=kkeys.hash_key,
                              round_keys=kkeys.round_keys, engine="nh")
        np.testing.assert_array_equal(np.asarray(macs), np.asarray(want))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 60))
    def test_fused_roundtrip_property(self, n_blocks):
        kkeys = SecureKeys.derive(55)
        rng = np.random.default_rng(n_blocks)
        pt = jnp.asarray(rng.integers(0, 256, 64 * n_blocks, dtype=np.uint8))
        cw = jnp.asarray(np.stack(
            [np.zeros(n_blocks, np.uint32),
             np.arange(n_blocks, dtype=np.uint32) * 4,
             np.zeros(n_blocks, np.uint32),
             np.full(n_blocks, 1, np.uint32)], -1))
        ct = baes.baes_encrypt(pt, kkeys.round_keys, cw, block_bytes=64,
                               key=kkeys.key)
        bind = mac.Binding.make(np.arange(n_blocks) * 4, 1, 0, 0,
                                np.arange(n_blocks))
        pt2, _ = secure_read_kernel(ct, bind, kkeys.round_keys, cw,
                                    kkeys.hash_key, block_bytes=64)
        np.testing.assert_array_equal(np.asarray(pt2), np.asarray(pt))


class TestFusedCryptMacMixed:
    """Mixed-key fused kernel: per-block bank rows, one fused pass."""

    def _bank(self, k_rows, seed=0):
        keys = [SecureKeys.derive(100 + seed * 16 + i) for i in range(k_rows)]
        return (jnp.stack([k.key for k in keys]),
                jnp.stack([k.round_keys for k in keys]),
                jnp.stack([k.hash_key for k in keys]), keys)

    @pytest.mark.parametrize("n,s", [(4, 2), (33, 4)])
    def test_mixed_kernel_vs_ref(self, n, s):
        rng = np.random.default_rng(n * s)
        ct = jnp.asarray(rng.integers(0, 2**32, (n, s * 4), dtype=np.uint32))
        base = jnp.asarray(rng.integers(0, 2**32, (n, 4), dtype=np.uint32))
        div = jnp.asarray(rng.integers(0, 2**32, (n, s, 4), dtype=np.uint32))
        bind = jnp.asarray(rng.integers(0, 2**32, (n, 8), dtype=np.uint32))
        key = jnp.asarray(rng.integers(0, 2**32, (n, s * 4 + 8),
                                       dtype=np.uint32))
        got_pt, got_nh = fused_crypt_mac_mixed(ct, base, div, bind, key)
        want_pt, want_nh = fused_crypt_mac_mixed_ref(ct, base, div, bind, key)
        np.testing.assert_array_equal(np.asarray(got_pt), np.asarray(want_pt))
        np.testing.assert_array_equal(np.asarray(got_nh), np.asarray(want_nh))

    @pytest.mark.parametrize("n_blocks", [5, 37])
    def test_mixed_secure_read_vs_per_key_reference(self, n_blocks):
        """Each block decrypts + MACs under its OWN bank row, matching
        the single-key path run once per row."""
        bb = 64
        rng = np.random.default_rng(n_blocks)
        bank_key, bank_rk, bank_hash, keys = self._bank(3, seed=n_blocks)
        rows = jnp.asarray(rng.integers(0, 3, n_blocks), jnp.int32)
        cw = jnp.asarray(rng.integers(0, 2**32, (n_blocks, 4),
                                      dtype=np.uint32))
        bind = mac.Binding.make(np.arange(n_blocks) * 4,
                                np.full(n_blocks, 7), np.full(n_blocks, 1),
                                np.full(n_blocks, 2), np.arange(n_blocks))
        ct = jnp.asarray(rng.integers(0, 256, n_blocks * bb, dtype=np.uint8))
        pt, macs = secure_read_kernel_mixed(ct, bind, bank_rk, cw, bank_hash,
                                            rows, block_bytes=bb)
        for i in range(n_blocks):
            r = int(rows[i])
            blk = ct.reshape(n_blocks, bb)[i]
            want_pt = baes.baes_encrypt(blk, keys[r].round_keys, cw[i:i + 1],
                                        block_bytes=bb, key=keys[r].key)
            b1 = mac.Binding(*(f[i:i + 1] for f in bind))
            want_mac = mac.block_macs(blk[None], b1,
                                      hash_key_u32=keys[r].hash_key,
                                      round_keys=keys[r].round_keys,
                                      engine="nh")
            np.testing.assert_array_equal(
                np.asarray(pt).reshape(n_blocks, bb)[i], np.asarray(want_pt))
            np.testing.assert_array_equal(np.asarray(macs[i]),
                                          np.asarray(want_mac[0]))

    def test_uniform_rows_match_single_key_kernel(self):
        """A mixed dispatch whose rows all agree is bit-identical to the
        single-key fused kernel."""
        bb = 64
        n = 12
        rng = np.random.default_rng(9)
        bank_key, bank_rk, bank_hash, keys = self._bank(2)
        rows = jnp.ones((n,), jnp.int32)
        cw = jnp.asarray(rng.integers(0, 2**32, (n, 4), dtype=np.uint32))
        bind = mac.Binding.make(np.arange(n) * 4, 3, 0, 1, np.arange(n))
        ct = jnp.asarray(rng.integers(0, 256, n * bb, dtype=np.uint8))
        got_pt, got_macs = secure_read_kernel_mixed(
            ct, bind, bank_rk, cw, bank_hash, rows, block_bytes=bb)
        want_pt, want_macs = secure_read_kernel(
            ct, bind, keys[1].round_keys, cw, keys[1].hash_key,
            block_bytes=bb)
        np.testing.assert_array_equal(np.asarray(got_pt), np.asarray(want_pt))
        np.testing.assert_array_equal(np.asarray(got_macs),
                                      np.asarray(want_macs))

    def test_multi_keystream_vs_single(self):
        """Per-block schedules equal to one schedule reproduce the
        single-key keystream kernel exactly."""
        kkeys = SecureKeys.derive(3)
        rng = np.random.default_rng(2)
        cw = jnp.asarray(rng.integers(0, 2**32, (50, 4), dtype=np.uint32))
        rk_per = jnp.broadcast_to(kkeys.round_keys[None], (50, 11, 16))
        got = aes_ops.keystream_lanes_multi(cw, rk_per)
        want = aes_ops.keystream_lanes(cw, kkeys.round_keys)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestFusedCryptMacWrite:
    """The write-direction kernels: encrypt + NH of the FRESH
    ciphertext in one pass (the one-pass dirty-page reseal)."""

    def _bank(self, k_rows, seed=0):
        keys = [SecureKeys.derive(200 + seed * 16 + i) for i in range(k_rows)]
        return (jnp.stack([k.round_keys for k in keys]),
                jnp.stack([k.hash_key for k in keys]), keys)

    @pytest.mark.parametrize("n,s", [(1, 2), (33, 4)])
    def test_write_kernel_vs_ref(self, n, s):
        rng = np.random.default_rng(n * s + 1)
        pt = jnp.asarray(rng.integers(0, 2**32, (n, s * 4), dtype=np.uint32))
        base = jnp.asarray(rng.integers(0, 2**32, (n, 4), dtype=np.uint32))
        div = jnp.asarray(rng.integers(0, 2**32, (s, 4), dtype=np.uint32))
        bind = jnp.asarray(rng.integers(0, 2**32, (n, 8), dtype=np.uint32))
        key = jnp.asarray(rng.integers(0, 2**32, (s * 4 + 8,),
                                       dtype=np.uint32))
        got_ct, got_nh = fused_crypt_mac_write(pt, base, div, bind, key)
        want_ct, want_nh = fused_crypt_mac_write_ref(pt, base, div, bind, key)
        np.testing.assert_array_equal(np.asarray(got_ct), np.asarray(want_ct))
        np.testing.assert_array_equal(np.asarray(got_nh), np.asarray(want_nh))

    @pytest.mark.parametrize("n,s", [(4, 2), (33, 4)])
    def test_mixed_write_kernel_vs_ref(self, n, s):
        rng = np.random.default_rng(n * s + 2)
        pt = jnp.asarray(rng.integers(0, 2**32, (n, s * 4), dtype=np.uint32))
        base = jnp.asarray(rng.integers(0, 2**32, (n, 4), dtype=np.uint32))
        div = jnp.asarray(rng.integers(0, 2**32, (n, s, 4), dtype=np.uint32))
        bind = jnp.asarray(rng.integers(0, 2**32, (n, 8), dtype=np.uint32))
        key = jnp.asarray(rng.integers(0, 2**32, (n, s * 4 + 8),
                                       dtype=np.uint32))
        got_ct, got_nh = fused_crypt_mac_write_mixed(pt, base, div, bind, key)
        want_ct, want_nh = fused_crypt_mac_write_mixed_ref(pt, base, div,
                                                           bind, key)
        np.testing.assert_array_equal(np.asarray(got_ct), np.asarray(want_ct))
        np.testing.assert_array_equal(np.asarray(got_nh), np.asarray(want_nh))

    @pytest.mark.parametrize("n_blocks", [4, 40])
    def test_secure_write_matches_encrypt_then_mac(self, kkeys, n_blocks):
        """ct bit-identical to the core B-AES encrypt, MACs bit-identical
        to mac.block_macs over that ciphertext — the exact unfused
        write-path composition the kernel replaces."""
        bb = 64
        rng = np.random.default_rng(n_blocks + 5)
        pt = jnp.asarray(rng.integers(0, 256, bb * n_blocks, dtype=np.uint8))
        cw = jnp.asarray(rng.integers(0, 2**32, (n_blocks, 4),
                                      dtype=np.uint32))
        bind = mac.Binding.make(np.arange(n_blocks) * 4,
                                np.full(n_blocks, 9), np.full(n_blocks, 1),
                                np.full(n_blocks, 0), np.arange(n_blocks))
        ct, macs = secure_write_kernel(pt, bind, kkeys.round_keys, cw,
                                       kkeys.hash_key, block_bytes=bb)
        want_ct = baes.baes_encrypt(pt, kkeys.round_keys, cw, block_bytes=bb,
                                    key=kkeys.key)
        np.testing.assert_array_equal(np.asarray(ct), np.asarray(want_ct))
        want_macs = mac.block_macs(want_ct.reshape(n_blocks, bb), bind,
                                   hash_key_u32=kkeys.hash_key,
                                   round_keys=kkeys.round_keys, engine="nh")
        np.testing.assert_array_equal(np.asarray(macs), np.asarray(want_macs))

    def test_write_then_read_roundtrip(self, kkeys):
        """A fused write's output verifies and decrypts through the
        fused read with the SAME binding/counters — the dirty page a
        tick reseals is readable (and checkable) next tick."""
        bb, n = 64, 12
        rng = np.random.default_rng(8)
        pt = jnp.asarray(rng.integers(0, 256, bb * n, dtype=np.uint8))
        cw = jnp.asarray(rng.integers(0, 2**32, (n, 4), dtype=np.uint32))
        bind = mac.Binding.make(np.arange(n) * 4, np.full(n, 3),
                                np.full(n, 0), np.full(n, 1), np.arange(n))
        ct, w_macs = secure_write_kernel(pt, bind, kkeys.round_keys, cw,
                                         kkeys.hash_key, block_bytes=bb)
        pt2, r_macs = secure_read_kernel(ct, bind, kkeys.round_keys, cw,
                                         kkeys.hash_key, block_bytes=bb)
        np.testing.assert_array_equal(np.asarray(pt2), np.asarray(pt))
        np.testing.assert_array_equal(np.asarray(r_macs), np.asarray(w_macs))

    @pytest.mark.parametrize("n_blocks", [5, 37])
    def test_mixed_secure_write_vs_per_key_reference(self, n_blocks):
        """Each block encrypts + MACs under its OWN bank row, matching
        the single-key path run once per row — the vmapped per-page
        write reference the mixed kernel replaces."""
        bb = 64
        rng = np.random.default_rng(n_blocks + 3)
        bank_rk, bank_hash, keys = self._bank(3, seed=n_blocks)
        rows = jnp.asarray(rng.integers(0, 3, n_blocks), jnp.int32)
        cw = jnp.asarray(rng.integers(0, 2**32, (n_blocks, 4),
                                      dtype=np.uint32))
        bind = mac.Binding.make(np.arange(n_blocks) * 4,
                                np.full(n_blocks, 7), np.full(n_blocks, 1),
                                np.full(n_blocks, 2), np.arange(n_blocks))
        pt = jnp.asarray(rng.integers(0, 256, n_blocks * bb, dtype=np.uint8))
        ct, macs = secure_write_kernel_mixed(pt, bind, bank_rk, cw,
                                             bank_hash, rows, block_bytes=bb)
        for i in range(n_blocks):
            r = int(rows[i])
            blk = pt.reshape(n_blocks, bb)[i]
            want_ct = baes.baes_encrypt(blk, keys[r].round_keys, cw[i:i + 1],
                                        block_bytes=bb, key=keys[r].key)
            b1 = mac.Binding(*(f[i:i + 1] for f in bind))
            want_mac = mac.block_macs(want_ct[None], b1,
                                      hash_key_u32=keys[r].hash_key,
                                      round_keys=keys[r].round_keys,
                                      engine="nh")
            np.testing.assert_array_equal(
                np.asarray(ct).reshape(n_blocks, bb)[i], np.asarray(want_ct))
            np.testing.assert_array_equal(np.asarray(macs[i]),
                                          np.asarray(want_mac[0]))

    def test_uniform_rows_match_single_key_write_kernel(self):
        """A mixed write whose rows all agree is bit-identical to the
        single-key fused write kernel."""
        bb, n = 64, 12
        rng = np.random.default_rng(10)
        bank_rk, bank_hash, keys = self._bank(2)
        rows = jnp.ones((n,), jnp.int32)
        cw = jnp.asarray(rng.integers(0, 2**32, (n, 4), dtype=np.uint32))
        bind = mac.Binding.make(np.arange(n) * 4, np.full(n, 3),
                                np.full(n, 0), np.full(n, 1), np.arange(n))
        pt = jnp.asarray(rng.integers(0, 256, n * bb, dtype=np.uint8))
        got_ct, got_macs = secure_write_kernel_mixed(
            pt, bind, bank_rk, cw, bank_hash, rows, block_bytes=bb)
        want_ct, want_macs = secure_write_kernel(
            pt, bind, keys[1].round_keys, cw, keys[1].hash_key,
            block_bytes=bb)
        np.testing.assert_array_equal(np.asarray(got_ct), np.asarray(want_ct))
        np.testing.assert_array_equal(np.asarray(got_macs),
                                      np.asarray(want_macs))
