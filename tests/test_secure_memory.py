"""SecureRegion protect/unprotect + SecureExecutor schemes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import SCHEMES, SecureExecutor, attacks
from repro.core import secure_memory as sm


def _tree(rng):
    return {
        "layer0": {"w": jnp.asarray(rng.standard_normal((8, 12),
                                                        dtype=np.float32)),
                   "b": jnp.asarray(rng.standard_normal(5,
                                                        dtype=np.float32))},
        "layer1": {"w": jnp.asarray(
            rng.integers(-100, 100, (31,), dtype=np.int32))},
    }


class TestSecureMemory:
    @pytest.mark.parametrize("block_bytes", [64, 128, 512])
    @pytest.mark.parametrize("use_baes", [True, False])
    def test_roundtrip(self, keys, rng, block_bytes, use_baes):
        tree = _tree(rng)
        spec = sm.make_region_spec(tree, block_bytes=block_bytes,
                                   use_baes=use_baes)
        st_ = sm.protect(tree, keys, spec, step=1)
        out, ok = sm.unprotect(st_, keys, spec)
        assert bool(ok)
        for a, b in zip(jax.tree_util.tree_leaves(out),
                        jax.tree_util.tree_leaves(tree)):
            assert (np.asarray(a) == np.asarray(b)).all()

    def test_ciphertext_differs_from_plaintext(self, keys, rng):
        tree = _tree(rng)
        spec = sm.make_region_spec(tree)
        st_ = sm.protect(tree, keys, spec)
        flat = jax.tree_util.tree_leaves(tree)
        from repro.core.bytesutil import tensor_to_bytes
        for ct, leaf in zip(st_.ciphertexts, flat):
            pt = np.asarray(tensor_to_bytes(leaf, multiple=64))
            assert not (np.asarray(ct) == pt).all()

    def test_vn_changes_ciphertext(self, keys, rng):
        tree = _tree(rng)
        spec = sm.make_region_spec(tree)
        s1 = sm.protect(tree, keys, spec, step=1)
        s2 = sm.protect(tree, keys, spec, step=2)
        assert not (np.asarray(s1.ciphertexts[0])
                    == np.asarray(s2.ciphertexts[0])).all()

    def test_replay_attack_detected(self, keys, rng):
        """Splicing an old (valid) ciphertext into a newer state fails:
        the VN differs, so MACs recompute differently (freshness)."""
        tree = _tree(rng)
        spec = sm.make_region_spec(tree)
        s1 = sm.protect(tree, keys, spec, step=1)
        tree2 = jax.tree_util.tree_map(lambda x: x + 1, tree)
        s2 = sm.protect(tree2, keys, spec, step=2)
        spliced = s2._replace(
            ciphertexts=(s1.ciphertexts[0],) + s2.ciphertexts[1:])
        _, ok = sm.unprotect(spliced, keys, spec)
        assert not bool(ok)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2), st.integers(0, 30))
    def test_tamper_any_leaf_any_byte(self, leaf_idx, byte_idx):
        keys = sm.SecureKeys.derive(9)
        rng = np.random.default_rng(3)
        tree = _tree(rng)
        spec = sm.make_region_spec(tree)
        st_ = sm.protect(tree, keys, spec)
        cts = list(st_.ciphertexts)
        byte_idx = byte_idx % cts[leaf_idx].shape[0]
        cts[leaf_idx] = cts[leaf_idx].at[byte_idx].set(
            cts[leaf_idx][byte_idx] ^ 0x5A)
        _, ok = sm.unprotect(st_._replace(ciphertexts=tuple(cts)), keys, spec)
        assert not bool(ok)

    def test_repa_shuffle_detected_on_leaf(self, keys, rng):
        tree = {"w": jnp.asarray(rng.standard_normal((32, 16),
                                                     dtype=np.float32))}
        spec = sm.make_region_spec(tree, block_bytes=64)
        st_ = sm.protect(tree, keys, spec)
        ct = np.asarray(st_.ciphertexts[0]).reshape(-1, 64)
        shuf = attacks.repa_shuffle(ct, seed=2).reshape(-1)
        _, ok = sm.unprotect(
            st_._replace(ciphertexts=(jnp.asarray(shuf),)), keys, spec)
        assert not bool(ok)


class TestSecureExecutor:
    @pytest.mark.parametrize("scheme", list(SCHEMES))
    def test_schemes_roundtrip(self, rng, scheme):
        ex = SecureExecutor(scheme=scheme)
        params = {"w": jnp.asarray(rng.standard_normal((16, 16),
                                                       dtype=np.float32))}
        spec = ex.region_spec(params)
        state = ex.protect(params, spec, step=0)
        out, ok = ex.unprotect(state, spec)
        assert bool(ok)
        assert (np.asarray(out["w"]) == np.asarray(params["w"])).all()

    def test_secure_step_updates_params(self, rng):
        ex = SecureExecutor(scheme="seda")
        params = {"w": jnp.ones((8, 8), jnp.float32)}
        spec = ex.region_spec(params)

        def step_fn(p, x):
            grad = jax.grad(lambda w: jnp.sum((w @ x) ** 2))(p["w"])
            return {"w": p["w"] - 0.1 * grad}, jnp.sum(grad)

        sec = ex.make_secure_step(step_fn, spec)
        state = ex.protect(params, spec, step=0)
        state, _, ok = jax.jit(sec)(state, 0, jnp.ones(8))
        assert bool(ok)
        out, ok2 = ex.unprotect(state, spec)
        assert bool(ok2)
        assert not (np.asarray(out["w"]) == 1.0).all()

    def test_off_scheme_is_passthrough(self, rng):
        ex = SecureExecutor(scheme="off")
        params = {"w": jnp.ones((4, 4))}
        spec = ex.region_spec(params)
        assert ex.protect(params, spec) is params
