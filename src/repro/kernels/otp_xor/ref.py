"""Pure-jnp oracle for the B-AES diversify+XOR crypt engine."""

from __future__ import annotations

import jax

__all__ = ["otp_xor_ref"]


def otp_xor_ref(data_lanes: jax.Array, base_otp_lanes: jax.Array,
                div_lanes: jax.Array) -> jax.Array:
    """Apply per-segment diversified OTPs to wide blocks.

    Args:
      data_lanes: (N, S*4) uint32 — N wide blocks, S 16B segments each.
      base_otp_lanes: (N, 4) uint32 — one base OTP per block (AES output).
      div_lanes: (S, 4) uint32 — per-segment diversifiers (round keys;
        row 0 is zero so segment 0 keeps the base OTP).

    Returns (N, S*4) uint32 ciphertext lanes:
      out[n, 4s+l] = data[n, 4s+l] ^ base[n, l] ^ div[s, l]
    """
    n, lanes = data_lanes.shape
    s = div_lanes.shape[0]
    d = data_lanes.reshape(n, s, 4)
    pads = base_otp_lanes[:, None, :] ^ div_lanes[None, :, :]
    return (d ^ pads).reshape(n, lanes)
