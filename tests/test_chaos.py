"""Chaos suite: deterministic fault injection against the secure engine.

Every fault is scheduled by a seeded :class:`FaultPlan` (no wall-clock,
no randomness at fire time), so each scenario is exactly reproducible:

  * memory tamper (bitflip / VN bump / page swap) against one slot is
    quarantined — only that session is preempted, every other session's
    tokens are bit-identical to a fault-free run, and the recovered
    session's final tokens match the fault-free run (secure recompute);
  * ``IntegrityError`` never escapes ``step()`` for contained faults,
    for every verifying scheme;
  * a transient verdict glitch is distinguished from persistent tamper
    by bounded re-read and costs nothing;
  * a spent retry budget declares the session dead (``sessions_lost``)
    without touching its neighbours;
  * quarantined frames never return to the allocator;
  * killing a shard fails it over: all of its sessions recover on the
    survivors with ``sessions_lost == 0``, and the cluster root MAC
    folds the dead shard out.

Without ``fault_tolerance`` the strict discipline is unchanged: the
same tamper still raises (the seed-era contract).
"""

import ast
import inspect

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.secure_exec import SCHEMES
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.obs.audit import AuditLog
from repro.serve import cluster as cluster_mod
from repro.serve import engine as engine_mod
from repro.serve.cluster import ClusterEngine
from repro.serve.engine import IntegrityError, SecureServingEngine
from repro.serve.faults import FAULT_KINDS, Fault, FaultPlan, RecoveryPolicy

VERIFYING = [s for s in SCHEMES if SCHEMES[s].verify != "none"]


@pytest.fixture(scope="module")
def smoke():
    arch = get_arch("minitron-4b")
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    return arch, cfg, params


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(0)
    # Slot 0's prompt spans two pages at admission (page_tokens=4), so
    # page_swap has an in-slot partner from the first tick.
    return [list(map(int, rng.integers(1, 256, n))) for n in (6, 5)]


def _engine(smoke, **kw):
    arch, cfg, params = smoke
    kw.setdefault("max_slots", 2)
    kw.setdefault("page_tokens", 4)
    kw.setdefault("pages_per_slot", 4)
    kw.setdefault("n_pages", 12)    # spare frames outlive quarantine
    kw.setdefault("scheme", "seda")
    return SecureServingEngine(arch, cfg, params, **kw)


def _cluster(smoke, **kw):
    arch, cfg, params = smoke
    kw.setdefault("shards", 2)
    kw.setdefault("max_slots", 2)
    kw.setdefault("page_tokens", 4)
    kw.setdefault("pages_per_slot", 4)
    kw.setdefault("scheme", "seda")
    return ClusterEngine(arch, cfg, params, **kw)


def _serve(eng, prompts, n=4):
    rids = [eng.submit(prompt=p, max_new_tokens=n) for p in prompts]
    eng.run()
    return rids, [list(eng.requests[r].generated) for r in rids]


@pytest.fixture(scope="module")
def baseline(smoke, prompts):
    """Fault-free reference tokens, computed once per scheme."""
    cache = {}

    def get(scheme):
        if scheme not in cache:
            _, cache[scheme] = _serve(_engine(smoke, scheme=scheme), prompts)
        return cache[scheme]

    return get


class TestPlan:
    def test_seeded_plans_are_reproducible(self):
        a = FaultPlan.random(7, n_faults=4, kinds=FAULT_KINDS,
                             n_shards=2, n_slots=2)
        b = FaultPlan.random(7, n_faults=4, kinds=FAULT_KINDS,
                             n_shards=2, n_slots=2)
        assert [vars(f) for f in a.faults] == [vars(f) for f in b.faults]

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            FaultPlan([Fault(tick=1, kind="meteor_strike")])


class TestContainment:
    @pytest.mark.parametrize("scheme", VERIFYING)
    def test_bitflip_quarantined_and_recovered(self, smoke, prompts,
                                               baseline, scheme):
        want = baseline(scheme)
        eng = _engine(smoke, scheme=scheme, fault_tolerance=True,
                      audit=AuditLog())
        FaultPlan([Fault(tick=3, kind="bitflip", slot=0)]).attach(eng)
        rids = [eng.submit(prompt=p, max_new_tokens=4) for p in prompts]
        eng.run()                       # IntegrityError must NOT escape
        got = [list(eng.requests[r].generated) for r in rids]
        # Unaffected session bit-identical AND recovered session's
        # final tokens match the fault-free run (secure recompute).
        assert got == want
        assert all(eng.requests[r].state == "finished" for r in rids)
        assert eng.stats["integrity_quarantined_pages"] >= 1
        assert eng.stats["sessions_recovered"] >= 1
        assert eng.stats["sessions_lost"] == 0
        # Only the tampered session was preempted.
        victims = [r for r in rids if eng.requests[r].n_evictions]
        assert len(victims) == 1
        assert eng.audit.events("quarantine")
        assert eng.audit.events("session_recovered")
        assert eng.audit.verify_chain()
        assert eng.deferred_check()

    @pytest.mark.parametrize("kind", ("vn_bump", "page_swap"))
    def test_replay_and_splice_tamper_contained(self, smoke, prompts,
                                                baseline, kind):
        want = baseline("seda")
        eng = _engine(smoke, scheme="seda", fault_tolerance=True)
        plan = FaultPlan([Fault(tick=3, kind=kind, slot=0)]).attach(eng)
        rids = [eng.submit(prompt=p, max_new_tokens=4) for p in prompts]
        eng.run()
        assert plan.fired
        assert [list(eng.requests[r].generated) for r in rids] == want
        assert eng.stats["integrity_quarantined_pages"] >= 1
        assert eng.stats["sessions_recovered"] >= 1
        assert eng.stats["sessions_lost"] == 0
        assert eng.deferred_check()

    @pytest.mark.parametrize("kind", ("mac_corrupt", "pool_mac_zap"))
    def test_metadata_tamper_contained(self, smoke, prompts, baseline,
                                       kind):
        """Stored-MAC tamper never changes plaintext, so tokens stay
        fault-free; containment must repair the deferred identity
        (quarantine or pool-MAC rebuild) without raising or losing a
        session."""
        want = baseline("seda")
        eng = _engine(smoke, scheme="seda", fault_tolerance=True,
                      audit=AuditLog())
        plan = FaultPlan([Fault(tick=3, kind=kind, slot=0)]).attach(eng)
        rids = [eng.submit(prompt=p, max_new_tokens=4) for p in prompts]
        eng.run()
        assert plan.fired
        assert [list(eng.requests[r].generated) for r in rids] == want
        assert eng.stats["sessions_lost"] == 0
        assert (eng.audit.events("fault_contained")
                or eng.audit.events("pool_mac_rebuild"))
        assert eng.deferred_check()

    def test_transient_fault_costs_nothing(self, smoke, prompts, baseline):
        want = baseline("seda")
        eng = _engine(smoke, scheme="seda", fault_tolerance=True,
                      audit=AuditLog())
        plan = FaultPlan([Fault(tick=3, kind="transient")]).attach(eng)
        rids = [eng.submit(prompt=p, max_new_tokens=4) for p in prompts]
        eng.run()
        assert plan.fired
        assert [list(eng.requests[r].generated) for r in rids] == want
        # Bounded re-read told it apart from persistent tamper.
        assert eng.stats["integrity_quarantined_pages"] == 0
        assert eng.stats["sessions_recovered"] == 0
        assert eng.stats["sessions_lost"] == 0
        assert eng.audit.events("transient_fault")

    def test_retry_budget_exhaustion_loses_only_victim(self, smoke,
                                                       prompts, baseline):
        want = baseline("seda")
        eng = _engine(smoke, scheme="seda", audit=AuditLog(),
                      fault_tolerance=RecoveryPolicy(max_retries=0))
        FaultPlan([Fault(tick=3, kind="bitflip", slot=0)]).attach(eng)
        rids = [eng.submit(prompt=p, max_new_tokens=4) for p in prompts]
        eng.run()                       # still must not raise
        assert eng.stats["sessions_lost"] == 1
        lost = [r for r in rids if eng.requests[r].state == "failed"]
        assert len(lost) == 1
        for r in rids:
            if r in lost:
                continue
            assert eng.requests[r].state == "finished"
            assert list(eng.requests[r].generated) == want[rids.index(r)]
        assert eng.audit.events("session_lost")

    def test_quarantined_frames_never_reallocated(self, smoke, prompts):
        eng = _engine(smoke, scheme="seda", fault_tolerance=True)
        FaultPlan([Fault(tick=3, kind="bitflip", slot=0)]).attach(eng)
        for p in prompts:
            eng.submit(prompt=p, max_new_tokens=4)
        eng.run()
        bad = set(eng.quarantined)
        assert bad
        assert not bad & set(eng.free_pages)
        # Keep serving: the retired frames must never come back.
        for p in prompts:
            eng.submit(prompt=p, max_new_tokens=4)
        eng.run()
        assert eng.quarantined == bad
        assert not bad & set(eng.free_pages)
        resident = {int(p) for s in eng.slots if s is not None
                    for p in s.pages}
        assert not bad & resident

    def test_without_fault_tolerance_same_tamper_still_raises(self, smoke,
                                                              prompts):
        eng = _engine(smoke, scheme="seda")
        FaultPlan([Fault(tick=3, kind="bitflip", slot=0)]).attach(eng)
        for p in prompts:
            eng.submit(prompt=p, max_new_tokens=4)
        with pytest.raises(IntegrityError):
            eng.run()


class TestShardFailover:
    @pytest.mark.parametrize("scheme", ("off", "seda"))
    def test_shard_kill_recovers_all_sessions(self, smoke, scheme):
        rng = np.random.default_rng(1)
        ps = [list(map(int, rng.integers(1, 256, n))) for n in (6, 5, 4)]
        base = _cluster(smoke, scheme=scheme)
        rids = [base.submit(prompt=p, max_new_tokens=4) for p in ps]
        base.run()
        want = [list(base.requests[r].generated) for r in rids]

        eng = _cluster(smoke, scheme=scheme, fault_tolerance=True)
        FaultPlan([Fault(tick=3, kind="shard_kill", shard=1)]
                  ).attach_cluster(eng)
        rids = [eng.submit(prompt=p, max_new_tokens=4) for p in ps]
        eng.run()                       # the kill must not escape
        got = [list(eng.requests[r].generated) for r in rids]
        assert got == want
        assert all(eng.requests[r].state == "finished" for r in rids)
        assert eng.stats["shard_failovers"] == 1
        assert eng.failed_shards == {1}
        agg = eng.engine_stats
        assert agg["sessions_lost"] == 0
        assert agg["sessions_recovered"] >= 1
        # The dead shard is folded out of the root compression.
        assert eng.deferred_check()

    def test_no_survivor_is_fatal(self, smoke, prompts):
        eng = _cluster(smoke, scheme="off", fault_tolerance=True)
        FaultPlan([Fault(tick=2, kind="shard_kill", shard=0),
                   Fault(tick=2, kind="shard_kill", shard=1)]
                  ).attach_cluster(eng)
        for p in prompts:
            eng.submit(prompt=p, max_new_tokens=4)
        with pytest.raises(IntegrityError):
            eng.run()


class TestSLOIntegration:
    def test_recovery_reports_degraded_then_ok(self, smoke, prompts):
        from repro.obs.slo import SLOMonitor
        eng = _engine(smoke, scheme="seda", fault_tolerance=True)
        mon = SLOMonitor().attach(eng)
        FaultPlan([Fault(tick=2, kind="bitflip", slot=0)]).attach(eng)
        for p in prompts:
            eng.submit(prompt=p, max_new_tokens=6)
        seen_degraded = False
        for _ in range(200):
            if not (eng._n_waiting()
                    or any(s is not None for s in eng.slots)):
                break
            eng.step()
            if eng._n_recovering():
                health = mon.health()
                assert health["status"] == "degraded"
                assert health["recovery"]["recovering"] >= 1
                seen_degraded = True
        assert seen_degraded
        assert mon.health()["status"] == "ok"
        assert not mon.hard_breach

    def test_session_loss_is_hard_breach(self, smoke, prompts):
        from repro.obs.slo import SLOMonitor, merge_health
        eng = _engine(smoke, scheme="seda",
                      fault_tolerance=RecoveryPolicy(max_retries=0))
        mon = SLOMonitor().attach(eng)
        FaultPlan([Fault(tick=3, kind="bitflip", slot=0)]).attach(eng)
        for p in prompts:
            eng.submit(prompt=p, max_new_tokens=4)
        eng.run()
        assert mon.hard_breach
        health = mon.health()
        assert health["status"] == "failing"
        assert health["recovery"]["sessions_lost"] == 1
        merged = merge_health([health])
        assert merged["status"] == "failing"
        assert merged["recovery"]["sessions_lost"] == 1


class TestIntegrityFailContext:
    """Every ``_integrity_fail`` site must say which gate failed (op)
    and, unless the op is inherently global, name the tenant/slot/page
    context the containment layer localizes from."""

    EXEMPT_OPS = {"decode_accum", "deferred"}   # pool-global by nature
    CONTEXT = {"tenant", "slot", "page", "pages", "to_shard", "to_tenant"}

    def test_call_sites_carry_context(self):
        for mod in (engine_mod, cluster_mod):
            tree = ast.parse(inspect.getsource(mod))
            sites = [n for n in ast.walk(tree)
                     if isinstance(n, ast.Call)
                     and isinstance(n.func, ast.Attribute)
                     and n.func.attr == "_integrity_fail"
                     and n.keywords]
            assert sites, f"no _integrity_fail sites found in {mod.__name__}"
            for call in sites:
                kwargs = {k.arg for k in call.keywords}
                assert "op" in kwargs or None in kwargs, ast.dump(call)
                op_kw = next((k for k in call.keywords if k.arg == "op"),
                             None)
                op = (op_kw.value.value if op_kw is not None
                      and isinstance(op_kw.value, ast.Constant) else None)
                if op in self.EXEMPT_OPS:
                    continue
                # A **ctx splat (arg None) forwards caller context.
                assert kwargs & self.CONTEXT or None in kwargs, \
                    ast.dump(call)
