"""B-AES vs T-AES area/power scaling model (paper Fig. 4, 28nm).

T-AES meets an N-fold bandwidth requirement by stacking N AES engines;
B-AES uses ONE engine plus per-segment 128-bit XOR/mux banks fed by the
KeyExpansion round keys (paper §III-B).

Constants are derived from the round-based AES-128 implementations in
Banerjee's thesis [22] scaled to 28nm: a full engine (datapath + on-the-
fly KeyExpansion) is ~15.5 kGE; a 128-bit XOR diversification bank
(XOR + mux + pipeline register) is ~0.7 kGE.  Absolute numbers are
model estimates; the paper's claim under test is the *scaling shape*
(linear for T-AES, near-flat for B-AES).
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["AESCost", "t_aes_cost", "b_aes_cost", "scaling_table"]

# 28nm technology constants.
GE_UM2 = 0.49                 # NAND2-equivalent gate area, um^2
AES_ENGINE_KGE = 12.5         # AES-128 round-based datapath
KEYEXP_KGE = 3.0              # on-the-fly KeyExpansion
XOR_BANK_KGE = 0.7            # 128b XOR + mux + pipeline reg per extra segment

AES_ENGINE_MW_GHZ = 4.4       # dynamic power per engine at 1 GHz
KEYEXP_MW_GHZ = 0.9
XOR_BANK_MW_GHZ = 0.055


@dataclass(frozen=True)
class AESCost:
    name: str
    bandwidth_multiple: int   # x the bandwidth of a single AES engine
    area_mm2: float
    power_mw: float           # at 1 GHz


def t_aes_cost(bandwidth_multiple: int) -> AESCost:
    """Traditional scaling: one full engine per bandwidth unit."""
    n = max(1, bandwidth_multiple)
    kge = n * (AES_ENGINE_KGE + KEYEXP_KGE)
    power = n * (AES_ENGINE_MW_GHZ + KEYEXP_MW_GHZ)
    return AESCost("t_aes", n, kge * 1e3 * GE_UM2 / 1e6, power)


def b_aes_cost(bandwidth_multiple: int) -> AESCost:
    """SeDA scaling: one engine + (n-1) XOR diversification banks."""
    n = max(1, bandwidth_multiple)
    kge = AES_ENGINE_KGE + KEYEXP_KGE + (n - 1) * XOR_BANK_KGE
    power = AES_ENGINE_MW_GHZ + KEYEXP_MW_GHZ + (n - 1) * XOR_BANK_MW_GHZ
    return AESCost("b_aes", n, kge * 1e3 * GE_UM2 / 1e6, power)


def scaling_table(max_multiple: int = 16) -> list:
    """Fig. 4 data: (multiple, T-AES area/power, B-AES area/power)."""
    rows = []
    for n in range(1, max_multiple + 1):
        t, b = t_aes_cost(n), b_aes_cost(n)
        rows.append({
            "bandwidth_multiple": n,
            "t_aes_area_mm2": round(t.area_mm2, 5),
            "b_aes_area_mm2": round(b.area_mm2, 5),
            "t_aes_power_mw": round(t.power_mw, 3),
            "b_aes_power_mw": round(b.power_mw, 3),
            "area_saving": round(1 - b.area_mm2 / t.area_mm2, 4),
            "power_saving": round(1 - b.power_mw / t.power_mw, 4),
        })
    return rows
