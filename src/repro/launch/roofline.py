"""Roofline analysis (deliverable g) — reads artifacts/dryrun/*.json.

Per (arch × shape) on the single-pod mesh:

    compute term    = dot_flops_per_chip / 197e12        (bf16 peak)
    memory term     = HBM bytes per chip / 819e9
    collective term = collective bytes per chip / 50e9   (per ICI link)

Two memory-byte sources are reported:
  * hlo   — loop-aware operand+output bytes parsed from the compiled
            module (XLA's own "bytes accessed" convention).  On the CPU
            lowering this over-counts attention intermediates that a
            TPU Pallas flash kernel keeps in VMEM;
  * model — analytic first-principles traffic: params (fwd+bwd+opt),
            saved activations under the remat policy, logits, caches.
            This is the headline number; both appear in EXPERIMENTS.md.

MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference), compared
against chips × dot_flops_per_chip to expose replication waste.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--json DIR]
"""

from __future__ import annotations

import argparse
import glob
import json
import math
import os

from repro.configs import SHAPES, get_arch
from repro.models import encdec as ed
from repro.models import lm as lm_mod
from repro.models.layers import ParamSpec

PEAK_FLOPS = 197e12       # bf16 per chip (TPU v5e)
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link
CHIPS = {"single": 256, "multi": 512}


def _iter_leaves(specs):
    import jax
    flat, _ = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    for path, s in flat:
        yield jax.tree_util.keystr(path), s


def _param_bytes(arch, cfg) -> tuple:
    """(total_bytes, active_bytes) — active scales MoE experts by k/E."""
    specs = (ed.encdec_specs(cfg) if arch.kind == "encdec"
             else lm_mod.lm_specs(cfg))
    total = active = 0.0
    moe = getattr(cfg, "moe", None)
    for path, s in _iter_leaves(specs):
        nbytes = math.prod(s.shape) * (2 if s.dtype == "bfloat16" else 4)
        total += nbytes
        frac = 1.0
        if moe is not None and "moe" in path and "shared" not in path \
                and "router" not in path:
            frac = moe.top_k / moe.n_experts
        active += nbytes * frac
    return total, active


def model_flops(arch, cfg, shape) -> float:
    """6·N_active·D for train, 2·N_active·D per generated/processed token."""
    total_b, active_b = _param_bytes(arch, cfg)
    n_active = active_b / 2  # bf16 params

    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence in the batch.
    return 2.0 * n_active * shape.global_batch


def analytic_mem_bytes_per_chip(arch, cfg, shape, chips: int) -> float:
    """First-principles HBM traffic per chip per step (headline model)."""
    total_b, active_b = _param_bytes(arch, cfg)
    p_chip = total_b / chips          # fully-sharded across the pod
    dp = 16                           # data-parallel ways on the 16x16 mesh
    b_local = max(1, shape.global_batch // dp)
    d_model = cfg.d_model
    n_layers = getattr(cfg, "n_layers", None) or (cfg.enc_layers
                                                  + cfg.dec_layers)
    act_dtype = 2

    if shape.kind == "train":
        # params: fwd read + bwd read + recompute read (full remat) = 3x
        # grads: write + read (2x); opt state f32 m,v r/w (8 or 4 bytes).
        opt_mult = 4 if arch.name not in ("deepseek-v3-671b",
                                          "jamba-v0.1-52b",
                                          "granite-34b") else 2
        params_traffic = (3 + 2) * p_chip + 2 * 2 * (opt_mult / 2) * p_chip
        # saved activations: one residual per layer, write + read.
        acts = 2 * n_layers * b_local * shape.seq_len * d_model * act_dtype
        # logits in f32: write + read (loss + backward).
        logits = 2 * b_local * shape.seq_len * cfg.vocab * 4 / 16  # vocab TP
        return params_traffic + acts + logits
    if shape.kind == "prefill":
        acts = 2 * n_layers * b_local * shape.seq_len * d_model * act_dtype
        caches = n_layers * b_local * shape.seq_len * d_model * act_dtype / 4
        return p_chip + acts + caches
    # decode: stream params once + read the whole cache once.
    cache = _cache_bytes_per_chip(arch, cfg, shape, dp)
    return p_chip + cache


def _cache_bytes_per_chip(arch, cfg, shape, dp) -> float:
    b_local = max(1, shape.global_batch // dp)
    if arch.kind == "encdec":
        per_tok = 2 * cfg.n_kv * cfg.head_dim * 2
        return cfg.dec_layers * b_local * shape.seq_len * per_tok / 1
    kinds = lm_mod.layout(cfg)
    total = 0.0
    for k in kinds:
        if k.mixer == "attn":
            shard = 16 if cfg.n_kv % 16 == 0 else 1  # kv-head TP
            total += b_local * shape.seq_len * 2 * cfg.n_kv * cfg.head_dim \
                * 2 / shard
        elif k.mixer == "mla":
            total += b_local * shape.seq_len * (cfg.mla.kv_lora_rank
                                                + cfg.mla.qk_rope_dim) * 2
        else:  # mamba: O(1) state
            m = cfg.mamba
            total += b_local * m.n_heads * m.head_dim * m.d_state * 4 / 16
    return total


def _dominant(terms: dict) -> str:
    return max(terms, key=terms.get)


def _advice(arch, shape, dom, ratio) -> str:
    if dom == "collective":
        return ("re-shard to cut cross-device dispatch (MoE all-to-all / "
                "dispatch all-reduces dominate)" if "moe" in arch.family
                else "overlap collectives with compute; reduce TP degree")
    if dom == "memory":
        if shape.kind == "decode":
            return "batch more sequences per chip to amortize param streaming"
        return "fuse attention (Pallas flash kernel) / raise arithmetic intensity"
    if ratio < 0.25:
        return ("reduce model-axis replication: attention heads do not "
                "TP-shard for this arch" if arch.name == "smollm-135m"
                else "cut remat recompute or replication waste")
    return "near compute roofline: increase per-chip batch for efficiency"


def build_table(json_dir: str, mesh_kind: str = "single") -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(json_dir,
                                              f"*_{mesh_kind}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        arch = get_arch(rec["arch"])
        cfg = arch.make_config()
        shape = SHAPES[rec["shape"]]
        chips = CHIPS[mesh_kind]

        compute_t = rec["dot_flops_per_chip"] / PEAK_FLOPS
        mem_hlo_t = rec.get("mem_bytes_per_chip", 0.0) / HBM_BW
        mem_model = analytic_mem_bytes_per_chip(arch, cfg, shape, chips)
        mem_model_t = mem_model / HBM_BW
        coll_t = rec["collective_total_per_chip"] / LINK_BW

        mflops = model_flops(arch, cfg, shape)
        hlo_total = rec["dot_flops_per_chip"] * chips
        ratio = mflops / hlo_total if hlo_total else 0.0

        terms = {"compute": compute_t, "memory": mem_model_t,
                 "collective": coll_t}
        dom = _dominant(terms)
        step_t = max(terms.values())
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": mesh_kind,
            "compute_s": compute_t, "memory_s": mem_model_t,
            "memory_hlo_s": mem_hlo_t, "collective_s": coll_t,
            "dominant": dom,
            "model_flops": mflops, "hlo_flops_total": hlo_total,
            "useful_ratio": ratio,
            "roofline_fraction": compute_t / step_t if step_t else 0.0,
            "advice": _advice(arch, shape, dom, ratio),
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    default_dir = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                               "artifacts", "dryrun")
    ap.add_argument("--json", default=os.path.abspath(default_dir))
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    rows = build_table(args.json, args.mesh)
    header = (f"{'arch':24s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
              f"{'mem(hlo)':>9s} {'collect':>9s} {'dominant':>10s} "
              f"{'useful':>7s} {'roofline':>8s}")
    print(header)
    lines = [header]
    for r in rows:
        line = (f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:9.3g} "
                f"{r['memory_s']:9.3g} {r['memory_hlo_s']:9.3g} "
                f"{r['collective_s']:9.3g} {r['dominant']:>10s} "
                f"{r['useful_ratio']:7.3f} {r['roofline_fraction']:8.3f}")
        print(line)
        lines.append(line)
    out = args.out or os.path.join(args.json, "..",
                                   f"roofline_{args.mesh}.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
