"""Pallas TPU kernel: FUSED decrypt + integrity-hash (beyond-paper).

SeDA's read path touches every protected byte twice: once to XOR the
pad (Crypt Engine) and once to hash for the optBlk MAC (Integ Engine).
In hardware those are parallel engines on the same bus; on TPU, running
them as two kernels costs two HBM reads of the full tensor.  This
kernel fuses both into ONE VMEM visit per tile:

    HBM -> VMEM: ct tile (TILE_N, S*4), base OTPs, diversifiers,
                 binding words (TILE_N, 8), NH key (S*4+8,)
    compute:     pt = ct ^ pad       (crypt engine)
                 nh = NH(ct ‖ bind)  (integ engine, over ciphertext)
    VMEM -> HBM: pt tile + (TILE_N, 2) hashes

Memory-term saving vs. unfused: reads drop from 2x data to 1x data
(hashes/pads are negligible), i.e. ~33% less HBM traffic on the
read+verify path.  Recorded as a §Perf optimization in EXPERIMENTS.md.

The WRITE direction is symmetric: a secure store encrypts the dirty
bytes and MACs the resulting ciphertext.  Unfused that is one kernel
producing ct and a second reading it back to hash — two VMEM visits of
the full tile.  ``fused_crypt_mac_write`` computes the pad XOR and the
NH compression of the just-produced ciphertext in one pass (the ct
never leaves VMEM between the engines), and the ``_mixed`` variant
carries per-block diversifiers + NH key rows so one dispatch reseals
pages owned by different tenant-epoch bank rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv, default_interpret

__all__ = ["fused_crypt_mac", "fused_crypt_mac_mixed",
           "fused_crypt_mac_write", "fused_crypt_mac_write_mixed"]


def _nh_rows(m: jax.Array, k: jax.Array) -> jax.Array:
    """NH over rows of ``m`` with PER-ROW keys ``k`` (both (T, L) u32);
    returns (T, 2) u32 (hi, lo) with emulated 64-bit accumulation.

    Shared by the single-key kernel (key row broadcast over the tile)
    and the mixed-key kernel (one key row per block) — one copy of the
    carry math, so the two paths cannot drift."""
    a = m[:, 0::2] + k[:, 0::2]
    b = m[:, 1::2] + k[:, 1::2]
    mask = jnp.uint32(0xFFFF)
    a_lo, a_hi = a & mask, a >> 16
    b_lo, b_hi = b & mask, b >> 16
    ll = a_lo * b_lo
    mid = a_lo * b_hi + a_hi * b_lo
    mid_carry = (mid < a_lo * b_hi).astype(jnp.uint32)
    lo = ll + (mid << 16)
    lo_carry = (lo < ll).astype(jnp.uint32)
    hi = a_hi * b_hi + (mid >> 16) + (mid_carry << 16) + lo_carry
    s0 = jnp.sum(lo & mask, axis=1, dtype=jnp.uint32)
    s1 = jnp.sum(lo >> 16, axis=1, dtype=jnp.uint32)
    tt = (s0 >> 16) + s1
    lo_sum = (s0 & mask) | ((tt & mask) << 16)
    hi_sum = jnp.sum(hi, axis=1, dtype=jnp.uint32) + (tt >> 16)
    return jnp.stack([hi_sum, lo_sum], axis=-1)


def _fused_kernel(ct_ref, base_ref, div_ref, bind_ref, key_ref,
                  pt_ref, nh_ref):
    ct = ct_ref[...]                           # (T, S*4) u32
    base = base_ref[...]                       # (T, 4) u32
    div = div_ref[...]                         # (S, 4) u32
    bind = bind_ref[...]                       # (T, 8) u32
    k = key_ref[...]                           # (S*4 + 8,) u32

    t, lanes = ct.shape
    s = div.shape[0]

    # --- Crypt engine: diversified pad XOR ---------------------------------
    pads = base[:, None, :] ^ div[None, :, :]
    pt_ref[...] = (ct.reshape(t, s, 4) ^ pads).reshape(t, lanes)

    # --- Integ engine: NH over ciphertext ‖ binding ------------------------
    m = jnp.concatenate([ct, bind], axis=-1)   # (T, L) with L = lanes + 8
    nh_ref[...] = _nh_rows(m, jnp.broadcast_to(k[None, :], m.shape))


def _fused_kernel_mixed(ct_ref, base_ref, div_ref, bind_ref, key_ref,
                        pt_ref, nh_ref):
    """Mixed-key variant: diversifiers and NH keys are PER BLOCK.

    div_ref is (T, S*4) (each row that block's own key schedule rounds
    1..S-1, flattened) and key_ref is (T, S*4 + 8) — one NH key row per
    block — so one kernel pass serves pages that resolve to different
    tenant-epoch bank rows.
    """
    ct = ct_ref[...]                           # (T, S*4) u32
    base = base_ref[...]                       # (T, 4) u32
    div = div_ref[...]                         # (T, S*4) u32
    bind = bind_ref[...]                       # (T, 8) u32
    k = key_ref[...]                           # (T, S*4 + 8) u32

    t, lanes = ct.shape
    s = lanes // 4

    # --- Crypt engine: per-block diversified pad XOR -----------------------
    pads = base[:, None, :] ^ div.reshape(t, s, 4)
    pt_ref[...] = (ct.reshape(t, s, 4) ^ pads).reshape(t, lanes)

    # --- Integ engine: NH over ciphertext ‖ binding, per-block keys --------
    m = jnp.concatenate([ct, bind], axis=-1)   # (T, L) with L = lanes + 8
    nh_ref[...] = _nh_rows(m, k)


def _fused_write_kernel(pt_ref, base_ref, div_ref, bind_ref, key_ref,
                        ct_ref, nh_ref):
    """Write direction: encrypt, then NH over the FRESH ciphertext.

    Same tile layout as :func:`_fused_kernel`; the only difference is
    which side of the pad XOR feeds the integ engine — reads hash the
    incoming bytes, writes hash the outgoing ones."""
    pt = pt_ref[...]                           # (T, S*4) u32
    base = base_ref[...]                       # (T, 4) u32
    div = div_ref[...]                         # (S, 4) u32
    bind = bind_ref[...]                       # (T, 8) u32
    k = key_ref[...]                           # (S*4 + 8,) u32

    t, lanes = pt.shape
    s = div.shape[0]

    # --- Crypt engine: diversified pad XOR ---------------------------------
    pads = base[:, None, :] ^ div[None, :, :]
    ct = (pt.reshape(t, s, 4) ^ pads).reshape(t, lanes)
    ct_ref[...] = ct

    # --- Integ engine: NH over ciphertext ‖ binding ------------------------
    m = jnp.concatenate([ct, bind], axis=-1)   # (T, L) with L = lanes + 8
    nh_ref[...] = _nh_rows(m, jnp.broadcast_to(k[None, :], m.shape))


def _fused_write_kernel_mixed(pt_ref, base_ref, div_ref, bind_ref, key_ref,
                              ct_ref, nh_ref):
    """Mixed-key write: per-block diversifiers + NH key rows, as in
    :func:`_fused_kernel_mixed`, hashing the fresh ciphertext."""
    pt = pt_ref[...]                           # (T, S*4) u32
    base = base_ref[...]                       # (T, 4) u32
    div = div_ref[...]                         # (T, S*4) u32
    bind = bind_ref[...]                       # (T, 8) u32
    k = key_ref[...]                           # (T, S*4 + 8) u32

    t, lanes = pt.shape
    s = lanes // 4

    # --- Crypt engine: per-block diversified pad XOR -----------------------
    pads = base[:, None, :] ^ div.reshape(t, s, 4)
    ct = (pt.reshape(t, s, 4) ^ pads).reshape(t, lanes)
    ct_ref[...] = ct

    # --- Integ engine: NH over ciphertext ‖ binding, per-block keys --------
    m = jnp.concatenate([ct, bind], axis=-1)   # (T, L) with L = lanes + 8
    nh_ref[...] = _nh_rows(m, k)


def _call_mixed(kernel_body, data_lanes, base_otp_lanes, div_lanes_per,
                bind_words, key_per_u32, tile_n, interpret):
    """Shared pad/tile/dispatch plumbing of the two mixed-key kernels
    (read and write share every shape — only the body differs)."""
    if interpret is None:
        interpret = default_interpret()
    n, lanes = data_lanes.shape
    s = div_lanes_per.shape[1]
    assert lanes == 4 * s and key_per_u32.shape == (n, lanes + 8)
    tile_n = min(tile_n, max(8, n))
    n_pad = cdiv(n, tile_n) * tile_n
    data_p = jnp.zeros((n_pad, lanes), jnp.uint32).at[:n].set(data_lanes)
    base_p = jnp.zeros((n_pad, 4), jnp.uint32).at[:n].set(base_otp_lanes)
    div_p = jnp.zeros((n_pad, lanes), jnp.uint32).at[:n].set(
        div_lanes_per.reshape(n, lanes))
    bind_p = jnp.zeros((n_pad, 8), jnp.uint32).at[:n].set(bind_words)
    key_p = jnp.zeros((n_pad, lanes + 8), jnp.uint32).at[:n].set(key_per_u32)

    out, nh = pl.pallas_call(
        kernel_body,
        grid=(n_pad // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, lanes), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 4), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, lanes), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 8), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, lanes + 8), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_n, lanes), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, lanes), jnp.uint32),
            jax.ShapeDtypeStruct((n_pad, 2), jnp.uint32),
        ],
        interpret=interpret,
    )(data_p, base_p, div_p, bind_p, key_p)
    return out[:n], nh[:n]


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def fused_crypt_mac_mixed(ct_lanes: jax.Array, base_otp_lanes: jax.Array,
                          div_lanes_per: jax.Array, bind_words: jax.Array,
                          key_per_u32: jax.Array, *, tile_n: int = 256,
                          interpret: bool | None = None):
    """Mixed-key fused decrypt + NH: per-block diversifiers (N, S, 4)
    and per-block NH keys (N, S*4 + 8).  Returns (plaintext lanes
    (N, S*4) u32, NH hashes (N, 2) u32), bit-identical to vmapping
    :func:`fused_crypt_mac` over per-key groups."""
    return _call_mixed(_fused_kernel_mixed, ct_lanes, base_otp_lanes,
                       div_lanes_per, bind_words, key_per_u32, tile_n,
                       interpret)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def fused_crypt_mac_write_mixed(pt_lanes: jax.Array,
                                base_otp_lanes: jax.Array,
                                div_lanes_per: jax.Array,
                                bind_words: jax.Array,
                                key_per_u32: jax.Array, *, tile_n: int = 256,
                                interpret: bool | None = None):
    """Mixed-key fused encrypt + NH (the one-pass dirty-page reseal):
    returns (ciphertext lanes (N, S*4) u32, NH hashes of the FRESH
    ciphertext (N, 2) u32), bit-identical to encrypting and then
    hashing per key group."""
    return _call_mixed(_fused_write_kernel_mixed, pt_lanes, base_otp_lanes,
                       div_lanes_per, bind_words, key_per_u32, tile_n,
                       interpret)


def _call_single(kernel_body, data_lanes, base_otp_lanes, div_lanes,
                 bind_words, key_u32, tile_n, interpret):
    """Shared plumbing of the two single-key kernels (read and write)."""
    if interpret is None:
        interpret = default_interpret()
    n, lanes = data_lanes.shape
    s = div_lanes.shape[0]
    assert lanes == 4 * s and key_u32.shape[0] == lanes + 8
    tile_n = min(tile_n, max(8, n))
    n_pad = cdiv(n, tile_n) * tile_n
    data_p = jnp.zeros((n_pad, lanes), jnp.uint32).at[:n].set(data_lanes)
    base_p = jnp.zeros((n_pad, 4), jnp.uint32).at[:n].set(base_otp_lanes)
    bind_p = jnp.zeros((n_pad, 8), jnp.uint32).at[:n].set(bind_words)

    out, nh = pl.pallas_call(
        kernel_body,
        grid=(n_pad // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, lanes), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 4), lambda i: (i, 0)),
            pl.BlockSpec((s, 4), lambda i: (0, 0)),
            pl.BlockSpec((tile_n, 8), lambda i: (i, 0)),
            pl.BlockSpec((lanes + 8,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile_n, lanes), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, lanes), jnp.uint32),
            jax.ShapeDtypeStruct((n_pad, 2), jnp.uint32),
        ],
        interpret=interpret,
    )(data_p, base_p, div_lanes, bind_p, key_u32)
    return out[:n], nh[:n]


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def fused_crypt_mac(ct_lanes: jax.Array, base_otp_lanes: jax.Array,
                    div_lanes: jax.Array, bind_words: jax.Array,
                    key_u32: jax.Array, *, tile_n: int = 256,
                    interpret: bool | None = None):
    """Returns (plaintext lanes (N, S*4) u32, NH hashes (N, 2) u32)."""
    return _call_single(_fused_kernel, ct_lanes, base_otp_lanes, div_lanes,
                        bind_words, key_u32, tile_n, interpret)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def fused_crypt_mac_write(pt_lanes: jax.Array, base_otp_lanes: jax.Array,
                          div_lanes: jax.Array, bind_words: jax.Array,
                          key_u32: jax.Array, *, tile_n: int = 256,
                          interpret: bool | None = None):
    """Single-key fused encrypt + NH: returns (ciphertext lanes
    (N, S*4) u32, NH hashes of the fresh ciphertext (N, 2) u32)."""
    return _call_single(_fused_write_kernel, pt_lanes, base_otp_lanes,
                        div_lanes, bind_words, key_u32, tile_n, interpret)
