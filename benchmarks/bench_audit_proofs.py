"""Audit-proof benchmark: proof size scaling + Merkle maintenance cost.

Two row families for the auditable integrity level
(``serve/merkle_pool.py``):

* **proof rows** (synthetic tree, one per pool size) — time to issue
  one membership proof against an ``n``-frame pool and the proof's
  sibling-path length; the O(log n) claim is the gate:
  ``proof_len <= ceil(log2(n_pages)) + 1``;
* **overhead rows** (one per scheme) — steady decode throughput of a
  real engine with the Merkle maintainer attached (``merkle=True``)
  vs. the identical run with only the CBC-MAC/XOR fold levels
  (``merkle=False``).  The amortized ``_tick_end`` maintenance must
  cost ``<= 5%`` tok/s (``check_audit_proofs.py``), plus the counters
  that prove the amortization actually ran (root updates ~ ticks /
  defer_interval, not ~ ticks).

Standalone JSON mode::

    PYTHONPATH=src python benchmarks/bench_audit_proofs.py --seed 7 \\
        --json bench-audit-proofs.json
"""

from __future__ import annotations

import argparse
import json
import math
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.secure_exec import SCHEMES
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.serve import merkle_pool as mkp
from repro.serve.engine import SecureServingEngine

try:                                    # package or script invocation
    from benchmarks._meta import stamp
except ImportError:
    from _meta import stamp  # noqa: E402

PROOF_POOL_SIZES = (16, 64, 256, 1024)
OVERHEAD_SCHEMES = ("off", "sgx64", "seda")


class _MacTable:
    """Pool stand-in: the maintainer only needs a MAC table."""

    def __init__(self, macs):
        self.macs = macs


def _measure_proof(n_pages: int, *, seed: int, iters: int = 200) -> dict:
    rng = np.random.default_rng(seed)
    macs = rng.integers(0, 256, (n_pages, mkp.MAC_BYTES), dtype=np.uint8)
    owners = rng.integers(0, 4, n_pages).astype(np.int64)
    m = mkp.MerklePagePool(n_pages, leaf_fn=lambda p: p.macs,
                           owners_fn=lambda: owners)
    m.on_pool_update(None, _MacTable(macs))
    m.sync()
    pages = rng.integers(0, n_pages, iters)
    t0 = time.perf_counter()
    for p in pages:
        m.page_proof(int(p))
    dt = time.perf_counter() - t0
    proof = m.page_proof(int(pages[0]))
    assert mkp.verify_proof(
        mkp.AuditProof(shard=0, n_pages=n_pages, tenant=None,
                       root=m.root_hex(), pages=(proof,)),
        expected_root=m.root_hex())
    return {
        "name": f"audit_proof_n{n_pages}",
        "mode": "proof",
        "n_pages": n_pages,
        "proof_len": len(proof.path),
        "proof_bytes": len(json.dumps(proof.to_dict())),
        "us_per_call": dt / iters * 1e6,
    }


def _throughput(arch, cfg, params, scheme: str, *, merkle: bool,
                seed: int, batch: int, gen_len: int, prompt_len: int,
                page_tokens: int, pages_per_slot: int) -> tuple:
    eng = SecureServingEngine(
        arch, cfg, params, scheme=scheme, max_slots=batch,
        page_tokens=page_tokens, pages_per_slot=pages_per_slot,
        n_pages=batch * pages_per_slot, merkle=merkle,
        defer_interval=4)       # several syncs per run, still amortized
    rng = np.random.default_rng(seed)
    for _ in range(batch):
        eng.submit(prompt=list(map(int, rng.integers(1, cfg.vocab,
                                                     prompt_len))),
                   max_new_tokens=gen_len)
    eng.step()                      # admission + first decode (compiles)
    t0 = time.perf_counter()
    while eng._n_waiting() or any(s is not None for s in eng.slots):
        eng.step()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.generated) for r in eng.requests.values())
    return n_tok, dt, eng


def _measure_overhead(arch, cfg, params, scheme: str, *,
                      repeats: int = 5, **kw) -> dict:
    # The Merkle maintainer is host-side only (same XLA programs), so
    # one warmup run compiles for both variants.  A percent-level
    # comparison on short CPU runs is noise-bound: the repeats
    # alternate base/merkle (decorrelating machine drift) and each
    # variant aggregates tokens over total time — one long effective
    # run per variant, not a median of noisy short ones.
    _throughput(arch, cfg, params, scheme, merkle=False, **kw)
    base_tok = base_dt = merk_tok = merk_dt = 0.0
    eng = None
    for _ in range(repeats):
        n, dt, _ = _throughput(arch, cfg, params, scheme, merkle=False,
                               **kw)
        base_tok, base_dt = base_tok + n, base_dt + dt
        n, dt, eng = _throughput(arch, cfg, params, scheme, merkle=True,
                                 **kw)
        merk_tok, merk_dt = merk_tok + n, merk_dt + dt
    base_tok_s = base_tok / max(base_dt, 1e-9)
    merk_tok_s = merk_tok / max(merk_dt, 1e-9)
    proof = eng.audit_proof()
    mkp.verify_proof(proof, expected_root=eng.merkle.root_hex())
    return {
        "name": f"merkle_overhead_{scheme}",
        "mode": "overhead",
        "scheme": scheme,
        "n_pages": eng.n_pages,
        "tok_per_s": merk_tok_s,
        "tok_per_s_base": base_tok_s,
        # Not the history-tracked `overhead_pct`: a percent-level CPU
        # A/B jitters far past that metric's regression band — the
        # dedicated check_audit_proofs.py gate owns the 5% bound.
        "merkle_overhead_pct": (base_tok_s - merk_tok_s)
        / base_tok_s * 100.0,
        "ticks": eng.tick,
        "root_updates": eng.stats["merkle_root_updates"],
        "leaf_updates": eng.stats["merkle_leaf_updates"],
        "proof_len": max((len(p.path) for p in proof.pages), default=0),
    }


def collect(pool_sizes=PROOF_POOL_SIZES, schemes=OVERHEAD_SCHEMES, *,
            arch_name: str = "minitron-4b", seed: int = 7,
            batch: int = 4, gen_len: int = 24, prompt_len: int = 9,
            page_tokens: int = 8, pages_per_slot: int = 8) -> list:
    results = [_measure_proof(n, seed=seed) for n in pool_sizes]
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    common = dict(seed=seed, batch=batch, gen_len=gen_len,
                  prompt_len=prompt_len, page_tokens=page_tokens,
                  pages_per_slot=pages_per_slot)
    for scheme in schemes:
        results.append(_measure_overhead(arch, cfg, params, scheme,
                                         **common))
    return results


def run() -> list:
    """benchmarks.run suite hook: CSV rows for a reduced sweep."""
    rows = []
    for r in collect(pool_sizes=(16, 256), schemes=("seda",)):
        if r["mode"] == "proof":
            rows.append({
                "name": r["name"],
                "us_per_call": r["us_per_call"],
                "derived": (f"proof_len={r['proof_len']} "
                            f"(bound={math.ceil(math.log2(r['n_pages']))}) "
                            f"bytes={r['proof_bytes']}"),
            })
        else:
            rows.append({
                "name": r["name"],
                "us_per_call": 1e6 / max(r["tok_per_s"], 1e-9),
                "derived": (f"overhead={r['merkle_overhead_pct']:.2f}% "
                            f"roots={r['root_updates']}/"
                            f"{r['ticks']}ticks "
                            f"leaves={r['leaf_updates']}"),
            })
    return rows


def main(argv=None) -> list:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--pool-sizes",
                    default=",".join(map(str, PROOF_POOL_SIZES)))
    ap.add_argument("--schemes", default=",".join(OVERHEAD_SCHEMES))
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=9)
    ap.add_argument("--page-tokens", type=int, default=8)
    ap.add_argument("--pages-per-slot", type=int, default=8)
    ap.add_argument("--json", default=None, help="write results to this file")
    args = ap.parse_args(argv)

    for s in args.schemes.split(","):
        if s not in SCHEMES:
            raise SystemExit(f"unknown scheme {s!r}")
    results = collect(
        pool_sizes=tuple(int(n) for n in args.pool_sizes.split(",")),
        schemes=tuple(args.schemes.split(",")),
        arch_name=args.arch, seed=args.seed, batch=args.batch,
        gen_len=args.gen_len, prompt_len=args.prompt_len,
        page_tokens=args.page_tokens, pages_per_slot=args.pages_per_slot)
    for r in results:
        if r["mode"] == "proof":
            print(f"[audit-bench] {r['name']:<24} "
                  f"len={r['proof_len']:2d} bytes={r['proof_bytes']:5d} "
                  f"us/proof={r['us_per_call']:7.1f}")
        else:
            print(f"[audit-bench] {r['name']:<24} "
                  f"overhead={r['merkle_overhead_pct']:6.2f}% "
                  f"roots={r['root_updates']}/{r['ticks']}ticks")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(stamp({"benchmark": "audit_proofs",
                             "seed": args.seed, "results": results}),
                      f, indent=2)
        print(f"[audit-bench] wrote {args.json}")
    return results


if __name__ == "__main__":
    main()
