"""Merkle pool integrity: maintenance equivalence + adversarial proofs.

Three layers of evidence for the auditable integrity level
(:mod:`repro.serve.merkle_pool`):

* **equivalence** — the incrementally-maintained tree is node-for-node
  identical to a from-scratch rebuild, property-tested over synthetic
  op streams (hypothesis when available, seeded streams always) and
  over *real* engine schedules (admit / decode / preempt / rotate /
  quarantine) across every scheme and shard count {1, 2};
* **forgery** — each of the five forgery classes in the threat model
  (flipped leaf MAC, swapped sibling, truncated/extended path,
  stale-root replay, cross-tenant reuse) fails ``verify_proof`` with
  its own distinct error type;
* **interaction** — quarantine (`_commit_repair`) excludes retired
  frames from the rebuilt tree and rotates the root out from under
  pre-repair proofs without disturbing anyone else's; migration and
  checkpoint restore carry verifiable transcripts.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.secure_exec import SCHEMES
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.serve import kv_pages as kvp
from repro.serve import merkle_pool as mkp
from repro.serve.cluster import ClusterEngine
from repro.serve.engine import SecureServingEngine
from repro.serve.faults import Fault, FaultPlan
from repro.tenancy import KeyHierarchy, TenantRegistry

from tests._hyp import HAVE_HYPOTHESIS, given, settings, st


@pytest.fixture(scope="module")
def smoke():
    arch = get_arch("minitron-4b")
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    return arch, cfg, params


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(0)
    return [list(map(int, rng.integers(1, 256, n))) for n in (6, 5, 7)]


def _engine(smoke, **kw):
    arch, cfg, params = smoke
    kw.setdefault("max_slots", 2)
    kw.setdefault("page_tokens", 4)
    kw.setdefault("pages_per_slot", 4)
    kw.setdefault("n_pages", 12)
    kw.setdefault("scheme", "seda")
    kw.setdefault("defer_interval", 2)
    return SecureServingEngine(arch, cfg, params, **kw)


def _cluster(smoke, **kw):
    arch, cfg, params = smoke
    kw.setdefault("shards", 2)
    kw.setdefault("max_slots", 2)
    kw.setdefault("page_tokens", 4)
    kw.setdefault("pages_per_slot", 4)
    kw.setdefault("scheme", "seda")
    kw.setdefault("defer_interval", 2)
    return ClusterEngine(arch, cfg, params, **kw)


def _two_tenants(seed=5):
    reg = TenantRegistry(KeyHierarchy(seed), max_tenants=4)
    reg.register("a")
    reg.register("b")
    return reg, reg.open_session("a"), reg.open_session("b")


def _assert_node_for_node(maintainer, pool, spec):
    """The incremental tree equals a from-scratch rebuild, every node."""
    snap = maintainer.snapshot()
    rebuilt = mkp.build_tree(kvp.merkle_leaf_macs(pool, spec),
                             maintainer._owners, maintainer._quar,
                             shard=maintainer.shard)
    assert len(snap) == len(rebuilt)
    for level, (got, want) in enumerate(zip(snap, rebuilt)):
        assert got == want, f"tree level {level} diverged from rebuild"


# -- pure-tree unit + property layer -------------------------------------


class _FakePool:
    """Stand-in pool object for driving the maintainer without jax."""

    def __init__(self, macs):
        self.macs = macs


def _drive(ops, n_pages=11, shard=1):
    """Apply an op stream both incrementally and per-step-rebuilt.

    Each op mutates (macs, owners, quarantined); after every op the
    maintainer syncs and must match ``build_tree`` node for node.
    """
    rngless = {"macs": np.zeros((n_pages, mkp.MAC_BYTES), np.uint8),
               "owners": np.full(n_pages, -1, np.int64),
               "quar": set()}
    m = mkp.MerklePagePool(
        n_pages, shard=shard, leaf_fn=lambda p: p.macs,
        owners_fn=lambda: rngless["owners"],
        quarantined_fn=lambda: rngless["quar"])
    pool = _FakePool(rngless["macs"].copy())
    m.on_pool_update(None, pool)
    m.sync()
    for kind, page, payload in ops:
        page = page % n_pages
        if kind == "mac":
            new = _FakePool(pool.macs.copy())
            new.macs[page] = np.frombuffer(
                payload.to_bytes(mkp.MAC_BYTES, "big"), np.uint8)
            m.on_pool_update(pool, new)
            pool = new
        elif kind == "owner":
            rngless["owners"][page] = payload % 7 - 1
        elif kind == "quarantine":
            rngless["quar"].add(page)
        elif kind == "resync":
            m.on_pool_update(None, pool)
        m.sync()
        quar = np.zeros(n_pages, bool)
        quar[sorted(rngless["quar"])] = True
        want = mkp.build_tree(pool.macs, rngless["owners"], quar,
                              shard=shard)
        assert m.snapshot() == want
    return m


class TestMerkleUnit:
    def test_depth_and_proof_length(self):
        for n in (1, 2, 3, 6, 8, 11, 16, 33):
            d = mkp.tree_depth(n)
            assert (1 << d) >= n and (d == 0 or (1 << (d - 1)) < n)
            macs = np.zeros((n, mkp.MAC_BYTES), np.uint8)
            m = mkp.MerklePagePool(n, leaf_fn=lambda p: p.macs)
            m.on_pool_update(None, _FakePool(macs))
            assert len(m.page_proof(0).path) == d

    def test_seeded_op_streams_match_rebuild_node_for_node(self):
        rng = np.random.default_rng(7)
        kinds = ("mac", "owner", "quarantine", "resync")
        for _ in range(6):
            ops = [(kinds[rng.integers(len(kinds))],
                    int(rng.integers(0, 64)),
                    int(rng.integers(0, 2**63)))
                   for _ in range(40)]
            _drive(ops)

    def test_dirty_path_update_is_logarithmic(self):
        """One dirty page rehashes one leaf; sync never walks clean
        subtrees (the amortization claim of the tentpole)."""
        n = 64
        m = mkp.MerklePagePool(n, leaf_fn=lambda p: p.macs)
        pool = _FakePool(np.zeros((n, mkp.MAC_BYTES), np.uint8))
        m.on_pool_update(None, pool)
        m.sync()
        new = _FakePool(pool.macs.copy())
        new.macs[17] ^= 0xA5
        m.on_pool_update(pool, new)
        roots, leaves = m.sync()
        assert (roots, leaves) == (1, 1)

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(
        st.sampled_from(("mac", "owner", "quarantine", "resync")),
        st.integers(min_value=0, max_value=63),
        st.integers(min_value=0, max_value=2**63 - 1)),
        max_size=30))
    def test_property_op_streams_match_rebuild(self, ops):
        _drive(ops)

    def test_retired_leaf_is_not_the_zero_mac_leaf(self):
        """Quarantine exclusion is a distinguished leaf, not a data
        leaf over the scrubbed zero MAC — so 'retired' and 'contains
        zeros' are cryptographically different statements."""
        zero = mkp.leaf_hash(0, 3, -1, bytes(mkp.MAC_BYTES))
        assert mkp.retired_leaf(0, 3) != zero
        assert mkp.empty_leaf(0, 3) != zero

    def test_compress_roots_binds_order_and_count(self):
        r = [(0, bytes(range(32))), (1, bytes(range(1, 33)))]
        assert mkp.compress_roots(r) != mkp.compress_roots(r[::-1])
        assert mkp.compress_roots(r) != mkp.compress_roots(
            r + [(2, bytes(32))])


# -- engine-schedule equivalence across SCHEMES x shards -----------------


class TestScheduleEquivalence:
    """Randomized admit/decode/preempt/rotate/quarantine schedules keep
    the incremental tree node-for-node identical to a rebuild — the
    engine-level form of the property above, for every scheme."""

    @pytest.mark.parametrize("scheme", sorted(SCHEMES))
    def test_single_shard_schedule(self, smoke, scheme):
        reg, sa, sb = _two_tenants(seed=11)
        eng = _engine(smoke, scheme=scheme, registry=reg, max_slots=2,
                      n_pages=14, rotate_every=3)
        rng = np.random.default_rng(hash(scheme) % 2**31)
        sessions = [sa, sb]
        free_probe = []
        for step_no in range(10):
            op = rng.integers(0, 4)
            if op == 0 and len(eng.requests) < 6:       # admit
                prompt = list(map(int, rng.integers(1, 256,
                                                    rng.integers(4, 9))))
                eng.submit(prompt=prompt, max_new_tokens=4,
                           session=sessions[int(rng.integers(2))])
            elif op == 1:                               # rotate (live)
                eng.rotate(("a", "b")[int(rng.integers(2))])
            elif op == 2 and eng.free_pages:            # quarantine a
                free_probe.append(eng.free_pages[-1])   # free frame
                eng._quarantine_pages([free_probe[-1]])
            eng.step()                                  # decode tick
        eng.run()
        _assert_node_for_node(eng.merkle, eng.pool, eng.spec)
        for page in free_probe:
            assert eng.merkle.snapshot()[0][page] == mkp.retired_leaf(
                eng.shard_id, page)

    @pytest.mark.parametrize("scheme", sorted(SCHEMES))
    def test_two_shard_schedule(self, smoke, scheme):
        cl = _cluster(smoke, scheme=scheme, n_pages=8)
        rng = np.random.default_rng(hash(scheme) % 2**31 + 1)
        for step_no in range(8):
            op = rng.integers(0, 3)
            if op == 0 and len(cl.requests) < 5:
                prompt = list(map(int, rng.integers(1, 256,
                                                    rng.integers(4, 9))))
                cl.submit(prompt=prompt, max_new_tokens=4)
            elif op == 1:
                shard = cl.engines[int(rng.integers(2))]
                if shard.free_pages:
                    shard._quarantine_pages([shard.free_pages[-1]])
            cl.step()
        cl.run()
        for eng in cl.engines:
            _assert_node_for_node(eng.merkle, eng.pool, eng.spec)
        assert cl.deferred_check()

    def test_preemption_keeps_equivalence(self, smoke, prompts):
        # Overcommitted pool: growth preempts the youngest slot; the
        # ownership churn (frames freed, re-admitted) must flow through
        # the owner diff into the tree.
        eng = _engine(smoke, max_slots=2, pages_per_slot=4, n_pages=5)
        rids = [eng.submit(prompt=p, max_new_tokens=8) for p in prompts]
        eng.run()
        assert eng.stats["preemptions"] > 0
        _assert_node_for_node(eng.merkle, eng.pool, eng.spec)


# -- adversarial proof forgery -------------------------------------------


@pytest.fixture(scope="module")
def forged(smoke):
    """A live 2-tenant engine + a valid proof for tenant a, shared by
    every forgery case (mutations below never touch the engine)."""
    reg, sa, sb = _two_tenants(seed=23)
    eng = _engine(smoke, registry=reg, max_slots=2, n_pages=14)
    rng = np.random.default_rng(3)
    for session in (sa, sb):
        eng.submit(prompt=list(map(int, rng.integers(1, 256, 6))),
                   max_new_tokens=8, session=session)
    eng.step()
    eng.step()
    proof = eng.audit_proof(sa)
    assert mkp.verify_proof(proof, expected_root=eng.merkle.root_hex(),
                            tenant=proof.tenant)
    return eng, sa, sb, proof


class TestProofForgery:
    def test_flipped_leaf_mac_rejected(self, forged):
        eng, sa, sb, proof = forged
        page = proof.pages[0]
        mac = bytearray(bytes.fromhex(page.mac))
        mac[0] ^= 0x01
        bad = dataclasses.replace(
            proof, pages=(dataclasses.replace(page, mac=bytes(mac).hex()),)
            + proof.pages[1:])
        with pytest.raises(mkp.LeafMacError):
            mkp.verify_proof(bad, expected_root=proof.root)

    def test_swapped_sibling_rejected(self, forged):
        eng, sa, sb, proof = forged
        page = proof.pages[0]
        other = eng.merkle.page_proof(
            next(p for p in range(eng.n_pages)
                 if p != page.page and (p >> 1) != (page.page >> 1)))
        path = (other.path[0],) + page.path[1:]
        bad = dataclasses.replace(
            proof, pages=(dataclasses.replace(page, path=path),)
            + proof.pages[1:])
        with pytest.raises(mkp.SiblingPathError):
            mkp.verify_proof(bad, expected_root=proof.root)

    def test_truncated_path_rejected(self, forged):
        eng, sa, sb, proof = forged
        page = proof.pages[0]
        bad = dataclasses.replace(
            proof,
            pages=(dataclasses.replace(page, path=page.path[:-1]),))
        with pytest.raises(mkp.PathLengthError):
            mkp.verify_proof(bad, expected_root=proof.root)

    def test_extended_path_rejected(self, forged):
        eng, sa, sb, proof = forged
        page = proof.pages[0]
        bad = dataclasses.replace(
            proof,
            pages=(dataclasses.replace(page,
                                       path=page.path + (page.path[-1],)),))
        with pytest.raises(mkp.PathLengthError):
            mkp.verify_proof(bad, expected_root=proof.root)

    def test_stale_root_replay_rejected(self, forged):
        eng, sa, sb, proof = forged
        old_root = proof.root
        for _ in range(4):          # decode on: MACs move, root rotates
            eng.step()
        current = eng.merkle.root_hex()
        assert current != old_root
        # Internally the old proof still folds (it was valid once)...
        assert mkp.verify_proof(proof, tenant=proof.tenant)
        # ...but replaying it against the attested current root fails.
        with pytest.raises(mkp.StaleRootError):
            mkp.verify_proof(proof, expected_root=current)

    def test_cross_tenant_proof_reuse_rejected(self, forged):
        eng, sa, sb, proof = forged
        tenant_b = eng.registry.validate(sb).index
        # Tenant b presenting tenant a's proof as its own:
        with pytest.raises(mkp.TenantMismatchError):
            mkp.verify_proof(proof, tenant=tenant_b)
        # ...and relabeling the tenant field breaks the leaf binding
        # instead (the owner is folded into every leaf hash).
        relabeled = dataclasses.replace(
            proof, tenant=tenant_b,
            pages=tuple(dataclasses.replace(p, owner=tenant_b)
                        for p in proof.pages))
        with pytest.raises(mkp.LeafMacError):
            mkp.verify_proof(relabeled, tenant=tenant_b)

    def test_issuing_cross_tenant_proof_refused_at_source(self, forged):
        eng, sa, sb, proof = forged
        b_idx = eng.registry.validate(sb).index
        with pytest.raises(ValueError):
            eng.merkle.audit_proof([p.page for p in proof.pages],
                                   tenant=b_idx)

    def test_forged_errors_are_distinct_classes(self):
        errs = (mkp.LeafMacError, mkp.SiblingPathError,
                mkp.PathLengthError, mkp.StaleRootError,
                mkp.TenantMismatchError)
        for i, a in enumerate(errs):
            for b in errs[i + 1:]:
                assert not issubclass(a, b) and not issubclass(b, a)


# -- quarantine x Merkle regression --------------------------------------


class TestQuarantineMerkle:
    def test_commit_repair_excludes_retired_frames(self, smoke, prompts):
        """PR 9's `_commit_repair` path: a contained bit-flip retires
        the victim frame; the rebuilt tree hashes it as a *retired*
        leaf, pre-repair proofs stop verifying against the new root,
        and the unaffected session's fresh proof still verifies."""
        eng = _engine(smoke, fault_tolerance=True)
        FaultPlan([Fault(tick=3, kind="bitflip", slot=0)]).attach(eng)
        rids = [eng.submit(prompt=p, max_new_tokens=4)
                for p in prompts[:2]]
        eng.step()
        pre = eng.audit_proof()                   # pre-repair transcript
        pre_root = pre.root
        eng.run()                                 # fault fires, contained
        assert eng.stats["integrity_quarantined_pages"] >= 1
        assert eng.quarantined
        snap = eng.merkle.snapshot()
        for page in eng.quarantined:
            assert snap[0][page] == mkp.retired_leaf(eng.shard_id, page)
            with pytest.raises(ValueError):
                eng.merkle.page_proof(page)
        _assert_node_for_node(eng.merkle, eng.pool, eng.spec)
        # The repair rotated the root: the pre-repair proof is stale.
        new_root = eng.merkle.root_hex()
        assert new_root != pre_root
        with pytest.raises(mkp.StaleRootError):
            mkp.verify_proof(pre, expected_root=new_root)

    def test_unaffected_sessions_proofs_still_verify(self, smoke):
        reg, sa, sb = _two_tenants(seed=31)
        eng = _engine(smoke, registry=reg, fault_tolerance=True,
                      max_slots=2, n_pages=14)
        rng = np.random.default_rng(9)
        eng.submit(prompt=list(map(int, rng.integers(1, 256, 6))),
                   max_new_tokens=8, session=sa)
        eng.submit(prompt=list(map(int, rng.integers(1, 256, 5))),
                   max_new_tokens=8, session=sb)
        eng.step()
        # Retire a free frame (metadata repair, no session involved).
        victim = eng.free_pages[-1]
        eng._quarantine_pages([victim])
        for session in (sa, sb):
            p = eng.audit_proof(session)
            assert p.pages
            assert mkp.verify_proof(p, expected_root=eng.merkle.root_hex(),
                                    tenant=p.tenant)
        assert victim not in [pp.page for s in (sa, sb)
                              for pp in eng.audit_proof(s).pages]

    def test_listener_bypass_page_swap_fails_merkle_level(self, smoke,
                                                          prompts):
        """A pool swapped in around the listener with a *consistent*
        XOR identity (page MACs + pool MAC + mirror all patched) passes
        the fold levels but fails the Merkle rebuild comparison — the
        new level catches what the mirrors alone cannot."""
        import jax.numpy as jnp
        from repro.core import mac as mac_mod
        cl = _cluster(smoke)
        for p in prompts:
            cl.submit(prompt=p, max_new_tokens=4)
        cl.step()
        assert cl.deferred_check()
        e0 = cl.engines[0]
        macs = np.asarray(e0.pool.page_macs).copy()
        macs[0] ^= 0x5A                           # swap page state...
        pool_mac = mac_mod.xor_aggregate(
            jnp.asarray(macs[: e0.spec.n_pages]))
        e0._pool = e0.pool._replace(               # ...bypassing the
            page_macs=jnp.asarray(macs),           # listener, with the
            pool_mac=pool_mac)                     # XOR identity patched
        cl.sharded._mirrors[0] = jnp.asarray(pool_mac)  # and the mirror
        assert not cl.deferred_check()
        assert 0 in cl.sharded.failing_shards()


# -- cluster proofs, migration, checkpoint threading ---------------------


class TestClusterProofs:
    def test_cluster_proof_chains_to_cluster_root(self, smoke):
        reg, sa, sb = _two_tenants(seed=41)
        cl = _cluster(smoke, registry=reg, n_pages=8)
        rng = np.random.default_rng(13)
        for session in (sa, sb, sa):
            cl.submit(prompt=list(map(int, rng.integers(1, 256, 5))),
                      max_new_tokens=6, session=session)
        cl.step()
        cl.step()
        proofs = cl.audit_proof(sa)
        assert proofs
        cluster_root = cl.sharded.merkle_root.hex()
        for p in proofs:
            assert p.cluster["root"] == cluster_root
            assert mkp.verify_proof(p, tenant=p.tenant)
        # Tampering the shard-root set breaks the cluster binding.
        p = proofs[0]
        forged_roots = [(s, ("0" * 64 if s != p.shard else r))
                        for s, r in p.cluster["shard_roots"]]
        bad = dataclasses.replace(p, cluster={
            "shard_roots": forged_roots, "root": p.cluster["root"]})
        with pytest.raises(mkp.ClusterRootError):
            mkp.verify_proof(bad)

    def test_failed_shard_folds_out_of_cluster_root(self, smoke, prompts):
        cl = _cluster(smoke)
        for p in prompts:
            cl.submit(prompt=p, max_new_tokens=4)
        cl.step()
        with_both = cl.sharded.merkle_root
        cl.sharded.fold_out(1)
        assert cl.sharded.merkle_root != with_both
        assert [s for s, _ in cl.sharded.merkle_roots()] == [0]

    def test_migration_carries_verifiable_transcript(self, smoke,
                                                     prompts):
        cl = _cluster(smoke, shards=2, max_slots=2, pages_per_slot=8,
                      n_pages=8)
        cl.submit(prompt=prompts[0], max_new_tokens=20)
        cl.submit(prompt=prompts[1], max_new_tokens=2)
        cl.submit(prompt=prompts[2], max_new_tokens=20)
        cl.run()
        assert cl.stats["migrations"] > 0
        assert cl.migration_proofs
        for entry in cl.migration_proofs:
            proof = mkp.proof_from_dict(entry["proof"])
            assert proof.shard == entry["to_shard"]
            assert mkp.verify_proof(proof)     # dst-side, post-landing
            assert entry["src_root"] != proof.root
        assert cl.deferred_check()

    def test_checkpoint_threads_and_reverifies_proofs(self, smoke,
                                                      tmp_path, keys):
        from repro.checkpoint.secure_ckpt import (CheckpointError,
                                                  load_checkpoint,
                                                  save_checkpoint)
        eng = _engine(smoke)
        rng = np.random.default_rng(17)
        eng.submit(prompt=list(map(int, rng.integers(1, 256, 6))),
                   max_new_tokens=8)
        eng.step()
        proof = eng.audit_proof()
        tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}
        path = save_checkpoint(str(tmp_path), 1, tree, keys,
                               audit_proofs=[proof])
        restored, manifest = load_checkpoint(path, tree, keys)
        assert manifest["audit_proofs"]
        stored = mkp.proof_from_dict(manifest["audit_proofs"][0])
        assert mkp.verify_proof(stored, expected_root=proof.root)
        # A tampered stored transcript fails the restore loudly.
        import json
        import os
        mpath = os.path.join(path, "manifest.json")
        with open(mpath) as f:
            doc = json.load(f)
        doc["audit_proofs"][0]["pages"][0]["mac"] = "00" * mkp.MAC_BYTES
        with open(mpath, "w") as f:
            json.dump(doc, f)
        with pytest.raises(CheckpointError):
            load_checkpoint(path, tree, keys)
