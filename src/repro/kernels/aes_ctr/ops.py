"""Public jit'd wrappers for the AES-CTR keystream kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.aes_ctr.kernel import aes_ctr_keystream

__all__ = ["keystream_lanes", "keystream_bytes"]


def keystream_lanes(counter_words: jax.Array, round_keys: jax.Array, *,
                    subbytes: str = "take",
                    interpret: bool | None = None) -> jax.Array:
    """OTPs as (N, 4) uint32 little-endian lanes."""
    return aes_ctr_keystream(counter_words, round_keys, subbytes=subbytes,
                             interpret=interpret)


def keystream_bytes(counter_words: jax.Array, round_keys: jax.Array, *,
                    subbytes: str = "take",
                    interpret: bool | None = None) -> jax.Array:
    """OTPs as (N, 16) uint8, matching :mod:`repro.core.ctr` layout."""
    lanes = keystream_lanes(counter_words, round_keys, subbytes=subbytes,
                            interpret=interpret)
    return jax.lax.bitcast_convert_type(lanes[..., None], jnp.uint8).reshape(
        lanes.shape[0], 16)
