"""Device-cost attribution: protection vs. model HLO cost per bucket.

``Engine.decode_cost_analysis`` reports one aggregate flops /
bytes-accessed number per compiled decode variant — enough to see that
a scheme costs *something*, useless for saying *where*.  This module
walks the compiled HLO text instead (``fn.lower().compile()
.as_text()``), which on both the CPU and TPU backends keeps per
-instruction ``metadata={op_name=... source_file=... source_line=...}``
pointing at the Python that built each op.  That lets us split the
decode step's cost into

* **protection** — AES-CTR keystream + BAES key schedule, NH/CBC-MAC,
  VN freshness, key-bank gathers, page binding/counter construction
  (the crypto files under ``core/`` and ``kernels/``, plus the
  protection helpers inside ``serve/kv_pages.py`` by source-line
  range), and
* **model** — attention/MLP/sampling and the paging glue the model
  would need even with protection ``off``.

Accounting conventions (deliberately close to XLA's own
HloCostAnalysis so the totals track ``cost_analysis()``):

* bytes: operand + output shape bytes of every *top-level* instruction
  (ENTRY / while bodies / called computations).  Instructions inside
  ``fused_computation``/``region_`` bodies are intermediates the
  fusion call line already accounts for; ``parameter`` /
  ``get-tuple-element`` / ``tuple`` / ``bitcast`` / ``constant`` are
  free (reads are charged at use sites).
* flops: ``dot`` = 2·M·N·K, elementwise arithmetic = one flop per
  output element, ``reduce`` = one per input element — counted in
  *every* computation (fusion bodies do the arithmetic; the fusion
  call itself contributes none).

The split is attached to the engine as lazy gauges (sampled from a
cache — snapshotting never compiles anything) and exported as JSON via
``Engine.profile()`` / ``ClusterEngine.profile()``.
"""

from __future__ import annotations

import ast
import math
import re
from dataclasses import dataclass, field
from typing import Optional

from repro.launch.hlo_utils import parse_shape_bytes
from repro.launch.roofline import HBM_BW, PEAK_FLOPS

__all__ = ["CostProfile", "attribute_hlo", "classify_source",
           "profile_decode"]

# -- source classification ---------------------------------------------------

# Crypto/integrity modules: every op they emit is protection work.
_PROTECTION_BASENAMES = frozenset({
    "aes.py", "baes.py", "ctr.py", "mac.py", "vn.py", "multilevel.py",
    "secure_exec.py", "secure_memory.py", "bytesutil.py",
})

# serve/kv_pages.py mixes paging glue (model-side) with the protection
# path; these functions are the protection side, attributed by the
# source-line ranges ast gives us.
_KV_PROTECTION_FUNCS = frozenset({
    "_block_pa", "_tenant_words", "_shard_ctr_word", "_block_counters",
    "_block_binding", "_uniform_keys", "_crypt", "_page_block_macs",
    "_fused_crossing", "_fused_read", "_fused_write",
    "deferred_pool_check",
})

_kv_ranges_cache: Optional[list] = None


def _kv_protection_ranges() -> list:
    """[(lo, hi)] source-line ranges of kv_pages' protection helpers."""
    global _kv_ranges_cache
    if _kv_ranges_cache is None:
        from repro.serve import kv_pages
        with open(kv_pages.__file__) as f:
            tree = ast.parse(f.read())
        ranges = []
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in _KV_PROTECTION_FUNCS:
                ranges.append((node.lineno, node.end_lineno or node.lineno))
        _kv_ranges_cache = sorted(ranges)
    return _kv_ranges_cache


def classify_source(source_file: str, source_line: int) -> str:
    """'protection' | 'model' for one attributed HLO instruction."""
    path = source_file.replace("\\", "/")
    if "/kernels/" in path:
        return "protection"
    base = path.rsplit("/", 1)[-1]
    if base in _PROTECTION_BASENAMES:
        return "protection"
    if base == "kv_pages.py":
        for lo, hi in _kv_protection_ranges():
            if lo <= source_line <= hi:
                return "protection"
    return "model"


# -- HLO text walking --------------------------------------------------------

_META_RE = re.compile(r'source_file="([^"]+)" source_line=(\d+)')
_SHAPE_RE = re.compile(r"\b(?:pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64"
                       r"|u64|f64|c64|c128)\[([0-9,]*)\]")
_OP_RE = re.compile(r"=\s*(?:\([^)]*\)\s*)?[a-z0-9_\[\],{}\s]*?"
                    r"([a-z][a-z0-9-]*)\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims={([0-9,]*)}")

# Shape-shuffling ops XLA charges nothing for (reads are charged where
# the value is consumed), plus control-flow wrappers whose operand
# tuples merely alias the bodies we already account for.
_FREE_OPS = frozenset({"parameter", "get-tuple-element", "tuple", "bitcast",
                       "constant", "after-all", "iota", "while",
                       "conditional", "call"})

# One flop per output element.
_ELEMENTWISE = frozenset({
    "add", "subtract", "multiply", "divide", "remainder", "power",
    "maximum", "minimum", "and", "or", "xor", "not", "negate", "abs",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "compare", "select", "exponential", "log", "tanh", "rsqrt", "sqrt",
    "sign", "floor", "ceil", "round-nearest-afz", "clamp", "convert",
    "sine", "cosine", "logistic", "atan2", "is-finite", "popcnt", "clz",
})

# Pure data movement: when even dataflow inheritance cannot attribute
# one of these, it is loop/layout glue and folds into the model bucket.
_MOVEMENT_OPS = frozenset({
    "copy", "broadcast", "transpose", "reshape", "pad", "slice",
    "concatenate", "dynamic-slice", "dynamic-update-slice", "reverse",
})

# Computations whose instructions are fusion/reduce intermediates; the
# calling instruction carries their memory traffic.
_INNER_COMP = re.compile(r"^%?(fused_computation|region_|\S*reduce_sub"
                         r"_computation|\S*scatter_computation)")


def _elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _line_flops(line: str, opcode: str) -> float:
    shapes = _SHAPE_RE.findall(line)
    if not shapes:
        return 0.0
    out = _elems(shapes[0])
    if opcode in ("dot", "convolution"):
        contract = 1
        m = _CONTRACT_RE.search(line)
        if m and len(shapes) >= 2:
            lhs = shapes[1].split(",") if shapes[1] else []
            for d in (m.group(1).split(",") if m.group(1) else []):
                d = int(d)
                if d < len(lhs):
                    contract *= int(lhs[d])
        return 2.0 * out * contract
    if opcode == "reduce" and len(shapes) >= 2:
        return float(_elems(shapes[1]))
    if opcode in _ELEMENTWISE:
        return float(out)
    return 0.0


_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")


def _iter_instructions(hlo_text: str):
    """Yield (computation_name, opcode, stripped_line) per instruction."""
    comp = "ENTRY"
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if line.endswith("{") and (") -> " in line
                                   or line.startswith("ENTRY")):
            name = line.split(" ", 1)[0].lstrip("%")
            comp = "ENTRY" if line.startswith("ENTRY") else name
            continue
        if line == "}" or "=" not in line:
            continue
        m_op = _OP_RE.search(line)
        if m_op:
            yield comp, m_op.group(1), line


_NAME_RE = re.compile(r"%[\w.\-]+")


def attribute_hlo(hlo_text: str) -> dict:
    """Split one HLO module's bytes/flops by protection|model|other.

    Returns ``{"protection": {...}, "model": {...}, "other": {...},
    "total": {...}, "by_file": {file: {...}}}`` where each leaf is
    ``{"bytes": float, "flops": float, "ops": int}``.

    Attribution cascades through three sources, strongest first:

    1. the instruction's own ``metadata={... source_file= ...}``;
    2. the flop-weighted majority source of a fused computation's body
       (for fusion call lines and metadata-less clones inside bodies);
    3. dataflow inheritance — XLA passes (e.g. the expansion of
       u8<->u32 bitcast-converts into whole shift/mask fusions) drop
       metadata entirely, so unresolved instructions inherit from
       their operands, then from their consumers, over a few sweeps.

    What still remains is ``other`` (XLA-inserted loop-carried copies
    with no attributable neighborhood) — the coverage criterion in
    ``tests`` keeps it under 5% of total bytes and flops.
    """
    # -- collect one record per instruction ---------------------------------
    records = []
    for comp, opcode, line in _iter_instructions(hlo_text):
        inner = bool(_INNER_COMP.match(comp))
        # Strip metadata / calls= before shape parsing: op_name strings
        # may embed shape-like text, and calls= carries no traffic.
        body = line.split(", metadata={")[0].split(", calls=")[0]
        lhs, _, rhs = body.partition("=")
        m_name = _NAME_RE.search(lhs)
        name = m_name.group(0) if m_name else None
        operands = _NAME_RE.findall(rhs)
        nbytes = 0.0
        if not inner and opcode not in _FREE_OPS:
            nbytes = float(parse_shape_bytes(body))
        flops = _line_flops(body, opcode)
        meta = _META_RE.search(line)
        src = (meta.group(1), int(meta.group(2))) if meta else None
        callees = _CALLS_RE.findall(line)
        records.append({"comp": comp, "opcode": opcode, "name": name,
                        "operands": operands, "bytes": nbytes,
                        "flops": flops, "src": src, "callees": callees})

    # -- fused-body majority vote (flop-weighted, +1 floor) -----------------
    votes: dict = {}
    for r in records:
        if r["src"] and _INNER_COMP.match(r["comp"]):
            tally = votes.setdefault(r["comp"], {})
            tally[r["src"]] = tally.get(r["src"], 0.0) + r["flops"] + 1.0
    body_src = {comp: max(tally, key=tally.get)
                for comp, tally in votes.items()}
    for r in records:
        if r["src"] is None and _INNER_COMP.match(r["comp"]):
            r["src"] = body_src.get(r["comp"])
        if r["src"] is None and r["callees"]:
            for callee in r["callees"]:
                if callee in body_src:
                    r["src"] = body_src[callee]
                    break

    # -- dataflow inheritance ------------------------------------------------
    # Free ops (GTE/copy/tuple) participate as conduits so chains like
    # attributed-op -> GTE -> orphan fusion resolve.  Names are unique
    # module-wide in printed HLO, so one flat map suffices.  A fused
    # computation's parameters alias the call site's operands, linking
    # body interiors to the data they actually process.
    comp_params: dict = {}
    for r in records:
        if r["opcode"] == "parameter" and r["name"]:
            comp_params.setdefault(r["comp"], []).append(r["name"])
    aliases = []
    for r in records:
        for callee in r["callees"]:
            if callee in comp_params:
                aliases += list(zip(comp_params[callee], r["operands"]))

    attr = {r["name"]: r["src"] for r in records
            if r["name"] and r["src"]}
    for _ in range(6):
        changed = False
        for r in records:                       # forward: from operands
            if r["src"] is None:
                for op in r["operands"]:
                    if op in attr:
                        r["src"] = attr[op]
                        if r["name"]:
                            attr[r["name"]] = r["src"]
                        changed = True
                        break
        for r in reversed(records):             # backward: from consumers
            if r["src"] is not None:
                for op in r["operands"]:
                    if op not in attr:
                        attr[op] = r["src"]
                        changed = True
        for a, b in aliases:                    # param <-> call operand
            if a in attr and b not in attr:
                attr[b] = attr[a]
                changed = True
            elif b in attr and a not in attr:
                attr[a] = attr[b]
                changed = True
        for r in records:
            if r["src"] is None and r["name"] in attr:
                r["src"] = attr[r["name"]]
                changed = True
        if not changed:
            break

    # A resolved caller covers its callee computation's metadata-less
    # interior: XLA's u8<->u32 bitcast-convert expansion emits whole
    # `xla.bitcast_convert_*` computations (and the fusions inside
    # them) without metadata, while the `call(..., to_apply=...)` site
    # keeps it.  Iterate so chains resolve: call -> called computation
    # -> fusion inside it -> fused body.
    comp_src = dict(body_src)
    for _ in range(4):
        changed = False
        for r in records:
            if r["src"] is not None:
                for callee in r["callees"]:
                    if callee not in comp_src:
                        comp_src[callee] = r["src"]
                        changed = True
            elif r["comp"] in comp_src:
                r["src"] = comp_src[r["comp"]]
                changed = True
        if not changed:
            break

    # Last resort for non-movement stragglers (bounds checks and
    # select/compare glue in while bodies whose operands are all loop
    # state): inherit the cost-weighted majority source of the
    # surrounding computation.
    comp_vote: dict = {}
    for r in records:
        if r["src"]:
            tally = comp_vote.setdefault(r["comp"], {})
            w = r["bytes"] + r["flops"] + 1.0
            tally[r["src"]] = tally.get(r["src"], 0.0) + w
    for r in records:
        if (r["src"] is None and r["opcode"] not in _MOVEMENT_OPS
                and r["comp"] in comp_vote):
            tally = comp_vote[r["comp"]]
            r["src"] = max(tally, key=tally.get)

    # -- fold into the three cost buckets -----------------------------------
    buckets = {k: {"bytes": 0.0, "flops": 0.0, "ops": 0}
               for k in ("protection", "model", "other")}
    by_file: dict = {}
    for r in records:
        nbytes, flops = r["bytes"], r["flops"]
        if nbytes == 0.0 and flops == 0.0:
            continue
        if r["src"] is None and r["opcode"] in _MOVEMENT_OPS:
            # Unattributable pure data movement (XLA-inserted loop
            # -carried copies, layout shuffles of model tensors) is
            # model-side glue: counting it as model is conservative —
            # it can only *under*state the protection-overhead ratio.
            buckets["model"]["bytes"] += nbytes
            buckets["model"]["flops"] += flops
            buckets["model"]["ops"] += 1
            continue
        if r["src"] is not None:
            src, lineno = r["src"]
            kind = classify_source(src, lineno)
            key = src.replace("\\", "/")
            if "/repro/" in key:
                key = key.split("/repro/", 1)[1]
            f = by_file.setdefault(key, {"bytes": 0.0, "flops": 0.0,
                                         "ops": 0})
            f["bytes"] += nbytes
            f["flops"] += flops
            f["ops"] += 1
        else:
            kind = "other"
        b = buckets[kind]
        b["bytes"] += nbytes
        b["flops"] += flops
        b["ops"] += 1
    total = {k: sum(buckets[c][k] for c in buckets)
             for k in ("bytes", "flops")}
    total["ops"] = sum(buckets[c]["ops"] for c in buckets)
    return {**buckets, "total": total, "by_file": by_file}


# -- the profile object ------------------------------------------------------

def _ratio(num: float, den: float) -> float:
    return num / den if den else 0.0


@dataclass
class CostProfile:
    """Attributed device cost of one compiled decode variant."""

    scheme: str
    bucket: int
    uniform: bool
    protection: dict
    model: dict
    other: dict
    total: dict
    by_file: dict = field(default_factory=dict)
    xla_cost: dict = field(default_factory=dict)
    tick_seconds_p50: Optional[float] = None

    @property
    def overhead_bytes_ratio(self) -> float:
        """Protection bytes per model byte (the SeDA overhead claim)."""
        return _ratio(self.protection["bytes"], self.model["bytes"])

    @property
    def overhead_flops_ratio(self) -> float:
        return _ratio(self.protection["flops"], self.model["flops"])

    @property
    def coverage(self) -> dict:
        """Fraction of total bytes/flops the protection+model split
        accounts for (the rest carried no source attribution)."""
        acc_b = self.protection["bytes"] + self.model["bytes"]
        acc_f = self.protection["flops"] + self.model["flops"]
        return {"bytes": _ratio(acc_b, self.total["bytes"]),
                "flops": _ratio(acc_f, self.total["flops"])}

    def roofline(self) -> dict:
        """Roofline time of the attributed cost, and — when a measured
        median tick is available — the achieved fraction of it."""
        t_compute = self.total["flops"] / PEAK_FLOPS
        t_memory = self.total["bytes"] / HBM_BW
        t_roof = max(t_compute, t_memory)
        out = {"compute_s": t_compute, "memory_s": t_memory,
               "roofline_s": t_roof,
               "bound": "compute" if t_compute >= t_memory else "memory"}
        if self.tick_seconds_p50 and self.tick_seconds_p50 > 0:
            out["measured_tick_s"] = self.tick_seconds_p50
            out["utilization"] = t_roof / self.tick_seconds_p50
        return out

    def to_dict(self) -> dict:
        return {
            "scheme": self.scheme, "bucket": self.bucket,
            "uniform": self.uniform,
            "protection": dict(self.protection), "model": dict(self.model),
            "other": dict(self.other), "total": dict(self.total),
            "overhead_bytes_ratio": self.overhead_bytes_ratio,
            "overhead_flops_ratio": self.overhead_flops_ratio,
            "coverage": self.coverage,
            "roofline": self.roofline(),
            "xla_cost": dict(self.xla_cost),
            "by_file": {k: dict(v) for k, v in sorted(self.by_file.items())},
        }


def profile_decode(engine, bucket: Optional[int] = None,
                   uniform: bool = False) -> CostProfile:
    """Lower + compile one decode variant and attribute its HLO cost.

    This is the expensive explicit path (one XLA compile per new
    (bucket, uniform) pair — cached by the engine's jit cache); the
    lazy gauges only ever read profiles already computed this way.
    """
    if bucket is None:
        bucket = engine.pages_per_slot
    args = engine._decode_analysis_args(bucket)
    compiled = engine._decode_fn_for(bucket, uniform).lower(*args).compile()
    attr = attribute_hlo(compiled.as_text())
    try:
        xla = compiled.cost_analysis()
        if isinstance(xla, (list, tuple)):
            xla = xla[0] if xla else {}
        xla = {k: v for k, v in dict(xla or {}).items()
               if k in ("flops", "bytes accessed")}
    except Exception:  # noqa: BLE001 - backend-dependent availability
        xla = {}
    tick_hist = engine.metrics.histograms.get("tick_seconds")
    p50 = None
    if tick_hist is not None and tick_hist.count:
        p50 = tick_hist.percentile(50)
        if math.isnan(p50):
            p50 = None
    return CostProfile(
        scheme=engine.scheme, bucket=bucket, uniform=uniform,
        protection=attr["protection"], model=attr["model"],
        other=attr["other"], total=attr["total"], by_file=attr["by_file"],
        xla_cost=xla, tick_seconds_p50=p50)
