"""Sharded secure serving: cluster throughput + shard balance.

Sweeps the cluster engine's shard axis {1, 2, 4} across protection
schemes, reporting

* steady-state decode throughput (tokens/s, compile excluded) — every
  shard's jitted decode is dispatched before any is collected, so the
  per-tick device work overlaps;
* per-shard page occupancy (mean + peak over ticks) — how well
  least-loaded routing with tenant affinity balances the pools;
* scheduler counters (migrations, preemptions) and p50/p95/p99 latency
  percentiles.

Sharding on one host needs forced CPU devices; the module sets
``--xla_force_host_platform_device_count`` before jax initializes
(the CI perf-smoke job also exports it).  Standalone JSON mode::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python benchmarks/bench_sharded_serving.py \
        --shard-counts 1,2 --gen-len 6 --json results.json
"""

from __future__ import annotations

import argparse
import json
import os
import time

if __name__ == "__main__" and "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4"
                               ).strip()

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.core.secure_exec import SCHEMES  # noqa: E402
from repro.models import lm as lm_mod  # noqa: E402
from repro.models.layers import init_params  # noqa: E402
from repro.serve.cluster import ClusterEngine  # noqa: E402

try:                                    # package or script invocation
    from benchmarks._meta import stamp
except ImportError:
    from _meta import stamp  # noqa: E402

DEFAULT_SHARDS = (1, 2, 4)


def _measure(arch, cfg, params, scheme: str, shards: int, *, batch: int,
             page_tokens: int, pages_per_slot: int, gen_len: int,
             prompt_len: int, seed: int = 0, tenants: int = 0,
             use_kernel: bool = False, label: str = None) -> dict:
    """One cluster measurement; ``tenants > 0`` serves the batch
    round-robin over that many tenant sessions (per-tenant key domains),
    ``use_kernel`` turns the Pallas kernels on."""
    from repro.tenancy.keys import KeyHierarchy
    from repro.tenancy.registry import TenantRegistry

    rng = np.random.default_rng(seed)
    registry, sessions = None, [None]
    if tenants:
        registry = TenantRegistry(KeyHierarchy(7), max_tenants=max(tenants,
                                                                   2))
        for i in range(tenants):
            registry.register(f"t{i}")
        sessions = [registry.open_session(f"t{i}") for i in range(tenants)]
    per_shard = -(-batch // shards)
    cluster = ClusterEngine(
        arch, cfg, params, shards=shards, scheme=scheme,
        max_slots=per_shard, page_tokens=page_tokens,
        pages_per_slot=pages_per_slot, registry=registry,
        use_kernel=use_kernel)
    for i in range(batch):
        prompt = list(map(int, rng.integers(1, cfg.vocab, prompt_len)))
        cluster.submit(prompt=prompt, max_new_tokens=gen_len,
                       session=sessions[i % len(sessions)])
    cluster.step()                  # admission + first decode (compiles)
    occ = [cluster.sharded.occupancy()]
    t0 = time.perf_counter()
    steps = 0
    while cluster._busy():
        cluster.step()
        occ.append(cluster.sharded.occupancy())
        steps += 1
    dt = time.perf_counter() - t0
    occ_arr = np.asarray(occ, np.float64)
    stats = cluster.engine_stats
    row = {
        "scheme": label or scheme,
        "shards": shards,
        "decode_steps_timed": steps,
        "tok_per_s": batch * steps / max(dt, 1e-9),
        "us_per_step": dt / max(steps, 1) * 1e6,
        "occupancy_mean": occ_arr.mean(axis=0).tolist(),
        "occupancy_peak": occ_arr.max(axis=0).tolist(),
        "migrations": cluster.stats["migrations"],
        "root_mac_ok": cluster.deferred_check(),
        "latency": cluster.run().latency,
    }
    # EVERY aggregated engine counter rides along — enumerating known
    # keys here is how the uniform/fused counters once went missing
    # from cluster rows, and how new ones (prefix cache) would again.
    for k, v in stats.items():
        row.setdefault(k, v)
    return row


def collect(schemes=tuple(SCHEMES), shard_counts=DEFAULT_SHARDS, *,
            arch_name: str = "minitron-4b", batch: int = 4,
            page_tokens: int = 8, pages_per_slot: int = 4,
            gen_len: int = 8, prompt_len: int = 9,
            fast_path_rows: bool = True) -> list:
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    n_dev = jax.local_device_count()
    results = []
    for shards in shard_counts:
        for scheme in schemes:
            r = _measure(arch, cfg, params, scheme, shards, batch=batch,
                         page_tokens=page_tokens,
                         pages_per_slot=pages_per_slot, gen_len=gen_len,
                         prompt_len=prompt_len)
            r["devices"] = min(shards, n_dev)
            results.append(r)
    if fast_path_rows:
        # Tenant-mode fast-path rows on one shard with the kernels on,
        # for the CI gate: one tenant -> every tick single-row
        # (uniform_fast_ticks); two tenants -> every tick mixed-row
        # (fused_mixed_ticks).  Both rows also reseal every dirty page
        # through the one-pass fused write (fused_write_ticks).  A
        # regression dropping any route zeroes its row's counter.
        for tenants, label in ((1, "seda(uniform-tenant,fused)"),
                               (2, "seda(mixed-tenant,fused)")):
            r = _measure(arch, cfg, params, "seda", 1, batch=batch,
                         page_tokens=page_tokens,
                         pages_per_slot=pages_per_slot, gen_len=gen_len,
                         prompt_len=prompt_len, tenants=tenants,
                         use_kernel=True, label=label)
            r["devices"] = 1
            results.append(r)
    return results


def run() -> list:
    """benchmarks.run suite hook: CSV rows for a reduced sweep."""
    rows = []
    for r in collect(schemes=("off", "seda", "mgx64"), shard_counts=(1, 2),
                     gen_len=6):
        occ = ";".join(f"{o:.1f}" for o in r["occupancy_peak"])
        rows.append({
            "name": f"sharded_{r['scheme']}_s{r['shards']}",
            "us_per_call": r["us_per_step"],
            "derived": (f"tok/s={r['tok_per_s']:.1f} peak_occ={occ} "
                        f"migrations={r['migrations']} "
                        f"uniform={r['uniform_fast_ticks']} "
                        f"fused_mixed={r['fused_mixed_ticks']} "
                        f"fused_write={r['fused_write_ticks']}"),
        })
    return rows


def main(argv=None) -> list:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--schemes", default=",".join(SCHEMES))
    ap.add_argument("--shard-counts",
                    default=",".join(map(str, DEFAULT_SHARDS)))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--page-tokens", type=int, default=8)
    ap.add_argument("--pages-per-slot", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=9)
    ap.add_argument("--json", default=None, help="write results to this file")
    args = ap.parse_args(argv)

    results = collect(
        schemes=tuple(args.schemes.split(",")),
        shard_counts=tuple(int(s) for s in args.shard_counts.split(",")),
        arch_name=args.arch, batch=args.batch, page_tokens=args.page_tokens,
        pages_per_slot=args.pages_per_slot, gen_len=args.gen_len,
        prompt_len=args.prompt_len)
    for r in results:
        occ = "/".join(f"{o:.1f}" for o in r["occupancy_mean"])
        print(f"[sharded-bench] scheme={r['scheme']:<8} "
              f"shards={r['shards']:<2} devices={r['devices']} "
              f"tok/s={r['tok_per_s']:9.1f} occ={occ} "
              f"migrations={r['migrations']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(stamp({"benchmark": "sharded_serving",
                             "device_count": jax.local_device_count(),
                             "results": results}), f, indent=2)
        print(f"[sharded-bench] wrote {args.json}")
    return results


if __name__ == "__main__":
    main()
