"""Sharded secure serving: shard-bound integrity + cluster scheduling.

Covers the distributed subsystem's guarantees:
  * shard binding — a byte-identical page (ciphertext + MAC + VN)
    replayed between shards fails verification, at the pool level and
    through a running cluster; ``shard=0, n_shards=1`` stays
    bit-identical to the unsharded layout;
  * parity — a ``shards=1`` cluster is token-identical to the plain
    engine for every scheme; ``shards in {2, 4}`` decode
    token-identically to ``shards=1`` (placement never changes
    tokens);
  * secure migration — under shard imbalance a running slot's pages
    move (decrypt under source binding, reseal under destination)
    with zero preemptions and zero recomputed prefills, for every
    scheme;
  * eager reseal — key rotation reseals pages leaving the retained
    window instead of preempting their slots (ROADMAP item);
  * uniform fast path — single-bank-row ticks dispatch the flat
    single-key route, token- and bit-identical to the vmapped one;
  * root MAC — per-shard deferred pool MACs roll into a cluster root;
    pool-state swaps that bypass the trusted increment fail the check.

The in-process tests run the shards logically on the 1-device CPU
(conftest forces no XLA flags by design); a subprocess test covers
real forced multi-device placement.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.secure_exec import SCHEMES
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.serve import kv_pages as kvp
from repro.serve.cluster import ClusterEngine
from repro.serve.engine import IntegrityError, SecureServingEngine
from repro.tenancy import KeyHierarchy, TenantRegistry

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def smoke():
    arch = get_arch("minitron-4b")
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    return arch, cfg, params


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(0)
    return [list(map(int, rng.integers(1, 256, n))) for n in (5, 7, 9)]


def _cluster(smoke, **kw):
    arch, cfg, params = smoke
    kw.setdefault("shards", 2)
    kw.setdefault("max_slots", 2)
    kw.setdefault("page_tokens", 4)
    kw.setdefault("pages_per_slot", 4)
    kw.setdefault("scheme", "seda")
    return ClusterEngine(arch, cfg, params, **kw)


def _engine(smoke, **kw):
    arch, cfg, params = smoke
    kw.setdefault("max_slots", 3)
    kw.setdefault("page_tokens", 4)
    kw.setdefault("pages_per_slot", 4)
    kw.setdefault("scheme", "seda")
    return SecureServingEngine(arch, cfg, params, **kw)


class TestShardedPoolUnit:
    """kv_pages-level shard binding, no model in the loop."""

    def _spec(self, scheme, shard, n_shards=2):
        from repro.models.attention import KVCache
        tree = [[KVCache(
            k=jax.ShapeDtypeStruct((2, 2, 16, 2, 8), jnp.float32),
            v=jax.ShapeDtypeStruct((2, 2, 16, 2, 8), jnp.float32),
            length=jax.ShapeDtypeStruct((2,), jnp.int32))]]
        return tree, kvp.build_page_spec(
            tree, scheme=scheme, page_tokens=4, n_pages=6, max_slots=2,
            max_len=16, shard=shard, n_shards=n_shards)

    def _filled(self, spec, keys, rng):
        pool = kvp.init_pool(spec)
        data = [jnp.asarray(rng.standard_normal((2, 1, 16, 2, 8)),
                            jnp.float32) for _ in spec.leaves]
        ids = jnp.asarray([0, 1, 2, 3], jnp.int32)
        return kvp.write_prefill(pool, spec, keys, ids, data, 4,
                                 jnp.uint32(1)), data, ids

    @pytest.mark.parametrize("scheme", ["seda", "sgx64", "mgx512"])
    def test_byte_identical_replay_across_shards_fails(self, rng, keys,
                                                       scheme):
        _, spec0 = self._spec(scheme, 0)
        _, spec1 = self._spec(scheme, 1)
        pool0, _, ids = self._filled(spec0, keys, rng)
        pool1 = kvp.init_pool(spec1)
        # Everything the untrusted side could capture moves verbatim:
        # ciphertext, per-page/per-block MACs, VNs.
        pool1 = kvp.PagedKVPool(
            cts=tuple(c1.at[ids].set(c0[ids])
                      for c0, c1 in zip(pool0.cts, pool1.cts)),
            page_macs=pool1.page_macs.at[ids].set(pool0.page_macs[ids]),
            block_macs=tuple(b1.at[ids].set(b0[ids]) for b0, b1 in
                             zip(pool0.block_macs, pool1.block_macs)),
            page_vns=pool1.page_vns.at[ids].set(pool0.page_vns[ids]),
            pool_mac=pool1.pool_mac)
        # On its own shard the data verifies; replayed on shard 1 the
        # binding (fmap bits 28-31) no longer matches.
        _, ok_own = kvp.read_pages_raw(pool0, spec0, keys, ids)
        _, ok_replay = kvp.read_pages_raw(pool1, spec1, keys, ids)
        assert bool(ok_own)
        assert not bool(ok_replay)

    def test_shard0_bit_identical_to_unsharded(self, rng, keys):
        from repro.models.attention import KVCache
        tree = [[KVCache(
            k=jax.ShapeDtypeStruct((2, 2, 16, 2, 8), jnp.float32),
            v=jax.ShapeDtypeStruct((2, 2, 16, 2, 8), jnp.float32),
            length=jax.ShapeDtypeStruct((2,), jnp.int32))]]
        plain = kvp.build_page_spec(tree, scheme="seda", page_tokens=4,
                                    n_pages=6, max_slots=2, max_len=16)
        sharded = plain._replace(n_shards=4)      # shard 0 of 4
        p_plain, data, ids = self._filled(plain, keys, rng)
        p_shard, _, _ = self._filled(sharded, keys,
                                     np.random.default_rng(0))
        for a, b in zip(p_plain.cts, p_shard.cts):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(p_plain.page_macs),
                                      np.asarray(p_shard.page_macs))

    def test_spec_rejects_out_of_budget_shards(self):
        from repro.models.attention import KVCache
        tree = [[KVCache(
            k=jax.ShapeDtypeStruct((2, 2, 16, 2, 8), jnp.float32),
            v=jax.ShapeDtypeStruct((2, 2, 16, 2, 8), jnp.float32),
            length=jax.ShapeDtypeStruct((2,), jnp.int32))]]
        with pytest.raises(ValueError):
            kvp.build_page_spec(tree, scheme="seda", page_tokens=4,
                                n_pages=6, max_slots=2, max_len=16,
                                shard=0, n_shards=kvp.MAX_SHARDS + 1)
        with pytest.raises(ValueError):
            kvp.build_page_spec(tree, scheme="seda", page_tokens=4,
                                n_pages=6, max_slots=2, max_len=16,
                                shard=2, n_shards=2)

    @pytest.mark.parametrize("scheme", ["seda", "sgx64", "mgx512"])
    def test_migrate_pages_roundtrips_and_rebinds(self, rng, keys, scheme):
        _, spec0 = self._spec(scheme, 0)
        _, spec1 = self._spec(scheme, 1)
        pool0, data, ids = self._filled(spec0, keys, rng)
        want, ok = kvp.read_pages_raw(pool0, spec0, keys, ids)
        assert bool(ok)
        dst = jnp.asarray([2, 3, 4, 5], jnp.int32)
        pool1, ok_mig = kvp.migrate_pages(pool0, spec0, kvp.init_pool(spec1),
                                          spec1, keys, ids, dst,
                                          jnp.uint32(9))
        assert bool(ok_mig)
        got, ok_dst = kvp.read_pages_raw(pool1, spec1, keys, dst)
        assert bool(ok_dst)
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(want[0]))

    def test_reseal_preserves_plaintext_and_reverifies(self, rng, keys):
        _, spec = self._spec("seda", 0)
        pool, data, ids = self._filled(spec, keys, rng)
        want, _ = kvp.read_pages_raw(pool, spec, keys, ids)
        resealed, ok = kvp.reseal_pages(pool, spec, keys, ids,
                                        jnp.uint32(7))
        assert bool(ok)
        assert not np.array_equal(np.asarray(pool.cts[0][0]),
                                  np.asarray(resealed.cts[0][0]))
        got, ok2 = kvp.read_pages_raw(resealed, spec, keys, ids)
        assert bool(ok2)
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(want[0]))


class TestClusterParity:
    def _baseline(self, smoke, prompts, scheme, gen=4):
        eng = _engine(smoke, scheme=scheme)
        rids = [eng.submit(p, max_new_tokens=gen) for p in prompts]
        return [eng.run()[r].generated for r in rids]

    @pytest.mark.parametrize("scheme", sorted(SCHEMES))
    def test_one_shard_token_identical_to_engine(self, smoke, prompts,
                                                 scheme):
        want = self._baseline(smoke, prompts, scheme)
        cluster = _cluster(smoke, shards=1, max_slots=3, scheme=scheme)
        rids = [cluster.submit(p, max_new_tokens=4) for p in prompts]
        done = cluster.run()
        assert [done[r].generated for r in rids] == want
        assert cluster.deferred_check()

    @pytest.mark.parametrize("shards", [2, 4])
    def test_multi_shard_token_identical(self, smoke, prompts, shards):
        want = self._baseline(smoke, prompts, "seda")
        cluster = _cluster(smoke, shards=shards)
        rids = [cluster.submit(p, max_new_tokens=4) for p in prompts]
        done = cluster.run()
        assert [done[r].generated for r in rids] == want
        assert cluster.deferred_check()

    def test_multi_tenant_cluster_token_identical(self, smoke, prompts):
        want = self._baseline(smoke, prompts, "seda")
        reg = TenantRegistry(KeyHierarchy(3), max_tenants=3)
        sess = []
        for i in range(3):
            reg.register(f"t{i}")
            sess.append(reg.open_session(f"t{i}"))
        cluster = _cluster(smoke, shards=2, registry=reg, rotate_every=2)
        rids = [cluster.submit(p, max_new_tokens=4, session=s)
                for p, s in zip(prompts, sess)]
        done = cluster.run()
        assert [done[r].generated for r in rids] == want
        assert cluster.engine_stats["rotations"] > 0
        assert cluster.deferred_check()

    def test_tenant_affinity_routing(self, smoke, prompts):
        reg = TenantRegistry(KeyHierarchy(4), max_tenants=2)
        reg.register("a")
        reg.register("b")
        sa, sb = reg.open_session("a"), reg.open_session("b")
        cluster = _cluster(smoke, shards=2, registry=reg)
        cluster.submit(prompts[0], max_new_tokens=8, session=sa)
        cluster.submit(prompts[1], max_new_tokens=8, session=sb)
        cluster.step()
        # Distinct tenants spread over distinct shards; a follow-up
        # request of tenant a joins a's shard despite the load tie.
        a_shard = next(s for s, e in enumerate(cluster.engines)
                       if any(sl is not None and sl.tenant is not None
                              and sl.tenant.tenant_id == "a"
                              for sl in e.slots))
        assert cluster._route(sa.index) == a_shard


class TestSecureMigration:
    @pytest.mark.parametrize("scheme", sorted(SCHEMES))
    def test_migration_under_load_zero_recompute(self, smoke, prompts,
                                                 scheme):
        # Two long decodes route to shard 0, a short one to shard 1;
        # when the short one drains, shard 0's page pressure migrates
        # its youngest slot — nothing is preempted or recomputed.
        cluster = _cluster(smoke, scheme=scheme, shards=2, max_slots=2,
                           pages_per_slot=8, n_pages=8)
        r0 = cluster.submit(prompts[0], max_new_tokens=20)
        r1 = cluster.submit(prompts[1], max_new_tokens=2)
        r2 = cluster.submit(prompts[2], max_new_tokens=20)
        done = cluster.run()
        stats = cluster.engine_stats
        assert cluster.stats["migrations"] > 0
        assert stats["preemptions"] == 0
        assert stats["admitted"] == 3          # zero recomputed prefills
        assert cluster.deferred_check()
        eng = _engine(smoke, scheme=scheme, max_slots=3, pages_per_slot=8,
                      n_pages=24)
        b0 = eng.submit(prompts[0], max_new_tokens=20)
        b1 = eng.submit(prompts[1], max_new_tokens=2)
        b2 = eng.submit(prompts[2], max_new_tokens=20)
        base = eng.run()
        assert [done[r].generated for r in (r0, r1, r2)] == \
               [base[b].generated for b in (b0, b1, b2)]

    def test_migrated_tenant_pages_reseal_to_destination(self, smoke,
                                                         prompts):
        reg = TenantRegistry(KeyHierarchy(5), max_tenants=2)
        reg.register("a")
        reg.register("b")
        sa, sb = reg.open_session("a"), reg.open_session("b")
        cluster = _cluster(smoke, shards=2, max_slots=2, pages_per_slot=8,
                           n_pages=8, registry=reg)
        r0 = cluster.submit(prompts[0], max_new_tokens=20, session=sa)
        r1 = cluster.submit(prompts[1], max_new_tokens=2, session=sb)
        r2 = cluster.submit(prompts[2], max_new_tokens=20, session=sa)
        done = cluster.run()
        assert cluster.stats["migrations"] > 0
        assert cluster.engine_stats["preemptions"] == 0
        assert all(len(done[r].generated) == n
                   for r, n in ((r0, 20), (r1, 2), (r2, 20)))
        assert cluster.deferred_check()


class TestResealRotation:
    def test_rotation_reseals_instead_of_preempting(self, smoke, prompts):
        reg = TenantRegistry(KeyHierarchy(3), max_tenants=2)
        reg.register("t0")
        s0 = reg.open_session("t0")
        eng = _engine(smoke, max_slots=1, pages_per_slot=6, registry=reg)
        rid = eng.submit(prompts[0], max_new_tokens=10, session=s0)
        eng.step()
        eng.step()
        # Three rotations: epoch-0 (and then epoch-1) pages would fall
        # out of the retained window — previously each exit preempted
        # the slot and recomputed its KV.
        for _ in range(3):
            eng.rotate("t0")
        done = eng.run()
        assert eng.stats["preemptions"] == 0
        assert eng.stats["reseals"] > 0
        assert eng.stats["admitted"] == 1
        reg2 = TenantRegistry(KeyHierarchy(3), max_tenants=2)
        reg2.register("t0")
        sx = reg2.open_session("t0")
        eng2 = _engine(smoke, max_slots=1, pages_per_slot=6, registry=reg2)
        r2 = eng2.submit(prompts[0], max_new_tokens=10, session=sx)
        assert eng2.run()[r2].generated == done[rid].generated

    def test_reseal_fans_out_to_every_engine(self, smoke, prompts):
        # Rotation triggered through ONE engine reseals resident pages
        # on EVERY engine sharing the registry.
        reg = TenantRegistry(KeyHierarchy(8), max_tenants=2)
        reg.register("t0")
        s0 = reg.open_session("t0")
        ea = _engine(smoke, max_slots=1, registry=reg)
        eb = _engine(smoke, max_slots=1, registry=reg)
        ra = ea.submit(prompts[0], max_new_tokens=8, session=s0)
        rb = eb.submit(prompts[0], max_new_tokens=8, session=s0)
        ea.step()
        eb.step()
        ea.rotate("t0")
        ea.rotate("t0")               # epoch-0 keys are dropped now
        assert eb.stats["reseals"] > 0
        assert eb.stats["preemptions"] == 0
        assert len(eb.run()[rb].generated) == 8
        assert len(ea.run()[ra].generated) == 8


class TestUniformFastPath:
    def test_single_row_ticks_use_fast_path(self, smoke, prompts):
        reg = TenantRegistry(KeyHierarchy(5), max_tenants=2)
        reg.register("solo")
        ss = reg.open_session("solo")
        eng = _engine(smoke, registry=reg)
        rids = [eng.submit(p, max_new_tokens=4, session=ss)
                for p in prompts]
        done = eng.run()
        assert eng.stats["uniform_fast_ticks"] > 0
        assert eng.stats["uniform_fast_ticks"] == eng.stats["decode_steps"]
        base = _engine(smoke)
        brids = [base.submit(p, max_new_tokens=4) for p in prompts]
        bdone = base.run()
        assert [done[r].generated for r in rids] == \
               [bdone[r].generated for r in brids]

    def test_mixed_tenants_fall_back_to_vmapped_path(self, smoke, prompts):
        reg = TenantRegistry(KeyHierarchy(5), max_tenants=2)
        reg.register("a")
        reg.register("b")
        sa, sb = reg.open_session("a"), reg.open_session("b")
        eng = _engine(smoke, max_slots=2, registry=reg)
        eng.submit(prompts[0], max_new_tokens=4, session=sa)
        eng.submit(prompts[1], max_new_tokens=4, session=sb)
        eng.run()
        assert eng.stats["uniform_fast_ticks"] == 0


class TestBucketedDecodeSharded:
    """Page-count-bucketed decode under the cluster's split-phase tick:
    per-shard buckets must not break parity or the dispatch overlap."""

    @pytest.mark.parametrize("scheme", ["off", "seda", "mgx512"])
    @pytest.mark.parametrize("shards", [1, 2])
    def test_long_context_parity_across_bucket_boundaries(self, smoke,
                                                          prompts, shards,
                                                          scheme):
        """Contexts straddling the 2-/4-/8-page buckets decode
        token-identically on shards {1, 2} and on the plain engine."""
        eng = _engine(smoke, scheme="off", max_slots=2, pages_per_slot=8)
        rids = [eng.submit(p, max_new_tokens=14) for p in prompts[:2]]
        done = eng.run()
        want = sorted(done[r].generated for r in rids)
        assert eng.stats["decode_bucket_compiles"] >= 3  # crossed buckets
        cl = _cluster(smoke, shards=shards, scheme=scheme, max_slots=2,
                      pages_per_slot=8)
        rids = [cl.submit(p, max_new_tokens=14) for p in prompts[:2]]
        done = cl.run()
        assert sorted(done[r].generated for r in rids) == want
        assert cl.deferred_check()

    def test_shards_pick_buckets_independently(self, smoke, prompts):
        """One shard serving a long context must not widen the other
        shard's decode window (buckets are per-shard)."""
        cl = _cluster(smoke, shards=2, max_slots=1, pages_per_slot=8)
        cl.submit(prompts[0], max_new_tokens=14)    # long decode
        cl.submit(prompts[1][:4], max_new_tokens=2)  # short decode
        done = cl.run()
        assert len(done) == 2
        reads = [e.stats["decode_page_reads"] for e in cl.engines]
        steps = [e.stats["decode_steps"] for e in cl.engines]
        per_step = [r / max(s, 1) for r, s in zip(reads, steps)]
        # The short-context shard stays on small buckets even while the
        # long one climbs to the 8-page window.
        assert min(per_step) < max(per_step)


class TestFusedWriteSharded:
    """The fused write path under shard bindings: shards {1, 2} stay
    token- and pool-bit-identical to the vmapped reference, and pages
    resealed by the fused write stay pinned to their shard."""

    @pytest.mark.parametrize("shards", [1, 2])
    def test_kernel_cluster_token_and_pool_identical(self, smoke, prompts,
                                                     shards):
        outs, pools, stats = [], [], []
        for use_kernel in (False, True):
            cl = _cluster(smoke, shards=shards, scheme="seda",
                          use_kernel=use_kernel)
            rids = [cl.submit(p, max_new_tokens=6) for p in prompts]
            done = cl.run()
            outs.append([done[r].generated for r in rids])
            pools.append([e.pool for e in cl.engines])
            stats.append(cl.engine_stats)
            assert cl.deferred_check()
        assert outs[0] == outs[1]
        for ref_pool, fused_pool in zip(*pools):
            for a, b in zip(ref_pool.cts, fused_pool.cts):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            np.testing.assert_array_equal(np.asarray(ref_pool.page_macs),
                                          np.asarray(fused_pool.page_macs))
            np.testing.assert_array_equal(np.asarray(ref_pool.pool_mac),
                                          np.asarray(fused_pool.pool_mac))
        assert stats[0]["fused_write_ticks"] == 0
        assert stats[1]["fused_write_ticks"] == stats[1]["decode_steps"] > 0

    def test_fused_written_page_replay_across_shards_fails(self, smoke,
                                                           prompts):
        """Cross-shard replay of a page the FUSED WRITE resealed: the
        destination shard's binding (fmap bits 28-31 + CTR word 0)
        still rejects the byte-identical capture."""
        cluster = _cluster(smoke, max_slots=1, use_kernel=True)
        cluster.submit(prompts[0], max_new_tokens=8)
        cluster.submit(prompts[1], max_new_tokens=6)
        cluster.step()
        cluster.step()                # dirty pages resealed (fused write)
        assert cluster.engine_stats["fused_write_ticks"] > 0
        e0, e1 = cluster.engines
        s0 = next(s for s in e0.slots if s is not None)
        s1 = next(s for s in e1.slots if s is not None)
        d0 = s0.pages[(s0.length - 1) // e0.page_tokens]
        d1 = s1.pages[(s1.length - 1) // e1.page_tokens]
        e1.pool = e1.pool._replace(
            cts=tuple(c1.at[d1].set(c0[d0])
                      for c0, c1 in zip(e0.pool.cts, e1.pool.cts)),
            page_macs=e1.pool.page_macs.at[d1].set(e0.pool.page_macs[d0]),
            page_vns=e1.pool.page_vns.at[d1].set(e0.pool.page_vns[d0]))
        with pytest.raises(IntegrityError):
            cluster.run()


class TestRootMacCompression:
    """The cluster root MAC is a keyed CBC compression over ordered
    (shard, pool MAC) pairs — it binds value, order AND shard count
    (the XOR fold it replaced saw none of the latter two)."""

    def test_swapping_two_shards_macs_changes_root(self, smoke, prompts):
        cl = _cluster(smoke)
        for p in prompts:
            cl.submit(p, max_new_tokens=4)
        cl.step()
        sh = cl.sharded
        macs = [e.pool.pool_mac for e in sh.engines]
        # Byte-identical MAC multiset, different order: an XOR fold is
        # blind to this; the CBC compression is not.
        assert not np.array_equal(sh._compress(macs),
                                  sh._compress(macs[::-1]))

    def test_shard_count_bound_into_root(self, smoke, prompts):
        cl = _cluster(smoke)
        cl.submit(prompts[0], max_new_tokens=4)
        cl.step()
        sh = cl.sharded
        macs = [e.pool.pool_mac for e in sh.engines]
        import jax.numpy as _jnp
        grown = macs + [_jnp.zeros_like(macs[0])]
        assert not np.array_equal(sh._compress(macs), sh._compress(grown))
        cl.run()
        assert cl.deferred_check()

    def test_listener_bypassing_swap_still_caught(self, smoke, prompts):
        """`deferred_root_check` semantics preserved: pool state swapped
        in WITHOUT the listener fails the root."""
        cl = _cluster(smoke)
        for p in prompts:
            cl.submit(p, max_new_tokens=4)
        cl.step()
        assert cl.deferred_check()
        e0 = cl.engines[0]
        tampered = np.asarray(e0.pool.pool_mac).copy()
        tampered[0] ^= 0xFF
        e0._pool = e0.pool._replace(pool_mac=jnp.asarray(tampered))
        assert not cl.deferred_check()


class TestClusterIntegrity:
    def test_cross_shard_replay_through_cluster_raises(self, smoke,
                                                       prompts):
        cluster = _cluster(smoke, max_slots=1)
        cluster.submit(prompts[0], max_new_tokens=8)
        cluster.submit(prompts[1], max_new_tokens=6)
        cluster.step()
        e0, e1 = cluster.engines
        s0 = next(s for s in e0.slots if s is not None)
        s1 = next(s for s in e1.slots if s is not None)
        pid0, pid1 = s0.pages[0], s1.pages[0]
        e1.pool = e1.pool._replace(
            cts=tuple(c1.at[pid1].set(c0[pid0])
                      for c0, c1 in zip(e0.pool.cts, e1.pool.cts)),
            page_macs=e1.pool.page_macs.at[pid1].set(
                e0.pool.page_macs[pid0]),
            page_vns=e1.pool.page_vns.at[pid1].set(
                e0.pool.page_vns[pid0]))
        with pytest.raises(IntegrityError):
            cluster.run()

    def test_root_mac_catches_untracked_pool_swap(self, smoke, prompts):
        cluster = _cluster(smoke)
        for p in prompts:
            cluster.submit(p, max_new_tokens=6)
        cluster.step()
        assert cluster.deferred_check()
        # A whole-pool-MAC substitution that bypasses the trusted
        # incremental maintenance (direct memory swap, not a pool
        # update the listener sees).
        e0 = cluster.engines[0]
        tampered = np.asarray(e0.pool.pool_mac).copy()
        tampered[0] ^= 0xFF
        e0._pool = e0.pool._replace(pool_mac=jnp.asarray(tampered))
        assert not cluster.deferred_check()


class TestMultiDeviceCluster:
    """Real multi-device placement needs forced host devices, which
    must exist before jax initializes — subprocess, like the dry-run
    infra tests."""

    def test_four_forced_devices_parity_and_root(self):
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
assert jax.local_device_count() == 4
import numpy as np
from repro.configs import get_arch
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.serve.cluster import ClusterEngine
from repro.serve.engine import SecureServingEngine

arch = get_arch("minitron-4b")
cfg = arch.make_smoke_config()
params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
prompts = [list(map(int, rng.integers(1, 256, n))) for n in (5, 7, 9)]
eng = SecureServingEngine(arch, cfg, params, scheme="seda", max_slots=3,
                          page_tokens=4, pages_per_slot=4)
want = None
rids = [eng.submit(p, max_new_tokens=4) for p in prompts]
done = eng.run()
want = [done[r].generated for r in rids]
cl = ClusterEngine(arch, cfg, params, shards=4, scheme="seda",
                   max_slots=2, page_tokens=4, pages_per_slot=4)
assert len({str(e._device) for e in cl.engines}) == 4
rids = [cl.submit(p, max_new_tokens=4) for p in prompts]
done = cl.run()
assert [done[r].generated for r in rids] == want
assert cl.deferred_check()
print("SHARDED4_OK")
"""
        env = dict(os.environ, PYTHONPATH=SRC)
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=500)
        assert "SHARDED4_OK" in out.stdout, out.stderr[-2000:]
