"""Paper Table III: qualitative scheme comparison, emitted as data."""

from __future__ import annotations

from repro.core.secure_exec import SCHEMES
from repro.sim.memprot import SCHEME_MODELS


def run() -> list:
    rows = []
    for name, m in SCHEME_MODELS.items():
        if name == "baseline":
            continue
        exec_cfg = SCHEMES.get(name if name != "seda" else "seda")
        enc_gran = ("bandwidth-aware" if name == "seda"
                    else "16B (T-AES)")
        integ = ("multi-level (optBlk/layer/model)" if name == "seda"
                 else f"{m.granularity}B MAC")
        offchip = []
        if m.mac_offchip:
            offchip.append("MAC")
        if m.vn_offchip:
            offchip.append("VN")
        if m.integrity_tree:
            offchip.append("IT")
        if m.layer_mac_offchip:
            offchip.append("layerMAC(8B)")
        rows.append({
            "name": f"table3_{name}",
            "us_per_call": 0.0,
            "derived": (f"enc_gran={enc_gran} integ={integ} "
                        f"offchip_meta={'+'.join(offchip) or 'none'} "
                        f"tiling_aware={name == 'seda'} "
                        f"enc_scalable={exec_cfg.baes if exec_cfg else False}"),
        })
    return rows
