"""Decoder-only language model: specs / forward / loss / prefill / decode.

Supports every assigned LM arch through the block layout:

  * homogeneous stacks (minitron, granite, smollm, olmoe, mamba2) scan
    one block body over stacked per-layer params;
  * periodic hybrids (jamba: attn every 8th mixer, MoE every 2nd ffn)
    scan a period of block bodies over stacked per-period params;
  * prefix-irregular stacks (deepseek-v3: 3 dense layers then 58 MoE)
    split into homogeneous segments, each scanned.

Params for a segment are stacked along a leading 'layers' axis, so the
HLO contains one body per distinct block kind — essential to keep
compile time sane for the 88-layer/61-layer dry-run cells.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import blocks as blk
from repro.models.layers import ParamSpec, rms_norm, spec
from repro.models.partitioning import constrain
from repro.models.mamba2 import Mamba2Config
from repro.models.mla import MLAConfig
from repro.models.moe import MoEConfig

__all__ = ["LMConfig", "layout", "segments", "lm_specs", "lm_forward",
           "lm_loss", "lm_prefill", "lm_decode", "cache_specs"]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    dtype: str = "bfloat16"
    # Block pattern:
    mixer: str = "attn"           # default mixer: attn | mla | mamba
    attn_every: int = 0           # jamba: one attn per this many layers
    attn_offset: int = 3
    ffn: str = "dense"            # dense | moe | none
    moe_every: int = 1            # moe on layers where idx % moe_every == moe_offset
    moe_offset: int = 0
    moe_start_layer: int = 0      # deepseek: dense layers before this index
    mamba: Optional[Mamba2Config] = None
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    # Embeddings / misc:
    gated_ffn: bool = True        # SwiGLU; False = plain GELU MLP (granite)
    tie_embeddings: bool = True
    q_block: int = 512
    kv_block: int = 1024
    ssd_chunk: int = 256
    remat: str = "full"           # full | none
    # Modality frontends (stubs; see vlm.py / configs):
    n_image_patches: int = 0
    d_vision: int = 0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def __post_init__(self):
        if not self.head_dim:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))


def layout(cfg: LMConfig) -> list:
    """Per-layer (mixer, ffn) kinds."""
    kinds = []
    for i in range(cfg.n_layers):
        if cfg.attn_every:
            mixer = "attn" if i % cfg.attn_every == cfg.attn_offset else cfg.mixer
        else:
            mixer = cfg.mixer
        ffn = cfg.ffn
        if cfg.ffn == "moe":
            is_moe = (i >= cfg.moe_start_layer
                      and i % cfg.moe_every == cfg.moe_offset)
            ffn = "moe" if is_moe else "dense"
        kinds.append(blk.LayerKind(mixer, ffn))
    return kinds


def segments(cfg: LMConfig) -> list:
    """[(period_kinds: tuple[LayerKind], steps: int), ...]."""
    kinds = layout(cfg)
    n = len(kinds)
    # Maximal uniform runs (homogeneous stacks, deepseek's dense prefix).
    segs = []
    i = 0
    while i < n:
        j = i
        while j < n and kinds[j] == kinds[i]:
            j += 1
        segs.append(((kinds[i],), j - i))
        i = j
    if len(segs) <= 4:
        return segs
    # Periodic hybrid (jamba): scan one period of block bodies.
    for p in range(2, min(16, n) + 1):
        if n % p == 0 and all(kinds[i] == kinds[i % p] for i in range(n)):
            return [(tuple(kinds[:p]), n // p)]
    return segs


def _stack_specs(specs: Any, steps: int) -> Any:
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((steps,) + s.shape, s.dtype, ("layers",) + s.axes,
                            s.init),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def lm_specs(cfg: LMConfig) -> dict:
    s: dict[str, Any] = {
        "embed": spec((cfg.vocab, cfg.d_model), ("vocab", "embed"), cfg.dtype,
                      init="embed"),
        "final_norm": spec((cfg.d_model,), ("embed",), "float32", init="ones"),
        "segments": [],
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = spec((cfg.d_model, cfg.vocab), ("embed", "vocab"),
                            cfg.dtype)
    if cfg.n_image_patches:
        s["img_proj"] = spec((cfg.d_vision, cfg.d_model), ("vision", "embed"),
                             cfg.dtype)
    for kinds, steps in segments(cfg):
        seg = [_stack_specs(blk.block_specs(cfg, kind), steps)
               for kind in kinds]
        s["segments"].append(seg)
    return s


def _maybe_remat(cfg: LMConfig, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


def _embed_tokens(cfg: LMConfig, params, batch) -> tuple:
    """Returns (x, positions).  Handles the VLM image-patch prefix."""
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.n_image_patches:
        img = batch["image_embeds"].astype(x.dtype)  # (B, P, d_vision)
        img = jnp.einsum("bpv,vd->bpd", img, params["img_proj"])
        x = jnp.concatenate([img, x], axis=1)
    x = constrain(x, "batch", "seq", "residual")
    b, l = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32)[None], (b, l))
    return x, positions


def lm_forward(cfg: LMConfig, params, batch) -> tuple:
    """Returns (logits, aux_loss)."""
    x, positions = _embed_tokens(cfg, params, batch)

    aux = jnp.zeros((), jnp.float32)
    for seg_params, (kinds, steps) in zip(params["segments"], segments(cfg)):
        def body(carry, layer_params):
            x, aux = carry
            for kind, p in zip(kinds, layer_params):
                x, aux = blk.block_forward(cfg, kind, p, x, positions, aux)
            return (x, aux), None

        body = _maybe_remat(cfg, body)
        (x, aux), _ = jax.lax.scan(body, (x, aux), seg_params)

    x = rms_norm(x, params["final_norm"])
    if cfg.tie_embeddings:
        logits = jnp.einsum("bld,vd->blv", x, params["embed"])
    else:
        logits = jnp.einsum("bld,dv->blv", x, params["lm_head"])
    return constrain(logits, "batch", "seq", "vocab"), aux


def lm_loss(cfg: LMConfig, params, batch) -> tuple:
    """Next-token cross entropy; returns (loss, metrics)."""
    logits, aux = lm_forward(cfg, params, batch)
    labels = batch["labels"]
    if cfg.n_image_patches:
        logits = logits[:, cfg.n_image_patches:]  # loss on text positions only
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    mask = (labels >= 0).astype(jnp.float32)  # -1 labels = padding
    labels_safe = jnp.maximum(labels, 0)
    gold = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    total = loss + 0.01 * aux
    return total, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Serving.
# ---------------------------------------------------------------------------


def cache_specs(cfg: LMConfig, batch: int, max_len: int) -> list:
    """Per-segment stacked cache ShapeDtypeStructs."""
    out = []
    for kinds, steps in segments(cfg):
        seg = []
        for kind in kinds:
            c = blk.block_cache_specs(cfg, kind, batch, max_len)
            seg.append(jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((steps,) + s.shape, s.dtype), c))
        out.append(seg)
    return out


def cache_axes(cfg: LMConfig) -> list:
    """Logical axes mirroring cache_specs (leading 'layers' stack dim)."""
    out = []
    for kinds, steps in segments(cfg):
        seg = []
        for kind in kinds:
            a = blk.block_cache_axes(cfg, kind)
            seg.append(jax.tree_util.tree_map(
                lambda ax: ("layers",) + ax, a,
                is_leaf=lambda x: isinstance(x, tuple) and all(
                    isinstance(e, str) for e in x)))
        out.append(seg)
    return out


def lm_prefill(cfg: LMConfig, params, batch, max_len: int,
               last_pos=None) -> tuple:
    """Full-sequence prefill: returns (last_logits, caches).

    ``last_pos`` (optional traced scalar) selects which position's
    logits to return instead of the literal last one — the serving
    engine's length-bucketed prefill right-pads prompts to a power-of
    -two length and needs the logits of the last *real* token (causal
    attention makes positions <= last_pos independent of the padding).
    """
    x, positions = _embed_tokens(cfg, params, batch)
    caches = []
    for seg_params, (kinds, steps) in zip(params["segments"], segments(cfg)):
        def body(carry, layer_params):
            x, aux = carry
            new_caches = []
            for kind, p in zip(kinds, layer_params):
                x, cache, aux = blk.block_prefill(cfg, kind, p, x, positions,
                                                  aux, max_len)
                new_caches.append(cache)
            return (x, aux), tuple(new_caches)

        (x, _), seg_cache = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), seg_params)
        caches.append(list(seg_cache))
    x = rms_norm(x, params["final_norm"])
    if last_pos is None:
        last = x[:, -1:]
    else:
        last = jax.lax.dynamic_slice_in_dim(x, last_pos, 1, axis=1)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bld,vd->blv", last, params["embed"])
    else:
        logits = jnp.einsum("bld,dv->blv", last, params["lm_head"])
    return constrain(logits, "batch", None, "vocab"), caches


def lm_decode(cfg: LMConfig, params, tokens, caches) -> tuple:
    """One decode step: tokens (B, 1) -> (logits (B,1,V), new caches)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "batch", None, "residual")
    new_caches = []
    for seg_params, seg_cache, (kinds, steps) in zip(
            params["segments"], caches, segments(cfg)):
        def body(x, inputs):
            layer_params, layer_caches = inputs
            aux = jnp.zeros((), jnp.float32)
            new_lc = []
            for kind, p, c in zip(kinds, layer_params, layer_caches):
                x, c, aux = blk.block_decode(cfg, kind, p, x, c, aux)
                new_lc.append(c)
            return x, tuple(new_lc)

        x, new_seg = jax.lax.scan(body, x, (seg_params, tuple(seg_cache)))
        new_caches.append(list(new_seg))
    x = rms_norm(x, params["final_norm"])
    if cfg.tie_embeddings:
        logits = jnp.einsum("bld,vd->blv", x, params["embed"])
    else:
        logits = jnp.einsum("bld,dv->blv", x, params["lm_head"])
    return constrain(logits, "batch", None, "vocab"), new_caches
