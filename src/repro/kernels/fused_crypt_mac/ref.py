"""Oracle for the fused decrypt+NH kernel: composition of the two refs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mac
from repro.kernels.otp_xor.ref import otp_xor_ref

__all__ = ["fused_crypt_mac_ref", "fused_crypt_mac_mixed_ref",
           "fused_crypt_mac_write_ref", "fused_crypt_mac_write_mixed_ref"]


def fused_crypt_mac_ref(ct_lanes: jax.Array, base_otp_lanes: jax.Array,
                        div_lanes: jax.Array, bind_words: jax.Array,
                        key_u32: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Decrypt wide blocks AND compute their NH hashes (over ciphertext).

    Args:
      ct_lanes: (N, S*4) u32 ciphertext lanes.
      base_otp_lanes: (N, 4) u32.
      div_lanes: (S, 4) u32.
      bind_words: (N, 8) u32 binding words appended to the NH payload.
      key_u32: (S*4 + 8,) u32 NH key.

    Returns (plaintext lanes (N, S*4), hashes (N, 2)).
    """
    pt = otp_xor_ref(ct_lanes, base_otp_lanes, div_lanes)
    payload = jnp.concatenate([ct_lanes, bind_words], axis=-1)
    hi, lo = mac.nh_hash(payload, key_u32)
    return pt, jnp.stack([hi, lo], axis=-1)


def fused_crypt_mac_mixed_ref(ct_lanes: jax.Array, base_otp_lanes: jax.Array,
                              div_lanes_per: jax.Array, bind_words: jax.Array,
                              key_per_u32: jax.Array
                              ) -> tuple[jax.Array, jax.Array]:
    """Mixed-key oracle: one single-key ref evaluation per block.

    ``div_lanes_per`` is (N, S, 4) and ``key_per_u32`` (N, S*4 + 8) —
    each block carries its own diversifiers and NH key (pages owned by
    different tenant-epoch bank rows).
    """
    def one(ct1, base1, div1, bind1, key1):
        pt, nh = fused_crypt_mac_ref(ct1[None], base1[None], div1,
                                     bind1[None], key1)
        return pt[0], nh[0]

    return jax.vmap(one)(ct_lanes, base_otp_lanes, div_lanes_per,
                         bind_words, key_per_u32)


def fused_crypt_mac_write_ref(pt_lanes: jax.Array, base_otp_lanes: jax.Array,
                              div_lanes: jax.Array, bind_words: jax.Array,
                              key_u32: jax.Array
                              ) -> tuple[jax.Array, jax.Array]:
    """Write-direction oracle: encrypt, then NH over the FRESH
    ciphertext (same shapes as :func:`fused_crypt_mac_ref`; the hash
    input moves to the pad-XOR output)."""
    ct = otp_xor_ref(pt_lanes, base_otp_lanes, div_lanes)
    payload = jnp.concatenate([ct, bind_words], axis=-1)
    hi, lo = mac.nh_hash(payload, key_u32)
    return ct, jnp.stack([hi, lo], axis=-1)


def fused_crypt_mac_write_mixed_ref(pt_lanes: jax.Array,
                                    base_otp_lanes: jax.Array,
                                    div_lanes_per: jax.Array,
                                    bind_words: jax.Array,
                                    key_per_u32: jax.Array
                                    ) -> tuple[jax.Array, jax.Array]:
    """Mixed-key write oracle: one single-key write ref per block."""
    def one(pt1, base1, div1, bind1, key1):
        ct, nh = fused_crypt_mac_write_ref(pt1[None], base1[None], div1,
                                           bind1[None], key1)
        return ct[0], nh[0]

    return jax.vmap(one)(pt_lanes, base_otp_lanes, div_lanes_per,
                         bind_words, key_per_u32)
