"""models/partitioning + launch/analysis unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.analysis import analyze_hlo
from repro.models.partitioning import activation_context, constrain


class TestConstrain:
    def test_identity_without_context(self):
        x = jnp.ones((4, 8))
        y = constrain(x, "batch", None)
        assert y is x  # no-op outside a partitioning context

    def test_applies_inside_context_single_device(self):
        from jax.sharding import Mesh
        dev = np.array(jax.devices()[:1]).reshape(1, 1)
        mesh = Mesh(dev, ("data", "model"))

        def f(x):
            with activation_context(mesh, {"batch": "data", "seq": None}):
                return constrain(x, "batch", "seq") * 2

        out = jax.jit(f)(jnp.ones((4, 8)))
        assert (np.asarray(out) == 2).all()

    def test_nondivisible_dim_falls_back(self):
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        # 5 % 16 != 0: the entry must resolve to None (replicated), so
        # with_sharding_constraint would get P(None). We can't run XLA
        # with a fake mesh; instead verify the resolution logic via the
        # planner's shared code path.
        from repro.launch.sharding import _resolve_axes
        axes = _resolve_axes((5, 32), ("batch", "seq"),
                             {"batch": "data", "seq": "model"}, FakeMesh())
        assert axes == [None, "model"]

    def test_axis_not_reused_across_dims(self):
        from repro.launch.sharding import _resolve_axes

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        axes = _resolve_axes((32, 32), ("a", "b"),
                             {"a": "model", "b": "model"}, FakeMesh())
        assert axes == ["model", None]


class TestAnalyzer:
    def test_nested_scan_multiplicity(self):
        """Flops inside scan-in-scan multiply by both trip counts."""
        def f(x):
            def outer(c, _):
                def inner(c2, _):
                    return c2 @ c2, None
                c, _ = jax.lax.scan(inner, c, None, length=3)
                return c, None
            out, _ = jax.lax.scan(outer, x, None, length=5)
            return out

        hlo = jax.jit(f).lower(
            jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile().as_text()
        stats = analyze_hlo(hlo)
        assert stats.dot_flops == pytest.approx(15 * 2 * 32 ** 3, rel=0.01)

    def test_collectives_empty_on_single_device(self):
        hlo = jax.jit(lambda x: x @ x).lower(
            jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile().as_text()
        stats = analyze_hlo(hlo)
        assert stats.collective_total == 0.0
        assert stats.dot_flops == pytest.approx(2 * 16 ** 3, rel=0.01)

    def test_mem_bytes_positive(self):
        hlo = jax.jit(lambda x: jnp.tanh(x @ x)).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile().as_text()
        stats = analyze_hlo(hlo)
        # at least operands+outputs of the dot: 3 x 16KB.
        assert stats.mem_bytes >= 3 * 64 * 64 * 4
