"""Chaos benchmark: recovery latency + blast radius under injected faults.

Replays a fixed, seeded fault schedule (``serve.faults.FaultPlan``)
against the fault-tolerant engine and measures what containment costs:

* **tamper rows** (one per verifying scheme) — a ciphertext bitflip is
  injected into slot 0 mid-run; the row records end-to-end throughput
  of the faulted run, the victim session's recovery latency in ticks
  (fault tick -> finished), quarantine/recovery counters, and two
  identity bits: ``unaffected_identical`` (every other session's tokens
  bit-match the fault-free run) and ``recovered_identical`` (the
  victim's recomputed tokens bit-match the fault-free run);
* **shard-kill rows** (``off`` and ``seda``) — one shard of a 2-shard
  cluster raises mid-run; the row records the failover counter and the
  same identity bits across the drained-and-recomputed sessions.

``check_chaos.py`` gates CI on these rows: every session recovered,
none lost, no token divergence.  Standalone JSON mode::

    PYTHONPATH=src python benchmarks/bench_chaos.py --seed 7 \\
        --json bench-chaos.json
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.secure_exec import SCHEMES
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.serve.cluster import ClusterEngine
from repro.serve.engine import SecureServingEngine
from repro.serve.faults import Fault, FaultPlan

try:                                    # package or script invocation
    from benchmarks._meta import stamp
except ImportError:
    from _meta import stamp  # noqa: E402

VERIFYING = tuple(s for s in SCHEMES if SCHEMES[s].verify != "none")
FAULT_TICK = 3


def _prompts(cfg, seed: int, batch: int, prompt_len: int) -> list:
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(1, cfg.vocab, prompt_len)))
            for _ in range(batch)]


def _run(eng, prompts, gen_len: int):
    """Serve the batch; returns (rids, tokens-per-rid, steady tok/s)."""
    rids = [eng.submit(prompt=p, max_new_tokens=gen_len) for p in prompts]
    eng.step()                      # admission + first decode (compiles)
    t0 = time.perf_counter()
    while eng._n_waiting() or any(s is not None for s in eng.slots):
        eng.step()
    eng.run()                       # end-of-run deferred checks
    dt = time.perf_counter() - t0
    toks = [list(eng.requests[r].generated) for r in rids]
    n_tok = sum(len(t) for t in toks)
    return rids, toks, n_tok / max(dt, 1e-9)


def _run_cluster(eng, prompts, gen_len: int):
    rids = [eng.submit(prompt=p, max_new_tokens=gen_len) for p in prompts]
    eng.step()
    t0 = time.perf_counter()
    while eng._busy():
        eng.step()
    eng.run()
    dt = time.perf_counter() - t0
    toks = [list(eng.requests[r].generated) for r in rids]
    n_tok = sum(len(t) for t in toks)
    return rids, toks, n_tok / max(dt, 1e-9)


def _identity(eng, rids, toks, want) -> dict:
    victims = [i for i, r in enumerate(rids)
               if eng.requests[r].integrity_retries
               or eng.requests[r].n_evictions]
    return {
        "unaffected_identical": all(
            toks[i] == want[i] for i in range(len(rids))
            if i not in victims),
        "recovered_identical": all(toks[i] == want[i] for i in victims),
        "n_victims": len(victims),
    }


def _measure_tamper(arch, cfg, params, scheme: str, *, seed: int,
                    batch: int, gen_len: int, prompt_len: int,
                    page_tokens: int, pages_per_slot: int) -> dict:
    kw = dict(scheme=scheme, max_slots=batch, page_tokens=page_tokens,
              pages_per_slot=pages_per_slot,
              n_pages=batch * pages_per_slot + 4)  # quarantine headroom
    prompts = _prompts(cfg, seed, batch, prompt_len)

    base = SecureServingEngine(arch, cfg, params, fault_tolerance=True,
                               **kw)
    _, want, _ = _run(base, prompts, gen_len)

    eng = SecureServingEngine(arch, cfg, params, fault_tolerance=True,
                              **kw)
    FaultPlan([Fault(tick=FAULT_TICK, kind="bitflip", slot=0)]).attach(eng)
    rids, toks, tok_per_s = _run(eng, prompts, gen_len)

    victims = [r for r in rids if eng.requests[r].integrity_retries]
    recovery_ticks = max(
        (eng.requests[r].done_tick - FAULT_TICK for r in victims
         if eng.requests[r].done_tick is not None), default=None)
    row = {
        "name": f"chaos_bitflip_{scheme}",
        "mode": "bitflip",
        "scheme": scheme,
        "batch": batch,
        "gen_len": gen_len,
        "tok_per_s": tok_per_s,
        "recovery_ticks": recovery_ticks,
        "quarantined_pages": eng.stats["integrity_quarantined_pages"],
        "sessions_recovered": eng.stats["sessions_recovered"],
        "sessions_lost": eng.stats["sessions_lost"],
        "deferred_mac_ok": bool(eng.deferred_check()),
    }
    row.update(_identity(eng, rids, toks, want))
    return row


def _measure_shard_kill(arch, cfg, params, scheme: str, *, seed: int,
                        batch: int, gen_len: int, prompt_len: int,
                        page_tokens: int, pages_per_slot: int,
                        shards: int = 2) -> dict:
    kw = dict(shards=shards, scheme=scheme,
              max_slots=-(-batch // shards), page_tokens=page_tokens,
              pages_per_slot=pages_per_slot)
    prompts = _prompts(cfg, seed, batch, prompt_len)

    base = ClusterEngine(arch, cfg, params, fault_tolerance=True, **kw)
    _, want, _ = _run_cluster(base, prompts, gen_len)

    eng = ClusterEngine(arch, cfg, params, fault_tolerance=True, **kw)
    FaultPlan([Fault(tick=FAULT_TICK, kind="shard_kill",
                     shard=shards - 1)]).attach_cluster(eng)
    rids, toks, tok_per_s = _run_cluster(eng, prompts, gen_len)

    agg = eng.engine_stats
    row = {
        "name": f"chaos_shardkill_{scheme}",
        "mode": "shard_kill",
        "scheme": scheme,
        "batch": batch,
        "shards": shards,
        "gen_len": gen_len,
        "tok_per_s": tok_per_s,
        "shard_failovers": eng.stats["shard_failovers"],
        "quarantined_pages": agg.get("integrity_quarantined_pages", 0),
        "sessions_recovered": agg.get("sessions_recovered", 0),
        "sessions_lost": agg.get("sessions_lost", 0),
        "root_mac_ok": bool(eng.deferred_check()),
    }
    row.update(_identity(eng, rids, toks, want))
    return row


def collect(schemes=VERIFYING, kill_schemes=("off", "seda"), *,
            arch_name: str = "minitron-4b", seed: int = 7,
            batch: int = 4, gen_len: int = 6, prompt_len: int = 9,
            page_tokens: int = 8, pages_per_slot: int = 4) -> list:
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    common = dict(seed=seed, batch=batch, gen_len=gen_len,
                  prompt_len=prompt_len, page_tokens=page_tokens,
                  pages_per_slot=pages_per_slot)
    results = []
    for scheme in schemes:
        results.append(_measure_tamper(arch, cfg, params, scheme, **common))
    for scheme in kill_schemes:
        results.append(_measure_shard_kill(arch, cfg, params, scheme,
                                           **common))
    return results


def run() -> list:
    """benchmarks.run suite hook: CSV rows for a reduced sweep."""
    rows = []
    for r in collect(schemes=("seda",), kill_schemes=("seda",)):
        rows.append({
            "name": r["name"],
            "us_per_call": 1e6 / max(r["tok_per_s"], 1e-9),
            "derived": (f"tok/s={r['tok_per_s']:.1f} "
                        f"recovered={r['sessions_recovered']} "
                        f"lost={r['sessions_lost']} "
                        f"identical={r['unaffected_identical']}"
                        f"/{r['recovered_identical']}"),
        })
    return rows


def main(argv=None) -> list:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--schemes", default=",".join(VERIFYING))
    ap.add_argument("--kill-schemes", default="off,seda")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=9)
    ap.add_argument("--page-tokens", type=int, default=8)
    ap.add_argument("--pages-per-slot", type=int, default=4)
    ap.add_argument("--json", default=None, help="write results to this file")
    args = ap.parse_args(argv)

    results = collect(
        schemes=tuple(args.schemes.split(",")),
        kill_schemes=tuple(args.kill_schemes.split(",")),
        arch_name=args.arch, seed=args.seed, batch=args.batch,
        gen_len=args.gen_len, prompt_len=args.prompt_len,
        page_tokens=args.page_tokens, pages_per_slot=args.pages_per_slot)
    for r in results:
        print(f"[chaos-bench] {r['name']:<24} tok/s={r['tok_per_s']:8.1f} "
              f"recovered={r['sessions_recovered']} "
              f"lost={r['sessions_lost']} "
              f"identical={r['unaffected_identical']}"
              f"/{r['recovered_identical']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(stamp({"benchmark": "chaos", "seed": args.seed,
                             "results": results}), f, indent=2)
        print(f"[chaos-bench] wrote {args.json}")
    return results


if __name__ == "__main__":
    main()
