"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 100 --global-batch 8 --seq-len 256 --smoke \
        --scheme seda --ckpt-dir /tmp/ckpt --ckpt-every 50

Features exercised end-to-end (deliverables b/h):
  * any assigned arch (--arch), reduced (--smoke) or full config;
  * SeDA secure boundary: params protected between steps under
    --scheme {off,seda,sgx64,mgx64,...} (paper-faithful emulation), and
    checkpoints always encrypted+MAC'd (tamper -> refuse to load);
  * fault tolerance: atomic checkpoints + deterministic resumable data
    pipeline (restart with the same flags resumes from the last step);
  * straggler watchdog: per-step wall-time EWMA; steps slower than
    --straggler-factor x the EWMA are logged (on a real pod this feeds
    the controller that re-shards around slow hosts).
"""

from __future__ import annotations

import argparse
import os
import time

import jax

from repro.checkpoint.secure_ckpt import (latest_step, load_checkpoint,
                                          save_checkpoint)
from repro.configs import OPT_DTYPE_OVERRIDES, get_arch
from repro.core import SecureExecutor
from repro.core.secure_memory import SecureKeys
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import encdec as ed
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


def build(arch_name: str, smoke: bool):
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_config() if smoke else arch.make_config()
    specs = (ed.encdec_specs(cfg) if arch.kind == "encdec"
             else lm_mod.lm_specs(cfg))
    return arch, cfg, specs


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scheme", default="off",
                    help="per-step secure boundary (off|seda|mgx64|sgx64|...)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    args = ap.parse_args(argv)

    arch, cfg, specs = build(args.arch, args.smoke)
    opt_cfg = AdamWConfig(
        lr=args.lr,
        state_dtype=OPT_DTYPE_OVERRIDES.get(args.arch, "float32")
        if not args.smoke else "float32")

    data_cfg = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq_len, global_batch=args.global_batch,
        seed=args.seed,
        kind=("encdec" if arch.kind == "encdec"
              else ("vlm" if getattr(cfg, "n_image_patches", 0) else "lm")),
        n_image_patches=getattr(cfg, "n_image_patches", 0),
        d_vision=getattr(cfg, "d_vision", 0),
        d_model=cfg.d_model, src_len=max(8, args.seq_len // 2))
    data = SyntheticLM(data_cfg)

    keys = SecureKeys.derive(args.seed)
    start_step = 0
    params = None
    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            path = os.path.join(args.ckpt_dir, f"step_{last:08d}")
            from repro.models.layers import shape_structs
            template = shape_structs(specs)
            params, manifest = load_checkpoint(path, template, keys)
            start_step = manifest["extra_state"]["data"]["step"]
            data.load_state_dict(manifest["extra_state"]["data"])
            print(f"[train] resumed from {path} at step {start_step} "
                  f"(integrity verified)")
    if params is None:
        params = init_params(specs, jax.random.PRNGKey(args.seed))
    opt = init_opt_state(params, opt_cfg)

    inner = make_train_step(arch, cfg, opt_cfg)
    executor = SecureExecutor(scheme=args.scheme, keys=keys)
    region = executor.region_spec(params)

    if args.scheme == "off":
        step_fn = jax.jit(inner)
        state = params
    else:
        # The secure step keeps opt state outside the boundary (it never
        # leaves HBM in this threat model; the paper protects weights +
        # activations crossing off-chip).
        def sec_step(state, opt, batch, idx):
            p, ok = executor.unprotect(state, region)
            p, opt, metrics = inner(p, opt, batch)
            metrics["integrity_ok"] = ok
            return executor.protect(p, region, step=idx + 1), opt, metrics

        step_fn = jax.jit(sec_step)
        state = executor.protect(params, region, step=start_step)

    ewma = None
    history = []
    for step in range(start_step, args.steps):
        batch = next(data)
        t0 = time.perf_counter()
        if args.scheme == "off":
            state, opt, metrics = step_fn(state, opt, batch)
        else:
            state, opt, metrics = step_fn(state, opt, batch, step)
            if not bool(metrics["integrity_ok"]):
                raise RuntimeError(
                    f"INTEGRITY FAILURE at step {step}: protected params "
                    f"failed their layer-MAC check — aborting")
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        if dt > args.straggler_factor * ewma and step > start_step + 3:
            print(f"[train][straggler] step {step} took {dt:.2f}s "
                  f"(ewma {ewma:.2f}s)")
        history.append(loss)
        if step % args.log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"({dt * 1e3:.0f} ms)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            p = (state if args.scheme == "off"
                 else executor.unprotect(state, region)[0])
            path = save_checkpoint(
                args.ckpt_dir, step + 1, p, keys,
                extra_state={"data": data.state_dict()})
            print(f"[train] secure checkpoint -> {path}")

    if args.ckpt_dir:
        p = (state if args.scheme == "off"
             else executor.unprotect(state, region)[0])
        save_checkpoint(args.ckpt_dir, args.steps, p, keys,
                        extra_state={"data": data.state_dict()})
    return {"first_loss": history[0] if history else None,
            "last_loss": history[-1] if history else None,
            "steps": len(history)}


if __name__ == "__main__":
    out = main()
    print(f"[train] done: {out}")
