"""Paged, MAC-protected KV-cache pool for batched secure serving.

The serving-side boundary in SeDA is the KV/latent cache: during long
decodes it is the tensor that lives in untrusted memory.  This module
co-designs the serving memory layout with the protection machinery:

* the cache is a pool of fixed-size **pages** (``page_tokens`` tokens
  per page, per sequence, spanning all layers);
* each page's per-layer payload is padded to the scheme's optBlk
  granularity (``block_bytes`` from :mod:`repro.core.secure_exec`), so
  a page is always a whole number of protection blocks — the page is
  the unit of ownership AND of MAC bookkeeping;
* each page carries a MAC (XOR aggregate of its optBlk MACs, per
  :mod:`repro.core.mac`) and a VN (:func:`repro.core.vn.kv_page_vn`);
* reads verify only the pages a decode step touches; writes re-MAC
  only dirty pages; a pool-level deferred MAC (the model-MAC level of
  :mod:`repro.core.multilevel`) is maintained incrementally and checked
  off the critical path.

Trust model (matches the paper's Table III assignments): ciphertext
pages (and, for the block-gated SGX/MGX schemes, their per-block MAC
tables) are untrusted; page MACs and VNs model on-chip SRAM metadata
for MGX/SeDA (SGX's off-chip VN table and integrity tree are charged as
emulated traffic, as in :mod:`repro.core.secure_exec`).  Replaying an
old page ciphertext therefore fails verification: the on-chip VN has
moved on and the MAC binding (PA, VN, layer, fmap, blk) no longer
matches.

Everything here is pure and jit-compatible; the serving engine traces
``read_pages`` + model decode + ``write_dirty`` as ONE jitted
computation.  On the B-AES/NH schemes with narrow blocks BOTH boundary
directions run fused Pallas kernels: reads through decrypt+hash
(:func:`repro.kernels.fused_crypt_mac.ops.secure_read_kernel`) and
writes through encrypt+hash-of-fresh-ciphertext
(:func:`repro.kernels.fused_crypt_mac.ops.secure_write_kernel`) — the
dirty-page reseal touches its bytes once, not once to encrypt and once
to MAC.

**Multi-tenant pages.**  Every boundary crossing optionally takes a
:class:`PageKeyCtx`: a stacked key bank (one row per retained
(tenant, epoch) — see :mod:`repro.tenancy.registry`) plus per-page row
indices and (tenant, epoch) identities.  With a ctx, each page is
encrypted/MACed under *its own tenant-epoch keys* (gathered from the
bank inside the traced computation and applied via ``vmap``), and the
tenant identity is folded into the RePA tuple twice over:

* the MAC binding's ``fmap`` word carries ``tenant_idx`` and the key
  epoch alongside the leaf index, and
* the CTR counter gains the tenant-epoch VN salt (word 0) and a
  ``tenant_idx ‖ epoch`` word (word 2),

so a page written under tenant A's keys fails verification when read
under tenant B's — or under a stale epoch — even before the key
mismatch scrambles the plaintext.  ``ctx=None`` keeps the single-key
fast path (including the fused-kernel route) bit-identical to the
single-tenant engine.  When every page of a crossing resolves to ONE
bank row, ``uniform=True`` keeps the per-page (tenant, epoch) words in
the RePA binding but dispatches the flat single-key crypt/MAC route
(including the fused kernels) instead of the vmapped per-page one —
bit-identical metadata, single-key speed.  MIXED-row crossings stay on
the fused kernels too, in BOTH directions: the mixed variants gather
each page's AES schedule, B-AES diversifiers and NH key row from the
bank inside one fused pass
(:func:`repro.kernels.fused_crypt_mac.ops.secure_read_kernel_mixed` /
:func:`repro.kernels.fused_crypt_mac.ops.secure_write_kernel_mixed`),
so a mixed-tenant tick's dirty-page reseal never falls back to the
vmapped per-page reference either.

**Touched-page windows.**  :class:`TwoLevelPageTable` (slot directory
-> pow2 page-count-bucketed windows) lets every boundary crossing run
on just the pages a tick touches: ``read_pages``/``write_dirty``
derive all shapes from the page table actually passed, so a (S, P)
window with P < pages_per_slot gathers/crypts/MACs P pages per slot —
protection work follows the live context, not pool capacity.

**Sharded pools.**  A :class:`PageSpec` additionally carries a
``(shard, n_shards)`` identity.  The shard id is folded into the RePA
binding (``fmap`` bits 28–31) and XORed into CTR counter word 0, so a
page is cryptographically pinned to its device: a byte-identical page
(ciphertext + MAC + VN) captured on shard 0 and replayed into shard
1's pool recomputes a different MAC under shard 1's binding and fails
its gate.  ``shard=0, n_shards=1`` (the default) is bit-identical to
the unsharded layout.  :func:`reseal_pages` (decrypt old keys →
re-encrypt new, one fused crossing) and :func:`migrate_pages` (reseal
across pools/shards) are the primitives live rotation and secure
cross-shard migration build on.

**One IO surface.**  Every boundary crossing is a method of
:class:`PageIO`, a facade bound to one ``(spec, keys)`` pair — the
prefix cache, the engine and the cluster all go through it.  The
module-level ``read_pages``/``write_pages``/... functions are thin
delegating wrappers kept for existing callers; both spellings are
bit-identical.

**Shared-prefix pages.**  :class:`PrefixCache` is the host-side
content-addressed index over pages sealed under a tenant's dedicated
*cache binding*: epoch word :data:`PREFIX_ROLE` (fmap bit 27) selects
the tenant's epoch-independent cache keys instead of a session epoch,
so a prefix sealed once is verify-read by many sessions — VN-stable,
no re-MAC on hit — and survives ``rotate()``.  Divergence is
copy-on-write: the engine reseals the first dirty shared page into a
private page under the session binding (see
:mod:`repro.serve.engine`).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baes, ctr, mac
from repro.core.layout import SEGMENT_BYTES
from repro.core.secure_exec import SCHEMES, SchemeConfig, emulated_tree_probe

__all__ = [
    "LeafPageSpec",
    "PageSpec",
    "PagedKVPool",
    "PageKeyCtx",
    "PageIO",
    "PrefixCache",
    "PrefixCacheEntry",
    "PREFIX_ROLE",
    "TwoLevelPageTable",
    "page_count_bucket",
    "PAGED_FIELDS",
    "paged_flags",
    "length_flags",
    "build_page_spec",
    "init_pool",
    "read_pages",
    "write_pages",
    "write_prefill",
    "write_dirty",
    "read_pages_raw",
    "reseal_pages",
    "migrate_pages",
    "deferred_pool_check",
]

# fmap-word bit budget: leaf idx (0-7) | tenant (8-15) | epoch word
# (16-27) | shard (28-31).  The shard field caps a sharded pool's
# fan-out.  The epoch word spends its top bit (fmap bit 27) as the
# prefix-cache ROLE: a page sealed into the shared-prefix cache
# carries epoch word PREFIX_ROLE instead of a session epoch, selecting
# the tenant's epoch-independent cache keys — session epochs occupy
# the remaining 11 bits (fmap 16-26).  The crypt/MAC plumbing below is
# role-agnostic: the role bit rides inside the epoch word through
# _tenant_words / _block_binding unchanged.
MAX_SHARDS = 16
PREFIX_ROLE = 0x800          # bit 11 of the epoch word -> fmap bit 27

# Cache NamedTuple fields whose leaves have a (steps, B, max_len, ...)
# sequence layout and cross the untrusted boundary.  Everything else
# (lengths, Mamba SSM/conv state) is small per-sequence register state
# that stays on-chip.
PAGED_FIELDS = frozenset({"k", "v", "c_kv", "k_pe"})


class LeafPageSpec(NamedTuple):
    """Static page layout for one paged cache leaf (hashable)."""

    leaf_idx: int        # index in the flat cache-leaf list
    steps: int           # layer-stack dim of the scanned segment
    base_layer: int      # global layer id of stack index 0 (MAC binding)
    rest: tuple          # per-token trailing dims, e.g. (n_kv, head_dim)
    dtype: str
    tok_bytes: int       # bytes per token per layer
    lp_bytes: int        # per-layer page payload, padded to block_bytes
    page_bytes: int      # steps * lp_bytes
    n_blocks: int        # optBlks per page = page_bytes // block_bytes
    pa_base: int         # pool base address in 16B-segment units


class PageSpec(NamedTuple):
    """Static description of the whole paged pool (hashable jit arg)."""

    leaves: tuple        # tuple[LeafPageSpec, ...]
    page_tokens: int
    pages_per_slot: int
    n_pages: int         # real pages; arrays carry one extra scratch row
    max_slots: int
    max_len: int         # page_tokens * pages_per_slot
    scheme: str          # key into core.secure_exec.SCHEMES
    use_kernel: bool     # route crypto through the Pallas kernels
    shard: int = 0       # this pool's shard id (folded into RePA/CTR)
    n_shards: int = 1    # cluster fan-out this pool belongs to

    @property
    def cfg(self) -> SchemeConfig:
        return SCHEMES[self.scheme]

    @property
    def scratch_page(self) -> int:
        """Write sink for inactive slots / unallocated table entries."""
        return self.n_pages

    @property
    def blocks_per_read(self) -> int:
        """optBlks touched by one full gather (tree-traffic emulation)."""
        return (sum(l.n_blocks for l in self.leaves)
                * self.max_slots * self.pages_per_slot)


class PagedKVPool(NamedTuple):
    """The cache as it lives across the boundary (+ its metadata)."""

    cts: tuple           # per paged leaf: (n_pages + 1, page_bytes) u8
    page_macs: jax.Array     # (n_pages + 1, MAC_BYTES) u8
    block_macs: tuple        # block-gated schemes: per leaf
    #                          (n_pages + 1, n_blocks, MAC_BYTES) u8; else ()
    page_vns: jax.Array      # (n_pages + 1,) u32
    pool_mac: jax.Array      # (MAC_BYTES,) u8 — deferred model-level MAC


class PageKeyCtx(NamedTuple):
    """Per-page tenant key selection for one boundary crossing.

    The four ``bank_*`` arrays are the registry's stacked key bank
    (K rows, one per retained (tenant, epoch)); the three per-page
    arrays select a row and carry the identity folded into the RePA
    binding.  All seven are ordinary traced arrays, so the same
    compiled step serves any tenant mix / post-rotation key state.
    """

    bank_key: jax.Array          # (K, 16) u8 cipher keys
    bank_round_keys: jax.Array   # (K, 11, 16) u8 schedules
    bank_hash_key: jax.Array     # (K, n_lanes) u32 NH lanes
    bank_salt: jax.Array         # (K,) u32 CTR-counter salts
    key_idx: jax.Array           # (N,) i32 bank row per page
    owners: jax.Array            # (N,) u32 tenant index per page
    epochs: jax.Array            # (N,) u32 key epoch per page

    @classmethod
    def make(cls, bank, key_idx, owners, epochs) -> "PageKeyCtx":
        """Build from a registry ``KeyBank`` + per-page selections."""
        return cls(bank.key, bank.round_keys, bank.hash_key, bank.salt,
                   jnp.asarray(key_idx, jnp.int32),
                   jnp.asarray(owners, jnp.uint32),
                   jnp.asarray(epochs, jnp.uint32))

    def take(self, n: int) -> "PageKeyCtx":
        """Ctx for the first ``n`` pages (static prefix slice)."""
        return self._replace(key_idx=self.key_idx[:n],
                             owners=self.owners[:n], epochs=self.epochs[:n])


# ---------------------------------------------------------------------------
# Two-level page table: slot directory -> bucketed page windows.
# ---------------------------------------------------------------------------


def page_count_bucket(n: int, cap: int) -> int:
    """Round a live page count up to the next power of two, capped."""
    b = 1
    while b < n:
        b <<= 1
    return min(b, cap)


class TwoLevelPageTable:
    """Host-side two-level page table over the paged pool.

    Level 1 — the **slot directory**: one variable-length page-id list
    per decode lane (plus, in tenant mode, the parallel per-page
    key-epoch list).  The directory holds the scheduler's *slot
    entries* (any object with ``pages`` and, optionally,
    ``page_epochs`` list attributes) and reads them live at window
    emission, so growth/eviction/migration bookkeeping — including
    wholesale list reassignment — is reflected without copying.

    Level 2 — the **page window**: a fixed-shape ``(max_slots, bucket)``
    int32 table emitted per boundary crossing, where ``bucket`` is the
    pow2 page-count bucket covering every live slot's touched pages
    (the pages holding positions <= length, i.e. ``length //
    page_tokens + 1`` of them).  The jitted decode step compiles once
    per bucket — at most ``log2(pages_per_slot) + 1`` variants,
    mirroring PR 2's prefill length bucketing — and its
    gather/crypt/MAC/verify work scales with the bucket, not with
    ``pages_per_slot``: a short live context in a large pool no longer
    pays for the pool's resident capacity.

    Invariant: every emitted window is a *prefix* of each slot's page
    list (pages are table-ordered by token position), and the bucket
    always covers each live slot's dirty write page, so decode output
    is token-identical to the all-resident window for every scheme.
    """

    def __init__(self, max_slots: int, pages_per_slot: int):
        self.max_slots = max_slots
        self.pages_per_slot = pages_per_slot
        self._entries: list = [None] * max_slots

    def install(self, idx: int, entry) -> None:
        """Register one lane's directory entry — any object carrying a
        ``pages`` list attribute (and ``page_epochs`` in tenant mode)."""
        self._entries[idx] = entry

    def clear(self, idx: int) -> None:
        self._entries[idx] = None

    def bucket_for(self, live_lengths, page_tokens: int) -> int:
        """Pow2 page-count bucket covering every live slot's touched
        pages *and* its dirty write page (``length // page_tokens + 1``
        pages per slot)."""
        need = 1
        for ln in live_lengths:
            need = max(need, ln // page_tokens + 1)
        return page_count_bucket(need, self.pages_per_slot)

    def window(self, bucket: int) -> np.ndarray:
        """Level-2 page window: (max_slots, bucket) int32, -1 where a
        slot is empty or holds fewer pages than the bucket."""
        tab = np.full((self.max_slots, bucket), -1, np.int32)
        for i, entry in enumerate(self._entries):
            pages = None if entry is None else entry.pages
            if not pages:
                continue
            k = min(len(pages), bucket)
            tab[i, :k] = pages[:k]
        return tab


# ---------------------------------------------------------------------------
# Structure classification + spec construction.
# ---------------------------------------------------------------------------


def _iter_field_flags(node: Any, wanted: frozenset):
    """Yield one bool per flat leaf: is it under a ``wanted`` field?"""
    if hasattr(node, "_fields"):  # cache NamedTuples (KVCache, MLACache, ...)
        for name in node._fields:
            sub = getattr(node, name)
            n_sub = len(jax.tree_util.tree_leaves(sub))
            hit = name in wanted
            for _ in range(n_sub):
                yield hit
    elif isinstance(node, (list, tuple)):
        for child in node:
            yield from _iter_field_flags(child, wanted)
    elif isinstance(node, dict):
        for key in sorted(node):
            yield from _iter_field_flags(node[key], wanted)
    else:
        yield False


def paged_flags(cache_tree: Any) -> list:
    """Per-flat-leaf bools: True for leaves that go through the pool."""
    return list(_iter_field_flags(cache_tree, PAGED_FIELDS))


def length_flags(cache_tree: Any) -> list:
    """Per-flat-leaf bools: True for per-layer ``length`` leaves."""
    return list(_iter_field_flags(cache_tree, frozenset({"length"})))


def build_page_spec(cache_tree: Any, *, scheme: str, page_tokens: int,
                    n_pages: int, max_slots: int, max_len: int,
                    use_kernel: bool = False, shard: int = 0,
                    n_shards: int = 1) -> PageSpec:
    """Lay the paged leaves of a cache pytree out as a protected pool.

    ``cache_tree`` is the ShapeDtypeStruct tree from
    ``lm.cache_specs(cfg, max_slots, max_len)``.  The page-size /
    block-granularity invariant: each leaf's per-layer page payload
    (``page_tokens`` tokens) is padded up to the scheme's optBlk
    granularity, so page size is always a whole multiple of the SeDA
    block size and a page never shares a protection block with its
    neighbour.
    """
    if max_len % page_tokens:
        raise ValueError(f"max_len {max_len} not a multiple of "
                         f"page_tokens {page_tokens}")
    if not 0 < n_shards <= MAX_SHARDS or not 0 <= shard < n_shards:
        raise ValueError(f"shard {shard} / n_shards {n_shards} outside the "
                         f"{MAX_SHARDS}-shard fmap-word budget")
    cfg = SCHEMES[scheme]
    flags = paged_flags(cache_tree)
    leaves = jax.tree_util.tree_leaves(cache_tree)
    if len(flags) != len(leaves):
        raise ValueError("flag walk disagrees with tree_leaves order")
    specs = []
    cursor = 0          # pool byte cursor across leaves
    base_layer = 0
    for idx, (leaf, is_paged) in enumerate(zip(leaves, flags)):
        if not is_paged:
            continue
        steps, bsz, seq = leaf.shape[0], leaf.shape[1], leaf.shape[2]
        if bsz != max_slots or seq != max_len:
            raise ValueError(
                f"paged leaf {idx} has shape {leaf.shape}, expected "
                f"(steps, {max_slots}, {max_len}, ...)")
        rest = tuple(int(d) for d in leaf.shape[3:])
        itemsize = jnp.dtype(leaf.dtype).itemsize
        tok_bytes = itemsize
        for d in rest:
            tok_bytes *= d
        lp_bytes = (-(-page_tokens * tok_bytes // cfg.block_bytes)
                    * cfg.block_bytes)
        page_bytes = steps * lp_bytes
        specs.append(LeafPageSpec(
            leaf_idx=idx, steps=steps, base_layer=base_layer, rest=rest,
            dtype=jnp.dtype(leaf.dtype).name, tok_bytes=tok_bytes,
            lp_bytes=lp_bytes, page_bytes=page_bytes,
            n_blocks=page_bytes // cfg.block_bytes,
            pa_base=cursor // SEGMENT_BYTES))
        cursor += (n_pages + 1) * page_bytes
        base_layer += steps
    if not specs:
        raise ValueError("cache tree has no paged (KV/latent) leaves — "
                         "the paged engine needs at least one attention "
                         "or MLA layer")
    return PageSpec(tuple(specs), page_tokens, max_len // page_tokens,
                    n_pages, max_slots, max_len, scheme, use_kernel,
                    shard, n_shards)


def init_pool(spec: PageSpec) -> PagedKVPool:
    cfg = spec.cfg
    cts = tuple(jnp.zeros((spec.n_pages + 1, l.page_bytes), jnp.uint8)
                for l in spec.leaves)
    block_macs = ()
    if cfg.verify == "block":
        block_macs = tuple(
            jnp.zeros((spec.n_pages + 1, l.n_blocks, mac.MAC_BYTES), jnp.uint8)
            for l in spec.leaves)
    return PagedKVPool(
        cts=cts,
        page_macs=jnp.zeros((spec.n_pages + 1, mac.MAC_BYTES), jnp.uint8),
        block_macs=block_macs,
        page_vns=jnp.zeros((spec.n_pages + 1,), jnp.uint32),
        pool_mac=jnp.zeros((mac.MAC_BYTES,), jnp.uint8),
    )


# ---------------------------------------------------------------------------
# Per-page crypto/MAC primitives (flattened over a batch of pages).
# ---------------------------------------------------------------------------


def _block_pa(spec: PageSpec, leaf: LeafPageSpec,
              page_ids: jax.Array) -> jax.Array:
    """(N,) page ids -> (N, n_blocks) u32 optBlk PAs (16B-segment units)."""
    bb = spec.cfg.block_bytes
    segs_per_page = leaf.page_bytes // SEGMENT_BYTES
    blk = jnp.arange(leaf.n_blocks, dtype=jnp.uint32) * (bb // SEGMENT_BYTES)
    return (jnp.uint32(leaf.pa_base)
            + page_ids.astype(jnp.uint32)[:, None] * jnp.uint32(segs_per_page)
            + blk[None, :])


def _tenant_words(ctx: PageKeyCtx, per_page: int):
    """Per-entry (salt, tenant ‖ epoch) u32 words, repeated ``per_page``."""
    salts = jnp.repeat(ctx.bank_salt[ctx.key_idx], per_page)
    tenant = jnp.repeat((ctx.owners << jnp.uint32(16))
                        | (ctx.epochs & jnp.uint32(0xFFFF)), per_page)
    return salts, tenant


def _shard_ctr_word(spec: PageSpec) -> jnp.ndarray:
    """Shard id XORed into CTR counter word 0 (zero for shard 0)."""
    return jnp.uint32(spec.shard) << jnp.uint32(24)


def _block_counters(spec: PageSpec, leaf: LeafPageSpec, page_ids: jax.Array,
                    vns: jax.Array,
                    ctx: PageKeyCtx | None = None) -> jax.Array:
    """PA||VN counter words per optBlk: (N * n_blocks, 4) u32.

    With a tenant ctx, word 0 carries the tenant-epoch VN salt and
    word 2 the ``tenant_idx ‖ epoch`` identity, so CTR streams never
    collide across tenants or epochs even at equal (PA, VN).  On a
    sharded pool the shard id is XORed into word 0 — the keystream of a
    page never repeats across shards even under one engine-wide key.
    """
    pa = _block_pa(spec, leaf, page_ids).reshape(-1)
    vn_col = jnp.repeat(vns.astype(jnp.uint32), leaf.n_blocks)
    shard_w = _shard_ctr_word(spec)
    if ctx is None:
        word0 = jnp.full_like(pa, shard_w)
        return jnp.stack([word0, pa, jnp.zeros_like(pa), vn_col], axis=-1)
    salts, tenant = _tenant_words(ctx, leaf.n_blocks)
    return jnp.stack([salts ^ shard_w, pa, tenant, vn_col], axis=-1)


def _block_binding(spec: PageSpec, leaf: LeafPageSpec, page_ids: jax.Array,
                   vns: jax.Array,
                   ctx: PageKeyCtx | None = None) -> mac.Binding:
    """MAC binding tuple for every optBlk of N pages (flattened).

    With a tenant ctx the ``fmap`` word is extended to
    ``leaf_idx | tenant_idx << 8 | key_epoch << 16`` — the RePA tuple
    then binds each block MAC to its owner and key epoch, so relocating
    a page across tenants (or replaying a stale-epoch page) breaks the
    binding independently of the key mismatch.  Bits 28-31 carry the
    pool's shard id, pinning every MAC to its device: a byte-identical
    page replayed onto another shard fails its gate.
    """
    n = page_ids.shape[0]
    bb = spec.cfg.block_bytes
    blocks_per_layer = leaf.lp_bytes // bb
    blk = jnp.arange(leaf.n_blocks, dtype=jnp.uint32)
    layer = jnp.uint32(leaf.base_layer) + blk // jnp.uint32(blocks_per_layer)
    pa = _block_pa(spec, leaf, page_ids).reshape(-1)
    fmap = jnp.uint32(leaf.leaf_idx) | (jnp.uint32(spec.shard)
                                        << jnp.uint32(28))
    if ctx is not None:
        fmap = jnp.repeat(
            fmap | (ctx.owners << jnp.uint32(8))
            | ((ctx.epochs & jnp.uint32(0xFFF)) << jnp.uint32(16)),
            leaf.n_blocks)
    return mac.Binding.make(
        pa,
        jnp.repeat(vns.astype(jnp.uint32), leaf.n_blocks),
        jnp.tile(layer, n),
        fmap,
        jnp.tile(blk, n))


def _uniform_keys(ctx: PageKeyCtx):
    """Single-row key view for the uniform fast path (row of page 0)."""
    row = ctx.key_idx[0]
    return (ctx.bank_key[row], ctx.bank_round_keys[row],
            ctx.bank_hash_key[row])


def _crypt(spec: PageSpec, leaf: LeafPageSpec, buf: jax.Array,
           page_ids: jax.Array, vns: jax.Array, keys,
           ctx: PageKeyCtx | None = None,
           uniform: bool = False) -> jax.Array:
    """XOR-crypt (enc == dec) page payloads.  buf: (N, page_bytes) u8.

    ``ctx=None``: every page under the engine-wide ``keys``.  With a
    ctx, each page's keys are gathered from the bank row it selects and
    the crypt is vmapped over pages (per-page key schedules); with
    ``uniform=True`` every page is known (host-side) to select the same
    bank row, so a single gathered key runs the flat single-key route —
    counters/bindings are unchanged, only the dispatch shape is.
    """
    cfg = spec.cfg
    if cfg.name == "off":
        return buf
    if cfg.baes:
        counters = _block_counters(spec, leaf, page_ids, vns, ctx)
        if ctx is not None and not uniform:
            rks = ctx.bank_round_keys[ctx.key_idx]         # (N, 11, 16)
            kks = ctx.bank_key[ctx.key_idx]                # (N, 16)
            per_page = counters.reshape(-1, leaf.n_blocks, 4)

            def one(buf1, rk1, kk1, ctr1):
                return baes.baes_encrypt(buf1, rk1, ctr1,
                                         block_bytes=cfg.block_bytes, key=kk1)

            return jax.vmap(one)(buf, rks, kks, per_page)
        if ctx is None:
            key, round_keys = keys.key, keys.round_keys
        else:
            key, round_keys, _ = _uniform_keys(ctx)
        narrow = cfg.block_bytes // SEGMENT_BYTES <= 11
        if spec.use_kernel and narrow:
            from repro.kernels.otp_xor.ops import baes_encrypt_kernel
            out = baes_encrypt_kernel(buf.reshape(-1), round_keys,
                                      counters, block_bytes=cfg.block_bytes)
        else:
            out = baes.baes_encrypt(buf.reshape(-1), round_keys, counters,
                                    block_bytes=cfg.block_bytes, key=key)
        return out.reshape(buf.shape)
    # T-AES: one AES invocation per 16B segment, PA advancing per segment.
    segs_per_page = leaf.page_bytes // SEGMENT_BYTES
    pa = (jnp.uint32(leaf.pa_base)
          + page_ids.astype(jnp.uint32)[:, None] * jnp.uint32(segs_per_page)
          + jnp.arange(segs_per_page, dtype=jnp.uint32)[None, :]).reshape(-1)
    vn_col = jnp.repeat(vns.astype(jnp.uint32), segs_per_page)
    shard_w = _shard_ctr_word(spec)
    if ctx is None:
        word0 = jnp.full_like(pa, shard_w)
        counters = jnp.stack([word0, pa, jnp.zeros_like(pa), vn_col], axis=-1)
        otp = ctr.ctr_keystream(keys.round_keys, counters)
        return (buf.reshape(-1, SEGMENT_BYTES) ^ otp).reshape(buf.shape)
    salts, tenant = _tenant_words(ctx, segs_per_page)
    counters = jnp.stack([salts ^ shard_w, pa, tenant, vn_col], axis=-1)
    if uniform:
        _, round_keys, _ = _uniform_keys(ctx)
        otp = ctr.ctr_keystream(round_keys, counters)
        return (buf.reshape(-1, SEGMENT_BYTES) ^ otp).reshape(buf.shape)
    per_page = counters.reshape(-1, segs_per_page, 4)
    otp = jax.vmap(ctr.ctr_keystream)(
        ctx.bank_round_keys[ctx.key_idx], per_page)
    return (buf.reshape(-1, segs_per_page, SEGMENT_BYTES) ^ otp).reshape(
        buf.shape)


def _page_block_macs(spec: PageSpec, leaf: LeafPageSpec, ct: jax.Array,
                     page_ids: jax.Array, vns: jax.Array, keys,
                     ctx: PageKeyCtx | None = None,
                     uniform: bool = False) -> jax.Array:
    """optBlk MACs of N ciphertext pages: (N, n_blocks, MAC_BYTES) u8."""
    cfg = spec.cfg
    binding = _block_binding(spec, leaf, page_ids, vns, ctx)
    n = page_ids.shape[0]
    if ctx is not None and not uniform:
        per_page = mac.Binding(
            *(jnp.broadcast_to(f, (n * leaf.n_blocks,))
              .reshape(n, leaf.n_blocks) for f in binding))

        def one(ct1, binding1, hk1, rk1):
            return mac.block_macs(ct1.reshape(-1, cfg.block_bytes), binding1,
                                  hash_key_u32=hk1, round_keys=rk1,
                                  engine=cfg.mac_engine)

        return jax.vmap(one)(ct, per_page, ctx.bank_hash_key[ctx.key_idx],
                             ctx.bank_round_keys[ctx.key_idx])
    if ctx is None:
        hash_key, round_keys = keys.hash_key, keys.round_keys
    else:
        _, round_keys, hash_key = _uniform_keys(ctx)
    blocks = ct.reshape(-1, cfg.block_bytes)
    macs = mac.block_macs(blocks, binding, hash_key_u32=hash_key,
                          round_keys=round_keys, engine=cfg.mac_engine)
    return macs.reshape(n, leaf.n_blocks, mac.MAC_BYTES)


def _fused_crossing(spec: PageSpec, leaf: LeafPageSpec, buf: jax.Array,
                    page_ids: jax.Array, vns: jax.Array, keys,
                    ctx: PageKeyCtx | None, uniform: bool, write: bool):
    """One kernel-fused crypt + optBlk-MAC pass over page bytes.

    Read (``write=False``: decrypt + hash the incoming ciphertext) and
    write (``write=True``: encrypt + hash the fresh ciphertext) build
    the SAME binding/counters and key selections — only the kernel pair
    differs, so the two directions cannot drift apart.  ``ctx=None``
    (engine-wide keys) and uniform ctxs run the single-key kernel; a
    MIXED ctx (pages resolving to different bank rows) runs the
    mixed-key kernel, which gathers each page's round-key schedule and
    NH key row from the bank and stays fused — the tenant words land in
    the binding/counters either way.
    """
    from repro.kernels.fused_crypt_mac import ops as fused_ops
    cfg = spec.cfg
    binding = _block_binding(spec, leaf, page_ids, vns, ctx)
    counters = _block_counters(spec, leaf, page_ids, vns, ctx)
    if ctx is not None and not uniform:
        kernel = (fused_ops.secure_write_kernel_mixed if write
                  else fused_ops.secure_read_kernel_mixed)
        rows = jnp.repeat(ctx.key_idx, leaf.n_blocks)
        out, macs = kernel(
            buf.reshape(-1), binding, ctx.bank_round_keys, counters,
            ctx.bank_hash_key, rows, block_bytes=cfg.block_bytes)
    else:
        kernel = (fused_ops.secure_write_kernel if write
                  else fused_ops.secure_read_kernel)
        if ctx is None:
            round_keys, hash_key = keys.round_keys, keys.hash_key
        else:
            _, round_keys, hash_key = _uniform_keys(ctx)
        out, macs = kernel(
            buf.reshape(-1), binding, round_keys, counters, hash_key,
            block_bytes=cfg.block_bytes)
    return (out.reshape(buf.shape),
            macs.reshape(page_ids.shape[0], leaf.n_blocks, mac.MAC_BYTES))


def _fused_read(spec: PageSpec, leaf: LeafPageSpec, ct: jax.Array,
                page_ids: jax.Array, vns: jax.Array, keys,
                ctx: PageKeyCtx | None = None, uniform: bool = False):
    """Kernel-fused decrypt + optBlk MACs (see :func:`_fused_crossing`)."""
    return _fused_crossing(spec, leaf, ct, page_ids, vns, keys, ctx,
                           uniform, write=False)


def _kernel_read_ok(spec: PageSpec) -> bool:
    cfg = spec.cfg
    return (spec.use_kernel and cfg.baes and cfg.mac_engine == "nh"
            and cfg.block_bytes // SEGMENT_BYTES <= 11)


# The fused write kernel has the same capability envelope as the read
# one (narrow-block B-AES + NH): a spec whose reads fuse also writes
# fused, so a kernel-capable tick never touches the vmapped reference
# in either direction.
_kernel_write_ok = _kernel_read_ok


def _fused_write(spec: PageSpec, leaf: LeafPageSpec, buf: jax.Array,
                 page_ids: jax.Array, vns: jax.Array, keys,
                 ctx: PageKeyCtx | None = None, uniform: bool = False):
    """Kernel-fused encrypt + optBlk MACs: the dirty page's plaintext
    is re-encrypted and its fresh ciphertext NH-hashed in ONE Pallas
    visit, instead of an encrypt dispatch followed by a MAC dispatch
    re-reading the ciphertext (see :func:`_fused_crossing`)."""
    return _fused_crossing(spec, leaf, buf, page_ids, vns, keys, ctx,
                           uniform, write=True)


# ---------------------------------------------------------------------------
# Dense <-> page byte layout.
# ---------------------------------------------------------------------------


def _pages_to_dense(spec: PageSpec, leaf: LeafPageSpec, pt: jax.Array,
                    lengths: jax.Array) -> jax.Array:
    """(S, P, page_bytes) u8 -> (steps, S, P*page_tokens, *rest), invalid
    token positions (>= length) zeroed so masked attention never sees
    decrypt garbage (and schemes stay token-bit-identical).

    P is the page-count window of this crossing — the full
    ``pages_per_slot`` or a smaller pow2 bucket: the dense view covers
    exactly the gathered window (a PREFIX of the context, since pages
    are table-ordered), so attention over it is token-identical to the
    full-length view whenever every valid position fits the window.
    """
    s, p = pt.shape[:2]
    ptok = spec.page_tokens
    win_len = p * ptok
    per_layer = pt.reshape(s, p, leaf.steps, leaf.lp_bytes)
    payload = per_layer[..., : ptok * leaf.tok_bytes]
    itemsize = jnp.dtype(leaf.dtype).itemsize
    elems = leaf.tok_bytes // itemsize
    grouped = payload.reshape(s, p, leaf.steps, ptok, elems, itemsize)
    vals = jax.lax.bitcast_convert_type(grouped, jnp.dtype(leaf.dtype))
    # (S, P, steps, ptok, elems) -> (steps, S, P*ptok, *rest)
    dense = vals.transpose(2, 0, 1, 3, 4).reshape(
        (leaf.steps, s, win_len) + leaf.rest)
    valid = (jnp.arange(win_len, dtype=jnp.int32)[None, :]
             < lengths[:, None])                       # (S, L)
    valid = valid.reshape((1, s, win_len) + (1,) * len(leaf.rest))
    return jnp.where(valid, dense, jnp.zeros((), dense.dtype))


def _dense_to_pages(spec: PageSpec, leaf: LeafPageSpec,
                    pages: jax.Array) -> jax.Array:
    """(N, steps, ptok, *rest) token data -> (N, page_bytes) u8."""
    n = pages.shape[0]
    ptok = spec.page_tokens
    itemsize = jnp.dtype(leaf.dtype).itemsize
    if jnp.dtype(leaf.dtype) == jnp.uint8:
        flat = pages.reshape(n, leaf.steps, ptok * leaf.tok_bytes)
    else:
        as_u8 = jax.lax.bitcast_convert_type(pages, jnp.uint8)
        flat = as_u8.reshape(n, leaf.steps, ptok * leaf.tok_bytes)
    pad = leaf.lp_bytes - ptok * leaf.tok_bytes
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, 0), (0, pad)))
    return flat.reshape(n, leaf.page_bytes)


def _bytes_to_tokens(spec: PageSpec, leaf: LeafPageSpec,
                     buf: jax.Array) -> jax.Array:
    """(N, page_bytes) u8 -> (N, steps, ptok, *rest) token data
    (inverse of :func:`_dense_to_pages`, padding stripped)."""
    n = buf.shape[0]
    ptok = spec.page_tokens
    per_layer = buf.reshape(n, leaf.steps, leaf.lp_bytes)
    payload = per_layer[..., : ptok * leaf.tok_bytes]
    itemsize = jnp.dtype(leaf.dtype).itemsize
    elems = leaf.tok_bytes // itemsize
    grouped = payload.reshape(n, leaf.steps, ptok, elems, itemsize)
    vals = jax.lax.bitcast_convert_type(grouped, jnp.dtype(leaf.dtype))
    return vals.reshape((n, leaf.steps, ptok) + leaf.rest)


# ---------------------------------------------------------------------------
# PageIO: the one IO surface over the pool.  Every boundary crossing —
# batched decode read, bulk/prefill/dirty write, raw page read, reseal
# and migration — is a method here; the module-level free functions
# below are thin delegating wrappers kept so existing callers stay
# bit-identical.
# ---------------------------------------------------------------------------


class PageIO:
    """All pool boundary crossings for one ``(spec, keys)`` binding.

    The facade binds what is static for an engine — the pool layout
    (:class:`PageSpec`) and the engine-wide fallback keys — while the
    pool itself, an immutable NamedTuple rewritten by every write,
    flows through the methods functionally.  Everything is pure and
    jit-compatible: the engine traces ``io.read`` + model decode +
    ``io.write_dirty`` as one computation, and the prefix cache /
    cluster share the same entry point (``io.copy`` / ``io.migrate``).
    """

    def __init__(self, spec: PageSpec, keys):
        self.spec = spec
        self.keys = keys
        # Host-side integrity verdict observers: the crossings below
        # run inside jit (verdicts are async device booleans), so the
        # caller reports each verdict the moment it host-syncs one via
        # :meth:`report_verdict` — the observability layer counts and
        # audit-logs them without touching the traced computation.
        self.verdict_hooks: list = []
        # Fault-injection hooks (repro.serve.faults): each may rewrite
        # the verdict *before* it fans out to the observers, so an
        # injected failure is indistinguishable downstream from a real
        # one.  Empty (zero-cost) outside chaos tests/benchmarks.
        self.fault_hooks: list = []

    def report_verdict(self, ok, op: str, **ctx) -> bool:
        """Fan one host-synced MAC-gate verdict out to the hooks.

        Returns ``bool(ok)`` so gate sites can write
        ``if not io.report_verdict(ok, "decode_read"): raise ...``
        with zero extra device syncs.
        """
        ok = bool(ok)
        for hook in self.fault_hooks:
            ok = bool(hook(ok, op, ctx))
        for hook in self.verdict_hooks:
            hook(ok, op, ctx)
        return ok

    def read(self, pool: PagedKVPool, page_table: jax.Array,
             lengths: jax.Array, ctx: PageKeyCtx | None = None,
             uniform: bool = False):
        """Gather + decrypt + verify the paged leaves for a batched decode.

        Args:
          page_table: (max_slots, P) int32; -1 = unallocated.  P may be
            the full ``pages_per_slot`` or a smaller pow2 page-count
            bucket (see :class:`TwoLevelPageTable`) — every shape below
            follows the table, so gather/crypt/MAC work scales with the
            bucket's page window, not with pool capacity.  The window
            must cover every valid token
            (``P * page_tokens > max(lengths)``).
          lengths: (max_slots,) int32 valid tokens per slot.
          ctx: optional per-page tenant keys (N = max_slots * P
            entries, row-major over the page table).
          uniform: host-side promise that every ctx entry selects one
            bank row — dispatches the flat single-key route with
            unchanged per-page bindings.  Mixed-row ctxs keep the fused
            kernel too, via its per-page round-key gather
            (:func:`_fused_read`).

        Returns ``(dense_leaves, ok)`` — one dense (steps, S,
        P*page_tokens, *rest) array per paged leaf, and the AND of
        every gated MAC check over the *touched* pages (pages holding
        positions < length).
        """
        spec, keys = self.spec, self.keys
        cfg = spec.cfg
        s, p = page_table.shape
        ptab = jnp.where(page_table < 0, spec.scratch_page, page_table)
        flat_ids = ptab.reshape(-1)
        vns = pool.page_vns[flat_ids]
        page_start = (jnp.arange(p, dtype=jnp.int32)
                      * spec.page_tokens)[None, :]
        touched = page_start < lengths[:, None]        # (S, P)

        ok = jnp.asarray(True)
        agg = jnp.zeros((s, p, mac.MAC_BYTES), jnp.uint8)
        dense = []
        for li, leaf in enumerate(spec.leaves):
            ct = pool.cts[li][flat_ids].reshape(s, p, leaf.page_bytes)
            need_macs = cfg.verify != "none"
            if need_macs and _kernel_read_ok(spec):
                pt, macs = _fused_read(spec, leaf,
                                       ct.reshape(-1, leaf.page_bytes),
                                       flat_ids, vns, keys, ctx, uniform)
                pt = pt.reshape(s, p, leaf.page_bytes)
                macs = macs.reshape(s, p, leaf.n_blocks, mac.MAC_BYTES)
            else:
                pt = _crypt(spec, leaf, ct.reshape(-1, leaf.page_bytes),
                            flat_ids, vns, keys, ctx,
                            uniform).reshape(s, p, leaf.page_bytes)
                macs = None
                if need_macs:
                    macs = _page_block_macs(
                        spec, leaf, ct.reshape(-1, leaf.page_bytes), flat_ids,
                        vns, keys, ctx, uniform).reshape(s, p, leaf.n_blocks,
                                                         mac.MAC_BYTES)
            if cfg.verify == "block":
                stored = pool.block_macs[li][flat_ids].reshape(macs.shape)
                ok = ok & jnp.all((macs == stored) | ~touched[..., None, None])
            elif cfg.verify == "layer":
                agg = agg ^ mac.xor_aggregate(macs, axis=2)
            dense.append(_pages_to_dense(spec, leaf, pt, lengths))
        if cfg.verify == "layer":
            stored = pool.page_macs[flat_ids].reshape(s, p, mac.MAC_BYTES)
            ok = ok & jnp.all((agg == stored) | ~touched[..., None])
        if cfg.emulate_tree:
            # Tree/VN traffic is charged for the WINDOW actually
            # gathered — the emulated SGX metadata cost shrinks with
            # the bucket too.
            ok = ok & emulated_tree_probe(
                sum(leaf.n_blocks for leaf in spec.leaves) * s * p)
        return dense, ok

    def write(self, pool: PagedKVPool, page_ids: jax.Array,
              leaf_pages: list, vn, real_mask: jax.Array,
              ctx: PageKeyCtx | None = None,
              uniform: bool = False) -> PagedKVPool:
        """Encrypt + MAC N pages and scatter them into the pool.

        Args:
          page_ids: (N,) int32 destinations (scratch row for masked
            slots — duplicates are only ever the scratch page, so
            last-write-wins is harmless).
          leaf_pages: per paged leaf, (N, steps, page_tokens, *rest).
          vn: scalar uint32 version number for this write event.
          real_mask: (N,) bool — writes that land on real (non-scratch)
            pages and therefore participate in the deferred pool MAC.
          ctx: optional per-page tenant keys (N entries).
        """
        spec, keys = self.spec, self.keys
        cfg = spec.cfg
        n = page_ids.shape[0]
        vns = jnp.broadcast_to(jnp.asarray(vn, jnp.uint32), (n,))
        agg = jnp.zeros((n, mac.MAC_BYTES), jnp.uint8)
        new_cts = []
        new_block_macs = list(pool.block_macs)
        for li, leaf in enumerate(spec.leaves):
            buf = _dense_to_pages(spec, leaf, leaf_pages[li])
            if cfg.verify != "none" and _kernel_write_ok(spec):
                # One fused Pallas pass: encrypt + NH of the fresh
                # ciphertext — the write-side twin of the fused read,
                # for uniform AND mixed-row key selections.
                ct, macs = _fused_write(spec, leaf, buf, page_ids, vns, keys,
                                        ctx, uniform)
            else:
                ct = _crypt(spec, leaf, buf, page_ids, vns, keys, ctx,
                            uniform)
                macs = None
                if cfg.verify != "none":
                    macs = _page_block_macs(spec, leaf, ct, page_ids, vns,
                                            keys, ctx, uniform)
            new_cts.append(pool.cts[li].at[page_ids].set(ct))
            if cfg.verify != "none":
                if cfg.verify == "block":
                    new_block_macs[li] = (
                        pool.block_macs[li].at[page_ids].set(macs))
                agg = agg ^ mac.xor_aggregate(macs, axis=1)
        old_macs = pool.page_macs[page_ids]            # read before scatter
        new_page_macs = pool.page_macs.at[page_ids].set(agg)
        new_vns = pool.page_vns.at[page_ids].set(vns)
        # Deferred model-level MAC: incremental XOR update, O(dirty).
        delta = jnp.where(real_mask[:, None], old_macs ^ agg,
                          jnp.zeros((), jnp.uint8))
        pool_mac = pool.pool_mac ^ mac.xor_aggregate(delta)
        return PagedKVPool(tuple(new_cts), new_page_macs,
                           tuple(new_block_macs), new_vns, pool_mac)

    def write_prefill(self, pool: PagedKVPool, page_ids: jax.Array,
                      dense_leaves: list, n_write_pages: int, vn,
                      ctx: PageKeyCtx | None = None,
                      uniform: bool = False) -> PagedKVPool:
        """Protect the first ``n_write_pages`` pages of one
        freshly-prefilled slot.  ``dense_leaves``: per paged leaf,
        (steps, 1, max_len, *rest).
        """
        spec = self.spec
        ptok = spec.page_tokens
        leaf_pages = []
        for leaf, dense_leaf in zip(spec.leaves, dense_leaves):
            toks = dense_leaf[:, 0, : n_write_pages * ptok]
            pages = toks.reshape((leaf.steps, n_write_pages, ptok)
                                 + leaf.rest)
            leaf_pages.append(jnp.moveaxis(pages, 1, 0))  # (N, steps, ...)
        ids = page_ids[:n_write_pages]
        real = ids < spec.n_pages
        if ctx is not None:
            ctx = ctx.take(n_write_pages)
        return self.write(pool, ids, leaf_pages, vn, real, ctx, uniform)

    def write_dirty(self, pool: PagedKVPool, page_table: jax.Array,
                    dense_leaves: list, lengths: jax.Array,
                    active: jax.Array, vn, ctx: PageKeyCtx | None = None,
                    uniform: bool = False) -> PagedKVPool:
        """Re-encrypt + re-MAC the ONE dirty page per active slot.

        ``lengths`` are the pre-increment lengths: the decode step just
        wrote its token at position ``length``, so the dirty page is
        ``length // page_tokens``.  Inactive slots write to the scratch
        row.

        ``ctx`` (one entry per slot) carries each slot's *current*
        tenant epoch — this is where lazy rotation lands: a page's next
        dirty write re-encrypts it under the new epoch keys.
        """
        spec = self.spec
        s = page_table.shape[0]
        ptok = spec.page_tokens
        dirty = lengths // ptok                        # (S,) page slot-index
        pid = jnp.take_along_axis(page_table, dirty[:, None], axis=1)[:, 0]
        real = active & (pid >= 0)
        pid = jnp.where(real, pid, spec.scratch_page)
        tok_idx = (dirty[:, None] * ptok
                   + jnp.arange(ptok, dtype=jnp.int32)[None])
        leaf_pages = []
        for leaf, dense_leaf in zip(spec.leaves, dense_leaves):
            idx = tok_idx.reshape((1, s, ptok) + (1,) * len(leaf.rest))
            page = jnp.take_along_axis(dense_leaf, idx, axis=2)
            leaf_pages.append(jnp.moveaxis(page, 0, 1))  # (S, steps, ...)
        return self.write(pool, pid, leaf_pages, vn, real, ctx, uniform)


    def read_raw(self, pool: PagedKVPool, page_ids: jax.Array,
                 ctx: PageKeyCtx | None = None, uniform: bool = False):
        """Decrypt + verify N whole pages, returning token payloads.

        Unlike :meth:`read` this is page-shaped, not slot-shaped: it
        returns per paged leaf a (N, steps, page_tokens, *rest) array —
        the exact ``leaf_pages`` layout :meth:`write` consumes — plus
        the AND of every gated MAC check over the *real* pages
        (scratch-page entries are ignored, so callers can pad to a
        bucketed size).  This is the read half of resealing and secure
        migration.
        """
        spec, keys = self.spec, self.keys
        cfg = spec.cfg
        n = page_ids.shape[0]
        vns = pool.page_vns[page_ids]
        real = page_ids < spec.n_pages
        ok = jnp.asarray(True)
        agg = jnp.zeros((n, mac.MAC_BYTES), jnp.uint8)
        out = []
        for li, leaf in enumerate(spec.leaves):
            ct = pool.cts[li][page_ids]
            need_macs = cfg.verify != "none"
            if need_macs and _kernel_read_ok(spec):
                pt, macs = _fused_read(spec, leaf, ct, page_ids, vns, keys,
                                       ctx, uniform)
            else:
                pt = _crypt(spec, leaf, ct, page_ids, vns, keys, ctx,
                            uniform)
                macs = None
                if need_macs:
                    macs = _page_block_macs(spec, leaf, ct, page_ids, vns,
                                            keys, ctx, uniform)
            if cfg.verify == "block":
                stored = pool.block_macs[li][page_ids]
                ok = ok & jnp.all((macs == stored) | ~real[:, None, None])
            elif cfg.verify == "layer":
                agg = agg ^ mac.xor_aggregate(macs, axis=1)
            out.append(_bytes_to_tokens(spec, leaf, pt))
        if cfg.verify == "layer":
            stored = pool.page_macs[page_ids]
            ok = ok & jnp.all((agg == stored) | ~real[:, None])
        if cfg.emulate_tree:
            ok = ok & emulated_tree_probe(
                n * sum(leaf.n_blocks for leaf in spec.leaves))
        return out, ok

    def reseal(self, pool: PagedKVPool, page_ids: jax.Array, vn,
               old_ctx: PageKeyCtx | None = None,
               new_ctx: PageKeyCtx | None = None,
               uniform: bool = False):
        """Decrypt N pages under ``old_ctx`` and re-protect under
        ``new_ctx`` in place — the eager-rotation primitive.

        One fused crossing: gather → decrypt+verify (old keys/epoch
        words) → re-encrypt + re-MAC (new keys/epoch words, fresh
        ``vn``) → scatter back to the SAME page ids.  Plaintext is
        bit-preserved, so decode output is unchanged; the pool/page
        metadata moves to the new epoch without preempting any slot.
        Returns ``(new_pool, ok)`` — the caller must gate on ``ok`` (a
        failed decrypt means the old bytes were tampered; writing their
        reseal would launder them).
        """
        leaf_pages, ok = self.read_raw(pool, page_ids, old_ctx, uniform)
        real = page_ids < self.spec.n_pages
        new_pool = self.write(pool, page_ids, leaf_pages, vn, real, new_ctx,
                              uniform)
        return new_pool, ok

    def copy(self, pool: PagedKVPool, src_ids: jax.Array,
             dst_ids: jax.Array, vn,
             src_ctx: PageKeyCtx | None = None,
             dst_ctx: PageKeyCtx | None = None):
        """Reseal N pages to *different* page ids within one pool.

        The rebinding primitive the prefix cache builds on: decrypt +
        verify the source pages under ``src_ctx``, re-encrypt + re-MAC
        the same plaintext into ``dst_ids`` under ``dst_ctx``.  Cache
        insert copies session pages into cache-bound pages
        (session epoch word → :data:`PREFIX_ROLE`), copy-on-write
        copies a shared cache page back into a private session page,
        and reseal-on-share copies one tenant's cache page into
        another's.  Returns ``(new_pool, ok)``; callers must gate on
        ``ok`` before committing the new pool (a tampered source must
        not be laundered into a freshly-MACed copy).
        """
        return self.migrate(pool, self.spec, pool, src_ids, dst_ids, vn,
                            src_ctx, dst_ctx)

    def migrate(self, src_pool: PagedKVPool, src_spec: PageSpec,
                dst_pool: PagedKVPool, src_ids: jax.Array,
                dst_ids: jax.Array, vn,
                src_ctx: PageKeyCtx | None = None,
                dst_ctx: PageKeyCtx | None = None):
        """Secure page migration: reseal N pages from ``src_pool`` into
        this IO's pool (single-dispatch form, for pools on one device).

        Decrypts under the *source* shard binding (shard id in the RePA
        fmap + CTR words), verifies, then re-encrypts + re-MACs under
        the *destination* binding — the page arrives cryptographically
        pinned to its new device and the old ciphertext is useless
        there.  For pools on different devices, run :meth:`read_raw` on
        the source device, transfer the plaintext leaf pages, and
        :meth:`write` on the destination (what the cluster engine
        does).  Returns ``(new_dst_pool, ok)``.
        """
        dst_spec = self.spec
        if src_spec.leaves != dst_spec.leaves:
            raise ValueError("migration needs identically-laid-out pools")
        leaf_pages, ok = PageIO(src_spec, self.keys).read_raw(
            src_pool, src_ids, src_ctx)
        real = dst_ids < dst_spec.n_pages
        new_dst = self.write(dst_pool, dst_ids, leaf_pages, vn, real,
                             dst_ctx)
        return new_dst, ok


# ---------------------------------------------------------------------------
# Free-function wrappers: the pre-PageIO module API, delegating 1:1.
# ---------------------------------------------------------------------------


def read_pages(pool: PagedKVPool, spec: PageSpec, keys, page_table: jax.Array,
               lengths: jax.Array, ctx: PageKeyCtx | None = None,
               uniform: bool = False):
    """Thin wrapper over :meth:`PageIO.read` (kept for existing callers)."""
    return PageIO(spec, keys).read(pool, page_table, lengths, ctx, uniform)


def write_pages(pool: PagedKVPool, spec: PageSpec, keys, page_ids: jax.Array,
                leaf_pages: list, vn, real_mask: jax.Array,
                ctx: PageKeyCtx | None = None,
                uniform: bool = False) -> PagedKVPool:
    """Thin wrapper over :meth:`PageIO.write` (kept for existing callers)."""
    return PageIO(spec, keys).write(pool, page_ids, leaf_pages, vn,
                                    real_mask, ctx, uniform)


def write_prefill(pool: PagedKVPool, spec: PageSpec, keys,
                  page_ids: jax.Array, dense_leaves: list, n_write_pages: int,
                  vn, ctx: PageKeyCtx | None = None,
                  uniform: bool = False) -> PagedKVPool:
    """Thin wrapper over :meth:`PageIO.write_prefill`."""
    return PageIO(spec, keys).write_prefill(pool, page_ids, dense_leaves,
                                            n_write_pages, vn, ctx, uniform)


def write_dirty(pool: PagedKVPool, spec: PageSpec, keys,
                page_table: jax.Array, dense_leaves: list,
                lengths: jax.Array, active: jax.Array, vn,
                ctx: PageKeyCtx | None = None,
                uniform: bool = False) -> PagedKVPool:
    """Thin wrapper over :meth:`PageIO.write_dirty`."""
    return PageIO(spec, keys).write_dirty(pool, page_table, dense_leaves,
                                          lengths, active, vn, ctx, uniform)


def read_pages_raw(pool: PagedKVPool, spec: PageSpec, keys,
                   page_ids: jax.Array, ctx: PageKeyCtx | None = None,
                   uniform: bool = False):
    """Thin wrapper over :meth:`PageIO.read_raw`."""
    return PageIO(spec, keys).read_raw(pool, page_ids, ctx, uniform)


def reseal_pages(pool: PagedKVPool, spec: PageSpec, keys,
                 page_ids: jax.Array, vn,
                 old_ctx: PageKeyCtx | None = None,
                 new_ctx: PageKeyCtx | None = None,
                 uniform: bool = False):
    """Thin wrapper over :meth:`PageIO.reseal`."""
    return PageIO(spec, keys).reseal(pool, page_ids, vn, old_ctx, new_ctx,
                                     uniform)


def migrate_pages(src_pool: PagedKVPool, src_spec: PageSpec,
                  dst_pool: PagedKVPool, dst_spec: PageSpec, keys,
                  src_ids: jax.Array, dst_ids: jax.Array, vn,
                  src_ctx: PageKeyCtx | None = None,
                  dst_ctx: PageKeyCtx | None = None):
    """Thin wrapper over :meth:`PageIO.migrate`."""
    return PageIO(dst_spec, keys).migrate(src_pool, src_spec, dst_pool,
                                          src_ids, dst_ids, vn, src_ctx,
                                          dst_ctx)


def deferred_pool_check(pool: PagedKVPool, spec: PageSpec) -> jax.Array:
    """Model-level deferred MAC (paper Table I): the XOR of every real
    page MAC must equal the incrementally-maintained pool MAC.  Run off
    the critical path (end of request / every N steps)."""
    return jnp.all(mac.xor_aggregate(pool.page_macs[: spec.n_pages])
                   == pool.pool_mac)


def merkle_leaf_macs(pool: PagedKVPool, spec: PageSpec) -> np.ndarray:
    """Host copy of the real-page MAC rows — the Merkle leaf material.

    This is the single point where the auditable Merkle level
    (:mod:`repro.serve.merkle_pool`, which is deliberately jax-free)
    touches pool state: the scratch row is excluded (it is not part of
    any integrity fold), and quarantined frames are excluded later by
    the maintainer itself, which hashes them to a distinguished
    *retired* leaf regardless of the scrubbed MAC bytes this returns.
    The pull is a tiny ``n_pages x MAC_BYTES`` transfer, only ever run
    at the amortized ``_tick_end`` cadence or on an explicit proof
    request — never on the decode dispatch path.
    """
    return np.asarray(pool.page_macs[: spec.n_pages], np.uint8)


# ---------------------------------------------------------------------------
# PrefixCache: content-addressed index over cache-bound shared pages.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PrefixCacheEntry:
    """One cached prefix chunk: a sealed page + its chain position.

    ``key`` is ``(tenant_index, chain_hash)`` where the chain hash
    covers every token from position 0 through this chunk — a page is
    only reachable by walking its full ancestry, so two prefixes
    collide only if their entire token histories do.  ``n_tokens`` may
    be short of a full page for the chain's leaf chunk (a partially
    filled final page); only leaves may be partial.
    """

    key: tuple
    parent: Optional["PrefixCacheEntry"]
    page_id: int
    n_tokens: int
    refs: int = 0
    last_use: int = 0


class PrefixCache:
    """Host-side content-addressed secure prefix cache.

    Entries index pool pages sealed under the owning tenant's dedicated
    *cache binding* — epoch word :data:`PREFIX_ROLE`, selecting the
    tenant's epoch-independent cache keys (see
    :meth:`repro.tenancy.registry.TenantRegistry.cache_row`).  A page
    sealed once is verify-read by every session that matches its chain
    (VN-stable: shared reads never re-MAC), and keys are per tenant, so
    a match can only ever hand a session pages its own tenant sealed —
    cross-tenant sharing must go through the engine's explicit
    reseal-on-share.

    **Keying.**  Token streams are chunked page-sized; chunk ``i``'s
    chain hash is ``H(chain[i-1] ‖ tokens_i)``.  Lookup walks the chain
    from chunk 0 and returns the longest fully-matched entry run (plus,
    after the last full chunk, the longest matching *partial* leaf), so
    a hit is always a page-aligned prefix of the slot's context — the
    windows-are-prefixes invariant of :class:`TwoLevelPageTable` holds
    with zero new window shapes.

    **Lifecycle.**  Slots ``acquire`` the whole matched chain (every
    ancestor's refcount rises, so a parent's refcount always dominates
    its children's) and ``release`` it on finish/preempt/CoW.  Eviction
    (``reclaim``) is LRU over refcount-zero *leaf* entries — the
    dominance invariant means cascading from the leaves can never
    strand a referenced descendant.

    The cache stores page *ids* only; sealing bytes in and out of those
    pages is the engine's job via :class:`PageIO`.
    """

    def __init__(self, page_tokens: int, capacity_pages: int):
        if capacity_pages < 1:
            raise ValueError("prefix cache needs capacity >= 1 page")
        self.page_tokens = page_tokens
        self.capacity_pages = capacity_pages
        self._entries: dict[tuple, PrefixCacheEntry] = {}
        self._children: dict[tuple, set] = {}
        self._clock = 0

    # -- chain hashing ------------------------------------------------------

    @staticmethod
    def _chain_hash(parent_hash: bytes, chunk) -> bytes:
        buf = np.asarray(list(chunk), np.uint32).tobytes()
        return hashlib.sha256(parent_hash + buf).digest()

    def _chain(self, tokens):
        """Page-sized chunks of ``tokens`` with their chain hashes:
        list of ``(hash, n_tokens)``; only the last may be partial."""
        out, h = [], b""
        ptok = self.page_tokens
        for start in range(0, len(tokens), ptok):
            chunk = tokens[start: start + ptok]
            h = self._chain_hash(h, chunk)
            out.append((h, len(chunk)))
        return out

    # -- lookup / refcounts -------------------------------------------------

    def match(self, tenant_index: int, tokens) -> list:
        """Longest cached chain covering a prefix of ``tokens``.

        Pure (no refcount/LRU side effects).  Walks full page-sized
        chunks first; after the first miss, probes partial leaves of
        the next chunk longest-first, so an exact-length partial page
        cached by a shorter prompt still hits.
        """
        matched, h = [], b""
        ptok = self.page_tokens
        consumed = 0
        while consumed < len(tokens):
            chunk = tokens[consumed: consumed + ptok]
            full_h = self._chain_hash(h, chunk)
            entry = self._entries.get((tenant_index, full_h))
            if entry is not None and entry.n_tokens == len(chunk):
                matched.append(entry)
                h = full_h
                consumed += len(chunk)
                continue
            for c in range(len(chunk) - 1, 0, -1):
                part_h = self._chain_hash(h, chunk[:c])
                entry = self._entries.get((tenant_index, part_h))
                if entry is not None and entry.n_tokens == c:
                    matched.append(entry)
                    break
            break
        return matched

    def match_tokens(self, tenant_index: int, tokens) -> int:
        """Tokens a :meth:`match` would cover (cluster routing metric)."""
        return sum(e.n_tokens for e in self.match(tenant_index, tokens))

    def missing(self, tenant_index: int, tokens):
        """Insertion plan after the longest match: ``(matched,
        missing)`` where ``missing`` is ``[(key, n_tokens), ...]`` for
        the chunks a full-chain insert still needs, in chain order."""
        matched = self.match(tenant_index, tokens)
        covered = sum(e.n_tokens for e in matched)
        if matched and matched[-1].n_tokens % self.page_tokens:
            return matched, []          # partial leaf: chain can't extend
        h = matched[-1].key[1] if matched else b""
        missing = [((tenant_index, ch), n)
                   for ch, n in self._chain(tokens[covered:])]
        return matched, missing

    def acquire(self, entries) -> None:
        """Pin a matched chain: every entry's refcount rises by one
        (ancestors included, preserving refcount dominance)."""
        self._clock += 1
        for e in entries:
            e.refs += 1
            e.last_use = self._clock

    def release(self, entries) -> None:
        for e in entries:
            if e.refs <= 0:
                raise RuntimeError(f"prefix-cache refcount underflow on "
                                   f"{e.key[1].hex()[:12]}")
            e.refs -= 1

    # -- insertion / eviction -----------------------------------------------

    def insert(self, key: tuple, parent: Optional[PrefixCacheEntry],
               page_id: int, n_tokens: int) -> PrefixCacheEntry:
        """Index a freshly cache-sealed page under its chain key.

        The caller has already copied the page's bytes into
        ``page_id`` under the cache binding (:meth:`PageIO.copy`); the
        cache only tracks ownership.  New entries start unreferenced —
        the inserting slot keeps decoding on its private pages.
        """
        if key in self._entries:
            raise ValueError("prefix chunk already cached")
        if len(self._entries) >= self.capacity_pages:
            raise ValueError("prefix cache over capacity — reclaim first")
        if parent is not None and parent.n_tokens % self.page_tokens:
            raise ValueError("cannot extend a partial (leaf) chunk")
        entry = PrefixCacheEntry(key=key, parent=parent, page_id=page_id,
                                 n_tokens=n_tokens)
        self._clock += 1
        entry.last_use = self._clock
        self._entries[key] = entry
        if parent is not None:
            self._children.setdefault(parent.key, set()).add(key)
        return entry

    @property
    def pages_used(self) -> int:
        return len(self._entries)

    @property
    def total_refs(self) -> int:
        """Total refcount pins across entries (gauge exposition)."""
        return sum(e.refs for e in self._entries.values())

    def free_capacity(self) -> int:
        return self.capacity_pages - len(self._entries)

    def _evict(self, entry: PrefixCacheEntry) -> None:
        del self._entries[entry.key]
        if entry.parent is not None:
            kids = self._children.get(entry.parent.key)
            if kids is not None:
                kids.discard(entry.key)
                if not kids:
                    del self._children[entry.parent.key]

    def reclaim(self, n_pages: int) -> list:
        """Evict up to ``n_pages`` unreferenced entries, LRU leaf-first
        (refcount dominance makes leaf-first cascade-safe); returns the
        freed page ids for the engine to reuse."""
        freed = []
        while len(freed) < n_pages:
            cands = [e for e in self._entries.values()
                     if e.refs == 0 and not self._children.get(e.key)]
            if not cands:
                break
            victim = min(cands, key=lambda e: e.last_use)
            self._evict(victim)
            freed.append(victim.page_id)
        return freed

    def evict_pages(self, page_ids) -> int:
        """Drop every entry holding one of ``page_ids`` — plus its
        descendants, unreachable without their ancestor — from the
        index regardless of refcounts: the quarantine path.  A page
        whose physical frame was retired must never satisfy a future
        match.  Slots already pinned keep their entry objects
        (:meth:`release` operates on the objects, not the index); the
        chain simply stops being discoverable.  Returns the number of
        entries dropped."""
        bad = {int(p) for p in page_ids}
        dropped, progress = 0, True
        while progress:
            progress = False
            for e in list(self._entries.values()):
                orphaned = (e.parent is not None
                            and e.parent.key not in self._entries)
                if e.page_id in bad or orphaned:
                    self._evict(e)
                    dropped += 1
                    progress = True
        return dropped

    def flush(self, tenant_index: Optional[int] = None) -> list:
        """Evict every unreferenced entry (optionally one tenant's) —
        the revocation path for the epoch-independent cache binding.
        Returns the freed page ids; referenced chains survive."""
        freed, progress = [], True
        while progress:
            progress = False
            for e in list(self._entries.values()):
                if tenant_index is not None and e.key[0] != tenant_index:
                    continue
                if e.refs == 0 and not self._children.get(e.key):
                    self._evict(e)
                    freed.append(e.page_id)
                    progress = True
        return freed
