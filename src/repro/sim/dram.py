"""Ramulator-lite: off-chip memory timing + end-to-end performance model.

Per layer: time = max(compute cycles, DRAM cycles) — the systolic array
double-buffers, so compute and DRAM streaming overlap and the slower
side wins.  Security adds (a) extra DRAM bytes (metadata/overfetch) and
(b) a per-layer verification drain that cannot overlap the next layer
when the scheme gates on it.

The DRAM efficiency factor models channel/bank scheduling losses
(Ramulator's achievable vs. peak bandwidth for streaming DNN traces).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.sim.memprot import SCHEME_MODELS, WorkloadSecurityResult
from repro.sim.npu_configs import NPUConfig
from repro.sim.scalesim import WorkloadTrace

__all__ = ["DramModel", "PerfResult", "performance"]

DRAM_EFFICIENCY = 0.75      # achievable fraction of peak streaming BW
DRAM_LATENCY_CYCLES = 100   # first-access latency (per layer drain)
TREE_WALK_LATENCY = 4 * DRAM_LATENCY_CYCLES  # serial tree-level walks


@dataclass(frozen=True)
class DramModel:
    npu: NPUConfig

    def cycles_for(self, n_bytes: float) -> float:
        eff_bw = self.npu.bytes_per_cycle * DRAM_EFFICIENCY
        return n_bytes / max(eff_bw, 1e-9)


@dataclass(frozen=True)
class PerfResult:
    scheme: str
    cycles: float
    baseline_cycles: float

    @property
    def slowdown(self) -> float:
        return self.cycles / self.baseline_cycles - 1.0

    @property
    def normalized_performance(self) -> float:
        return self.baseline_cycles / self.cycles


def performance(trace: WorkloadTrace, security: WorkloadSecurityResult,
                npu: NPUConfig) -> PerfResult:
    dram = DramModel(npu)
    scheme = SCHEME_MODELS[security.scheme]

    baseline_cycles = 0.0
    protected_cycles = 0.0
    for layer_trace, sec in zip(trace.layers, security.layers):
        base_bytes = layer_trace.total_bytes
        base = max(layer_trace.compute_cycles, dram.cycles_for(base_bytes))
        baseline_cycles += base + DRAM_LATENCY_CYCLES

        prot = max(layer_trace.compute_cycles, dram.cycles_for(sec.total))
        # Verification drain: per-block-gated schemes stall on the tree
        # walk / MAC fetch for the first accesses of the layer; SeDA's
        # layer-MAC check is one XOR compare folded into the layer end.
        if scheme.integrity_tree:
            drain = TREE_WALK_LATENCY
        elif scheme.mac_offchip:
            drain = 2 * DRAM_LATENCY_CYCLES
        elif scheme.layer_mac_offchip:
            drain = DRAM_LATENCY_CYCLES + 1
        else:
            drain = DRAM_LATENCY_CYCLES
        protected_cycles += prot + drain

    return PerfResult(security.scheme, protected_cycles, baseline_cycles)
