"""Sharded secure serving: a multi-device cluster of paged KV pools.

    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
    PYTHONPATH=src python examples/sharded_serving.py

Two shard engines — each a full continuous-batching engine with its
own MAC-protected page pool, pinned to its own device — serve one
request stream behind a cluster scheduler:

* every page's RePA binding and CTR counter carry the shard id, so a
  byte-identical page (ciphertext + MAC + VN) captured on shard 0 and
  replayed into shard 1's pool fails verification — demonstrated
  below;
* per-shard deferred pool MACs roll up into a cluster root MAC,
  checked off the critical path;
* when one shard starves while another has room, a running slot's
  pages MIGRATE: decrypted + verified under the source shard's
  binding, re-encrypted + re-MACed under the destination's — no
  eviction, no prefill recompute.
"""

import os
import sys

if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2"
                               ).strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                     # noqa: E402
import numpy as np                             # noqa: E402

from repro.configs import get_arch             # noqa: E402
from repro.models import lm as lm_mod          # noqa: E402
from repro.models.layers import init_params    # noqa: E402
from repro.serve.cluster import ClusterEngine  # noqa: E402
from repro.serve.engine import IntegrityError  # noqa: E402


def main() -> None:
    arch = get_arch("minitron-4b")
    cfg = arch.make_smoke_config()
    print(f"=== sharded secure serving: {cfg.name} on "
          f"{jax.local_device_count()} devices ===")
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)

    cluster = ClusterEngine(arch, cfg, params, shards=2, scheme="seda",
                            max_slots=2, page_tokens=4, pages_per_slot=8,
                            n_pages=8)
    print(f"cluster: {cluster.sharded.n_shards} shards x "
          f"{cluster.engines[0].n_pages} pages, devices "
          f"{[str(d) for d in cluster.devices]}")

    # Two long decodes land on shard 0, one short on shard 1; when the
    # short one drains, shard 0's pressure migrates a slot over.
    long_a = list(map(int, rng.integers(1, cfg.vocab, 5)))
    short = list(map(int, rng.integers(1, cfg.vocab, 7)))
    long_b = list(map(int, rng.integers(1, cfg.vocab, 9)))
    rids = [cluster.submit(prompt=long_a, max_new_tokens=20),
            cluster.submit(prompt=short, max_new_tokens=2),
            cluster.submit(prompt=long_b, max_new_tokens=20)]
    done = cluster.run()
    stats = cluster.engine_stats
    for rid in rids:
        print(f"  rid {rid}: {len(done[rid].generated)} tokens, "
              f"{done[rid].n_evictions} evictions")
    print(f"cluster: {cluster.stats['migrations']} secure migrations, "
          f"{stats['preemptions']} preemptions, "
          f"{stats['admitted']} admissions (one per request: nothing "
          f"was recomputed), root MAC "
          f"{'OK' if cluster.deferred_check() else 'FAIL'}")
    assert cluster.deferred_check()
    assert stats["preemptions"] == 0 and stats["admitted"] == len(rids)

    # --- cross-shard replay: byte-identical page swapped between shards --
    cl2 = ClusterEngine(arch, cfg, params, shards=2, scheme="seda",
                        max_slots=1, page_tokens=4, pages_per_slot=4)
    cl2.submit(prompt=long_a, max_new_tokens=6)
    cl2.submit(prompt=long_b, max_new_tokens=6)
    cl2.step()
    e0, e1 = cl2.engines
    s0 = next(s for s in e0.slots if s is not None)
    s1 = next(s for s in e1.slots if s is not None)
    pid0, pid1 = s0.pages[0], s1.pages[0]
    # Ciphertext, page MAC and VN all copied verbatim — on one device
    # this replay would verify; the shard-bound binding rejects it.
    e1.pool = e1.pool._replace(
        cts=tuple(c1.at[pid1].set(jax.device_put(c0[pid0], e1._device))
                  for c0, c1 in zip(e0.pool.cts, e1.pool.cts)),
        page_macs=e1.pool.page_macs.at[pid1].set(
            jax.device_put(e0.pool.page_macs[pid0], e1._device)),
        page_vns=e1.pool.page_vns.at[pid1].set(
            jax.device_put(e0.pool.page_vns[pid0], e1._device)))
    try:
        cl2.step()
        raise AssertionError("cross-shard replay was NOT rejected")
    except IntegrityError as e:
        print(f"cross-shard page replay rejected as designed: {e}")
    print("=== sharded_serving OK ===")


if __name__ == "__main__":
    main()
