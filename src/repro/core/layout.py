"""Physical-address assignment for pytrees crossing the untrusted boundary.

The AES-CTR counter and every MAC binding need a stable *physical
address* per protected block.  We model the accelerator's DMA address
map: leaves of a pytree are laid out in deterministic
``jax.tree_util`` order, each aligned to the protection block size.

Addresses are byte addresses in units of 16B segments (so PA increments
by ``block_bytes // 16`` between consecutive wide blocks, matching the
per-segment counter advance of T-AES).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax

from repro.core.bytesutil import TensorSpec

__all__ = ["LeafLayout", "AddressMap", "build_address_map"]

SEGMENT_BYTES = 16


class LeafLayout(NamedTuple):
    path: str
    spec: TensorSpec
    pa_base: int          # in 16B-segment units
    padded_bytes: int     # layout footprint (aligned to block_bytes)
    layer_id: int         # paper's layer_id binding
    fmap_idx: int         # index of the tensor within its layer


class AddressMap(NamedTuple):
    leaves: tuple
    total_bytes: int
    block_bytes: int

    def by_path(self) -> dict:
        return {l.path: l for l in self.leaves}


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def build_address_map(tree: Any, *, block_bytes: int = 64,
                      layer_of=None) -> AddressMap:
    """Assign PAs to every leaf of ``tree``.

    Args:
      tree: pytree of arrays or ShapeDtypeStructs.
      block_bytes: protection granularity (optBlk size).
      layer_of: optional ``path_str -> layer_id`` mapping function; by
        default each top-level key of the tree is a "layer" (matching
        the paper's per-DNN-layer MAC grouping).

    Returns an AddressMap with deterministic, stable ordering.
    """
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    if layer_of is None:
        top_keys: dict[str, int] = {}

        def layer_of(path_str: str) -> int:  # noqa: F811 - intentional default
            top = path_str.split("]")[0] + "]" if "]" in path_str else path_str
            return top_keys.setdefault(top, len(top_keys))

    layouts = []
    cursor = 0
    fmap_counters: dict[int, int] = {}
    for path, leaf in leaves_with_paths:
        spec = TensorSpec.of(leaf)
        padded = (spec.nbytes + block_bytes - 1) // block_bytes * block_bytes
        path_s = _path_str(path)
        lid = int(layer_of(path_s))
        fmap = fmap_counters.get(lid, 0)
        fmap_counters[lid] = fmap + 1
        layouts.append(LeafLayout(path_s, spec, cursor // SEGMENT_BYTES,
                                  padded, lid, fmap))
        cursor += padded
    return AddressMap(tuple(layouts), cursor, block_bytes)
