"""repro — SeDA (Secure and Efficient DNN Accelerators) as a multi-pod
JAX/Pallas framework.

Public API surface:

    from repro import configs            # the 10 assigned architectures
    from repro.core import SecureExecutor, SecureKeys, protect, unprotect
    from repro.checkpoint.secure_ckpt import save_checkpoint, load_checkpoint
    from repro.launch.mesh import make_production_mesh
    from repro.launch.cells import build_cell

Entry points:

    python -m repro.launch.train     # training driver (--arch ... --scheme seda)
    python -m repro.launch.serve     # serving driver
    python -m repro.launch.dryrun    # multi-pod dry-run sweep
    python -m repro.launch.roofline  # roofline report
    python -m repro.launch.hillclimb # §Perf variant measurement
"""

__version__ = "1.0.0"
