"""Benchmark harness (deliverable d): one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only fig5]
"""

from __future__ import annotations

import argparse
import sys
import traceback
import types

from benchmarks import (bench_area_power, bench_audit_proofs, bench_chaos,
                        bench_crypt_kernels, bench_memory_traffic,
                        bench_multi_tenant, bench_performance,
                        bench_secure_serving, bench_secure_step,
                        bench_sharded_serving, bench_table3)

SUITES = {
    "fig4_area_power": bench_area_power,
    "fig5_memory_traffic": bench_memory_traffic,
    "fig6_performance": bench_performance,
    "table3_schemes": bench_table3,
    "crypt_kernels": bench_crypt_kernels,
    "secure_step": bench_secure_step,
    "secure_serving": bench_secure_serving,
    "decode_scaling": types.SimpleNamespace(
        run=bench_secure_serving.run_decode_scaling),
    "multi_tenant_serving": bench_multi_tenant,
    "sharded_serving": bench_sharded_serving,
    "chaos": bench_chaos,
    "audit_proofs": bench_audit_proofs,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on suite name")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failed = []
    for suite_name, mod in SUITES.items():
        if args.only and args.only not in suite_name:
            continue
        try:
            for row in mod.run():
                derived = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            failed.append(suite_name)
            traceback.print_exc()
            print(f"{suite_name},ERROR,{type(e).__name__}: {e}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
