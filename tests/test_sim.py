"""Simulator substrate: paper-claim reproduction + model properties."""

import statistics

import pytest
from _hyp import given, settings, st

from repro.sim.area_power import b_aes_cost, scaling_table, t_aes_cost
from repro.sim.caches import LRUCache
from repro.sim.dram import performance
from repro.sim.memprot import SCHEME_MODELS, overlay_scheme
from repro.sim.npu_configs import EDGE_NPU, NPUS, SERVER_NPU
from repro.sim.scalesim import simulate_workload
from repro.sim.secureloop import (CANDIDATE_BLOCKS, optimal_block_cross_layer,
                                  optimal_block_for_streams)
from repro.sim.workloads import WORKLOADS


def _mean_overhead(npu, scheme):
    vals = []
    for w in WORKLOADS.values():
        tr = simulate_workload(w, npu)
        vals.append(overlay_scheme(tr, scheme, npu).traffic_overhead)
    return statistics.mean(vals)


def _mean_slowdown(npu, scheme):
    vals = []
    for w in WORKLOADS.values():
        tr = simulate_workload(w, npu)
        sec = overlay_scheme(tr, scheme, npu)
        vals.append(performance(tr, sec, npu).slowdown)
    return statistics.mean(vals)


class TestPaperClaims:
    """Reproduction of the paper's §IV headline numbers (tolerances in
    EXPERIMENTS.md; the sim is analytic, the paper's is cycle-level)."""

    def test_workload_count_matches_paper(self):
        assert len(WORKLOADS) == 13

    @pytest.mark.parametrize("npu_name,expected", [
        ("server", 0.30), ("edge", 0.2829)])
    def test_sgx64_traffic(self, npu_name, expected):
        got = _mean_overhead(NPUS[npu_name], "sgx64")
        assert abs(got - expected) < 0.05

    @pytest.mark.parametrize("npu_name,expected", [
        ("server", 0.1251), ("edge", 0.1263)])
    def test_mgx64_traffic(self, npu_name, expected):
        got = _mean_overhead(NPUS[npu_name], "mgx64")
        assert abs(got - expected) < 0.02

    @pytest.mark.parametrize("npu_name", ["server", "edge"])
    def test_seda_traffic_near_zero(self, npu_name):
        """Paper: +0.12% (server) / +0.03% (edge)."""
        got = _mean_overhead(NPUS[npu_name], "seda")
        assert 0.0 <= got < 0.005

    @pytest.mark.parametrize("npu_name", ["server", "edge"])
    def test_scheme_ordering(self, npu_name):
        """Fig. 5/6 ordering: sgx64 > sgx512/mgx64 > mgx512 > seda."""
        npu = NPUS[npu_name]
        ov = {s: _mean_overhead(npu, s)
              for s in ("sgx64", "sgx512", "mgx64", "mgx512", "seda")}
        assert ov["sgx64"] > ov["mgx64"] > ov["mgx512"] > ov["seda"]
        assert ov["sgx64"] > ov["sgx512"] > ov["seda"]

    @pytest.mark.parametrize("npu_name", ["server", "edge"])
    def test_seda_improvement_over_mgx64_exceeds_12pct(self, npu_name):
        """Abstract: SeDA decreases performance overhead by >12% vs the
        64B state of the art (12.26% server / 12.29% edge)."""
        npu = NPUS[npu_name]
        improvement = _mean_slowdown(npu, "mgx64") - _mean_slowdown(npu, "seda")
        assert improvement > 0.12

    def test_seda_slowdown_below_1pct(self):
        for npu in (SERVER_NPU, EDGE_NPU):
            assert _mean_slowdown(npu, "seda") < 0.01


class TestAreaPower:
    def test_b_aes_scaling_nearly_flat(self):
        """Fig. 4: B-AES area/power grow sub-10% while T-AES grows 16x."""
        t1, t16 = t_aes_cost(1), t_aes_cost(16)
        b1, b16 = b_aes_cost(1), b_aes_cost(16)
        assert t16.area_mm2 / t1.area_mm2 == pytest.approx(16.0)
        assert b16.area_mm2 / b1.area_mm2 < 1.75
        assert b16.power_mw / b1.power_mw < 1.25
        assert t16.power_mw / t1.power_mw == pytest.approx(16.0)

    def test_equal_at_multiple_1(self):
        assert t_aes_cost(1).area_mm2 == b_aes_cost(1).area_mm2

    def test_savings_monotonic(self):
        rows = scaling_table(16)
        savings = [r["area_saving"] for r in rows]
        assert savings == sorted(savings)
        assert savings[-1] > 0.85


class TestSecureLoop:
    def test_optblk_in_candidates(self):
        npu = SERVER_NPU
        for w in ("resnet18", "mobilenet", "transformer_fwd"):
            tr = simulate_workload(WORKLOADS[w], npu)
            for lt in tr.layers:
                g = optimal_block_for_streams(lt.streams, npu)
                assert g in CANDIDATE_BLOCKS

    def test_cross_layer_serves_both_patterns(self):
        npu = SERVER_NPU
        tr = simulate_workload(WORKLOADS["resnet18"], npu)
        g = optimal_block_cross_layer(tr.layers[0], tr.layers[1], npu)
        assert g in CANDIDATE_BLOCKS

    def test_embed_like_streams_prefer_small_blocks(self):
        npu = SERVER_NPU
        tr = simulate_workload(WORKLOADS["lenet"], npu)
        # Tiny layers must not choose 4KB blocks (overfetch dominates).
        for lt in tr.layers:
            g = optimal_block_for_streams(lt.streams, npu)
            assert g <= 1024


class TestLRUCache:
    def test_hit_after_fill(self):
        c = LRUCache(capacity_bytes=128, line_bytes=64)
        assert not c.access(0)
        assert c.access(63)       # same line
        assert not c.access(64)   # second line
        assert c.access(0)        # still resident

    def test_eviction_order(self):
        c = LRUCache(capacity_bytes=128, line_bytes=64)
        c.access(0)
        c.access(64)
        c.access(128)  # evicts line 0
        assert not c.access(0)

    def test_writeback_count(self):
        c = LRUCache(capacity_bytes=64, line_bytes=64)
        c.access(0, write=True)
        c.access(64)  # evicts dirty line
        assert c.stats.writebacks == 1

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(0, 1023), min_size=1, max_size=200))
    def test_miss_rate_bounded_by_unique_lines(self, addrs):
        c = LRUCache(capacity_bytes=1 << 20, line_bytes=64)  # everything fits
        for a in addrs:
            c.access(a)
        unique = len({a // 64 for a in addrs})
        assert c.stats.misses == unique


class TestScaleSim:
    def test_traffic_positive_and_finite(self):
        for npu in (SERVER_NPU, EDGE_NPU):
            for w in WORKLOADS.values():
                tr = simulate_workload(w, npu)
                assert tr.total_bytes > 0
                assert tr.compute_cycles > 0

    def test_edge_rereads_more_than_server(self):
        """480KB SRAM forces re-fetch passes the 24MB server avoids."""
        w = WORKLOADS["alexnet"]
        server = simulate_workload(w, SERVER_NPU).total_bytes
        edge = simulate_workload(w, EDGE_NPU).total_bytes
        assert edge >= server

    def test_baseline_scheme_adds_nothing(self):
        npu = SERVER_NPU
        tr = simulate_workload(WORKLOADS["resnet18"], npu)
        res = overlay_scheme(tr, "baseline", npu)
        assert res.traffic_overhead == pytest.approx(0.0)
