"""Deterministic, resumable synthetic token pipeline.

Production framing: every batch is derived purely from (seed, step), so
(a) any worker can regenerate any batch — preemption-safe restarts need
only the step counter from the checkpoint manifest, and (b) elastic
re-scaling replays the exact token stream on a different host count.

Optionally each batch is authenticated at ingest with the SeDA MAC
(the data pipeline crosses the untrusted boundary too).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "make_batch"]


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "lm"            # lm | vlm | encdec
    n_image_patches: int = 0
    d_vision: int = 0
    d_model: int = 0            # encdec frame-embedding dim
    src_len: int = 0


def _tokens_for_step(cfg: DataConfig, step: int) -> np.ndarray:
    """Markov-ish synthetic tokens: deterministic in (seed, step)."""
    rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
    base = rng.integers(0, cfg.vocab, size=(cfg.global_batch, cfg.seq_len + 1),
                        dtype=np.int64)
    # Inject learnable structure: every even position repeats its
    # predecessor with p=0.5 (so tiny models show loss decreasing).
    repeat = rng.random((cfg.global_batch, cfg.seq_len + 1)) < 0.5
    repeat[:, 0] = False
    out = base.copy()
    for _ in range(1):
        shifted = np.roll(out, 1, axis=1)
        out = np.where(repeat, shifted, out)
    return out.astype(np.int32)


def make_batch(cfg: DataConfig, step: int) -> dict:
    """Batch for ``step`` (pure function of config + step)."""
    toks = _tokens_for_step(cfg, step)
    batch = {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
    }
    if cfg.kind == "vlm":
        rng = np.random.default_rng(np.uint64(cfg.seed * 7_000_003 + step))
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((cfg.global_batch, cfg.n_image_patches,
                                 cfg.d_vision), dtype=np.float32))
        # Labels cover text positions only (image prefix handled in loss).
    if cfg.kind == "encdec":
        rng = np.random.default_rng(np.uint64(cfg.seed * 9_000_003 + step))
        batch = {
            "src_embeds": jnp.asarray(rng.standard_normal(
                (cfg.global_batch, cfg.src_len, cfg.d_model),
                dtype=np.float32)),
            "tgt_tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
    return batch


class SyntheticLM:
    """Stateful iterator facade with O(1) checkpoint/restore."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        batch = make_batch(self.cfg, self.step)
        self.step += 1
        return batch

    # -- checkpoint integration ------------------------------------------

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "data seed mismatch on resume"
        self.step = int(state["step"])
