"""AES-128 + CTR mode against official vectors, plus properties."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import aes, ctr


def _hex(b) -> str:
    return bytes(np.asarray(b)).hex()


class TestFIPS197:
    def test_appendix_b_vector(self):
        key = np.arange(16, dtype=np.uint8)  # 000102...0f
        pt = np.frombuffer(bytes.fromhex("00112233445566778899aabbccddeeff"),
                           dtype=np.uint8)
        rks = aes.key_expansion_np(key)
        ct = aes.aes128_encrypt(jnp.asarray(pt)[None], jnp.asarray(rks))[0]
        assert _hex(ct) == "69c4e0d86a7b0430d8cdb78070b4c55a"

    def test_sp800_38a_ecb_block(self):
        key = np.frombuffer(bytes.fromhex("2b7e151628aed2a6abf7158809cf4f3c"),
                            dtype=np.uint8)
        pt = np.frombuffer(bytes.fromhex("6bc1bee22e409f96e93d7e117393172a"),
                           dtype=np.uint8)
        rks = aes.key_expansion_np(key)
        ct = aes.aes128_encrypt(jnp.asarray(pt)[None], jnp.asarray(rks))[0]
        assert _hex(ct) == "3ad77bb40d7a3660a89ecaf32466ef97"

    def test_key_expansion_traced_matches_numpy(self):
        key = np.frombuffer(bytes.fromhex("2b7e151628aed2a6abf7158809cf4f3c"),
                            dtype=np.uint8)
        want = aes.key_expansion_np(key)
        got = np.asarray(aes.key_expansion(jnp.asarray(key)))
        assert (got == want).all()

    def test_fips_key_expansion_first_round_keys(self):
        # FIPS-197 A.1: key 2b7e...3c -> w4..w7 = a0fafe17 88542cb1 ...
        key = np.frombuffer(bytes.fromhex("2b7e151628aed2a6abf7158809cf4f3c"),
                            dtype=np.uint8)
        rks = aes.key_expansion_np(key)
        assert rks[1].tobytes().hex() == (
            "a0fafe1788542cb123a339392a6c7605")


class TestCTR:
    def test_sp800_38a_ctr_keystream(self):
        # SP 800-38A F.5.1: CTR-AES128 with counter f0f1...ff.
        key = np.frombuffer(bytes.fromhex("2b7e151628aed2a6abf7158809cf4f3c"),
                            dtype=np.uint8)
        counter = bytes.fromhex("f0f1f2f3f4f5f6f7f8f9fafbfcfdfeff")
        rks = jnp.asarray(aes.key_expansion_np(key))
        pt = np.frombuffer(bytes.fromhex("6bc1bee22e409f96e93d7e117393172a"),
                           dtype=np.uint8)
        otp = aes.aes128_encrypt(
            jnp.asarray(np.frombuffer(counter, np.uint8))[None], rks)[0]
        ct = np.asarray(otp) ^ pt
        assert ct.tobytes().hex() == "874d6191b620e3261bef6864990db6ce"

    def test_counter_block_layout_big_endian(self):
        words = jnp.asarray([[0, 1, 0, 0x0102]], dtype=jnp.uint32)
        blk = np.asarray(ctr.counter_blocks(words))[0]
        assert list(blk) == [0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0, 1, 2]

    def test_roundtrip(self, keys, rng):
        data = jnp.asarray(rng.integers(0, 256, 160, dtype=np.uint8))
        enc = ctr.ctr_encrypt(data, keys.round_keys, jnp.uint32(0),
                              jnp.uint32(7), jnp.uint32(0), jnp.uint32(3))
        dec = ctr.ctr_decrypt(enc, keys.round_keys, jnp.uint32(0),
                              jnp.uint32(7), jnp.uint32(0), jnp.uint32(3))
        assert (np.asarray(dec) == np.asarray(data)).all()
        assert not (np.asarray(enc) == np.asarray(data)).all()

    def test_distinct_counters_distinct_pads(self, keys):
        segs = ctr._segment_counters(64, jnp.uint32(0), jnp.uint32(0),
                                     jnp.uint32(0), jnp.uint32(9))
        otps = np.asarray(ctr.ctr_keystream(keys.round_keys, segs))
        assert len({bytes(o) for o in otps}) == 64

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
    def test_roundtrip_property(self, pa, vn):
        keys = __import__("repro.core.secure_memory",
                          fromlist=["SecureKeys"]).SecureKeys.derive(7)
        data = jnp.asarray(np.arange(48, dtype=np.uint8))
        enc = ctr.ctr_encrypt(data, keys.round_keys, jnp.uint32(0),
                              jnp.uint32(pa), jnp.uint32(0), jnp.uint32(vn))
        dec = ctr.ctr_decrypt(enc, keys.round_keys, jnp.uint32(0),
                              jnp.uint32(pa), jnp.uint32(0), jnp.uint32(vn))
        assert (np.asarray(dec) == np.asarray(data)).all()
