"""Public wrapper: full B-AES encryption path built from the two kernels.

``baes_encrypt_kernel`` = AES-CTR keystream kernel (1 AES per wide
block) + fused diversify/XOR kernel — the complete Crypt Engine of
Fig. 3(a), validated against :func:`repro.core.baes.baes_encrypt`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import baes
from repro.core.bytesutil import bytes_to_u32, u32_to_bytes
from repro.kernels.aes_ctr.ops import keystream_lanes
from repro.kernels.otp_xor.kernel import otp_xor

__all__ = ["otp_xor", "baes_encrypt_kernel"]


def _div_lanes(round_keys: jax.Array, n_segments: int) -> jax.Array:
    """Diversifiers as (S, 4) uint32 lanes (row 0 = zeros)."""
    div_u8 = baes.diversifiers(round_keys, n_segments)  # (S, 16) u8
    return jax.lax.bitcast_convert_type(
        div_u8.reshape(n_segments, 4, 4), jnp.uint32)


def baes_encrypt_kernel(plaintext_u8: jax.Array, round_keys: jax.Array,
                        counter_words: jax.Array, *, block_bytes: int,
                        subbytes: str = "take",
                        interpret: bool | None = None) -> jax.Array:
    """Kernel-backed B-AES over a flat uint8 buffer (len % block_bytes == 0).

    Narrow mode only (block_bytes <= 176, i.e. segments <= 11); wide
    mode derives per-block key schedules and stays on the pure-jnp path.
    """
    n_segments = block_bytes // 16
    if n_segments - 1 > 10:
        raise ValueError("kernel path supports narrow mode (<= 11 segments); "
                         "use repro.core.baes for wide mode")
    base = keystream_lanes(counter_words, round_keys, subbytes=subbytes,
                           interpret=interpret)            # (N, 4) u32
    data = bytes_to_u32(plaintext_u8).reshape(-1, n_segments * 4)
    div = _div_lanes(round_keys, n_segments)
    ct = otp_xor(data, base, div, interpret=interpret)
    return u32_to_bytes(ct.reshape(-1)).reshape(plaintext_u8.shape)
