"""Gradient compression with error feedback (1000+-node bandwidth trick).

Before the data-parallel all-reduce, gradients are quantized to int8
with a per-tensor scale; the quantization residual is carried into the
next step (error feedback), which keeps SGD/Adam convergence intact
(Karimireddy et al., 2019).  Under jit+SPMD the all-reduce then moves
1/4 of the bf16 bytes (1/2 vs f32) across the pod links — directly
shrinking the collective roofline term of gradient sync.

Enabled per-run via ``make_compressed_train_step`` (examples + tests);
the dry-run cells keep uncompressed sync so the baseline/optimized
comparison in EXPERIMENTS.md stays about sharding, not precision.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CompressionState", "init_compression", "compress_grads",
           "make_compressed_train_step"]


class CompressionState(NamedTuple):
    error: Any  # residual pytree (param dtype)


def init_compression(params: Any) -> CompressionState:
    return CompressionState(
        error=jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _quantize_leaf(g: jax.Array, err: jax.Array):
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), gf - deq


def compress_grads(grads: Any, state: CompressionState):
    """Returns (dequantized grads, new state).  The int8 tensor is what
    crosses the wire; XLA fuses quant -> all-reduce -> dequant."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(state.error)
    out = [_quantize_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_g, CompressionState(new_e)


def make_compressed_train_step(loss_fn, opt_update):
    """step(params, opt_state, comp_state, batch) with int8 grad sync."""

    def step(params, opt_state, comp_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        grads, comp_state = compress_grads(grads, comp_state)
        params, opt_state, opt_metrics = opt_update(grads, params, opt_state)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, comp_state, metrics

    return step
