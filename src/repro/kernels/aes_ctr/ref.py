"""Pure-jnp oracle for the AES-CTR keystream kernel.

The oracle reuses the FIPS-validated cipher from :mod:`repro.core.aes`
(which tests validate against the official vectors), so kernel
correctness chains back to FIPS-197.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ctr

__all__ = ["aes_ctr_keystream_ref"]


def aes_ctr_keystream_ref(counter_words: jax.Array,
                          round_keys: jax.Array) -> jax.Array:
    """(N, 4) uint32 counters + (11, 16) uint8 schedule -> (N, 16) uint8 OTPs."""
    return ctr.ctr_keystream(round_keys, counter_words)


def aes_ctr_keystream_lanes_ref(counter_words: jax.Array,
                                round_keys: jax.Array) -> jax.Array:
    """Same as above but returning (N, 4) uint32 little-endian lanes,
    matching the kernel's u32-lane output layout."""
    otp_u8 = aes_ctr_keystream_ref(counter_words, round_keys)
    return jax.lax.bitcast_convert_type(
        otp_u8.reshape(otp_u8.shape[0], 4, 4), jnp.uint32)
