"""Declared-metrics registry: counters, gauges, histograms.

The serving engines used to keep a raw ``self.stats = {...}`` dict —
easy to typo (an increment of a misspelled key silently creates a new
counter) and impossible to enumerate for exposition.  Here every
metric is **declared once** with a help string; the canonical name
sets below (:data:`ENGINE_COUNTERS`, :data:`CLUSTER_COUNTERS`, …) are
what ``docs/check_stats.py`` checks engine code and docs against.

Compatibility: :class:`StatsView` wraps a registry's counters in the
old dict API (``stats["admitted"] += 1``, ``stats.items()``,
``dict(**stats)``) so engines, benches and tests keep working
unchanged.  Assigning an *undeclared* key through the view declares a
counter on the fly — the cluster's forward-every-counter aggregation
relies on that — but code inside ``src/repro/serve/`` is gated by
``docs/check_stats.py`` to use declared names only.

Exposition: :meth:`MetricsRegistry.snapshot` returns a JSON-able dict
(gauges sampled lazily at call time, so they cost nothing on the tick
path) and :meth:`MetricsRegistry.prometheus` renders the Prometheus
text format, both with optional constant labels (the cluster rolls up
shard registries with ``shard=`` labels this way).
"""

from __future__ import annotations

import math
from collections.abc import MutableMapping
from typing import Callable, Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "StatsView",
           "ENGINE_COUNTERS", "CLUSTER_COUNTERS", "ENGINE_GAUGES",
           "ENGINE_HISTOGRAMS", "CLUSTER_HISTOGRAMS"]


# -- canonical declarations (the single source of truth for names) ----------

ENGINE_COUNTERS = {
    "admitted": "requests admitted into a decode slot (prefill or hit)",
    "preemptions": "running slots evicted back to the waiting queue",
    "decode_steps": "batched jitted decode dispatches",
    "deferred_checks": "off-critical-path deferred pool-MAC checks",
    "rotations": "tenant key rotations observed by this engine",
    "prefill_compiles": "distinct prefill shapes compiled",
    "reseals": "eager pre-rotation reseal dispatches",
    "uniform_fast_ticks": "single-bank-row ticks on the flat crypto route",
    "fused_mixed_ticks": "mixed-row ticks kept on the fused READ kernel",
    "fused_write_ticks": "ticks resealing dirty pages via the fused WRITE "
                         "kernel",
    "decode_bucket_compiles": "(bucket, uniform) decode variants compiled",
    "decode_page_reads": "pages gathered by decode (active slots x bucket)",
    "prefix_hit_pages": "cache pages installed read-only at admission",
    "prefix_cow_pages": "shared pages copy-resealed private on first write",
    "prefix_inserted_pages": "session pages copy-resealed into the cache",
    "prefix_shared_pages": "pages explicitly resealed cross-tenant",
    "prefill_pages_skipped": "prompt pages a prefix hit exempted from "
                             "prefill",
    "integrity_verdicts": "host-synced MAC-gate verdicts observed",
    "integrity_failures": "MAC-gate / deferred-MAC verdicts that failed",
    "integrity_quarantined_pages": "physical frames permanently retired "
                                   "after a localized integrity failure",
    "sessions_recovered": "preempted sessions re-admitted via secure "
                          "recompute after an integrity fault",
    "sessions_lost": "sessions declared dead after exhausting the "
                     "integrity-recovery retry budget",
    "audit_events": "records appended to the security audit log",
    "merkle_root_updates": "amortized Merkle root recomputes (batched "
                           "dirty-path maintenance at the deferred "
                           "cadence)",
    "merkle_leaf_updates": "Merkle leaves rehashed by incremental "
                           "maintenance (dirty pages, ownership changes, "
                           "quarantine exclusions)",
    "audit_proofs": "per-tenant membership proofs issued against the "
                    "shard Merkle root",
    "slo_ttft_breaches": "requests whose wall-clock ttft missed the "
                         "per-tenant SLO target",
    "slo_tick_p99_breaches": "ok->breach transitions of the rolling p99 "
                             "tick-latency target",
    "slo_integrity_alarms": "ok->alarm transitions of the windowed "
                            "integrity-failure-rate alarm",
    "slo_stuck_ticks": "watchdog firings: no tick end within N x the "
                       "rolling median tick",
}

CLUSTER_COUNTERS = {
    "migrations": "slots moved cross-shard via secure page migration",
    "root_checks": "cluster root-MAC checks",
    "rerouted_preemptions": "preempted requests re-routed across shards",
    "shard_failovers": "shards folded out of the cluster after an "
                       "integrity failure, sessions drained to survivors",
}

ENGINE_GAUGES = {
    "pool_free_pages": "KV pool pages on the free list right now",
    "pool_pages_total": "KV pool capacity in pages",
    "slots_active": "decode slots currently running a request",
    "waiting_requests": "requests queued for admission",
    "tenant_resident_pages": "pool pages owned per tenant (label: tenant)",
    "prefix_cache_pages": "prefix-cache entries resident (pages)",
    "prefix_cache_refs": "total refcount pins across cache entries",
    "protection_overhead_ratio": "attributed protection/model HLO bytes "
                                 "per decode variant (label: bucket)",
    "protection_overhead_flops_ratio": "attributed protection/model HLO "
                                       "flops per decode variant "
                                       "(label: bucket)",
    "roofline_utilization": "attributed roofline time / measured p50 "
                            "tick per decode variant (label: bucket)",
}

ENGINE_HISTOGRAMS = {
    "tick_seconds": "wall-clock latency of one full engine tick",
    "phase_tick_begin_seconds": "wall-clock time in _tick_begin",
    "phase_decode_dispatch_seconds": "wall-clock time in _decode_dispatch",
    "phase_decode_collect_seconds": "wall-clock time in _decode_collect",
    "phase_tick_end_seconds": "wall-clock time in _tick_end",
    "ttft_ticks": "scheduler ticks from submit to first token",
    "ttft_seconds": "wall-clock seconds from submit to first token",
    "decode_bucket": "page-count bucket distribution over decode ticks",
}

CLUSTER_HISTOGRAMS = {
    "cluster_tick_seconds": "wall-clock latency of one cluster tick",
}


class Counter:
    """Monotonic (well, resettable) integer counter."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):  # noqa: A002
        self.name, self.help, self.value = name, help, 0

    def inc(self, n=1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Point-in-time value, either set directly or sampled via ``fn``.

    ``fn`` may return a number, or a ``{label_value: number}`` dict for
    labeled gauges (e.g. per-tenant resident pages, label ``tenant``).
    Sampling happens only at snapshot/exposition time — a callback
    gauge costs literally nothing on the hot path.
    """

    __slots__ = ("name", "help", "label", "fn", "_value")

    def __init__(self, name: str, help: str = "", *,  # noqa: A002
                 fn: Optional[Callable] = None, label: Optional[str] = None):
        self.name, self.help, self.label, self.fn = name, help, label, fn
        self._value = 0

    def set(self, v) -> None:
        self._value = v

    def sample(self):
        return self.fn() if self.fn is not None else self._value

    def reset(self) -> None:
        self._value = 0


class Histogram:
    """Sample-keeping histogram with np.percentile-compatible quantiles.

    Keeps raw observations (bounded by ``max_samples``; oldest dropped
    first) so percentiles are exact over the retained window —
    :meth:`percentile` matches ``np.percentile(..., method="linear")``
    bit-for-bit, which ``tests/test_obs.py`` asserts.  ``count``/
    ``sum``/``min``/``max`` cover the whole life of the histogram even
    after the sample window rolls.
    """

    __slots__ = ("name", "help", "max_samples", "samples", "count", "sum",
                 "min", "max")

    def __init__(self, name: str, help: str = "", *,  # noqa: A002
                 max_samples: int = 65536):
        self.name, self.help = name, help
        self.max_samples = max_samples
        self.reset()

    def reset(self) -> None:
        self.samples: list = []
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self.samples.append(v)
        if len(self.samples) > self.max_samples:
            del self.samples[: len(self.samples) - self.max_samples]

    def percentile(self, q: float) -> float:
        """Linear-interpolated percentile (numpy's default method)."""
        if not self.samples:
            return math.nan
        xs = sorted(self.samples)
        if len(xs) == 1:
            return xs[0]
        pos = (len(xs) - 1) * (q / 100.0)
        lo = int(math.floor(pos))
        hi = min(lo + 1, len(xs) - 1)
        frac = pos - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def summary(self) -> dict:
        if not self.count:
            return {"count": 0, "sum": 0.0}
        return {"count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


class MetricsRegistry:
    """One namespace of declared counters/gauges/histograms."""

    def __init__(self):
        self.counters: dict = {}
        self.gauges: dict = {}
        self.histograms: dict = {}

    # Declarations are get-or-create so shared code paths can redeclare
    # idempotently; conflicting kinds under one name are an error.

    def counter(self, name: str, help: str = "") -> Counter:  # noqa: A002
        self._check_free(name, self.counters)
        if name not in self.counters:
            self.counters[name] = Counter(name, help)
        return self.counters[name]

    def gauge(self, name: str, help: str = "", *,  # noqa: A002
              fn: Optional[Callable] = None,
              label: Optional[str] = None) -> Gauge:
        self._check_free(name, self.gauges)
        if name not in self.gauges:
            self.gauges[name] = Gauge(name, help, fn=fn, label=label)
        return self.gauges[name]

    def histogram(self, name: str, help: str = "", *,  # noqa: A002
                  max_samples: int = 65536) -> Histogram:
        self._check_free(name, self.histograms)
        if name not in self.histograms:
            self.histograms[name] = Histogram(name, help,
                                              max_samples=max_samples)
        return self.histograms[name]

    def _check_free(self, name: str, own: dict) -> None:
        for kind in (self.counters, self.gauges, self.histograms):
            if kind is not own and name in kind:
                raise ValueError(f"metric {name!r} already declared as a "
                                 f"different kind")

    def names(self) -> set:
        return (set(self.counters) | set(self.gauges)
                | set(self.histograms))

    def reset(self) -> None:
        for m in (*self.counters.values(), *self.gauges.values(),
                  *self.histograms.values()):
            m.reset()

    # -- exposition ---------------------------------------------------------

    def snapshot(self, labels: Optional[dict] = None) -> dict:
        """JSON-able point-in-time view (gauges sampled now)."""
        out = {
            "counters": {n: c.value for n, c in sorted(self.counters.items())},
            "gauges": {n: g.sample() for n, g in sorted(self.gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(self.histograms.items())},
        }
        if labels:
            out["labels"] = dict(labels)
        return out

    def prometheus(self, prefix: str = "repro",
                   labels: Optional[dict] = None) -> str:
        """Prometheus text exposition format (one block per metric).

        Label values and help strings are escaped per the text-format
        spec (label values: ``\\`` ``"`` and newline; help: ``\\`` and
        newline), so tenant ids and file paths with arbitrary bytes
        round-trip through a Prometheus parser —
        ``tests/test_obs.py`` parses the exposition back and compares.
        """
        base = dict(labels or {})

        def esc_label(v) -> str:
            return (str(v).replace("\\", r"\\").replace('"', r'\"')
                    .replace("\n", r"\n"))

        def esc_help(s: str) -> str:
            return str(s).replace("\\", r"\\").replace("\n", r"\n")

        def fmt_labels(extra: Optional[dict] = None) -> str:
            items = dict(base, **(extra or {}))
            if not items:
                return ""
            inner = ",".join(f'{k}="{esc_label(v)}"'
                             for k, v in sorted(items.items()))
            return "{" + inner + "}"

        lines = []
        for name, c in sorted(self.counters.items()):
            full = f"{prefix}_{name}"
            lines += [f"# HELP {full} {esc_help(c.help)}",
                      f"# TYPE {full} counter",
                      f"{full}{fmt_labels()} {c.value}"]
        for name, g in sorted(self.gauges.items()):
            full = f"{prefix}_{name}"
            lines += [f"# HELP {full} {esc_help(g.help)}",
                      f"# TYPE {full} gauge"]
            value = g.sample()
            if isinstance(value, dict):
                key = g.label or "label"
                for lv, v in sorted(value.items()):
                    lines.append(f"{full}{fmt_labels({key: lv})} {v}")
            else:
                lines.append(f"{full}{fmt_labels()} {value}")
        for name, h in sorted(self.histograms.items()):
            full = f"{prefix}_{name}"
            lines += [f"# HELP {full} {esc_help(h.help)}",
                      f"# TYPE {full} summary"]
            if h.count:
                for q in (50, 95, 99):
                    lines.append(
                        f"{full}{fmt_labels({'quantile': q / 100})} "
                        f"{h.percentile(q)}")
            lines.append(f"{full}_sum{fmt_labels()} {h.sum}")
            lines.append(f"{full}_count{fmt_labels()} {h.count}")
        return "\n".join(lines) + "\n"


class StatsView(MutableMapping):
    """The old ``engine.stats`` dict API over a registry's counters.

    ``view[k]`` reads a counter, ``view[k] = v`` sets one (declaring it
    on the fly when unknown — how cluster aggregation forwards counters
    it has never heard of), ``+=`` composes the two.  Iteration order
    follows declaration order, like the dict it replaces.
    """

    __slots__ = ("_registry",)

    def __init__(self, registry: MetricsRegistry):
        self._registry = registry

    def __getitem__(self, key: str):
        try:
            return self._registry.counters[key].value
        except KeyError:
            raise KeyError(key) from None

    def __setitem__(self, key: str, value) -> None:
        self._registry.counter(key).value = value

    def __delitem__(self, key: str) -> None:
        del self._registry.counters[key]

    def __iter__(self):
        return iter(self._registry.counters)

    def __len__(self) -> int:
        return len(self._registry.counters)

    def __repr__(self) -> str:
        return f"StatsView({dict(self)!r})"
