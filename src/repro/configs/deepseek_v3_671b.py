"""deepseek-v3-671b — MLA + 256-expert MoE [arXiv:2412.19437; hf].

[moe] 61L d_model=7168 128H d_ff=2048 (per routed expert) vocab=129280,
MoE: 1 shared + 256 routed experts top-8, first 3 layers dense
(d_ff 18432).  MLA: q_lora 1536, kv_lora 512, nope 128, rope 64, v 128.
The MTP head is omitted (not exercised by the assigned shapes;
recorded in DESIGN.md §5).
"""

from repro.configs.base import ArchDef
from repro.models.lm import LMConfig
from repro.models.mla import MLAConfig
from repro.models.moe import MoEConfig

DENSE_PREFIX_FF = 18432  # d_ff of the 3 dense prefix layers (DSv3 report)


def make_config() -> LMConfig:
    return LMConfig(
        name="deepseek-v3-671b",
        n_layers=61, d_model=7168, n_heads=128, n_kv=128, head_dim=128,
        d_ff=DENSE_PREFIX_FF, vocab=129280,
        mixer="mla", ffn="moe", moe_every=1, moe_start_layer=3,
        tie_embeddings=False,
        mla=MLAConfig(d_model=7168, n_heads=128, q_lora_rank=1536,
                      kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(n_experts=256, top_k=8, d_model=7168, d_ff=2048,
                      n_shared=1, shared_d_ff=2048, capacity_factor=1.25),
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="deepseek-v3-671b-smoke",
        n_layers=4, d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=128, vocab=256, dtype="float32",
        mixer="mla", ffn="moe", moe_every=1, moe_start_layer=1,
        tie_embeddings=False,
        q_block=16, kv_block=16, remat="none",
        mla=MLAConfig(d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_model=64, d_ff=32, n_shared=1,
                      shared_d_ff=32, capacity_factor=2.0),
    )


ARCH = ArchDef(
    name="deepseek-v3-671b", family="moe", kind="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    source="arXiv:2412.19437; hf",
    rules={"heads": "model"},  # 128 heads / 16 = 8 per shard
    notes="MLA compressed KV cache (c_kv 512 + k_pe 64 per token) is the "
          "decode-cell boundary tensor.  256 routed experts EP-shard "
          "over model=16; optimizer state in bf16 so the multi-pod cell "
          "fits v5e HBM (see configs/__init__.OPT_DTYPE_OVERRIDES).",
)
