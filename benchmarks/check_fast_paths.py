"""CI gate: bench JSONs must show the engine's fast paths actually ran.

Two gated benchmarks, dispatched on the JSON's ``benchmark`` field:

**sharded_serving** — the decode hot path has three cheap routes that
regressions tend to lose silently (everything still produces correct
tokens, just slower):

* ``uniform_fast_ticks`` — single-key ticks (no registry, or every page
  resolving to one tenant-epoch bank row) dispatch the flat crypt/MAC
  route;
* ``fused_mixed_ticks`` — mixed-bank-row ticks stay on the fused Pallas
  READ kernel via its per-page round-key gather instead of falling back
  to the vmapped per-page reference;
* ``fused_write_ticks`` — kernel-capable ticks reseal their dirty pages
  through the one-pass fused WRITE kernel (encrypt + MAC of the fresh
  ciphertext in a single Pallas visit), never the vmapped per-page
  write reference.

Fails (exit 1) when ``uniform_fast_ticks + fused_mixed_ticks == 0``
across the bench results, and additionally when a dedicated fast-path
row (the bench's one-tenant "uniform" / two-tenant "mixed"
measurements, which run with the kernels on) recorded zero ticks on
any of its routes — the per-row checks are the sharp ones, since
registry-less rows count every tick as uniform by construction, and
the mixed row is the only one that exercises the mixed-key read AND
write kernels together.

**shared_prefix** — every row at ``hit_rate > 0`` must show the secure
prefix cache at work: ``prefix_hit_pages > 0`` and
``prefill_pages_skipped > 0`` (shared pages actually deleted prefill
work), and ``tokens_match`` true (the cached engine stayed
token-identical to the no-cache engine).  A row failing any of these
means the cache silently stopped hitting — or worse, stopped being
transparent.

Usage::

    python benchmarks/check_fast_paths.py bench-sharded-serving.json
    python benchmarks/check_fast_paths.py bench-shared-prefix.json
"""

from __future__ import annotations

import json
import sys

# marker substring in the row's scheme label -> counters that must be
# non-zero on at least one such row.
ROW_GATES = (
    ("uniform", ("uniform_fast_ticks", "fused_write_ticks")),
    ("mixed", ("fused_mixed_ticks", "fused_write_ticks")),
)


def check_decode_fast_paths(results: list) -> int:
    uniform = sum(r.get("uniform_fast_ticks", 0) for r in results)
    fused_mixed = sum(r.get("fused_mixed_ticks", 0) for r in results)
    fused_write = sum(r.get("fused_write_ticks", 0) for r in results)
    print(f"[fast-paths] uniform_fast_ticks={uniform} "
          f"fused_mixed_ticks={fused_mixed} "
          f"fused_write_ticks={fused_write} over {len(results)} results")
    if uniform + fused_mixed == 0:
        print("[fast-paths] FAIL: no tick took a fast path — the "
              "single-key/fused decode routes were silently lost")
        return 1
    ok = True
    for marker, counters in ROW_GATES:
        rows = [r for r in results if marker in str(r.get("scheme", ""))]
        if not rows:
            continue
        for counter in counters:
            if not any(r.get(counter, 0) for r in rows):
                print(f"[fast-paths] FAIL: dedicated {marker}-tenant "
                      f"measurement present but recorded zero {counter} — "
                      f"that decode route was silently lost")
                ok = False
    return 0 if ok else 1


def check_shared_prefix(results: list) -> int:
    hit_rows = [r for r in results if r.get("hit_rate", 0) > 0]
    print(f"[fast-paths] shared-prefix: {len(hit_rows)} hit-rate>0 rows "
          f"of {len(results)}")
    if not hit_rows:
        print("[fast-paths] FAIL: shared-prefix bench has no hit-rate>0 "
              "rows to gate on")
        return 1
    ok = True
    for r in results:
        label = f"scheme={r.get('scheme')} hit={r.get('hit_rate')}"
        if not r.get("tokens_match", False):
            print(f"[fast-paths] FAIL: {label} diverged from the "
                  f"no-cache engine — the prefix cache is not transparent")
            ok = False
        if r.get("hit_rate", 0) <= 0:
            continue
        for counter in ("prefix_hit_pages", "prefill_pages_skipped"):
            if not r.get(counter, 0):
                print(f"[fast-paths] FAIL: {label} recorded zero "
                      f"{counter} — the prefix cache silently stopped "
                      f"hitting")
                ok = False
    return 0 if ok else 1


def check(path: str) -> int:
    with open(path) as f:
        data = json.load(f)
    results = data.get("results", [])
    if data.get("benchmark") == "shared_prefix":
        rc = check_shared_prefix(results)
    else:
        rc = check_decode_fast_paths(results)
    if rc == 0:
        print("[fast-paths] ok")
    return rc


if __name__ == "__main__":
    sys.exit(check(sys.argv[1]))
