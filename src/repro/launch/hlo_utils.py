"""Post-SPMD HLO analysis: collective-traffic extraction.

``compiled.as_text()`` is the per-device module after the SPMD
partitioner has materialized collectives.  We sum operand byte sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction; shapes in that module are already
per-device, so the totals are per-chip collective bytes.
"""

from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_bytes", "DTYPE_BYTES", "parse_shape_bytes"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64"
                       r"|f64|c64|c128)\[([0-9,]*)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# Start/done pairs appear for async collectives; count each op once.
_SKIP_SUFFIXES = ("-done",)


def parse_shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape literal in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind operand bytes from a post-SPMD HLO module.

    Returns {kind: bytes, ..., 'total': bytes, 'count': n_ops}.
    """
    out: dict = defaultdict(float)
    count = 0
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        m = re.search(r"=\s*(?:\([^)]*\)\s*)?[a-z0-9\[\],{}\s]*?"
                      r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                      r"collective-permute)(-start|-done)?\(", line)
        if not m:
            continue
        kind, suffix = m.group(1), m.group(2) or ""
        if suffix == "-done":
            continue  # counted at -start
        # Operand region: everything after the op's opening paren.
        start = line.index(m.group(0)) + len(m.group(0))
        operand_text = line[start:]
        nbytes = parse_shape_bytes(operand_text)
        if nbytes == 0:
            # Operands not typed inline: fall back to the output shape
            # (text before the '=').
            nbytes = parse_shape_bytes(line[: line.index("=")])
            if nbytes == 0:
                # Output tuple printed after '=': scan the full line.
                nbytes = parse_shape_bytes(line)
        out[kind] += nbytes
        count += 1
    out["total"] = float(sum(v for k, v in out.items() if k in _COLLECTIVES))
    out["count"] = count
    return dict(out)
