"""Secure checkpoints: roundtrip, tamper, atomicity, resume plumbing."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.secure_ckpt import (CheckpointError, latest_step,
                                          load_checkpoint, save_checkpoint)
from repro.core.secure_memory import SecureKeys


@pytest.fixture()
def tree(rng):
    return {
        "embed": jnp.asarray(rng.standard_normal((32, 16), dtype=np.float32)),
        "layers": {"w1": jnp.asarray(rng.standard_normal((16, 16),
                                                         dtype=np.float32))
                   .astype(jnp.bfloat16),
                   "b": jnp.asarray(rng.integers(-5, 5, 7, dtype=np.int32))},
    }


class TestSecureCheckpoint:
    def test_roundtrip(self, tree, keys, tmp_path):
        path = save_checkpoint(str(tmp_path), 5, tree, keys,
                               extra_state={"data": {"step": 5, "seed": 0}})
        out, manifest = load_checkpoint(path, tree, keys)
        for a, b in zip(jax.tree_util.tree_leaves(out),
                        jax.tree_util.tree_leaves(tree)):
            assert (np.asarray(a) == np.asarray(b)).all()
        assert manifest["extra_state"]["data"]["step"] == 5

    def test_template_can_be_structs(self, tree, keys, tmp_path):
        path = save_checkpoint(str(tmp_path), 1, tree, keys)
        template = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        out, _ = load_checkpoint(path, template, keys)
        assert (np.asarray(out["embed"])
                == np.asarray(tree["embed"])).all()

    def test_tamper_detection(self, tree, keys, tmp_path):
        path = save_checkpoint(str(tmp_path), 2, tree, keys)
        leaf = os.path.join(path, "leaf_00001.bin")
        raw = bytearray(open(leaf, "rb").read())
        raw[len(raw) // 2] ^= 0x01
        open(leaf, "wb").write(bytes(raw))
        with pytest.raises(CheckpointError, match="integrity"):
            load_checkpoint(path, tree, keys)

    def test_wrong_key_rejected(self, tree, keys, tmp_path):
        path = save_checkpoint(str(tmp_path), 3, tree, keys)
        wrong = SecureKeys.derive(999)
        with pytest.raises(CheckpointError):
            load_checkpoint(path, tree, wrong)

    def test_manifest_tamper_rejected(self, tree, keys, tmp_path):
        path = save_checkpoint(str(tmp_path), 4, tree, keys)
        mpath = os.path.join(path, "manifest.json")
        manifest = json.load(open(mpath))
        manifest["layer_macs"][0][0] ^= 0xFF
        json.dump(manifest, open(mpath, "w"))
        with pytest.raises(CheckpointError):
            load_checkpoint(path, tree, keys)

    def test_latest_step_and_atomicity(self, tree, keys, tmp_path):
        assert latest_step(str(tmp_path)) is None
        save_checkpoint(str(tmp_path), 10, tree, keys)
        save_checkpoint(str(tmp_path), 20, tree, keys)
        # A stale .tmp dir (crashed writer) must be ignored.
        os.makedirs(os.path.join(str(tmp_path), "step_00000030.tmp"))
        assert latest_step(str(tmp_path)) == 20

    def test_shape_mismatch_rejected(self, tree, keys, tmp_path):
        path = save_checkpoint(str(tmp_path), 6, tree, keys)
        bad = dict(tree)
        bad["embed"] = jnp.zeros((8, 8), jnp.float32)
        with pytest.raises(CheckpointError, match="mismatch"):
            load_checkpoint(path, bad, keys)

    def test_elastic_reshard_roundtrip(self, tree, keys, tmp_path):
        """Checkpoints are stored unsharded: restore onto a different
        'mesh' (here: different leaf placement) is just device_put."""
        path = save_checkpoint(str(tmp_path), 7, tree, keys,
                               mesh_shape=(16, 16))
        out, manifest = load_checkpoint(path, tree, keys)
        assert manifest["mesh_shape"] == [16, 16]
        # re-placing on the current (1-device) "mesh" works
        re_placed = jax.device_put(out)
        assert (np.asarray(re_placed["embed"])
                == np.asarray(tree["embed"])).all()
