"""Observability subsystem: metrics registry, tracer, audit chain.

Covers the telemetry contracts:
  * registry — declared counters behind the old ``engine.stats`` dict
    API (snapshot/reset, auto-declare on unknown assignment, kind
    conflicts rejected, Prometheus text well-formed);
  * histograms — ``percentile()`` matches numpy's default linear
    interpolation;
  * tracer — exports valid Chrome trace-event JSON with tick-phase
    spans correctly nested inside their tick span;
  * audit log — the SHA-256 chain verifies end-to-end and any
    single-field tamper, truncation, or reorder breaks it;
  * engines — tracing + metrics + audit enabled is observation-only
    (token-identical for every scheme); the cluster rolls shard
    counters up with per-shard labels.
"""

import json
import re

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.secure_exec import SCHEMES
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.obs.audit import AuditLog
from repro.obs.metrics import (ENGINE_COUNTERS, Histogram, MetricsRegistry,
                               StatsView)
from repro.obs.trace import SpanTracer
from repro.serve.cluster import ClusterEngine
from repro.serve.engine import SecureServingEngine
from repro.tenancy import KeyHierarchy, TenantRegistry


@pytest.fixture(scope="module")
def smoke():
    arch = get_arch("minitron-4b")
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    return arch, cfg, params


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(0)
    return [list(map(int, rng.integers(1, 256, n))) for n in (5, 7, 9)]


def _engine(smoke, **kw):
    arch, cfg, params = smoke
    kw.setdefault("max_slots", 2)
    kw.setdefault("page_tokens", 4)
    kw.setdefault("pages_per_slot", 4)
    return SecureServingEngine(arch, cfg, params, **kw)


class TestRegistry:
    def test_counters_snapshot_and_reset(self):
        reg = MetricsRegistry()
        reg.counter("a", "first").inc()
        reg.counter("a").inc(4)
        reg.gauge("g").set(7)
        reg.histogram("h").observe(2.5)
        snap = reg.snapshot(labels={"shard": "0"})
        assert snap["counters"] == {"a": 5}
        assert snap["gauges"] == {"g": 7}
        assert snap["histograms"]["h"]["count"] == 1
        assert snap["labels"] == {"shard": "0"}
        reg.reset()
        snap = reg.snapshot()
        assert snap["counters"] == {"a": 0}
        assert snap["histograms"]["h"]["count"] == 0

    def test_stats_view_dict_api(self):
        reg = MetricsRegistry()
        for name, help_ in ENGINE_COUNTERS.items():
            reg.counter(name, help_)
        stats = StatsView(reg)
        stats["admitted"] += 1
        stats["admitted"] += 2
        assert stats["admitted"] == 3
        assert dict(stats)["admitted"] == 3
        assert "admitted" in stats
        assert set(stats.keys()) == set(ENGINE_COUNTERS)
        assert len(stats) == len(ENGINE_COUNTERS)
        with pytest.raises(KeyError):
            stats.__getitem__("never_declared")

    def test_autodeclare_unknown_key(self):
        reg = MetricsRegistry()
        stats = StatsView(reg)
        stats["brand_new"] = 3
        assert reg.counters["brand_new"].value == 3
        assert stats["brand_new"] == 3

    def test_kind_conflict_rejected(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(ValueError):
            reg.gauge("x")
        with pytest.raises(ValueError):
            reg.histogram("x")

    def test_lazy_gauge_and_labels(self):
        reg = MetricsRegistry()
        backing = {"t0": 4, "t1": 2}
        reg.gauge("resident", fn=lambda: dict(backing), label="tenant")
        backing["t0"] = 9
        assert reg.snapshot()["gauges"]["resident"] == {"t0": 9, "t1": 2}

    def test_prometheus_text(self):
        reg = MetricsRegistry()
        reg.counter("ticks", "engine ticks").inc(3)
        reg.gauge("free", "free pages").set(11)
        reg.gauge("resident", fn=lambda: {"t0": 4}, label="tenant")
        reg.histogram("lat").observe(1.0)
        text = reg.prometheus(labels={"shard": "1"})
        assert "# TYPE repro_ticks counter" in text
        assert 'repro_ticks{shard="1"} 3' in text
        assert 'repro_free{shard="1"} 11' in text
        assert 'repro_resident{shard="1",tenant="t0"} 4' in text
        assert 'repro_lat_count{shard="1"} 1' in text

    def test_prometheus_escaping_parses_back(self):
        # Hostile label values and help text: backslash, quote, newline.
        reg = MetricsRegistry()
        evil = 'a\\b"c\nd'
        reg.gauge("resident", 'help \\ with\nnewline',
                  fn=lambda: {evil: 7}, label="tenant")
        reg.counter("ticks", "plain").inc(2)
        text = reg.prometheus(labels={"shard": evil})

        # Exposition-format invariant: every sample is one line, every
        # quoted label value uses only \\ \" \n escapes.
        samples = {}
        helps = {}
        for line in text.splitlines():
            if line.startswith("# HELP "):
                name, help_ = line[7:].split(" ", 1)
                helps[name] = help_
                continue
            if line.startswith("#") or not line:
                continue
            name_part, value = line.rsplit(" ", 1)
            labels = {}
            if "{" in name_part:
                name, rest = name_part.split("{", 1)
                body = rest.rsplit("}", 1)[0]
                for m in re.finditer(r'(\w+)="((?:[^"\\]|\\.)*)"', body):
                    raw = m.group(2)
                    assert "\n" not in raw
                    labels[m.group(1)] = (raw.replace("\\n", "\n")
                                          .replace('\\"', '"')
                                          .replace("\\\\", "\\"))
            else:
                name = name_part
            samples[(name, tuple(sorted(labels.items())))] = float(value)

        key = ("repro_resident",
               (("shard", evil), ("tenant", evil)))
        assert samples[key] == 7
        assert "\n" not in helps["repro_resident"]
        assert helps["repro_resident"].replace("\\n", "\n").replace(
            "\\\\", "\\") == 'help \\ with\nnewline'
        # Labeled-gauge expansions carry HELP/TYPE headers too.
        assert "# TYPE repro_resident gauge" in text


class TestHistogram:
    def test_percentiles_match_numpy(self):
        rng = np.random.default_rng(7)
        xs = rng.normal(size=257).tolist()
        h = Histogram("lat")
        for x in xs:
            h.observe(x)
        for q in (0, 5, 25, 50, 75, 90, 95, 99, 100):
            want = float(np.percentile(xs, q, method="linear"))
            assert h.percentile(q) == pytest.approx(want, rel=1e-12, abs=0)
        assert h.count == len(xs)
        assert h.min == min(xs) and h.max == max(xs)

    def test_sample_window_rolls_but_totals_persist(self):
        h = Histogram("lat", max_samples=4)
        for v in range(10):
            h.observe(v)
        assert h.count == 10 and h.sum == sum(range(10))
        assert h.samples == [6.0, 7.0, 8.0, 9.0]


class TestTrace:
    def test_chrome_trace_json(self, tmp_path):
        tr = SpanTracer(pid=3, tid=1)
        with tr.span("outer", tick=0):
            with tr.span("inner"):
                pass
        path = tmp_path / "trace.json"
        doc = tr.export(str(path))
        loaded = json.loads(path.read_text())
        assert loaded == doc
        events = loaded["traceEvents"]
        assert [e["name"] for e in events] == ["outer", "inner"]
        for e in events:
            assert e["ph"] == "X"
            assert e["pid"] == 3 and e["tid"] == 1
            assert e["dur"] >= 0
        outer, inner = events
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6

    def test_ring_buffer_bounded(self):
        tr = SpanTracer(capacity=8)
        for i in range(20):
            tr.add(f"s{i}", 0, 1000)
        events = tr.events()
        assert len(events) == 8
        assert events[0]["name"] == "s12"

    def test_phase_spans_nested(self, smoke, prompts):
        eng = _engine(smoke, scheme="seda", trace=True)
        for p in prompts[:2]:
            eng.submit(p, max_new_tokens=4)
        eng.run()
        events = eng.tracer.events()
        ticks = [e for e in events if e["name"] == "tick"]
        phases = [e for e in events if e["name"].startswith(
            ("tick_begin", "decode_dispatch", "decode_collect", "tick_end"))]
        assert ticks and phases
        names = {e["name"] for e in phases}
        assert names == {"tick_begin", "decode_dispatch",
                         "decode_collect", "tick_end"}
        for ph in phases:
            assert any(t["ts"] - 1e-6 <= ph["ts"] and
                       ph["ts"] + ph["dur"] <= t["ts"] + t["dur"] + 1e-6
                       for t in ticks), ph["name"]


class TestAudit:
    def _log(self, n=5):
        log = AuditLog()
        for i in range(n):
            log.append("rotation", tenant=f"t{i % 2}", new_epoch=i)
        return log

    def test_chain_verifies_and_round_trips(self, tmp_path):
        log = self._log()
        assert len(log) == 5
        assert log.verify_chain()
        assert log.records()[0]["prev"] == "0" * 64
        path = tmp_path / "audit.jsonl"
        log.dump(str(path))
        loaded = AuditLog.load(str(path))
        assert loaded.verify_chain()
        assert loaded.head == log.head
        assert len(loaded.events("rotation")) == 5

    def test_tamper_detected(self):
        log = self._log()
        # Single-field edit: flip one byte of a recorded field.
        log._records[2]["tenant"] = "t9"
        assert not log.verify_chain()

        log = self._log()
        del log._records[1]                     # truncation / drop
        assert not log.verify_chain()

        log = self._log()
        log._records[1], log._records[2] = \
            log._records[2], log._records[1]    # reorder
        assert not log.verify_chain()

        log = self._log()
        log._records[4]["hash"] = "f" * 64      # forged head
        assert not log.verify_chain()

    def test_reserved_fields_rejected(self):
        log = AuditLog()
        with pytest.raises(ValueError):
            log.append("rotation", seq=3)
        with pytest.raises(ValueError):
            log.append("rotation", hash="x")


class TestEngineObs:
    @pytest.mark.parametrize("scheme", sorted(SCHEMES))
    def test_token_parity_all_schemes(self, smoke, prompts, scheme):
        bare = _engine(smoke, scheme=scheme)
        rids = [bare.submit(p, max_new_tokens=4) for p in prompts[:2]]
        want = [bare.run()[r].generated for r in rids]

        eng = _engine(smoke, scheme=scheme, trace=True, audit=True)
        rids = [eng.submit(p, max_new_tokens=4) for p in prompts[:2]]
        done = eng.run()
        assert [done[r].generated for r in rids] == want
        assert len(eng.tracer) > 0
        assert eng.audit.verify_chain()

    def test_engine_snapshot_and_rotation_audit(self, smoke, prompts):
        reg = TenantRegistry(KeyHierarchy(2), max_tenants=2)
        reg.register("a")
        sess = reg.open_session("a")
        eng = _engine(smoke, scheme="seda", registry=reg, rotate_every=2,
                      trace=True, audit=True)
        eng.submit(prompts[0], max_new_tokens=6, session=sess)
        eng.run()
        snap = eng.snapshot()
        assert snap["counters"]["admitted"] == 1
        assert snap["counters"]["decode_steps"] > 0
        assert snap["counters"]["rotations"] > 0
        assert snap["gauges"]["pool_free_pages"] == \
            snap["gauges"]["pool_pages_total"]       # drained engine
        assert snap["histograms"]["tick_seconds"]["count"] > 0
        assert snap["histograms"]["ttft_ticks"]["count"] == 1
        rotations = eng.audit.events("rotation")
        assert rotations and rotations[0]["tenant"] == "a"
        assert eng.audit.verify_chain()
        assert snap["counters"]["audit_events"] == len(eng.audit)
        assert "# TYPE repro_admitted counter" in eng.prometheus()

    def test_cluster_rollup_labels(self, smoke, prompts):
        cluster = ClusterEngine(*smoke, shards=2, max_slots=2,
                                page_tokens=4, pages_per_slot=4,
                                scheme="seda", trace=True, audit=True)
        rids = [cluster.submit(p, max_new_tokens=4) for p in prompts]
        done = cluster.run()
        assert len(done) == len(rids)
        snap = cluster.snapshot()
        shards = snap["shards"]
        assert [s["labels"]["shard"] for s in shards] == ["0", "1"]
        assert snap["rollup"]["admitted"] == \
            sum(s["counters"]["admitted"] for s in shards) == 3
        text = cluster.prometheus()
        assert 'repro_admitted{shard="0"}' in text
        assert 'repro_admitted{shard="1"}' in text
        assert "repro_migrations" in text
        # One shared audit chain across shards.
        assert cluster.audit is cluster.engines[0].audit
        assert cluster.audit.verify_chain()
        # Cluster trace merges every shard's track plus its own.
        pids = {e["pid"] for e in
                cluster.export_trace()["traceEvents"]}
        assert pids == {0, 1, 2}

    def test_trace_valid_under_cluster_rotation(self, smoke, prompts,
                                                tmp_path):
        """Key rotation mid-run must not corrupt the merged trace:
        still valid JSON, and per pid the spans of any single phase
        never overlap (a rotation pausing a shard cannot interleave
        two `decode_dispatch` spans on one track)."""
        reg = TenantRegistry(KeyHierarchy(2), max_tenants=2)
        reg.register("a")
        reg.register("b")
        sessions = [reg.open_session(t) for t in ("a", "b", "a")]
        cluster = ClusterEngine(*smoke, shards=2, max_slots=2,
                                page_tokens=4, pages_per_slot=4,
                                scheme="seda", registry=reg,
                                rotate_every=2, trace=True, audit=True)
        for p, s in zip(prompts, sessions):
            cluster.submit(p, max_new_tokens=6, session=s)
        cluster.run()
        assert cluster.snapshot()["rollup"]["rotations"] > 0

        path = tmp_path / "trace.json"
        doc = cluster.export_trace(str(path))
        loaded = json.loads(path.read_text())   # valid JSON on disk
        assert loaded == doc
        by_pid_phase: dict = {}
        for e in loaded["traceEvents"]:
            assert set(e) >= {"name", "ph", "pid", "tid", "ts", "dur"}
            by_pid_phase.setdefault((e["pid"], e["name"]), []).append(e)
        assert len(by_pid_phase) > 1
        for (pid, name), spans in by_pid_phase.items():
            spans.sort(key=lambda e: e["ts"])
            for prev, nxt in zip(spans, spans[1:]):
                assert prev["ts"] + prev["dur"] <= nxt["ts"] + 1e-6, \
                    (pid, name)
