"""Per-arch smoke tests (reduced configs) + component oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import attention as att
from repro.models import encdec as ed
from repro.models import lm as lm_mod
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models.layers import init_params


def _smoke_batch(arch, cfg, b=2, l=16):
    rng = np.random.default_rng(0)
    if arch.kind == "encdec":
        toks = rng.integers(1, cfg.vocab, (b, l), dtype=np.int64)
        return {
            "src_embeds": jnp.asarray(rng.standard_normal(
                (b, 8, cfg.d_model), dtype=np.float32)),
            "tgt_tokens": jnp.asarray(toks[:, :-1].astype(np.int32)),
            "labels": jnp.asarray(toks[:, 1:].astype(np.int32)),
        }
    toks = rng.integers(1, cfg.vocab, (b, l + 1), dtype=np.int64)
    batch = {"tokens": jnp.asarray(toks[:, :-1].astype(np.int32)),
             "labels": jnp.asarray(toks[:, 1:].astype(np.int32))}
    if cfg.n_image_patches:
        batch["image_embeds"] = jnp.asarray(rng.standard_normal(
            (b, cfg.n_image_patches, cfg.d_vision), dtype=np.float32))
    return batch


@pytest.mark.parametrize("arch_name", sorted(ARCHS))
class TestArchSmoke:
    """One reduced-config forward + train step per assigned arch (f)."""

    def test_forward_shapes_and_no_nans(self, arch_name):
        arch = get_arch(arch_name)
        cfg = arch.make_smoke_config()
        key = jax.random.PRNGKey(0)
        if arch.kind == "encdec":
            params = init_params(ed.encdec_specs(cfg), key)
            batch = _smoke_batch(arch, cfg)
            logits = ed.encdec_forward(cfg, params, batch)
            assert logits.shape == batch["tgt_tokens"].shape + (cfg.vocab,)
        else:
            params = init_params(lm_mod.lm_specs(cfg), key)
            batch = _smoke_batch(arch, cfg)
            logits, aux = lm_mod.lm_forward(cfg, params, batch)
            l_total = batch["tokens"].shape[1] + cfg.n_image_patches
            assert logits.shape == (2, l_total, cfg.vocab)
            assert bool(jnp.isfinite(aux))
        assert bool(jnp.isfinite(logits).all())

    def test_one_train_step_decreases_nothing_nan(self, arch_name):
        from repro.train.optimizer import AdamWConfig, init_opt_state
        from repro.train.train_step import make_train_step
        arch = get_arch(arch_name)
        cfg = arch.make_smoke_config()
        key = jax.random.PRNGKey(1)
        specs = (ed.encdec_specs(cfg) if arch.kind == "encdec"
                 else lm_mod.lm_specs(cfg))
        params = init_params(specs, key)
        opt_cfg = AdamWConfig(lr=1e-3)
        opt = init_opt_state(params, opt_cfg)
        step = jax.jit(make_train_step(arch, cfg, opt_cfg))
        batch = _smoke_batch(arch, cfg)
        new_params, new_opt, metrics = step(params, opt, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert bool(jnp.isfinite(metrics["grad_norm"]))
        assert int(new_opt.count) == 1
        # params actually moved
        moved = any(
            not np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(new_params),
                            jax.tree_util.tree_leaves(params)))
        assert moved

    def test_decode_step(self, arch_name):
        arch = get_arch(arch_name)
        cfg = arch.make_smoke_config()
        key = jax.random.PRNGKey(2)
        b, max_len = 2, 24
        if arch.kind == "encdec":
            params = init_params(ed.encdec_specs(cfg), key)
            batch = _smoke_batch(arch, cfg)
            del batch["labels"]
            logits, caches = ed.decoder_prefill(cfg, params, batch, max_len)
            logits2, caches2 = ed.decoder_decode(
                cfg, params, jnp.ones((b, 1), jnp.int32), caches)
        else:
            params = init_params(lm_mod.lm_specs(cfg), key)
            batch = _smoke_batch(arch, cfg)
            del batch["labels"]
            logits, caches = lm_mod.lm_prefill(cfg, params, batch, max_len)
            logits2, caches2 = lm_mod.lm_decode(
                cfg, params, jnp.ones((b, 1), jnp.int32), caches)
        assert logits2.shape == (b, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits2).all())


class TestComponentOracles:
    def test_chunked_attention_vs_naive(self):
        key = jax.random.PRNGKey(0)
        b, l, h, hkv, hd = 2, 29, 8, 4, 16
        q = jax.random.normal(key, (b, l, h, hd))
        k = jax.random.normal(jax.random.PRNGKey(1), (b, l, hkv, hd))
        v = jax.random.normal(jax.random.PRNGKey(2), (b, l, hkv, hd))
        got = att._chunked_causal_attention(q, k, v, q_block=8, kv_block=4)
        # Naive oracle
        import math
        kk = jnp.repeat(k, h // hkv, axis=2)
        vv = jnp.repeat(v, h // hkv, axis=2)
        s = jnp.einsum("blhd,bmhd->bhlm", q, kk) / math.sqrt(hd)
        mask = jnp.tril(jnp.ones((l, l), bool))
        s = jnp.where(mask[None, None], s, -1e30)
        want = jnp.einsum("bhlm,bmhd->blhd", jax.nn.softmax(s, -1), vv)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)

    def test_ssd_chunked_vs_reference(self):
        rng = np.random.default_rng(0)
        b, l, h, p, n = 2, 37, 4, 8, 16
        x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32) * .5
        bb = jnp.asarray(rng.standard_normal((b, l, 1, n)), jnp.float32) * .5
        cc = jnp.asarray(rng.standard_normal((b, l, 1, n)), jnp.float32) * .5
        dt = jax.nn.softplus(jnp.asarray(
            rng.standard_normal((b, l, h)), jnp.float32))
        ld = -dt * 0.3
        y_ref, s_ref = m2.ssd_reference(x, bb, cc, dt, ld)
        y, s = m2.ssd_chunked(x, bb, cc, dt, ld, chunk=8)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                                   atol=1e-4)

    def test_moe_vs_loop_oracle(self):
        cfg = moe_mod.MoEConfig(n_experts=8, top_k=2, d_model=16, d_ff=32,
                                capacity_factor=2.0)
        params = init_params(moe_mod.moe_specs(cfg, "float32"),
                             jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
        y, _ = moe_mod.moe_ffn(cfg, params, x)
        logits = x @ params["router"]
        probs = jax.nn.softmax(logits, -1)
        gates, idx = jax.lax.top_k(probs, 2)
        gates = gates / gates.sum(-1, keepdims=True)
        want = np.zeros((32, 16), np.float32)
        for t in range(32):
            for j in range(2):
                e = int(idx[t, j])
                up = x[t] @ params["w_up"][e]
                g = x[t] @ params["w_gate"][e]
                hid = jax.nn.silu(g) * up
                want[t] += float(gates[t, j]) * np.asarray(
                    hid @ params["w_down"][e])
        np.testing.assert_allclose(np.asarray(y), want, atol=2e-5)

    def test_decode_matches_forward_gqa(self):
        """Incremental decode == teacher-forced forward (tiny dense LM)."""
        cfg = get_arch("minitron-4b").make_smoke_config()
        params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(3))
        toks = jax.random.randint(jax.random.PRNGKey(4), (2, 10), 0,
                                  cfg.vocab)
        logits_full, _ = lm_mod.lm_forward(cfg, params, {"tokens": toks})
        last, caches = lm_mod.lm_prefill(cfg, params,
                                         {"tokens": toks[:, :-1]}, 16)
        np.testing.assert_allclose(np.asarray(last[:, 0]),
                                   np.asarray(logits_full[:, -2]), atol=2e-4)
        dec, _ = lm_mod.lm_decode(cfg, params, toks[:, -1:], caches)
        np.testing.assert_allclose(np.asarray(dec[:, 0]),
                                   np.asarray(logits_full[:, -1]), atol=2e-4)

    def test_jamba_layout(self):
        cfg = get_arch("jamba-v0.1-52b").make_config()
        kinds = lm_mod.layout(cfg)
        assert len(kinds) == 32
        assert sum(1 for k in kinds if k.mixer == "attn") == 4
        assert sum(1 for k in kinds if k.ffn == "moe") == 16
        segs = lm_mod.segments(cfg)
        assert len(segs) == 1 and segs[0][1] == 4  # period 8 x 4 steps

    def test_deepseek_segments(self):
        cfg = get_arch("deepseek-v3-671b").make_config()
        segs = lm_mod.segments(cfg)
        assert [(len(k), s) for k, s in segs] == [(1, 3), (1, 58)]
