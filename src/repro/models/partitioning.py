"""Logical sharding constraints for activations (MaxText-style).

Model code calls ``constrain(x, 'batch', 'seq', None)`` with *logical*
axis names; when a partitioning context is active (set by the launcher
/ dry-run around trace time), this resolves to
``jax.lax.with_sharding_constraint`` over the production mesh.  With no
context (unit tests, single-device smoke runs) it is the identity.

Without these anchors XLA's SPMD propagation can lose the batch
sharding through gather ops (token embedding lookups) and silently
replicate the whole forward pass — 16x the flops and catastrophic temp
memory on the 16x16 mesh.  (Found via the loop-aware HLO analysis;
recorded in EXPERIMENTS.md §Perf as baseline-fix #1.)
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["activation_context", "constrain"]

_STATE = threading.local()


@contextlib.contextmanager
def activation_context(mesh, rules: dict):
    """rules: logical activation axis -> mesh axis (or None)."""
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = (mesh, dict(rules))
    try:
        yield
    finally:
        _STATE.ctx = prev


def constrain(x: jax.Array, *logical_axes) -> jax.Array:
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    entries = []
    used: set = set()
    for dim, name in zip(x.shape, logical_axes):
        axis = rules.get(name) if name else None
        if axis is None:
            entries.append(None)
            continue
        names = axis if isinstance(axis, tuple) else (axis,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        if dim % size or any(n in used for n in names):
            entries.append(None)
        else:
            entries.append(axis)
            used.update(names)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
