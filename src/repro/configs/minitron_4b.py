"""minitron-4b — pruned Nemotron [arXiv:2407.14679; hf].

[dense] 32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
"""

from repro.configs.base import ArchDef
from repro.models.lm import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="minitron-4b",
        n_layers=32, d_model=3072, n_heads=24, n_kv=8, head_dim=128,
        d_ff=9216, vocab=256000,
        mixer="attn", ffn="dense", tie_embeddings=True,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="minitron-4b-smoke",
        n_layers=2, d_model=96, n_heads=6, n_kv=2, head_dim=16,
        d_ff=192, vocab=256, dtype="float32",
        mixer="attn", ffn="dense", q_block=16, kv_block=16, remat="none",
    )


ARCH = ArchDef(
    name="minitron-4b", family="dense", kind="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    source="arXiv:2407.14679; hf",
    notes="24 heads not divisible by model=16: attention heads replicate "
          "over the model axis; ffn/vocab TP-shard (planner fallback).",
)
