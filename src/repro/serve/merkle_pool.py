"""Merkle pool integrity: auditable roots and per-tenant membership proofs.

The deferred pool MAC and the cluster root (``sharded_pool``) are
*verifier-side* levels: a tenant has to trust that the host actually
runs ``deferred_pool_check`` and tears the process down on a failed
verdict.  This module adds the first **auditable** level of the
hierarchy — an incrementally-maintained Merkle tree over the per-page
MACs — so every tenant can hold an O(log n) membership proof for its
resident pages and check it against an attested root with *no pool
access and no host trust*:

    per-block MAC+VN  ->  deferred pool MAC  ->  Merkle root  ->  cluster root
    (read gate)           (XOR fold, in-jit)    (this module)     (compression
                                                                   over shard
                                                                   Merkle roots)

Design points:

* **Listener-driven.**  :class:`MerklePagePool` attaches to the
  engine's pool-listener interface (the same contract the sharded
  pool's mirror fold uses).  The listener itself is O(1) — it only
  records the freshest pool object; leaf hashing and path recompute
  are batched and amortized at ``_tick_end`` (:meth:`sync`), off the
  decode critical path, exactly like the deferred check.
* **Resync-by-assignment.**  A ``(None, new_pool)`` listener event —
  the wholesale re-adoption fired by ``_commit_repair`` after
  quarantine or a pool-MAC rebuild — schedules a from-scratch rebuild,
  never an incremental delta: tamper bypassed the setter, so no delta
  can be trusted.
* **Quarantine exclusion.**  Frames retired by the fault-containment
  layer hash to a distinguished *retired* leaf (not a data leaf over
  the scrubbed zero MAC), so the rebuilt tree provably excludes them
  and any pre-repair proof stops verifying.
* **Tenant binding.**  Each data leaf folds the owning tenant index
  into the hash, so a proof replayed by another tenant fails
  cryptographically, not just by label comparison.
* **Host-independent verification.**  :func:`verify_proof` depends on
  nothing but ``hashlib`` — a tenant can run it standalone.  Each of
  the five forgery classes in the threat model fails with a *distinct*
  error type (see the ``ProofError`` taxonomy).

The incremental update is the textbook one: a dirty leaf invalidates
exactly its root path, so a sync over ``d`` dirty pages recomputes at
most ``d * ceil(log2 n)`` interior nodes (shared ancestors are
deduplicated level by level).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Iterable, List, Optional, Sequence, Tuple

import numpy as np

MAC_BYTES = 8           # must match repro.core.mac.MAC_BYTES (asserted there)
HASH_BYTES = 32
PROOF_VERSION = 1

# Domain-separation tags: a leaf can never be confused with an interior
# node (classic second-preimage fix), a retired frame can never be
# presented as a data leaf, and the cluster compression can never be
# confused with an in-tree node.
_TAG_LEAF = b"\x00seda.leaf"
_TAG_RETIRED = b"\x01seda.retired"
_TAG_EMPTY = b"\x02seda.empty"
_TAG_NODE = b"\x03seda.node"
_TAG_CLUSTER = b"\x04seda.cluster"

_FREE_OWNER = -1        # owner index of unowned (free / cache) frames


def _u32(x: int) -> bytes:
    return int(x & 0xFFFFFFFF).to_bytes(4, "big")


def _sha(*parts: bytes) -> bytes:
    h = hashlib.sha256()
    for p in parts:
        h.update(p)
    return h.digest()


def leaf_hash(shard: int, index: int, owner: int, mac: bytes) -> bytes:
    """Data leaf: binds shard, frame index, owning tenant and page MAC."""
    if len(mac) != MAC_BYTES:
        raise ValueError(f"page MAC must be {MAC_BYTES} bytes, got {len(mac)}")
    return _sha(_TAG_LEAF, _u32(shard), _u32(index), _u32(owner), mac)


def retired_leaf(shard: int, index: int) -> bytes:
    """Leaf of a quarantined frame — excluded from the data tree."""
    return _sha(_TAG_RETIRED, _u32(shard), _u32(index))


def empty_leaf(shard: int, index: int) -> bytes:
    """Padding leaf (tree width is the next power of two)."""
    return _sha(_TAG_EMPTY, _u32(shard), _u32(index))


def node_hash(left: bytes, right: bytes) -> bytes:
    return _sha(_TAG_NODE, left, right)


def tree_depth(n_pages: int) -> int:
    """Path length of every proof over an ``n_pages``-frame pool."""
    if n_pages < 1:
        raise ValueError("n_pages must be >= 1")
    d, width = 0, 1
    while width < n_pages:
        width <<= 1
        d += 1
    return d


def build_tree(macs: np.ndarray, owners: np.ndarray,
               quarantined: np.ndarray, *, shard: int) -> List[List[bytes]]:
    """From-scratch tree over ``n_pages`` frames; the reference algebra.

    ``levels[0]`` are the (padded) leaves, ``levels[-1][0]`` the root.
    The incremental maintainer must be node-for-node identical to this
    (property-tested in ``tests/test_audit_proofs.py``).
    """
    n_pages = len(macs)
    width = 1 << tree_depth(n_pages)
    leaves = []
    for i in range(width):
        if i >= n_pages:
            leaves.append(empty_leaf(shard, i))
        elif quarantined[i]:
            leaves.append(retired_leaf(shard, i))
        else:
            leaves.append(leaf_hash(shard, i, int(owners[i]),
                                    bytes(macs[i])))
    levels = [leaves]
    while len(levels[-1]) > 1:
        prev = levels[-1]
        levels.append([node_hash(prev[2 * j], prev[2 * j + 1])
                       for j in range(len(prev) // 2)])
    return levels


def compress_roots(pairs: Sequence[Tuple[int, bytes]]) -> bytes:
    """Cluster root: ordered compression over active (shard, root) pairs.

    Binds value, order AND shard count — same contract as the pool-MAC
    CBC compression it sits beside, but hash-based so a tenant can
    recompute it host-independently from the published shard roots.
    """
    h = hashlib.sha256()
    h.update(_TAG_CLUSTER)
    h.update(_u32(len(pairs)))
    for shard, root in pairs:
        if len(root) != HASH_BYTES:
            raise ValueError("shard root must be a digest")
        h.update(_u32(shard))
        h.update(root)
    return h.digest()


# -- proof objects -------------------------------------------------------


class ProofError(Exception):
    """Base class: ``verify_proof`` failed.  Each forgery class in the
    threat model maps to a distinct subclass."""


class MalformedProofError(ProofError):
    """Structurally invalid proof (bad hex, out-of-range frame index,
    internally inconsistent tenant/owner fields)."""


class TenantMismatchError(ProofError):
    """Cross-tenant proof reuse: the proof names a different tenant
    than the verifying one (and the tenant is folded into every leaf,
    so relabeling the field breaks the leaf hash instead)."""


class PathLengthError(ProofError):
    """Truncated or extended sibling path: the path length does not
    match the tree depth implied by the pool geometry."""


class LeafMacError(ProofError):
    """The leaf MAC does not hash to the committed leaf digest
    (flipped / substituted page MAC)."""


class SiblingPathError(ProofError):
    """The sibling path does not fold to the stated root (swapped or
    substituted sibling)."""


class StaleRootError(ProofError):
    """The proof is internally consistent but speaks for a root the
    verifier no longer accepts (replay after rotation / repair)."""


class ClusterRootError(ProofError):
    """The cluster section does not recompute: the shard root is not
    bound into the published cluster root."""


@dataclasses.dataclass(frozen=True)
class PageProof:
    """O(log n) membership proof for one resident frame."""
    page: int                   # frame index (position in the leaf row)
    owner: int                  # tenant index folded into the leaf
    mac: str                    # page MAC, hex
    leaf: str                   # committed leaf digest, hex
    path: Tuple[str, ...]       # sibling digests, leaf -> root, hex

    def to_dict(self) -> dict:
        return {"page": self.page, "owner": self.owner, "mac": self.mac,
                "leaf": self.leaf, "path": list(self.path)}


@dataclasses.dataclass(frozen=True)
class AuditProof:
    """Per-tenant audit proof: every resident frame of one session /
    tenant on one shard, plus the shard root they verify against and
    (for cluster proofs) the shard-root set binding that root into the
    cluster root."""
    shard: int
    n_pages: int
    tenant: Optional[int]       # tenant index, None on single-tenant engines
    root: str                   # shard Merkle root, hex
    pages: Tuple[PageProof, ...]
    version: int = PROOF_VERSION
    cluster: Optional[dict] = None  # {"shard_roots": [[shard, hex], ...],
    #                                  "root": hex} — order is normative

    def to_dict(self) -> dict:
        d = {"version": self.version, "shard": self.shard,
             "n_pages": self.n_pages, "tenant": self.tenant,
             "root": self.root,
             "pages": [p.to_dict() for p in self.pages]}
        if self.cluster is not None:
            d["cluster"] = {"shard_roots": [[int(s), r] for s, r in
                                            self.cluster["shard_roots"]],
                            "root": self.cluster["root"]}
        return d


def proof_from_dict(d: dict) -> AuditProof:
    """Inverse of :meth:`AuditProof.to_dict` (checkpoint manifests)."""
    try:
        pages = tuple(PageProof(page=int(p["page"]), owner=int(p["owner"]),
                                mac=p["mac"], leaf=p["leaf"],
                                path=tuple(p["path"]))
                      for p in d["pages"])
        cluster = None
        if d.get("cluster") is not None:
            cluster = {"shard_roots": [(int(s), r) for s, r in
                                       d["cluster"]["shard_roots"]],
                       "root": d["cluster"]["root"]}
        return AuditProof(shard=int(d["shard"]), n_pages=int(d["n_pages"]),
                          tenant=(None if d.get("tenant") is None
                                  else int(d["tenant"])),
                          root=d["root"], pages=pages,
                          version=int(d.get("version", PROOF_VERSION)),
                          cluster=cluster)
    except (KeyError, TypeError, ValueError) as err:
        raise MalformedProofError(f"undecodable proof: {err}") from err


def _hex_digest(s: str, what: str) -> bytes:
    try:
        raw = bytes.fromhex(s)
    except (ValueError, TypeError) as err:
        raise MalformedProofError(f"{what} is not valid hex") from err
    if len(raw) != HASH_BYTES and what != "page MAC":
        raise MalformedProofError(f"{what} has wrong digest length")
    return raw


def verify_proof(proof: AuditProof, *, expected_root: Optional[str] = None,
                 tenant: Optional[int] = None) -> bool:
    """Host-independent proof verification (``hashlib`` only).

    Checks run in a fixed order so each forgery class fails with a
    distinct :class:`ProofError` subclass:

    1. structural decode            -> :class:`MalformedProofError`
    2. tenant binding (``tenant=``) -> :class:`TenantMismatchError`
    3. path length vs tree depth    -> :class:`PathLengthError`
    4. leaf MAC -> leaf digest      -> :class:`LeafMacError`
    5. path fold -> stated root     -> :class:`SiblingPathError`
    6. stated vs attested root      -> :class:`StaleRootError`
    7. cluster compression          -> :class:`ClusterRootError`

    Returns ``True`` (never ``False``) — failure is always an
    exception, so a caller cannot accidentally ignore a verdict.
    """
    if not isinstance(proof, AuditProof):
        raise MalformedProofError("not an AuditProof")
    if proof.version != PROOF_VERSION:
        raise MalformedProofError(f"unknown proof version {proof.version}")
    if proof.n_pages < 1:
        raise MalformedProofError("n_pages must be >= 1")
    if tenant is not None and proof.tenant != tenant:
        raise TenantMismatchError(
            f"proof speaks for tenant {proof.tenant}, verifier is {tenant}")
    depth = tree_depth(proof.n_pages)
    root = _hex_digest(proof.root, "root")
    for p in proof.pages:
        if not (0 <= p.page < proof.n_pages):
            raise MalformedProofError(f"frame {p.page} outside the pool")
        if proof.tenant is not None and p.owner != proof.tenant:
            raise MalformedProofError(
                f"frame {p.page} owner {p.owner} contradicts proof tenant "
                f"{proof.tenant}")
        if len(p.path) != depth:
            raise PathLengthError(
                f"frame {p.page}: path length {len(p.path)} != tree depth "
                f"{depth}")
        mac = _hex_digest(p.mac, "page MAC")
        committed = _hex_digest(p.leaf, "leaf digest")
        if leaf_hash(proof.shard, p.page, p.owner, mac) != committed:
            raise LeafMacError(
                f"frame {p.page}: page MAC does not hash to the committed "
                "leaf")
        node, idx = committed, p.page
        for sib_hex in p.path:
            sib = _hex_digest(sib_hex, "sibling digest")
            node = (node_hash(sib, node) if idx & 1
                    else node_hash(node, sib))
            idx >>= 1
        if node != root:
            raise SiblingPathError(
                f"frame {p.page}: sibling path does not fold to the stated "
                "root")
    if expected_root is not None and proof.root != expected_root:
        raise StaleRootError(
            "proof root is not the attested current root (stale replay "
            "after rotation or repair)")
    if proof.cluster is not None:
        pairs = [(int(s), _hex_digest(r, "shard root"))
                 for s, r in proof.cluster["shard_roots"]]
        if compress_roots(pairs).hex() != proof.cluster["root"]:
            raise ClusterRootError(
                "shard-root set does not compress to the stated cluster "
                "root")
        if (proof.shard, root) not in pairs:
            raise ClusterRootError(
                "proof's shard root is not bound into the cluster root")
    return True


# -- the incremental maintainer ------------------------------------------


class MerklePagePool:
    """Incrementally-maintained Merkle tree over one engine's page MACs.

    Attached via ``engine.attach_pool_listener``; the listener is O(1)
    (records the freshest pool object), and :meth:`sync` — called from
    ``_tick_end`` at the deferred-check cadence, and on demand before a
    proof or root read — pulls the (tiny) MAC table to the host, diffs
    it against the leaf mirror, and recomputes only the dirty paths.

    ``leaf_fn(pool)`` extracts the real-page MAC rows from a pool
    object (see ``kv_pages.merkle_leaf_macs``) so this module stays
    free of any jax dependency; ``owners_fn()`` and
    ``quarantined_fn()`` report the engine's host-side frame ownership
    and quarantine set at sync time.
    """

    def __init__(self, n_pages: int, *, shard: int = 0,
                 leaf_fn: Callable = None,
                 owners_fn: Optional[Callable] = None,
                 quarantined_fn: Optional[Callable] = None):
        if leaf_fn is None:
            raise ValueError("MerklePagePool needs a leaf_fn")
        self.n_pages = int(n_pages)
        self.shard = int(shard)
        self._leaf_fn = leaf_fn
        self._owners_fn = owners_fn
        self._quar_fn = quarantined_fn
        self._depth = tree_depth(self.n_pages)
        self._width = 1 << self._depth
        self._pool_obj = None
        self._pending = False       # a listener event since the last sync
        self._need_full = True      # resync-by-assignment / first build
        self._macs = np.zeros((self.n_pages, MAC_BYTES), np.uint8)
        self._owners = np.full(self.n_pages, _FREE_OWNER, np.int64)
        self._quar = np.zeros(self.n_pages, bool)
        self._levels: Optional[List[List[bytes]]] = None

    # -- listener side (hot path, O(1)) ----------------------------------

    def on_pool_update(self, old_pool, new_pool) -> None:
        """Pool-listener entry point (``listener(old, new)`` contract).

        ``old is None`` is the resync-by-assignment signal fired by
        ``_commit_repair``: the previous pool state cannot be trusted,
        so the next :meth:`sync` rebuilds from scratch instead of
        applying a delta.
        """
        self._pool_obj = new_pool
        self._pending = True
        if old_pool is None:
            self._need_full = True

    # -- sync / amortized maintenance ------------------------------------

    def _inputs(self):
        # Copies, not views: the mirrors (_macs/_owners/_quar) must stay
        # frozen at the last-synced state — np.asarray would alias a
        # caller-owned array and the dirty diff would never fire.
        macs = np.array(self._leaf_fn(self._pool_obj), np.uint8)
        if macs.shape != (self.n_pages, MAC_BYTES):
            raise ValueError(f"leaf_fn returned {macs.shape}, expected "
                             f"{(self.n_pages, MAC_BYTES)}")
        owners = (np.array(self._owners_fn(), np.int64)
                  if self._owners_fn is not None
                  else np.full(self.n_pages, _FREE_OWNER, np.int64))
        quar = np.zeros(self.n_pages, bool)
        if self._quar_fn is not None:
            ids = [p for p in self._quar_fn() if 0 <= p < self.n_pages]
            quar[ids] = True
        return macs, owners, quar

    def sync(self) -> Tuple[int, int]:
        """Fold pending pool state into the tree.

        Returns ``(root_updates, leaf_updates)``: 1 if the root was
        recomputed this call, and the number of leaves rehashed —
        these feed the ``merkle_root_updates`` / ``merkle_leaf_updates``
        counters.
        """
        if self._pool_obj is None:
            return (0, 0)
        macs, owners, quar = self._inputs()
        if self._need_full or self._levels is None:
            levels = build_tree(macs, owners, quar, shard=self.shard)
            changed = (self.n_pages if self._levels is None else
                       sum(a != b for a, b in
                           zip(levels[0], self._levels[0])))
            self._levels = levels
            self._macs, self._owners, self._quar = macs, owners, quar
            self._need_full = self._pending = False
            return (1, int(changed))
        dirty = np.nonzero((macs != self._macs).any(axis=1)
                           | (owners != self._owners)
                           | (quar != self._quar))[0]
        self._pending = False
        if dirty.size == 0:
            return (0, 0)
        leaves = self._levels[0]
        for i in dirty:
            i = int(i)
            leaves[i] = (retired_leaf(self.shard, i) if quar[i]
                         else leaf_hash(self.shard, i, int(owners[i]),
                                        bytes(macs[i])))
        touched = {int(i) for i in dirty}
        for level in range(self._depth):
            parents = {i >> 1 for i in touched}
            row, up = self._levels[level], self._levels[level + 1]
            for j in parents:
                up[j] = node_hash(row[2 * j], row[2 * j + 1])
            touched = parents
        self._macs, self._owners, self._quar = macs, owners, quar
        return (1, int(dirty.size))

    # -- roots / verification --------------------------------------------

    def root(self) -> bytes:
        self.sync()
        return self._levels[-1][0]

    def root_hex(self) -> str:
        return self.root().hex()

    def snapshot(self) -> List[List[bytes]]:
        """Copy of every tree level (node-for-node test support)."""
        self.sync()
        return [list(level) for level in self._levels]

    def verify_against(self, actual_macs: np.ndarray) -> bool:
        """True iff the maintained tree matches a from-scratch rebuild
        over the *actual* pool MACs — a pool state swapped in without
        the listener (direct ``_pool`` write) diverges here, the Merkle
        analogue of the mirror-vs-recompute root check."""
        self.sync()
        macs = np.asarray(actual_macs, np.uint8)
        rebuilt = build_tree(macs, self._owners, self._quar,
                             shard=self.shard)
        return rebuilt[-1][0] == self._levels[-1][0]

    # -- proofs -----------------------------------------------------------

    def page_proof(self, page: int) -> PageProof:
        self.sync()
        if not (0 <= page < self.n_pages):
            raise ValueError(f"frame {page} outside the pool")
        if self._quar[page]:
            raise ValueError(f"frame {page} is quarantined — retired "
                             "frames have no membership proof")
        path, idx = [], page
        for level in range(self._depth):
            path.append(self._levels[level][idx ^ 1].hex())
            idx >>= 1
        return PageProof(page=page, owner=int(self._owners[page]),
                         mac=bytes(self._macs[page]).hex(),
                         leaf=self._levels[0][page].hex(),
                         path=tuple(path))

    def audit_proof(self, pages: Iterable[int],
                    tenant: Optional[int] = None) -> AuditProof:
        """Membership proof for a session's resident frames.

        Every requested frame must be owned by ``tenant`` (when given)
        — issuing a proof over someone else's frames is refused at the
        source, not just rejected at verification."""
        self.sync()
        proofs = []
        for p in sorted(set(int(p) for p in pages)):
            pp = self.page_proof(p)
            if tenant is not None and pp.owner != tenant:
                raise ValueError(
                    f"frame {p} is owned by tenant {pp.owner}, not "
                    f"{tenant} — refusing to issue a cross-tenant proof")
            proofs.append(pp)
        return AuditProof(shard=self.shard, n_pages=self.n_pages,
                          tenant=tenant, root=self.root_hex(),
                          pages=tuple(proofs))
