"""Optional-dependency shim for hypothesis.

The tier-1 suite must collect and run without optional deps.  When
hypothesis is installed, the real decorators are re-exported; when it
is missing, ``@given`` tests are skipped individually while the
deterministic tests in the same modules keep running (a module-level
``pytest.importorskip`` would skip those too — e.g. the FIPS-197 /
SP 800-38A vectors in test_aes.py).
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal envs
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Attribute sink: st.integers(...), st.binary(...), ... -> None."""

        def __getattr__(self, name):
            def _strategy(*_args, **_kwargs):
                return None

            return _strategy

    st = _StrategyStub()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
