"""olmoe-1b-7b — 64 experts top-8 [arXiv:2409.02060; hf].

[moe] 16L d_model=2048 16H (GQA kv=16 = MHA) d_ff=1024 (per expert)
vocab=50304, MoE 64e top-8 on every layer.
"""

from repro.configs.base import ArchDef
from repro.models.lm import LMConfig
from repro.models.moe import MoEConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="olmoe-1b-7b",
        n_layers=16, d_model=2048, n_heads=16, n_kv=16, head_dim=128,
        d_ff=1024, vocab=50304,
        mixer="attn", ffn="moe", moe_every=1, tie_embeddings=True,
        moe=MoEConfig(n_experts=64, top_k=8, d_model=2048, d_ff=1024,
                      capacity_factor=1.25),
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="olmoe-1b-7b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=32, vocab=256, dtype="float32",
        mixer="attn", ffn="moe", moe_every=1,
        q_block=16, kv_block=16, remat="none",
        moe=MoEConfig(n_experts=8, top_k=2, d_model=64, d_ff=32,
                      capacity_factor=2.0),
    )


ARCH = ArchDef(
    name="olmoe-1b-7b", family="moe", kind="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    source="arXiv:2409.02060; hf",
    notes="64 experts EP-shard over model=16 (4/shard).",
)
