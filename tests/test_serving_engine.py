"""Batched secure serving engine: paged MAC-protected KV cache.

Covers the tentpole guarantees:
  * scheme parity — seda (and friends) produce token-identical output
    to the unprotected baseline and to the dense serve_step path;
  * partial-page dirty writes — decode re-MACs exactly the dirty page;
  * eviction under a full pool — preempted requests finish with the
    same greedy tokens;
  * tamper/replay — flipped ciphertext bytes and replayed pages fail
    the page-MAC gate; metadata tampering on pages outside the read
    set fails the deferred pool-level MAC.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.serve import kv_pages as kvp
from repro.serve.engine import IntegrityError, SecureServingEngine
from repro.serve.serve_step import (greedy_sample, make_decode_step,
                                    make_prefill_step)


@pytest.fixture(scope="module")
def smoke():
    arch = get_arch("minitron-4b")
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    return arch, cfg, params


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(0)
    return [list(map(int, rng.integers(1, 256, n))) for n in (5, 7, 9)]


def _engine(smoke, **kw):
    arch, cfg, params = smoke
    kw.setdefault("max_slots", 2)
    kw.setdefault("page_tokens", 4)
    kw.setdefault("pages_per_slot", 4)
    return SecureServingEngine(arch, cfg, params, **kw)


def _dense_baseline(smoke, prompt, gen_len, max_len=16):
    arch, cfg, params = smoke
    prefill = jax.jit(make_prefill_step(arch, cfg, max_len))
    decode = jax.jit(make_decode_step(arch, cfg))
    logits, caches = prefill(params,
                             {"tokens": jnp.asarray([prompt], jnp.int32)})
    tok = greedy_sample(logits)
    out = [int(tok[0, 0])]
    for _ in range(gen_len - 1):
        logits, caches = decode(params, tok, caches)
        tok = greedy_sample(logits)
        out.append(int(tok[0, 0]))
    return out


class TestSchemeParity:
    def test_seda_matches_unprotected_and_dense(self, smoke, prompts):
        dense = [_dense_baseline(smoke, p, 6) for p in prompts]
        for scheme in ("off", "seda"):
            eng = _engine(smoke, scheme=scheme)
            rids = [eng.submit(p, max_new_tokens=6) for p in prompts]
            done = eng.run()
            assert [done[r].generated for r in rids] == dense, scheme

    @pytest.mark.parametrize("scheme", ["sgx64", "mgx64", "seda512",
                                        "mgx512", "sgx512"])
    def test_all_schemes_token_identical(self, smoke, prompts, scheme):
        off = _engine(smoke, scheme="off")
        rids = [off.submit(p, max_new_tokens=4) for p in prompts[:2]]
        want = [off.run()[r].generated for r in rids]
        eng = _engine(smoke, scheme=scheme)
        rids = [eng.submit(p, max_new_tokens=4) for p in prompts[:2]]
        done = eng.run()
        assert [done[r].generated for r in rids] == want

    def test_fused_kernel_path_bit_identical(self, smoke, prompts):
        plain = _engine(smoke, scheme="seda", use_kernel=False)
        rid = plain.submit(prompts[0], max_new_tokens=5)
        want = plain.run()[rid].generated
        fused = _engine(smoke, scheme="seda", use_kernel=True)
        rid = fused.submit(prompts[0], max_new_tokens=5)
        assert fused.run()[rid].generated == want

    def test_mla_arch_serves(self):
        arch = get_arch("deepseek-v3-671b")
        cfg = arch.make_smoke_config()
        params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(1))
        eng = SecureServingEngine(arch, cfg, params, scheme="seda",
                                  max_slots=2, page_tokens=4,
                                  pages_per_slot=3)
        rng = np.random.default_rng(1)
        rids = [eng.submit(list(map(int, rng.integers(1, cfg.vocab, 5))),
                           max_new_tokens=4) for _ in range(2)]
        done = eng.run()
        assert all(len(done[r].generated) == 4 for r in rids)
        assert eng.deferred_check()


class TestDirtyPages:
    def test_partial_page_dirty_write_remacs_only_dirty_page(self, smoke):
        """A mid-page decode rewrites exactly one page's MAC and VN."""
        eng = _engine(smoke, scheme="seda", max_slots=1)
        eng.submit([3, 1, 4, 1, 5], max_new_tokens=6)  # 5 tokens: page 1 is
        eng.step()                                     # partially filled
        slot = eng.slots[0]
        macs_before = np.asarray(eng.pool.page_macs).copy()
        vns_before = np.asarray(eng.pool.page_vns).copy()
        dirty_pid = slot.pages[slot.length // eng.page_tokens]
        eng.step()
        macs_after = np.asarray(eng.pool.page_macs)
        vns_after = np.asarray(eng.pool.page_vns)
        changed = {int(i) for i in range(eng.n_pages)
                   if not (macs_before[i] == macs_after[i]).all()
                   or vns_before[i] != vns_after[i]}
        assert changed == {dirty_pid}
        assert eng.deferred_check()

    def test_page_boundary_allocates_and_macs_fresh_page(self, smoke):
        """Crossing into a new page MACs it for the first time."""
        eng = _engine(smoke, scheme="seda", max_slots=1)
        eng.submit([3, 1, 4, 1, 5, 9, 2], max_new_tokens=7)  # crosses at 8
        eng.step()                               # admit + first decode
        while eng.slots[0] is not None and eng.slots[0].length < 9:
            eng.step()
        assert len(eng.slots[0].pages) >= 3      # grew past page 2 boundary
        eng.run()
        assert eng.deferred_check()


class TestEviction:
    def test_eviction_under_full_pool_preserves_tokens(self, smoke, prompts):
        roomy = _engine(smoke, scheme="seda", max_slots=3,
                        n_pages=12)
        rids = [roomy.submit(p, max_new_tokens=6) for p in prompts]
        want = [roomy.run()[r].generated for r in rids]
        assert roomy.stats["preemptions"] == 0

        tight = _engine(smoke, scheme="seda", max_slots=3, n_pages=5)
        rids = [tight.submit(p, max_new_tokens=6) for p in prompts]
        done = tight.run()
        assert tight.stats["preemptions"] > 0
        assert [done[r].generated for r in rids] == want
        assert tight.n_free_pages == 5           # everything returned

    def test_oversized_request_rejected(self, smoke):
        eng = _engine(smoke, scheme="seda", max_slots=1, n_pages=2)
        with pytest.raises(ValueError):
            eng.submit(list(range(1, 12)), max_new_tokens=8)


class TestTamper:
    def test_ciphertext_flip_fails_page_gate(self, smoke, prompts):
        eng = _engine(smoke, scheme="seda", max_slots=1)
        eng.submit(prompts[0], max_new_tokens=6)
        eng.step()
        pid = eng.slots[0].pages[0]
        ct = eng.pool.cts[0]
        eng.pool = eng.pool._replace(
            cts=(ct.at[pid, 3].set(ct[pid, 3] ^ 0x5A),) + eng.pool.cts[1:])
        with pytest.raises(IntegrityError):
            eng.step()

    @pytest.mark.parametrize("scheme", ["sgx64", "mgx64"])
    def test_ciphertext_flip_fails_block_gate(self, smoke, prompts, scheme):
        eng = _engine(smoke, scheme=scheme, max_slots=1)
        eng.submit(prompts[0], max_new_tokens=6)
        eng.step()
        pid = eng.slots[0].pages[0]
        ct = eng.pool.cts[1]
        eng.pool = eng.pool._replace(
            cts=eng.pool.cts[:1] + (ct.at[pid, 0].set(ct[pid, 0] ^ 0x01),))
        with pytest.raises(IntegrityError):
            eng.step()

    def test_replayed_page_fails_vn_freshness(self, smoke, prompts):
        """Restoring an old (valid-at-the-time) ciphertext is caught:
        the on-chip VN moved on, so the MAC binding no longer holds."""
        eng = _engine(smoke, scheme="seda", max_slots=1)
        eng.submit([3, 1, 4, 1, 5], max_new_tokens=7)
        eng.step()
        slot = eng.slots[0]
        dirty_pid = slot.pages[slot.length // eng.page_tokens]
        old_row = np.asarray(eng.pool.cts[0][dirty_pid]).copy()
        eng.step()                                # rewrites the dirty page
        eng.pool = eng.pool._replace(
            cts=(eng.pool.cts[0].at[dirty_pid].set(jnp.asarray(old_row)),)
            + eng.pool.cts[1:])
        with pytest.raises(IntegrityError):
            eng.step()

    def test_evicted_page_metadata_tamper_fails_deferred_mac(self, smoke,
                                                             prompts):
        """Pages of an evicted (finished) request sit outside every read
        set, so the per-read gate never touches them — tampering there
        is caught by the deferred pool-level MAC (paper's model MAC)."""
        eng = _engine(smoke, scheme="seda", max_slots=1, defer_interval=0)
        rid = eng.submit(prompts[0], max_new_tokens=3)
        done = eng.run()
        assert done[rid].state == "finished"
        assert eng.deferred_check()
        evicted_pid = 0                           # freed back to the pool
        eng.pool = eng.pool._replace(
            page_macs=eng.pool.page_macs.at[evicted_pid, 0].set(
                eng.pool.page_macs[evicted_pid, 0] ^ 0xFF))
        assert not eng.deferred_check()


class TestPrefillBuckets:
    def test_bucketing_is_token_identical_and_caps_compiles(self, smoke,
                                                            prompts):
        exact = _engine(smoke, scheme="seda", prefill_buckets=False)
        rids = [exact.submit(p, max_new_tokens=5) for p in prompts]
        want = [exact.run()[r].generated for r in rids]
        assert exact.stats["prefill_compiles"] == 3   # one per length

        bucketed = _engine(smoke, scheme="seda")      # buckets on (default)
        assert bucketed.prefill_buckets
        rids = [bucketed.submit(p, max_new_tokens=5) for p in prompts]
        done = bucketed.run()
        assert [done[r].generated for r in rids] == want
        # Lengths 5 and 7 share the 8-bucket; 9 rides the 16-bucket.
        assert bucketed.stats["prefill_compiles"] == 2

    def test_power_of_two_bucket_capped_at_max_len(self, smoke):
        from repro.serve.engine import _bucket_len
        assert _bucket_len(5, 16) == 8
        assert _bucket_len(8, 16) == 8
        assert _bucket_len(9, 16) == 16
        assert _bucket_len(9, 12) == 12


class TestPageCountBuckets:
    """Touched-page bucketed decode: the two-level page table."""

    ALL_SCHEMES = ["off", "seda", "seda512", "mgx64", "mgx512", "sgx64",
                   "sgx512"]

    def test_bucket_helpers(self):
        import types
        assert kvp.page_count_bucket(1, 8) == 1
        assert kvp.page_count_bucket(3, 8) == 4
        assert kvp.page_count_bucket(5, 8) == 8
        assert kvp.page_count_bucket(9, 8) == 8
        tab = kvp.TwoLevelPageTable(2, 8)
        entry = types.SimpleNamespace(pages=[4, 5, 6])
        tab.install(0, entry)
        win = tab.window(2)
        assert win.shape == (2, 2)
        assert win[0].tolist() == [4, 5] and win[1].tolist() == [-1, -1]
        # The directory reads entries LIVE: wholesale list reassignment
        # (migration, or host-state tampering a gate must see) shows up
        # in the next window.
        entry.pages = [9]
        assert tab.window(2)[0].tolist() == [9, -1]
        assert tab.bucket_for([7, 11], 4) == 4    # 11 // 4 + 1 = 3 -> 4
        tab.clear(0)
        assert (tab.window(2) == -1).all()

    @pytest.mark.parametrize("scheme", ALL_SCHEMES)
    def test_long_context_parity_across_bucket_boundaries(self, smoke,
                                                          prompts, scheme):
        """Decodes whose contexts straddle the pow2 page-count buckets
        (2 -> 4 -> 8 pages here) stay token-identical to the
        unprotected engine for every scheme."""
        kw = dict(page_tokens=4, pages_per_slot=8, max_slots=2)
        off = _engine(smoke, scheme="off", **kw)
        rids = [off.submit(p, max_new_tokens=14) for p in prompts[:2]]
        want = [off.run()[r].generated for r in rids]
        # Contexts reach 19-21 tokens: page need goes 2..6, so the
        # decode crossed the 2-, 4- and 8-page buckets.
        assert off.stats["decode_bucket_compiles"] >= 3
        eng = _engine(smoke, scheme=scheme, **kw)
        rids = [eng.submit(p, max_new_tokens=14) for p in prompts[:2]]
        done = eng.run()
        assert [done[r].generated for r in rids] == want

    def test_short_context_reads_fewer_pages_than_pool(self, smoke,
                                                       prompts):
        """A short live context in a large pool must not pay for the
        pool: per-step page reads follow the touched-page bucket."""
        eng = _engine(smoke, scheme="seda", page_tokens=4, pages_per_slot=16,
                      max_slots=2)
        rids = [eng.submit(p[:5], max_new_tokens=4) for p in prompts[:2]]
        done = eng.run()
        assert all(len(done[r].generated) == 4 for r in rids)
        steps = eng.stats["decode_steps"]
        all_resident = steps * 2 * 16          # the pre-bucketing window
        assert eng.stats["decode_page_reads"] < all_resident / 4

    def test_bucket_compiles_bounded_by_log2(self, smoke, prompts):
        eng = _engine(smoke, scheme="seda", page_tokens=4, pages_per_slot=8,
                      max_slots=2)
        rids = [eng.submit(p, max_new_tokens=14) for p in prompts[:2]]
        done = eng.run()
        assert all(len(done[r].generated) == 14 for r in rids)
        # pow2 buckets cap compiles at log2(pages_per_slot) + 1 per
        # (bucket, uniform) family — here the single-key family only.
        assert eng.stats["decode_bucket_compiles"] <= 4

    def test_bucketed_cost_analysis_scales_down(self, smoke):
        """HLO bytes accessed of the bucketed decode shrink vs. the
        all-resident window (the measurable gather/crypt/MAC saving)."""
        eng = _engine(smoke, scheme="seda", page_tokens=4, pages_per_slot=8,
                      max_slots=2)
        small = eng.decode_cost_analysis(bucket=1).get("bytes accessed", 0)
        full = eng.decode_cost_analysis().get("bytes accessed", 0)
        if small and full:          # cost analysis is backend-dependent
            assert small < full


class TestFusedWritePath:
    """The one-pass fused write (dirty-page re-encrypt + re-MAC in a
    single Pallas visit) must be invisible except for speed: pool
    bytes, MACs and tokens bit-identical to the vmapped/unfused
    reference, across the 2-/4-/8-page bucket boundaries, for every
    scheme."""

    ALL_SCHEMES = ["off", "seda", "seda512", "mgx64", "mgx512", "sgx64",
                   "sgx512"]

    def _run(self, smoke, prompts, scheme, use_kernel):
        kw = dict(page_tokens=4, pages_per_slot=8, max_slots=2)
        eng = _engine(smoke, scheme=scheme, use_kernel=use_kernel, **kw)
        rids = [eng.submit(p, max_new_tokens=14) for p in prompts[:2]]
        done = eng.run()
        return [done[r].generated for r in rids], eng

    @pytest.mark.parametrize("scheme", ALL_SCHEMES)
    def test_dirty_page_bit_identity_across_bucket_boundaries(self, smoke,
                                                              prompts,
                                                              scheme):
        """Contexts straddling the 2-/4-/8-page buckets: the kernel
        engine's final pool (ciphertext, page MACs, VNs, deferred pool
        MAC) is byte-for-byte the reference engine's."""
        want, ref = self._run(smoke, prompts, scheme, use_kernel=False)
        got, fused = self._run(smoke, prompts, scheme, use_kernel=True)
        assert got == want
        assert ref.stats["decode_bucket_compiles"] >= 3  # crossed buckets
        for a, b in zip(ref.pool.cts, fused.pool.cts):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(ref.pool.block_macs, fused.pool.block_macs):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(ref.pool.page_macs),
                                      np.asarray(fused.pool.page_macs))
        np.testing.assert_array_equal(np.asarray(ref.pool.page_vns),
                                      np.asarray(fused.pool.page_vns))
        np.testing.assert_array_equal(np.asarray(ref.pool.pool_mac),
                                      np.asarray(fused.pool.pool_mac))
        assert fused.deferred_check()

    def test_fused_write_ticks_counted_only_on_kernel_path(self, smoke,
                                                           prompts):
        """Every kernel-capable tick reseals through the fused write
        (seda + use_kernel); the reference engine and non-capable
        schemes (wide blocks, T-AES) report zero."""
        _, ref = self._run(smoke, prompts, "seda", use_kernel=False)
        _, fused = self._run(smoke, prompts, "seda", use_kernel=True)
        assert ref.stats["fused_write_ticks"] == 0
        assert fused.stats["fused_write_ticks"] > 0
        assert fused.stats["fused_write_ticks"] == \
            fused.stats["decode_steps"]
        _, wide = self._run(smoke, prompts, "seda512", use_kernel=True)
        assert wide.stats["fused_write_ticks"] == 0    # > 11 segments
        _, taes = self._run(smoke, prompts, "mgx64", use_kernel=True)
        assert taes.stats["fused_write_ticks"] == 0    # T-AES, no B-AES

    def test_fused_written_page_tamper_still_caught(self, smoke, prompts):
        """A page resealed by the fused write keeps its gate: flipping
        one ciphertext byte fails the next read's verification."""
        eng = _engine(smoke, scheme="seda", max_slots=1, use_kernel=True)
        eng.submit(prompts[0], max_new_tokens=6)
        eng.step()
        eng.step()                    # dirty page rewritten (fused path)
        assert eng.stats["fused_write_ticks"] > 0
        slot = eng.slots[0]
        dirty_pid = slot.pages[(slot.length - 1) // eng.page_tokens]
        ct = eng.pool.cts[0]
        eng.pool = eng.pool._replace(
            cts=(ct.at[dirty_pid, 3].set(ct[dirty_pid, 3] ^ 0x5A),)
            + eng.pool.cts[1:])
        with pytest.raises(IntegrityError):
            eng.step()


class TestLatencyStats:
    def test_run_result_carries_percentiles(self, smoke, prompts):
        eng = _engine(smoke, scheme="off")
        rids = [eng.submit(p, max_new_tokens=5) for p in prompts]
        done = eng.run()
        lat = done.latency
        assert set(lat) == {"p50_ttft_ticks", "p95_ttft_ticks",
                            "p99_ttft_ticks", "p50_ticks_per_token",
                            "p95_ticks_per_token", "p99_ticks_per_token"}
        assert lat["p50_ttft_ticks"] >= 0
        assert lat["p95_ttft_ticks"] >= lat["p50_ttft_ticks"]
        assert lat["p99_ttft_ticks"] >= lat["p95_ttft_ticks"]
        assert lat["p50_ticks_per_token"] > 0
        for rid in rids:
            req = done[rid]
            assert req.first_tick is not None
            assert req.done_tick >= req.first_tick >= req.submit_tick


class TestPoolUnit:
    """kv_pages roundtrip without a model in the loop."""

    def _spec_and_tree(self, scheme="seda", use_kernel=False):
        from repro.models.attention import KVCache
        tree = [[KVCache(
            k=jax.ShapeDtypeStruct((2, 2, 16, 2, 8), jnp.float32),
            v=jax.ShapeDtypeStruct((2, 2, 16, 2, 8), jnp.float32),
            length=jax.ShapeDtypeStruct((2,), jnp.int32))]]
        spec = kvp.build_page_spec(tree, scheme=scheme, page_tokens=4,
                                   n_pages=6, max_slots=2, max_len=16,
                                   use_kernel=use_kernel)
        return spec, tree

    @pytest.mark.parametrize("scheme", ["off", "seda", "sgx64", "mgx512"])
    def test_write_read_roundtrip(self, keys, rng, scheme):
        spec, _ = self._spec_and_tree(scheme)
        pool = kvp.init_pool(spec)
        data = [jnp.asarray(rng.standard_normal((2, 1, 16, 2, 8)),
                            jnp.float32) for _ in spec.leaves]  # k and v
        page_ids = jnp.asarray([0, 1, 2, 3], jnp.int32)
        pool = kvp.write_prefill(pool, spec, keys, page_ids, data, 4,
                                 jnp.uint32(1))
        table = jnp.asarray([[0, 1, 2, 3], [-1, -1, -1, -1]], jnp.int32)
        lengths = jnp.asarray([16, 0], jnp.int32)
        dense, ok = kvp.read_pages(pool, spec, keys, table, lengths)
        assert bool(ok)
        for got, want in zip(dense, data):
            np.testing.assert_array_equal(np.asarray(got[:, 0]),
                                          np.asarray(want[:, 0]))
            # Slot 1 is unallocated: its view must be zero, not garbage.
            assert (np.asarray(got[:, 1]) == 0).all()

    def test_page_blocks_aligned_to_scheme_granularity(self):
        for scheme in ("seda", "seda512", "sgx64"):
            spec, _ = self._spec_and_tree(scheme)
            bb = spec.cfg.block_bytes
            for leaf in spec.leaves:
                assert leaf.lp_bytes % bb == 0
                assert leaf.page_bytes == leaf.steps * leaf.lp_bytes
                assert leaf.n_blocks == leaf.page_bytes // bb
