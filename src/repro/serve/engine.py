"""Continuous-batching secure serving engine over the paged KV pool.

The engine multiplexes many requests over ``max_slots`` decode lanes
and a shared pool of MAC-protected KV pages (:mod:`repro.serve.kv_pages`):

* **admission** — waiting requests are prefetched into a free slot when
  the pool has pages for their prompt; prefill runs per request (with
  power-of-two length bucketing so prefill compiles once per bucket,
  not once per distinct prompt length) and the resulting cache pages
  are encrypted + MACed into the pool;
* **decode** — one jitted computation per tick batches every running
  slot: gather pages -> decrypt -> verify touched pages -> attend/append
  -> re-encrypt + re-MAC only the dirty page per slot.  All schemes from
  :data:`repro.core.secure_exec.SCHEMES` run through the same step.
  The step runs over a pow2 **page-count-bucketed** window from the
  two-level page table (:class:`repro.serve.kv_pages.TwoLevelPageTable`)
  picked host-side per tick, so protection work scales with the pages
  a tick actually touches (one compile per bucket), not with
  ``pages_per_slot``;
* **growth / eviction** — slots allocate pages on demand as decodes
  lengthen; under a full pool the youngest running request is preempted
  (pages freed, request requeued, KV recomputed on re-admission), so
  long-running decodes never deadlock the pool;
* **deferred verification** — the pool-level MAC (the model-MAC level
  of :mod:`repro.core.multilevel`) is checked off the critical path,
  every ``defer_interval`` ticks, amortizing it across the batch.

**Multi-tenant mode.**  Constructed with a
:class:`repro.tenancy.registry.TenantRegistry`, the engine becomes a
shared-accelerator serving plane with per-tenant cryptographic
domains:

* requests must carry a :class:`~repro.tenancy.registry.SessionHandle`
  into :meth:`submit`; the registry validates it and pins the request
  to its tenant;
* every KV page is encrypted + MACed under its owner's (tenant, epoch)
  keys, with the identity folded into the RePA binding — a page
  written by tenant A fails verification when read under tenant B's
  keys or under a stale epoch;
* admission is **weighted-fair** (stride scheduling over tenant
  virtual time, weighted by ``Tenant.weight``) and **quota-gated**: a
  tenant at its page quota queues its own requests rather than
  evicting anyone else's;
* eviction is **tenant-scoped**: a tenant under memory pressure
  preempts its *own* youngest request before touching others';
* :meth:`rotate` bumps a tenant's key epoch **live**: resident pages
  re-encrypt to the new epoch lazily on their next dirty write, reads
  of previous-epoch pages keep verifying against the retained key, and
  pages about to fall out of the retention window are **eagerly
  resealed** (one jitted decrypt-old → re-encrypt-new crossing, via
  :func:`repro.serve.kv_pages.reseal_pages`) — no slot is preempted
  and no KV is recomputed.

**Sharded mode.**  Constructed with ``shard_id``/``n_shards`` (and
optionally ``device``), the engine becomes one shard of a
:class:`repro.serve.cluster.ClusterEngine`: its pool's RePA bindings
and CTR counters carry the shard id (pages are cryptographically
pinned to this device), its tick is split into dispatch/collect halves
so the cluster can overlap every shard's decode in one multi-device
dispatch, and pool updates are observable (``attach_pool_listener``)
so the cluster can roll per-shard deferred pool MACs into a root MAC.

**Fault containment.**  Constructed with ``fault_tolerance`` (``True``
or a :class:`repro.serve.faults.RecoveryPolicy`), an integrity failure
no longer aborts the process: :meth:`step` catches it, localizes the
failing page(s) by re-reading every resident page through the raw
verify path, permanently quarantines the condemned physical frames
(never reallocated; scrubbed from the free list, the prefix cache and
the deferred pool MAC), and preempts only the affected slot for
**secure-recompute recovery** — re-admission re-prefills the prompt
plus all already-emitted tokens, so the recovered stream is
token-identical to a fault-free run.  A bounded re-read retry
distinguishes transient faults from persistent tamper; a retry budget
with exponential backoff bounds how often one session may recover
before it is declared dead (``sessions_lost``).  Detection stays loud
(audit events, counters, SLO integration) while the blast radius
shrinks to one session.

Host-side scheduling state (free list, queues, lengths, page epochs)
is plain Python; everything that touches tensor data stays inside jit.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mac as mac_mod
from repro.core import multilevel
from repro.core import secure_memory as sm
from repro.core import vn as vn_mod
from repro.core.secure_exec import SCHEMES
from repro.models import lm as lm_mod
from repro.obs import audit as audit_mod
from repro.obs import metrics as metrics_mod
from repro.obs import profiler as profiler_mod
from repro.obs import trace as trace_mod
from repro.serve import kv_pages as kvp
from repro.serve import merkle_pool as mkp
from repro.serve.serve_step import greedy_sample

assert mkp.MAC_BYTES == mac_mod.MAC_BYTES  # jax-free module, own literal

__all__ = ["IntegrityError", "Request", "RunResult", "SecureServingEngine",
           "SubmitAPI", "SubmitRequest", "latency_percentiles"]


class IntegrityError(RuntimeError):
    """A MAC gate (page/block) or the deferred pool MAC failed."""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    state: str = "waiting"          # waiting | running | finished | failed
    n_evictions: int = 0
    # Fault-containment state: recovering marks a session preempted by
    # an integrity failure (cleared — and counted — on re-admission);
    # hold_until delays re-admission for exponential backoff;
    # integrity_retries counts recoveries against the retry budget.
    recovering: bool = False
    hold_until: int = 0
    integrity_retries: int = 0
    tenant_idx: Optional[int] = None
    submit_tick: int = 0
    first_tick: Optional[int] = None    # tick the first token appeared
    done_tick: Optional[int] = None
    share_prefix: bool = True       # may use / populate the prefix cache
    submit_time: float = 0.0        # perf_counter at submit (ttft_seconds)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


@dataclasses.dataclass
class SubmitRequest:
    """The admission argument object of the unified ``submit()``.

    One dataclass consumed by both :class:`SecureServingEngine` and
    :class:`repro.serve.cluster.ClusterEngine` (via :class:`SubmitAPI`),
    so the two surfaces cannot drift apart again.  ``share_prefix=False``
    opts a request out of the shared-prefix cache in both directions:
    it neither reads cached pages nor seals its own prefix in.
    """

    prompt: list
    max_new_tokens: int = 16
    session: Optional[object] = None    # tenancy SessionHandle | None
    share_prefix: bool = True


class SubmitAPI:
    """The one keyword-only ``submit()`` shared by engine and cluster.

    Subclasses implement ``_submit(SubmitRequest) -> rid``; this mixin
    owns argument handling, so ``Engine.submit`` and
    ``ClusterEngine.submit`` are the same surface by construction.
    Legacy positional calls (``submit(prompt, max_new_tokens)``) keep
    working through a thin :class:`DeprecationWarning` shim.
    """

    def _submit(self, request: SubmitRequest) -> int:
        raise NotImplementedError

    def submit(self, request=None, /, *legacy, **kw) -> int:
        """Queue one request; returns its rid.

        Preferred forms::

            eng.submit(SubmitRequest(prompt=toks, max_new_tokens=8))
            eng.submit(prompt=toks, max_new_tokens=8, session=sess)

        The legacy positional form ``submit(toks, 8)`` still works but
        warns.
        """
        if isinstance(request, SubmitRequest):
            if legacy or kw:
                raise TypeError("submit(SubmitRequest) takes no other "
                                "arguments")
            return self._submit(request)
        if request is not None:
            warnings.warn(
                "positional submit(prompt, ...) is deprecated; pass a "
                "SubmitRequest or keyword arguments",
                DeprecationWarning, stacklevel=2)
            if "prompt" in kw:
                raise TypeError("submit() got prompt twice")
            kw["prompt"] = request
            if legacy:
                if len(legacy) > 1 or "max_new_tokens" in kw:
                    raise TypeError("submit() takes at most prompt and "
                                    "max_new_tokens positionally")
                kw["max_new_tokens"] = legacy[0]
        elif legacy:
            raise TypeError("submit() got positional arguments but no "
                            "prompt")
        return self._submit(SubmitRequest(**kw))


class RunResult(dict):
    """``{rid: Request}`` plus aggregate ``latency`` percentiles."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.latency: dict = {}


def latency_percentiles(requests) -> dict:
    """p50/p95/p99 latency over finished requests.

    Interpolated (``np.percentile``, linear) rather than nearest-rank:
    cluster benchmarks read tail latency off handfuls of requests,
    where nearest-rank p95/p99 degenerate to the max and hide real
    movement between runs.
    """
    ttft, tpt = [], []
    for r in requests:
        if r.state != "finished" or r.first_tick is None:
            continue
        ttft.append(r.first_tick - r.submit_tick)
        if r.done_tick is not None and len(r.generated) > 1:
            tpt.append((r.done_tick - r.first_tick) / (len(r.generated) - 1))
    if not ttft:
        return {}
    out = {}
    for q in (50, 95, 99):
        out[f"p{q}_ttft_ticks"] = float(
            np.percentile(ttft, q, method="linear"))
    for q in (50, 95, 99):
        if tpt:
            out[f"p{q}_ticks_per_token"] = float(
                np.percentile(tpt, q, method="linear"))
    return out


@dataclasses.dataclass
class _Slot:
    req: Request
    length: int                     # KV tokens resident (host mirror)
    pages: list                     # owned pool page ids, in token order
    admit_seq: int
    tenant: object = None           # tenancy.registry.Tenant | None
    page_epochs: list = dataclasses.field(default_factory=list)
    # Shared-prefix state: the first ``shared_n`` entries of ``pages``
    # are read-only prefix-cache pages (epoch word PREFIX_ROLE), pinned
    # via ``shared_entries``; ``replay`` holds the prompt tokens the
    # skipped prefill still owes the decode loop (teacher-forced — the
    # sampled token of the LAST replay step is the first real output).
    shared_n: int = 0
    shared_entries: list = dataclasses.field(default_factory=list)
    replay: deque = dataclasses.field(default_factory=deque)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _bucket_len(n: int, cap: int) -> int:
    """Round ``n`` up to the next power of two, capped at ``cap``."""
    b = 1
    while b < n:
        b <<= 1
    return min(b, cap)


class SecureServingEngine(SubmitAPI):
    """Batched secure decoding with paged, MAC-protected KV residency.

    Typical single-tenant use::

        eng = SecureServingEngine(arch, cfg, params, scheme="seda",
                                  max_slots=4, page_tokens=8,
                                  pages_per_slot=4, n_pages=12)
        rids = [eng.submit(prompt=prompt, max_new_tokens=8)
                for prompt in prompts]
        done = eng.run()            # RunResult: {rid: Request} + .latency

    Multi-tenant use::

        reg = TenantRegistry(KeyHierarchy(0))
        reg.register("alice", weight=2.0, page_quota=8)
        eng = SecureServingEngine(arch, cfg, params, registry=reg, ...)
        sess = reg.open_session("alice")
        eng.submit(prompt=prompt, max_new_tokens=8, session=sess)
        eng.rotate("alice")         # live key rotation
        done = eng.run()

    With ``prefix_cache=True`` (registry required) the engine keeps a
    content-addressed :class:`repro.serve.kv_pages.PrefixCache`: a
    submitted prompt whose leading pages were already sealed by an
    earlier same-tenant request skips their prefill entirely — the
    shared pages are installed read-only in the slot directory, the
    remaining prompt tokens are teacher-forced through the normal
    batched decode (token-identical to a full prefill), and the first
    dirty write to a shared page triggers a copy-on-write reseal into a
    private page.  Cross-tenant sharing happens only through the
    explicit :meth:`share_prefix` reseal.
    """

    def __init__(self, arch, cfg, params, *, scheme: str = "seda",
                 max_slots: int = 4, page_tokens: int = 8,
                 pages_per_slot: int = 8, n_pages: Optional[int] = None,
                 keys: Optional[sm.SecureKeys] = None,
                 use_kernel: bool = False, defer_interval: int = 16,
                 eos_id: Optional[int] = None,
                 verify_every_step: bool = True,
                 registry=None, rotate_every: int = 0,
                 prefill_buckets: Optional[bool] = None,
                 shard_id: int = 0, n_shards: int = 1,
                 device=None, preempt_hook=None,
                 prefix_cache: bool = False,
                 prefix_cache_pages: Optional[int] = None,
                 fault_tolerance=None,
                 merkle: bool = True,
                 trace=None, audit=None):
        if arch.kind != "lm":
            raise ValueError("the paged serving engine supports decoder-only "
                             "LMs (enc-dec serving stays on serve_step)")
        if scheme not in SCHEMES:
            raise KeyError(f"unknown scheme {scheme!r}")
        if rotate_every and registry is None:
            raise ValueError("rotate_every needs a tenant registry — there "
                             "is no key hierarchy to rotate without one")
        if prefix_cache and registry is None:
            raise ValueError("prefix_cache needs a tenant registry — cache "
                             "pages are sealed under per-tenant cache keys")
        self.arch, self.cfg = arch, cfg
        self.scheme = scheme
        self.max_slots = max_slots
        self.page_tokens = page_tokens
        self.pages_per_slot = pages_per_slot
        self.max_len = page_tokens * pages_per_slot
        if n_pages is None:
            n_pages = max_slots * pages_per_slot
        self.n_pages = n_pages
        self.keys = keys if keys is not None else sm.SecureKeys.derive(0)
        self.defer_interval = defer_interval
        self.eos_id = eos_id
        self.verify_every_step = verify_every_step
        self.registry = registry
        self.rotate_every = rotate_every
        self.shard_id = shard_id
        self.n_shards = n_shards
        self._device = device
        # Called as preempt_hook(request) on eviction; returning True
        # means the caller (the cluster scheduler) took ownership and
        # the request must NOT be requeued locally — it may be re-routed
        # to a less loaded shard instead.
        self._preempt_hook = preempt_hook
        # fault_tolerance=None keeps the strict discipline (an
        # IntegrityError escapes step()/run() and aborts); True or a
        # RecoveryPolicy turns on quarantine + secure-recompute
        # recovery (see the module docstring).
        self.ft = None
        if fault_tolerance:
            from repro.serve.faults import RecoveryPolicy
            self.ft = (RecoveryPolicy() if fault_tolerance is True
                       else fault_tolerance)
        self.params = (params if device is None
                       else jax.device_put(params, device))

        cache_tree = lm_mod.cache_specs(cfg, max_slots, self.max_len)
        flat, self.treedef = jax.tree_util.tree_flatten(cache_tree)
        paged = kvp.paged_flags(cache_tree)
        lengths = kvp.length_flags(cache_tree)
        self.paged_idx = [i for i, f in enumerate(paged) if f]
        self.len_leaves = [(i, flat[i].shape[0])
                           for i, f in enumerate(lengths) if f]
        self.onchip_idx = [i for i in range(len(flat))
                           if not paged[i] and not lengths[i]]
        self.n_leaves = len(flat)
        self.spec = kvp.build_page_spec(
            cache_tree, scheme=scheme, page_tokens=page_tokens,
            n_pages=n_pages, max_slots=max_slots, max_len=self.max_len,
            use_kernel=use_kernel, shard=shard_id, n_shards=n_shards)
        self.page_io = kvp.PageIO(self.spec, self.keys)
        self.prefix_cache = None
        if prefix_cache:
            if self.onchip_idx:
                raise ValueError(
                    "prefix_cache is unavailable for archs with recurrent "
                    "on-chip state (Mamba SSM/conv): the skipped prefill's "
                    "state cannot be reconstructed from cached KV pages")
            cap = (prefix_cache_pages if prefix_cache_pages is not None
                   else max(1, n_pages // 4))
            self.prefix_cache = kvp.PrefixCache(page_tokens, cap)
        self.policy = (multilevel.SEDA_DEFAULT
                       if SCHEMES[scheme].verify == "layer"
                       else multilevel.SGX_LIKE if SCHEMES[scheme].emulate_tree
                       else multilevel.MGX_LIKE)
        # Length bucketing is safe when every cache leaf is either paged
        # (read path zeroes positions >= length) or a length mirror;
        # recurrent on-chip state (Mamba SSM/conv) would absorb the pad
        # tokens, so those archs keep exact-length prefill.
        if prefill_buckets is None:
            prefill_buckets = not self.onchip_idx
        self.prefill_buckets = prefill_buckets

        # Device state.
        self._pool_listeners: list = []
        pool = kvp.init_pool(self.spec)
        onchip = [jnp.zeros(flat[i].shape, flat[i].dtype)
                  for i in self.onchip_idx]
        if device is not None:
            pool = jax.device_put(pool, device)
            onchip = [jax.device_put(a, device) for a in onchip]
        self.pool = pool
        self.onchip = onchip
        self._ok_accum = jnp.asarray(True)

        # Host scheduling state.
        self.waiting: deque = deque()           # single-tenant FIFO
        self._tenant_waiting: dict = {}         # tenant idx -> deque
        self._vtime: dict = {}                  # tenant idx -> virtual time
        self._rotate_rr = 0
        self.slots: list = [None] * max_slots
        self.free_pages: list = list(range(n_pages))
        # Physical frames permanently retired after a localized
        # integrity failure: never on the free list, never reallocated.
        self.quarantined: set = set()
        self.requests: dict = {}
        self._next_rid = 0
        self._admit_seq = 0
        self._epoch = 0
        self.tick = 0
        self._prefill_shapes: set = set()
        self._init_obs(trace, audit)

        # Auditable Merkle level over the page MACs: listener-driven,
        # O(1) on the hot path, batched into ``_tick_end``.  ``merkle=
        # False`` keeps only the verifier-side folds (the bench uses it
        # to price the maintenance against the plain CBC-MAC root).
        self.merkle = None
        if merkle:
            self.merkle = mkp.MerklePagePool(
                self.n_pages, shard=shard_id,
                leaf_fn=lambda pool: kvp.merkle_leaf_macs(pool, self.spec),
                owners_fn=self._page_owners,
                quarantined_fn=lambda: self.quarantined)
            self.attach_pool_listener(self.merkle.on_pool_update)
            self.merkle.on_pool_update(None, self.pool)

        # Two-level page table: the slot directory (level 1) feeds pow2
        # page-count-bucketed decode windows (level 2); the decode step
        # compiles once per (bucket, uniform) variant on demand.
        self.page_table = kvp.TwoLevelPageTable(max_slots, pages_per_slot)
        self._decode_fns: dict = {}
        self._prefill_fn = jax.jit(self._build_prefill_fn())
        self._writers: dict = {}
        self._resealers: dict = {}
        self._copiers: dict = {}
        self._page_readers: dict = {}
        self._page_writers: dict = {}
        if registry is not None:
            # Rotations repair every engine sharing the registry, no
            # matter which one (or which operator call) triggered them:
            # the pre hook reseals pages that would leave the retained
            # window (while the dying epoch's keys are still banked),
            # the post hook preempts anything a reseal could not save.
            registry.attach_rotation_hook(self._pre_rotation, pre=True)
            registry.attach_rotation_hook(self._on_rotation)

    # -- pool indirection (sharded-pool observability) ----------------------

    @property
    def pool(self) -> kvp.PagedKVPool:
        return self._pool

    @pool.setter
    def pool(self, new_pool: kvp.PagedKVPool) -> None:
        old = getattr(self, "_pool", None)
        self._pool = new_pool
        for listener in self._pool_listeners:
            listener(old, new_pool)

    def attach_pool_listener(self, listener) -> None:
        """``listener(old_pool, new_pool)`` runs on every pool update —
        the cluster's sharded pool mirrors per-shard deferred MACs into
        its root MAC this way, without syncing the device."""
        self._pool_listeners.append(listener)

    # -- observability (metrics / tracing / audit) ---------------------------

    def _init_obs(self, trace, audit) -> None:
        """Wire the observability layer (:mod:`repro.obs`).

        The metrics registry is always on — its counters ARE the old
        ``stats`` dict, one attribute bump per event — and gauges are
        lazy callbacks sampled only at :meth:`snapshot` time.  The span
        tracer and the wall-clock phase histograms only exist when
        ``trace`` was passed (``True`` or a
        :class:`~repro.obs.trace.SpanTracer`): the tick phases are then
        wrapped per-instance, so a default engine pays zero timing
        calls on its hot path.  ``audit`` (``True`` or a shared
        :class:`~repro.obs.audit.AuditLog`) enables the hash-chained
        security event log.
        """
        self.metrics = metrics_mod.MetricsRegistry()
        for name, help_ in metrics_mod.ENGINE_COUNTERS.items():
            self.metrics.counter(name, help_)
        self._stats = metrics_mod.StatsView(self.metrics)
        g = metrics_mod.ENGINE_GAUGES
        self.metrics.gauge("pool_free_pages", g["pool_free_pages"],
                           fn=lambda: len(self.free_pages))
        self.metrics.gauge("pool_pages_total", g["pool_pages_total"],
                           fn=lambda: self.n_pages)
        self.metrics.gauge("slots_active", g["slots_active"],
                           fn=lambda: sum(1 for s in self.slots
                                          if s is not None))
        self.metrics.gauge("waiting_requests", g["waiting_requests"],
                           fn=self._n_waiting)
        if self.registry is not None:
            self.metrics.gauge(
                "tenant_resident_pages", g["tenant_resident_pages"],
                label="tenant",
                fn=lambda: {
                    self.registry.by_index(i).tenant_id:
                        self.tenant_resident_pages(i)
                    for i in range(self.registry.n_tenants)})
        if self.prefix_cache is not None:
            self.metrics.gauge("prefix_cache_pages",
                               g["prefix_cache_pages"],
                               fn=lambda: self.prefix_cache.pages_used)
            self.metrics.gauge("prefix_cache_refs", g["prefix_cache_refs"],
                               fn=lambda: self.prefix_cache.total_refs)
        # Device-cost profiler gauges sample the profile() cache only —
        # an engine that never called profile() exposes empty dicts and
        # never compiles anything at snapshot time.
        self._cost_profiles: dict = {}

        def _profile_gauge(attr):
            return lambda: {
                f"{b}{'u' if u else ''}": getattr(p, attr)
                for (b, u), p in sorted(self._cost_profiles.items())}

        self.metrics.gauge(
            "protection_overhead_ratio", g["protection_overhead_ratio"],
            label="bucket", fn=_profile_gauge("overhead_bytes_ratio"))
        self.metrics.gauge(
            "protection_overhead_flops_ratio",
            g["protection_overhead_flops_ratio"],
            label="bucket", fn=_profile_gauge("overhead_flops_ratio"))
        self.metrics.gauge(
            "roofline_utilization", g["roofline_utilization"],
            label="bucket",
            fn=lambda: {
                f"{b}{'u' if u else ''}":
                    p.roofline().get("utilization", 0.0)
                for (b, u), p in sorted(self._cost_profiles.items())})
        h = metrics_mod.ENGINE_HISTOGRAMS
        self._ttft_ticks = self.metrics.histogram("ttft_ticks",
                                                  h["ttft_ticks"])
        self._ttft_seconds = self.metrics.histogram("ttft_seconds",
                                                    h["ttft_seconds"])
        self._bucket_hist = self.metrics.histogram("decode_bucket",
                                                   h["decode_bucket"])
        # isinstance first: an EMPTY shared log is falsy (len == 0) but
        # must still be adopted — the cluster hands shards a fresh one.
        if isinstance(audit, audit_mod.AuditLog):
            self.audit = audit
        elif audit:
            self.audit = audit_mod.AuditLog()
        else:
            self.audit = None
        self.tracer = None
        if trace:
            self.tracer = (trace if isinstance(trace, trace_mod.SpanTracer)
                           else trace_mod.SpanTracer(pid=self.shard_id))
            self._instrument_phases()
        # kv_pages-level integrity verdict hook: every host-synced MAC
        # gate verdict (decode read, reseal, CoW, cache insert/share,
        # migration, deferred checks) lands in the counters no matter
        # which crossing produced it.
        self.page_io.verdict_hooks.append(self._on_verdict)

    def _on_verdict(self, ok: bool, op: str, ctx: dict) -> None:
        self.stats["integrity_verdicts"] += 1
        if not ok:
            self.stats["integrity_failures"] += 1

    def _observe_ttft(self, req: Request) -> None:
        self._ttft_ticks.observe(req.first_tick - req.submit_tick)
        if req.submit_time:
            self._ttft_seconds.observe(time.perf_counter() - req.submit_time)

    def _instrument_phases(self) -> None:
        """Per-instance wrap of the tick phases with spans + histograms.

        Instance attributes shadow the class methods, so both
        ``step()`` and a cluster driving the phases directly hit the
        instrumented versions — and an engine without a tracer never
        executes a single timing call.
        """
        h = metrics_mod.ENGINE_HISTOGRAMS
        tracer = self.tracer

        def timed(span_name, fn, hist):
            def wrapper(*a, **kw):
                t0 = time.perf_counter_ns()
                try:
                    return fn(*a, **kw)
                finally:
                    t1 = time.perf_counter_ns()
                    tracer.add(span_name, t0, t1, {"tick": self.tick})
                    hist.observe((t1 - t0) / 1e9)
            return wrapper

        for name in ("_tick_begin", "_decode_dispatch", "_decode_collect",
                     "_tick_end"):
            key = f"phase{name}_seconds"
            hist = self.metrics.histogram(key, h[key])
            setattr(self, name, timed(name.lstrip("_"), getattr(self, name),
                                      hist))
        tick_hist = self.metrics.histogram("tick_seconds",
                                           h["tick_seconds"])
        self.step = timed("tick", self.step, tick_hist)

    @property
    def stats(self):
        """The counters under the old dict API (see
        :class:`repro.obs.metrics.StatsView`)."""
        return self._stats

    def _audit(self, event: str, **fields) -> None:
        """Append one security event (no-op without an audit log)."""
        if self.audit is not None:
            self.audit.append(event, shard=self.shard_id,
                              scheme=self.scheme, tick=self.tick, **fields)
            self.stats["audit_events"] += 1

    def _integrity_fail(self, msg: str, **ctx) -> IntegrityError:
        """Audit + build (the caller raises) one integrity failure.

        ``ctx`` (op, tenant, slot, page/pages…) rides on the exception
        as ``err.ctx`` so the fault-containment layer can quarantine
        the named pages without re-localizing."""
        self._audit("integrity_error", detail=msg, **ctx)
        err = IntegrityError(msg)
        err.ctx = dict(ctx)
        return err

    def snapshot(self) -> dict:
        """JSON-able metrics snapshot (gauges sampled now)."""
        return self.metrics.snapshot(labels={"shard": str(self.shard_id)}
                                     if self.n_shards > 1 else None)

    def prometheus(self) -> str:
        """Prometheus text exposition of this engine's metrics."""
        return self.metrics.prometheus(
            labels={"shard": str(self.shard_id)}
            if self.n_shards > 1 else None)

    def export_trace(self, path: Optional[str] = None) -> dict:
        """Chrome trace-event JSON of the recorded phase spans."""
        if self.tracer is None:
            raise ValueError("engine was built without trace=...")
        return self.tracer.export(path)

    # -- traced builders ----------------------------------------------------

    def _merge_cache_leaves(self, dense, onchip, lengths):
        leaves = [None] * self.n_leaves
        for j, idx in enumerate(self.paged_idx):
            leaves[idx] = dense[j]
        for idx, steps in self.len_leaves:
            leaves[idx] = jnp.broadcast_to(lengths[None, :],
                                           (steps, self.max_slots))
        for j, idx in enumerate(self.onchip_idx):
            leaves[idx] = onchip[j]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def _decode_fn_for(self, bucket: int, uniform: bool = False):
        """The jitted decode step for one pow2 page-count bucket.

        One compile per (bucket, uniform) pair — bounded by
        2 * (log2(pages_per_slot) + 1) variants over an engine's life.
        """
        key = (bucket, uniform)
        if key not in self._decode_fns:
            self.stats["decode_bucket_compiles"] += 1
            self._decode_fns[key] = jax.jit(
                self._build_decode_fn(bucket, uniform))
        return self._decode_fns[key]

    def _build_decode_fn(self, bucket: int, uniform: bool = False):
        cfg, io = self.cfg, self.page_io
        tenant_mode = self.registry is not None

        def core(params, pool, onchip, page_table, lengths, active, tokens,
                 epoch, read_ctx, write_ctx):
            dense, ok = io.read(pool, page_table, lengths, read_ctx, uniform)
            caches = self._merge_cache_leaves(dense, onchip, lengths)
            logits, new_caches = lm_mod.lm_decode(cfg, params, tokens, caches)
            tok = greedy_sample(logits)                    # (S, 1)
            new_leaves = jax.tree_util.tree_leaves(new_caches)
            vn = vn_mod.kv_page_vn(epoch)
            new_pool = io.write_dirty(
                pool, page_table,
                [new_leaves[i] for i in self.paged_idx], lengths, active, vn,
                write_ctx, uniform)
            new_onchip = []
            for j, idx in enumerate(self.onchip_idx):
                leaf = new_leaves[idx]
                keep = active.reshape((1, self.max_slots)
                                      + (1,) * (leaf.ndim - 2))
                new_onchip.append(jnp.where(keep, leaf, onchip[j]))
            return new_pool, new_onchip, tok, ok

        if not tenant_mode:
            def decode_fn(params, pool, onchip, page_table, lengths, active,
                          tokens, epoch):
                return core(params, pool, onchip, page_table, lengths,
                            active, tokens, epoch, None, None)
            return decode_fn

        def decode_fn(params, pool, onchip, page_table, lengths, active,
                      tokens, epoch, bank, key_idx, owners, key_epochs,
                      cur_key_idx, cur_epochs):
            read_ctx = kvp.PageKeyCtx.make(
                bank, key_idx.reshape(-1),
                jnp.repeat(owners, bucket), key_epochs.reshape(-1))
            write_ctx = kvp.PageKeyCtx.make(bank, cur_key_idx, owners,
                                            cur_epochs)
            return core(params, pool, onchip, page_table, lengths, active,
                        tokens, epoch, read_ctx, write_ctx)

        return decode_fn

    def _build_prefill_fn(self):
        cfg, max_len = self.cfg, self.max_len

        def prefill_fn(params, tokens, last_pos):       # tokens: (1, Lp)
            logits, caches = lm_mod.lm_prefill(cfg, params,
                                               {"tokens": tokens}, max_len,
                                               last_pos=last_pos)
            leaves = jax.tree_util.tree_leaves(caches)
            return (greedy_sample(logits),
                    [leaves[i] for i in self.paged_idx],
                    [leaves[i] for i in self.onchip_idx])

        return prefill_fn

    def _writer(self, n_write_pages: int):
        if n_write_pages not in self._writers:
            spec, keys = self.spec, self.keys

            if self.registry is None:
                def write(pool, page_ids, paged_leaves, epoch):
                    vn = vn_mod.kv_page_vn(epoch)
                    return kvp.write_prefill(pool, spec, keys, page_ids,
                                             paged_leaves, n_write_pages, vn)
            else:
                def write(pool, page_ids, paged_leaves, epoch, ctx):
                    vn = vn_mod.kv_page_vn(epoch)
                    return kvp.write_prefill(pool, spec, keys, page_ids,
                                             paged_leaves, n_write_pages, vn,
                                             ctx)

            self._writers[n_write_pages] = jax.jit(write)
        return self._writers[n_write_pages]

    # Migration halves (used by the cluster engine): decrypt+verify N
    # whole pages on THIS shard / re-protect N transferred pages into
    # THIS shard's pool.  Split in two so the plaintext can hop devices
    # between the dispatches.

    def _page_reader(self, n: int):
        if n not in self._page_readers:
            spec, keys = self.spec, self.keys

            if self.registry is None:
                def read(pool, page_ids):
                    return kvp.read_pages_raw(pool, spec, keys, page_ids)
            else:
                def read(pool, page_ids, bank, rows, owners, epochs):
                    ctx = kvp.PageKeyCtx.make(bank, rows, owners, epochs)
                    return kvp.read_pages_raw(pool, spec, keys, page_ids,
                                              ctx)

            self._page_readers[n] = jax.jit(read)
        return self._page_readers[n]

    def _page_writer(self, n: int):
        if n not in self._page_writers:
            spec, keys = self.spec, self.keys

            if self.registry is None:
                def write(pool, page_ids, leaf_pages, epoch):
                    vn = vn_mod.kv_page_vn(epoch)
                    real = page_ids < spec.n_pages
                    return kvp.write_pages(pool, spec, keys, page_ids,
                                           leaf_pages, vn, real)
            else:
                def write(pool, page_ids, leaf_pages, epoch, bank, rows,
                          owners, epochs):
                    ctx = kvp.PageKeyCtx.make(bank, rows, owners, epochs)
                    vn = vn_mod.kv_page_vn(epoch)
                    real = page_ids < spec.n_pages
                    return kvp.write_pages(pool, spec, keys, page_ids,
                                           leaf_pages, vn, real, ctx)

            self._page_writers[n] = jax.jit(write)
        return self._page_writers[n]

    # -- public API ---------------------------------------------------------

    def _submit(self, request: SubmitRequest) -> int:
        prompt = [int(t) for t in request.prompt]
        max_new_tokens = request.max_new_tokens
        session = request.session
        if not prompt or max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and max_new_tokens>=1")
        total = len(prompt) + max_new_tokens
        if total > self.max_len:
            raise ValueError(f"prompt+max_new_tokens={total} exceeds "
                             f"max_len={self.max_len}")
        worst_pages = _ceil_div(total, self.page_tokens)
        if worst_pages > min(self.pages_per_slot, self.n_pages):
            raise ValueError(f"request needs up to {worst_pages} pages; pool "
                             f"has {self.n_pages} (per-slot cap "
                             f"{self.pages_per_slot})")
        tenant = None
        if self.registry is not None:
            if session is None:
                raise PermissionError("multi-tenant engine: submit() needs a "
                                      "registry session handle")
            tenant = self.registry.validate(session)
            if worst_pages > tenant.page_quota:
                raise ValueError(
                    f"request needs up to {worst_pages} pages; tenant "
                    f"{tenant.tenant_id!r} quota is {tenant.page_quota}")
        elif session is not None:
            raise ValueError("session handle given but the engine has no "
                             "tenant registry")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, prompt, max_new_tokens, submit_tick=self.tick,
                      share_prefix=bool(request.share_prefix),
                      submit_time=time.perf_counter())
        self.requests[rid] = req
        if tenant is not None:
            req.tenant_idx = tenant.index
            if not self._tenant_active(tenant.index):
                self._activate_vtime(tenant.index)
            self._tenant_waiting.setdefault(tenant.index,
                                            deque()).append(req)
        else:
            self.waiting.append(req)
        return rid

    def _tenant_active(self, index: int) -> bool:
        """Tenant has queued or running work (stride-scheduler sense)."""
        if self._tenant_waiting.get(index):
            return True
        return any(s is not None and s.tenant is not None
                   and s.tenant.index == index for s in self.slots)

    def _activate_vtime(self, index: int) -> None:
        """Re-anchor an (in)active tenant's virtual time on activation.

        Standard WFQ no-credit-for-idle rule: a tenant entering the
        backlog starts at max(its own virtual time, the system virtual
        time), approximated by the minimum virtual time of currently
        active tenants (or the maximum ever reached when the system is
        idle).  Without this, a late-arriving tenant would start at 0
        and monopolize admission until it "caught up" with incumbents.
        """
        active = [v for j, v in self._vtime.items()
                  if j != index and self._tenant_active(j)]
        if active:
            floor = min(active)
        else:
            floor = max(self._vtime.values(), default=0.0)
        self._vtime[index] = max(self._vtime.get(index, 0.0), floor)

    def rotate(self, tenant_id: str) -> int:
        """Live key rotation for one tenant (lazy re-encryption).

        Bumps the tenant's epoch in the registry.  Pages written under
        the *previous* epoch keep verifying (its keys stay in the
        bank); each re-encrypts to the new epoch on its next dirty
        write.  Before any key material moves, every attached engine's
        pre-rotation hook (:meth:`_pre_rotation`) eagerly reseals pages
        that would leave the retained window — decrypt under the dying
        epoch, re-encrypt under the current one, in one jitted crossing
        — so no slot is preempted and no KV recomputed.
        """
        if self.registry is None:
            raise ValueError("rotate() needs a tenant registry")
        return self.registry.rotate(tenant_id)

    def _page_owners(self) -> np.ndarray:
        """Per-frame owning tenant index (-1 = free / unowned).

        Fed into the Merkle leaves at sync time so every membership
        proof is tenant-bound; frames of two tenants can never swap
        proofs even with byte-identical MACs.  Same-tenant prefix
        sharing keeps a single owner, and cross-tenant sharing reseals
        into the destination's own frames, so the map is single-valued
        by construction.
        """
        owners = np.full(self.n_pages, -1, np.int64)
        for s in self.slots:
            if s is None or s.tenant is None:
                continue
            for p in s.pages:
                owners[p] = s.tenant.index
        return owners

    def audit_proof(self, session=None, *, rid: Optional[int] = None):
        """O(log n) membership proof for a session's resident frames.

        Returns a :class:`repro.serve.merkle_pool.AuditProof` — leaf
        MACs, sibling paths, shard id and the current shard Merkle root
        — which the tenant verifies host-independently with
        :func:`repro.serve.merkle_pool.verify_proof`.  On a
        multi-tenant engine the proof covers every resident frame of
        the session's tenant (narrow with ``rid=``); on a single-tenant
        engine it covers every resident frame.
        """
        if self.merkle is None:
            raise ValueError("audit_proof() needs the Merkle level "
                             "(engine built with merkle=False)")
        tenant = None
        if rid is not None:
            slot = next((s for s in self.slots
                         if s is not None and s.req.rid == rid), None)
            if slot is None:
                raise KeyError(f"request {rid} has no resident slot")
            tenant = slot.tenant
        elif self.registry is not None:
            if session is None:
                raise PermissionError("multi-tenant engine: audit_proof() "
                                      "needs a session handle")
            tenant = self.registry.validate(session)
        pages: list = []
        for s in self.slots:
            if s is None:
                continue
            if rid is not None and s.req.rid != rid:
                continue
            if tenant is not None and (s.tenant is None
                                       or s.tenant.index != tenant.index):
                continue
            pages.extend(s.pages)
        self._merkle_sync()
        proof = self.merkle.audit_proof(
            pages, tenant=None if tenant is None else tenant.index)
        self.stats["audit_proofs"] += 1
        self._audit("audit_proof",
                    tenant=None if tenant is None else tenant.tenant_id,
                    pages=len(proof.pages), root=proof.root)
        return proof

    def share_prefix(self, tokens, *, from_session, to_session) -> int:
        """Explicitly reseal one tenant's cached prefix for another.

        The ONLY cross-tenant sharing path: a plain cache match never
        crosses tenants (entries are keyed and sealed per tenant, so a
        borrowed page id simply fails its MAC gate).  Here the operator
        presents valid sessions for BOTH tenants; the source tenant's
        cached chain covering ``tokens`` is decrypt-verified under the
        source cache binding and re-sealed page-by-page under the
        destination tenant's cache binding, then indexed on the
        destination's own chain.  Returns the number of pages shared.
        """
        if self.prefix_cache is None:
            raise ValueError("share_prefix() needs prefix_cache=True")
        src = self.registry.validate(from_session)
        dst = self.registry.validate(to_session)
        tokens = [int(t) for t in tokens]
        pc = self.prefix_cache
        src_chain = pc.match(src.index, tokens)
        if not src_chain:
            return 0
        covered = sum(e.n_tokens for e in src_chain)
        matched_dst, missing = pc.missing(dst.index, tokens[:covered])
        if not missing:
            return 0            # already cached for dst (or partial leaf)
        m = len(matched_dst)    # chunk-aligned: dst already holds m chunks
        src_entries = src_chain[m:]
        short = pc.free_capacity()
        if short < len(missing):
            self._free(pc.reclaim(len(missing) - short))
        k = min(len(missing), pc.free_capacity(), len(self.free_pages))
        if k == 0:
            return 0
        missing, src_entries = missing[:k], src_entries[:k]
        dst_pages = [self.free_pages.pop() for _ in range(k)]
        n = max(self.pages_per_slot, k)
        src_ids = np.full((n,), self.spec.scratch_page, np.int32)
        dst_ids = np.full((n,), self.spec.scratch_page, np.int32)
        src_ids[:k] = [e.page_id for e in src_entries]
        dst_ids[:k] = dst_pages
        src_rows = np.full((n,), self.registry.cache_row(src.index), np.int32)
        dst_rows = np.full((n,), self.registry.cache_row(dst.index), np.int32)
        role = np.full((n,), kvp.PREFIX_ROLE, np.uint32)
        new_pool, ok = self._copier(n)(
            self.pool, self._bank(), jnp.asarray(src_ids),
            jnp.asarray(dst_ids), jnp.asarray(src_rows), jnp.asarray(role),
            jnp.full((n,), src.index, jnp.uint32), jnp.asarray(dst_rows),
            jnp.asarray(role), jnp.full((n,), dst.index, jnp.uint32),
            self._next_epoch())
        if not self.page_io.report_verdict(ok, "prefix_share"):
            self._free(dst_pages)
            raise self._integrity_fail(
                f"reseal-on-share {src.tenant_id!r} -> {dst.tenant_id!r} "
                f"failed source verification", op="prefix_share",
                tenant=src.tenant_id, to_tenant=dst.tenant_id,
                pages=[int(e.page_id) for e in src_entries])
        self.pool = new_pool
        parent = matched_dst[-1] if matched_dst else None
        for (key, n_tok), page_id in zip(missing, dst_pages):
            parent = pc.insert(key, parent, page_id, n_tok)
        self.stats["prefix_shared_pages"] += k
        self._audit("prefix_share", tenant=src.tenant_id,
                    to_tenant=dst.tenant_id, pages=k)
        return k

    def _pre_rotation(self, tenant, new_epoch: int) -> None:
        """Eagerly reseal pages about to fall out of the key window.

        Runs while the dying epoch's keys are still in the bank.  All
        such pages across this engine's slots are resealed to the
        tenant's CURRENT epoch (which stays retained after the bump) in
        one batched ``reseal_pages`` dispatch per slot.
        """
        oldest_after = new_epoch - self.registry.retain + 1
        cur = tenant.current_epoch
        for i, slot in enumerate(self.slots):
            if slot is None or slot.tenant is not tenant:
                continue
            # Cache-bound pages (epoch word PREFIX_ROLE) live outside
            # the epoch window: their keys never rotate.
            stale = [j for j, e in enumerate(slot.page_epochs)
                     if not (e & kvp.PREFIX_ROLE) and e < oldest_after]
            if not stale:
                continue
            self._reseal_slot(i, stale, cur)

    def _reseal_slot(self, slot_idx: int, page_pos: list,
                     to_epoch: int) -> None:
        """Reseal the given page positions of one slot to ``to_epoch``."""
        slot = self.slots[slot_idx]
        tenant = slot.tenant
        n = self.pages_per_slot                       # padded/bucketed size
        page_ids = np.full((n,), self.spec.scratch_page, np.int32)
        old_rows = np.zeros((n,), np.int32)
        old_epochs = np.zeros((n,), np.uint32)
        new_row = self.registry.key_row(tenant.index, to_epoch)
        for k, j in enumerate(page_pos):
            page_ids[k] = slot.pages[j]
            old_epochs[k] = slot.page_epochs[j]
            old_rows[k] = self.registry.key_row(tenant.index,
                                                slot.page_epochs[j])
        owners = np.full((n,), tenant.index, np.uint32)
        new_pool, ok = self._resealer(n)(
            self.pool, self._bank(), jnp.asarray(page_ids),
            jnp.asarray(old_rows), jnp.asarray(old_epochs),
            jnp.asarray(owners),
            jnp.full((n,), new_row, jnp.int32),
            jnp.full((n,), np.uint32(to_epoch), jnp.uint32),
            self._next_epoch())
        # Gate BEFORE committing: a failed decrypt means the old bytes
        # were tampered, and storing their reseal would launder them
        # under fresh, valid MACs.
        if not self.page_io.report_verdict(ok, "reseal"):
            raise self._integrity_fail(
                f"reseal of slot {slot_idx} pages {page_pos} failed "
                f"verification (tenant {tenant.tenant_id!r})",
                op="reseal", tenant=tenant.tenant_id, slot=slot_idx,
                pages=[int(slot.pages[j]) for j in page_pos])
        self.pool = new_pool
        for j in page_pos:
            slot.page_epochs[j] = to_epoch
        self.stats["reseals"] += 1
        self._audit("reseal", tenant=tenant.tenant_id, slot=slot_idx,
                    pages=len(page_pos), to_epoch=to_epoch)

    def _resealer(self, n: int):
        if n not in self._resealers:
            spec, keys = self.spec, self.keys

            def reseal(pool, bank, page_ids, old_rows, old_epochs, owners,
                       new_rows, new_epochs, epoch):
                old_ctx = kvp.PageKeyCtx.make(bank, old_rows, owners,
                                              old_epochs)
                new_ctx = kvp.PageKeyCtx.make(bank, new_rows, owners,
                                              new_epochs)
                vn = vn_mod.kv_page_vn(epoch)
                return kvp.reseal_pages(pool, spec, keys, page_ids, vn,
                                        old_ctx, new_ctx)

            self._resealers[n] = jax.jit(reseal)
        return self._resealers[n]

    def _on_rotation(self, tenant, new_epoch: int) -> None:
        """Post-rotation hook: preempt anything a reseal missed.

        After an eager reseal nothing should be left outside the
        window; this is the belt-and-braces fallback (e.g. a slot whose
        page-epoch mirror was tampered between the hooks)."""
        oldest_retained = new_epoch - self.registry.retain + 1
        for i, slot in enumerate(self.slots):
            if (slot is not None and slot.tenant is tenant
                    and any(not (e & kvp.PREFIX_ROLE) and e < oldest_retained
                            for e in slot.page_epochs)):
                self._preempt(i)
        self.stats["rotations"] += 1
        self._audit("rotation", tenant=tenant.tenant_id,
                    new_epoch=new_epoch)

    def step(self) -> list:
        """One scheduler tick: admit, grow/evict, batched decode.

        Returns the requests that finished during this tick.  The tick
        is split into :meth:`_tick_begin` (host scheduling + prefill),
        dispatch/collect decode halves, and :meth:`_tick_end` (deferred
        verification), so a cluster scheduler can interleave the phases
        of many shard engines — dispatching every shard's decode before
        blocking on any of them.
        """
        finished: list = []
        if self.ft is None:
            active_idx = self._tick_begin(finished)
            if active_idx:
                pending = self._decode_dispatch(active_idx)
                self._decode_collect(active_idx, pending, finished)
            self._tick_end()
            return finished
        # Fault-contained tick: an IntegrityError raised by any phase
        # (admission reseal/CoW/cache-insert, stale-epoch page-table
        # checks, the decode MAC gate, the deferred pool check) is
        # localized and quarantined instead of escaping.  Skipping the
        # remainder of a phase for one tick is token-invariant: no
        # slot's bookkeeping advanced for the skipped work.
        try:
            active_idx = self._tick_begin(finished)
        except IntegrityError as err:
            self._contain_error(err)
            active_idx = []
        try:
            if active_idx:
                pending = self._decode_dispatch(active_idx)
                self._decode_collect(active_idx, pending, finished)
        except IntegrityError as err:
            self._contain_error(err)
        try:
            self._tick_end()
        except IntegrityError as err:
            self._contain_error(err)
        return finished

    def _tick_begin(self, finished: list) -> list:
        """Advance the tick: rotation policy, admission, growth.

        Returns the slot indices active for this tick's decode."""
        self.tick += 1
        if (self.registry is not None and self.rotate_every
                and self.tick % self.rotate_every == 0
                and self.registry.n_tenants):
            idx = self._rotate_rr % self.registry.n_tenants
            self._rotate_rr += 1
            self.rotate(self.registry.by_index(idx).tenant_id)
        self._admit(finished)
        self._ensure_growth()
        if self.prefix_cache is not None:
            self._ensure_cow()
        return [i for i, s in enumerate(self.slots) if s is not None]

    def _tick_end(self) -> None:
        if (self.policy.deferred_model_mac and self.defer_interval
                and self.tick % self.defer_interval == 0):
            self._deferred_check()
        # Merkle maintenance shares the deferred cadence but not the
        # scheme gate: audit proofs exist for every scheme (the page-MAC
        # table is part of the pool under all of them).
        if (self.merkle is not None and self.defer_interval
                and self.tick % self.defer_interval == 0):
            self._merkle_sync()

    def _merkle_sync(self) -> None:
        roots, leaves = self.merkle.sync()
        self.stats["merkle_root_updates"] += roots
        self.stats["merkle_leaf_updates"] += leaves

    def run(self, max_ticks: int = 100_000) -> RunResult:
        """Drive ticks until every submitted request finished.

        Returns a :class:`RunResult`: ``{rid: Request}`` for finished
        requests, with per-request latency percentiles (ticks-to-first
        -token and ticks-per-token) on ``.latency``.
        """
        for _ in range(max_ticks):
            if self._n_waiting() or any(s is not None for s in self.slots):
                self.step()
                continue
            if self._drained():
                break
        else:
            raise RuntimeError("run() exceeded max_ticks")
        result = RunResult({rid: r for rid, r in self.requests.items()
                            if r.state == "finished"})
        result.latency = self.latency_stats()
        return result

    def _drained(self) -> bool:
        """End-of-run verification; True when nothing was re-queued.

        Without fault tolerance a failed check raises exactly as
        before.  With it, a failure is contained — which may re-queue
        recovering sessions, in which case :meth:`run` keeps ticking.
        """
        if self.policy.deferred_model_mac:
            if self.ft is None:
                self._deferred_check()
            else:
                try:
                    self._deferred_check()
                except IntegrityError as err:
                    self._contain_error(err)
        if not self.verify_every_step and not self.page_io.report_verdict(
                self._ok_accum, "decode_accum"):
            err = self._integrity_fail(
                "accumulated page-MAC verification failed", op="decode_accum")
            if self.ft is None:
                raise err
            self._contain_error(err)
            self._ok_accum = jnp.asarray(True)
        return not (self._n_waiting()
                    or any(s is not None for s in self.slots))

    def latency_stats(self) -> dict:
        """p50/p95/p99 ticks-to-first-token + ticks-per-token (finished)."""
        return latency_percentiles(self.requests.values())

    def deferred_check(self) -> bool:
        """Model-level deferred MAC over the whole pool (paper Table I)."""
        return bool(kvp.deferred_pool_check(self.pool, self.spec))

    def decode_cost_analysis(self, bucket: Optional[int] = None) -> dict:
        """XLA cost analysis of the jitted batched decode step.

        ``bytes accessed`` makes the protection traffic HLO-visible:
        the delta vs. the ``off`` scheme is the metadata + crypto
        traffic a scheme adds to one batched decode.  ``bucket``
        selects the page-count-bucketed variant to analyse (default:
        the all-resident ``pages_per_slot`` window) — the delta across
        buckets is the gather/crypt/MAC work touched-page bucketing
        removes for short live contexts.
        """
        if bucket is None:
            bucket = self.pages_per_slot
        try:
            fn = self._decode_fn_for(bucket)
            args = self._decode_analysis_args(bucket)
            cost = fn.lower(*args).compile().cost_analysis()
        except Exception:  # noqa: BLE001 - backend-dependent availability
            return {}
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        return dict(cost or {})

    def _decode_analysis_args(self, bucket: int) -> list:
        """Shape-representative args for lowering one decode variant
        (shared by :meth:`decode_cost_analysis` and the profiler)."""
        args = [
            self.params, self.pool, self.onchip,
            jnp.zeros((self.max_slots, bucket), jnp.int32),
            jnp.ones((self.max_slots,), jnp.int32),
            jnp.ones((self.max_slots,), bool),
            jnp.zeros((self.max_slots, 1), jnp.int32),
            jnp.uint32(1),
        ]
        if self.registry is not None:
            args += [
                self._bank(),
                jnp.zeros((self.max_slots, bucket), jnp.int32),
                jnp.zeros((self.max_slots,), jnp.uint32),
                jnp.zeros((self.max_slots, bucket), jnp.uint32),
                jnp.zeros((self.max_slots,), jnp.int32),
                jnp.zeros((self.max_slots,), jnp.uint32),
            ]
        return args

    def profile(self, buckets=None, uniform: bool = False,
                refresh: bool = False) -> dict:
        """Attributed device-cost profile (protection vs model HLO
        cost) of the decode variants — see :mod:`repro.obs.profiler`.

        Compiles each requested (bucket, uniform) variant on first use
        and caches the :class:`~repro.obs.profiler.CostProfile`; the
        ``protection_overhead_ratio`` / ``roofline_utilization`` lazy
        gauges sample this cache, so snapshots never trigger a compile.
        """
        if buckets is None:
            buckets = [self.pages_per_slot]
        profiles = []
        for bucket in buckets:
            key = (int(bucket), bool(uniform))
            if refresh or key not in self._cost_profiles:
                self._cost_profiles[key] = profiler_mod.profile_decode(
                    self, bucket, uniform)
            profiles.append(self._cost_profiles[key])
        return {"scheme": self.scheme, "shard": self.shard_id,
                "profiles": [p.to_dict() for p in profiles]}

    @property
    def n_free_pages(self) -> int:
        return len(self.free_pages)

    def tenant_resident_pages(self, index: int) -> int:
        """Pool pages currently owned by one tenant's running slots."""
        return sum(len(s.pages) for s in self.slots
                   if s is not None and s.tenant is not None
                   and s.tenant.index == index)

    # -- scheduler internals ------------------------------------------------

    def _n_waiting(self) -> int:
        return len(self.waiting) + sum(len(q) for q in
                                       self._tenant_waiting.values())

    def _next_epoch(self) -> jnp.ndarray:
        self._epoch += 1
        return jnp.uint32(self._epoch)

    # -- admission ----------------------------------------------------------

    def _prefill(self, seq: list):
        """Run (bucketed) prefill for one request's token sequence."""
        lp = len(seq)
        if self.prefill_buckets:
            padded = seq + [0] * (_bucket_len(lp, self.max_len) - lp)
        else:
            padded = seq
        if len(padded) not in self._prefill_shapes:
            self._prefill_shapes.add(len(padded))
            self.stats["prefill_compiles"] += 1
        return self._prefill_fn(self.params,
                                jnp.asarray([padded], jnp.int32),
                                jnp.int32(lp - 1))

    def _admission_pages(self, req: Request) -> int:
        # +1 so the first decode's write position is always covered.
        return min(len(req.prompt + req.generated) // self.page_tokens + 1,
                   self.pages_per_slot)

    def _held(self, req: Request) -> bool:
        """Recovery backoff: re-admission is delayed past hold_until."""
        return req.hold_until > self.tick

    def _admit(self, finished: list) -> None:
        if self.registry is None:
            while None in self.slots:
                # FCFS over requests not held back by recovery backoff.
                req = next((r for r in self.waiting
                            if not self._held(r)), None)
                if req is None or \
                        len(self.free_pages) < self._admission_pages(req):
                    break
                self.waiting.remove(req)
                self._admit_one(req, None, finished)
            return
        # Weighted-fair (stride) admission across tenant queues: among
        # tenants whose head request fits (free pages AND page quota),
        # admit the one with the least virtual time; charge it the
        # pages it allocated, scaled by 1/weight.  A quota-capped
        # tenant queues its own work — it never evicts other tenants.
        while None in self.slots:
            best = None
            for idx, queue in self._tenant_waiting.items():
                if not queue or self._held(queue[0]):
                    continue
                tenant = self.registry.by_index(idx)
                n_alloc = self._admission_pages(queue[0])
                if n_alloc > len(self.free_pages):
                    continue
                if self.tenant_resident_pages(idx) + n_alloc > \
                        tenant.page_quota:
                    continue
                vt = self._vtime[idx]
                if best is None or vt < best[0]:
                    best = (vt, idx, tenant, n_alloc)
            if best is None:
                break
            _, idx, tenant, n_alloc = best
            req = self._tenant_waiting[idx].popleft()
            self._vtime[idx] += n_alloc / tenant.weight
            self._admit_one(req, tenant, finished)

    def _admit_one(self, req: Request, tenant, finished: list) -> None:
        seq = req.prompt + req.generated
        if (self.prefix_cache is not None and tenant is not None
                and req.share_prefix and len(seq) > 1):
            # Match over seq[:-1] so at least one token is left to feed
            # the decode loop (the hit path generates via decode only).
            hit = self.prefix_cache.match(tenant.index, seq[:-1])
            if hit:
                self._admit_hit(req, tenant, hit, seq, finished)
                return
        n_alloc = self._admission_pages(req)
        slot_idx = self.slots.index(None)
        pages = [self.free_pages.pop() for _ in range(n_alloc)]
        tok, paged_leaves, onchip_leaves = self._prefill(seq)
        n_write = _ceil_div(len(seq), self.page_tokens)
        page_ids = np.full((self.pages_per_slot,),
                           self.spec.scratch_page, np.int32)
        page_ids[: len(pages)] = pages
        if tenant is None:
            self.pool = self._writer(n_write)(
                self.pool, jnp.asarray(page_ids), paged_leaves,
                self._next_epoch())
            page_epochs = []
        else:
            epoch = tenant.current_epoch
            row = self.registry.key_row(tenant.index, epoch)
            ctx = kvp.PageKeyCtx.make(
                self._bank(),
                np.full((self.pages_per_slot,), row, np.int32),
                np.full((self.pages_per_slot,), tenant.index, np.uint32),
                np.full((self.pages_per_slot,), epoch, np.uint32))
            self.pool = self._writer(n_write)(
                self.pool, jnp.asarray(page_ids), paged_leaves,
                self._next_epoch(), ctx)
            page_epochs = [epoch] * len(pages)
        for j, idx in enumerate(self.onchip_idx):
            self.onchip[j] = self.onchip[j].at[:, slot_idx].set(
                onchip_leaves[j][:, 0])
        self._admit_seq += 1
        self.stats["admitted"] += 1
        slot = _Slot(req, length=len(seq), pages=pages,
                     admit_seq=self._admit_seq, tenant=tenant,
                     page_epochs=page_epochs)
        self.slots[slot_idx] = slot
        self.page_table.install(slot_idx, slot)
        req.state = "running"
        self._note_recovered(req)
        req.generated.append(int(tok[0, 0]))
        if req.first_tick is None:
            req.first_tick = self.tick
            self._observe_ttft(req)
        if (self.prefix_cache is not None and tenant is not None
                and req.share_prefix):
            self._prefix_insert(tenant, seq, slot)
        self._maybe_finish(slot_idx, finished)

    def _admit_hit(self, req: Request, tenant, hit: list, seq: list,
                   finished: list) -> None:
        """Admit a request whose leading pages are already cached.

        No prefill runs.  The matched chain's pages are installed
        read-only at the front of the slot (``shared_n``, epoch word
        :data:`~repro.serve.kv_pages.PREFIX_ROLE`), the slot length is
        set to the covered token count, and the rest of the prompt is
        queued on ``slot.replay``: each tick teacher-forces the next
        prompt token through the normal batched decode (its KV lands in
        private pages), and the sampled token of the LAST replay step
        is the first real output — token-identical to a full prefill
        because causal KV at position p depends only on tokens 0..p.
        """
        covered = sum(e.n_tokens for e in hit)
        n_shared = len(hit)
        slot_idx = self.slots.index(None)
        self.prefix_cache.acquire(hit)
        slot = _Slot(req, length=covered,
                     pages=[e.page_id for e in hit],
                     admit_seq=self._admit_seq + 1, tenant=tenant,
                     page_epochs=[kvp.PREFIX_ROLE] * n_shared,
                     shared_n=n_shared, shared_entries=list(hit),
                     replay=deque(seq[covered:]))
        self._admit_seq += 1
        self.stats["admitted"] += 1
        self.stats["prefix_hit_pages"] += n_shared
        self.stats["prefill_pages_skipped"] += n_shared
        self.slots[slot_idx] = slot
        self.page_table.install(slot_idx, slot)
        req.state = "running"
        self._note_recovered(req)

    def _note_recovered(self, req: Request) -> None:
        """Count a recompute-recovery re-admission (any shard's)."""
        if req.recovering:
            req.recovering = False
            self.stats["sessions_recovered"] += 1
            self._audit("session_recovered", rid=req.rid,
                        retries=req.integrity_retries)

    def _prefix_insert(self, tenant, seq: list, slot: _Slot) -> None:
        """Seed the cache from a freshly-prefilled slot (full miss only).

        Copy-reseals the slot's leading chunk pages into cache-owned
        free pages under the tenant's cache binding (session epoch word
        → ``PREFIX_ROLE``); the slot keeps decoding on its private
        pages.  Gated on ``ok`` BEFORE the pool commits, so tampered
        session pages cannot be laundered into valid cache MACs.
        """
        pc = self.prefix_cache
        matched, missing = pc.missing(tenant.index, seq)
        if matched or not missing:
            return              # partial hits never extend the chain here
        short = pc.free_capacity()
        if short < len(missing):
            self._free(pc.reclaim(len(missing) - short))
        k = min(len(missing), pc.free_capacity(), len(self.free_pages))
        if k == 0:
            return
        missing = missing[:k]
        dst_pages = [self.free_pages.pop() for _ in range(k)]
        n = self.pages_per_slot
        src_ids = np.full((n,), self.spec.scratch_page, np.int32)
        dst_ids = np.full((n,), self.spec.scratch_page, np.int32)
        src_ids[:k] = slot.pages[:k]
        dst_ids[:k] = dst_pages
        epoch = tenant.current_epoch
        src_rows = np.full((n,), self.registry.key_row(tenant.index, epoch),
                           np.int32)
        src_epochs = np.full((n,), epoch, np.uint32)
        dst_rows = np.full((n,), self.registry.cache_row(tenant.index),
                           np.int32)
        dst_epochs = np.full((n,), kvp.PREFIX_ROLE, np.uint32)
        owners = np.full((n,), tenant.index, np.uint32)
        new_pool, ok = self._copier(n)(
            self.pool, self._bank(), jnp.asarray(src_ids),
            jnp.asarray(dst_ids), jnp.asarray(src_rows),
            jnp.asarray(src_epochs), jnp.asarray(owners),
            jnp.asarray(dst_rows), jnp.asarray(dst_epochs),
            jnp.asarray(owners), self._next_epoch())
        if not self.page_io.report_verdict(ok, "prefix_insert"):
            self._free(dst_pages)
            raise self._integrity_fail(
                f"prefix-cache insert for tenant {tenant.tenant_id!r} "
                f"failed source verification",
                op="prefix_insert", tenant=tenant.tenant_id,
                pages=[int(p) for p in slot.pages[:k]])
        self.pool = new_pool
        parent = None
        for (key, n_tok), page_id in zip(missing, dst_pages):
            parent = pc.insert(key, parent, page_id, n_tok)
        self.stats["prefix_inserted_pages"] += k
        self._audit("prefix_insert", tenant=tenant.tenant_id, pages=k)

    def _copier(self, n: int):
        """Jitted page-copy reseal (cache insert / CoW / share), padded
        to ``n`` lanes with scratch pages."""
        if n not in self._copiers:
            io = self.page_io

            def copy(pool, bank, src_ids, dst_ids, src_rows, src_epochs,
                     src_owners, dst_rows, dst_epochs, dst_owners, epoch):
                src_ctx = kvp.PageKeyCtx.make(bank, src_rows, src_owners,
                                              src_epochs)
                dst_ctx = kvp.PageKeyCtx.make(bank, dst_rows, dst_owners,
                                              dst_epochs)
                vn = vn_mod.kv_page_vn(epoch)
                return io.copy(pool, src_ids, dst_ids, vn, src_ctx, dst_ctx)

            self._copiers[n] = jax.jit(copy)
        return self._copiers[n]

    # -- growth / eviction ---------------------------------------------------

    def _ensure_growth(self) -> None:
        order = sorted((i for i, s in enumerate(self.slots) if s is not None),
                       key=lambda i: self.slots[i].admit_seq)
        for i in order:
            slot = self.slots[i]
            if slot is None:                      # evicted by an older slot
                continue
            need = slot.length // self.page_tokens
            while self.slots[i] is not None and len(slot.pages) <= need:
                tenant = slot.tenant
                if tenant is not None and \
                        self.tenant_resident_pages(tenant.index) + 1 > \
                        tenant.page_quota:
                    # Over quota: the tenant preempts ITS OWN youngest.
                    self._preempt(self._pick_victim(tenant))
                    continue
                if self.free_pages:
                    slot.pages.append(self.free_pages.pop())
                    if tenant is not None:
                        slot.page_epochs.append(tenant.current_epoch)
                    continue
                self._preempt(self._pick_victim(tenant))

    def _ensure_cow(self) -> None:
        """Copy-on-write any shared page this tick's decode will dirty.

        Runs after growth, before dispatch: the dirty page is
        ``length // page_tokens``; when it is still inside the shared
        prefix it is privatized first, so decode never writes a
        refcounted cache page.  By construction only the LAST shared
        page can ever be partial, so at most one CoW fires per slot
        over its whole life.
        """
        for i, slot in enumerate(self.slots):
            if slot is None or not slot.shared_n:
                continue
            if slot.length // self.page_tokens < slot.shared_n:
                self._cow_page(i)

    def _cow_page(self, idx: int) -> None:
        """Privatize one slot's deepest shared page before it is dirtied."""
        slot = self.slots[idx]
        tenant = slot.tenant
        pos = slot.shared_n - 1     # only the deepest shared page is partial
        while not self.free_pages:
            freed = self.prefix_cache.reclaim(1)
            if freed:
                self._free(freed)
                break
            self._preempt(self._pick_victim(tenant))
            if self.slots[idx] is None:
                return              # the CoW slot itself was the victim
        dst = self.free_pages.pop()
        n = self.pages_per_slot
        src_ids = np.full((n,), self.spec.scratch_page, np.int32)
        dst_ids = np.full((n,), self.spec.scratch_page, np.int32)
        src_ids[0] = slot.pages[pos]
        dst_ids[0] = dst
        epoch = tenant.current_epoch
        src_rows = np.full((n,), self.registry.cache_row(tenant.index),
                           np.int32)
        src_epochs = np.full((n,), kvp.PREFIX_ROLE, np.uint32)
        dst_rows = np.full((n,), self.registry.key_row(tenant.index, epoch),
                           np.int32)
        dst_epochs = np.full((n,), epoch, np.uint32)
        owners = np.full((n,), tenant.index, np.uint32)
        new_pool, ok = self._copier(n)(
            self.pool, self._bank(), jnp.asarray(src_ids),
            jnp.asarray(dst_ids), jnp.asarray(src_rows),
            jnp.asarray(src_epochs), jnp.asarray(owners),
            jnp.asarray(dst_rows), jnp.asarray(dst_epochs),
            jnp.asarray(owners), self._next_epoch())
        if not self.page_io.report_verdict(ok, "cow"):
            self._free([dst])
            raise self._integrity_fail(
                f"copy-on-write of slot {idx} shared page {pos} failed "
                f"verification (tenant {tenant.tenant_id!r})",
                op="cow", tenant=tenant.tenant_id, slot=idx,
                page=int(slot.pages[pos]))
        self.pool = new_pool
        slot.pages[pos] = dst
        slot.page_epochs[pos] = epoch
        slot.shared_n -= 1
        self.prefix_cache.release([slot.shared_entries.pop()])
        self.stats["prefix_cow_pages"] += 1
        self._audit("cow", tenant=tenant.tenant_id, slot=idx, page=int(dst))

    def _pick_victim(self, tenant=None) -> int:
        """Youngest running slot (LIFO preemption, vLLM-style) — scoped
        to ``tenant``'s own slots in multi-tenant mode, so one tenant's
        memory pressure never evicts another's requests.  May be the
        slot whose growth triggered the eviction."""
        candidates = [i for i, s in enumerate(self.slots) if s is not None
                      and (tenant is None or s.tenant is tenant)]
        return max(candidates, key=lambda i: self.slots[i].admit_seq)

    def _unpin_shared(self, slot: _Slot) -> None:
        """Drop a dying slot's pin on its shared prefix pages.

        Shared pages belong to the cache, not the slot — only the
        private tail returns to the free list."""
        if slot.shared_n:
            self.prefix_cache.release(slot.shared_entries)
            del slot.pages[: slot.shared_n]
            del slot.page_epochs[: slot.shared_n]
            slot.shared_n = 0
            slot.shared_entries = []

    def _preempt(self, idx: int) -> None:
        slot = self.slots[idx]
        self._unpin_shared(slot)
        self._free(slot.pages)
        self.slots[idx] = None
        self.page_table.clear(idx)
        slot.req.state = "waiting"
        slot.req.n_evictions += 1
        self.stats["preemptions"] += 1
        if self._preempt_hook is not None and self._preempt_hook(slot.req):
            return          # the cluster took it (re-routes across shards)
        if slot.tenant is not None:               # preempted go to the front
            self._tenant_waiting[slot.tenant.index].appendleft(slot.req)
        else:
            self.waiting.appendleft(slot.req)

    def _release(self, idx: int) -> None:
        slot = self.slots[idx]
        self._unpin_shared(slot)
        self._free(slot.pages)
        self.slots[idx] = None
        self.page_table.clear(idx)
        slot.req.state = "finished"

    def _maybe_finish(self, idx: int, finished: list) -> None:
        slot = self.slots[idx]
        req = slot.req
        hit_eos = (self.eos_id is not None and req.generated
                   and req.generated[-1] == self.eos_id)
        if req.done or hit_eos:
            req.done_tick = self.tick
            self._release(idx)
            finished.append(req)

    # -- fault containment (quarantine + secure-recompute recovery) ----------

    def _free(self, pages) -> None:
        """Return pages to the free list — minus quarantined frames,
        which are permanently retired."""
        self.free_pages.extend(p for p in pages
                               if int(p) not in self.quarantined)

    def _n_recovering(self) -> int:
        """Sessions currently preempted for secure-recompute recovery
        (queued or backing off) — the SLO monitor's degraded signal."""
        return sum(1 for r in self.requests.values() if r.recovering)

    def _commit_repair(self, new_pool: kvp.PagedKVPool) -> None:
        """Commit a repaired pool, resyncing listeners wholesale.

        The tamper being repaired bypassed the pool setter (untrusted
        memory does not announce writes), so folding the repair's
        *delta* into the cluster mirrors would propagate the attacker's
        divergence.  Listeners are instead told to re-adopt the
        repaired pool MAC (``old_pool=None``)."""
        self._pool = new_pool
        for listener in self._pool_listeners:
            listener(None, new_pool)

    def _quarantine_pages(self, pages) -> None:
        """Permanently retire physical frames after a localized fault.

        The frames leave the free list forever, the prefix cache drops
        any entry holding them, their MAC/VN metadata rows are scrubbed
        and the deferred pool MAC is rebuilt from the scrubbed page
        MACs — the pool's XOR identity holds again without trusting a
        single tampered byte."""
        fresh = sorted({int(p) for p in pages} - self.quarantined)
        if not fresh:
            return
        self.quarantined.update(fresh)
        self.free_pages = [p for p in self.free_pages
                           if p not in self.quarantined]
        if self.prefix_cache is not None:
            self.prefix_cache.evict_pages(fresh)
        pool = self.pool
        ids = jnp.asarray(fresh, jnp.int32)
        page_macs = pool.page_macs.at[ids].set(0)
        block_macs = tuple(bm.at[ids].set(0) for bm in pool.block_macs)
        page_vns = pool.page_vns.at[ids].set(0)
        pool_mac = mac_mod.xor_aggregate(page_macs[: self.spec.n_pages])
        self._commit_repair(pool._replace(
            page_macs=page_macs, block_macs=block_macs,
            page_vns=page_vns, pool_mac=pool_mac))
        self.stats["integrity_quarantined_pages"] += len(fresh)
        self._audit("quarantine", pages=fresh)

    def _rebuild_pool_mac(self) -> None:
        """Recompute the deferred pool MAC from the stored page MACs.

        The containment fallback when localization finds no failing
        page yet a pool-level check failed: the pool MAC itself — not
        any page — was hit, and rebuilding it from page MACs that all
        just re-verified restores the XOR identity.  Free pages' MACs
        are unverifiable here, but they protect no live data and are
        overwritten (and freshly MACed) by their next prefill."""
        pool = self.pool
        self._commit_repair(pool._replace(
            pool_mac=mac_mod.xor_aggregate(
                pool.page_macs[: self.spec.n_pages])))
        self._audit("pool_mac_rebuild")

    def _probe_page(self, slot_idx: int, pos: int) -> bool:
        """Re-read one resident page through the raw verify path.

        Retried ``ft.reread_retries`` extra times so a transient fault
        does not condemn a healthy frame as persistent tamper.  Probe
        verdicts flow through ``report_verdict`` like any other MAC
        gate (op ``probe``)."""
        slot = self.slots[slot_idx]
        pid = int(slot.pages[pos])
        ids = jnp.asarray([pid], jnp.int32)
        attempts = 1 + (self.ft.reread_retries if self.ft is not None else 0)
        for _ in range(attempts):
            if self.registry is None:
                _, ok = self._page_reader(1)(self.pool, ids)
            else:
                tenant = slot.tenant
                epoch = slot.page_epochs[pos]
                if epoch & kvp.PREFIX_ROLE:
                    row = self.registry.cache_row(tenant.index)
                else:
                    try:
                        row = self.registry.key_row(tenant.index, epoch)
                    except KeyError:
                        return False    # unverifiable == condemned
                _, ok = self._page_reader(1)(
                    self.pool, ids, self._bank(),
                    jnp.asarray([row], jnp.int32),
                    jnp.asarray([tenant.index], jnp.uint32),
                    jnp.asarray([np.uint32(epoch)], jnp.uint32))
            if self.page_io.report_verdict(ok, "probe", slot=slot_idx,
                                           page=pid):
                return True
        return False

    def _localize(self, slot_idxs=None) -> list:
        """Per-page probe sweep over the given (default: all occupied)
        slots; returns ``[(slot_idx, pos, page_id), ...]`` for every
        resident page that persistently fails verification."""
        idxs = (slot_idxs if slot_idxs is not None
                else range(self.max_slots))
        bad = []
        for i in idxs:
            slot = self.slots[i]
            if slot is None:
                continue
            for pos in range(len(slot.pages)):
                if not self._probe_page(i, pos):
                    bad.append((i, pos, int(slot.pages[pos])))
        return bad

    def _preempt_recover(self, idx: int) -> None:
        """Preempt one slot for secure-recompute recovery — or declare
        its session dead once the retry budget is spent."""
        slot = self.slots[idx]
        req = slot.req
        req.integrity_retries += 1
        if self.ft is not None and \
                req.integrity_retries > self.ft.max_retries:
            self._unpin_shared(slot)
            self._free(slot.pages)
            self.slots[idx] = None
            self.page_table.clear(idx)
            req.state = "failed"
            req.recovering = False
            self.stats["sessions_lost"] += 1
            self._audit("session_lost", rid=req.rid, slot=idx,
                        retries=req.integrity_retries)
            return
        req.recovering = True
        if self.ft is not None and self.ft.backoff_ticks:
            req.hold_until = self.tick + self.ft.backoff_ticks * (
                1 << (req.integrity_retries - 1))
        self._audit("session_recovery", rid=req.rid, slot=idx,
                    retries=req.integrity_retries)
        self._preempt(idx)

    def _contain_error(self, err: IntegrityError) -> None:
        """Quarantine + recover after a caught integrity failure.

        Pages named by the error's context are condemned directly;
        otherwise a full localization sweep re-verifies every resident
        page.  When nothing persistently fails — a transient fault or
        a hit on the pool MAC itself — the deferred identity is
        rebuilt instead, so the next pool-level check passes without
        laundering any tampered page."""
        ctx = getattr(err, "ctx", None) or {}
        pages = [int(p) for p in ctx.get("pages", [])]
        if "page" in ctx and int(ctx["page"]) not in pages:
            pages.append(int(ctx["page"]))
        if not pages:
            pages = [b[2] for b in self._localize()]
        self._audit("fault_contained", detail=str(err),
                    op=ctx.get("op"), pages=pages)
        if pages:
            self._quarantine_pages(pages)
            for i, slot in enumerate(self.slots):
                if slot is not None and any(
                        int(p) in self.quarantined for p in slot.pages):
                    self._preempt_recover(i)
        else:
            self._rebuild_pool_mac()

    # -- decode --------------------------------------------------------------

    def _bank(self):
        """The registry key bank, replicated onto this shard's device."""
        return self.registry.bank_for(self._device)

    def _uniform_row(self, active_idx: list):
        """The single bank row serving every page this tick, or None.

        The host-side single-key fast-path gate: when every resident
        page AND every dirty write of the tick resolves to one
        (tenant, epoch) bank row, the vmapped per-page crypt is
        overkill — the uniform decode fn runs the flat single-key route
        (fused kernels included) with bit-identical RePA metadata.
        """
        tenant, row = None, None
        for i in active_idx:
            slot = self.slots[i]
            t = slot.tenant
            if t is None:
                return None
            if any(e != t.current_epoch for e in slot.page_epochs):
                return None
            r = self.registry.key_row(t.index, t.current_epoch)
            if row is None:
                tenant, row = t, r
            elif r != row:
                return None
        return (tenant, row)

    def _tenant_decode_args(self, active_idx: list, bucket: int) -> tuple:
        """Per-slot/per-page key selections for one decode tick.

        Per-page arrays are shaped to the tick's page-count ``bucket``
        (the level-2 window), matching the bucketed page table.
        Returns ``(args, uniform)`` — when ``uniform`` the whole batch
        resolves to one bank row (arrays are filled uniformly so the
        single gathered key covers scratch writes of inactive slots
        too) and the caller dispatches the single-key decode fn.
        """
        s, p = self.max_slots, bucket
        uni = self._uniform_row(active_idx)
        if uni is not None:
            tenant, row = uni
            epoch = np.uint32(tenant.current_epoch)
            return ([self._bank(),
                     jnp.full((s, p), row, jnp.int32),
                     jnp.full((s,), tenant.index, jnp.uint32),
                     jnp.full((s, p), epoch, jnp.uint32),
                     jnp.full((s,), row, jnp.int32),
                     jnp.full((s,), epoch, jnp.uint32)], True)
        key_idx = np.zeros((s, p), np.int32)
        owners = np.zeros((s,), np.uint32)
        key_epochs = np.zeros((s, p), np.uint32)
        cur_key_idx = np.zeros((s,), np.int32)
        cur_epochs = np.zeros((s,), np.uint32)
        for i, slot in enumerate(self.slots):
            if slot is None or slot.tenant is None:
                continue
            tenant = slot.tenant
            owners[i] = tenant.index
            cur_epochs[i] = tenant.current_epoch
            cur_key_idx[i] = self.registry.key_row(tenant.index,
                                                   tenant.current_epoch)
            for j, epoch in enumerate(slot.page_epochs[:p]):
                key_epochs[i, j] = epoch
                if epoch & kvp.PREFIX_ROLE:
                    # Shared prefix page: sealed under the tenant's
                    # epoch-independent cache binding, not a session
                    # epoch row.
                    key_idx[i, j] = self.registry.cache_row(tenant.index)
                    continue
                try:
                    key_idx[i, j] = self.registry.key_row(tenant.index,
                                                          epoch)
                except KeyError as e:
                    # A resident page claiming an epoch its tenant has
                    # no retained key for is an integrity violation
                    # (stale-epoch replay / page-table tamper), not a
                    # scheduling error.
                    raise self._integrity_fail(
                        f"slot {i} page {j}: {e.args[0]}",
                        op="stale_epoch", tenant=tenant.tenant_id,
                        slot=i, page=int(slot.pages[j])) from e
        return ([self._bank(), jnp.asarray(key_idx),
                 jnp.asarray(owners), jnp.asarray(key_epochs),
                 jnp.asarray(cur_key_idx), jnp.asarray(cur_epochs)], False)

    def _decode(self, active_idx: list, finished: list) -> None:
        pending = self._decode_dispatch(active_idx)
        self._decode_collect(active_idx, pending, finished)

    def _decode_dispatch(self, active_idx: list):
        """Launch this tick's batched decode; no host sync.

        The page-count bucket is picked HERE, host-side, from the live
        lengths (no device value is consulted), so the dispatch stays
        async and a cluster can dispatch every shard before collecting
        any.  Protection work inside the jitted step scales with the
        bucket's page window, not with ``pages_per_slot``.

        Returns the (still-async) ``(toks, ok)`` device values; the
        pool/onchip state is already swapped to the new (async) arrays.
        """
        bucket = self.page_table.bucket_for(
            (self.slots[i].length for i in active_idx), self.page_tokens)
        page_table = self.page_table.window(bucket)
        lengths = np.zeros((self.max_slots,), np.int32)
        active = np.zeros((self.max_slots,), bool)
        tokens = np.zeros((self.max_slots, 1), np.int32)
        for i in active_idx:
            slot = self.slots[i]
            lengths[i] = slot.length
            active[i] = True
            # Replay (shared-prefix hit) teacher-forces the prompt
            # suffix the skipped prefill still owes the KV cache.
            tokens[i, 0] = (slot.replay[0] if slot.replay
                            else slot.req.generated[-1])
        args = [self.params, self.pool, self.onchip, jnp.asarray(page_table),
                jnp.asarray(lengths), jnp.asarray(active),
                jnp.asarray(tokens), self._next_epoch()]
        uniform = False
        if self.registry is not None:
            tenant_args, uniform = self._tenant_decode_args(active_idx,
                                                            bucket)
            args += tenant_args
        decode_fn = self._decode_fn_for(bucket, uniform)
        if uniform or self.registry is None:
            # Single-key tick: flat crypt/MAC route, fused kernels when
            # the spec qualifies.
            self.stats["uniform_fast_ticks"] += 1
        elif kvp._kernel_read_ok(self.spec) and \
                self.spec.cfg.verify != "none":
            # Mixed bank rows, but the fused kernel stays on via its
            # per-page round-key gather.  (verify == "none" reads skip
            # MACs entirely and never enter the fused kernel, so they
            # must not count as fused ticks.)
            self.stats["fused_mixed_ticks"] += 1
        if kvp._kernel_write_ok(self.spec) and \
                self.spec.cfg.verify != "none":
            # The tick's dirty-page reseal runs the one-pass fused
            # write kernel (single-key, uniform, or mixed-row alike) —
            # write_pages never touches the vmapped reference.
            self.stats["fused_write_ticks"] += 1
        self.stats["decode_page_reads"] += len(active_idx) * bucket
        self._bucket_hist.observe(bucket)
        self.pool, self.onchip, toks, ok = decode_fn(*args)
        self.stats["decode_steps"] += 1
        return toks, ok

    def _decode_collect(self, active_idx: list, pending,
                        finished: list) -> None:
        """Sync on a dispatched decode and apply host bookkeeping."""
        toks, ok = pending
        if self.verify_every_step:
            if not self.page_io.report_verdict(ok, "decode"):
                self._decode_failure(active_idx)
        else:
            self._ok_accum = self._ok_accum & ok
        toks = np.asarray(toks)
        for i in active_idx:
            slot = self.slots[i]
            if slot is None:
                continue    # quarantined + preempted by _decode_failure:
                            # its bookkeeping must not advance — recompute
                            # recovery replays from the last good token.
            if slot.tenant is not None:
                # The dirty page was just re-encrypted under the
                # tenant's CURRENT epoch (lazy rotation lands here).
                dirty = slot.length // self.page_tokens
                if dirty < len(slot.page_epochs):
                    slot.page_epochs[dirty] = slot.tenant.current_epoch
            slot.length += 1
            if slot.replay:
                slot.replay.popleft()
                if slot.replay:
                    continue        # mid-replay: the sample is discarded
                # The LAST replay step's sample is the first real output
                # (exactly what a full prefill would have returned).
            slot.req.generated.append(int(toks[i, 0]))
            if slot.req.first_tick is None:
                slot.req.first_tick = self.tick
                self._observe_ttft(slot.req)
            self._maybe_finish(i, finished)

    def _decode_failure(self, active_idx: list) -> None:
        """The decode-tick MAC gate failed: localize, then contain.

        Localization re-reads every active slot's resident pages and
        condemns the ones that persistently fail.  Without fault
        tolerance the strict discipline raises — now with the failing
        page(s) in the error context.  With it, the condemned frames
        are quarantined and only their slots preempted for recovery;
        every other slot's reads verified, so its token and dirty write
        are bit-identical to a fault-free tick and bookkeeping
        proceeds.  An empty localization is a transient fault: the
        tick's tokens came from reads that now re-verify, so nothing is
        preempted."""
        bad = self._localize(active_idx)
        ctx = {}
        if bad:
            slot = self.slots[bad[0][0]]
            ctx = dict(slot=bad[0][0], pages=[b[2] for b in bad])
            if slot is not None and slot.tenant is not None:
                ctx["tenant"] = slot.tenant.tenant_id
        if self.ft is None:
            raise self._integrity_fail(
                f"page MAC verification failed at tick {self.tick} "
                f"(scheme={self.scheme}, shard={self.shard_id})",
                op="decode", **ctx)
        if not bad:
            self._audit("transient_fault", op="decode")
            return
        self._audit("fault_contained", op="decode",
                    pages=[b[2] for b in bad])
        self._quarantine_pages([b[2] for b in bad])
        for idx in sorted({b[0] for b in bad}):
            if self.slots[idx] is not None:
                self._preempt_recover(idx)

    def _deferred_check(self) -> None:
        self.stats["deferred_checks"] += 1
        if not self.page_io.report_verdict(self.deferred_check(), "deferred"):
            raise self._integrity_fail(
                "deferred pool-level MAC check failed "
                f"(tick {self.tick}, scheme={self.scheme})", op="deferred")
