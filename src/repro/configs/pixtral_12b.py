"""pixtral-12b — pixtral-ViT + mistral-nemo decoder
[hf:mistralai/Pixtral-12B-2409; unverified].

[vlm] 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
The vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, 256, d_vision=1024); a learned projector maps them into
the decoder's embedding space.
"""

from repro.configs.base import ArchDef
from repro.models.lm import LMConfig

N_PATCHES = 256
D_VISION = 1024


def make_config() -> LMConfig:
    return LMConfig(
        name="pixtral-12b",
        n_layers=40, d_model=5120, n_heads=32, n_kv=8, head_dim=128,
        d_ff=14336, vocab=131072,
        mixer="attn", ffn="dense", tie_embeddings=True,
        n_image_patches=N_PATCHES, d_vision=D_VISION,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="pixtral-12b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256, dtype="float32",
        mixer="attn", ffn="dense", q_block=16, kv_block=16, remat="none",
        n_image_patches=8, d_vision=32,
    )


ARCH = ArchDef(
    name="pixtral-12b", family="vlm", kind="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    source="hf:mistralai/Pixtral-12B-2409; unverified",
    notes="Backbone only per the assignment; modality frontend stubbed "
          "to precomputed patch embeddings.  Loss masks image positions.",
)
