"""Paper-evaluation substrate: the SCALE-Sim2 + security + Ramulator2
stack of §IV, reimplemented analytically.

- :mod:`repro.sim.workloads`   — the 13 benchmark DNNs as layer tables
- :mod:`repro.sim.scalesim`    — systolic-array cycles + DRAM streams
- :mod:`repro.sim.memprot`     — SGX/MGX/SeDA metadata + overfetch overlay
- :mod:`repro.sim.secureloop`  — optBlk granularity search
- :mod:`repro.sim.dram`        — Ramulator-lite timing / performance
- :mod:`repro.sim.caches`      — LRU metadata caches (trace mode)
- :mod:`repro.sim.area_power`  — B-AES vs T-AES 28nm scaling (Fig. 4)
"""

from repro.sim.npu_configs import EDGE_NPU, NPUS, SERVER_NPU  # noqa: F401
from repro.sim.workloads import WORKLOADS  # noqa: F401
