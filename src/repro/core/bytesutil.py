"""Byte-view utilities for the secure-memory layer.

Every tensor that crosses the untrusted boundary is (de)serialized to a
flat uint8 buffer, padded to the encryption-block granularity.  All
conversions are jit-compatible bitcasts (no host round-trips).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "TensorSpec",
    "tensor_to_bytes",
    "bytes_to_tensor",
    "pad_to_multiple",
    "bytes_to_u32",
    "u32_to_bytes",
]


class TensorSpec(NamedTuple):
    """Static metadata needed to reconstruct a tensor from its bytes."""

    shape: tuple
    dtype: str
    nbytes: int  # unpadded payload size

    @staticmethod
    def of(x: jax.Array | jax.ShapeDtypeStruct) -> "TensorSpec":
        nbytes = int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        return TensorSpec(tuple(x.shape), jnp.dtype(x.dtype).name, nbytes)


def pad_to_multiple(buf: jax.Array, multiple: int) -> jax.Array:
    """Zero-pad a flat uint8 buffer to a length multiple (static shapes)."""
    n = buf.shape[0]
    padded = (n + multiple - 1) // multiple * multiple
    if padded == n:
        return buf
    return jnp.concatenate([buf, jnp.zeros((padded - n,), dtype=jnp.uint8)])


def tensor_to_bytes(x: jax.Array, *, multiple: int = 16) -> jax.Array:
    """Bitcast any tensor to a flat, padded uint8 buffer."""
    if x.dtype == jnp.uint8:
        flat = x.reshape(-1)
    else:
        # bitcast_convert_type to a smaller dtype appends a trailing axis
        # of size itemsize.
        as_u8 = jax.lax.bitcast_convert_type(x, jnp.uint8)
        flat = as_u8.reshape(-1)
    return pad_to_multiple(flat, multiple)


def bytes_to_tensor(buf: jax.Array, spec: TensorSpec) -> jax.Array:
    """Inverse of :func:`tensor_to_bytes` given the static spec."""
    dtype = jnp.dtype(spec.dtype)
    payload = buf[: spec.nbytes]
    if dtype == jnp.uint8:
        return payload.reshape(spec.shape)
    itemsize = dtype.itemsize
    grouped = payload.reshape(-1, itemsize)
    out = jax.lax.bitcast_convert_type(grouped, dtype)
    return out.reshape(spec.shape)


def bytes_to_u32(buf: jax.Array) -> jax.Array:
    """View a flat uint8 buffer (len % 4 == 0) as little-endian uint32 lanes."""
    return jax.lax.bitcast_convert_type(buf.reshape(-1, 4), jnp.uint32).reshape(-1)


def u32_to_bytes(lanes: jax.Array) -> jax.Array:
    """Inverse of :func:`bytes_to_u32`."""
    return jax.lax.bitcast_convert_type(lanes.reshape(-1, 1), jnp.uint8).reshape(-1)
