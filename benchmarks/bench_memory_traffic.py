"""Paper Fig. 5: normalized memory traffic per protection scheme."""

from __future__ import annotations

import statistics
import time

from repro.sim.memprot import overlay_scheme
from repro.sim.npu_configs import NPUS
from repro.sim.scalesim import simulate_workload
from repro.sim.workloads import WORKLOADS

PAPER = {
    ("server", "sgx64"): 0.30, ("server", "mgx64"): 0.1251,
    ("server", "sgx512"): 0.2217, ("server", "mgx512"): 0.0892,
    ("server", "seda"): 0.0012,
    ("edge", "sgx64"): 0.2829, ("edge", "mgx64"): 0.1263,
    ("edge", "sgx512"): 0.2316, ("edge", "mgx512"): 0.1024,
    ("edge", "seda"): 0.0003,
}


def run() -> list:
    rows = []
    for npu_name, npu in NPUS.items():
        for scheme in ("sgx64", "sgx512", "mgx64", "mgx512", "seda"):
            t0 = time.perf_counter()
            per_workload = {}
            for wname, w in WORKLOADS.items():
                tr = simulate_workload(w, npu)
                per_workload[wname] = overlay_scheme(tr, scheme,
                                                     npu).traffic_overhead
            dt = (time.perf_counter() - t0) * 1e6
            mean = statistics.mean(per_workload.values())
            paper = PAPER[(npu_name, scheme)]
            rows.append({
                "name": f"fig5_{npu_name}_{scheme}",
                "us_per_call": dt,
                "derived": (f"traffic_overhead={mean:+.2%} "
                            f"paper={paper:+.2%} "
                            f"delta={mean - paper:+.2%}"),
            })
    return rows
