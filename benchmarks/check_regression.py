"""CI gate: fail when a bench run regresses vs. the history baseline.

Reads the current run's bench JSON artifacts, normalizes them exactly
like ``history.py``, and compares every metric against the **best
clean prior row** in ``BENCH_history.jsonl`` for the same
(benchmark, scheme, config) key:

* baseline rows must be clean — ``git_dirty`` rows are skipped, so a
  lucky number from an uncommitted tree never ratchets the bar;
* wall-clock metrics (tok/s, us/step) additionally require the
  baseline's host fingerprint to match the current run's (a dev
  workstation's tok/s is meaningless as a CI-runner bar) and get a
  wide tolerance band (``--throughput-tol``, default 50% relative) —
  shared runners are noisy;
* ratio metrics (traffic / protection overhead) are deterministic-ish
  and compared host-independently with a tight band
  (``--ratio-tol`` relative, default 25%, plus ``--ratio-abs``
  absolute slack, default 0.05).

Keys with no clean matching baseline are reported WARN (first-run
mode: the gate passes); once a baseline row exists a regression is a
hard failure.  A trajectory table (baseline -> current per key) is
always printed.

Usage::

    python benchmarks/check_regression.py \\
        --history BENCH_history.jsonl bench-*.json
"""

from __future__ import annotations

import argparse
import json

from history import METRIC_KEYS, load_history, normalize

# Wall-clock metrics: noisy, host-dependent.
_THROUGHPUT_METRICS = frozenset({
    "tok_per_s", "tok_per_s_off", "tok_per_s_on", "us_per_call",
    "us_per_step",
})


def _key(row: dict) -> tuple:
    return (row["benchmark"], row["scheme"], row["config"])


def _best_baseline(history: list, key: tuple, metric: str,
                   higher_better: bool, host: str) -> float | None:
    values = []
    for row in history:
        if _key(row) != key or row.get("git_dirty", True):
            continue
        if metric in _THROUGHPUT_METRICS and row.get("host") != host:
            continue
        v = row.get("metrics", {}).get(metric)
        if v is not None:
            values.append(float(v))
    if not values:
        return None
    return max(values) if higher_better else min(values)


def check(current_rows: list, history: list, *,
          throughput_tol: float = 0.50, ratio_tol: float = 0.25,
          ratio_abs: float = 0.05) -> tuple:
    """Returns (failures, warnings, table_lines)."""
    failures, warnings, table = [], [], []
    header = (f"{'benchmark':<18} {'scheme':<8} {'config':<28} "
              f"{'metric':<22} {'baseline':>12} {'current':>12} {'':<6}")
    table.append(header)
    table.append("-" * len(header))
    for row in current_rows:
        key = _key(row)
        for metric, value in sorted(row["metrics"].items()):
            higher_better = METRIC_KEYS.get(metric, True)
            base = _best_baseline(history, key, metric, higher_better,
                                  row.get("host", "unknown"))
            tag = ""
            if base is None:
                tag = "WARN"
                warnings.append(
                    f"{key} {metric}: no clean baseline yet (first run "
                    f"for this key/host) — recording only")
            else:
                if metric in _THROUGHPUT_METRICS:
                    tol = throughput_tol
                    if higher_better:
                        bad = value < base * (1.0 - tol)
                    else:
                        bad = value > base * (1.0 + tol)
                else:
                    if higher_better:
                        bad = value < min(base * (1.0 - ratio_tol),
                                          base - ratio_abs)
                    else:
                        bad = value > max(base * (1.0 + ratio_tol),
                                          base + ratio_abs)
                if bad:
                    tag = "FAIL"
                    failures.append(
                        f"{key} {metric}: {value:.6g} regressed past "
                        f"baseline {base:.6g} (band: "
                        f"{'+-' + format(throughput_tol, '.0%') if metric in _THROUGHPUT_METRICS else f'{ratio_tol:.0%} rel / {ratio_abs} abs'})")
                else:
                    tag = "ok"
            table.append(
                f"{key[0]:<18} {key[1]:<8} {key[2]:<28.28} {metric:<22} "
                f"{base if base is not None else float('nan'):>12.5g} "
                f"{value:>12.5g} {tag:<6}")
    return failures, warnings, table


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("jsons", nargs="+", help="current bench JSON artifacts")
    ap.add_argument("--history", default="BENCH_history.jsonl")
    ap.add_argument("--throughput-tol", type=float, default=0.50,
                    help="relative band for wall-clock metrics")
    ap.add_argument("--ratio-tol", type=float, default=0.25,
                    help="relative band for overhead-ratio metrics")
    ap.add_argument("--ratio-abs", type=float, default=0.05,
                    help="absolute slack for overhead-ratio metrics")
    args = ap.parse_args(argv)

    current = []
    for path in args.jsons:
        with open(path) as f:
            current.extend(normalize(json.load(f)))
    history = load_history(args.history)
    failures, warnings, table = check(
        current, history, throughput_tol=args.throughput_tol,
        ratio_tol=args.ratio_tol, ratio_abs=args.ratio_abs)

    print(f"[regression] {len(history)} history rows, "
          f"{len(current)} current rows")
    for line in table:
        print("[regression] " + line)
    for w in warnings:
        print("[regression] WARN " + w)
    for f in failures:
        print("[regression] FAIL " + f)
    if failures:
        print(f"[regression] {len(failures)} regression(s) vs. baseline")
        return 1
    print("[regression] OK — no metric regressed past its baseline band")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
