"""Pallas TPU kernel: batched AES-128-CTR keystream generation.

This is SeDA's "AES Engine" (paper Fig. 2(b)) mapped to a TPU core.
One grid program produces the OTPs for ``TILE_N`` counter blocks from
VMEM-resident state:

  HBM -> VMEM: counter words (TILE_N, 4) u32, round keys (11,16), S-box
  VMEM compute: 10 unrolled AES rounds over a (TILE_N, 16) int32 state
               (one byte per int32 lane — VPU-native shifts/xors)
  VMEM -> HBM: OTP lanes (TILE_N, 4) u32

TPU adaptation of SubBytes (the only non-affine step):

* ``subbytes="take"``   — 256-entry table gather (works everywhere;
  gathers are serviced by the scalar/vector load units on TPU).
* ``subbytes="onehot"`` — one-hot(state) @ sbox matmul: a (TILE_N*16,
  256) f32 one-hot times a (256, 1) table runs on the MXU.  This is the
  TPU-native analogue of "adding AES engines": bandwidth scales with
  MXU throughput instead of gather throughput.  Exact because all
  values are small integers in f32.

Both paths are validated against the FIPS-chained oracle in ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.aes import _RCON_NP, _SBOX_NP, _SHIFT_ROWS_PERM_NP  # noqa: F401
from repro.kernels.common import cdiv, default_interpret

__all__ = ["aes_ctr_keystream", "aes_ctr_keystream_multi"]

def _iota(n: int, dtype=jnp.int32) -> jax.Array:
    """1D iota built in-kernel (Pallas forbids captured array constants)."""
    return jax.lax.broadcasted_iota(dtype, (n,), 0)


def _unpack_counter_bytes(words_u32: jax.Array) -> jax.Array:
    """(T, 4) u32 -> (T, 16) i32 byte state (big-endian per word)."""
    w = words_u32.astype(jnp.uint32)
    shifts = ((3 - _iota(4)) * 8).astype(jnp.uint32)  # [24, 16, 8, 0]
    b = w[:, :, None] >> shifts[None, None, :]
    return (b & jnp.uint32(0xFF)).astype(jnp.int32).reshape(w.shape[0], 16)


def _pack_lanes_le(state_i32: jax.Array) -> jax.Array:
    """(T, 16) i32 byte state -> (T, 4) u32 little-endian lanes."""
    s = state_i32.astype(jnp.uint32).reshape(state_i32.shape[0], 4, 4)
    shifts = (_iota(4) * 8).astype(jnp.uint32)  # [0, 8, 16, 24]
    return jnp.sum(s << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


def _xtime(x: jax.Array) -> jax.Array:
    """GF(2^8) doubling on int32 byte lanes."""
    doubled = (x << 1) ^ jnp.where(x & 0x80, 0x1B, 0)
    return doubled & 0xFF


def _mix_columns(state: jax.Array) -> jax.Array:
    s = state.reshape(state.shape[0], 4, 4)  # (T, col, row)
    a0, a1, a2, a3 = s[:, :, 0], s[:, :, 1], s[:, :, 2], s[:, :, 3]
    x0, x1, x2, x3 = _xtime(a0), _xtime(a1), _xtime(a2), _xtime(a3)
    b0 = x0 ^ (x1 ^ a1) ^ a2 ^ a3
    b1 = a0 ^ x1 ^ (x2 ^ a2) ^ a3
    b2 = a0 ^ a1 ^ x2 ^ (x3 ^ a3)
    b3 = (x0 ^ a0) ^ a1 ^ a2 ^ x3
    return jnp.stack([b0, b1, b2, b3], axis=-1).reshape(state.shape)


def _sub_bytes_take(state: jax.Array, sbox: jax.Array) -> jax.Array:
    return jnp.take(sbox, state, axis=0)


def _sub_bytes_onehot(state: jax.Array, sbox_f32: jax.Array) -> jax.Array:
    """SubBytes on the MXU: one-hot(state) @ sbox."""
    flat = state.reshape(-1)
    onehot = jax.nn.one_hot(flat, 256, dtype=jnp.float32)
    looked = onehot @ sbox_f32  # (T*16,)
    return looked.astype(jnp.int32).reshape(state.shape)


def _aes_ctr_kernel(counters_ref, rk_ref, sbox_ref, out_ref, *, subbytes: str):
    state = _unpack_counter_bytes(counters_ref[...])
    rk = rk_ref[...].astype(jnp.int32)  # (11, 16)
    if subbytes == "onehot":
        sbox = sbox_ref[...].astype(jnp.float32)
        sub = functools.partial(_sub_bytes_onehot, sbox_f32=sbox)
    else:
        sbox = sbox_ref[...].astype(jnp.int32)
        sub = functools.partial(_sub_bytes_take, sbox=sbox)
    # ShiftRows permutation, built in-kernel: perm[r+4c] = r + 4((c+r)%4).
    idx = _iota(16)
    r, c = idx % 4, idx // 4
    perm = r + 4 * ((c + r) % 4)

    state = state ^ rk[0][None, :]
    for rnd in range(1, 10):  # unrolled: round keys static-indexed
        state = sub(state)
        state = jnp.take(state, perm, axis=1)  # ShiftRows
        state = _mix_columns(state)
        state = state ^ rk[rnd][None, :]
    state = sub(state)
    state = jnp.take(state, perm, axis=1)
    state = state ^ rk[10][None, :]
    out_ref[...] = _pack_lanes_le(state)


def _aes_ctr_kernel_multi(counters_ref, rk_ref, sbox_ref, out_ref, *,
                          subbytes: str):
    """Per-block key schedules: rk_ref is (T, 11*16) — one schedule per
    counter block, so one kernel pass serves a mixed-key batch (pages
    owned by different tenant-epoch bank rows)."""
    state = _unpack_counter_bytes(counters_ref[...])
    t = state.shape[0]
    rk = rk_ref[...].astype(jnp.int32).reshape(t, 11, 16)
    if subbytes == "onehot":
        sbox = sbox_ref[...].astype(jnp.float32)
        sub = functools.partial(_sub_bytes_onehot, sbox_f32=sbox)
    else:
        sbox = sbox_ref[...].astype(jnp.int32)
        sub = functools.partial(_sub_bytes_take, sbox=sbox)
    idx = _iota(16)
    r, c = idx % 4, idx // 4
    perm = r + 4 * ((c + r) % 4)

    state = state ^ rk[:, 0]
    for rnd in range(1, 10):
        state = sub(state)
        state = jnp.take(state, perm, axis=1)
        state = _mix_columns(state)
        state = state ^ rk[:, rnd]
    state = sub(state)
    state = jnp.take(state, perm, axis=1)
    state = state ^ rk[:, 10]
    out_ref[...] = _pack_lanes_le(state)


@functools.partial(jax.jit, static_argnames=("tile_n", "subbytes", "interpret"))
def aes_ctr_keystream_multi(counter_words: jax.Array,
                            round_keys_per: jax.Array, *, tile_n: int = 256,
                            subbytes: str = "take",
                            interpret: bool | None = None) -> jax.Array:
    """(N, 4) u32 counters + PER-BLOCK (N, 11, 16) u8 schedules ->
    (N, 4) u32 OTP lanes.  Mixed-key sibling of
    :func:`aes_ctr_keystream`; bit-identical to running the single-key
    kernel once per distinct schedule."""
    if interpret is None:
        interpret = default_interpret()
    n = counter_words.shape[0]
    tile_n = min(tile_n, max(8, n))
    n_pad = cdiv(n, tile_n) * tile_n
    padded = jnp.zeros((n_pad, 4), jnp.uint32).at[:n].set(counter_words)
    rk_flat = round_keys_per.reshape(n, 11 * 16)
    rk_pad = jnp.zeros((n_pad, 11 * 16), jnp.uint8).at[:n].set(rk_flat)
    sbox = jnp.asarray(_SBOX_NP, jnp.int32)

    out = pl.pallas_call(
        functools.partial(_aes_ctr_kernel_multi, subbytes=subbytes),
        grid=(n_pad // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, 4), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 11 * 16), lambda i: (i, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_n, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 4), jnp.uint32),
        interpret=interpret,
    )(padded, rk_pad, sbox)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("tile_n", "subbytes", "interpret"))
def aes_ctr_keystream(counter_words: jax.Array, round_keys: jax.Array, *,
                      tile_n: int = 256, subbytes: str = "take",
                      interpret: bool | None = None) -> jax.Array:
    """(N, 4) u32 counters + (11, 16) u8 schedule -> (N, 4) u32 OTP lanes."""
    if interpret is None:
        interpret = default_interpret()
    n = counter_words.shape[0]
    tile_n = min(tile_n, max(8, n))
    n_pad = cdiv(n, tile_n) * tile_n
    padded = jnp.zeros((n_pad, 4), jnp.uint32).at[:n].set(counter_words)
    sbox = jnp.asarray(_SBOX_NP, jnp.int32)

    out = pl.pallas_call(
        functools.partial(_aes_ctr_kernel, subbytes=subbytes),
        grid=(n_pad // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, 4), lambda i: (i, 0)),
            pl.BlockSpec((11, 16), lambda i: (0, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_n, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 4), jnp.uint32),
        interpret=interpret,
    )(padded, round_keys, sbox)
    return out[:n]
