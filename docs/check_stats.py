"""Stats gate: every metric name used is declared AND documented.

The observability layer (``src/repro/obs/metrics.py``) declares every
counter/gauge/histogram once, with a help string.  This gate keeps the
three surfaces that mention metric names from drifting apart:

* **code** — AST-scans ``src/repro/serve/`` (plus the serve launcher)
  for ``stats["..."]`` subscripts and ``metrics.counter/gauge/
  histogram("...")`` declaration calls: every literal name must be
  declared in the canonical dicts (a typo'd key can no longer mint a
  silent counter), and a non-literal key inside the serving stack is
  itself an error;
* **architecture doc** — every declared *counter* must appear
  (backticked) in the stats table of ``docs/architecture.md`` §8;
* **observability doc** — every declared counter, gauge, and
  histogram must appear (backticked) in ``docs/observability.md``.

Everything is parsed from source text — no ``repro`` import — so the
gate runs in the dependency-free CI docs job.  ``docs/check_docs.py``
runs it as part of ``run_checks()``; ``tests/test_docs.py`` covers it
in tier-1.
"""

from __future__ import annotations

import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
METRICS_PY = ROOT / "src" / "repro" / "obs" / "metrics.py"
ARCH_MD = ROOT / "docs" / "architecture.md"
OBS_MD = ROOT / "docs" / "observability.md"

_DECL_DICTS = {
    "ENGINE_COUNTERS": "counter",
    "CLUSTER_COUNTERS": "counter",
    "ENGINE_GAUGES": "gauge",
    "ENGINE_HISTOGRAMS": "histogram",
    "CLUSTER_HISTOGRAMS": "histogram",
}


def scanned_files() -> list:
    """The serving-stack sources whose metric names this gate owns."""
    return sorted((ROOT / "src" / "repro" / "serve").glob("*.py")) + [
        ROOT / "src" / "repro" / "launch" / "serve.py"]


def declared() -> dict:
    """``{kind: set(names)}`` parsed from the canonical metrics dicts."""
    tree = ast.parse(METRICS_PY.read_text())
    out = {"counter": set(), "gauge": set(), "histogram": set()}
    for node in tree.body:
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            continue
        kind = _DECL_DICTS.get(node.targets[0].id)
        if kind is None or not isinstance(node.value, ast.Dict):
            continue
        for key in node.value.keys:
            if isinstance(key, ast.Constant) and isinstance(key.value, str):
                out[kind].add(key.value)
    return out


def _is_stats_subscript(node: ast.Subscript) -> bool:
    base = node.value
    return ((isinstance(base, ast.Attribute) and base.attr == "stats")
            or (isinstance(base, ast.Name) and base.id == "stats"))


def used_in(path: pathlib.Path) -> tuple:
    """(stats keys, declaration-call names per kind, errors) for a file."""
    tree = ast.parse(path.read_text())
    keys, calls, errors = set(), {"counter": set(), "gauge": set(),
                                  "histogram": set()}, []
    for node in ast.walk(tree):
        if isinstance(node, ast.Subscript) and _is_stats_subscript(node):
            if isinstance(node.slice, ast.Constant) and \
                    isinstance(node.slice.value, str):
                keys.add(node.slice.value)
            else:
                errors.append(
                    f"{path.relative_to(ROOT)}:{node.lineno}: non-literal "
                    f"stats[...] key (the gate cannot check it)")
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in calls and node.args:
            first = node.args[0]
            if isinstance(first, ast.Constant) and \
                    isinstance(first.value, str):
                calls[node.func.attr].add(first.value)
    return keys, calls, errors


def _documented(path: pathlib.Path) -> set:
    """Backticked identifiers mentioned anywhere in one markdown file."""
    return set(re.findall(r"`([a-z][a-z0-9_]*)`", path.read_text()))


def run_checks() -> list:
    decls = declared()
    counters = decls["counter"]
    errors = []
    if not counters:
        return [f"no counter declarations parsed from "
                f"{METRICS_PY.relative_to(ROOT)}"]
    for path in scanned_files():
        keys, calls, errs = used_in(path)
        errors += errs
        rel = path.relative_to(ROOT)
        for key in sorted(keys - counters):
            errors.append(f"{rel}: stats[{key!r}] is not a declared counter")
        for kind, names in calls.items():
            for name in sorted(names - decls[kind]):
                errors.append(f"{rel}: {kind} {name!r} is not in the "
                              f"canonical declaration dicts")
    if ARCH_MD.exists():
        table = _documented(ARCH_MD)
        for name in sorted(counters - table):
            errors.append(f"architecture.md: counter `{name}` missing from "
                          f"the stats table")
    else:
        errors.append("docs/architecture.md does not exist")
    if OBS_MD.exists():
        documented = _documented(OBS_MD)
        for kind in ("counter", "gauge", "histogram"):
            for name in sorted(decls[kind] - documented):
                errors.append(f"observability.md: {kind} `{name}` is "
                              f"undocumented")
    else:
        errors.append("docs/observability.md does not exist")
    return errors


def main() -> int:
    errors = run_checks()
    for e in errors:
        print(f"[stats] FAIL: {e}")
    if errors:
        return 1
    decls = declared()
    print(f"[stats] ok ({len(decls['counter'])} counters, "
          f"{len(decls['gauge'])} gauges, "
          f"{len(decls['histogram'])} histograms declared + documented)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
