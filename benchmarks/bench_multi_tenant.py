"""Multi-tenant secure serving: throughput + protection traffic.

Sweeps the tenancy axes of the serving engine: tenant count {1, 2, 4}
(requests interleaved round-robin across tenant sessions) and key
rotation period, across every protection scheme in
:data:`repro.core.secure_exec.SCHEMES`, reporting

* steady-state decode throughput (tokens/s, compile excluded),
* HLO-visible protection traffic of the tenant-aware decode step
  (``bytes accessed`` minus the ``off`` scheme at the same tenant
  count — the cost of per-page key gathering + (tenant, epoch) RePA
  binding on top of the baseline), and
* scheduler counters (preemptions, rotations) + latency percentiles.

Standalone JSON mode for the CI perf-smoke job::

    PYTHONPATH=src python benchmarks/bench_multi_tenant.py \
        --tenant-counts 1,2 --gen-len 6 --json results.json
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.secure_exec import SCHEMES
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.serve.engine import SecureServingEngine
from repro.tenancy import KeyHierarchy, TenantRegistry

try:                                    # package or script invocation
    from benchmarks._meta import stamp
except ImportError:
    from _meta import stamp

DEFAULT_TENANTS = (1, 2, 4)
# Rotation period in ticks; 0 = never.  Must stay below the ~gen_len
# tick run length or the rotation rows silently measure no rotations.
DEFAULT_ROTATIONS = (0, 4)


def _measure(arch, cfg, params, scheme: str, n_tenants: int, *,
             rotate_every: int, batch: int, page_tokens: int,
             pages_per_slot: int, gen_len: int, prompt_len: int,
             seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    registry = TenantRegistry(KeyHierarchy(seed), max_tenants=n_tenants)
    sessions = []
    for t in range(n_tenants):
        registry.register(f"tenant-{t}")
        sessions.append(registry.open_session(f"tenant-{t}"))
    eng = SecureServingEngine(
        arch, cfg, params, scheme=scheme, max_slots=batch,
        page_tokens=page_tokens, pages_per_slot=pages_per_slot,
        n_pages=batch * pages_per_slot, registry=registry,
        rotate_every=rotate_every)
    for i in range(batch):
        prompt = list(map(int, rng.integers(1, cfg.vocab, prompt_len)))
        eng.submit(prompt=prompt, max_new_tokens=gen_len,
                   session=sessions[i % n_tenants])
    eng.step()                       # admission + first decode (compiles)
    t0 = time.perf_counter()
    steps = 0
    while any(s is not None for s in eng.slots) or eng._n_waiting():
        eng.step()
        steps += 1
    dt = time.perf_counter() - t0
    cost = eng.decode_cost_analysis()
    return {
        "scheme": scheme,
        "tenants": n_tenants,
        "rotate_every": rotate_every,
        "decode_steps_timed": steps,
        "tok_per_s": batch * steps / max(dt, 1e-9),
        "us_per_step": dt / max(steps, 1) * 1e6,
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "preemptions": eng.stats["preemptions"],
        "rotations": eng.stats["rotations"],
        "latency": eng.latency_stats(),
    }


def collect(schemes=tuple(SCHEMES), tenant_counts=DEFAULT_TENANTS,
            rotations=DEFAULT_ROTATIONS, *, arch_name: str = "minitron-4b",
            batch: int = 4, page_tokens: int = 8, pages_per_slot: int = 4,
            gen_len: int = 8, prompt_len: int = 9) -> list:
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    results = []
    for n_tenants in tenant_counts:
        for rotate_every in rotations:
            base_bytes = None
            for scheme in schemes:
                r = _measure(arch, cfg, params, scheme, n_tenants,
                             rotate_every=rotate_every, batch=batch,
                             page_tokens=page_tokens,
                             pages_per_slot=pages_per_slot,
                             gen_len=gen_len, prompt_len=prompt_len)
                if scheme == "off":
                    base_bytes = r["bytes_accessed"]
                if base_bytes:
                    r["protection_traffic_bytes"] = (r["bytes_accessed"]
                                                     - base_bytes)
                    r["traffic_overhead"] = (r["bytes_accessed"] / base_bytes
                                             - 1)
                results.append(r)
    return results


def run() -> list:
    """benchmarks.run suite hook: CSV rows for a reduced sweep."""
    rows = []
    for r in collect(schemes=("off", "seda", "mgx64"),
                     tenant_counts=(1, 2), rotations=(0, 4), gen_len=6):
        overhead = r.get("traffic_overhead")
        derived = (f"tok/s={r['tok_per_s']:.1f} "
                   f"rotations={r['rotations']}")
        if overhead is not None:
            derived += f" traffic_overhead={overhead:+.1%}"
        rows.append({
            "name": (f"mt_{r['scheme']}_t{r['tenants']}"
                     f"_r{r['rotate_every']}"),
            "us_per_call": r["us_per_step"],
            "derived": derived,
        })
    return rows


def main(argv=None) -> list:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--schemes", default=",".join(SCHEMES))
    ap.add_argument("--tenant-counts",
                    default=",".join(map(str, DEFAULT_TENANTS)))
    ap.add_argument("--rotations",
                    default=",".join(map(str, DEFAULT_ROTATIONS)))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--page-tokens", type=int, default=8)
    ap.add_argument("--pages-per-slot", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=9)
    ap.add_argument("--json", default=None, help="write results to this file")
    args = ap.parse_args(argv)

    results = collect(
        schemes=tuple(args.schemes.split(",")),
        tenant_counts=tuple(int(t) for t in args.tenant_counts.split(",")),
        rotations=tuple(int(r) for r in args.rotations.split(",")),
        arch_name=args.arch, batch=args.batch, page_tokens=args.page_tokens,
        pages_per_slot=args.pages_per_slot, gen_len=args.gen_len,
        prompt_len=args.prompt_len)
    for r in results:
        print(f"[mt-bench] scheme={r['scheme']:<8} tenants={r['tenants']:<2} "
              f"rot={r['rotate_every']:<3} tok/s={r['tok_per_s']:9.1f} "
              f"traffic={r.get('protection_traffic_bytes', 0):12.0f}B")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(stamp({"benchmark": "multi_tenant_serving",
                             "results": results}), f, indent=2)
        print(f"[mt-bench] wrote {args.json}")
    return results


if __name__ == "__main__":
    main()
