"""Wrapper: fused secure-read (decrypt + verify hash) for flat buffers."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mac
from repro.core.bytesutil import bytes_to_u32, u32_to_bytes
from repro.kernels.aes_ctr.ops import (keystream_bytes, keystream_bytes_multi,
                                       keystream_lanes, keystream_lanes_multi)
from repro.kernels.fused_crypt_mac.kernel import (fused_crypt_mac,
                                                  fused_crypt_mac_mixed)
from repro.kernels.otp_xor.ops import _div_lanes

__all__ = ["secure_read_kernel", "secure_read_kernel_mixed",
           "fused_crypt_mac", "fused_crypt_mac_mixed"]


def secure_read_kernel(ct_u8: jax.Array, binding: mac.Binding,
                       round_keys: jax.Array, counter_words: jax.Array,
                       hash_key_u32: jax.Array, *, block_bytes: int,
                       subbytes: str = "take",
                       interpret: bool | None = None):
    """Kernel-backed secure read: returns (plaintext_u8, block_macs_u8).

    One pass over the ciphertext performs both the B-AES decrypt and
    the NH compression; the AES finalization of the MACs runs on the
    tiny hash list.  Bit-identical to the unfused core path.
    """
    n_segments = block_bytes // 16
    if n_segments - 1 > 10:
        raise ValueError("kernel path supports narrow mode (<= 11 segments)")
    base = keystream_lanes(counter_words, round_keys, subbytes=subbytes,
                           interpret=interpret)
    ct = bytes_to_u32(ct_u8).reshape(-1, n_segments * 4)
    n = ct.shape[0]
    div = _div_lanes(round_keys, n_segments)
    bind_words = binding.words(n)
    key = hash_key_u32[: ct.shape[1] + 8]
    pt_lanes, hashes = fused_crypt_mac(ct, base, div, bind_words, key,
                                       interpret=interpret)
    fin = mac.finalize_words(hashes[:, 0], hashes[:, 1], binding)
    pads = keystream_bytes(fin, round_keys, subbytes=subbytes,
                           interpret=interpret)
    pt = u32_to_bytes(pt_lanes.reshape(-1)).reshape(ct_u8.shape)
    return pt, pads[:, : mac.MAC_BYTES]


def secure_read_kernel_mixed(ct_u8: jax.Array, binding: mac.Binding,
                             bank_round_keys: jax.Array,
                             counter_words: jax.Array,
                             bank_hash_key: jax.Array, row_idx: jax.Array, *,
                             block_bytes: int, subbytes: str = "take",
                             interpret: bool | None = None):
    """Mixed-key fused secure read: per-BLOCK keys gathered from a bank.

    Args:
      bank_round_keys: (K, 11, 16) u8 — the device key bank's schedules
        (one row per retained (tenant, epoch)).
      bank_hash_key: (K, n_lanes) u32 NH key rows.
      row_idx: (N,) int32 bank row per optBlk (a page's row repeated
        over its blocks).

    Every block is decrypted and NH-hashed under its OWN bank row in
    one fused pass — the route that keeps MIXED-row decode ticks on the
    fused kernels instead of falling back to the vmapped per-page
    reference.  Bit-identical to that vmapped path.
    """
    n_segments = block_bytes // 16
    if n_segments - 1 > 10:
        raise ValueError("kernel path supports narrow mode (<= 11 segments)")
    rk_blocks = bank_round_keys[row_idx]                 # (N, 11, 16)
    base = keystream_lanes_multi(counter_words, rk_blocks,
                                 subbytes=subbytes, interpret=interpret)
    ct = bytes_to_u32(ct_u8).reshape(-1, n_segments * 4)
    n = ct.shape[0]
    # Diversifiers are a pure function of a row's schedule: build the
    # (K, S, 4) bank once, then gather rows per block.
    div_bank = jax.vmap(lambda rk: _div_lanes(rk, n_segments))(
        bank_round_keys)
    div = div_bank[row_idx]                              # (N, S, 4)
    bind_words = binding.words(n)
    key = bank_hash_key[:, : ct.shape[1] + 8].astype(jnp.uint32)[row_idx]
    pt_lanes, hashes = fused_crypt_mac_mixed(ct, base, div, bind_words, key,
                                             interpret=interpret)
    fin = mac.finalize_words(hashes[:, 0], hashes[:, 1], binding)
    pads = keystream_bytes_multi(fin, rk_blocks, subbytes=subbytes,
                                 interpret=interpret)
    pt = u32_to_bytes(pt_lanes.reshape(-1)).reshape(ct_u8.shape)
    return pt, pads[:, : mac.MAC_BYTES]
