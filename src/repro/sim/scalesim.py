"""SCALE-Sim-style analytic systolic-array model.

For every layer we derive (a) compute cycles on the PE array and (b)
the off-chip DRAM traffic as a set of *streams* — (total payload bytes,
contiguous chunk size, read/write).  Chunks matter: DRAM serves 64B
bursts, and protection schemes fetch at their own granularity, so both
the baseline and the overlay round chunks to their access size
(:mod:`repro.sim.memprot`).

Traffic honors SRAM capacity (operands that fit stream once; operands
that do not are re-fetched once per tile sweep of the non-resident
dimension — SCALE-Sim's double-buffered behaviour).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.sim.npu_configs import NPUConfig
from repro.sim.workloads import Layer, Workload

__all__ = ["Stream", "LayerTrace", "WorkloadTrace", "simulate_layer",
           "simulate_workload", "BURST_BYTES"]

BURST_BYTES = 64  # DRAM burst: baseline access granularity

# Systolic-array pipeline inefficiency vs. the ideal tile formula:
# inter-tile bubbles, edge tiles, accumulation stalls (SCALE-Sim traces
# consistently run above the closed form).
ARRAY_OVERHEAD = 1.35


@dataclass(frozen=True)
class Stream:
    name: str            # ifmap | filter | ofmap | embed
    total_bytes: float   # payload (pre-rounding)
    chunk_bytes: float   # contiguous bytes per request
    is_write: bool
    has_halo: bool = False
    halo_fraction: float = 0.0

    def burst_bytes(self) -> float:
        """Bytes actually moved at 64B-burst granularity (baseline)."""
        return rounded_bytes(self.total_bytes, self.chunk_bytes, BURST_BYTES)


def rounded_bytes(total: float, chunk: float, granularity: int) -> float:
    """Total bytes when each chunk is fetched at ``granularity`` units."""
    if total <= 0:
        return 0.0
    chunk = max(chunk, 1.0)
    n_chunks = max(1.0, total / chunk)
    per_chunk = -(-chunk // granularity) * granularity
    return n_chunks * per_chunk


@dataclass(frozen=True)
class LayerTrace:
    layer: Layer
    compute_cycles: float
    streams: tuple  # tuple[Stream, ...]
    tile_rows: int
    tile_cols: int

    @property
    def total_bytes(self) -> float:
        """Baseline off-chip traffic (64B-burst granularity)."""
        return sum(s.burst_bytes() for s in self.streams)

    @property
    def read_bytes(self) -> float:
        return sum(s.burst_bytes() for s in self.streams if not s.is_write)

    @property
    def write_bytes(self) -> float:
        return sum(s.burst_bytes() for s in self.streams if s.is_write)


@dataclass(frozen=True)
class WorkloadTrace:
    workload: Workload
    layers: tuple

    @property
    def total_bytes(self) -> float:
        return sum(t.total_bytes for t in self.layers)

    @property
    def compute_cycles(self) -> float:
        return sum(t.compute_cycles for t in self.layers)


def _ceil_div(a: float, b: float) -> int:
    return int(-(-a // b))


def simulate_layer(layer: Layer, npu: NPUConfig) -> LayerTrace:
    p = npu.precision_bytes
    m, k, n = layer.m, layer.k, layer.n

    if layer.kind == "embed":
        # Embedding lookups: SCALE-Sim's topology files express these as
        # dense streaming reads (the gathered rows are staged into a
        # contiguous region before the MLP), so the stream is one span.
        row = n * p
        streams = (Stream("embed", m * row, m * row, False),
                   Stream("ofmap", m * row, m * row, True))
        cycles = (2 * m * row) / max(npu.bytes_per_cycle, 1e-9)
        return LayerTrace(layer, cycles, streams, 1, min(n, npu.pe_cols))

    rows, cols = npu.pe_rows, npu.pe_cols
    tiles_m = _ceil_div(m, rows)
    tiles_n = _ceil_div(n, cols)
    compute_cycles = tiles_m * tiles_n * (k + rows + cols - 2) * ARRAY_OVERHEAD

    ifmap_bytes = m * k * p
    filter_bytes = k * n * p
    ofmap_bytes = m * n * p

    ifmap_sram = npu.sram_bytes * 0.5
    filter_sram = npu.sram_bytes * 0.375

    if_passes = 1 if ifmap_bytes <= ifmap_sram else tiles_n
    fl_passes = 1 if filter_bytes <= filter_sram else tiles_m

    # Contiguous chunks: ifmap rows (W*C in NHWC), filters whole-tensor,
    # ofmap full rows (accumulated in the SRAM ofmap buffer, written
    # once per row of Q*N bytes for conv / N for GEMM).
    # Tensors small enough for a single DMA burst sequence move as one
    # contiguous span; larger tensors are walked in tile-row requests.
    dma_coalesce = 64 * 1024
    if layer.kind in ("conv", "dwconv") and layer.w:
        # Conv: tile windows walk the fmap in NHWC rows — requests are
        # row-sized and repositioned per tile (the paper's intra-layer
        # tiling-misalignment source).
        raw_if = layer.h * layer.w * layer.c * p  # actual fmap footprint
        if_chunk = raw_if if raw_if <= dma_coalesce else layer.w * layer.c * p
        q_out = max(1, int(round(m ** 0.5)))  # output row length (P*Q, ~square)
        of_chunk = (ofmap_bytes if ofmap_bytes <= dma_coalesce
                    else q_out * n * p)       # one NHWC output row
    else:
        # GEMM: operands stream as single contiguous spans per pass.
        if_chunk = ifmap_bytes
        of_chunk = ofmap_bytes

    halo = 0.0
    if layer.has_halo:
        halo = (layer.r - layer.stride) / max(layer.r, 1)

    streams = (
        Stream("ifmap", ifmap_bytes * if_passes, if_chunk, False,
               has_halo=layer.has_halo, halo_fraction=halo),
        Stream("filter", filter_bytes * fl_passes,
               min(filter_bytes, filter_sram), False),
        Stream("ofmap", ofmap_bytes, of_chunk, True),
    )
    return LayerTrace(layer, compute_cycles, streams,
                      min(m, rows), min(n, cols))


def simulate_workload(workload: Workload, npu: NPUConfig) -> WorkloadTrace:
    return WorkloadTrace(workload,
                         tuple(simulate_layer(l, npu) for l in workload.layers))
