"""CI gate: audit proofs must stay logarithmic and near-free.

Reads a ``bench_audit_proofs.py`` JSON artifact and fails (exit 1)
unless:

* every **proof** row has ``proof_len <= ceil(log2(n_pages)) + 1`` —
  the membership proof is O(log n) in the pool size, not O(n) (the
  ``+ 1`` absorbs the next-power-of-two padding of non-power-of-two
  pools);
* every **overhead** row has ``merkle_overhead_pct <= 5`` — the amortized
  ``_tick_end`` Merkle maintenance costs at most 5% tok/s over the
  CBC-MAC/XOR fold levels alone;
* every **overhead** row shows the maintenance actually amortized:
  ``0 < root_updates < ticks`` (a root recompute every tick means the
  deferral never engaged; zero means the maintainer never ran).

Usage::

    python benchmarks/check_audit_proofs.py bench-audit-proofs.json
"""

from __future__ import annotations

import json
import math
import sys

MAX_OVERHEAD_PCT = 5.0


def check_rows(results: list) -> int:
    proof_rows = [r for r in results if r.get("mode") == "proof"]
    over_rows = [r for r in results if r.get("mode") == "overhead"]
    if not proof_rows or not over_rows:
        print("[audit] FAIL: need both proof and overhead rows "
              f"(got {len(proof_rows)}/{len(over_rows)})")
        return 1
    ok = True

    def fail(label: str, msg: str) -> None:
        nonlocal ok
        print(f"[audit] FAIL: {label}: {msg}")
        ok = False

    for r in proof_rows:
        label = r.get("name", "?")
        bound = math.ceil(math.log2(max(r["n_pages"], 2))) + 1
        if r["proof_len"] > bound:
            fail(label, f"proof_len={r['proof_len']} exceeds "
                        f"ceil(log2({r['n_pages']}))+1={bound} — "
                        f"membership proofs are no longer O(log n)")
    for r in over_rows:
        label = r.get("name", "?")
        if r["merkle_overhead_pct"] > MAX_OVERHEAD_PCT:
            fail(label, f"Merkle maintenance costs "
                        f"{r['merkle_overhead_pct']:.2f}% tok/s "
                        f"(budget {MAX_OVERHEAD_PCT}%)")
        if not r.get("root_updates", 0):
            fail(label, "zero root updates — the maintainer never ran, "
                        "the overhead number is vacuous")
        elif r["root_updates"] >= r.get("ticks", 0):
            fail(label, f"root_updates={r['root_updates']} >= "
                        f"ticks={r['ticks']} — maintenance ran every "
                        f"tick, the deferred amortization never engaged")
    print(f"[audit] {len(proof_rows)} proof + {len(over_rows)} overhead "
          f"rows checked")
    return 0 if ok else 1


def check(path: str) -> int:
    with open(path) as f:
        data = json.load(f)
    rc = check_rows(data.get("results", []))
    if rc == 0:
        print("[audit] ok")
    return rc


if __name__ == "__main__":
    sys.exit(check(sys.argv[1]))
