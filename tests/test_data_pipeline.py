"""Deterministic, resumable data pipeline."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.data.pipeline import DataConfig, SyntheticLM, make_batch


def _cfg(**kw):
    base = dict(vocab=101, seq_len=32, global_batch=4, seed=7)
    base.update(kw)
    return DataConfig(**base)


class TestPipeline:
    def test_deterministic_in_step(self):
        b1 = make_batch(_cfg(), 12)
        b2 = make_batch(_cfg(), 12)
        assert (np.asarray(b1["tokens"]) == np.asarray(b2["tokens"])).all()

    def test_different_steps_differ(self):
        b1 = make_batch(_cfg(), 0)
        b2 = make_batch(_cfg(), 1)
        assert not (np.asarray(b1["tokens"])
                    == np.asarray(b2["tokens"])).all()

    def test_labels_are_shifted_tokens(self):
        b = make_batch(_cfg(), 3)
        # labels[t] is the next token after tokens[t] (same underlying
        # stream shifted by one).
        assert (np.asarray(b["tokens"][:, 1:])
                == np.asarray(b["labels"][:, :-1])).all()

    def test_resume_replays_identically(self):
        it = SyntheticLM(_cfg())
        seen = [next(it) for _ in range(5)]
        state = it.state_dict()
        it2 = SyntheticLM(_cfg())
        it2.load_state_dict(state)
        nxt = next(it2)
        ref = make_batch(_cfg(), 5)
        assert (np.asarray(nxt["tokens"]) == np.asarray(ref["tokens"])).all()
        del seen

    def test_seed_mismatch_refused(self):
        it = SyntheticLM(_cfg(seed=1))
        with pytest.raises(AssertionError):
            it.load_state_dict({"step": 3, "seed": 2})

    def test_vlm_and_encdec_batches(self):
        vlm = make_batch(_cfg(kind="vlm", n_image_patches=4, d_vision=8), 0)
        assert vlm["image_embeds"].shape == (4, 4, 8)
        ed = make_batch(_cfg(kind="encdec", d_model=16, src_len=6), 0)
        assert ed["src_embeds"].shape == (4, 6, 16)
        assert "tgt_tokens" in ed

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 10_000), st.integers(2, 500))
    def test_token_range_property(self, step, vocab):
        b = make_batch(_cfg(vocab=vocab), step)
        toks = np.asarray(b["tokens"])
        assert toks.min() >= 0 and toks.max() < vocab
