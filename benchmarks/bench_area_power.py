"""Paper Fig. 4: B-AES vs T-AES area/power scaling with bandwidth."""

from __future__ import annotations

import time

from repro.sim.area_power import scaling_table


def run() -> list:
    rows = []
    t0 = time.perf_counter()
    table = scaling_table(16)
    dt = (time.perf_counter() - t0) * 1e6
    for r in table:
        rows.append({
            "name": f"fig4_bw_x{r['bandwidth_multiple']}",
            "us_per_call": dt / len(table),
            "derived": (f"t_aes_area={r['t_aes_area_mm2']}mm2 "
                        f"b_aes_area={r['b_aes_area_mm2']}mm2 "
                        f"t_aes_power={r['t_aes_power_mw']}mW "
                        f"b_aes_power={r['b_aes_power_mw']}mW "
                        f"area_saving={r['area_saving']:.1%}"),
        })
    return rows
