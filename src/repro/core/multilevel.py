"""Multi-level integrity verification policy (paper §III-C, Table I).

Three granularities:

  optBlk MAC — off-chip, flexible, avoids redundant re-auth of tile
               overlaps (granularity from the SecureLoop-style search);
  layer MAC  — XOR of a layer's optBlk MACs; small enough for on-chip
               SRAM (or off-chip "for fairness", as the paper's eval
               does) => near-zero metadata traffic;
  model MAC  — one MAC for all weights, verified at end of inference.

``VerifyPolicy`` selects which level gates a read (block/layer) and
which is deferred (model).  The policy also records *where* each level
resides (on-chip vs off-chip) — the `sim/` package uses the same enum
to charge DRAM traffic for off-chip metadata.
"""

from __future__ import annotations

import enum
from typing import NamedTuple

__all__ = ["Level", "Residency", "VerifyPolicy", "SEDA_DEFAULT", "SGX_LIKE", "MGX_LIKE"]


class Level(enum.IntEnum):
    OPTBLK = 0
    LAYER = 1
    MODEL = 2


class Residency(enum.IntEnum):
    ONCHIP = 0
    OFFCHIP = 1


class VerifyPolicy(NamedTuple):
    """Which MAC levels exist, where they live, and which gates reads."""

    gate_level: Level              # verification required before data is used
    deferred_model_mac: bool       # model MAC checked at end of inference
    layer_residency: Residency     # paper stores layer MACs off-chip "for fairness"
    optblk_residency: Residency
    has_integrity_tree: bool       # SGX-style VN/MT traffic (sim only)
    per_block_vn_offchip: bool     # SGX stores VNs off-chip; MGX/SeDA derive on-chip

    @property
    def name(self) -> str:
        return f"gate={self.gate_level.name.lower()}"


# SeDA: layer MAC gates reads; optBlk MACs never leave the chip during
# steady-state (they are recomputed and XOR-folded on the fly); model
# MAC deferred.
SEDA_DEFAULT = VerifyPolicy(
    gate_level=Level.LAYER,
    deferred_model_mac=True,
    layer_residency=Residency.ONCHIP,
    optblk_residency=Residency.ONCHIP,
    has_integrity_tree=False,
    per_block_vn_offchip=False,
)

# SGX-like: per-block MAC + off-chip VN + integrity tree.
SGX_LIKE = VerifyPolicy(
    gate_level=Level.OPTBLK,
    deferred_model_mac=False,
    layer_residency=Residency.OFFCHIP,
    optblk_residency=Residency.OFFCHIP,
    has_integrity_tree=True,
    per_block_vn_offchip=True,
)

# MGX-like: per-block MAC off-chip, VNs derived on-chip, no tree.
MGX_LIKE = VerifyPolicy(
    gate_level=Level.OPTBLK,
    deferred_model_mac=False,
    layer_residency=Residency.OFFCHIP,
    optblk_residency=Residency.OFFCHIP,
    has_integrity_tree=False,
    per_block_vn_offchip=False,
)
