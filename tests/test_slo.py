"""Per-tenant SLO watchdog: breach counters, audit events, health.

Breaches are injected deterministically — a fake ``now`` for the
stuck-tick watchdog, direct verdict-hook calls for the integrity
alarm, an absurdly tight target for TTFT — so the tests never depend
on wall-clock speed.  The observation-only contract also holds:
attaching a monitor must not change a single generated token.
"""

import time

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import lm as lm_mod
from repro.models.layers import init_params
from repro.obs.audit import AuditLog
from repro.obs.slo import SLOMonitor, merge_health
from repro.serve.engine import SecureServingEngine


@pytest.fixture(scope="module")
def smoke():
    arch = get_arch("minitron-4b")
    cfg = arch.make_smoke_config()
    params = init_params(lm_mod.lm_specs(cfg), jax.random.PRNGKey(0))
    return arch, cfg, params


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(0)
    return [list(map(int, rng.integers(1, 256, n))) for n in (3, 4)]


def _engine(smoke, **kw):
    arch, cfg, params = smoke
    kw.setdefault("max_slots", 2)
    kw.setdefault("page_tokens", 4)
    kw.setdefault("pages_per_slot", 4)
    return SecureServingEngine(arch, cfg, params, **kw)


def _run_some(eng, prompts, n=4):
    for p in prompts:
        eng.submit(prompt=p, max_new_tokens=n)
    eng.run()


class TestBreaches:
    def test_stalled_tick_fires_counter_audit_and_health(self, smoke,
                                                         prompts):
        eng = _engine(smoke, scheme="seda", audit=AuditLog())
        mon = SLOMonitor(stall_factor=2.0)
        mon.attach(eng)
        _run_some(eng, prompts)
        assert eng.stats["slo_stuck_ticks"] == 0
        assert not mon.hard_breach

        # Idle engine (queue drained, slots empty): never stuck, even
        # an eternity after the last tick.
        assert mon.check_stalled(now=time.monotonic() + 1e6) is False
        assert eng.stats["slo_stuck_ticks"] == 0

        # Inject the stall: queue work, then pretend an eternity
        # passed since the last _tick_end without a tick landing.
        eng.submit(prompt=prompts[0], max_new_tokens=2)
        mon.check_stalled(now=time.monotonic() + 1e6)
        assert eng.stats["slo_stuck_ticks"] == 1
        assert mon.hard_breach
        health = mon.health()
        assert health["status"] == "failing"
        assert health["stuck"] is True
        events = eng.audit.events("slo_breach")
        assert any(e["kind"] == "stuck_tick" for e in events)
        assert eng.audit.verify_chain()
        # Latch: repeated checks while stuck don't re-count.
        mon.check_stalled(now=time.monotonic() + 2e6)
        assert eng.stats["slo_stuck_ticks"] == 1
        # A fresh tick clears the latch.
        _run_some(eng, prompts[:1], n=2)
        assert mon.check_stalled(now=mon._last_end + 1e-9) is False
        assert not mon.hard_breach

    def test_integrity_burst_fires_alarm(self, smoke, prompts):
        eng = _engine(smoke, scheme="seda", audit=AuditLog())
        mon = SLOMonitor(integrity_window=16, integrity_threshold=0.5,
                         integrity_min_failures=3)
        mon.attach(eng)
        _run_some(eng, prompts)
        assert eng.stats["slo_integrity_alarms"] == 0

        for _ in range(4):                      # injected IntegrityError burst
            for hook in eng.page_io.verdict_hooks:
                hook(False, "read", {"slot": 0})
        assert eng.stats["slo_integrity_alarms"] == 1
        assert mon.hard_breach
        health = mon.health()
        assert health["status"] == "failing"
        assert health["integrity"]["alarm"] is True
        assert health["integrity"]["failures"] >= 3
        events = eng.audit.events("slo_breach")
        assert any(e["kind"] == "integrity_rate" for e in events)
        # More failures while alarmed: no double-count (transition-based).
        for hook in eng.page_io.verdict_hooks:
            hook(False, "read", {"slot": 0})
        assert eng.stats["slo_integrity_alarms"] == 1
        # A run of successes clears the alarm.
        for _ in range(64):
            for hook in eng.page_io.verdict_hooks:
                hook(True, "read", {"slot": 0})
        assert not mon.hard_breach

    def test_ttft_breach_per_tenant(self, smoke, prompts):
        eng = _engine(smoke, scheme="off", audit=AuditLog())
        mon = SLOMonitor(ttft_ms=1e-6)          # nothing can meet this
        mon.attach(eng)
        _run_some(eng, prompts)
        assert eng.stats["slo_ttft_breaches"] == len(prompts)
        health = mon.health()
        assert health["tenants"]["default"]["breaches"] == len(prompts)
        # TTFT alone degrades but is not a hard breach.
        assert health["status"] == "degraded"
        assert not mon.hard_breach

    def test_tick_p99_breach(self, smoke, prompts):
        eng = _engine(smoke, scheme="off")
        mon = SLOMonitor(p99_tick_ms=1e-9, min_ticks=2)
        mon.attach(eng)
        _run_some(eng, prompts)
        assert eng.stats["slo_tick_p99_breaches"] == 1   # transition, once
        assert mon.health()["ticks"]["p99_breached"] is True


class TestContract:
    def test_tokens_bit_identical_with_monitor(self, smoke, prompts):
        bare = _engine(smoke, scheme="seda")
        rids = [bare.submit(prompt=p, max_new_tokens=4) for p in prompts]
        want = [bare.run()[r].generated for r in rids]

        eng = _engine(smoke, scheme="seda", audit=AuditLog())
        SLOMonitor(ttft_ms=1e-6, p99_tick_ms=1e-9).attach(eng)
        rids = [eng.submit(prompt=p, max_new_tokens=4) for p in prompts]
        done = eng.run()
        assert [done[r].generated for r in rids] == want

    def test_attach_twice_rejected(self, smoke):
        eng = _engine(smoke, scheme="off")
        SLOMonitor().attach(eng)
        with pytest.raises(ValueError):
            SLOMonitor().attach(eng)

    def test_no_monitor_no_hooks(self, smoke):
        eng = _engine(smoke, scheme="off")
        assert not any(
            isinstance(getattr(h, "__self__", None), SLOMonitor)
            for h in eng.page_io.verdict_hooks)
        assert not hasattr(eng, "slo")


class TestHealth:
    def test_merge_health_worst_wins(self):
        ok = {"status": "ok", "shard": 0}
        degraded = {"status": "degraded", "shard": 1}
        failing = {"status": "failing", "shard": 2}
        assert merge_health([ok, ok])["status"] == "ok"
        assert merge_health([ok, degraded])["status"] == "degraded"
        merged = merge_health([ok, degraded, failing])
        assert merged["status"] == "failing"
        assert len(merged["shards"]) == 3
