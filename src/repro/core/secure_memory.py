"""SecureRegion: the boundary crossing for pytrees.

``protect``  = encrypt (B-AES) + multi-level MAC   (write to untrusted)
``unprotect`` = decrypt + verify                    (read from untrusted)

Everything is jit-compatible; static structure (address map, specs,
granularity) is captured in a ``RegionSpec`` built once per pytree
structure.  The B-AES mechanism means the AES work per protected byte
is ``1/(block_bytes/16)`` of the traditional path — the paper's
hardware saving shows up directly as compute saving here (one AES
invocation per wide block, wide XOR for the rest).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aes, baes, mac, vn
from repro.core.bytesutil import bytes_to_tensor, tensor_to_bytes
from repro.core.layout import SEGMENT_BYTES, AddressMap, build_address_map

__all__ = ["SecureKeys", "RegionSpec", "SecureState", "protect", "unprotect",
           "make_region_spec"]


class SecureKeys(NamedTuple):
    key: jax.Array         # (16,) uint8 AES key (Ke)
    round_keys: jax.Array  # (11, 16) uint8 schedule
    hash_key: jax.Array    # (n_lanes,) uint32 NH key (Kh)

    @staticmethod
    def derive(seed: int | jax.Array, *, nh_lanes: int = 2048) -> "SecureKeys":
        """Derive session keys from a seed (stand-in for a fused root key).

        ``nh_lanes`` bounds the supported optBlk size: payload lanes =
        block_bytes/4 + 8 must not exceed it (2048 lanes covers 8KB
        blocks).
        """
        rng = np.random.default_rng(np.uint32(seed) if np.isscalar(seed) else None)
        key_np = rng.integers(0, 256, size=16, dtype=np.uint8)
        hash_np = rng.integers(0, 2 ** 32, size=nh_lanes, dtype=np.uint32)
        return SecureKeys(
            key=jnp.asarray(key_np),
            round_keys=jnp.asarray(aes.key_expansion_np(key_np)),
            hash_key=jnp.asarray(hash_np),
        )


class RegionSpec(NamedTuple):
    """Static description of a protected pytree (hashable/static arg)."""

    treedef: Any
    addr_map: AddressMap
    block_bytes: int
    mac_engine: str
    role: int
    n_layers: int
    use_baes: bool = True  # False = T-AES: one AES call per 16B segment


class SecureState(NamedTuple):
    """The pytree as it lives in untrusted memory."""

    ciphertexts: tuple         # flat tuple of uint8 buffers (padded)
    layer_macs: jax.Array      # (n_layers, 8) uint8
    model_mac: jax.Array       # (8,) uint8
    vn_lo: jax.Array           # scalar uint32 version number used


def make_region_spec(tree: Any, *, block_bytes: int = 64, mac_engine: str = "nh",
                     role: int = int(vn.Role.WEIGHT), layer_of=None,
                     use_baes: bool = True) -> RegionSpec:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    addr_map = build_address_map(tree, block_bytes=block_bytes, layer_of=layer_of)
    n_layers = 1 + max((l.layer_id for l in addr_map.leaves), default=0)
    return RegionSpec(treedef, addr_map, block_bytes, mac_engine, role, n_layers,
                      use_baes)


def _encrypt(buf, keys: SecureKeys, counters, spec: RegionSpec, layout):
    """Dispatch B-AES (one AES per wide block) vs T-AES (per segment)."""
    if spec.use_baes:
        return baes.baes_encrypt(buf, keys.round_keys, counters,
                                 block_bytes=spec.block_bytes, key=keys.key)
    from repro.core import ctr as _ctr
    return _ctr.ctr_encrypt(buf, keys.round_keys,
                            jnp.uint32(0), jnp.uint32(layout.pa_base),
                            jnp.uint32(0), counters[0, 3])


def _leaf_counters(layout, n_blocks: int, vn_lo, block_bytes: int) -> jax.Array:
    """(n_blocks, 4) uint32 PA||VN counter words for one leaf."""
    seg_per_blk = block_bytes // SEGMENT_BYTES
    pa = jnp.uint32(layout.pa_base) + jnp.arange(n_blocks, dtype=jnp.uint32) * seg_per_blk
    zeros = jnp.zeros_like(pa)
    vn_col = jnp.broadcast_to(jnp.asarray(vn_lo, jnp.uint32), pa.shape)
    return jnp.stack([zeros, pa, zeros, vn_col], axis=-1)


def _leaf_binding(layout, n_blocks: int, vn_lo, block_bytes: int) -> mac.Binding:
    seg_per_blk = block_bytes // SEGMENT_BYTES
    pa = jnp.uint32(layout.pa_base) + jnp.arange(n_blocks, dtype=jnp.uint32) * seg_per_blk
    return mac.Binding.make(
        pa, jnp.asarray(vn_lo, jnp.uint32), layout.layer_id, layout.fmap_idx,
        jnp.arange(n_blocks, dtype=jnp.uint32))


@functools.partial(jax.jit, static_argnames=("spec",))
def protect(tree: Any, keys: SecureKeys, spec: RegionSpec, *, step=0) -> SecureState:
    """Encrypt + MAC a pytree for residency in untrusted memory."""
    leaves = jax.tree_util.tree_leaves(tree)
    vn_lo = vn.vn_for(spec.role, layer_id=0, step=step)
    ciphertexts = []
    layer_macs = jnp.zeros((spec.n_layers, mac.MAC_BYTES), jnp.uint8)
    for leaf, layout in zip(leaves, spec.addr_map.leaves):
        buf = tensor_to_bytes(leaf, multiple=spec.block_bytes)
        n_blocks = buf.shape[0] // spec.block_bytes
        counters = _leaf_counters(layout, n_blocks, vn_lo, spec.block_bytes)
        ct = _encrypt(buf, keys, counters, spec, layout)
        binding = _leaf_binding(layout, n_blocks, vn_lo, spec.block_bytes)
        macs = mac.block_macs(ct.reshape(n_blocks, spec.block_bytes), binding,
                              hash_key_u32=keys.hash_key,
                              round_keys=keys.round_keys, engine=spec.mac_engine)
        leaf_agg = mac.xor_aggregate(macs)
        layer_macs = layer_macs.at[layout.layer_id].set(
            layer_macs[layout.layer_id] ^ leaf_agg)
        ciphertexts.append(ct)
    return SecureState(tuple(ciphertexts), layer_macs,
                       mac.model_mac(layer_macs), jnp.asarray(vn_lo, jnp.uint32))


@functools.partial(jax.jit, static_argnames=("spec", "verify"))
def unprotect(state: SecureState, keys: SecureKeys, spec: RegionSpec,
              *, verify: str = "layer") -> tuple[Any, jax.Array]:
    """Decrypt + verify; returns (pytree, ok).

    verify: "layer" recomputes layer MACs and compares (SeDA gate);
    "model" compares only the model MAC (deferred check);
    "none" skips verification (unprotected read).
    """
    leaves = []
    layer_macs = jnp.zeros((spec.n_layers, mac.MAC_BYTES), jnp.uint8)
    for ct, layout in zip(state.ciphertexts, spec.addr_map.leaves):
        n_blocks = ct.shape[0] // spec.block_bytes
        counters = _leaf_counters(layout, n_blocks, state.vn_lo, spec.block_bytes)
        if verify != "none":
            binding = _leaf_binding(layout, n_blocks, state.vn_lo, spec.block_bytes)
            macs = mac.block_macs(ct.reshape(n_blocks, spec.block_bytes), binding,
                                  hash_key_u32=keys.hash_key,
                                  round_keys=keys.round_keys,
                                  engine=spec.mac_engine)
            layer_macs = layer_macs.at[layout.layer_id].set(
                layer_macs[layout.layer_id] ^ mac.xor_aggregate(macs))
        pt = _encrypt(ct, keys, counters, spec, layout)  # XOR cipher: enc == dec
        leaves.append(bytes_to_tensor(pt, layout.spec))
    tree = jax.tree_util.tree_unflatten(spec.treedef, leaves)
    if verify == "layer":
        ok = jnp.all(layer_macs == state.layer_macs)
    elif verify == "model":
        ok = jnp.all(mac.model_mac(layer_macs) == state.model_mac)
    else:
        ok = jnp.asarray(True)
    return tree, ok
