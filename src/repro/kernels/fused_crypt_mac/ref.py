"""Oracle for the fused decrypt+NH kernel: composition of the two refs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mac
from repro.kernels.otp_xor.ref import otp_xor_ref

__all__ = ["fused_crypt_mac_ref"]


def fused_crypt_mac_ref(ct_lanes: jax.Array, base_otp_lanes: jax.Array,
                        div_lanes: jax.Array, bind_words: jax.Array,
                        key_u32: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Decrypt wide blocks AND compute their NH hashes (over ciphertext).

    Args:
      ct_lanes: (N, S*4) u32 ciphertext lanes.
      base_otp_lanes: (N, 4) u32.
      div_lanes: (S, 4) u32.
      bind_words: (N, 8) u32 binding words appended to the NH payload.
      key_u32: (S*4 + 8,) u32 NH key.

    Returns (plaintext lanes (N, S*4), hashes (N, 2)).
    """
    pt = otp_xor_ref(ct_lanes, base_otp_lanes, div_lanes)
    payload = jnp.concatenate([ct_lanes, bind_words], axis=-1)
    hi, lo = mac.nh_hash(payload, key_u32)
    return pt, jnp.stack([hi, lo], axis=-1)
