"""Multi-device sharded view over per-shard paged KV pools.

One :class:`~repro.serve.engine.SecureServingEngine` per accelerator
owns a :class:`~repro.serve.kv_pages.PagedKVPool` whose RePA bindings
and CTR counters carry that shard's id (see :mod:`repro.serve.kv_pages`
"Sharded pools").  This module is the level *above*: a
:class:`ShardedKVPool` aggregates the per-shard pools into one logical
cache with

* **shard-local free lists** — page allocation never crosses a device
  or takes a cluster-wide lock; each shard engine allocates from its
  own list and the cluster scheduler only moves *requests* (or, via
  secure migration, whole pages) between shards;
* **a cluster root MAC** — SeDA's integrity hierarchy (block MAC →
  page VN → deferred pool MAC) extended one level up: each shard's
  deferred pool MAC is mirrored incrementally from pool-MAC deltas on
  every pool update, and the root is a **keyed CBC-MAC compression**
  over the ordered ``(shard id, pool MAC)`` pairs, seeded with the
  shard *count*.  Unlike the XOR fold it replaces, the root therefore
  binds position and fan-out: swapping two shards' (byte-identical)
  pool MACs, dropping a shard, or presenting the same MACs under a
  different cluster size all change the root.  The mirror update is a
  listener on each engine's pool assignment, so it stays off the
  decode critical path and never forces a device sync (deltas hop to
  the root's device as async 8-byte transfers; the AES compression
  runs only at check time);
* **a deferred root check** — off the critical path, verify every
  shard's pool MAC against its page MACs AND the compression of all
  shard pool MACs against the compression of the mirrors.  A shard
  silently swapping its whole pool state (a cross-shard variant of the
  splicing attack the pool MAC defeats within one device) fails the
  root;
* **an auditable cluster root** — the same fold-in/fold-out shard set,
  compressed one more way: each shard engine's listener-maintained
  Merkle tree (:mod:`repro.serve.merkle_pool`) publishes a root, and
  :attr:`ShardedKVPool.merkle_root` hash-compresses the ordered
  ``(shard, Merkle root)`` pairs so a tenant can chain a page-level
  membership proof up to the cluster root with no keys and no pool
  access.  ``deferred_root_check`` additionally verifies every active
  shard's tree against a from-scratch rebuild, so a listener-bypass
  swap fails the auditable level exactly as it fails the mirrors.

Cross-device replay is defeated one level down (shard-id binding in
:mod:`kv_pages`); this module's job is aggregate bookkeeping and the
secure-migration plumbing between two shards' pools.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aes, ctr, mac

__all__ = ["ShardedKVPool"]


class ShardedKVPool:
    """Aggregate view + root MAC over the pools of N shard engines.

    Built by :class:`repro.serve.cluster.ClusterEngine`; usable
    standalone over any list of engines whose specs agree on layout::

        sharded = ShardedKVPool(engines)
        ...  # engines serve; every pool update folds into the mirrors
        assert sharded.deferred_root_check()
    """

    def __init__(self, engines, *, root_device=None):
        if not engines:
            raise ValueError("a sharded pool needs at least one shard")
        layouts = {(e.spec.leaves, e.spec.page_tokens, e.spec.n_pages,
                    e.spec.scheme) for e in engines}
        if len(layouts) != 1:
            raise ValueError("shard engines must share one pool layout "
                             "(leaves, page_tokens, n_pages, scheme)")
        shards = sorted(e.spec.shard for e in engines)
        if shards != list(range(len(engines))):
            raise ValueError(f"engines carry shard ids {shards}, expected "
                             f"0..{len(engines) - 1}")
        self.engines = sorted(engines, key=lambda e: e.spec.shard)
        self._root_dev = root_device or jax.devices()[0]
        # The compression key: the engines' shared AES schedule (every
        # shard is constructed with the same SecureKeys; shard 0's copy
        # is authoritative for the root).
        self._root_rk = jax.device_put(
            self.engines[0].keys.round_keys, self._root_dev)
        # Per-shard pool-MAC mirrors, maintained incrementally.
        self._mirrors = [jnp.zeros((mac.MAC_BYTES,), jnp.uint8)
                         for _ in self.engines]
        # Shards still contributing to the root (failover folds out).
        self._active = list(range(len(self.engines)))
        for shard, engine in enumerate(self.engines):
            engine.attach_pool_listener(
                lambda old, new, s=shard: self._fold(s, old, new))
            # Fold in whatever state the pool already carries.
            self._fold(shard, None, engine.pool)

    # -- root MAC maintenance -----------------------------------------------

    def _fold(self, shard: int, old_pool, new_pool) -> None:
        if old_pool is None:
            # Wholesale (re-)adoption: the initial fold, or a repair
            # commit after a tamper that bypassed the pool setter — a
            # delta fold there would propagate the attacker's
            # divergence into the mirror.
            self._mirrors[shard] = jnp.asarray(
                jax.device_put(new_pool.pool_mac, self._root_dev))
            return
        delta = old_pool.pool_mac ^ new_pool.pool_mac
        # Async 8-byte hop to the root's device; no host sync.
        self._mirrors[shard] = (self._mirrors[shard]
                                ^ jax.device_put(delta, self._root_dev))

    def fold_out(self, shard: int) -> None:
        """Remove one shard from the root compression (failover).

        The shard's pool MAC no longer participates in the root; the
        compression's length seed and positional chain re-bind the
        reduced shard set on both the actual and mirrored side."""
        if shard in self._active:
            self._active.remove(shard)

    def failing_shards(self) -> list:
        """Active shards whose pool state cannot be trusted: the pool's
        own deferred identity fails, its pool MAC diverged from the
        incrementally-folded mirror, or its Merkle tree no longer
        matches a from-scratch rebuild over the actual page MACs.
        Localizes a root-check failure."""
        from repro.serve import kv_pages as kvp
        bad = []
        for s in self._active:
            engine = self.engines[s]
            if not bool(kvp.deferred_pool_check(engine.pool, engine.spec)):
                bad.append(s)
            elif not np.array_equal(np.asarray(self._mirrors[s]),
                                    np.asarray(engine.pool.pool_mac)):
                bad.append(s)
            elif not self._merkle_ok(engine):
                bad.append(s)
        return bad

    @staticmethod
    def _merkle_ok(engine) -> bool:
        """One shard's listener-maintained Merkle tree vs. a rebuild
        over the pool's actual MAC table — the auditable-level analogue
        of the mirror check (a pool swapped in around the listener
        diverges here even if its XOR identity was patched up)."""
        if engine.merkle is None:
            return True
        from repro.serve import kv_pages as kvp
        return engine.merkle.verify_against(
            kvp.merkle_leaf_macs(engine.pool, engine.spec))

    def _compress(self, pool_macs) -> np.ndarray:
        """Keyed CBC-MAC over the ordered (shard, pool MAC) pairs.

        ``state_0 = AES_K(n_shards ‖ 0)``; then for each shard ``s`` in
        order, ``state = AES_K(state ^ (s ‖ mac_s ‖ 0))``.  The chain
        binds shard order, each shard's MAC value, AND the shard count
        — none of which the XOR fold it replaces could see.  Runs off
        the critical path (check time only).
        """
        seed = jnp.asarray([[len(pool_macs), 0, 0, 0]], jnp.uint32)
        state = aes.aes128_encrypt_block(ctr.counter_blocks(seed),
                                         self._root_rk)
        for s, m in enumerate(pool_macs):
            blk = jnp.zeros((1, 16), jnp.uint8)
            blk = blk.at[0, :4].set(jnp.asarray(
                [s >> 24 & 0xFF, s >> 16 & 0xFF, s >> 8 & 0xFF, s & 0xFF],
                jnp.uint8))
            blk = blk.at[0, 4: 4 + mac.MAC_BYTES].set(
                jax.device_put(jnp.asarray(m, jnp.uint8), self._root_dev))
            state = aes.aes128_encrypt_block(state ^ blk, self._root_rk)
        return np.asarray(state[0, : mac.MAC_BYTES])

    @property
    def root_mac(self) -> jax.Array:
        """The cluster root MAC: the keyed compression of the
        incrementally-maintained per-shard pool-MAC mirrors (active
        shards only — failed-over shards are folded out)."""
        return jnp.asarray(self._compress(
            [self._mirrors[s] for s in self._active]))

    # -- auditable Merkle level ---------------------------------------------

    def merkle_roots(self) -> list:
        """Ordered ``(shard, root)`` pairs of the active shards' Merkle
        roots (syncing each maintainer's pending pool state first).
        Failed-over shards are folded out exactly as they are from the
        pool-MAC compression."""
        pairs = []
        for s in self._active:
            engine = self.engines[s]
            if engine.merkle is None:
                raise ValueError(f"shard {s} was built with merkle=False — "
                                 "no auditable root to compress")
            pairs.append((s, engine.merkle.root()))
        return pairs

    @property
    def merkle_root(self) -> bytes:
        """The auditable cluster root: a hash compression over the
        ordered active ``(shard, Merkle root)`` pairs, seeded with the
        shard count (:func:`repro.serve.merkle_pool.compress_roots`).
        Unlike :attr:`root_mac` this is host-independently recomputable
        by a tenant holding the published shard roots, so cluster audit
        proofs chain leaf -> shard root -> cluster root with no key."""
        from repro.serve import merkle_pool as mkp
        return mkp.compress_roots(self.merkle_roots())

    @property
    def n_shards(self) -> int:
        return len(self.engines)

    @property
    def pools(self) -> list:
        return [e.pool for e in self.engines]

    @property
    def specs(self) -> list:
        return [e.spec for e in self.engines]

    # -- aggregate bookkeeping ----------------------------------------------

    def free_pages(self, shard: int) -> int:
        """Shard-local free list depth (allocation never leaves a shard)."""
        return len(self.engines[shard].free_pages)

    def occupancy(self) -> list:
        """Per-shard resident page count (n_pages - free)."""
        return [e.n_pages - len(e.free_pages) for e in self.engines]

    # -- deferred verification ----------------------------------------------

    def deferred_root_check(self) -> bool:
        """Whole-cluster deferred MAC: every shard's pool MAC verifies
        against its page MACs, and the keyed CBC compression of the
        actual ``(shard, pool MAC)`` sequence matches the compression
        of the incrementally-maintained mirrors.  Off the critical path
        (cluster tick interval / end of run).  Failed-over shards are
        folded out and no longer checked — nothing may trust them."""
        from repro.serve import kv_pages as kvp
        for s in self._active:
            engine = self.engines[s]
            if not bool(kvp.deferred_pool_check(engine.pool, engine.spec)):
                return False
        actual = self._compress([self.engines[s].pool.pool_mac
                                 for s in self._active])
        mirrored = self._compress([self._mirrors[s] for s in self._active])
        if not np.array_equal(actual, mirrored):
            return False
        return all(self._merkle_ok(self.engines[s]) for s in self._active)
