"""Multi-device sharded view over per-shard paged KV pools.

One :class:`~repro.serve.engine.SecureServingEngine` per accelerator
owns a :class:`~repro.serve.kv_pages.PagedKVPool` whose RePA bindings
and CTR counters carry that shard's id (see :mod:`repro.serve.kv_pages`
"Sharded pools").  This module is the level *above*: a
:class:`ShardedKVPool` aggregates the per-shard pools into one logical
cache with

* **shard-local free lists** — page allocation never crosses a device
  or takes a cluster-wide lock; each shard engine allocates from its
  own list and the cluster scheduler only moves *requests* (or, via
  secure migration, whole pages) between shards;
* **a cluster root MAC** — SeDA's integrity hierarchy (block MAC →
  page VN → deferred pool MAC) extended one level up: each shard's
  deferred pool MAC is XOR-folded into a root maintained incrementally
  from pool-MAC deltas on every pool update.  The root update is a
  listener on each engine's pool assignment, so it stays off the
  decode critical path and never forces a device sync (deltas hop to
  the root's device as async 8-byte transfers);
* **a deferred root check** — off the critical path, verify every
  shard's pool MAC against its page MACs AND the XOR of all shard pool
  MACs against the root.  A shard silently swapping its whole pool
  state (a cross-shard variant of the splicing attack the pool MAC
  defeats within one device) fails the root.

Cross-device replay is defeated one level down (shard-id binding in
:mod:`kv_pages`); this module's job is aggregate bookkeeping and the
secure-migration plumbing between two shards' pools.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mac

__all__ = ["ShardedKVPool"]


class ShardedKVPool:
    """Aggregate view + root MAC over the pools of N shard engines.

    Built by :class:`repro.serve.cluster.ClusterEngine`; usable
    standalone over any list of engines whose specs agree on layout::

        sharded = ShardedKVPool(engines)
        ...  # engines serve; every pool update folds into the root
        assert sharded.deferred_root_check()
    """

    def __init__(self, engines, *, root_device=None):
        if not engines:
            raise ValueError("a sharded pool needs at least one shard")
        layouts = {(e.spec.leaves, e.spec.page_tokens, e.spec.n_pages,
                    e.spec.scheme) for e in engines}
        if len(layouts) != 1:
            raise ValueError("shard engines must share one pool layout "
                             "(leaves, page_tokens, n_pages, scheme)")
        shards = sorted(e.spec.shard for e in engines)
        if shards != list(range(len(engines))):
            raise ValueError(f"engines carry shard ids {shards}, expected "
                             f"0..{len(engines) - 1}")
        self.engines = sorted(engines, key=lambda e: e.spec.shard)
        self._root_dev = root_device or jax.devices()[0]
        self._root = jnp.zeros((mac.MAC_BYTES,), jnp.uint8)
        for engine in self.engines:
            engine.attach_pool_listener(self._listener)
            # Fold in whatever state the pool already carries.
            self._fold(None, engine.pool)

    # -- root MAC maintenance -----------------------------------------------

    def _listener(self, old_pool, new_pool) -> None:
        self._fold(old_pool, new_pool)

    def _fold(self, old_pool, new_pool) -> None:
        delta = (new_pool.pool_mac if old_pool is None
                 else old_pool.pool_mac ^ new_pool.pool_mac)
        # Async 8-byte hop to the root's device; no host sync.
        self._root = self._root ^ jax.device_put(delta, self._root_dev)

    @property
    def root_mac(self) -> jax.Array:
        """The incrementally-maintained cluster root MAC."""
        return self._root

    @property
    def n_shards(self) -> int:
        return len(self.engines)

    @property
    def pools(self) -> list:
        return [e.pool for e in self.engines]

    @property
    def specs(self) -> list:
        return [e.spec for e in self.engines]

    # -- aggregate bookkeeping ----------------------------------------------

    def free_pages(self, shard: int) -> int:
        """Shard-local free list depth (allocation never leaves a shard)."""
        return len(self.engines[shard].free_pages)

    def occupancy(self) -> list:
        """Per-shard resident page count (n_pages - free)."""
        return [e.n_pages - len(e.free_pages) for e in self.engines]

    # -- deferred verification ----------------------------------------------

    def deferred_root_check(self) -> bool:
        """Whole-cluster deferred MAC: every shard's pool MAC verifies
        against its page MACs, and the XOR of all shard pool MACs
        matches the incrementally-maintained root.  Off the critical
        path (cluster tick interval / end of run)."""
        from repro.serve import kv_pages as kvp
        for engine in self.engines:
            if not bool(kvp.deferred_pool_check(engine.pool, engine.spec)):
                return False
        agg = np.zeros((mac.MAC_BYTES,), np.uint8)
        for engine in self.engines:
            agg ^= np.asarray(engine.pool.pool_mac)
        return bool(np.array_equal(agg, np.asarray(self._root)))
