"""Serving driver: load a SeDA-secured checkpoint and decode batches.

    PYTHONPATH=src python -m repro.launch.serve --arch minitron-4b \
        --smoke --ckpt-dir /tmp/ck --prompt-len 16 --gen-len 16 --batch 4

Weights restore ONLY if their layer MACs verify (tampered checkpoints
are refused); the deferred model-MAC check runs after the generation
loop (paper Table I semantics).
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.secure_ckpt import latest_step, load_checkpoint
from repro.configs import get_arch
from repro.core.secure_memory import SecureKeys
from repro.models import encdec as ed
from repro.models import lm as lm_mod
from repro.models.layers import init_params, shape_structs
from repro.serve.serve_step import (greedy_sample, make_decode_step,
                                    make_prefill_step)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if arch.kind == "encdec":
        raise SystemExit("use the decoder demo in examples/ for enc-dec")
    cfg = arch.make_smoke_config() if args.smoke else arch.make_config()
    specs = lm_mod.lm_specs(cfg)
    keys = SecureKeys.derive(args.seed)

    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        step = latest_step(args.ckpt_dir)
        path = os.path.join(args.ckpt_dir, f"step_{step:08d}")
        params, _ = load_checkpoint(path, shape_structs(specs), keys)
        print(f"[serve] loaded + verified checkpoint {path}")
    else:
        params = init_params(specs, jax.random.PRNGKey(args.seed))
        print("[serve] no checkpoint: serving fresh init")

    max_len = args.prompt_len + args.gen_len
    prefill = jax.jit(make_prefill_step(arch, cfg, max_len))
    decode = jax.jit(make_decode_step(arch, cfg))

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(
        1, cfg.vocab, (args.batch, args.prompt_len), dtype=np.int64)
        .astype(np.int32))
    logits, caches = prefill(params, {"tokens": prompts})
    tok = greedy_sample(logits)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen_len - 1):
        logits, caches = decode(params, tok, caches)
        tok = greedy_sample(logits)
        out.append(tok)
    dt = time.perf_counter() - t0
    toks = jnp.concatenate(out, axis=1)
    rate = args.batch * args.gen_len / max(dt, 1e-9)
    print(f"[serve] {args.gen_len} tokens x {args.batch} requests "
          f"({rate:.1f} tok/s)")
    return {"tokens": np.asarray(toks), "tok_per_s": rate}


if __name__ == "__main__":
    main()
