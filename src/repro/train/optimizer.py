"""AdamW with dtype-configurable moments (ZeRO-style sharding for free).

Moments inherit each param's sharding (the optimizer update is
elementwise), so FSDP'd params give fully-sharded optimizer state.  The
largest assigned configs set ``state_dtype='bfloat16'`` so the 512-chip
multi-pod training cells fit v5e HBM (configs.OPT_DTYPE_OVERRIDES).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "opt_state_specs",
           "adamw_update"]


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100


class OptState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def opt_state_specs(param_specs: Any, cfg: AdamWConfig) -> OptState:
    """Specs for the optimizer state (mirrors params, state dtype)."""
    def conv(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, cfg.state_dtype, s.axes, "zeros")

    as_state = jax.tree_util.tree_map(
        conv, param_specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return OptState(mu=as_state, nu=as_state,
                    count=ParamSpec((), "int32", (), "zeros"))


def init_opt_state(params: Any, cfg: AdamWConfig) -> OptState:
    dtype = jnp.dtype(cfg.state_dtype)
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, dtype), params)
    return OptState(mu=zeros, nu=zeros, count=jnp.zeros((), jnp.int32))


def _schedule(cfg: AdamWConfig, count):
    warm = jnp.minimum(count.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def adamw_update(grads: Any, params: Any, state: OptState,
                 cfg: AdamWConfig) -> tuple:
    """Returns (new_params, new_state, metrics)."""
    # Global-norm clip in f32.
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    count = state.count + 1
    lr = _schedule(cfg, count)
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    sdtype = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = mf / c1
        vhat = vf / c2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # decay matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), mf.astype(sdtype), vf.astype(sdtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(new_m, new_v, count), {"grad_norm": gnorm, "lr": lr}
