"""Pallas TPU kernels for SeDA's perf-critical compute.

Each kernel package has kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd public wrappers) and ref.py (pure-jnp oracle).
All are validated in interpret mode against their oracles, which chain
back to FIPS-197 test vectors for everything AES-derived.

- aes_ctr        — AES-128-CTR keystream ("AES Engine"); SubBytes via
                   table gather or MXU one-hot matmul
- otp_xor        — fused B-AES diversify + data XOR ("Crypt Engine")
- xormac         — NH universal hash for optBlk MACs ("Integ Engine")
- fused_crypt_mac — beyond-paper single-pass decrypt + hash
"""
